"""Python mirrors of the quantizer family (codebook construction).

The authoritative runtime implementation is Rust (``rust/src/quant``);
these mirrors exist to (a) validate the codebook math in pytest, and
(b) dump a golden ``quant_codebooks.json`` at AOT time that a Rust test
compares bit-for-bit against its own codebooks — a cross-language
consistency check on the format definitions.

All codebooks are the *nonnegative magnitude levels* normalized so the
largest level is 1.0 (the per-tensor scale gamma maps max|w| onto it).
"""

from __future__ import annotations

import json

import numpy as np

# Equal 9-bit storage budget for every scheme (paper Table 1 is "W9A9
# equivalent"): RTN sign+8, PoT/LogQ sign+8-bit exponent, APoT/Delta-PoT
# sign + two 4-bit terms.
RTN_BITS = 9
POT_EXP_BITS = 8
APOT_K = 4
DPOT_K0 = 4
DPOT_K1 = 4


def rtn_levels(bits: int = RTN_BITS) -> np.ndarray:
    qmax = 2 ** (bits - 1) - 1
    return np.arange(0, qmax + 1, dtype=np.float64) / qmax


def pot_levels(exp_bits: int = POT_EXP_BITS) -> np.ndarray:
    """{0} u {2^-e}: exponents 0 .. 2^exp_bits - 1 (deep underflow allowed)."""
    e = np.arange(0, 2 ** exp_bits, dtype=np.float64)
    return np.unique(np.concatenate([[0.0], np.exp2(-e)]))


def logq_levels(exp_bits: int = POT_EXP_BITS) -> np.ndarray:
    """Same level set as PoT; LogQ differs in *assignment* (log-domain
    rounding), see ``quantize_logq``."""
    return pot_levels(exp_bits)


def apot_levels(k: int = APOT_K, n: int = 2) -> np.ndarray:
    """Paper eq (4): p_i in {0, 2^-i, 2^-(i+n), ..., 2^-(i+(2^k-2)n)}."""
    terms = []
    for i in range(n):
        vals = [0.0] + [2.0 ** -(i + j * n) for j in range(2 ** k - 1)]
        terms.append(np.array(vals))
    levels = (terms[0][:, None] + terms[1][None, :]).ravel()
    levels = np.unique(levels)
    return levels / levels.max()


def dpot_levels(k0: int = DPOT_K0, k1: int = DPOT_K1) -> np.ndarray:
    """Paper eq (5)-(6): p0 = 2^-dq0 (dq0 in 1..2^k0-1, 0 -> p0=0),
    p1 = p0 * 2^-dq1 (dq1 in 1..2^k1-1, 0 -> p1=0); level = 2*(p0+p1)."""
    levels = {0.0}
    for dq0 in range(1, 2 ** k0):
        p0 = 2.0 ** -dq0
        levels.add(2.0 * p0)
        for dq1 in range(1, 2 ** k1):
            levels.add(2.0 * (p0 + p0 * 2.0 ** -dq1))
    arr = np.unique(np.array(sorted(levels)))
    return arr / arr.max()


def _nearest(levels: np.ndarray, y: np.ndarray) -> np.ndarray:
    """Map |w|/s values to the nearest codebook level (levels sorted asc)."""
    idx = np.searchsorted(levels, y)
    idx = np.clip(idx, 1, len(levels) - 1)
    lo = levels[idx - 1]
    hi = levels[idx]
    return np.where(y - lo < hi - y, lo, hi)


def fake_quant(w: np.ndarray, levels: np.ndarray) -> np.ndarray:
    """Nearest-level fake quantization with per-tensor max scaling."""
    s = np.abs(w).max()
    if s == 0:
        return w.copy()
    y = np.abs(w) / s
    return np.sign(w) * _nearest(np.asarray(levels, np.float64), y) * s


def quantize_logq(w: np.ndarray, exp_bits: int = POT_EXP_BITS) -> np.ndarray:
    """Log-domain rounding: e = round(-log2(|w|/s)), clamp, reconstruct."""
    s = np.abs(w).max()
    if s == 0:
        return w.copy()
    y = np.abs(w) / s
    with np.errstate(divide="ignore"):
        e = np.round(-np.log2(np.maximum(y, 1e-300)))
    e = np.clip(e, 0, 2 ** exp_bits - 1)
    out = np.exp2(-e)
    out[y == 0] = 0.0
    # deep underflow flushes to zero exactly like the PoT level set does
    return np.sign(w) * out * s


SCHEMES = ["rtn", "pot", "logq", "apot", "dpot"]


def fake_quant_scheme(w: np.ndarray, scheme: str) -> np.ndarray:
    if scheme == "rtn":
        return fake_quant(w, rtn_levels())
    if scheme == "pot":
        return fake_quant(w, pot_levels())
    if scheme == "logq":
        return quantize_logq(w)
    if scheme == "apot":
        return fake_quant(w, apot_levels())
    if scheme == "dpot":
        return fake_quant(w, dpot_levels())
    raise ValueError(scheme)


def dump_codebooks(path: str) -> None:
    """Golden codebook dump compared bit-for-bit by a Rust test."""
    data = {
        "rtn": rtn_levels().tolist(),
        "pot": [lv for lv in pot_levels().tolist() if lv == 0.0 or lv >= 2.0 ** -64],
        "apot": apot_levels().tolist(),
        "dpot": dpot_levels().tolist(),
        "params": {
            "rtn_bits": RTN_BITS,
            "pot_exp_bits": POT_EXP_BITS,
            "apot_k": APOT_K,
            "dpot_k0": DPOT_K0,
            "dpot_k1": DPOT_K1,
        },
    }
    with open(path, "w") as f:
        json.dump(data, f)
