"""Synthetic corpus + evaluation-suite generator.

Substitution for the paper's datasets (DESIGN.md section 2): the Pile-style
pretraining data and the seven benchmark datasets (LAMBADA, HellaSwag,
ARC-Easy/Challenge, SciQ, PIQA, Winogrande) are replaced by a seeded
word-level grammar whose documents require *context-dependent* prediction
(entity-attribute recall, relations, modular arithmetic).  Table 1's
finding is a *relative ordering* of quantization schemes at equal bit
budget, which any task that moves with model fidelity exposes.

Each paper benchmark is mirrored by a suite with an analogous shape:

* ``lambada``        — last-word prediction + ppl over full documents
* ``hellaswag``      — 4-way continuation choice (plausible ending)
* ``arc_easy``       — arithmetic QA, far distractors
* ``arc_challenge``  — arithmetic QA, near (+-1) distractors
* ``sciq``           — attribute-recall QA, random distractors
* ``piqa``           — 2-way relation completion
* ``winogrande``     — 2-way entity resolution

Everything is deterministic given the seed, and the eval seed is disjoint
from the training seed (held-out entity bindings).
"""

from __future__ import annotations

import json
import random

NAMES = ["alice", "bob", "carol", "dave", "erin", "frank", "grace", "henry",
         "iris", "jack", "kate", "liam"]
COLORS = ["red", "blue", "green", "yellow", "black", "white", "purple",
          "orange", "pink", "gray"]
OBJECTS = ["hat", "cup", "book", "ball", "coat", "lamp", "key", "ring",
           "bag", "box", "pen", "shoe"]
DIGITS = ["zero", "one", "two", "three", "four", "five", "six", "seven",
          "eight", "nine"]
VERBS = ["likes", "trusts", "helps", "follows"]
FUNC = ["the", "has", "a", "of", "is", "plus", "minus", "times", "and",
        ".", ",", "?", "so", "then", "who", "what", "answer"]

PAD, BOS = 0, 1


def build_vocab(size: int = 128):
    """Word-level vocabulary with stable ids; padded to ``size``."""
    words = ["<pad>", "<bos>"] + NAMES + COLORS + OBJECTS + DIGITS + VERBS + FUNC
    assert len(set(words)) == len(words)
    assert len(words) <= size, len(words)
    words = words + [f"<unk{i}>" for i in range(size - len(words))]
    return words


VOCAB = build_vocab()
W2I = {w: i for i, w in enumerate(VOCAB)}


def enc(text_words):
    return [W2I[w] for w in text_words]


# --------------------------------------------------------------------------
# Document generators
# --------------------------------------------------------------------------

def _gen_fact_doc(rng: random.Random):
    """Facts then recalls: the recalled color is determined by context."""
    n = rng.randint(3, 6)
    names = rng.sample(NAMES, n)
    objs = rng.sample(OBJECTS, n)
    cols = [rng.choice(COLORS) for _ in range(n)]
    words = []
    for nm, ob, co in zip(names, objs, cols):
        words += [nm, "has", "a", co, ob, "."]
    idx = list(range(n))
    rng.shuffle(idx)
    for i in idx[: rng.randint(2, n)]:
        words += ["the", objs[i], "of", names[i], "is", cols[i], "."]
    return words


def _gen_relation_doc(rng: random.Random):
    """Symmetric relation pattern: 'a V b . so b V a .'"""
    words = []
    for _ in range(rng.randint(2, 4)):
        a, b = rng.sample(NAMES, 2)
        v = rng.choice(VERBS)
        words += [a, v, b, ".", "so", b, v, a, "."]
    return words


def _gen_arith_doc(rng: random.Random):
    """Mod-10 arithmetic sentences: 'three plus four is seven .'"""
    words = []
    for _ in range(rng.randint(3, 6)):
        a, b = rng.randrange(10), rng.randrange(10)
        op = rng.choice(["plus", "minus", "times"])
        c = {"plus": a + b, "minus": a - b, "times": a * b}[op] % 10
        words += [DIGITS[a], op, DIGITS[b], "is", DIGITS[c], "."]
    return words


GENS = [_gen_fact_doc, _gen_relation_doc, _gen_arith_doc]


def gen_stream(seed: int, n_tokens: int):
    """Token stream of concatenated documents, BOS-separated."""
    rng = random.Random(seed)
    out = []
    while len(out) < n_tokens:
        gen = rng.choice(GENS)
        out += [BOS] + enc(gen(rng))
    return out[:n_tokens]


# --------------------------------------------------------------------------
# Evaluation suites
# --------------------------------------------------------------------------

def _mc(ctx, choices, gold):
    return {"ctx": ctx, "choices": choices, "gold": gold}


def _gen_lambada(rng, n):
    """Documents whose final token (a color) is context-determined."""
    items = []
    for _ in range(n):
        k = rng.randint(3, 5)
        names = rng.sample(NAMES, k)
        objs = rng.sample(OBJECTS, k)
        cols = [rng.choice(COLORS) for _ in range(k)]
        words = []
        for nm, ob, co in zip(names, objs, cols):
            words += [nm, "has", "a", co, ob, "."]
        q = rng.randrange(k)
        words += ["the", objs[q], "of", names[q], "is", cols[q]]
        items.append({"tokens": [BOS] + enc(words)})
    return items


def _gen_hellaswag(rng, n):
    """4-way ending choice over a recall sentence."""
    items = []
    for _ in range(n):
        k = rng.randint(3, 5)
        names = rng.sample(NAMES, k)
        objs = rng.sample(OBJECTS, k)
        cols = rng.sample(COLORS, k)  # distinct so distractors are wrong
        words = []
        for nm, ob, co in zip(names, objs, cols):
            words += [nm, "has", "a", co, ob, "."]
        q = rng.randrange(k)
        ctx = [BOS] + enc(words + ["the", objs[q], "of", names[q], "is"])
        wrong = [c for c in cols if c != cols[q]][:3]
        if len(wrong) < 3:
            wrong += rng.sample([c for c in COLORS if c != cols[q]], 3 - len(wrong))
        choices = [enc([cols[q], "."])] + [enc([w, "."]) for w in wrong]
        order = list(range(4))
        rng.shuffle(order)
        items.append(_mc(ctx, [choices[i] for i in order], order.index(0)))
    return items


def _gen_arith_mc(rng, n, near: bool):
    """Arithmetic QA; near=True puts distractors at +-1/+-2 (mod 10)."""
    items = []
    for _ in range(n):
        a, b = rng.randrange(10), rng.randrange(10)
        op = rng.choice(["plus", "minus", "times"])
        c = {"plus": a + b, "minus": a - b, "times": a * b}[op] % 10
        ctx = [BOS] + enc([DIGITS[a], op, DIGITS[b], "is"])
        if near:
            ds = [(c + d) % 10 for d in (1, 9, 2)]
        else:
            ds = rng.sample([x for x in range(10) if x != c], 3)
        choices = [enc([DIGITS[c]])] + [enc([DIGITS[d]]) for d in ds]
        order = list(range(4))
        rng.shuffle(order)
        items.append(_mc(ctx, [choices[i] for i in order], order.index(0)))
    return items


def _gen_sciq(rng, n):
    """Attribute recall with random object distractors."""
    items = []
    for _ in range(n):
        k = rng.randint(3, 5)
        names = rng.sample(NAMES, k)
        objs = rng.sample(OBJECTS, k)
        cols = [rng.choice(COLORS) for _ in range(k)]
        words = []
        for nm, ob, co in zip(names, objs, cols):
            words += [nm, "has", "a", co, ob, "."]
        q = rng.randrange(k)
        ctx = [BOS] + enc(words + ["what", "of", names[q], "is", cols[q], "?",
                                   "answer", "the"])
        wrong = rng.sample([o for o in OBJECTS if o != objs[q]], 3)
        choices = [enc([objs[q]])] + [enc([w]) for w in wrong]
        order = list(range(4))
        rng.shuffle(order)
        items.append(_mc(ctx, [choices[i] for i in order], order.index(0)))
    return items


def _gen_piqa(rng, n):
    """2-way relation completion: 'a V b . so b V' -> a."""
    items = []
    for _ in range(n):
        a, b = rng.sample(NAMES, 2)
        v = rng.choice(VERBS)
        ctx = [BOS] + enc([a, v, b, ".", "so", b, v])
        wrong = rng.choice([x for x in NAMES if x not in (a, b)])
        choices = [enc([a, "."]), enc([wrong, "."])]
        gold = 0
        if rng.random() < 0.5:
            choices = choices[::-1]
            gold = 1
        items.append(_mc(ctx, choices, gold))
    return items


def _gen_winogrande(rng, n):
    """2-way entity resolution: which name has the attribute."""
    items = []
    for _ in range(n):
        a, b = rng.sample(NAMES, 2)
        oa, ob_ = rng.sample(OBJECTS, 2)
        ca, cb = rng.sample(COLORS, 2)
        words = [a, "has", "a", ca, oa, ".", b, "has", "a", cb, ob_, "."]
        pick_a = rng.random() < 0.5
        obj, col = (oa, ca) if pick_a else (ob_, cb)
        ctx = [BOS] + enc(words + ["who", "has", "the", col, obj, "?", "answer"])
        choices = [enc([a, "."]), enc([b, "."])]
        items.append(_mc(ctx, choices, 0 if pick_a else 1))
    return items


def gen_eval_data(seed: int = 10_007, n_per_suite: int = 200):
    rng = random.Random(seed)
    return {
        "vocab": VOCAB,
        # held-out document stream for low-variance perplexity deltas
        "valid_stream": gen_stream(seed + 1, 4000),
        "lambada": _gen_lambada(rng, n_per_suite),
        "suites": {
            "hellaswag": _gen_hellaswag(rng, n_per_suite),
            "arc_easy": _gen_arith_mc(rng, n_per_suite, near=False),
            "arc_challenge": _gen_arith_mc(rng, n_per_suite, near=True),
            "sciq": _gen_sciq(rng, n_per_suite),
            "piqa": _gen_piqa(rng, n_per_suite),
            "winogrande": _gen_winogrande(rng, n_per_suite),
        },
    }


def write_eval_data(path: str, seed: int = 10_007, n_per_suite: int = 200):
    with open(path, "w") as f:
        json.dump(gen_eval_data(seed, n_per_suite), f)
