"""HFWT: a tiny self-describing tensor container (writer side).

Layout:  magic ``HFWT1\\n`` | u64-LE header length | JSON header | raw data.
Header: ``{"tensors": [{"name", "dtype", "shape", "offset", "nbytes"}],
"meta": {...}}`` with offsets relative to the start of the data section,
each tensor 64-byte aligned.  The Rust reader lives in
``rust/src/model/weights.rs``; keep the two in sync.
"""

from __future__ import annotations

import json
import struct

import numpy as np

MAGIC = b"HFWT1\n"
ALIGN = 64


def save_tensors(path: str, tensors: dict, meta: dict | None = None) -> None:
    """Write ``{name: np.ndarray}`` (f32/i8/i32) to ``path``."""
    entries = []
    blobs = []
    offset = 0
    for name in sorted(tensors):
        arr = np.ascontiguousarray(tensors[name])
        if arr.dtype == np.float64:
            arr = arr.astype(np.float32)
        assert arr.dtype in (np.float32, np.int8, np.int32), (name, arr.dtype)
        raw = arr.tobytes()
        entries.append({
            "name": name,
            "dtype": str(arr.dtype),
            "shape": list(arr.shape),
            "offset": offset,
            "nbytes": len(raw),
        })
        blobs.append(raw)
        offset += len(raw)
        pad = (-offset) % ALIGN
        if pad:
            blobs.append(b"\0" * pad)
            offset += pad
    header = json.dumps({"tensors": entries, "meta": meta or {}}).encode()
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<Q", len(header)))
        f.write(header)
        for b in blobs:
            f.write(b)


def load_tensors(path: str):
    """Read an HFWT file back (used by pytest round-trip checks)."""
    with open(path, "rb") as f:
        magic = f.read(len(MAGIC))
        assert magic == MAGIC, magic
        (hlen,) = struct.unpack("<Q", f.read(8))
        header = json.loads(f.read(hlen))
        data = f.read()
    out = {}
    for e in header["tensors"]:
        buf = data[e["offset"]: e["offset"] + e["nbytes"]]
        arr = np.frombuffer(buf, dtype=np.dtype(e["dtype"])).reshape(e["shape"])
        out[e["name"]] = arr
    return out, header["meta"]
