"""L2: RWKV-4 model in JAX — forward (token-step and sequence) + loss.

Three execution variants of the same architecture:

* ``variant="exact"``   — libm nonlinearities, jnp LayerNorm.  Ground truth.
* ``variant="pallas"``  — the Pallas kernels from ``kernels/`` (LayerNorm
  ATAC kernel, WKV kernel); this is what gets AOT-lowered to the runtime
  artifact, so the L1 kernels land inside the served HLO.
* ``variant="hwapprox"``— every nonlinearity routed through the paper's
  hardware approximations (EXP-LUT, sigmoid PWL, DIVU, ATAC LayerNorm) in
  f32.  AOT-lowered as a second artifact so the Rust harness can measure
  the approximation impact end to end.

The recurrent state is a single ``[n_layer, 5, d_model]`` array with rows
(att_x_prev, ffn_x_prev, aa, bb, pp); ``pp`` starts at ``PP_INIT``.

Weights are *function arguments* (never baked constants): the Rust side
feeds arbitrary fake-quantized weight sets through the same executable —
that is how the Table 1 ablation runs without Python on the request path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .config import RwkvConfig
from .kernels import hw_layernorm as ln_kernel
from .kernels import hw_ops
from .kernels import wkv as wkv_kernel

PP_INIT = -1e30
S_ATT_X, S_FFN_X, S_AA, S_BB, S_PP = range(5)

# Canonical per-block parameter names, in flattening order.
BLOCK_PARAMS = [
    ("ln1.weight", "d"), ("ln1.bias", "d"),
    ("att.time_decay", "d"), ("att.time_first", "d"),
    ("att.time_mix_k", "d"), ("att.time_mix_v", "d"), ("att.time_mix_r", "d"),
    ("att.key", "dd"), ("att.value", "dd"),
    ("att.receptance", "dd"), ("att.output", "dd"),
    ("ln2.weight", "d"), ("ln2.bias", "d"),
    ("ffn.time_mix_k", "d"), ("ffn.time_mix_r", "d"),
    ("ffn.key", "fd"), ("ffn.receptance", "dd"), ("ffn.value", "df"),
]
TOP_PARAMS = [
    ("emb", "vd"),
    ("ln0.weight", "d"), ("ln0.bias", "d"),
    ("ln_out.weight", "d"), ("ln_out.bias", "d"),
    ("head", "vd"),
]


def _shape_of(code: str, cfg: RwkvConfig):
    d, f, v = cfg.d_model, cfg.d_ffn, cfg.vocab
    return {"d": (d,), "dd": (d, d), "fd": (f, d), "df": (d, f), "vd": (v, d)}[code]


def param_order(cfg: RwkvConfig):
    """Deterministic flat ordering of all parameters: (name, shape) list.

    This ordering IS the artifact ABI — the Rust runtime feeds buffers in
    exactly this order.  It is recorded in the AOT manifest.
    """
    order = []
    for name, code in TOP_PARAMS[:3]:  # emb, ln0.*
        order.append((name, _shape_of(code, cfg)))
    for i in range(cfg.n_layer):
        for name, code in BLOCK_PARAMS:
            order.append((f"blocks.{i}.{name}", _shape_of(code, cfg)))
    for name, code in TOP_PARAMS[3:]:  # ln_out.*, head
        order.append((name, _shape_of(code, cfg)))
    return order


def init_params(cfg: RwkvConfig, key) -> dict:
    """Initialize RWKV-4 parameters (simplified variant of the official
    init: scaled-normal projections, layer-ramped decays and mixes)."""
    d, f, v, n = cfg.d_model, cfg.d_ffn, cfg.vocab, cfg.n_layer
    keys = iter(jax.random.split(key, 8 + 8 * n))
    p: dict = {}
    p["emb"] = jax.random.normal(next(keys), (v, d)) * 0.02
    p["ln0.weight"] = jnp.ones(d)
    p["ln0.bias"] = jnp.zeros(d)
    h = jnp.arange(d) / max(d - 1, 1)
    for i in range(n):
        ratio0 = i / max(n - 1, 1)            # 0 -> 1 across layers
        ratio1 = 1.0 - i / n                  # 1 -> ~0 across layers
        b = f"blocks.{i}."
        p[b + "ln1.weight"] = jnp.ones(d)
        p[b + "ln1.bias"] = jnp.zeros(d)
        p[b + "ln2.weight"] = jnp.ones(d)
        p[b + "ln2.bias"] = jnp.zeros(d)
        # decay_raw in [-6, -1] ramped over channels; w = -exp(raw).
        p[b + "att.time_decay"] = -5.0 + 8.0 * h ** (0.7 + 1.3 * ratio0)
        p[b + "att.time_first"] = jnp.full((d,), jnp.log(0.3)) + (h * 0.5)
        p[b + "att.time_mix_k"] = h ** ratio1
        p[b + "att.time_mix_v"] = h ** ratio1 + 0.3 * ratio0
        p[b + "att.time_mix_r"] = h ** (0.5 * ratio1)
        sc = 0.8 / (d ** 0.5)
        p[b + "att.key"] = jax.random.normal(next(keys), (d, d)) * sc
        p[b + "att.value"] = jax.random.normal(next(keys), (d, d)) * sc
        p[b + "att.receptance"] = jax.random.normal(next(keys), (d, d)) * sc
        p[b + "att.output"] = jax.random.normal(next(keys), (d, d)) * (sc * 0.5)
        p[b + "ffn.time_mix_k"] = h ** ratio1
        p[b + "ffn.time_mix_r"] = h ** ratio1
        p[b + "ffn.key"] = jax.random.normal(next(keys), (f, d)) * sc
        p[b + "ffn.receptance"] = jax.random.normal(next(keys), (d, d)) * sc
        p[b + "ffn.value"] = jax.random.normal(next(keys), (d, f)) * (0.8 / f ** 0.5)
    p["ln_out.weight"] = jnp.ones(d)
    p["ln_out.bias"] = jnp.zeros(d)
    p["head"] = jax.random.normal(next(keys), (v, d)) * 0.02
    return p


def flatten_params(params: dict, cfg: RwkvConfig):
    return [jnp.asarray(params[name], jnp.float32) for name, _ in param_order(cfg)]


def unflatten_params(flat, cfg: RwkvConfig) -> dict:
    names = [name for name, _ in param_order(cfg)]
    assert len(flat) == len(names), (len(flat), len(names))
    return dict(zip(names, flat))


def init_state(cfg: RwkvConfig):
    s = jnp.zeros((cfg.n_layer, 5, cfg.d_model))
    return s.at[:, S_PP, :].set(PP_INIT)


# --------------------------------------------------------------------------
# Variant-dispatched primitive ops
# --------------------------------------------------------------------------

def _ops(variant: str):
    if variant == "exact":
        return dict(
            ln=lambda x, w, b: _ln_exact(x, w, b),
            sigmoid=jax.nn.sigmoid,
            exp=jnp.exp,
            div=lambda a, b: a / b,
            wkv=None,
        )
    if variant == "pallas":
        return dict(
            ln=lambda x, w, b: ln_kernel.layernorm(x, w, b),
            sigmoid=jax.nn.sigmoid,
            exp=jnp.exp,
            div=lambda a, b: a / b,
            wkv=wkv_kernel.wkv_step,
        )
    if variant == "hwapprox":
        return dict(
            ln=hw_ops.hw_layernorm,
            sigmoid=hw_ops.hw_sigmoid,
            exp=hw_ops.hw_exp,
            div=hw_ops.hw_div,
            wkv=None,
        )
    raise ValueError(f"unknown variant {variant!r}")


def _ln_exact(x, w, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * w + b


def _wkv_generic(ops, k, v, aa, bb, pp, u, w):
    ww = u + k
    qq = jnp.maximum(pp, ww)
    e1 = ops["exp"](pp - qq)
    e2 = ops["exp"](ww - qq)
    wkv = ops["div"](e1 * aa + e2 * v, e1 * bb + e2)
    ww = pp + w
    qq = jnp.maximum(ww, k)
    e1 = ops["exp"](ww - qq)
    e2 = ops["exp"](k - qq)
    return wkv, e1 * aa + e2 * v, e1 * bb + e2, qq


# --------------------------------------------------------------------------
# Token-step forward (inference / serving path)
# --------------------------------------------------------------------------

def _time_mixing(ops, p, b: str, x, st):
    """x is the ln1 output; st is this layer's [5, d] state slice."""
    xp = st[S_ATT_X]
    xk = x * p[b + "att.time_mix_k"] + xp * (1.0 - p[b + "att.time_mix_k"])
    xv = x * p[b + "att.time_mix_v"] + xp * (1.0 - p[b + "att.time_mix_v"])
    xr = x * p[b + "att.time_mix_r"] + xp * (1.0 - p[b + "att.time_mix_r"])
    r = ops["sigmoid"](p[b + "att.receptance"] @ xr)
    k = p[b + "att.key"] @ xk
    v = p[b + "att.value"] @ xv
    w_eff = -jnp.exp(p[b + "att.time_decay"])
    u = p[b + "att.time_first"]
    if ops["wkv"] is not None:
        wkv, aa, bb, pp = ops["wkv"](k, v, st[S_AA], st[S_BB], st[S_PP], u, w_eff)
    else:
        wkv, aa, bb, pp = _wkv_generic(ops, k, v, st[S_AA], st[S_BB], st[S_PP], u, w_eff)
    out = p[b + "att.output"] @ (r * wkv)
    st = st.at[S_ATT_X].set(x).at[S_AA].set(aa).at[S_BB].set(bb).at[S_PP].set(pp)
    return out, st


def _channel_mixing(ops, p, b: str, x, st):
    xp = st[S_FFN_X]
    xk = x * p[b + "ffn.time_mix_k"] + xp * (1.0 - p[b + "ffn.time_mix_k"])
    xr = x * p[b + "ffn.time_mix_r"] + xp * (1.0 - p[b + "ffn.time_mix_r"])
    r = ops["sigmoid"](p[b + "ffn.receptance"] @ xr)
    k = jnp.square(jnp.maximum(p[b + "ffn.key"] @ xk, 0.0))
    out = r * (p[b + "ffn.value"] @ k)
    return out, st.at[S_FFN_X].set(x)


def step(params: dict, state, token, cfg: RwkvConfig, variant: str = "exact"):
    """One autoregressive step: token id -> (logits [V], new state)."""
    ops = _ops(variant)
    p = params
    x = jnp.take(p["emb"], token, axis=0)
    x = ops["ln"](x, p["ln0.weight"], p["ln0.bias"])
    new_rows = []
    for i in range(cfg.n_layer):
        b = f"blocks.{i}."
        st = state[i]
        dx, st = _time_mixing(ops, p, b, ops["ln"](x, p[b + "ln1.weight"], p[b + "ln1.bias"]), st)
        x = x + dx
        dx, st = _channel_mixing(ops, p, b, ops["ln"](x, p[b + "ln2.weight"], p[b + "ln2.bias"]), st)
        x = x + dx
        new_rows.append(st)
    x = ops["ln"](x, p["ln_out.weight"], p["ln_out.bias"])
    logits = p["head"] @ x
    return logits, jnp.stack(new_rows)


# --------------------------------------------------------------------------
# Sequence forward (training / bulk evaluation path)
# --------------------------------------------------------------------------

def forward_seq(params: dict, tokens, cfg: RwkvConfig):
    """RNN-mode forward over a token sequence [T] -> logits [T, V].

    Uses lax.scan over time with the exact variant (training never uses
    Pallas: interpret-mode tracing is slow and gradients are cleaner
    through plain jnp).
    """
    ops = _ops("exact")
    p = params

    def one(carry, token):
        state = carry
        logits, state = step(p, state, token, cfg, variant="exact")
        return state, logits

    del ops
    state0 = init_state(cfg)
    _, logits = jax.lax.scan(one, state0, tokens)
    return logits


def forward_seq_batched(params: dict, tokens, cfg: RwkvConfig):
    """tokens [B, T] -> logits [B, T, V]."""
    return jax.vmap(lambda t: forward_seq(params, t, cfg))(tokens)


def loss_fn(params: dict, tokens, cfg: RwkvConfig):
    """Next-token cross-entropy over a [B, T] batch (predict t+1 from t)."""
    logits = forward_seq_batched(params, tokens[:, :-1], cfg)
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


# --------------------------------------------------------------------------
# AOT entry points (flat-argument ABI)
# --------------------------------------------------------------------------

def make_step_fn(cfg: RwkvConfig, variant: str):
    """Return f(*flat_params, state, token) -> (logits, state') for AOT."""
    n = len(param_order(cfg))

    def fn(*args):
        flat, state, token = args[:n], args[n], args[n + 1]
        params = unflatten_params(list(flat), cfg)
        return step(params, state, token, cfg, variant=variant)

    return fn


def make_seq_fn(cfg: RwkvConfig, seq_len: int, variant: str = "exact"):
    """Return f(*flat_params, state, tokens[T]) -> (logits [T,V], state').

    Chunked-sequence evaluator: state threads across calls so the Rust
    side can score arbitrarily long documents in fixed-T chunks.
    """
    n = len(param_order(cfg))

    def fn(*args):
        flat, state, tokens = args[:n], args[n], args[n + 1]
        params = unflatten_params(list(flat), cfg)

        def one(carry, token):
            logits, new_state = step(params, carry, token, cfg, variant=variant)
            return new_state, logits

        state_out, logits = jax.lax.scan(one, state, tokens)
        return logits, state_out

    return fn


@functools.lru_cache(maxsize=None)
def jit_step(cfg: RwkvConfig, variant: str = "exact"):
    return jax.jit(lambda p, s, t: step(p, s, t, cfg, variant=variant))
