"""Vectorized float models of the paper's hardware function units.

These are the *usable* counterparts of the algorithmic references in
``ref.py`` — the same LUT widths and PWL segments, packaged so the L2
model can be lowered in an "hwapprox" variant where every nonlinearity
goes through the paper's approximation instead of libm.  That artifact
lets the Rust side measure the end-to-end accuracy impact of the
approximations through the exact same PJRT path as the exact model.

The bit-exact 9/16-bit integer datapaths live in ``rust/src/arith``;
here the structure (truncation points, segment boundaries, LUT index
widths) is identical but evaluated in f32.
"""

from __future__ import annotations

import jax.numpy as jnp

from . import ref

# Domain clamp of the EXP unit: 16-bit internal fixed point with 8
# fractional bits covers 2^u for u in roughly [-16, 15]; the model clamps
# exponent inputs into a safe window, which also acts as the fixed-point
# stabilizer for the WKV recurrence.
EXP_IN_LO = -20.0
EXP_IN_HI = 10.0


def hw_sigmoid(x):
    """Sigmoid via the 5-segment PWL of eq (9) (EXP-sigma unit, mode 1)."""
    return ref.sigmoid_pwl_ref(x)


def hw_exp(x):
    """e^x via the shift-add x*log2e + 256-entry EXP-LUT (mode 0)."""
    return ref.exp_lut_ref(jnp.clip(x, EXP_IN_LO, EXP_IN_HI))


def hw_div(num, den):
    """Signed division routed through the unsigned division unit.

    Sign-bit separation happens before the DIVU (paper 4.3); zeros are
    guarded the way the hardware guards them (minimum denominator ulp).
    """
    sign = jnp.sign(num) * jnp.sign(den)
    sign = jnp.where(sign == 0.0, 1.0, sign)
    n = jnp.maximum(jnp.abs(num), 2.0 ** -16)
    d = jnp.maximum(jnp.abs(den), 2.0 ** -16)
    return sign * ref.divu_ref(n, d)


def hw_layernorm(x, weight, bias, eps=1e-5):
    """LayerNorm in the ATAC single-pass identity form (eq 12), with the
    final (x-mu)/sigma division routed through the DIVU model."""
    d = x.shape[-1]
    mu = jnp.sum(x, axis=-1, keepdims=True) / d
    ex2 = jnp.sum(x * x, axis=-1, keepdims=True) / d
    sigma = jnp.sqrt(ex2 - mu * mu + eps)
    return hw_div(x - mu, sigma) * weight + bias


def quant_sym(x, bits: int = 9, scale=None):
    """Fake uniform symmetric quantization (RTN) at the given bit width.

    Per-tensor scale defaults to max|x|; this is the W/A quantizer of
    paper section 3.2 (9-bit activations, 16-bit internals).
    """
    qmax = float(2 ** (bits - 1) - 1)
    s = jnp.max(jnp.abs(x)) if scale is None else scale
    s = jnp.maximum(s, 1e-12)
    q = jnp.clip(jnp.round(x / s * qmax), -qmax, qmax)
    return q * s / qmax
