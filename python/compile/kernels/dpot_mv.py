"""Pallas matrix-vector kernel over Delta-PoT-encoded weights.

Hardware adaptation (DESIGN.md section 3): on the FPGA the Delta-PoT format
turns each multiply into two barrel shifts + one add inside a PMAC unit.
On TPU the efficient multiplier *is* the MXU, so the kernel dequantizes the
(sign, dq0, dq1) planes on the fly inside VMEM — exp2 on the VPU — and
feeds an ordinary dot product.  Arithmetic value is identical to the
shift-add datapath (the Rust ``arith::dpot`` module is the bit-exact
model); only the execution strategy differs.

The HBM->VMEM schedule the paper implements with ping-pong URAM buffers is
expressed with a grid over row tiles: each grid step stages one
(tile_out, d_in) slice of the three code planes plus the full input vector.

Runs with ``interpret=True`` — CPU PJRT cannot execute Mosaic custom-calls.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE_OUT = 128


def _dequant_tile(sign, dq0, dq1, two_gamma):
    """Decode a tile of Delta-PoT codes to f32 (paper eq 5-6)."""
    dq0f = dq0.astype(jnp.float32)
    dq1f = dq1.astype(jnp.float32)
    p0 = jnp.where(dq0 > 0, jnp.exp2(-dq0f), 0.0)
    p1 = jnp.where((dq1 > 0) & (dq0 > 0), p0 * jnp.exp2(-dq1f), 0.0)
    return sign.astype(jnp.float32) * two_gamma * (p0 + p1)


def _mv_kernel(sign_ref, dq0_ref, dq1_ref, x_ref, gamma_ref, o_ref):
    two_gamma = 2.0 * gamma_ref[0]
    w = _dequant_tile(sign_ref[...], dq0_ref[...], dq1_ref[...], two_gamma)
    # f32 accumulate (the FPGA uses 16-bit accumulators with overflow
    # protection; the bit-exact model lives in rust arith::pmac).
    o_ref[...] = w @ x_ref[...]


@functools.partial(jax.jit, static_argnames=("tile_out",))
def dpot_matvec(sign, dq0, dq1, gamma, x, *, tile_out: int = DEFAULT_TILE_OUT):
    """Compute ``dequant(sign,dq0,dq1,gamma) @ x`` tiled over output rows.

    sign/dq0/dq1: int8 [d_out, d_in] code planes; gamma: f32 [1]; x: f32
    [d_in].  Row tiles of ``tile_out`` keep the staged weight slice
    VMEM-sized (tile_out * d_in * 3 bytes of codes + d_in * 4 of vector).
    """
    d_out, d_in = sign.shape
    t = min(tile_out, d_out)
    while d_out % t != 0:
        t //= 2
    grid = (d_out // t,)
    plane = pl.BlockSpec((t, d_in), lambda i: (i, 0))
    return pl.pallas_call(
        _mv_kernel,
        grid=grid,
        in_specs=[
            plane,
            plane,
            plane,
            pl.BlockSpec((d_in,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((t,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((d_out,), jnp.float32),
        interpret=True,
    )(sign, dq0, dq1, x, gamma)
