"""Pure-jnp correctness oracles for every Pallas kernel.

Two kinds of reference live here:

* *exact* references (``layernorm_ref``, ``wkv_step_ref``, ``matvec_ref``)
  — ordinary float math, the ground truth the kernels must match to
  ``assert_allclose`` tolerance;
* *algorithmic* references for the paper's hardware approximations
  (``sigmoid_pwl_ref``, ``exp_lut_ref``, ``divu_ref``) — bit-faithful in
  structure (segment boundaries, LUT indexing width) but evaluated in
  float.  The Rust ``arith`` layer implements the same algorithms on 9/16
  bit integers; pytest checks the *approximation error vs exact math* here,
  and Rust property tests check the integer datapaths against these bounds.
"""

from __future__ import annotations

import jax.numpy as jnp


# --------------------------------------------------------------------------
# Exact references
# --------------------------------------------------------------------------

def layernorm_ref(x, weight, bias, eps=1e-5):
    """LayerNorm over the last axis, textbook two-pass formulation."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * weight + bias


def layernorm_identity_ref(x, weight, bias, eps=1e-5):
    """LayerNorm via the paper's sigma^2 = E[x^2] - E[x]^2 identity (eq 12)."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    ex2 = jnp.mean(x * x, axis=-1, keepdims=True)
    var = ex2 - mu * mu
    return (x - mu) / jnp.sqrt(var + eps) * weight + bias


def matvec_ref(w, x):
    """w @ x for w [out, in], x [in]."""
    return w @ x


def token_shift_ref(x_t, x_prev, mix):
    """RWKV token-shift interpolation (eq 1, pre-projection part)."""
    return x_t * mix + x_prev * (1.0 - mix)


def wkv_step_ref(k, v, aa, bb, pp, time_first, time_decay):
    """One numerically-stabilized RWKV-4 WKV update (eq 2, running-max form).

    ``time_decay`` is the *effective* decay w = -exp(decay_param) < 0.
    Returns (wkv, aa', bb', pp').
    """
    ww = time_first + k
    qq = jnp.maximum(pp, ww)
    e1 = jnp.exp(pp - qq)
    e2 = jnp.exp(ww - qq)
    wkv = (e1 * aa + e2 * v) / (e1 * bb + e2)

    ww = pp + time_decay
    qq = jnp.maximum(ww, k)
    e1 = jnp.exp(ww - qq)
    e2 = jnp.exp(k - qq)
    aa_new = e1 * aa + e2 * v
    bb_new = e1 * bb + e2
    return wkv, aa_new, bb_new, qq


def channel_mix_ref(x, x_prev, mix_k, mix_r, wk, wv, wr):
    """RWKV-4 channel-mixing sublayer (returns delta; new x_prev is x)."""
    xk = token_shift_ref(x, x_prev, mix_k)
    xr = token_shift_ref(x, x_prev, mix_r)
    r = jnp.reciprocal(1.0 + jnp.exp(-(wr @ xr)))
    k = jnp.square(jnp.maximum(wk @ xk, 0.0))
    return r * (wv @ k)


# --------------------------------------------------------------------------
# Algorithmic references for the hardware approximations
# --------------------------------------------------------------------------

def sigmoid_pwl_ref(x):
    """Paper eq (9): 5-segment piecewise-linear sigmoid, dyadic slopes."""
    ax = jnp.abs(x)
    pos = jnp.where(
        ax >= 5.0,
        1.0,
        jnp.where(
            ax >= 2.375,
            0.03125 * ax + 0.84375,
            jnp.where(ax >= 1.0, 0.125 * ax + 0.625, 0.25 * ax + 0.5),
        ),
    )
    return jnp.where(x >= 0.0, pos, 1.0 - pos)


LOG2E_Q4 = 1.0 + 0.25 + 0.125 + 0.0625  # 1.0111_2 = 1.4375, paper eq (8)
EXP_LUT_BITS = 8                        # 256-entry EXP-LUT (paper 4.4)


def exp_lut_ref(x):
    """Paper eq (8): e^x = 2^(x*log2e), with log2e ~= 1.0111b and the
    fractional 2^v looked up at 8-bit index resolution."""
    y = x * LOG2E_Q4
    u = jnp.floor(y)
    v = y - u
    # 8-bit LUT: the fractional index is truncated to 2^-8 resolution.
    v_idx = jnp.floor(v * (1 << EXP_LUT_BITS)) / (1 << EXP_LUT_BITS)
    return jnp.exp2(u) * jnp.exp2(v_idx)


DIV_LUT_BITS = 4  # 4x4-bit indexing -> 256-entry 2D-LUT (paper 4.3)


def divu_ref(x, y):
    """Paper eq (7): X/Y = (x/y) << (k1-k2) with 4-bit-mantissa 2D-LUT.

    Float model of the unsigned division unit: normalize both operands to
    [1,2), truncate mantissas to 1+4 bits, look up x/y (here: compute it on
    the truncated mantissas, which is exactly what the LUT stores at 8-bit
    output precision), recombine exponents.
    """
    k1 = jnp.floor(jnp.log2(x))
    k2 = jnp.floor(jnp.log2(y))
    mx = x / jnp.exp2(k1)
    my = y / jnp.exp2(k2)
    step = 2.0 ** (-DIV_LUT_BITS)
    mx_t = jnp.floor(mx / step) * step
    my_t = jnp.floor(my / step) * step
    frac = mx_t / my_t
    # LUT output is stored at 8-bit fractional precision.
    frac = jnp.floor(frac * 256.0) / 256.0
    return frac * jnp.exp2(k1 - k2)


# --------------------------------------------------------------------------
# Delta-PoT dequantization reference
# --------------------------------------------------------------------------

def dpot_dequant_ref(sign, dq0, dq1, gamma):
    """Decode Delta-PoT codes (paper eq 5-6): value = sign*2*gamma*(p0+p1),
    p0 = 2^-dq0 (0 if dq0 == 0), p1 = p0 * 2^-dq1 (0 if dq1 == 0)."""
    p0 = jnp.where(dq0 > 0, jnp.exp2(-dq0.astype(jnp.float32)), 0.0)
    p1 = jnp.where((dq1 > 0) & (dq0 > 0), p0 * jnp.exp2(-dq1.astype(jnp.float32)), 0.0)
    return sign.astype(jnp.float32) * 2.0 * gamma * (p0 + p1)


def dpot_matvec_ref(sign, dq0, dq1, gamma, x):
    """Matvec against Delta-PoT-encoded weights, decode-then-dot."""
    return dpot_dequant_ref(sign, dq0, dq1, gamma) @ x
