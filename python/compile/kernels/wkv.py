"""Pallas kernel for the RWKV-4 WKV recurrent state update (paper eq 2).

One call performs a single time-step update over the full channel dimension
— exactly the element-wise workload the paper routes through the
Matrix-Vector Processing Array in element-wise mode plus the Complex
Computing Units (EXP-sigma for the exponentials, DIVU for the division).

The numerically-stabilized running-max (``pp``) form is used, matching the
official RWKV-4 inference code the paper benchmarks on CPU/GPU; the FPGA's
EXP-LUT domain clamp plays the same stabilization role in fixed point.

Runs with ``interpret=True`` — CPU PJRT cannot execute Mosaic custom-calls.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _wkv_kernel(k_ref, v_ref, aa_ref, bb_ref, pp_ref, u_ref, w_ref,
                wkv_ref, aa_o_ref, bb_o_ref, pp_o_ref):
    k = k_ref[...]
    v = v_ref[...]
    aa = aa_ref[...]
    bb = bb_ref[...]
    pp = pp_ref[...]

    # Output branch: bonus u applied to the current token.
    ww = u_ref[...] + k
    qq = jnp.maximum(pp, ww)
    e1 = jnp.exp(pp - qq)          # EXP-sigma unit, mode 0
    e2 = jnp.exp(ww - qq)
    wkv_ref[...] = (e1 * aa + e2 * v) / (e1 * bb + e2)  # DIVU

    # State branch: decay w folded into the running max.
    ww = pp + w_ref[...]
    qq = jnp.maximum(ww, k)
    e1 = jnp.exp(ww - qq)
    e2 = jnp.exp(k - qq)
    aa_o_ref[...] = e1 * aa + e2 * v
    bb_o_ref[...] = e1 * bb + e2
    pp_o_ref[...] = qq


@jax.jit
def wkv_step(k, v, aa, bb, pp, time_first, time_decay):
    """One WKV update over d channels; returns (wkv, aa', bb', pp').

    ``time_decay`` must already be the effective decay -exp(decay_param).
    All seven inputs are f32 [d] and fit VMEM comfortably for d <= 16k.
    """
    d = k.shape[-1]
    out = jax.ShapeDtypeStruct((d,), jnp.float32)
    return pl.pallas_call(
        _wkv_kernel,
        out_shape=(out, out, out, out),
        interpret=True,
    )(k, v, aa, bb, pp, time_first, time_decay)
