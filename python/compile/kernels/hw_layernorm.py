"""Pallas LayerNorm kernel mirroring the paper's ATAC module (Fig 6).

The FPGA module computes the mean and variance with two parallel
addition-tree+accumulator (ATAC) reductions over 512-wide blocks, using the
identity sigma^2 = E[x^2] - E[x]^2 (eq 12) so a single pass over the data
suffices.  On TPU the analogous structure is a blocked single-pass
reduction over (d/P, P) tiles held in VMEM; the block width P plays the
role of the tree parallelism.

Runs with ``interpret=True`` — CPU PJRT cannot execute Mosaic custom-calls.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TREE_PARALLELISM = 512


def _ln_kernel(x_ref, w_ref, b_ref, o_ref, *, block: int, eps: float):
    x = x_ref[...]
    d = x.shape[-1]
    # ATAC analog: fold the vector into (d/block, block) lanes, reduce the
    # lane axis with the "tree", accumulate partials along the other axis.
    xb = x.reshape(d // block, block)
    s1 = jnp.sum(jnp.sum(xb, axis=1))          # mean path ATAC
    s2 = jnp.sum(jnp.sum(xb * xb, axis=1))     # variance path ATAC
    mu = s1 / d
    var = s2 / d - mu * mu                     # eq (12)
    inv = jax.lax.rsqrt(var + eps)             # subtract-sqrt module
    o_ref[...] = (x - mu) * inv * w_ref[...] + b_ref[...]


@functools.partial(jax.jit, static_argnames=("block", "eps"))
def layernorm(x, weight, bias, *, block: int | None = None, eps: float = 1e-5):
    """LayerNorm over a 1-D vector using the blocked single-pass kernel.

    ``block`` is the tree-parallelism analog; it must divide ``d`` (we clamp
    it to ``d`` for short vectors, matching the paper's per-config
    ``tree parallelism`` in [256, 512]).
    """
    d = x.shape[-1]
    blk = min(block or DEFAULT_TREE_PARALLELISM, d)
    while d % blk != 0:  # clamp to a divisor for ragged dims
        blk //= 2
    kernel = functools.partial(_ln_kernel, block=blk, eps=eps)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True,
    )(x, weight, bias)
