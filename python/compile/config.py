"""Model and run configurations shared across the Python build layer.

The *tiny* config is the one we actually train and AOT-lower (the runtime
model served by the Rust coordinator).  The paper-scale configs
(RWKV-4 169M..7B) exist so the AOT layer and the Rust simulator agree on
tensor shapes; the simulator only needs shapes, never weights.
"""

from __future__ import annotations

import dataclasses
import json


@dataclasses.dataclass(frozen=True)
class RwkvConfig:
    """Architecture hyper-parameters of an RWKV-4 model."""

    name: str
    n_layer: int
    d_model: int
    d_ffn: int
    vocab: int

    @property
    def n_params(self) -> int:
        """Exact parameter count of our RWKV-4 parameterization."""
        d, f, v, n = self.d_model, self.d_ffn, self.vocab, self.n_layer
        per_layer = (
            4 * d * d          # att: key/value/receptance/output
            + 5 * d            # time_decay, time_first, time_mix_{k,v,r}
            + 2 * d * f        # ffn key (f,d) + value (d,f)
            + d * d            # ffn receptance
            + 2 * d            # ffn time_mix_{k,r}
            + 4 * d            # ln1/ln2 weight+bias
        )
        return v * d * 2 + n * per_layer + 2 * d + 2 * d  # emb+head, ln0, ln_out

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


# The model we train + serve end to end.
TINY = RwkvConfig(name="tiny-1m", n_layer=4, d_model=128, d_ffn=512, vocab=128)

# Published RWKV-4 family shapes (used by the simulator / shape manifest).
RWKV4_169M = RwkvConfig("rwkv4-169m", n_layer=12, d_model=768, d_ffn=3072, vocab=50277)
RWKV4_430M = RwkvConfig("rwkv4-430m", n_layer=24, d_model=1024, d_ffn=4096, vocab=50277)
RWKV4_1B5 = RwkvConfig("rwkv4-1b5", n_layer=24, d_model=2048, d_ffn=8192, vocab=50277)
RWKV4_3B = RwkvConfig("rwkv4-3b", n_layer=32, d_model=2560, d_ffn=10240, vocab=50277)
RWKV4_7B = RwkvConfig("rwkv4-7b", n_layer=32, d_model=4096, d_ffn=16384, vocab=50277)

PAPER_SIZES = [RWKV4_169M, RWKV4_430M, RWKV4_1B5, RWKV4_3B, RWKV4_7B]


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    """Training hyper-parameters for the tiny end-to-end model."""

    seq_len: int = 128
    batch: int = 8
    steps: int = 1400
    lr: float = 3e-3
    lr_final: float = 3e-4
    warmup: int = 20
    beta1: float = 0.9
    beta2: float = 0.99
    eps: float = 1e-8
    weight_decay: float = 1e-4
    grad_clip: float = 1.0
    seed: int = 0
    log_every: int = 10


def dump_shapes_manifest(path: str) -> None:
    """Write the paper-size shape manifest consumed by the Rust simulator."""
    data = {c.name: {**c.to_dict(), "n_params": c.n_params} for c in PAPER_SIZES}
    with open(path, "w") as f:
        json.dump(data, f, indent=2)
