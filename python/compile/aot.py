"""AOT pipeline: train (if needed) + lower the RWKV model to HLO text.

Python runs ONCE here; the Rust binary is self-contained afterwards.

Interchange format is HLO *text*, not a serialized HloModuleProto — the
image's xla_extension 0.5.1 rejects jax>=0.5's 64-bit-instruction-id
protos, while the text parser reassigns ids (see
/opt/xla-example/README.md).  Lowering goes through stablehlo ->
XlaComputation with ``return_tuple=True``; the Rust side unwraps the
tuple.

Artifacts written (all under ``artifacts/``):

* ``rwkv_step.hlo.txt``     — token step, Pallas-kernel variant (L1 inside)
* ``rwkv_step_hw.hlo.txt``  — token step, hardware-approximation variant
* ``rwkv_seq.hlo.txt``      — SEQ_CHUNK-token chunked scorer
* ``tiny.weights.bin``      — trained weights (HFWT container)
* ``manifest.json``         — the ABI: parameter order/shapes, state shape
* ``eval_data.json``        — held-out eval suites (DESIGN.md E1)
* ``quant_codebooks.json``  — golden codebooks for the Rust parity test
* ``paper_shapes.json``     — RWKV-4 169M..7B shape manifest for the sim
* ``train_log.json``        — loss curve of the tiny-model training run
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data, model, quantize, serialize, train
from .config import TINY, TrainConfig, dump_shapes_manifest

SEQ_CHUNK = 32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_step(cfg, variant: str) -> str:
    fn = model.make_step_fn(cfg, variant)
    specs = [jax.ShapeDtypeStruct(shape, jnp.float32)
             for _, shape in model.param_order(cfg)]
    specs.append(jax.ShapeDtypeStruct((cfg.n_layer, 5, cfg.d_model), jnp.float32))
    specs.append(jax.ShapeDtypeStruct((), jnp.int32))
    return to_hlo_text(jax.jit(fn).lower(*specs))


def lower_seq(cfg, seq_len: int, variant: str = "exact") -> str:
    fn = model.make_seq_fn(cfg, seq_len, variant)
    specs = [jax.ShapeDtypeStruct(shape, jnp.float32)
             for _, shape in model.param_order(cfg)]
    specs.append(jax.ShapeDtypeStruct((cfg.n_layer, 5, cfg.d_model), jnp.float32))
    specs.append(jax.ShapeDtypeStruct((seq_len,), jnp.int32))
    return to_hlo_text(jax.jit(fn).lower(*specs))


def write_manifest(path: str, cfg) -> None:
    manifest = {
        "config": cfg.to_dict(),
        "n_params": cfg.n_params,
        "param_order": [
            {"name": name, "shape": list(shape)}
            for name, shape in model.param_order(cfg)
        ],
        "state_shape": [cfg.n_layer, 5, cfg.d_model],
        "pp_init": model.PP_INIT,
        "seq_chunk": SEQ_CHUNK,
        "artifacts": {
            "step": "rwkv_step.hlo.txt",
            "step_hw": "rwkv_step_hw.hlo.txt",
            "seq": "rwkv_seq.hlo.txt",
            "weights": "tiny.weights.bin",
            "eval_data": "eval_data.json",
        },
    }
    with open(path, "w") as f:
        json.dump(manifest, f, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--steps", type=int, default=TrainConfig().steps)
    ap.add_argument("--skip-train", action="store_true",
                    help="reuse existing weights if present")
    args = ap.parse_args()
    out = args.out_dir
    os.makedirs(out, exist_ok=True)
    cfg = TINY

    wpath = os.path.join(out, "tiny.weights.bin")
    if os.path.exists(wpath) and args.skip_train:
        print(f"reusing {wpath}")
    else:
        tc = TrainConfig(steps=args.steps)
        print(f"training {cfg.name} ({cfg.n_params/1e6:.2f}M params) "
              f"for {tc.steps} steps ...", flush=True)
        params, log = train.train(cfg, tc)
        train.save_log(log, os.path.join(out, "train_log.json"))
        tensors = {name: np.asarray(params[name], np.float32)
                   for name, _ in model.param_order(cfg)}
        serialize.save_tensors(wpath, tensors, meta=cfg.to_dict())
        print(f"wrote {wpath} (final loss {log[-1]['loss']:.4f})")

    for fname, variant in [("rwkv_step.hlo.txt", "pallas"),
                           ("rwkv_step_hw.hlo.txt", "hwapprox")]:
        path = os.path.join(out, fname)
        text = lower_step(cfg, variant)
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    path = os.path.join(out, "rwkv_seq.hlo.txt")
    text = lower_seq(cfg, SEQ_CHUNK)
    with open(path, "w") as f:
        f.write(text)
    print(f"wrote {path} ({len(text)} chars)")

    write_manifest(os.path.join(out, "manifest.json"), cfg)
    data.write_eval_data(os.path.join(out, "eval_data.json"))
    quantize.dump_codebooks(os.path.join(out, "quant_codebooks.json"))
    dump_shapes_manifest(os.path.join(out, "paper_shapes.json"))
    print("aot done")


if __name__ == "__main__":
    main()
