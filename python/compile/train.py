"""Train the tiny end-to-end RWKV-4 model on the synthetic corpus.

Build-time only (invoked from ``aot.py`` / ``make artifacts``); the loss
curve is logged to ``artifacts/train_log.json`` and summarized in
EXPERIMENTS.md.  Hand-rolled AdamW (optax is not in the image) with cosine
decay + warmup and global-norm gradient clipping.
"""

from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data, model
from .config import TINY, RwkvConfig, TrainConfig


def _adamw_update(params, grads, m, v, step, tc: TrainConfig, lr):
    """One AdamW step over the params dict; returns (params, m, v)."""
    b1, b2, eps, wd = tc.beta1, tc.beta2, tc.eps, tc.weight_decay
    new_p, new_m, new_v = {}, {}, {}
    t = step + 1
    for k in params:
        g = grads[k]
        m_k = b1 * m[k] + (1 - b1) * g
        v_k = b2 * v[k] + (1 - b2) * jnp.square(g)
        mhat = m_k / (1 - b1 ** t)
        vhat = v_k / (1 - b2 ** t)
        # no decay on gains/biases/1-d params (ln, time_*), like RWKV's init
        decay = wd if params[k].ndim >= 2 else 0.0
        new_p[k] = params[k] - lr * (mhat / (jnp.sqrt(vhat) + eps) + decay * params[k])
        new_m[k] = m_k
        new_v[k] = v_k
    return new_p, new_m, new_v


def _clip_by_global_norm(grads, max_norm):
    leaves = jax.tree_util.tree_leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g)) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), gnorm


def _lr_at(step: int, tc: TrainConfig) -> float:
    if step < tc.warmup:
        return tc.lr * (step + 1) / tc.warmup
    frac = (step - tc.warmup) / max(tc.steps - tc.warmup, 1)
    cos = 0.5 * (1.0 + np.cos(np.pi * frac))
    return tc.lr_final + (tc.lr - tc.lr_final) * cos


def make_batches(stream, tc: TrainConfig, seed: int):
    """Sample [B, T+1] windows from the token stream forever."""
    rng = np.random.default_rng(seed)
    arr = np.asarray(stream, dtype=np.int32)
    n = len(arr) - (tc.seq_len + 1)
    while True:
        starts = rng.integers(0, n, size=tc.batch)
        yield np.stack([arr[s: s + tc.seq_len + 1] for s in starts])


def train(cfg: RwkvConfig = TINY, tc: TrainConfig = TrainConfig(),
          n_train_tokens: int = 200_000, verbose: bool = True):
    """Train and return (params, log) where log is a list of step records."""
    key = jax.random.PRNGKey(tc.seed)
    params = model.init_params(cfg, key)
    m = jax.tree_util.tree_map(jnp.zeros_like, params)
    v = jax.tree_util.tree_map(jnp.zeros_like, params)

    stream = data.gen_stream(seed=tc.seed + 1, n_tokens=n_train_tokens)
    batches = make_batches(stream, tc, seed=tc.seed + 2)

    loss_grad = jax.jit(jax.value_and_grad(
        lambda p, toks: model.loss_fn(p, toks, cfg)))

    @jax.jit
    def opt_step(params, grads, m, v, step, lr):
        grads, gnorm = _clip_by_global_norm(grads, tc.grad_clip)
        params, m, v = _adamw_update(params, grads, m, v, step, tc, lr)
        return params, m, v, gnorm

    log = []
    t0 = time.time()
    for step in range(tc.steps):
        toks = jnp.asarray(next(batches))
        loss, grads = loss_grad(params, toks)
        lr = _lr_at(step, tc)
        params, m, v, gnorm = opt_step(params, grads, m, v, step, lr)
        if step % tc.log_every == 0 or step == tc.steps - 1:
            rec = {"step": step, "loss": float(loss), "lr": lr,
                   "gnorm": float(gnorm), "elapsed_s": time.time() - t0}
            log.append(rec)
            if verbose:
                print(f"step {step:4d}  loss {rec['loss']:.4f}  "
                      f"lr {lr:.2e}  gnorm {rec['gnorm']:.2f}  "
                      f"({rec['elapsed_s']:.0f}s)", flush=True)
    return params, log


def save_log(log, path: str) -> None:
    with open(path, "w") as f:
        json.dump(log, f, indent=1)
