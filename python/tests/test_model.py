"""L2 model: shapes, variant parity, state semantics, flat ABI."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.config import TINY, RwkvConfig

CFG_SMALL = RwkvConfig("unit", n_layer=2, d_model=64, d_ffn=128, vocab=64)


@pytest.fixture(scope="module")
def params():
    return model.init_params(CFG_SMALL, jax.random.PRNGKey(0))


def test_param_count_matches_config(params):
    n = sum(int(np.prod(v.shape)) for v in params.values())
    assert n == CFG_SMALL.n_params


def test_param_order_covers_all_params(params):
    order = model.param_order(CFG_SMALL)
    assert {name for name, _ in order} == set(params.keys())
    for name, shape in order:
        assert tuple(params[name].shape) == shape, name


def test_flatten_unflatten_roundtrip(params):
    flat = model.flatten_params(params, CFG_SMALL)
    back = model.unflatten_params(flat, CFG_SMALL)
    for k in params:
        np.testing.assert_array_equal(np.asarray(params[k]), np.asarray(back[k]))


def test_step_shapes(params):
    s = model.init_state(CFG_SMALL)
    logits, s2 = model.step(params, s, jnp.int32(3), CFG_SMALL)
    assert logits.shape == (CFG_SMALL.vocab,)
    assert s2.shape == s.shape
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_pallas_variant_matches_exact(params):
    s = model.init_state(CFG_SMALL)
    tok = jnp.int32(5)
    le, se = model.step(params, s, tok, CFG_SMALL, variant="exact")
    lp, sp = model.step(params, s, tok, CFG_SMALL, variant="pallas")
    np.testing.assert_allclose(le, lp, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(se, sp, rtol=1e-4, atol=1e-4)


def test_hwapprox_variant_close_but_not_equal(params):
    s = model.init_state(CFG_SMALL)
    tok = jnp.int32(5)
    le, _ = model.step(params, s, tok, CFG_SMALL, variant="exact")
    lh, _ = model.step(params, s, tok, CFG_SMALL, variant="hwapprox")
    # approximations must move the logits a little, but not blow them up
    diff = float(jnp.max(jnp.abs(le - lh)))
    assert 0.0 < diff < 5.0


def test_state_carries_information(params):
    """Same token, different history -> different logits."""
    s0 = model.init_state(CFG_SMALL)
    _, s_a = model.step(params, s0, jnp.int32(7), CFG_SMALL)
    _, s_b = model.step(params, s0, jnp.int32(11), CFG_SMALL)
    la, _ = model.step(params, s_a, jnp.int32(3), CFG_SMALL)
    lb, _ = model.step(params, s_b, jnp.int32(3), CFG_SMALL)
    assert float(jnp.max(jnp.abs(la - lb))) > 1e-4


def test_seq_forward_matches_step_loop(params):
    """lax.scan sequence forward == manual step loop (same state math)."""
    T = 6
    toks = jnp.array([1, 4, 2, 8, 5, 7], jnp.int32)
    seq_logits = model.forward_seq(params, toks, CFG_SMALL)
    s = model.init_state(CFG_SMALL)
    for t in range(T):
        step_logits, s = model.step(params, s, toks[t], CFG_SMALL)
        np.testing.assert_allclose(seq_logits[t], step_logits, rtol=2e-4, atol=2e-5)


def test_make_step_fn_flat_abi(params):
    flat = model.flatten_params(params, CFG_SMALL)
    fn = model.make_step_fn(CFG_SMALL, "exact")
    s = model.init_state(CFG_SMALL)
    logits, s2 = fn(*flat, s, jnp.int32(2))
    want, _ = model.step(params, s, jnp.int32(2), CFG_SMALL)
    np.testing.assert_allclose(logits, want, rtol=1e-6)


def test_make_seq_fn_state_threading(params):
    """Chunked scoring with threaded state == one long sequence."""
    flat = model.flatten_params(params, CFG_SMALL)
    fn = model.make_seq_fn(CFG_SMALL, 4)
    toks = jnp.array([1, 2, 3, 4, 5, 6, 7, 8], jnp.int32)
    s = model.init_state(CFG_SMALL)
    l1, s = fn(*flat, s, toks[:4])
    l2, s = fn(*flat, s, toks[4:])
    chunked = jnp.concatenate([l1, l2])
    full = model.forward_seq(params, toks, CFG_SMALL)
    np.testing.assert_allclose(chunked, full, rtol=2e-4, atol=2e-5)


def test_loss_fn_finite_and_near_uniform_at_init(params):
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, CFG_SMALL.vocab)
    loss = model.loss_fn(params, toks, CFG_SMALL)
    assert bool(jnp.isfinite(loss))
    # at init the model is near-uniform: loss ~ log(V)
    assert abs(float(loss) - np.log(CFG_SMALL.vocab)) < 1.0


def test_tiny_config_param_count():
    # documented size of the end-to-end model
    assert TINY.n_params == pytest.approx(1_000_000, rel=0.35)
