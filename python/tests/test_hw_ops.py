"""Approximation-error bounds of the hardware function units (vs libm).

These bounds are the paper's accuracy story: the PWL sigmoid, EXP-LUT and
DIVU-LUT must stay within small, known error envelopes.  The same bounds
are asserted by the Rust property tests on the integer datapaths.
"""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import hw_ops, ref

SET = settings(max_examples=50, deadline=None)

# Known max absolute error of the eq-(9) PWL on [0, inf): the worst gap of
# the classic Amin/Curtis/Hayes-Gill segmentation is < 0.019 (measured
# 0.018941 at the segment joints).
SIGMOID_PWL_MAX_ERR = 0.0190


def test_sigmoid_pwl_max_error_grid():
    x = jnp.linspace(-10.0, 10.0, 20001)
    err = jnp.abs(ref.sigmoid_pwl_ref(x) - 1.0 / (1.0 + jnp.exp(-x)))
    assert float(jnp.max(err)) <= SIGMOID_PWL_MAX_ERR + 1e-6


@SET
@given(st.floats(-50.0, 50.0))
def test_sigmoid_pwl_pointwise(x):
    got = float(ref.sigmoid_pwl_ref(jnp.float32(x)))
    want = float(1.0 / (1.0 + np.exp(-np.float64(x))))
    assert abs(got - want) <= SIGMOID_PWL_MAX_ERR + 1e-5
    assert 0.0 <= got <= 1.0


def test_sigmoid_pwl_symmetry():
    x = jnp.linspace(-8.0, 8.0, 999)
    s = ref.sigmoid_pwl_ref(x)
    np.testing.assert_allclose(s + ref.sigmoid_pwl_ref(-x), 1.0, atol=1e-6)


def test_sigmoid_pwl_nearly_monotone():
    # Eq (9) as printed has a small downward jump at the x=2.375 joint
    # (0.921875 -> 0.917969, i.e. -0.0039); the approximation is monotone
    # only up to that discontinuity.  Assert no larger violation exists.
    x = jnp.linspace(-8.0, 8.0, 4001)
    s = np.asarray(ref.sigmoid_pwl_ref(x))
    assert np.all(np.diff(s) >= -0.004)


# EXP unit: relative error comes from (a) log2e ~= 1.4375 (0.37% low) and
# (b) the 8-bit LUT truncation (up to 2^-8 in the exponent).  Bound ~3%
# relative over a wide domain.
EXP_REL_ERR = 0.032


@SET
@given(st.floats(-15.0, 8.0))
def test_exp_lut_relative_error(x):
    got = float(ref.exp_lut_ref(jnp.float32(x)))
    # compare against 2^(1.4375*x): the LUT truncation is the only error
    want = float(2.0 ** (1.4375 * np.float64(x)))
    assert got > 0
    assert abs(got - want) / want <= EXP_REL_ERR


def test_exp_lut_against_true_exp_domain():
    """Total error (log2e rounding + LUT) stays within 4% on [-10, 5]."""
    x = jnp.linspace(-10.0, 5.0, 5001)
    got = np.asarray(ref.exp_lut_ref(x), np.float64)
    want = np.exp(np.asarray(x, np.float64))
    rel = np.abs(got - want) / want
    assert rel.max() <= 0.04, rel.max()


def test_hw_exp_clamps_domain():
    assert float(hw_ops.hw_exp(jnp.float32(-1e30))) >= 0.0
    assert np.isfinite(float(hw_ops.hw_exp(jnp.float32(1e30))))


# DIVU: 4-bit mantissa truncation gives <= ~12.5% worst-case mantissa
# error; 8-bit output storage adds 2^-8.
DIV_REL_ERR = 0.13


@SET
@given(st.floats(2.0**-10, 2.0**10), st.floats(2.0**-10, 2.0**10))
def test_divu_relative_error(x, y):
    got = float(ref.divu_ref(jnp.float32(x), jnp.float32(y)))
    want = x / y
    assert abs(got - want) / want <= DIV_REL_ERR


def test_divu_exact_on_powers_of_two():
    for k1 in range(-4, 5):
        for k2 in range(-4, 5):
            x, y = 2.0**k1, 2.0**k2
            got = float(ref.divu_ref(jnp.float32(x), jnp.float32(y)))
            np.testing.assert_allclose(got, x / y, rtol=1e-6)


def test_hw_div_signs():
    for sn in (-3.0, 3.0):
        for sd in (-2.0, 2.0):
            got = float(hw_ops.hw_div(jnp.float32(sn), jnp.float32(sd)))
            assert np.sign(got) == np.sign(sn / sd)


def test_hw_layernorm_close_to_exact():
    import jax
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (256,)) * 2.0
    w = jnp.ones(256)
    b = jnp.zeros(256)
    got = hw_ops.hw_layernorm(x, w, b)
    want = ref.layernorm_ref(x, w, b)
    # DIVU mantissa truncation dominates: allow its relative envelope
    err = np.abs(np.asarray(got - want))
    scale = np.abs(np.asarray(want)) + 1e-3
    assert (err / scale).max() <= 0.15


def test_quant_sym_roundtrip_properties():
    import jax
    x = jax.random.normal(jax.random.PRNGKey(1), (1000,)) * 3.0
    q = hw_ops.quant_sym(x, bits=9)
    # max quantization step = s/qmax
    step = float(jnp.max(jnp.abs(x))) / 255.0
    assert float(jnp.max(jnp.abs(q - x))) <= step * 0.5 + 1e-7
    # idempotent
    q2 = hw_ops.quant_sym(q, bits=9, scale=jnp.max(jnp.abs(x)))
    np.testing.assert_allclose(q, q2, atol=1e-7)
