"""Pallas kernels vs pure-jnp oracle — the core L1 correctness signal.

hypothesis sweeps shapes/dtypes/value ranges; every kernel must
assert_allclose against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import dpot_mv, hw_layernorm, ref, wkv

SET = settings(max_examples=20, deadline=None)


def rand(key, shape, scale=1.0):
    return jax.random.normal(key, shape) * scale


# --------------------------------------------------------------------------
# LayerNorm kernel
# --------------------------------------------------------------------------

@SET
@given(
    d=st.sampled_from([32, 64, 128, 256, 768, 1024]),
    block=st.sampled_from([64, 128, 256, 512]),
    seed=st.integers(0, 2**31 - 1),
)
def test_layernorm_matches_ref(d, block, seed):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    x = rand(k1, (d,), 3.0)
    w = 1.0 + rand(k2, (d,), 0.1)
    b = rand(k3, (d,), 0.1)
    got = hw_layernorm.layernorm(x, w, b, block=block)
    want = ref.layernorm_ref(x, w, b)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_layernorm_identity_equals_twopass():
    key = jax.random.PRNGKey(0)
    x = rand(key, (256,), 5.0)
    w = jnp.ones(256)
    b = jnp.zeros(256)
    a = ref.layernorm_ref(x, w, b)
    c = ref.layernorm_identity_ref(x, w, b)
    np.testing.assert_allclose(a, c, rtol=1e-5, atol=1e-5)


def test_layernorm_ragged_dim_clamps_block():
    # d=96 is not divisible by 64; the kernel must clamp the block width.
    key = jax.random.PRNGKey(1)
    x = rand(key, (96,), 2.0)
    w = jnp.ones(96)
    b = jnp.zeros(96)
    got = hw_layernorm.layernorm(x, w, b, block=64)
    np.testing.assert_allclose(got, ref.layernorm_ref(x, w, b), rtol=2e-5, atol=2e-5)


def test_layernorm_constant_input_stable():
    # var == 0: eps must keep the output finite.
    x = jnp.full((128,), 3.0)
    got = hw_layernorm.layernorm(x, jnp.ones(128), jnp.zeros(128))
    assert bool(jnp.all(jnp.isfinite(got)))


# --------------------------------------------------------------------------
# WKV kernel
# --------------------------------------------------------------------------

@SET
@given(d=st.sampled_from([16, 64, 128, 512]), seed=st.integers(0, 2**31 - 1))
def test_wkv_step_matches_ref(d, seed):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 7)
    k = rand(ks[0], (d,), 1.5)
    v = rand(ks[1], (d,), 1.0)
    aa = rand(ks[2], (d,), 0.5)
    bb = jnp.abs(rand(ks[3], (d,), 0.5)) + 0.5
    pp = rand(ks[4], (d,), 1.0)
    u = rand(ks[5], (d,), 0.3)
    w = -jnp.exp(rand(ks[6], (d,), 0.5))  # effective decay, negative
    got = wkv.wkv_step(k, v, aa, bb, pp, u, w)
    want = ref.wkv_step_ref(k, v, aa, bb, pp, u, w)
    for g, wnt in zip(got, want):
        np.testing.assert_allclose(g, wnt, rtol=1e-5, atol=1e-5)


def test_wkv_recurrence_stays_finite():
    """pp running max keeps exp() in range over many steps."""
    d = 64
    key = jax.random.PRNGKey(3)
    u = rand(key, (d,), 0.3)
    w = -jnp.exp(jnp.linspace(-5.0, -1.0, d))
    aa = jnp.zeros(d)
    bb = jnp.zeros(d)
    pp = jnp.full((d,), -1e30)
    for t in range(200):
        kk = rand(jax.random.PRNGKey(100 + t), (d,), 2.0)
        vv = rand(jax.random.PRNGKey(500 + t), (d,), 1.0)
        out, aa, bb, pp = wkv.wkv_step(kk, vv, aa, bb, pp, u, w)
    assert bool(jnp.all(jnp.isfinite(out)))
    assert bool(jnp.all(jnp.isfinite(aa))) and bool(jnp.all(jnp.isfinite(bb)))


def test_wkv_first_token_equals_bonus_path():
    """With empty state (pp=-inf), wkv == v for the first token."""
    d = 32
    key = jax.random.PRNGKey(4)
    k = rand(key, (d,))
    v = rand(jax.random.PRNGKey(5), (d,))
    u = rand(jax.random.PRNGKey(6), (d,))
    w = -jnp.ones(d)
    out, aa, bb, pp = wkv.wkv_step(
        k, v, jnp.zeros(d), jnp.zeros(d), jnp.full((d,), -1e30), u, w)
    np.testing.assert_allclose(out, v, rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------
# Delta-PoT matvec kernel
# --------------------------------------------------------------------------

def _random_codes(key, shape):
    k1, k2, k3 = jax.random.split(key, 3)
    sign = jnp.where(jax.random.bernoulli(k1, 0.5, shape), 1, -1).astype(jnp.int8)
    dq0 = jax.random.randint(k2, shape, 0, 16).astype(jnp.int8)
    dq1 = jax.random.randint(k3, shape, 0, 16).astype(jnp.int8)
    return sign, dq0, dq1


@SET
@given(
    dims=st.sampled_from([(16, 16), (64, 32), (128, 128), (256, 128), (96, 64)]),
    tile=st.sampled_from([32, 64, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_dpot_matvec_matches_ref(dims, tile, seed):
    d_out, d_in = dims
    key = jax.random.PRNGKey(seed)
    sign, dq0, dq1 = _random_codes(key, (d_out, d_in))
    x = rand(jax.random.fold_in(key, 7), (d_in,))
    gamma = jnp.array([0.37], jnp.float32)
    got = dpot_mv.dpot_matvec(sign, dq0, dq1, gamma, x, tile_out=tile)
    want = ref.dpot_matvec_ref(sign, dq0, dq1, gamma[0], x)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_dpot_zero_code_is_zero_weight():
    """dq0 == 0 encodes exact zero regardless of dq1 (paper eq 6)."""
    d = 8
    sign = jnp.ones((d, d), jnp.int8)
    dq0 = jnp.zeros((d, d), jnp.int8)
    dq1 = jnp.full((d, d), 5, jnp.int8)
    x = jnp.ones(d)
    out = dpot_mv.dpot_matvec(sign, dq0, dq1, jnp.array([1.0]), x)
    np.testing.assert_allclose(out, jnp.zeros(d), atol=0)


def test_dpot_single_term_value():
    """dq0=1, dq1=0 -> weight = 2*gamma*2^-1 = gamma."""
    sign = jnp.ones((4, 4), jnp.int8)
    dq0 = jnp.ones((4, 4), jnp.int8)
    dq1 = jnp.zeros((4, 4), jnp.int8)
    x = jnp.ones(4)
    out = dpot_mv.dpot_matvec(sign, dq0, dq1, jnp.array([0.25]), x)
    np.testing.assert_allclose(out, jnp.full(4, 4 * 0.25), rtol=1e-6)
