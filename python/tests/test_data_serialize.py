"""Corpus/eval generators (determinism, gold validity) + HFWT round-trip."""

import json

import numpy as np
import pytest

from compile import data, serialize


def test_vocab_stable_and_sized():
    v = data.build_vocab()
    assert len(v) == 128
    assert v[0] == "<pad>" and v[1] == "<bos>"
    assert len(set(v)) == 128


def test_stream_deterministic():
    a = data.gen_stream(42, 5000)
    b = data.gen_stream(42, 5000)
    assert a == b
    assert len(a) == 5000
    assert all(0 <= t < 128 for t in a)


def test_stream_seed_sensitivity():
    assert data.gen_stream(1, 1000) != data.gen_stream(2, 1000)


def test_fact_doc_recalls_are_consistent():
    import random
    rng = random.Random(7)
    for _ in range(50):
        words = data._gen_fact_doc(rng)
        # parse facts
        facts = {}
        i = 0
        while i < len(words):
            j = words.index(".", i)
            sent = words[i:j]
            if len(sent) == 5 and sent[1] == "has":
                facts[(sent[4], sent[0])] = sent[3]  # (object, name) -> color
            elif len(sent) == 6 and sent[0] == "the":
                # the OBJ of NAME is COLOR
                assert facts[(sent[1], sent[3])] == sent[5]
            i = j + 1


def test_eval_data_gold_indices_valid():
    d = data.gen_eval_data(seed=1, n_per_suite=40)
    assert len(d["lambada"]) == 40
    for item in d["lambada"]:
        assert len(item["tokens"]) > 5
    for name, suite in d["suites"].items():
        assert len(suite) == 40, name
        for item in suite:
            assert 0 <= item["gold"] < len(item["choices"])
            assert all(len(c) >= 1 for c in item["choices"])
            # distractors differ from the gold continuation
            gold = item["choices"][item["gold"]]
            assert all(c != gold for i, c in enumerate(item["choices"])
                       if i != item["gold"])


def test_eval_data_deterministic():
    a = data.gen_eval_data(seed=3, n_per_suite=10)
    b = data.gen_eval_data(seed=3, n_per_suite=10)
    assert json.dumps(a) == json.dumps(b)


def test_hfwt_roundtrip(tmp_path):
    rng = np.random.default_rng(0)
    tensors = {
        "a.weight": rng.normal(size=(7, 13)).astype(np.float32),
        "b.codes": rng.integers(-8, 8, size=(5,)).astype(np.int8),
        "c.scalar": np.array([3], np.int32),
    }
    p = tmp_path / "w.bin"
    serialize.save_tensors(str(p), tensors, meta={"hello": 1})
    back, meta = serialize.load_tensors(str(p))
    assert meta == {"hello": 1}
    assert set(back) == set(tensors)
    for k in tensors:
        np.testing.assert_array_equal(back[k], tensors[k])


def test_hfwt_alignment(tmp_path):
    tensors = {"x": np.ones(3, np.float32), "y": np.ones(5, np.float32)}
    p = tmp_path / "w.bin"
    serialize.save_tensors(str(p), tensors)
    back, _ = serialize.load_tensors(str(p))
    np.testing.assert_array_equal(back["y"], tensors["y"])


def test_hfwt_rejects_bad_dtype(tmp_path):
    with pytest.raises(AssertionError):
        serialize.save_tensors(str(tmp_path / "w.bin"),
                               {"x": np.ones(3, np.float16)})
