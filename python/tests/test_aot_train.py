"""AOT lowering sanity + short-training smoke (loss must drop)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model, train
from compile.config import RwkvConfig, TrainConfig

CFG = RwkvConfig("unit", n_layer=2, d_model=64, d_ffn=128, vocab=64)


def test_lower_step_produces_hlo_text():
    text = aot.lower_step(CFG, "exact")
    assert text.startswith("HloModule"), text[:60]
    assert "ROOT" in text
    # one HLO parameter per model param + state + token
    n_expected = len(model.param_order(CFG)) + 2
    assert text.count("parameter(") >= n_expected


def test_lower_step_pallas_variant_lowered():
    text = aot.lower_step(CFG, "pallas")
    assert text.startswith("HloModule")
    # interpret-mode pallas inlines to plain HLO: no custom-call may remain
    assert "custom-call" not in text.lower() or "mosaic" not in text.lower()


def test_lower_seq_has_loop():
    text = aot.lower_seq(CFG, 8)
    assert text.startswith("HloModule")
    assert "while" in text  # lax.scan lowers to a while loop


def test_short_training_reduces_loss():
    tc = TrainConfig(steps=25, batch=4, seq_len=64, warmup=5, log_every=5,
                     lr=4e-3)
    params, log = train.train(CFG, tc, n_train_tokens=20_000, verbose=False)
    first, last = log[0]["loss"], log[-1]["loss"]
    assert np.isfinite(last)
    assert last < first - 0.3, (first, last)


def test_lr_schedule_shape():
    tc = TrainConfig(steps=100, warmup=10)
    lrs = [train._lr_at(s, tc) for s in range(100)]
    assert lrs[0] < lrs[9] <= tc.lr + 1e-12          # warmup rises
    assert max(lrs) == pytest.approx(tc.lr, rel=1e-6)
    assert lrs[-1] < tc.lr_final * 1.2               # decays to ~lr_final


def test_make_batches_windows():
    tc = TrainConfig(batch=3, seq_len=16)
    stream = list(range(2000))
    b = next(train.make_batches(stream, tc, seed=0))
    assert b.shape == (3, 17)
    # each row is a contiguous window
    for row in b:
        assert list(row) == list(range(row[0], row[0] + 17))
