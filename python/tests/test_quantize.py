"""Quantizer codebooks: structure, paper-example checks, scheme ordering."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import quantize as q

SET = settings(max_examples=25, deadline=None)


def test_rtn_levels_uniform():
    lv = q.rtn_levels(9)
    assert lv[0] == 0.0 and lv[-1] == 1.0
    np.testing.assert_allclose(np.diff(lv), 1.0 / 255.0, rtol=1e-12)


def test_pot_levels_are_powers_of_two():
    lv = q.pot_levels()
    nz = lv[lv > 0]
    np.testing.assert_allclose(np.exp2(np.round(np.log2(nz))), nz, rtol=0)
    assert lv.max() == 1.0


def test_dpot_levels_match_paper_example():
    """Paper section 3.1: gamma*(2^0 + 2^-2) is exactly representable as
    2*gamma*(2^-1 + 2^-3) in Delta-PoT but not in 4-bit APoT."""
    target = 2.0**0 + 2.0**-2  # 1.25
    dpot = q.dpot_levels(k0=2, k1=2)
    # normalize target by the pre-normalization max (2*(2^-1+2^-2)=1.5)
    pre_levels = {0.0}
    for dq0 in range(1, 4):
        p0 = 2.0**-dq0
        pre_levels.add(2 * p0)
        for dq1 in range(1, 4):
            pre_levels.add(2 * (p0 + p0 * 2.0**-dq1))
    assert any(abs(lv - target) < 1e-12 for lv in pre_levels), sorted(pre_levels)
    assert dpot.max() == 1.0


def test_dpot_level_count_9bit_budget():
    lv = q.dpot_levels(4, 4)
    # sign+4+4 bits: at most 1 + 15*16 magnitudes, deduplicated
    assert 100 <= len(lv) <= 241


def test_apot_levels_sorted_unique_max1():
    lv = q.apot_levels()
    assert np.all(np.diff(lv) > 0)
    assert lv[0] == 0.0 and lv[-1] == 1.0


@SET
@given(st.integers(0, 2**31 - 1))
def test_fake_quant_bounded_error(seed):
    rng = np.random.default_rng(seed)
    w = rng.normal(size=512).astype(np.float64)
    for scheme in q.SCHEMES:
        wq = q.fake_quant_scheme(w, scheme)
        assert wq.shape == w.shape
        assert np.abs(wq).max() <= np.abs(w).max() * (1 + 1e-9)
        # signs never flip
        assert np.all((np.sign(wq) == np.sign(w)) | (wq == 0.0))


def test_scheme_mse_ordering_gaussian():
    """The paper's Table-1 story at codebook level: on gaussian weights,
    Delta-PoT < {RTN-ish} << PoT in reconstruction MSE, and Delta-PoT
    beats plain PoT and LogQ decisively."""
    rng = np.random.default_rng(0)
    w = rng.normal(size=200_000) * 0.02
    mse = {s: np.mean((q.fake_quant_scheme(w, s) - w) ** 2) for s in q.SCHEMES}
    assert mse["dpot"] < mse["pot"] * 0.25, mse
    assert mse["dpot"] < mse["logq"] * 0.25, mse
    assert mse["rtn"] < mse["pot"], mse


def test_quantize_logq_log_domain_rounding():
    w = np.array([0.9, 0.6, 0.3, 0.1]) * 1.0
    wq = q.quantize_logq(w)
    nz = wq[wq > 0]
    # every output is a power of two times the scale (scale = 0.9)
    ratio = nz / 0.9
    np.testing.assert_allclose(np.exp2(np.round(np.log2(ratio))), ratio, rtol=1e-12)


def test_zero_tensor_passthrough():
    w = np.zeros(16)
    for scheme in q.SCHEMES:
        np.testing.assert_array_equal(q.fake_quant_scheme(w, scheme), w)


def test_dump_codebooks_roundtrip(tmp_path):
    import json
    p = tmp_path / "cb.json"
    q.dump_codebooks(str(p))
    data = json.loads(p.read_text())
    assert set(data) == {"rtn", "pot", "apot", "dpot", "params"}
    np.testing.assert_allclose(data["dpot"], q.dpot_levels().tolist())
