//! Quantization deep-dive: codebook geometry, reconstruction error by
//! weight distribution, and the bit-budget sweep behind the paper's §3
//! claim that Δ-PoT's flexible (k0,k1) allocation beats APoT's fixed
//! split.  Runs without artifacts.
//!
//! ```bash
//! cargo run --release --example quant_ablation
//! ```

use hfrwkv::harness::ablation::dpot_levels_k;
use hfrwkv::quant::{self, Codebook, Scheme};
use hfrwkv::Rng64;

fn gaussian(n: usize, sigma: f32, seed: u64) -> Vec<f32> {
    let mut rng = Rng64::new(seed);
    (0..n).map(|_| rng.normal() as f32 * sigma).collect()
}

fn laplacian(n: usize, b: f32, seed: u64) -> Vec<f32> {
    // heavier tails than gaussian — closer to real LLM weight histograms
    let mut rng = Rng64::new(seed);
    (0..n)
        .map(|_| {
            let u = rng.next_f64() - 0.5;
            (-u.abs().ln() * u.signum()) as f32 * b
        })
        .collect()
}

fn mse(w: &[f32], scheme: Scheme) -> f64 {
    let mut q = w.to_vec();
    quant::fake_quant(&mut q, scheme);
    w.iter().zip(&q).map(|(a, b)| ((a - b) as f64).powi(2)).sum::<f64>() / w.len() as f64
}

fn main() {
    println!("== codebook sizes at the 9-bit budget ==");
    for (name, n) in [
        ("RTN", quant::rtn_levels().len()),
        ("PoT", quant::pot_levels().len()),
        ("APoT", quant::apot_levels().len()),
        ("Δ-PoT", quant::dpot_levels().len()),
    ] {
        println!("  {name:<6} {n} magnitude levels");
    }

    println!("\n== reconstruction MSE by weight distribution (lower is better) ==");
    println!("  {:<12} {:>10} {:>10} {:>10} {:>10} {:>10}", "distribution", "RTN", "PoT", "LogQ", "APoT", "Δ-PoT");
    for (name, w) in [
        ("gauss σ=.02", gaussian(200_000, 0.02, 1)),
        ("gauss σ=.2", gaussian(200_000, 0.2, 2)),
        ("laplace b=.05", laplacian(200_000, 0.05, 3)),
    ] {
        print!("  {name:<12}");
        for s in [Scheme::Rtn, Scheme::Pot, Scheme::LogQ, Scheme::Apot, Scheme::Dpot] {
            print!(" {:>10.3e}", mse(&w, s));
        }
        println!();
    }

    println!("\n== Δ-PoT (k0,k1) allocation sweep (gaussian σ=.02) ==");
    let w = gaussian(200_000, 0.02, 4);
    for (k0, k1) in [(2u32, 2u32), (3, 3), (4, 4), (5, 3), (3, 5), (2, 6), (6, 2)] {
        let levels = dpot_levels_k(k0, k1);
        let cb = Codebook::new(levels.iter().map(|&x| x as f32).collect());
        println!(
            "  k0={k0} k1={k1} ({:>2} bits): {} levels, MSE {:.3e}",
            1 + k0 + k1,
            cb.levels().len(),
            cb.mse(&w)
        );
    }

    println!("\n== the paper's §3.1 worked example ==");
    // a second element pins the tensor scale at 1.5 (the codebook max)
    // so 1.25 = (2^0 + 2^-2)·γ with γ such that max level ↔ 1.5
    println!("  target (2^0 + 2^-2)γ = 1.25γ within a tensor scaled to 1.5γ");
    let mut apot = [1.5f32, 1.25];
    quant::fake_quant(&mut apot, Scheme::Apot);
    let mut dpot = [1.5f32, 1.25];
    quant::fake_quant(&mut dpot, Scheme::Dpot);
    println!("  APoT rounds to  {:.6} (nearest level in its stride-2 set)", apot[1]);
    println!("  Δ-PoT rounds to {:.6} (exact: 2γ(2^-1+2^-3))", dpot[1]);
}
