//! E7 — end-to-end validation (DESIGN.md §6): serve a batch of
//! generation requests over the *trained* tiny RWKV through the full
//! stack (coordinator → PJRT → HLO with Pallas kernels lowered in),
//! streaming the first session's tokens live, reporting latency
//! percentiles and aggregate throughput, demonstrating 1-prefill/8-branch
//! best-of-n decode off one shared RWKV state, serving the same trained
//! weights through the `HFRWKV_BACKEND`-selected native backend (exact
//! f32 / decoded hw / packed 9-bit SIMD) with its weight-traffic report,
//! then verifying model quality on the held-out suites.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_demo
//! # quantized throughput configuration:
//! HFRWKV_BACKEND=packed cargo run --release --example serve_demo
//! # Perfetto-loadable trace of the PJRT serving phases:
//! HFRWKV_TRACE=/tmp/serve_trace.json cargo run --release --example serve_demo
//! # serve the demo model over HTTP/SSE instead of running the phases
//! # (then drive it with the curl line the process prints):
//! HFRWKV_HTTP=127.0.0.1:8090 cargo run --release --example serve_demo
//! ```

use std::io::Write;
use std::time::Instant;

use hfrwkv::coordinator::{Backend, Coordinator, CoordinatorConfig, GenEvent, GenRequest};
use hfrwkv::eval;
use hfrwkv::model::{RwkvModel, Tokenizer, WeightFile};
use hfrwkv::runtime::{Manifest, RwkvRuntime};
use hfrwkv::util::bench::percentile_sorted;

fn main() -> hfrwkv::Result<()> {
    let dir = std::path::Path::new("artifacts");
    let manifest = Manifest::load(dir)?;
    let eval_json = manifest.load_eval_data()?;
    let tokenizer = Tokenizer::from_json(eval_json.req("vocab")?)?;

    // ---- HFRWKV_HTTP=<addr>: serve the demo model over the network ---------
    // binds the HTTP/SSE tier on the trained weights (served natively
    // through the HFRWKV_BACKEND-selected backend) and blocks, so the
    // transport is exercisable by hand with curl
    if let Ok(addr) = std::env::var("HFRWKV_HTTP") {
        let weights = WeightFile::load(&manifest.weights)?;
        let native = RwkvModel::from_weights(&weights)?;
        let backend = Backend::from_env();
        let calib = {
            let mut t = vec![hfrwkv::model::tokenizer::BOS];
            t.extend(tokenizer.encode("alice has a red hat . the hat of alice is")?);
            t
        };
        let coord = std::sync::Arc::new(Coordinator::spawn_native(
            native,
            calib,
            CoordinatorConfig { max_active: 8, backend, ..Default::default() },
        ));
        // the server's encoder owns its own tokenizer, so string
        // prompts work over the wire: `"prompt": "text"` as well as ids
        let tok = tokenizer.clone();
        let encoder: hfrwkv::net::Encoder = std::sync::Arc::new(move |text: &str| {
            let mut prompt = vec![hfrwkv::model::tokenizer::BOS];
            prompt.extend(tok.encode(text)?);
            Ok(prompt)
        });
        let server = hfrwkv::net::Server::bind_with(
            addr.as_str(),
            coord,
            hfrwkv::net::ServerConfig { encoder: Some(encoder), ..Default::default() },
        )?;
        println!("serving the demo model ({backend:?} backend) on http://{}", server.addr());
        println!("try a streaming request (SSE frames render as they arrive):");
        println!(
            "  curl -N -X POST http://{}/v1/generate \\\n       -H 'X-Priority: 1' \\\n       -d '{{\"prompt\": \"alice has a red hat . the hat of alice is\", \"max_new_tokens\": 24}}'",
            server.addr()
        );
        println!(
            "observability: curl http://{0}/metrics   and   curl http://{0}/trace",
            server.addr()
        );
        println!("press Ctrl-C to stop");
        loop {
            std::thread::park();
        }
    }

    // ---- phase 0: live token streaming ------------------------------------
    println!("== streaming (one session, tokens rendered as they arrive) ==");
    // max_active 8 so the best-of-8 fork below gets a slot per branch
    // (submit clamps n_best to max_active) — phase 1's 24 queued
    // requests therefore decode up to 8-way, not the historical 4-way
    let coord = Coordinator::spawn_with(
        || RwkvRuntime::load(std::path::Path::new("artifacts")).expect("runtime"),
        CoordinatorConfig { max_active: 8, ..Default::default() },
    );
    // warm-up (compilation happens inside the worker)
    let _ = coord.generate(GenRequest::greedy(vec![1], 1))?;

    let encode = |text: &str| -> Vec<u32> {
        let mut prompt = vec![hfrwkv::model::tokenizer::BOS];
        prompt.extend(tokenizer.encode(text).unwrap());
        prompt
    };
    let mut stream = coord.submit(GenRequest::greedy(
        encode("alice has a red hat . the hat of alice is"),
        24,
    ))?;
    print!("  ");
    while let Some(ev) = stream.recv() {
        match ev {
            GenEvent::Started { cached_prefix_tokens, .. } => {
                print!("[started, {cached_prefix_tokens} cached] ");
            }
            GenEvent::Token { token, .. } => {
                print!("{} ", tokenizer.decode(&[token]));
                let _ = std::io::stdout().flush();
            }
            GenEvent::Redriven { attempt, replayed_from, .. } => {
                print!("[redriven #{attempt}, resuming after token {replayed_from}] ");
            }
            GenEvent::Finished(r) => {
                println!("\n  [finished: {:?}, {:.1} tok/s]", r.finish, r.decode_tokens_per_sec());
            }
            GenEvent::Error { message, .. } => println!("\n  [error: {message}]"),
        }
    }

    // ---- phase 1: batched serving through PJRT -----------------------------
    println!("\n== serving (coordinator -> PJRT CPU, batch-1 model, continuous batching) ==");
    let prompts = [
        "alice has a red hat . the hat of alice is",
        "three plus four is",
        "bob likes carol . so carol",
        "two times three is",
        "erin has a green bag . the bag of erin is",
        "frank trusts grace . so grace",
    ];
    let n_requests = 24;
    let t0 = Instant::now();
    let mut rxs = Vec::new();
    for i in 0..n_requests {
        rxs.push(coord.submit(GenRequest::greedy(encode(prompts[i % prompts.len()]), 24))?);
    }
    let mut latencies = Vec::new();
    let mut decode_rates = Vec::new();
    // the 24 requests cycle 6 prompts, so repeats resume from cached
    // prefix states: split TTFT by cold vs cached to show the effect
    let (mut ttft_cold, mut ttft_cached) = (Vec::new(), Vec::new());
    for (i, rx) in rxs.into_iter().enumerate() {
        let r = rx.wait_one()?;
        latencies.push(r.queue_seconds + r.prefill_seconds + r.decode_seconds);
        decode_rates.push(r.decode_tokens_per_sec());
        if r.cached_prefix_tokens > 0 {
            ttft_cached.push(r.ttft_seconds);
        } else {
            ttft_cold.push(r.ttft_seconds);
        }
        if i < 6 {
            println!("  [{i}] {}", tokenizer.decode(&r.tokens));
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    latencies.sort_by(|a, b| a.total_cmp(b));
    let m = coord.metrics.lock().unwrap().clone();
    println!("\n{}", m.report());
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
    println!(
        "ttft     {:.2} ms mean cold ({} reqs) vs {:.2} ms mean cache-resumed ({} reqs)",
        mean(&ttft_cold) * 1e3,
        ttft_cold.len(),
        mean(&ttft_cached) * 1e3,
        ttft_cached.len()
    );
    println!(
        "latency  p50 {:.1} ms   p95 {:.1} ms   max {:.1} ms",
        percentile_sorted(&latencies, 0.50) * 1e3,
        percentile_sorted(&latencies, 0.95) * 1e3,
        latencies.last().unwrap() * 1e3
    );
    println!(
        "aggregate {:.0} tok/s over {:.2} s wall ({} requests x 24 tokens)",
        m.tokens_generated as f64 / wall,
        wall,
        n_requests
    );
    // the same numbers as the report, machine-readable (scrapers take
    // this line instead of parsing the human report)
    println!("metrics-json {}", m.to_json());
    // HFRWKV_TRACE=<path> dumps the serving-phase trace ring as a
    // Chrome-trace JSON file — open it in Perfetto (ui.perfetto.dev) to
    // see each session's async span over the per-cycle scheduler slices
    if let Ok(path) = std::env::var("HFRWKV_TRACE") {
        match coord.export_trace(&path) {
            Ok(()) => println!("trace    wrote Perfetto-loadable trace to {path}"),
            Err(e) => eprintln!("trace    export to {path} failed: {e}"),
        }
    }

    // ---- phase 1b: best-of-n off one shared state --------------------------
    // one prompt prefill, 8 sampled continuations forked off the
    // post-prompt snapshot (seeds seed+0..seed+7) — the RWKV state is
    // O(1) bytes, so the fork costs 8 small state copies, not 8 prompt
    // prefills (the `prefilled` delta below is the proof)
    println!("\n== best-of-8 (ONE prefill, 8 branches off one shared state) ==");
    let prefilled_before = coord.metrics.lock().unwrap().prompt_tokens_prefilled;
    let req = GenRequest::builder(encode("bob likes carol . so carol"), 16)
        .temperature(0.9)
        .top_k(20)
        .seed(42)
        .n_best(8)
        .build();
    let prompt_len = req.prompt.len() as u64;
    let t0 = Instant::now();
    let branches = coord.generate_all(req)?;
    let fork_wall = t0.elapsed().as_secs_f64();
    for r in &branches {
        println!("  branch {}: {}", r.branch, tokenizer.decode(&r.tokens));
    }
    let prefilled = coord.metrics.lock().unwrap().prompt_tokens_prefilled - prefilled_before;
    println!(
        "  {} branches in {:.1} ms; prompt tokens prefilled: {} (= {} once{})",
        branches.len(),
        fork_wall * 1e3,
        prefilled,
        prompt_len,
        if prefilled <= prompt_len { ", shared across all branches" } else { " PER BRANCH?!" },
    );

    // ---- phase 1c: native backend serving (HFRWKV_BACKEND) -----------------
    // the same trained weights served WITHOUT PJRT, through whichever
    // native backend the env selects: exact f32 (default), decoded-plane
    // `hw`, or `packed` — the 9-bit SIMD throughput configuration, which
    // streams half the weight bytes per decode cycle (the traffic line in
    // the report below makes that visible)
    let backend = Backend::from_env();
    println!("\n== native serving (HFRWKV_BACKEND -> {backend:?}) ==");
    let weights = WeightFile::load(&manifest.weights)?;
    let native = RwkvModel::from_weights(&weights)?;
    // calibrate the quantized backends on in-distribution text: the
    // demo's own prompt set
    let calib: Vec<u32> = prompts.iter().flat_map(|p| encode(p)).collect();
    let nc = Coordinator::spawn_native(
        native,
        calib,
        CoordinatorConfig { max_active: 4, backend, ..Default::default() },
    );
    let t0 = Instant::now();
    let nrxs: Vec<_> = (0..12)
        .map(|i| nc.submit(GenRequest::greedy(encode(prompts[i % prompts.len()]), 24)))
        .collect::<hfrwkv::Result<_>>()?;
    let mut native_tokens = 0usize;
    for rx in nrxs {
        native_tokens += rx.wait_one()?.tokens.len();
    }
    let native_wall = t0.elapsed().as_secs_f64();
    let nm = nc.metrics.lock().unwrap().clone();
    println!("{}", nm.report());
    println!(
        "aggregate {:.0} tok/s over {:.2} s wall (12 requests x 24 tokens, {backend:?} backend)",
        native_tokens as f64 / native_wall,
        native_wall
    );
    nc.shutdown();

    // ---- phase 2: model quality on held-out data ---------------------------
    println!("\n== held-out quality (native forward) ==");
    let mut model = RwkvModel::from_weights(&weights)?;
    let (docs, suites) = eval::parse_eval_data(&eval_json)?;
    if let Some(stream) = eval::parse_valid_stream(&eval_json) {
        println!("  stream ppl     {:.3} (uniform = 128)", eval::stream_ppl(&mut model, &stream));
    }
    let (ppl, acc) = eval::eval_lambada(&mut model, &docs);
    println!("  lambada ppl    {ppl:.3}   last-word acc {:.1}%", acc * 100.0);
    for (name, items) in &suites {
        println!(
            "  {name:<14} acc {:.1}%",
            eval::eval_suite(&mut model, items) * 100.0
        );
    }
    Ok(())
}
