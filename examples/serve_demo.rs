//! E7 — end-to-end validation (DESIGN.md §6): serve a batch of
//! generation requests over the *trained* tiny RWKV through the full
//! stack (coordinator → PJRT → HLO with Pallas kernels lowered in),
//! reporting latency percentiles and aggregate throughput, then verify
//! model quality on the held-out suites.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_demo
//! ```

use std::time::Instant;

use hfrwkv::coordinator::{Coordinator, CoordinatorConfig, GenRequest};
use hfrwkv::eval;
use hfrwkv::model::{RwkvModel, Tokenizer, WeightFile};
use hfrwkv::runtime::{Manifest, RwkvRuntime};

fn pct(sorted: &[f64], p: f64) -> f64 {
    sorted[((sorted.len() as f64 * p) as usize).min(sorted.len() - 1)]
}

fn main() -> hfrwkv::Result<()> {
    let dir = std::path::Path::new("artifacts");
    let manifest = Manifest::load(dir)?;
    let eval_json = manifest.load_eval_data()?;
    let tokenizer = Tokenizer::from_json(eval_json.req("vocab")?)?;

    // ---- phase 1: batched serving through PJRT -----------------------------
    println!("== serving (coordinator -> PJRT CPU, batch-1 model, 4-way continuous batching) ==");
    let coord = Coordinator::spawn_with(
        || RwkvRuntime::load(std::path::Path::new("artifacts")).expect("runtime"),
        CoordinatorConfig { max_active: 4, ..Default::default() },
    );
    // warm-up (compilation happens inside the worker)
    let _ = coord.generate(GenRequest::greedy(vec![1], 1))?;

    let prompts = [
        "alice has a red hat . the hat of alice is",
        "three plus four is",
        "bob likes carol . so carol",
        "two times three is",
        "erin has a green bag . the bag of erin is",
        "frank trusts grace . so grace",
    ];
    let n_requests = 24;
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..n_requests)
        .map(|i| {
            // BOS-prefix: documents are BOS-led in the training corpus
            let mut prompt = vec![hfrwkv::model::tokenizer::BOS];
            prompt.extend(tokenizer.encode(prompts[i % prompts.len()]).unwrap());
            coord.submit(GenRequest::greedy(prompt, 24))
        })
        .collect();
    let mut latencies = Vec::new();
    let mut decode_rates = Vec::new();
    // the 24 requests cycle 6 prompts, so repeats resume from cached
    // prefix states: split TTFT by cold vs cached to show the effect
    let (mut ttft_cold, mut ttft_cached) = (Vec::new(), Vec::new());
    for (i, rx) in rxs.into_iter().enumerate() {
        let r = rx.recv().unwrap()?;
        latencies.push(r.queue_seconds + r.prefill_seconds + r.decode_seconds);
        decode_rates.push(r.decode_tokens_per_sec());
        if r.cached_prefix_tokens > 0 {
            ttft_cached.push(r.ttft_seconds);
        } else {
            ttft_cold.push(r.ttft_seconds);
        }
        if i < 6 {
            println!("  [{i}] {}", tokenizer.decode(&r.tokens));
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    latencies.sort_by(|a, b| a.total_cmp(b));
    let m = coord.metrics.lock().unwrap().clone();
    println!("\n{}", m.report());
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
    println!(
        "ttft     {:.2} ms mean cold ({} reqs) vs {:.2} ms mean cache-resumed ({} reqs)",
        mean(&ttft_cold) * 1e3,
        ttft_cold.len(),
        mean(&ttft_cached) * 1e3,
        ttft_cached.len()
    );
    println!(
        "latency  p50 {:.1} ms   p95 {:.1} ms   max {:.1} ms",
        pct(&latencies, 0.50) * 1e3,
        pct(&latencies, 0.95) * 1e3,
        latencies.last().unwrap() * 1e3
    );
    println!(
        "aggregate {:.0} tok/s over {:.2} s wall ({} requests x 24 tokens)",
        m.tokens_generated as f64 / wall,
        wall,
        n_requests
    );

    // ---- phase 2: model quality on held-out data ---------------------------
    println!("\n== held-out quality (native forward) ==");
    let weights = WeightFile::load(&manifest.weights)?;
    let mut model = RwkvModel::from_weights(&weights)?;
    let (docs, suites) = eval::parse_eval_data(&eval_json)?;
    if let Some(stream) = eval::parse_valid_stream(&eval_json) {
        println!("  stream ppl     {:.3} (uniform = 128)", eval::stream_ppl(&mut model, &stream));
    }
    let (ppl, acc) = eval::eval_lambada(&mut model, &docs);
    println!("  lambada ppl    {ppl:.3}   last-word acc {:.1}%", acc * 100.0);
    for (name, items) in &suites {
        println!(
            "  {name:<14} acc {:.1}%",
            eval::eval_suite(&mut model, items) * 100.0
        );
    }
    Ok(())
}
