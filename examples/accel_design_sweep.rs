//! Design-space exploration with the cycle-accurate simulator: sweep the
//! MV-array width, ATAC tree parallelism, URAM chunk size and clock to
//! see where the paper's chosen configs sit.  Runs without artifacts.
//!
//! ```bash
//! cargo run --release --example accel_design_sweep
//! ```

use hfrwkv::config::{AccelConfig, HFRWKV_CONFIGS, PAPER_SHAPES};
use hfrwkv::sim::{resource_usage, AccelSim};

fn main() {
    let base = HFRWKV_CONFIGS[3]; // HFRWKV*_1 (U280 streaming)
    let shape = &PAPER_SHAPES[2]; // 1B5: between compute- and BW-bound

    println!("== MV-array width (d) sweep @ {} on U280 ==", shape.name);
    println!("{:<8} {:>12} {:>10} {:>8} {:>8}", "d", "tok/s", "BW util", "DSP", "fits?");
    for d in [256usize, 512, 768, 1024, 1536, 2048, 4096] {
        let cfg = AccelConfig { pmac_count: d, ..base };
        let r = AccelSim::new(&cfg).evaluate(shape);
        let usage = resource_usage(&cfg);
        let fits = usage.fits_in(&cfg.platform.resources());
        println!(
            "{:<8} {:>12.1} {:>9.1}% {:>8} {:>8}",
            d,
            r.tokens_per_sec,
            r.bandwidth_utilization * 100.0,
            usage.dsp,
            if fits { "yes" } else { "NO" }
        );
    }
    println!("(paper picks d=1024: past ~1024 the stream is the bottleneck — more PMACs buy nothing)");

    println!("\n== URAM chunk-size sweep @ 7B on U280 ==");
    println!("{:<12} {:>12} {:>10} {:>8}", "chunk", "tok/s", "BW util", "URAM");
    for banks in [16usize, 32, 64, 128, 256] {
        let cfg = AccelConfig { chunk_bytes: banks * 36 * 1024, ..base };
        let r = AccelSim::new(&cfg).evaluate(&PAPER_SHAPES[4]);
        println!(
            "{:<12} {:>12.1} {:>9.1}% {:>8}",
            format!("{banks}x36KB"),
            r.tokens_per_sec,
            r.bandwidth_utilization * 100.0,
            2 * banks
        );
    }
    println!("(diminishing returns past 128 banks = the paper's 256-URAM ping-pong)");

    println!("\n== clock scaling @ 169M on U50_0 ==");
    println!("{:<10} {:>12} {:>10}", "freq", "tok/s", "power W");
    for mhz in [200.0f64, 300.0, 350.0, 400.0, 500.0] {
        let cfg = AccelConfig { freq_hz: mhz * 1e6, ..HFRWKV_CONFIGS[0] };
        let r = AccelSim::new(&cfg).evaluate(&PAPER_SHAPES[0]);
        println!("{:<10} {:>12.1} {:>10.1}", format!("{mhz:.0} MHz"), r.tokens_per_sec, r.power_watts);
    }

    println!("\n== deployment grid: which config would you pick per model? ==");
    println!("{:<12} {:>14} {:>14} {:>14} {:>14}", "model", HFRWKV_CONFIGS[0].name,
             HFRWKV_CONFIGS[1].name, HFRWKV_CONFIGS[2].name, HFRWKV_CONFIGS[3].name);
    for shape in &PAPER_SHAPES {
        print!("{:<12}", shape.name);
        for cfg in &HFRWKV_CONFIGS {
            let r = AccelSim::new(cfg).evaluate(shape);
            if r.feasible {
                print!(" {:>13.0} ", r.tokens_per_sec);
            } else {
                print!(" {:>13} ", "-");
            }
        }
        println!();
    }
}
