//! Quickstart: load the AOT artifacts, generate a few tokens through the
//! PJRT runtime, and show the simulator's estimate for the same model on
//! the paper's hardware.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use hfrwkv::config::shapes::TINY_SHAPE;
use hfrwkv::coordinator::{Coordinator, CoordinatorConfig, GenRequest};
use hfrwkv::model::Tokenizer;
use hfrwkv::runtime::{Manifest, RwkvRuntime};
use hfrwkv::sim::AccelSim;

fn main() -> hfrwkv::Result<()> {
    let dir = std::path::Path::new("artifacts");

    // --- serve one request through the full stack ---------------------------
    let manifest = Manifest::load(dir)?;
    let tokenizer = Tokenizer::from_json(manifest.load_eval_data()?.req("vocab")?)?;
    let coord = Coordinator::spawn_with(
        || RwkvRuntime::load(std::path::Path::new("artifacts")).expect("runtime"),
        CoordinatorConfig::default(),
    );
    let mut prompt = vec![hfrwkv::model::tokenizer::BOS];
    prompt.extend(tokenizer.encode("alice has a red hat . the hat of alice is")?);
    let resp = coord.generate(GenRequest::greedy(prompt, 8))?;
    println!("generated: {}", tokenizer.decode(&resp.tokens));
    println!(
        "decode: {:.0} tok/s on this CPU (PJRT, batch 1)",
        resp.decode_tokens_per_sec()
    );

    // --- what the accelerator would do with this model ----------------------
    let sim = AccelSim::deployed_for(false, &TINY_SHAPE);
    let r = sim.evaluate(&TINY_SHAPE);
    println!(
        "HFRWKV_0 (Alveo U50) estimate for {}: {:.0} tok/s at {:.1} W",
        TINY_SHAPE.name, r.tokens_per_sec, r.power_watts
    );
    Ok(())
}
