//! # HFRWKV — fully on-chip RWKV accelerator, reproduced as a library
//!
//! Reproduction of *"HFRWKV: A High-Performance Fully On-Chip Hardware
//! Accelerator for RWKV"* (Liu et al., CS.AR 2026) as a three-layer
//! Rust + JAX + Pallas system.  This crate is Layer 3: everything that runs
//! at request time.  Python (Layers 1/2) runs once at build time and
//! produces `artifacts/` (HLO text + trained weights); see `python/`.
//!
//! Module map (see DESIGN.md §5 for the full system inventory):
//!
//! * [`config`]      — model shapes (RWKV-4 169M..7B), accelerator configs
//!   (HFRWKV_0/1 on Alveo U50/U280), platform specs.
//! * [`quant`]       — the quantizer family: RTN, PoT, LogQ, APoT and the
//!   paper's Δ-PoT (§3), plus fixed-point helpers and calibration.
//! * [`arith`]       — bit-accurate models of the FPGA function units:
//!   LOD, barrel shifter, Δ-PoT multiplier/PMAC (§4.2), unsigned division
//!   unit (§4.3), exponential–sigmoid unit (§4.4), ATAC adder tree (§4.5).
//! * [`model`]       — RWKV-4 inference in Rust: weights container and
//!   ONE generic layer walk behind swappable numerics backends — the f32
//!   exact backend and the hardware backend built on [`arith`] +
//!   [`quant`].
//! * [`runtime`]     — PJRT wrapper: load `artifacts/*.hlo.txt`, compile on
//!   the CPU client, execute with device-resident weight buffers.
//! * [`coordinator`] — the serving layer: streaming sessions
//!   (submit → incremental token events → finish), cancellation and
//!   wall-clock deadlines, a bounded admission queue with priorities,
//!   best-of-n decode forked off one shared RWKV state, the batching
//!   scheduler, generation engine and metrics.
//! * [`statecache`]  — prefix-sharing state cache: radix-trie snapshot
//!   store that lets sessions resume prefill from cached RWKV states
//!   (O(1) bytes per entry — the RWKV advantage a Transformer KV cache
//!   can't match), plus the decode-state namespace fork requests reuse.
//! * [`trace`]       — serving observability: fixed-size log-bucketed
//!   latency histograms (TTFT / inter-token / queue / prefill-chunk /
//!   decode-cycle tails in `Metrics`), a bounded ring of typed per-session
//!   and per-cycle trace events, and a Chrome-trace (Perfetto) exporter.
//! * [`chaos`]       — deterministic fault injection: a seeded
//!   [`chaos::ChaosModel`] wrapper that makes any `EngineModel` panic,
//!   emit NaN, or stall on schedule, driving the fault-tolerance soak
//!   tests and `rust/benches/chaos.rs`.
//! * [`sim`]         — cycle-accurate accelerator simulator: HBM bridge
//!   with ping-pong double buffering, MV-array / complex-unit / LayerNorm
//!   timing, resource model (Table 2), energy model (Fig 8).
//! * [`baselines`]   — analytic CPU/GPU rooflines (i7-12650H, RTX 2080Ti,
//!   RTX 3090, A100) for Figs 7–8.
//! * [`eval`]        — perplexity + the seven synthetic benchmark suites
//!   standing in for LAMBADA/HellaSwag/ARC/SciQ/PIQA/Winogrande.
//! * [`net`]         — network serving tier: dependency-free HTTP/1.1 +
//!   SSE front-end over the coordinator (`POST /v1/generate` streams
//!   token events; `/metrics` and `/trace` expose observability), with
//!   a bounded connection-handler pool and transport-level shedding.
//! * [`loadgen`]     — open-loop realistic-traffic load harness: Poisson
//!   and bursty arrivals, lognormal prompt lengths, Zipf-shared system
//!   prompts, best-of-n and early-cancel mixes driven over real TCP
//!   sockets, reporting TTFT/inter-token tails and goodput-under-SLO.
//! * [`harness`]     — regenerates every paper table and figure.

pub mod arith;
pub mod baselines;
pub mod chaos;
pub mod config;
pub mod coordinator;
pub mod eval;
pub mod harness;
pub mod loadgen;
pub mod model;
pub mod net;
pub mod quant;
pub mod runtime;
pub mod sim;
pub mod statecache;
pub mod trace;
pub mod util;

pub use config::{AccelConfig, ModelShape, Platform};

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Deterministic xorshift64* PRNG — used everywhere randomness is needed
/// (workload generation, proptest seeds) so runs are reproducible without
/// pulling in the `rand` crate.
#[derive(Clone, Debug)]
pub struct Rng64 {
    state: u64,
}

impl Rng64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed.wrapping_mul(0x9E3779B97F4A7C15).max(1) }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_f64() * n as f64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_deterministic() {
        let mut a = Rng64::new(7);
        let mut b = Rng64::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_uniform_range() {
        let mut r = Rng64::new(1);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn rng_normal_moments() {
        let mut r = Rng64::new(2);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }
}
