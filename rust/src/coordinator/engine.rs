//! Generation engine: prefill + decode over either the PJRT runtime or
//! the native Rust forwards (the engine is generic so every model path —
//! exact PJRT, hwapprox PJRT, native f32, native hardware-numerics —
//! serves through the same coordinator).

use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use super::{FinishReason, GenRequest};
use crate::model::sampler::Sampler;
use crate::model::{HwModel, RwkvModel, State};
use crate::runtime::{RwkvRuntime, Variant};
use crate::statecache::{CacheStats, SnapshotRef, StateCacheConfig, StateStore};

/// Anything that can run RWKV one token at a time with explicit state.
pub trait EngineModel {
    fn vocab(&self) -> usize;
    fn state_len(&self) -> usize;
    fn init_state(&self) -> Vec<f32>;
    /// One step; returns logits and mutates `state` in place.
    fn forward(&mut self, state: &mut Vec<f32>, token: u32, variant: Variant) -> Result<Vec<f32>>;

    /// Batched decode: advance each (state, token) pair by one step,
    /// writing one flat `[B * vocab]` logits panel into the caller-owned
    /// `logits` buffer (session j's logits at `j*vocab..(j+1)*vocab`)
    /// and returning one *per-session* outcome, in order (`None` = ok) —
    /// so one failing session cannot poison its batchmates (each entry's
    /// state is advanced exactly once, error or not; a failing session's
    /// logits slice is unspecified and must not be read).
    ///
    /// The caller reuses `logits` across decode cycles, so the steady
    /// state allocates nothing.  The default loops
    /// [`EngineModel::forward`]; batch-aware models override it to fuse
    /// the B per-matrix matvecs into one matmul so every weight row
    /// fetched does B columns of MAC work — the software analog of the
    /// paper's on-chip weight reuse (§Perf L3-3).
    fn forward_batch(
        &mut self,
        states: &mut [&mut Vec<f32>],
        tokens: &[u32],
        variant: Variant,
        logits: &mut Vec<f32>,
    ) -> Vec<Option<anyhow::Error>> {
        let vocab = self.vocab();
        if logits.len() != states.len() * vocab {
            logits.clear();
            logits.resize(states.len() * vocab, 0.0);
        }
        let mut outcomes = Vec::with_capacity(states.len());
        for (j, (state, &tok)) in states.iter_mut().zip(tokens).enumerate() {
            match self.forward(state, tok, variant) {
                Ok(lg) if lg.len() == vocab => {
                    logits[j * vocab..(j + 1) * vocab].copy_from_slice(&lg);
                    outcomes.push(None);
                }
                Ok(lg) => outcomes.push(Some(anyhow!(
                    "forward returned {} logits, expected {vocab}",
                    lg.len()
                ))),
                Err(e) => outcomes.push(Some(e)),
            }
        }
        outcomes
    }

    /// Drain accumulated observability counters — for the hardware
    /// backend, the cumulative 9-bit activation clip total since the
    /// last drain (the coordinator folds it into `Metrics`).  Models
    /// without such counters report 0.
    fn take_clip_events(&mut self) -> u64 {
        0
    }

    /// Consume a bounded slice of prompt tokens, returning the logits of
    /// the slice's LAST token.  This is the scheduler's unit of prefill
    /// work: a `Prefilling` session consumes one chunk per scheduling
    /// cycle, interleaved with decode, so a long prompt cannot
    /// head-of-line-block active decoders.
    ///
    /// The default runs token-by-token; sequence-parallel models
    /// override it to stream each weight matrix ONCE per chunk over a
    /// `[T, d]` token panel (§Perf L3-4).  An empty slice is an error:
    /// returning empty logits would send every caller's sampler out of
    /// bounds (BOS-pad upstream instead, as [`Engine::admit`] does).
    fn prefill_chunk(
        &mut self,
        state: &mut Vec<f32>,
        tokens: &[u32],
        variant: Variant,
    ) -> Result<Vec<f32>> {
        reject_empty_prompt(tokens)?;
        let mut logits = Vec::new();
        for &t in tokens {
            logits = self.forward(state, t, variant)?;
        }
        Ok(logits)
    }

    /// Whole-prompt prefill: one maximal chunk.  Callers that need
    /// bounded per-call latency use [`EngineModel::prefill_chunk`]
    /// directly (the scheduler does).
    fn prefill(&mut self, state: &mut Vec<f32>, tokens: &[u32], variant: Variant) -> Result<Vec<f32>> {
        self.prefill_chunk(state, tokens, variant)
    }

    /// Capture the session state as a cacheable snapshot — flat f32s the
    /// prefix cache ([`crate::statecache`]) can hold and later hand to
    /// [`EngineModel::restore_state`].  The defaults copy the flat
    /// engine state verbatim (every current model keeps its state
    /// host-resident in exactly that layout); a model holding state
    /// device-resident would download/upload here instead.
    fn snapshot_state(&mut self, state: &[f32]) -> Vec<f32> {
        state.to_vec()
    }

    /// Restore a snapshot captured by [`EngineModel::snapshot_state`]
    /// into a session state, replacing its contents.
    fn restore_state(&mut self, snapshot: &[f32], state: &mut Vec<f32>) {
        state.clear();
        state.extend_from_slice(snapshot);
    }
}

/// Cache-key namespace for a variant: states produced by different
/// numerics must never cross-resume (the PJRT runtime runs genuinely
/// different math per variant; the native models ignore the variant, so
/// for them the split is merely conservative).
fn variant_class(v: Variant) -> u32 {
    match v {
        Variant::Exact => 0,
        Variant::HwApprox => 1,
    }
}

/// The one empty-prompt guard every prefill path shares: empty logits
/// would send the caller's sampler out of bounds, so reject here.
fn reject_empty_prompt(tokens: &[u32]) -> Result<()> {
    if tokens.is_empty() {
        bail!("prefill requires at least one prompt token (pad empty prompts with BOS)");
    }
    Ok(())
}

/// Shared `prefill_chunk` glue for the native models: reject empty
/// slices, marshal the flat engine state into a [`State`], run the
/// sequence-parallel panel prefill, scatter the state back.
fn prefill_via_state(
    n_layer: usize,
    d: usize,
    state: &mut Vec<f32>,
    tokens: &[u32],
    run: impl FnOnce(&mut State, &[u32]) -> Vec<f32>,
) -> Result<Vec<f32>> {
    reject_empty_prompt(tokens)?;
    let mut st = State { data: std::mem::take(state), n_layer, d };
    let logits = run(&mut st, tokens);
    *state = st.data;
    Ok(logits)
}

/// Shared `forward_batch` glue for the native models: marshal the flat
/// engine states into [`State`]s, run the fused batch step (which
/// writes the caller's flat logits panel directly — no per-session
/// allocation), scatter the states back.  The native walks are
/// infallible, so every per-session outcome is `None` (ok).
fn batch_via_step(
    n_layer: usize,
    d: usize,
    states: &mut [&mut Vec<f32>],
    step: impl FnOnce(&mut [State]),
) -> Vec<Option<anyhow::Error>> {
    let mut sts: Vec<State> = states
        .iter_mut()
        .map(|s| State { data: std::mem::take(&mut **s), n_layer, d })
        .collect();
    step(&mut sts);
    for (slot, st) in states.iter_mut().zip(sts) {
        **slot = st.data;
    }
    states.iter().map(|_| None).collect()
}

impl EngineModel for RwkvRuntime {
    fn vocab(&self) -> usize {
        self.manifest.vocab
    }

    fn state_len(&self) -> usize {
        self.manifest.state_len()
    }

    fn init_state(&self) -> Vec<f32> {
        RwkvRuntime::init_state(self)
    }

    fn forward(&mut self, state: &mut Vec<f32>, token: u32, variant: Variant) -> Result<Vec<f32>> {
        let out = self.step(variant, state, token)?;
        *state = out.state;
        Ok(out.logits)
    }

    fn prefill_chunk(
        &mut self,
        state: &mut Vec<f32>,
        tokens: &[u32],
        variant: Variant,
    ) -> Result<Vec<f32>> {
        reject_empty_prompt(tokens)?;
        // chunk through the scan executable (exact variant only — the hw
        // artifact has no seq build), then finish with single steps
        let chunk = self.manifest.seq_chunk;
        let vocab = self.manifest.vocab;
        let mut last_logits = Vec::new();
        let mut i = 0;
        if variant == Variant::Exact {
            while tokens.len() - i >= chunk {
                let (logits_flat, new_state) = self.seq_chunk(state, &tokens[i..i + chunk])?;
                *state = new_state;
                last_logits = logits_flat[(chunk - 1) * vocab..].to_vec();
                i += chunk;
            }
        }
        for &t in &tokens[i..] {
            last_logits = self.forward(state, t, variant)?;
        }
        Ok(last_logits)
    }
}

impl EngineModel for RwkvModel {
    fn vocab(&self) -> usize {
        self.vocab
    }

    fn state_len(&self) -> usize {
        self.n_layer * 5 * self.d
    }

    fn init_state(&self) -> Vec<f32> {
        self.new_state().data
    }

    fn forward(&mut self, state: &mut Vec<f32>, token: u32, _variant: Variant) -> Result<Vec<f32>> {
        let mut st = State { data: std::mem::take(state), n_layer: self.n_layer, d: self.d };
        let logits = self.step(&mut st, token);
        *state = st.data;
        Ok(logits)
    }

    fn forward_batch(
        &mut self,
        states: &mut [&mut Vec<f32>],
        tokens: &[u32],
        _variant: Variant,
        logits: &mut Vec<f32>,
    ) -> Vec<Option<anyhow::Error>> {
        batch_via_step(self.n_layer, self.d, states, |sts| {
            self.step_batch_into(sts, tokens, logits)
        })
    }

    fn prefill_chunk(
        &mut self,
        state: &mut Vec<f32>,
        tokens: &[u32],
        _variant: Variant,
    ) -> Result<Vec<f32>> {
        let (n_layer, d) = (self.n_layer, self.d);
        prefill_via_state(n_layer, d, state, tokens, |st, toks| {
            RwkvModel::prefill_chunk(self, st, toks)
        })
    }
}

impl EngineModel for HwModel {
    fn vocab(&self) -> usize {
        HwModel::vocab(self)
    }

    fn state_len(&self) -> usize {
        self.n_layer() * 5 * self.d()
    }

    fn init_state(&self) -> Vec<f32> {
        self.new_state().data
    }

    fn forward(&mut self, state: &mut Vec<f32>, token: u32, _variant: Variant) -> Result<Vec<f32>> {
        let (n_layer, d) = (self.n_layer(), self.d());
        let mut st = State { data: std::mem::take(state), n_layer, d };
        let logits = self.step(&mut st, token);
        *state = st.data;
        Ok(logits)
    }

    fn forward_batch(
        &mut self,
        states: &mut [&mut Vec<f32>],
        tokens: &[u32],
        _variant: Variant,
        logits: &mut Vec<f32>,
    ) -> Vec<Option<anyhow::Error>> {
        let (n_layer, d) = (self.n_layer(), self.d());
        batch_via_step(n_layer, d, states, |sts| {
            self.step_batch_into(sts, tokens, logits)
        })
    }

    fn prefill_chunk(
        &mut self,
        state: &mut Vec<f32>,
        tokens: &[u32],
        _variant: Variant,
    ) -> Result<Vec<f32>> {
        let (n_layer, d) = (self.n_layer(), self.d());
        prefill_via_state(n_layer, d, state, tokens, |st, toks| {
            HwModel::prefill_chunk(self, st, toks)
        })
    }

    fn take_clip_events(&mut self) -> u64 {
        HwModel::take_clip_events(self)
    }
}

/// Where a session is in its lifecycle.  Admission no longer runs the
/// whole prompt inline: a session starts `Prefilling` and consumes one
/// bounded chunk per scheduling cycle (via [`Engine::prefill_tick`]),
/// interleaved with the batched decode of the sessions already in
/// `Decoding` — continuous batching across both phases.
#[derive(Clone, Debug)]
pub enum SessionPhase {
    /// Prompt being consumed; `pos` tokens of `req.prompt` (BOS-padded
    /// in place at admission, never empty) are already folded into the
    /// state.
    Prefilling { pos: usize },
    /// Prompt fully consumed; `next_token` holds the pending sample.
    Decoding,
}

/// One in-flight generation (the session): state held, prompt being
/// consumed or decode in progress (see [`SessionPhase`]).
pub struct ActiveSession {
    pub request_id: u64,
    pub req: GenRequest,
    pub phase: SessionPhase,
    pub state: Vec<f32>,
    pub generated: Vec<u32>,
    pub sampler: Sampler,
    /// Sampled but not yet committed token — meaningless until the
    /// session reaches [`SessionPhase::Decoding`].
    pub next_token: u32,
    /// Prompt tokens whose prefill was skipped by resuming from the
    /// prefix cache (0 on a cache miss or with the cache disabled).
    pub cached_prefix_tokens: usize,
    /// Handle on the snapshot this session resumed from, held while the
    /// session is still prefilling so the cache can't evict a borrowed
    /// entry mid-resume; released at the decode transition — the state
    /// was privately copied at admission, so a long decode must not
    /// keep the entry unevictable.
    pub snapshot_pin: Option<SnapshotRef>,
    pub prefill_seconds: f64,
    pub decode_seconds: f64,
    /// Time from enqueue to the first sampled token (set when prefill
    /// completes; 0 while still prefilling).
    pub ttft_seconds: f64,
    pub enqueued_at: Instant,
    pub started_at: Instant,
}

impl ActiveSession {
    /// True once the prompt is fully consumed and decode can proceed.
    pub fn is_decoding(&self) -> bool {
        matches!(self.phase, SessionPhase::Decoding)
    }
}

/// The engine drives sessions over any [`EngineModel`].
pub struct Engine<M: EngineModel> {
    pub model: M,
    /// Reusable flat `[B * vocab]` logits panel for batched decode —
    /// together with the walk's thread-local scratch this makes the
    /// native decode hot path allocation-free in steady state.
    batch_logits: Vec<f32>,
    /// Prefix-sharing state cache ([`crate::statecache`]): admission
    /// resumes sessions from the deepest cached prompt-prefix state, and
    /// every prefill chunk boundary captures a snapshot.  `None` = the
    /// pre-cache behavior, bit for bit.
    cache: Option<StateStore>,
}

impl<M: EngineModel> Engine<M> {
    pub fn new(model: M) -> Engine<M> {
        Engine { model, batch_logits: Vec::new(), cache: None }
    }

    /// An engine with the prefix-sharing state cache enabled.  Resuming
    /// is bit-exact with full prefill (asserted in
    /// `rust/tests/statecache.rs`), so the cache changes latency, never
    /// tokens.
    pub fn with_cache(model: M, cfg: StateCacheConfig) -> Engine<M> {
        Engine { model, batch_logits: Vec::new(), cache: Some(StateStore::new(cfg)) }
    }

    /// Cache counters + gauges, if the cache is enabled (the scheduler
    /// mirrors them into [`super::Metrics`] every cycle).
    pub fn cache_stats(&self) -> Option<CacheStats> {
        self.cache.as_ref().map(|c| c.stats())
    }

    /// Admit a request WITHOUT doing any forward work: the session
    /// enters [`SessionPhase::Prefilling`] and the scheduler drives it
    /// through [`Engine::prefill_tick`] one bounded chunk at a time.
    /// An empty prompt is BOS-padded in place (one prompt copy per
    /// session, read by every tick — no duplicate allocation).
    ///
    /// With the prefix cache enabled, admission additionally runs a
    /// longest-prefix lookup: on a hit the session's state is restored
    /// from the deepest cached snapshot and prefill resumes *after* it —
    /// a request behind a fully-cached shared prompt prefills only its
    /// last token.  The lookup is capped at `prompt.len() - 1` because
    /// the sampler needs the final prompt token's logits, which
    /// snapshots don't carry; the matched depth is recorded in
    /// [`ActiveSession::cached_prefix_tokens`] and the snapshot handle
    /// stays pinned until the session's prefill completes.
    pub fn admit(&mut self, request_id: u64, mut req: GenRequest, enqueued_at: Instant) -> ActiveSession {
        let mut state = self.model.init_state();
        let sampler = Sampler::new(req.temperature, req.top_k, req.seed);
        if req.prompt.is_empty() {
            req.prompt = vec![crate::model::tokenizer::BOS];
        }
        let mut cached_prefix_tokens = 0;
        let mut snapshot_pin = None;
        if let Some(cache) = &mut self.cache {
            let class = variant_class(req.variant);
            if let Some(snap) = cache.lookup(class, &req.prompt, req.prompt.len() - 1) {
                self.model.restore_state(snap.state(), &mut state);
                cached_prefix_tokens = snap.tokens();
                snapshot_pin = Some(snap);
            }
        }
        ActiveSession {
            request_id,
            req,
            phase: SessionPhase::Prefilling { pos: cached_prefix_tokens },
            state,
            generated: Vec::new(),
            sampler,
            next_token: 0,
            cached_prefix_tokens,
            snapshot_pin,
            prefill_seconds: 0.0,
            decode_seconds: 0.0,
            ttft_seconds: 0.0,
            enqueued_at,
            started_at: Instant::now(),
        }
    }

    /// Consume up to `max_chunk` prompt tokens of a `Prefilling` session
    /// (ONE [`EngineModel::prefill_chunk`] call — a single matmul pass
    /// per weight matrix for sequence-parallel models).  When the prompt
    /// is exhausted the first token is sampled, time-to-first-token is
    /// recorded, and the session moves to [`SessionPhase::Decoding`].
    ///
    /// Returns true once the session is decoding (immediately true for
    /// sessions already there).
    pub fn prefill_tick(&mut self, s: &mut ActiveSession, max_chunk: usize) -> Result<bool> {
        let SessionPhase::Prefilling { pos } = &mut s.phase else {
            return Ok(true);
        };
        let t0 = Instant::now();
        let prompt = &s.req.prompt;
        let end = pos.saturating_add(max_chunk.max(1)).min(prompt.len());
        let logits = self.model.prefill_chunk(&mut s.state, &prompt[*pos..end], s.req.variant)?;
        *pos = end;
        let done = *pos == prompt.len();
        // capture a snapshot at the chunk boundary: prefill is bit-exact
        // across chunkings, so this state is exactly what ANY future
        // prefill of the same `prompt[..end]` would pass through.  The
        // closure only materializes a copy when the prefix isn't already
        // cached (a re-walked shared prefix just refreshes its recency).
        if let Some(cache) = &mut self.cache {
            let class = variant_class(s.req.variant);
            let (model, state) = (&mut self.model, &s.state);
            // state.len() prices the entry so dedup/rejection never
            // materializes the snapshot copy
            cache.insert_with(class, &prompt[..end], state.len(), || {
                model.snapshot_state(state)
            });
        }
        s.prefill_seconds += t0.elapsed().as_secs_f64();
        if done {
            s.next_token = s.sampler.sample(&logits);
            s.ttft_seconds = s.enqueued_at.elapsed().as_secs_f64();
            s.phase = SessionPhase::Decoding;
            // prefill over: release the resumed-from snapshot so decode
            // time doesn't hold it unevictable (see the field docs)
            s.snapshot_pin = None;
        }
        Ok(done)
    }

    /// Admit a request and run its whole prefill to completion (one
    /// maximal chunk): the blocking convenience path for tests, examples
    /// and non-scheduler callers.
    pub fn start(&mut self, request_id: u64, req: GenRequest, enqueued_at: Instant) -> Result<ActiveSession> {
        let mut sess = self.admit(request_id, req, enqueued_at);
        self.prefill_tick(&mut sess, usize::MAX)?;
        debug_assert!(sess.is_decoding(), "maximal prefill_tick must finish the prompt");
        Ok(sess)
    }

    /// First half of a decode step: commit the pending sampled token and
    /// check the finish conditions.  Returns Some(reason) when the
    /// session is done (no forward needed); otherwise the caller runs
    /// the second half — forward + resample — per session via
    /// [`Engine::step_session`] or fused via [`Engine::step_batch`].
    pub fn commit_pending(&self, s: &mut ActiveSession) -> Option<FinishReason> {
        debug_assert!(
            s.is_decoding(),
            "commit_pending requires a Decoding session — drive prefill_tick (or start) first, \
             otherwise the placeholder next_token would be committed as output"
        );
        let tok = s.next_token;
        s.generated.push(tok);
        if s.req.stop_token == Some(tok) {
            return Some(FinishReason::StopToken);
        }
        if s.generated.len() >= s.req.max_new_tokens {
            return Some(FinishReason::MaxTokens);
        }
        None
    }

    /// One decode step for a session; returns Some(reason) when done.
    pub fn step_session(&mut self, s: &mut ActiveSession) -> Result<Option<FinishReason>> {
        let t0 = Instant::now();
        if let Some(reason) = self.commit_pending(s) {
            s.decode_seconds += t0.elapsed().as_secs_f64();
            return Ok(Some(reason));
        }
        let tok = *s.generated.last().expect("commit_pending pushed a token");
        let logits = self.model.forward(&mut s.state, tok, s.req.variant)?;
        s.next_token = s.sampler.sample(&logits);
        s.decode_seconds += t0.elapsed().as_secs_f64();
        Ok(None)
    }

    /// Second half of a batched decode cycle: advance every continuing
    /// session (pending token already committed) with ONE
    /// [`EngineModel::forward_batch`] per variant group, then resample.
    /// Order within a group is the caller's — i.e. admission — order, so
    /// round-robin fairness and determinism are preserved.  The batch
    /// wall time is split evenly across participants for the per-session
    /// decode metrics.
    ///
    /// Outcomes are per session, aligned with `sessions` (None =
    /// advanced fine): a failing session reports its own error and its
    /// batchmates keep generating — the same isolation the pre-fusion
    /// per-session scheduler had.
    pub fn step_batch(&mut self, sessions: &mut [&mut ActiveSession]) -> Vec<Option<anyhow::Error>> {
        let n = sessions.len();
        let mut errors: Vec<Option<anyhow::Error>> = (0..n).map(|_| None).collect();
        if n == 0 {
            return errors;
        }
        let t0 = Instant::now();
        let mut variants: Vec<Variant> = Vec::new();
        for s in sessions.iter() {
            if !variants.contains(&s.req.variant) {
                variants.push(s.req.variant);
            }
        }
        for variant in variants {
            let idx: Vec<usize> = (0..n)
                .filter(|&i| sessions[i].req.variant == variant)
                .collect();
            let tokens: Vec<u32> = idx
                .iter()
                .map(|&i| *sessions[i].generated.last().expect("pending token committed"))
                .collect();
            let outcomes = {
                let mut states: Vec<&mut Vec<f32>> = sessions
                    .iter_mut()
                    .filter(|s| s.req.variant == variant)
                    .map(|s| &mut s.state)
                    .collect();
                self.model
                    .forward_batch(&mut states, &tokens, variant, &mut self.batch_logits)
            };
            // defensive: a misbehaving override returning the wrong
            // outcome count or logits-panel size means the
            // result/session alignment is unknown — fail the whole
            // group rather than misassign logits
            let vocab = self.model.vocab();
            if outcomes.len() != idx.len() || self.batch_logits.len() != idx.len() * vocab {
                for &i in &idx {
                    errors[i] = Some(anyhow!(
                        "forward_batch returned {} outcomes / {} logits for {} sessions",
                        outcomes.len(),
                        self.batch_logits.len(),
                        idx.len()
                    ));
                }
                continue;
            }
            for (slot, outcome) in outcomes.into_iter().enumerate() {
                let i = idx[slot];
                let s = &mut *sessions[i];
                match outcome {
                    None => {
                        let lg = &self.batch_logits[slot * vocab..(slot + 1) * vocab];
                        s.next_token = s.sampler.sample(lg);
                    }
                    Some(e) => errors[i] = Some(e),
                }
            }
        }
        let dt = t0.elapsed().as_secs_f64() / n as f64;
        for s in sessions.iter_mut() {
            s.decode_seconds += dt;
        }
        errors
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::rwkv::testing::test_model;

    fn engine() -> Engine<RwkvModel> {
        Engine::new(test_model(2, 32, 64, 50))
    }

    #[test]
    fn generates_requested_token_count() {
        let mut e = engine();
        let req = GenRequest::greedy(vec![1, 2, 3], 10);
        let mut s = e.start(1, req, Instant::now()).unwrap();
        let mut finish = None;
        while finish.is_none() {
            finish = e.step_session(&mut s).unwrap();
        }
        assert_eq!(finish, Some(FinishReason::MaxTokens));
        assert_eq!(s.generated.len(), 10);
    }

    #[test]
    fn stop_token_halts_early() {
        let mut e = engine();
        // find what greedy generates first, then use it as the stop token
        let req = GenRequest::greedy(vec![1, 2, 3], 5);
        let mut s = e.start(1, req, Instant::now()).unwrap();
        let first = s.next_token;
        let mut req2 = GenRequest::greedy(vec![1, 2, 3], 50);
        req2.stop_token = Some(first);
        let mut s2 = e.start(2, req2, Instant::now()).unwrap();
        let mut finish = None;
        while finish.is_none() {
            finish = e.step_session(&mut s2).unwrap();
        }
        assert_eq!(finish, Some(FinishReason::StopToken));
        assert_eq!(s2.generated, vec![first]);
    }

    #[test]
    fn greedy_is_deterministic_across_sessions() {
        let mut e = engine();
        let gen = |e: &mut Engine<RwkvModel>| {
            let mut s = e.start(0, GenRequest::greedy(vec![4, 9], 12), Instant::now()).unwrap();
            while e.step_session(&mut s).unwrap().is_none() {}
            s.generated
        };
        assert_eq!(gen(&mut e), gen(&mut e));
    }

    #[test]
    fn chunked_prefill_ticks_match_start() {
        let mut a = engine();
        let mut b = engine();
        let req = GenRequest::greedy(vec![1, 2, 3, 4, 5, 6, 7], 6);
        let sa = a.start(1, req.clone(), Instant::now()).unwrap();
        let mut sb = b.admit(1, req, Instant::now());
        assert!(!sb.is_decoding());
        let mut ticks = 0;
        while !b.prefill_tick(&mut sb, 3).unwrap() {
            ticks += 1;
            assert!(ticks < 10, "prefill_tick failed to make progress");
        }
        assert!(sb.is_decoding());
        assert_eq!(sa.next_token, sb.next_token);
        assert_eq!(sa.state, sb.state);
        assert!(sb.ttft_seconds > 0.0);
        // further ticks are no-ops
        assert!(b.prefill_tick(&mut sb, 3).unwrap());
    }

    #[test]
    fn empty_prompt_uses_bos() {
        let mut e = engine();
        let mut s = e.start(0, GenRequest::greedy(vec![], 3), Instant::now()).unwrap();
        while e.step_session(&mut s).unwrap().is_none() {}
        assert_eq!(s.generated.len(), 3);
    }

    #[test]
    fn forward_batch_matches_forward_loop() {
        let mut a = test_model(2, 32, 64, 50);
        let mut b = test_model(2, 32, 64, 50);
        let mut states_a: Vec<Vec<f32>> = (0..3).map(|_| a.init_state()).collect();
        let mut states_b = states_a.clone();
        let tokens = [3u32, 7, 9];
        let loop_logits: Vec<Vec<f32>> = states_a
            .iter_mut()
            .zip(tokens)
            .map(|(s, t)| a.forward(s, t, Variant::Exact).unwrap())
            .collect();
        let batch_logits: Vec<Vec<f32>> = {
            let mut refs: Vec<&mut Vec<f32>> = states_b.iter_mut().collect();
            let mut flat = Vec::new();
            let outcomes = b.forward_batch(&mut refs, &tokens, Variant::Exact, &mut flat);
            assert!(outcomes.iter().all(|o| o.is_none()));
            assert_eq!(flat.len(), 3 * b.vocab);
            flat.chunks(b.vocab).map(|c| c.to_vec()).collect()
        };
        assert_eq!(loop_logits, batch_logits);
        assert_eq!(states_a, states_b);
    }

    #[test]
    fn default_forward_batch_fills_flat_panel() {
        // a model with no forward_batch override must produce the same
        // flat panel layout as the fused native override
        struct Plain(RwkvModel);
        impl EngineModel for Plain {
            fn vocab(&self) -> usize {
                self.0.vocab
            }
            fn state_len(&self) -> usize {
                EngineModel::state_len(&self.0)
            }
            fn init_state(&self) -> Vec<f32> {
                EngineModel::init_state(&self.0)
            }
            fn forward(
                &mut self,
                state: &mut Vec<f32>,
                token: u32,
                variant: Variant,
            ) -> Result<Vec<f32>> {
                self.0.forward(state, token, variant)
            }
        }
        let mut fused = test_model(2, 32, 64, 50);
        let mut plain = Plain(test_model(2, 32, 64, 50));
        let mut states_f: Vec<Vec<f32>> = (0..3).map(|_| fused.init_state()).collect();
        let mut states_p = states_f.clone();
        let tokens = [2u32, 11, 29];
        let (mut flat_f, mut flat_p) = (Vec::new(), Vec::new());
        {
            let mut refs: Vec<&mut Vec<f32>> = states_f.iter_mut().collect();
            fused.forward_batch(&mut refs, &tokens, Variant::Exact, &mut flat_f);
        }
        {
            let mut refs: Vec<&mut Vec<f32>> = states_p.iter_mut().collect();
            plain.forward_batch(&mut refs, &tokens, Variant::Exact, &mut flat_p);
        }
        assert_eq!(flat_f, flat_p);
        assert_eq!(states_f, states_p);
    }

    #[test]
    fn engine_model_surfaces_hw_clip_totals() {
        let calib: Vec<u32> = (0..64u32).map(|i| (i * 11 + 3) % 50).collect();
        let mut hw = HwModel::from_f32(test_model(2, 32, 64, 50), &calib);
        let mut st = EngineModel::init_state(&hw);
        hw.forward(&mut st, 3, Variant::Exact).unwrap();
        let c1 = hw.clip_events;
        hw.forward(&mut st, 5, Variant::Exact).unwrap();
        let c2 = hw.clip_events;
        // the trait drain reports the lossless cumulative total, then 0
        assert_eq!(EngineModel::take_clip_events(&mut hw), c1 + c2);
        assert_eq!(EngineModel::take_clip_events(&mut hw), 0);
        // non-hw models have nothing to report
        let mut plain = test_model(1, 16, 32, 20);
        assert_eq!(EngineModel::take_clip_events(&mut plain), 0);
    }

    #[test]
    fn prefill_rejects_empty_prompt() {
        let mut m = test_model(1, 32, 64, 50);
        let mut state = m.init_state();
        assert!(m.prefill(&mut state, &[], Variant::Exact).is_err());
    }

    #[test]
    fn engine_step_batch_equals_step_session() {
        // two engines over the same model: one driven per session, one
        // through commit_pending + step_batch — identical tokens
        let mut per = engine();
        let mut bat = engine();
        let reqs = [
            GenRequest::greedy(vec![1, 2, 3], 9),
            GenRequest::greedy(vec![4], 9),
            GenRequest::greedy(vec![5, 6], 9),
        ];
        let mut ps: Vec<ActiveSession> = reqs
            .iter()
            .enumerate()
            .map(|(i, r)| per.start(i as u64, r.clone(), Instant::now()).unwrap())
            .collect();
        let mut bs: Vec<ActiveSession> = reqs
            .iter()
            .enumerate()
            .map(|(i, r)| bat.start(i as u64, r.clone(), Instant::now()).unwrap())
            .collect();
        // per-session path
        for s in ps.iter_mut() {
            while per.step_session(s).unwrap().is_none() {}
        }
        // batched path
        let mut done = vec![false; bs.len()];
        loop {
            let mut live: Vec<&mut ActiveSession> = Vec::new();
            for (s, d) in bs.iter_mut().zip(done.iter_mut()) {
                if *d {
                    continue;
                }
                match bat.commit_pending(s) {
                    Some(_) => *d = true,
                    None => live.push(s),
                }
            }
            if live.is_empty() {
                break;
            }
            let errs = bat.step_batch(&mut live);
            assert!(errs.iter().all(|e| e.is_none()));
        }
        for (p, b) in ps.iter().zip(&bs) {
            assert_eq!(p.generated, b.generated);
        }
    }

    #[test]
    fn cached_resume_matches_cold_prefill_bitexact() {
        // second session with the same prompt resumes from the deepest
        // chunk-boundary snapshot and must land on the identical state
        let mut cold = engine();
        let mut warm = Engine::with_cache(
            test_model(2, 32, 64, 50),
            crate::statecache::StateCacheConfig::default(),
        );
        let prompt: Vec<u32> = (0..17u32).map(|t| (t * 3 + 1) % 50).collect();
        let req = GenRequest::greedy(prompt, 5);

        let sc = cold.start(1, req.clone(), Instant::now()).unwrap();

        // first warm session populates boundaries at 4, 8, 12, 16, 17
        let mut s1 = warm.admit(1, req.clone(), Instant::now());
        assert_eq!(s1.cached_prefix_tokens, 0, "cold cache cannot hit");
        while !warm.prefill_tick(&mut s1, 4).unwrap() {}
        assert_eq!(s1.next_token, sc.next_token);
        assert_eq!(s1.state, sc.state);

        // second warm session resumes at 16 (the deepest boundary ≤ 16)
        let mut s2 = warm.admit(2, req.clone(), Instant::now());
        assert_eq!(s2.cached_prefix_tokens, 16);
        assert!(s2.snapshot_pin.is_some(), "resumed session must pin its snapshot");
        while !warm.prefill_tick(&mut s2, 4).unwrap() {}
        assert!(s2.snapshot_pin.is_none(), "pin must release when prefill completes");
        assert_eq!(s2.next_token, sc.next_token);
        assert_eq!(s2.state, sc.state);

        let stats = warm.cache_stats().unwrap();
        assert_eq!(stats.hits, 1);
        assert_eq!(stats.tokens_skipped, 16);
        assert!(stats.inserts >= 5);
    }

    #[test]
    fn cache_disabled_engine_reports_no_stats() {
        let mut e = engine();
        assert!(e.cache_stats().is_none());
        let s = e.start(1, GenRequest::greedy(vec![1, 2, 3], 2), Instant::now()).unwrap();
        assert_eq!(s.cached_prefix_tokens, 0);
        assert!(s.snapshot_pin.is_none());
    }

    #[test]
    fn single_token_prompts_never_hit() {
        // a 1-token prompt caps the lookup at depth 0 — always a miss,
        // and the post-prefill snapshot (depth 1) must not break that
        let mut e = Engine::with_cache(
            test_model(2, 32, 64, 50),
            crate::statecache::StateCacheConfig::default(),
        );
        for _ in 0..2 {
            let s = e.start(1, GenRequest::greedy(vec![7], 2), Instant::now()).unwrap();
            assert_eq!(s.cached_prefix_tokens, 0);
        }
        let stats = e.cache_stats().unwrap();
        assert_eq!(stats.hits, 0);
        assert_eq!(stats.misses, 2);
    }

    #[test]
    fn interleaved_equals_sequential() {
        // THE state-isolation invariant: driving two sessions
        // alternately must produce exactly what driving them one after
        // the other produces.
        let mut e = engine();
        let ra = GenRequest::greedy(vec![3, 1, 4], 8);
        let rb = GenRequest::greedy(vec![2, 7], 8);

        // sequential
        let mut sa = e.start(1, ra.clone(), Instant::now()).unwrap();
        while e.step_session(&mut sa).unwrap().is_none() {}
        let mut sb = e.start(2, rb.clone(), Instant::now()).unwrap();
        while e.step_session(&mut sb).unwrap().is_none() {}

        // interleaved
        let mut ia = e.start(3, ra, Instant::now()).unwrap();
        let mut ib = e.start(4, rb, Instant::now()).unwrap();
        let (mut da, mut db) = (false, false);
        while !(da && db) {
            if !da {
                da = e.step_session(&mut ia).unwrap().is_some();
            }
            if !db {
                db = e.step_session(&mut ib).unwrap().is_some();
            }
        }
        assert_eq!(sa.generated, ia.generated);
        assert_eq!(sb.generated, ib.generated);
    }
}
