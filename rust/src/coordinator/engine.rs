//! Generation engine: prefill + decode over either the PJRT runtime or
//! the native Rust forwards (the engine is generic so every model path —
//! exact PJRT, hwapprox PJRT, native f32, native hardware-numerics —
//! serves through the same coordinator).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use super::journal::{FaultEvent, FaultJournal, FaultKind, FaultPhase, RecoveryAction};
use super::{FinishReason, GenRequest};
use crate::model::sampler::Sampler;
use crate::model::{panel_all_finite, HwModel, PackedModel, RwkvModel, State};
use crate::runtime::{RwkvRuntime, Variant};
use crate::statecache::{CacheStats, SnapshotRef, StateCacheConfig, StateStore};
use crate::trace::{CyclePhaseKind, TraceEvent, TraceEventKind, Tracer};

/// How the engine treats model-level faults (panics and non-finite
/// output) in its scheduler-driven calls ([`Engine::prefill_tick`],
/// [`Engine::step_batch`]).  See the crate-level "Failure model"
/// section ([`crate::coordinator`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultPolicy {
    /// Scan logits and recurrent-state panels for NaN/±Inf after every
    /// guarded model call ([`panel_all_finite`]).  Off = the pre-guard
    /// behavior: non-finite values flow through (and the only cache
    /// protection is the store's own insert-time quarantine).
    pub health_guards: bool,
    /// Rollback-retry budget per faulting call: a panic or poisoned
    /// panel restores the affected sessions' last cycle-boundary state
    /// (an O(1)-byte copy) and re-runs, up to this many times, before
    /// the fault surfaces as a typed terminal.  0 = fail fast (also
    /// disables the per-cycle state snapshot, saving its memcpy).
    pub max_retries: u32,
    /// Base of the exponential backoff between retries, in milliseconds
    /// (attempt k sleeps `base << (k-1)`, capped at 64× base).  0 = no
    /// sleep — what tests and benches use.
    pub retry_backoff_ms: u64,
}

impl Default for FaultPolicy {
    fn default() -> Self {
        FaultPolicy { health_guards: true, max_retries: 2, retry_backoff_ms: 1 }
    }
}

/// Cumulative fault-handling counters for one engine (mirrored into
/// [`super::Metrics`] by the scheduler every cycle).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// Guarded calls re-run after a transient fault.
    pub retries: u64,
    /// Session states restored from their last-good snapshot.
    pub rollbacks: u64,
    /// Model panics caught by the per-call `catch_unwind` guards.
    pub panics_caught: u64,
    /// Non-finite logits/state panels detected by the health guards.
    pub numeric_faults: u64,
}

/// A fault that ended one session's guarded engine call after the
/// retry budget (see [`FaultPolicy`]).  The scheduler maps these onto
/// terminal events: [`SessionFault::Numeric`] →
/// [`FinishReason::NumericFault`] (typed, carries partial tokens),
/// the other two → [`super::GenEvent::Error`].
#[derive(Debug)]
pub enum SessionFault {
    /// The model *returned* an error — deliberate, never retried.
    Error(anyhow::Error),
    /// NaN/±Inf in the logits or state, reproduced on every retry.
    Numeric,
    /// The model panicked on every retry; the payload message.
    Panicked(String),
    /// A retry's backoff sleep would cross the session's wall-clock
    /// deadline, so the retry chain was abandoned instead of sleeping
    /// into it; the scheduler maps this onto
    /// [`FinishReason::DeadlineExceeded`].
    DeadlineExceeded,
}

impl std::fmt::Display for SessionFault {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SessionFault::Error(e) => write!(f, "model error: {e}"),
            SessionFault::Numeric => {
                write!(f, "model produced non-finite logits or state (retries exhausted)")
            }
            SessionFault::Panicked(msg) => write!(f, "model panicked: {msg}"),
            SessionFault::DeadlineExceeded => {
                write!(f, "retry backoff abandoned: it would cross the session deadline")
            }
        }
    }
}

impl std::error::Error for SessionFault {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            SessionFault::Error(e) => {
                let src: &(dyn std::error::Error + 'static) = e.as_ref();
                Some(src)
            }
            _ => None,
        }
    }
}

/// Best-effort human-readable panic payload (the common `&str`/`String`
/// cases; anything else gets a placeholder).
fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Exponential backoff before retry `attempt` (1-based): `base << (k-1)`
/// milliseconds, capped at 64× base so a deep retry chain cannot stall
/// the whole worker for seconds.  Returned as a duration (not slept
/// inline) so callers can first check it against a session deadline —
/// sleeping *into* a deadline would burn wall-clock the session can
/// never recover.
fn backoff_duration(base_ms: u64, attempt: u32) -> Duration {
    if base_ms == 0 {
        return Duration::ZERO;
    }
    let factor = 1u64 << attempt.saturating_sub(1).min(6);
    Duration::from_millis(base_ms.saturating_mul(factor))
}

/// Would sleeping `sleep` from now cross `deadline_at`?  (A retry whose
/// backoff lands past the deadline is pointless — the session would be
/// reaped `DeadlineExceeded` before its retried call could commit.)
fn sleep_crosses_deadline(sleep: Duration, deadline_at: Option<Instant>) -> bool {
    deadline_at.is_some_and(|dl| {
        Instant::now().checked_add(sleep).map_or(true, |wake| wake >= dl)
    })
}

/// Anything that can run RWKV one token at a time with explicit state.
pub trait EngineModel {
    fn vocab(&self) -> usize;
    fn state_len(&self) -> usize;
    fn init_state(&self) -> Vec<f32>;
    /// One step; returns logits and mutates `state` in place.
    fn forward(&mut self, state: &mut Vec<f32>, token: u32, variant: Variant) -> Result<Vec<f32>>;

    /// Batched decode: advance each (state, token) pair by one step,
    /// writing one flat `[B * vocab]` logits panel into the caller-owned
    /// `logits` buffer (session j's logits at `j*vocab..(j+1)*vocab`)
    /// and returning one *per-session* outcome, in order (`None` = ok) —
    /// so one failing session cannot poison its batchmates (each entry's
    /// state is advanced exactly once, error or not; a failing session's
    /// logits slice is unspecified and must not be read).
    ///
    /// The caller reuses `logits` across decode cycles, so the steady
    /// state allocates nothing.  The default loops
    /// [`EngineModel::forward`]; batch-aware models override it to fuse
    /// the B per-matrix matvecs into one matmul so every weight row
    /// fetched does B columns of MAC work — the software analog of the
    /// paper's on-chip weight reuse (§Perf L3-3).
    fn forward_batch(
        &mut self,
        states: &mut [&mut Vec<f32>],
        tokens: &[u32],
        variant: Variant,
        logits: &mut Vec<f32>,
    ) -> Vec<Option<anyhow::Error>> {
        let vocab = self.vocab();
        if logits.len() != states.len() * vocab {
            logits.clear();
            logits.resize(states.len() * vocab, 0.0);
        }
        let mut outcomes = Vec::with_capacity(states.len());
        for (j, (state, &tok)) in states.iter_mut().zip(tokens).enumerate() {
            match self.forward(state, tok, variant) {
                Ok(lg) if lg.len() == vocab => {
                    logits[j * vocab..(j + 1) * vocab].copy_from_slice(&lg);
                    outcomes.push(None);
                }
                Ok(lg) => outcomes.push(Some(anyhow!(
                    "forward returned {} logits, expected {vocab}",
                    lg.len()
                ))),
                Err(e) => outcomes.push(Some(e)),
            }
        }
        outcomes
    }

    /// Drain accumulated observability counters — for the hardware
    /// backend, the cumulative 9-bit activation clip total since the
    /// last drain (the coordinator folds it into `Metrics`).  Models
    /// without such counters report 0.
    fn take_clip_events(&mut self) -> u64 {
        0
    }

    /// Bytes of weight-plane traffic ONE full decode cycle streams
    /// (the seven per-layer matrices plus the head; the embedding is a
    /// row gather, not a streamed plane).  The scheduler multiplies
    /// this by decode cycles into [`super::Metrics`], making the
    /// exact-vs-packed traffic cut (4 vs 2 bytes per weight) visible
    /// in the serve report.  0 = the model doesn't expose its plane
    /// footprint (e.g. the PJRT runtime, whose traffic lives
    /// device-side).
    fn weight_stream_bytes(&self) -> u64 {
        0
    }

    /// Consume a bounded slice of prompt tokens, returning the logits of
    /// the slice's LAST token.  This is the scheduler's unit of prefill
    /// work: a `Prefilling` session consumes one chunk per scheduling
    /// cycle, interleaved with decode, so a long prompt cannot
    /// head-of-line-block active decoders.
    ///
    /// The default runs token-by-token; sequence-parallel models
    /// override it to stream each weight matrix ONCE per chunk over a
    /// `[T, d]` token panel (§Perf L3-4).  An empty slice is an error:
    /// returning empty logits would send every caller's sampler out of
    /// bounds (BOS-pad upstream instead, as [`Engine::admit`] does).
    fn prefill_chunk(
        &mut self,
        state: &mut Vec<f32>,
        tokens: &[u32],
        variant: Variant,
    ) -> Result<Vec<f32>> {
        reject_empty_prompt(tokens)?;
        let mut logits = Vec::new();
        for &t in tokens {
            logits = self.forward(state, t, variant)?;
        }
        Ok(logits)
    }

    /// Whole-prompt prefill: one maximal chunk.  Callers that need
    /// bounded per-call latency use [`EngineModel::prefill_chunk`]
    /// directly (the scheduler does).
    fn prefill(&mut self, state: &mut Vec<f32>, tokens: &[u32], variant: Variant) -> Result<Vec<f32>> {
        self.prefill_chunk(state, tokens, variant)
    }

    /// Capture the session state as a cacheable snapshot — flat f32s the
    /// prefix cache ([`crate::statecache`]) can hold and later hand to
    /// [`EngineModel::restore_state`].  The defaults copy the flat
    /// engine state verbatim (every current model keeps its state
    /// host-resident in exactly that layout); a model holding state
    /// device-resident would download/upload here instead.
    fn snapshot_state(&mut self, state: &[f32]) -> Vec<f32> {
        state.to_vec()
    }

    /// Restore a snapshot captured by [`EngineModel::snapshot_state`]
    /// into a session state, replacing its contents.
    fn restore_state(&mut self, snapshot: &[f32], state: &mut Vec<f32>) {
        state.clear();
        state.extend_from_slice(snapshot);
    }
}

/// Cache-key namespace for a variant: states produced by different
/// numerics must never cross-resume (the PJRT runtime runs genuinely
/// different math per variant; the native models ignore the variant, so
/// for them the split is merely conservative).
fn variant_class(v: Variant) -> u32 {
    match v {
        Variant::Exact => 0,
        Variant::HwApprox => 1,
    }
}

/// High bit partitioning the cache's class space into the *decode-state*
/// namespace: post-prompt snapshots that additionally carry the last
/// prompt token's logits, written and consumed only by the fork
/// (best-of-n) path.  Prefix snapshots (no logits) keep the plain
/// variant class, so the two kinds can never cross-resume.
const DECODE_NS: u32 = 1 << 31;

/// The one empty-prompt guard every prefill path shares: empty logits
/// would send the caller's sampler out of bounds, so reject here.
fn reject_empty_prompt(tokens: &[u32]) -> Result<()> {
    if tokens.is_empty() {
        bail!("prefill requires at least one prompt token (pad empty prompts with BOS)");
    }
    Ok(())
}

/// Shared `prefill_chunk` glue for the native models: reject empty
/// slices, marshal the flat engine state into a [`State`], run the
/// sequence-parallel panel prefill, scatter the state back.
fn prefill_via_state(
    n_layer: usize,
    d: usize,
    state: &mut Vec<f32>,
    tokens: &[u32],
    run: impl FnOnce(&mut State, &[u32]) -> Vec<f32>,
) -> Result<Vec<f32>> {
    reject_empty_prompt(tokens)?;
    let mut st = State { data: std::mem::take(state), n_layer, d };
    let logits = run(&mut st, tokens);
    *state = st.data;
    Ok(logits)
}

/// Shared `forward_batch` glue for the native models: marshal the flat
/// engine states into [`State`]s, run the fused batch step (which
/// writes the caller's flat logits panel directly — no per-session
/// allocation), scatter the states back.  The native walks are
/// infallible, so every per-session outcome is `None` (ok).
fn batch_via_step(
    n_layer: usize,
    d: usize,
    states: &mut [&mut Vec<f32>],
    step: impl FnOnce(&mut [State]),
) -> Vec<Option<anyhow::Error>> {
    let mut sts: Vec<State> = states
        .iter_mut()
        .map(|s| State { data: std::mem::take(&mut **s), n_layer, d })
        .collect();
    step(&mut sts);
    for (slot, st) in states.iter_mut().zip(sts) {
        **slot = st.data;
    }
    states.iter().map(|_| None).collect()
}

/// [`EngineModel::weight_stream_bytes`] for the f32-plane backends:
/// `n_layer` blocks of five `d×d` and two `d×f` matrices plus the
/// `vocab×d` head, at 4 bytes per weight.  (The packed backend
/// computes its own 2-byte figure from its planes.)
fn f32_weight_stream_bytes(n_layer: usize, d: usize, f: usize, vocab: usize) -> u64 {
    (n_layer * (5 * d * d + 2 * d * f) + vocab * d) as u64 * 4
}

impl EngineModel for RwkvRuntime {
    fn vocab(&self) -> usize {
        self.manifest.vocab
    }

    fn state_len(&self) -> usize {
        self.manifest.state_len()
    }

    fn init_state(&self) -> Vec<f32> {
        RwkvRuntime::init_state(self)
    }

    fn forward(&mut self, state: &mut Vec<f32>, token: u32, variant: Variant) -> Result<Vec<f32>> {
        let out = self.step(variant, state, token)?;
        *state = out.state;
        Ok(out.logits)
    }

    fn prefill_chunk(
        &mut self,
        state: &mut Vec<f32>,
        tokens: &[u32],
        variant: Variant,
    ) -> Result<Vec<f32>> {
        reject_empty_prompt(tokens)?;
        // chunk through the scan executable (exact variant only — the hw
        // artifact has no seq build), then finish with single steps
        let chunk = self.manifest.seq_chunk;
        let vocab = self.manifest.vocab;
        let mut last_logits = Vec::new();
        let mut i = 0;
        if variant == Variant::Exact {
            while tokens.len() - i >= chunk {
                let (logits_flat, new_state) = self.seq_chunk(state, &tokens[i..i + chunk])?;
                *state = new_state;
                last_logits = logits_flat[(chunk - 1) * vocab..].to_vec();
                i += chunk;
            }
        }
        for &t in &tokens[i..] {
            last_logits = self.forward(state, t, variant)?;
        }
        Ok(last_logits)
    }
}

impl EngineModel for RwkvModel {
    fn vocab(&self) -> usize {
        self.vocab
    }

    fn state_len(&self) -> usize {
        self.n_layer * 5 * self.d
    }

    fn init_state(&self) -> Vec<f32> {
        self.new_state().data
    }

    fn forward(&mut self, state: &mut Vec<f32>, token: u32, _variant: Variant) -> Result<Vec<f32>> {
        let mut st = State { data: std::mem::take(state), n_layer: self.n_layer, d: self.d };
        let logits = self.step(&mut st, token);
        *state = st.data;
        Ok(logits)
    }

    fn forward_batch(
        &mut self,
        states: &mut [&mut Vec<f32>],
        tokens: &[u32],
        _variant: Variant,
        logits: &mut Vec<f32>,
    ) -> Vec<Option<anyhow::Error>> {
        batch_via_step(self.n_layer, self.d, states, |sts| {
            self.step_batch_into(sts, tokens, logits)
        })
    }

    fn prefill_chunk(
        &mut self,
        state: &mut Vec<f32>,
        tokens: &[u32],
        _variant: Variant,
    ) -> Result<Vec<f32>> {
        let (n_layer, d) = (self.n_layer, self.d);
        prefill_via_state(n_layer, d, state, tokens, |st, toks| {
            RwkvModel::prefill_chunk(self, st, toks)
        })
    }

    fn weight_stream_bytes(&self) -> u64 {
        f32_weight_stream_bytes(self.n_layer, self.d, self.f, self.vocab)
    }
}

impl EngineModel for HwModel {
    fn vocab(&self) -> usize {
        HwModel::vocab(self)
    }

    fn state_len(&self) -> usize {
        self.n_layer() * 5 * self.d()
    }

    fn init_state(&self) -> Vec<f32> {
        self.new_state().data
    }

    fn forward(&mut self, state: &mut Vec<f32>, token: u32, _variant: Variant) -> Result<Vec<f32>> {
        let (n_layer, d) = (self.n_layer(), self.d());
        let mut st = State { data: std::mem::take(state), n_layer, d };
        let logits = self.step(&mut st, token);
        *state = st.data;
        Ok(logits)
    }

    fn forward_batch(
        &mut self,
        states: &mut [&mut Vec<f32>],
        tokens: &[u32],
        _variant: Variant,
        logits: &mut Vec<f32>,
    ) -> Vec<Option<anyhow::Error>> {
        let (n_layer, d) = (self.n_layer(), self.d());
        batch_via_step(n_layer, d, states, |sts| {
            self.step_batch_into(sts, tokens, logits)
        })
    }

    fn prefill_chunk(
        &mut self,
        state: &mut Vec<f32>,
        tokens: &[u32],
        _variant: Variant,
    ) -> Result<Vec<f32>> {
        let (n_layer, d) = (self.n_layer(), self.d());
        prefill_via_state(n_layer, d, state, tokens, |st, toks| {
            HwModel::prefill_chunk(self, st, toks)
        })
    }

    fn take_clip_events(&mut self) -> u64 {
        HwModel::take_clip_events(self)
    }

    fn weight_stream_bytes(&self) -> u64 {
        // decoded Δ-PoT: same grid as packed, but full f32 planes
        f32_weight_stream_bytes(self.n_layer(), self.d(), self.f(), self.vocab())
    }
}

impl EngineModel for PackedModel {
    fn vocab(&self) -> usize {
        PackedModel::vocab(self)
    }

    fn state_len(&self) -> usize {
        self.n_layer() * 5 * self.d()
    }

    fn init_state(&self) -> Vec<f32> {
        self.new_state().data
    }

    fn forward(&mut self, state: &mut Vec<f32>, token: u32, _variant: Variant) -> Result<Vec<f32>> {
        let (n_layer, d) = (self.n_layer(), self.d());
        let mut st = State { data: std::mem::take(state), n_layer, d };
        let logits = self.step(&mut st, token);
        *state = st.data;
        Ok(logits)
    }

    fn forward_batch(
        &mut self,
        states: &mut [&mut Vec<f32>],
        tokens: &[u32],
        _variant: Variant,
        logits: &mut Vec<f32>,
    ) -> Vec<Option<anyhow::Error>> {
        let (n_layer, d) = (self.n_layer(), self.d());
        batch_via_step(n_layer, d, states, |sts| {
            self.step_batch_into(sts, tokens, logits)
        })
    }

    fn prefill_chunk(
        &mut self,
        state: &mut Vec<f32>,
        tokens: &[u32],
        _variant: Variant,
    ) -> Result<Vec<f32>> {
        let (n_layer, d) = (self.n_layer(), self.d());
        prefill_via_state(n_layer, d, state, tokens, |st, toks| {
            PackedModel::prefill_chunk(self, st, toks)
        })
    }

    fn take_clip_events(&mut self) -> u64 {
        PackedModel::take_clip_events(self)
    }

    fn weight_stream_bytes(&self) -> u64 {
        // packed Δ-PoT words: 2 bytes per weight, half the f32 traffic
        self.decode_cycle_weight_bytes()
    }
}

/// Which native numerics backend a serving stack runs (the backend
/// table in [`crate::model`]).  Selected per coordinator via
/// [`super::CoordinatorConfig::backend`] and built by
/// [`BackendModel::build`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Backend {
    /// Plain f32 planes ([`RwkvModel`]) — the exact reference.
    #[default]
    Exact,
    /// Decoded Δ-PoT planes + integer elementwise units ([`HwModel`])
    /// — bit-faithful accuracy model, full f32 traffic.
    Hw,
    /// Packed Δ-PoT planes on the SIMD kernels ([`PackedModel`]) — the
    /// throughput configuration, half the weight traffic.
    Packed,
}

impl Backend {
    /// Read the `HFRWKV_BACKEND` environment variable (`exact` / `hw`
    /// / `packed`, case-insensitive).  Unset or unrecognized values
    /// fall back to the default exact backend — serving must not fail
    /// on a typo'd env.
    pub fn from_env() -> Backend {
        match std::env::var("HFRWKV_BACKEND").as_deref() {
            Ok(s) if s.eq_ignore_ascii_case("hw") => Backend::Hw,
            Ok(s) if s.eq_ignore_ascii_case("packed") => Backend::Packed,
            _ => Backend::Exact,
        }
    }
}

/// A config-selected native backend behind one [`EngineModel`] — what
/// [`super::Coordinator::spawn_native`] serves.  Variants are boxed so
/// the enum stays small regardless of backend footprint (the packed
/// model carries every plane twice over: codes + the quantized base).
pub enum BackendModel {
    Exact(Box<RwkvModel>),
    Hw(Box<HwModel>),
    Packed(Box<PackedModel>),
}

/// Delegate one expression across the three [`BackendModel`] variants.
macro_rules! for_backend {
    ($self:expr, $m:ident => $body:expr) => {
        match $self {
            BackendModel::Exact($m) => $body,
            BackendModel::Hw($m) => $body,
            BackendModel::Packed($m) => $body,
        }
    };
}

impl BackendModel {
    /// Build `backend` from an f32 base model.  `calib_tokens` drives
    /// the activation-scale calibration of the quantized backends
    /// (ignored by `Exact`); hw and packed calibrate through the same
    /// pipeline, so switching between them never moves the scales.
    pub fn build(base: RwkvModel, backend: Backend, calib_tokens: &[u32]) -> BackendModel {
        match backend {
            Backend::Exact => BackendModel::Exact(Box::new(base)),
            Backend::Hw => BackendModel::Hw(Box::new(HwModel::from_f32(base, calib_tokens))),
            Backend::Packed => {
                BackendModel::Packed(Box::new(PackedModel::from_f32(base, calib_tokens)))
            }
        }
    }
}

impl EngineModel for BackendModel {
    fn vocab(&self) -> usize {
        for_backend!(self, m => EngineModel::vocab(&**m))
    }

    fn state_len(&self) -> usize {
        for_backend!(self, m => EngineModel::state_len(&**m))
    }

    fn init_state(&self) -> Vec<f32> {
        for_backend!(self, m => EngineModel::init_state(&**m))
    }

    fn forward(&mut self, state: &mut Vec<f32>, token: u32, variant: Variant) -> Result<Vec<f32>> {
        for_backend!(self, m => m.forward(state, token, variant))
    }

    fn forward_batch(
        &mut self,
        states: &mut [&mut Vec<f32>],
        tokens: &[u32],
        variant: Variant,
        logits: &mut Vec<f32>,
    ) -> Vec<Option<anyhow::Error>> {
        for_backend!(self, m => m.forward_batch(states, tokens, variant, logits))
    }

    fn prefill_chunk(
        &mut self,
        state: &mut Vec<f32>,
        tokens: &[u32],
        variant: Variant,
    ) -> Result<Vec<f32>> {
        for_backend!(self, m => m.prefill_chunk(state, tokens, variant))
    }

    fn take_clip_events(&mut self) -> u64 {
        for_backend!(self, m => EngineModel::take_clip_events(&mut **m))
    }

    fn weight_stream_bytes(&self) -> u64 {
        for_backend!(self, m => EngineModel::weight_stream_bytes(&**m))
    }
}

/// Where a session is in its lifecycle.  Admission no longer runs the
/// whole prompt inline: a session starts `Prefilling` and consumes one
/// bounded chunk per scheduling cycle (via [`Engine::prefill_tick`]),
/// interleaved with the batched decode of the sessions already in
/// `Decoding` — continuous batching across both phases.
#[derive(Clone, Debug)]
pub enum SessionPhase {
    /// Prompt being consumed; `pos` tokens of `req.prompt` (BOS-padded
    /// in place at admission, never empty) are already folded into the
    /// state.
    Prefilling { pos: usize },
    /// Prompt fully consumed by a fork request (`n_best > 1`): the last
    /// prompt token's logits are held for the scheduler to call
    /// [`Engine::fork`] — no token has been sampled yet (each branch
    /// samples with its own seeded sampler).  `logits` is empty iff the
    /// session resumed from a decode-state snapshot, whose pin
    /// (`snapshot_pin`) carries the logits instead.
    ForkReady { logits: Vec<f32> },
    /// Prompt fully consumed; `next_token` holds the pending sample.
    Decoding,
}

/// One in-flight generation (the session): state held, prompt being
/// consumed or decode in progress (see [`SessionPhase`]).
pub struct ActiveSession {
    pub request_id: u64,
    /// Best-of-n branch index (0 for ordinary sessions and fork
    /// parents; [`Engine::fork`] numbers the branches 0..n_best).
    pub branch: usize,
    pub req: GenRequest,
    pub phase: SessionPhase,
    pub state: Vec<f32>,
    pub generated: Vec<u32>,
    pub sampler: Sampler,
    /// Sampled but not yet committed token — meaningless until the
    /// session reaches [`SessionPhase::Decoding`].
    pub next_token: u32,
    /// Prompt tokens whose prefill was skipped by resuming from the
    /// prefix cache (0 on a cache miss or with the cache disabled).
    pub cached_prefix_tokens: usize,
    /// Handle on the snapshot this session resumed from, held while the
    /// session is still prefilling so the cache can't evict a borrowed
    /// entry mid-resume; released at the decode transition — the state
    /// was privately copied at admission, so a long decode must not
    /// keep the entry unevictable.  Exception: fork branches hold their
    /// shared decode-state pin for their whole lifetime (that sharing is
    /// the point — it is released when the branch completes or is
    /// reaped).
    pub snapshot_pin: Option<SnapshotRef>,
    /// Rollback anchor: the session's state as of the last guarded-call
    /// boundary, captured only while [`FaultPolicy::max_retries`] > 0
    /// (empty otherwise).  A faulting chunk/cycle restores from here and
    /// retries — prefill and decode are bit-exact replays from a state,
    /// so a successful retry is indistinguishable from never faulting.
    pub last_good: Vec<f32>,
    pub prefill_seconds: f64,
    pub decode_seconds: f64,
    /// Time from enqueue to the first sampled token (set when prefill
    /// completes; 0 while still prefilling).  A redriven session keeps
    /// its original TTFT — the first token was genuinely delivered
    /// before the crash.
    pub ttft_seconds: f64,
    pub enqueued_at: Instant,
    pub started_at: Instant,
    /// Absolute deadline (`enqueued_at + req.deadline`), precomputed at
    /// admission so the retry-backoff guards don't re-derive it per
    /// fault.  `None` = no deadline.
    pub deadline_at: Option<Instant>,
    /// How many times the supervisor has already redriven this session
    /// (0 = never crashed); compared against `req.redrive_budget`.
    pub redrive_attempt: u32,
    /// Length of the *client's* prompt (post BOS-pad).  Equal to
    /// `req.prompt.len()` for ordinary sessions; shorter for redriven
    /// ones, whose prompt was extended with the already-committed
    /// tokens (`req.prompt[orig_prompt_len..]` = the replayed output).
    pub orig_prompt_len: usize,
    /// When the worker crash that redrove this session was handled —
    /// consumed by the scheduler at the next committed token to measure
    /// time-to-first-token-after-fault.  `None` for ordinary sessions.
    pub redriven_at: Option<Instant>,
    /// When this session's previous token was committed — the scheduler
    /// feeds the gap into [`super::Metrics::inter_token_hist`].  `None`
    /// until the first commit, and reset to `None` across a redrive
    /// resume so the crash stall never enters the steady-state
    /// inter-token distribution.
    pub last_token_at: Option<Instant>,
}

impl ActiveSession {
    /// True once the prompt is fully consumed and decode can proceed.
    pub fn is_decoding(&self) -> bool {
        matches!(self.phase, SessionPhase::Decoding)
    }

    /// True while prompt tokens remain to be consumed.
    pub fn is_prefilling(&self) -> bool {
        matches!(self.phase, SessionPhase::Prefilling { .. })
    }

    /// True when a fork parent's prompt is done and [`Engine::fork`]
    /// must spawn its branches.
    pub fn is_fork_ready(&self) -> bool {
        matches!(self.phase, SessionPhase::ForkReady { .. })
    }
}

/// The engine drives sessions over any [`EngineModel`].
pub struct Engine<M: EngineModel> {
    pub model: M,
    /// Reusable flat `[B * vocab]` logits panel for batched decode —
    /// together with the walk's thread-local scratch this makes the
    /// native decode hot path allocation-free in steady state.
    batch_logits: Vec<f32>,
    /// Prefix-sharing state cache ([`crate::statecache`]): admission
    /// resumes sessions from the deepest cached prompt-prefix state, and
    /// every prefill chunk boundary captures a snapshot.  `None` = the
    /// pre-cache behavior, bit for bit.
    cache: Option<StateStore>,
    /// Prompt tokens actually consumed by prefill forwards, cumulative
    /// over the engine's lifetime (cached resumes and decode-state fork
    /// hits skip tokens without counting here) — the ground truth the
    /// fork bench's one-prefill assertion reads via
    /// [`super::Metrics::prompt_tokens_prefilled`].
    prefilled_tokens: u64,
    /// Fault handling for the guarded calls (see [`FaultPolicy`]).
    policy: FaultPolicy,
    /// Cumulative fault counters (see [`FaultStats`]).
    faults: FaultStats,
    /// Structured fault journal (see [`super::journal`]): every guarded-
    /// call fault is recorded with its attribution tuple.  Shared so
    /// the scheduler's supervisor can append worker-scope events to the
    /// same ring ([`Engine::set_journal`]).
    journal: Arc<Mutex<FaultJournal>>,
    /// Scheduling cycle counter, bumped by the worker loop via
    /// [`Engine::begin_cycle`] — the `cycle` stamped into journal
    /// events (0 for non-scheduler callers that never bump it).
    cycle: u64,
    /// Shared trace handle ([`crate::trace::Tracer`]): prefill chunks,
    /// first tokens, forks, the decode forward/scatter split and fault
    /// mirrors are recorded here.  Disabled by default; the scheduler
    /// installs the coordinator's tracer via [`Engine::set_tracer`].
    tracer: Tracer,
}

impl<M: EngineModel> Engine<M> {
    pub fn new(model: M) -> Engine<M> {
        Engine {
            model,
            batch_logits: Vec::new(),
            cache: None,
            prefilled_tokens: 0,
            policy: FaultPolicy::default(),
            faults: FaultStats::default(),
            journal: Arc::new(Mutex::new(FaultJournal::default())),
            cycle: 0,
            tracer: Tracer::disabled(),
        }
    }

    /// An engine with the prefix-sharing state cache enabled.  Resuming
    /// is bit-exact with full prefill (asserted in
    /// `rust/tests/statecache.rs`), so the cache changes latency, never
    /// tokens.
    pub fn with_cache(model: M, cfg: StateCacheConfig) -> Engine<M> {
        let mut e = Engine::new(model);
        e.cache = Some(StateStore::new(cfg));
        e
    }

    /// Set how guarded calls treat faults (see [`FaultPolicy`]; the
    /// scheduler forwards [`super::CoordinatorConfig::fault`] here).
    pub fn set_fault_policy(&mut self, policy: FaultPolicy) {
        self.policy = policy;
    }

    pub fn fault_policy(&self) -> FaultPolicy {
        self.policy
    }

    /// Cumulative fault-handling counters (see the field docs).
    pub fn fault_stats(&self) -> FaultStats {
        self.faults
    }

    /// Reset the engine's serving-side state after a worker-scope
    /// failure: the batch panel is dropped (a panic can leave it
    /// half-written) and the state cache runs a **selective**
    /// crash-recovery sweep ([`StateStore::recover`]) — residents whose
    /// panels pass the non-finite scan survive with recency intact
    /// (the insert-time quarantine already kept poison out, and the
    /// scan re-proves each survivor healthy *now*), while anything the
    /// dying cycle managed to corrupt is purged.  Surviving snapshots
    /// are what lets a redriven session resume from its deepest cached
    /// prefix instead of re-prefilling from token 0.  The model and
    /// the cumulative counters survive; per-session state belonged to
    /// the sessions the supervisor just dropped (which also released
    /// their pins).  Returns `(kept, purged)` cache entries — `(0, 0)`
    /// with the cache disabled.
    pub fn recover(&mut self) -> (usize, usize) {
        self.batch_logits = Vec::new();
        match &mut self.cache {
            Some(cache) => cache.recover(),
            None => (0, 0),
        }
    }

    /// Handle on the structured fault journal (see [`super::journal`]).
    pub fn journal(&self) -> Arc<Mutex<FaultJournal>> {
        Arc::clone(&self.journal)
    }

    /// Replace the journal with a shared one (the scheduler installs a
    /// ring it also hands to the supervisor and the `Coordinator`
    /// front-end, so all three record into one attribution stream).
    pub fn set_journal(&mut self, journal: Arc<Mutex<FaultJournal>>) {
        self.journal = journal;
    }

    /// Install the shared trace handle (the scheduler passes the
    /// coordinator's tracer so engine- and scheduler-side events share
    /// one epoch and one ring).
    pub fn set_tracer(&mut self, tracer: Tracer) {
        self.tracer = tracer;
    }

    /// Bump the scheduling-cycle stamp (the worker loop calls this once
    /// per cycle; journal events record the current value).
    pub fn begin_cycle(&mut self) {
        self.cycle += 1;
    }

    pub fn cycle(&self) -> u64 {
        self.cycle
    }

    /// Append one attribution record to the fault journal.
    fn record_fault(
        &self,
        request_id: u64,
        branch: usize,
        phase: FaultPhase,
        kind: FaultKind,
        attempt: u32,
        action: RecoveryAction,
    ) {
        let mut j = self.journal.lock().unwrap_or_else(|e| e.into_inner());
        j.record(FaultEvent {
            request_id,
            branch,
            cycle: self.cycle,
            phase,
            kind,
            attempt,
            action,
            unix_s: 0.0,
        });
        drop(j);
        // mirror onto the session's trace timeline: same attribution
        // tuple, cross-referenced to the journal by (request, cycle)
        self.tracer.instant(
            request_id,
            branch as u32,
            self.cycle,
            TraceEventKind::Fault { phase, kind, attempt, action },
        );
    }

    /// Purge any non-finite snapshot from the cache — called whenever a
    /// health guard trips, so a poisoned state detected *anywhere* also
    /// evicts whatever poison may have already been cached this cycle.
    fn quarantine_cache(&mut self) {
        if let Some(cache) = &mut self.cache {
            cache.purge_non_finite();
        }
    }

    /// Restore `s` to its last-good snapshot (no-op when none was
    /// captured, i.e. `max_retries == 0` fail-fast mode).
    fn rollback_session(&mut self, s: &mut ActiveSession) {
        if s.last_good.is_empty() {
            return;
        }
        let snap = std::mem::take(&mut s.last_good);
        self.model.restore_state(&snap, &mut s.state);
        s.last_good = snap;
        self.faults.rollbacks += 1;
    }

    /// Cache counters + gauges, if the cache is enabled (the scheduler
    /// mirrors them into [`super::Metrics`] every cycle).
    pub fn cache_stats(&self) -> Option<CacheStats> {
        self.cache.as_ref().map(|c| c.stats())
    }

    /// Resident snapshots currently carrying NaN/±Inf — always 0 under
    /// the statecache quarantine rule; the chaos soak asserts exactly
    /// that ([`crate::statecache::StateStore::scan_non_finite`]).
    pub fn cache_scan_non_finite(&self) -> usize {
        self.cache.as_ref().map_or(0, |c| c.scan_non_finite())
    }

    /// Cumulative prompt tokens consumed by prefill forwards (see the
    /// field doc).
    pub fn prefilled_tokens(&self) -> u64 {
        self.prefilled_tokens
    }

    /// Admit a request WITHOUT doing any forward work: the session
    /// enters [`SessionPhase::Prefilling`] and the scheduler drives it
    /// through [`Engine::prefill_tick`] one bounded chunk at a time.
    /// An empty prompt is BOS-padded in place (one prompt copy per
    /// session, read by every tick — no duplicate allocation).
    ///
    /// With the prefix cache enabled, admission additionally runs a
    /// longest-prefix lookup: on a hit the session's state is restored
    /// from the deepest cached snapshot and prefill resumes *after* it —
    /// a request behind a fully-cached shared prompt prefills only its
    /// last token.  The lookup is capped at `prompt.len() - 1` because
    /// the sampler needs the final prompt token's logits, which
    /// snapshots don't carry; the matched depth is recorded in
    /// [`ActiveSession::cached_prefix_tokens`] and the snapshot handle
    /// stays pinned until the session's prefill completes.
    pub fn admit(&mut self, request_id: u64, mut req: GenRequest, enqueued_at: Instant) -> ActiveSession {
        let mut state = self.model.init_state();
        let sampler = Sampler::new(req.temperature, req.top_k, req.seed);
        if req.prompt.is_empty() {
            req.prompt = vec![crate::model::tokenizer::BOS];
        }
        let mut cached_prefix_tokens = 0;
        let mut snapshot_pin = None;
        let mut phase = SessionPhase::Prefilling { pos: 0 };
        if let Some(cache) = &mut self.cache {
            let class = variant_class(req.variant);
            // fork requests first probe the decode-state namespace: a
            // full-prompt hit carries the last token's logits, so the
            // whole prefill is skipped and the scheduler can fork at the
            // next cycle boundary.  The probe is opportunistic — a miss
            // must not double-count against the prefix hit rate.
            if req.n_best > 1 {
                if let Some(snap) = cache.lookup_exact(class | DECODE_NS, &req.prompt) {
                    debug_assert!(!snap.logits().is_empty(), "decode-ns entries carry logits");
                    // no restore and no logits copy: [`Engine::fork`]
                    // builds every branch straight off the pinned
                    // snapshot, and nothing else ever reads a fork
                    // parent's state — copying here would be pure waste
                    cached_prefix_tokens = snap.tokens();
                    phase = SessionPhase::ForkReady { logits: Vec::new() };
                    snapshot_pin = Some(snap);
                }
            }
            if snapshot_pin.is_none() {
                if let Some(snap) = cache.lookup(class, &req.prompt, req.prompt.len() - 1) {
                    self.model.restore_state(snap.state(), &mut state);
                    cached_prefix_tokens = snap.tokens();
                    phase = SessionPhase::Prefilling { pos: cached_prefix_tokens };
                    snapshot_pin = Some(snap);
                }
            }
        }
        let deadline_at = req.deadline.and_then(|d| enqueued_at.checked_add(d));
        let orig_prompt_len = req.prompt.len();
        ActiveSession {
            request_id,
            branch: 0,
            req,
            phase,
            state,
            generated: Vec::new(),
            sampler,
            next_token: 0,
            cached_prefix_tokens,
            snapshot_pin,
            last_good: Vec::new(),
            prefill_seconds: 0.0,
            decode_seconds: 0.0,
            ttft_seconds: 0.0,
            enqueued_at,
            started_at: Instant::now(),
            deadline_at,
            redrive_attempt: 0,
            orig_prompt_len,
            redriven_at: None,
            last_token_at: None,
        }
    }

    /// Turn a freshly admitted session back into the continuation of a
    /// crashed one (the supervisor re-submitted it with its prompt
    /// extended by the already-committed tokens — see the redrive
    /// section of the [`crate::coordinator`] docs).  `orig_prompt_len`
    /// splits that extended prompt back into client prompt vs replayed
    /// output: the suffix is re-seeded into `generated`, so `seq_idx`
    /// (`generated.len() - 1` at commit) continues without gaps or
    /// duplicates and the finish conditions count the replayed tokens.
    /// The sampler is rebuilt at the session's branch seed and
    /// fast-forwarded by the replayed count — [`Sampler::sample`]
    /// consumes exactly one draw per token, so the continuation is
    /// bit-exact with the run that never crashed.  Chunked prefill over
    /// the extended prompt is bit-exact with the stepwise decode that
    /// produced those tokens, so the restored state is too.
    pub fn resume_redriven(
        &mut self,
        s: &mut ActiveSession,
        branch: usize,
        attempt: u32,
        orig_prompt_len: usize,
        failed_at: Instant,
    ) {
        debug_assert!(orig_prompt_len <= s.req.prompt.len());
        // A redrive with no committed tokens to replay (a fork parent
        // crashed mid-prefill, say) may legally re-admit straight into
        // ForkReady via a decode-NS cache hit; any session with a
        // replay suffix must still be prefilling it.
        debug_assert!(
            s.is_prefilling() || orig_prompt_len == s.req.prompt.len(),
            "a redrive with replayed tokens re-enters through chunked prefill"
        );
        s.branch = branch;
        s.redrive_attempt = attempt;
        s.orig_prompt_len = orig_prompt_len;
        s.generated = s.req.prompt[orig_prompt_len..].to_vec();
        s.sampler = Sampler::new(
            s.req.temperature,
            s.req.top_k,
            s.req.seed.wrapping_add(branch as u64),
        );
        s.sampler.fast_forward(s.generated.len());
        s.redriven_at = Some(failed_at);
        // the inter-token gap clock must not span the crash stall
        s.last_token_at = None;
    }

    /// Consume up to `max_chunk` prompt tokens of a `Prefilling` session
    /// (ONE [`EngineModel::prefill_chunk`] call — a single matmul pass
    /// per weight matrix for sequence-parallel models).  When the prompt
    /// is exhausted the first token is sampled, time-to-first-token is
    /// recorded, and the session moves to [`SessionPhase::Decoding`].
    ///
    /// Returns true once the session is decoding (immediately true for
    /// sessions already there).
    ///
    /// The model call runs under the fault guards ([`FaultPolicy`]): a
    /// panic or (with `health_guards`) a non-finite logits/state panel
    /// rolls the session back to its pre-chunk state and retries up to
    /// `max_retries` times before surfacing as a [`SessionFault`].  On
    /// a fault the session's phase is untouched, so a caller that
    /// chooses to keep it could tick it again.
    pub fn prefill_tick(
        &mut self,
        s: &mut ActiveSession,
        max_chunk: usize,
    ) -> Result<bool, SessionFault> {
        let pos = match s.phase {
            SessionPhase::Prefilling { pos } => pos,
            _ => return Ok(true),
        };
        let t0 = Instant::now();
        let trace_t0 = self.tracer.now_us();
        let end = pos.saturating_add(max_chunk.max(1)).min(s.req.prompt.len());
        let done = end == s.req.prompt.len();
        if self.policy.max_retries > 0 {
            s.last_good.clear();
            s.last_good.extend_from_slice(&s.state);
        }
        let mut attempt = 0u32;
        let logits = loop {
            let outcome = {
                let model = &mut self.model;
                let state = &mut s.state;
                let chunk = &s.req.prompt[pos..end];
                let variant = s.req.variant;
                catch_unwind(AssertUnwindSafe(move || {
                    model.prefill_chunk(state, chunk, variant)
                }))
            };
            let fault = match outcome {
                Ok(Ok(lg)) => {
                    if !self.policy.health_guards
                        || (panel_all_finite(&lg) && panel_all_finite(&s.state))
                    {
                        break lg;
                    }
                    self.faults.numeric_faults += 1;
                    self.quarantine_cache();
                    SessionFault::Numeric
                }
                // an error the model *returned* is deliberate (e.g. a
                // dead runtime): surface immediately, never retry
                Ok(Err(e)) => {
                    s.prefill_seconds += t0.elapsed().as_secs_f64();
                    self.record_fault(
                        s.request_id,
                        s.branch,
                        FaultPhase::Prefill,
                        FaultKind::ModelError,
                        attempt,
                        RecoveryAction::SessionFailed,
                    );
                    return Err(SessionFault::Error(e));
                }
                Err(payload) => {
                    self.faults.panics_caught += 1;
                    SessionFault::Panicked(panic_message(payload))
                }
            };
            let kind = match fault {
                SessionFault::Numeric => FaultKind::NonFinite,
                _ => FaultKind::Panic,
            };
            // a panic can abandon the state mid-marshal and a NaN has
            // definitely poisoned it — roll back either way (no-op in
            // fail-fast mode, where the faulting session dies anyway)
            self.rollback_session(s);
            if attempt >= self.policy.max_retries {
                s.prefill_seconds += t0.elapsed().as_secs_f64();
                self.record_fault(
                    s.request_id,
                    s.branch,
                    FaultPhase::Prefill,
                    kind,
                    attempt,
                    RecoveryAction::SessionFailed,
                );
                return Err(fault);
            }
            attempt += 1;
            let sleep = backoff_duration(self.policy.retry_backoff_ms, attempt);
            if sleep_crosses_deadline(sleep, s.deadline_at) {
                s.prefill_seconds += t0.elapsed().as_secs_f64();
                self.record_fault(
                    s.request_id,
                    s.branch,
                    FaultPhase::Prefill,
                    kind,
                    attempt,
                    RecoveryAction::DeadlineAbandoned,
                );
                return Err(SessionFault::DeadlineExceeded);
            }
            self.record_fault(
                s.request_id,
                s.branch,
                FaultPhase::Prefill,
                kind,
                attempt,
                RecoveryAction::Retried,
            );
            self.faults.retries += 1;
            if !sleep.is_zero() {
                std::thread::sleep(sleep);
            }
        };
        self.prefilled_tokens += (end - pos) as u64;
        s.phase = SessionPhase::Prefilling { pos: end };
        // capture a snapshot at the chunk boundary: prefill is bit-exact
        // across chunkings, so this state is exactly what ANY future
        // prefill of the same `prompt[..end]` would pass through.  The
        // closure only materializes a copy when the prefix isn't already
        // cached (a re-walked shared prefix just refreshes its recency).
        if let Some(cache) = &mut self.cache {
            let class = variant_class(s.req.variant);
            let (model, state, prompt) = (&mut self.model, &s.state, &s.req.prompt);
            // state.len() prices the entry so dedup/rejection never
            // materializes the snapshot copy
            cache.insert_with(class, &prompt[..end], state.len(), || {
                model.snapshot_state(state)
            });
        }
        s.prefill_seconds += t0.elapsed().as_secs_f64();
        self.tracer.span(
            trace_t0,
            s.request_id,
            s.branch as u32,
            self.cycle,
            TraceEventKind::PrefillChunk { from: pos as u32, to: end as u32 },
        );
        if done {
            // prefill over: release the resumed-from snapshot so decode
            // time doesn't hold it unevictable (see the field docs)
            s.snapshot_pin = None;
            if s.req.n_best > 1 {
                // fork parent: hold the logits for [`Engine::fork`] —
                // each branch samples its own first token with its own
                // seeded sampler, so nothing is sampled here
                s.phase = SessionPhase::ForkReady { logits };
            } else {
                s.next_token = s.sampler.sample(&logits);
                // a redriven session keeps its pre-crash TTFT (the
                // scheduler restores it before this tick runs)
                if s.ttft_seconds == 0.0 {
                    s.ttft_seconds = s.enqueued_at.elapsed().as_secs_f64();
                    self.tracer.instant(
                        s.request_id,
                        s.branch as u32,
                        self.cycle,
                        TraceEventKind::FirstToken,
                    );
                }
                s.phase = SessionPhase::Decoding;
            }
        }
        Ok(done)
    }

    /// Admit a request and run its whole prefill to completion (one
    /// maximal chunk): the blocking convenience path for tests, examples
    /// and non-scheduler callers.  Single-branch requests only — a fork
    /// request (`n_best > 1`) ends in [`SessionPhase::ForkReady`] and
    /// must go through [`Engine::fork`] (the scheduler's path).
    pub fn start(&mut self, request_id: u64, req: GenRequest, enqueued_at: Instant) -> Result<ActiveSession> {
        debug_assert!(req.n_best <= 1, "start() cannot fork; drive admit + prefill_tick + fork");
        let mut sess = self.admit(request_id, req, enqueued_at);
        self.prefill_tick(&mut sess, usize::MAX)?;
        debug_assert!(sess.is_decoding(), "maximal prefill_tick must finish the prompt");
        Ok(sess)
    }

    /// Fork a [`SessionPhase::ForkReady`] parent into its `n_best`
    /// decoding branches.  The prompt was prefilled ONCE; its
    /// post-prompt state becomes one shared pinned snapshot (offered to
    /// the cache's decode namespace together with the last token's
    /// logits, so an identical later fork request skips prefill
    /// entirely), and branch `b` resumes copy-on-write from it with
    /// sampler seed `seed + b`.  Each branch holds the pin until it
    /// completes or is reaped.  Branch outputs are bit-exact with
    /// `n_best` sequential single-session runs of the same request at
    /// those seeds (`rust/tests/streaming.rs`, `rust/benches/fork.rs`).
    pub fn fork(&mut self, parent: ActiveSession) -> Vec<ActiveSession> {
        let ActiveSession {
            request_id,
            req,
            phase,
            state,
            snapshot_pin,
            cached_prefix_tokens,
            prefill_seconds,
            enqueued_at,
            started_at,
            deadline_at,
            redrive_attempt,
            orig_prompt_len,
            redriven_at,
            ..
        } = parent;
        let SessionPhase::ForkReady { logits } = phase else {
            panic!("fork requires a ForkReady session");
        };
        let n = req.n_best.max(1);
        // one shared pinned snapshot for every branch: reuse the
        // decode-ns entry the parent resumed from if there is one,
        // otherwise capture the post-prompt state now and offer it to
        // the decode namespace (adopt shares the Arc — no extra copy;
        // with the cache disabled the detached handle is the pin)
        let snap = match snapshot_pin {
            Some(s) if s.tokens() == req.prompt.len() && !s.logits().is_empty() => s,
            _ => {
                // prefill path: the phase carried the real logits (the
                // decode-ns-hit path always takes the arm above)
                debug_assert!(!logits.is_empty(), "prefill-path ForkReady must carry logits");
                let fresh = SnapshotRef::detached(
                    self.model.snapshot_state(&state),
                    req.prompt.len(),
                    logits,
                );
                match &mut self.cache {
                    Some(cache) => {
                        let class = variant_class(req.variant) | DECODE_NS;
                        cache.adopt(class, &req.prompt, fresh)
                    }
                    None => fresh,
                }
            }
        };
        let ttft = enqueued_at.elapsed().as_secs_f64();
        self.tracer.instant(request_id, 0, self.cycle, TraceEventKind::Fork { branches: n as u32 });
        // per branch: one state copy (the fundamental fork cost) plus a
        // req clone — the prompt Vec in it is dominated by the state
        // floats, so sharing it behind an Arc isn't worth the API churn
        (0..n)
            .map(|b| {
                let mut st = Vec::new();
                self.model.restore_state(snap.state(), &mut st);
                let mut sampler =
                    Sampler::new(req.temperature, req.top_k, req.seed.wrapping_add(b as u64));
                let next_token = sampler.sample(snap.logits());
                self.tracer.instant(request_id, b as u32, self.cycle, TraceEventKind::FirstToken);
                ActiveSession {
                    request_id,
                    branch: b,
                    req: req.clone(),
                    phase: SessionPhase::Decoding,
                    state: st,
                    generated: Vec::new(),
                    sampler,
                    next_token,
                    cached_prefix_tokens,
                    snapshot_pin: Some(snap.clone()),
                    last_good: Vec::new(),
                    // the one prompt prefill is accounted to branch 0 so
                    // the Metrics prefill-seconds sum stays truthful
                    prefill_seconds: if b == 0 { prefill_seconds } else { 0.0 },
                    decode_seconds: 0.0,
                    ttft_seconds: ttft,
                    enqueued_at,
                    started_at,
                    deadline_at,
                    redrive_attempt,
                    orig_prompt_len,
                    // same accounting as prefill_seconds: one crash, one
                    // resume measurement
                    redriven_at: if b == 0 { redriven_at } else { None },
                    last_token_at: None,
                }
            })
            .collect()
    }

    /// First half of a decode step: commit the pending sampled token and
    /// check the finish conditions.  Returns Some(reason) when the
    /// session is done (no forward needed); otherwise the caller runs
    /// the second half — forward + resample — per session via
    /// [`Engine::step_session`] or fused via [`Engine::step_batch`].
    pub fn commit_pending(&self, s: &mut ActiveSession) -> Option<FinishReason> {
        debug_assert!(
            s.is_decoding(),
            "commit_pending requires a Decoding session — drive prefill_tick (or start) first, \
             otherwise the placeholder next_token would be committed as output"
        );
        let tok = s.next_token;
        s.generated.push(tok);
        if s.req.stop_token == Some(tok) {
            return Some(FinishReason::StopToken);
        }
        if s.generated.len() >= s.req.max_new_tokens {
            return Some(FinishReason::MaxTokens);
        }
        None
    }

    /// One decode step for a session; returns Some(reason) when done.
    pub fn step_session(&mut self, s: &mut ActiveSession) -> Result<Option<FinishReason>> {
        let t0 = Instant::now();
        if let Some(reason) = self.commit_pending(s) {
            s.decode_seconds += t0.elapsed().as_secs_f64();
            return Ok(Some(reason));
        }
        let tok = *s.generated.last().expect("commit_pending pushed a token");
        let logits = self.model.forward(&mut s.state, tok, s.req.variant)?;
        s.next_token = s.sampler.sample(&logits);
        s.decode_seconds += t0.elapsed().as_secs_f64();
        Ok(None)
    }

    /// Second half of a batched decode cycle: advance every continuing
    /// session (pending token already committed) with ONE
    /// [`EngineModel::forward_batch`] per variant group, then resample.
    /// Order within a group is the caller's — i.e. admission — order, so
    /// round-robin fairness and determinism are preserved.  The batch
    /// wall time is split evenly across participants for the per-session
    /// decode metrics.
    ///
    /// Outcomes are per session, aligned with `sessions` (None =
    /// advanced fine): a failing session reports its own
    /// [`SessionFault`] and its batchmates keep generating — the same
    /// isolation the pre-fusion per-session scheduler had.
    ///
    /// The fused call runs under the fault guards ([`FaultPolicy`]):
    /// healthy members sample from their logits slice *before* any
    /// retry overwrites the shared panel, so they advance exactly once
    /// and stay bit-exact with a fault-free run; only the panicked /
    /// poisoned members are rolled back to their pre-cycle state and
    /// re-run (retry time is therefore confined to the faulting
    /// subset — at batch width 1 the guarded path degenerates to the
    /// per-session one).
    pub fn step_batch(&mut self, sessions: &mut [&mut ActiveSession]) -> Vec<Option<SessionFault>> {
        let n = sessions.len();
        let mut errors: Vec<Option<SessionFault>> = (0..n).map(|_| None).collect();
        if n == 0 {
            return errors;
        }
        let t0 = Instant::now();
        let trace_t0 = self.tracer.now_us();
        // sampler-scatter time accumulated across variant groups and
        // retries; the rest of the cycle is the fused forward
        let mut scatter_us = 0u64;
        let mut variants: Vec<Variant> = Vec::new();
        for s in sessions.iter() {
            if !variants.contains(&s.req.variant) {
                variants.push(s.req.variant);
            }
        }
        let vocab = self.model.vocab();
        for variant in variants {
            let idx: Vec<usize> = (0..n)
                .filter(|&i| sessions[i].req.variant == variant)
                .collect();
            if self.policy.max_retries > 0 {
                for &i in &idx {
                    let s = &mut *sessions[i];
                    s.last_good.clear();
                    s.last_good.extend_from_slice(&s.state);
                }
            }
            // the members still owed a healthy step, in admission order
            // (order is preserved across retries, so the panel layout
            // stays deterministic)
            let mut pending = idx;
            let mut attempt = 0u32;
            while !pending.is_empty() {
                let tokens: Vec<u32> = pending
                    .iter()
                    .map(|&i| *sessions[i].generated.last().expect("pending token committed"))
                    .collect();
                let outcome = {
                    let model = &mut self.model;
                    let batch_logits = &mut self.batch_logits;
                    let mut states: Vec<&mut Vec<f32>> = sessions
                        .iter_mut()
                        .enumerate()
                        .filter(|(i, _)| pending.contains(i))
                        .map(|(_, s)| &mut s.state)
                        .collect();
                    catch_unwind(AssertUnwindSafe(move || {
                        model.forward_batch(&mut states, &tokens, variant, batch_logits)
                    }))
                };
                let outcomes = match outcome {
                    Err(payload) => {
                        // a panic abandons the whole fused call: which
                        // states/panel slots were written is unknown, so
                        // every still-pending member rolls back together
                        self.faults.panics_caught += 1;
                        let msg = panic_message(payload);
                        for slot in 0..pending.len() {
                            let i = pending[slot];
                            // split the borrow: rollback_session needs
                            // &mut self and one session at a time
                            let s = &mut *sessions[i];
                            self.rollback_session(s);
                        }
                        if attempt >= self.policy.max_retries {
                            for &i in &pending {
                                self.record_fault(
                                    sessions[i].request_id,
                                    sessions[i].branch,
                                    FaultPhase::Decode,
                                    FaultKind::Panic,
                                    attempt,
                                    RecoveryAction::SessionFailed,
                                );
                                errors[i] = Some(SessionFault::Panicked(msg.clone()));
                            }
                            pending.clear();
                        } else {
                            attempt += 1;
                            let sleep = backoff_duration(self.policy.retry_backoff_ms, attempt);
                            // never sleep a member into its deadline:
                            // doomed ones finish DeadlineExceeded now,
                            // the rest keep their retry
                            pending.retain(|&i| {
                                if sleep_crosses_deadline(sleep, sessions[i].deadline_at) {
                                    self.record_fault(
                                        sessions[i].request_id,
                                        sessions[i].branch,
                                        FaultPhase::Decode,
                                        FaultKind::Panic,
                                        attempt,
                                        RecoveryAction::DeadlineAbandoned,
                                    );
                                    errors[i] = Some(SessionFault::DeadlineExceeded);
                                    false
                                } else {
                                    self.record_fault(
                                        sessions[i].request_id,
                                        sessions[i].branch,
                                        FaultPhase::Decode,
                                        FaultKind::Panic,
                                        attempt,
                                        RecoveryAction::Retried,
                                    );
                                    true
                                }
                            });
                            if !pending.is_empty() {
                                self.faults.retries += 1;
                                if !sleep.is_zero() {
                                    std::thread::sleep(sleep);
                                }
                            }
                        }
                        continue;
                    }
                    Ok(outcomes) => outcomes,
                };
                // defensive: a misbehaving override returning the wrong
                // outcome count or logits-panel size means the
                // result/session alignment is unknown — fail the whole
                // group rather than misassign logits
                if outcomes.len() != pending.len()
                    || self.batch_logits.len() != pending.len() * vocab
                {
                    let msg = anyhow!(
                        "forward_batch returned {} outcomes / {} logits for {} sessions",
                        outcomes.len(),
                        self.batch_logits.len(),
                        pending.len()
                    );
                    for &i in &pending {
                        errors[i] = Some(SessionFault::Error(anyhow!("{msg}")));
                    }
                    pending.clear();
                    continue;
                }
                let mut next_pending: Vec<usize> = Vec::new();
                let mut poisoned = false;
                let t_scatter = self.tracer.now_us();
                for (slot, outcome) in outcomes.into_iter().enumerate() {
                    let i = pending[slot];
                    match outcome {
                        // a model-returned error is deliberate: the
                        // member's state advanced exactly once (the
                        // forward_batch contract), no retry
                        Some(e) => {
                            self.record_fault(
                                sessions[i].request_id,
                                sessions[i].branch,
                                FaultPhase::Decode,
                                FaultKind::ModelError,
                                attempt,
                                RecoveryAction::SessionFailed,
                            );
                            errors[i] = Some(SessionFault::Error(e));
                        }
                        None => {
                            let healthy = {
                                let lg = &self.batch_logits[slot * vocab..(slot + 1) * vocab];
                                !self.policy.health_guards
                                    || (panel_all_finite(lg)
                                        && panel_all_finite(&sessions[i].state))
                            };
                            if healthy {
                                let s = &mut *sessions[i];
                                let lg = &self.batch_logits[slot * vocab..(slot + 1) * vocab];
                                s.next_token = s.sampler.sample(lg);
                            } else {
                                self.faults.numeric_faults += 1;
                                poisoned = true;
                                let s = &mut *sessions[i];
                                self.rollback_session(s);
                                next_pending.push(i);
                            }
                        }
                    }
                }
                scatter_us += self.tracer.now_us().saturating_sub(t_scatter);
                if poisoned {
                    self.quarantine_cache();
                }
                if next_pending.is_empty() {
                    pending.clear();
                } else if attempt >= self.policy.max_retries {
                    for &i in &next_pending {
                        self.record_fault(
                            sessions[i].request_id,
                            sessions[i].branch,
                            FaultPhase::Decode,
                            FaultKind::NonFinite,
                            attempt,
                            RecoveryAction::SessionFailed,
                        );
                        errors[i] = Some(SessionFault::Numeric);
                    }
                    pending.clear();
                } else {
                    pending = next_pending;
                    attempt += 1;
                    let sleep = backoff_duration(self.policy.retry_backoff_ms, attempt);
                    pending.retain(|&i| {
                        if sleep_crosses_deadline(sleep, sessions[i].deadline_at) {
                            self.record_fault(
                                sessions[i].request_id,
                                sessions[i].branch,
                                FaultPhase::Decode,
                                FaultKind::NonFinite,
                                attempt,
                                RecoveryAction::DeadlineAbandoned,
                            );
                            errors[i] = Some(SessionFault::DeadlineExceeded);
                            false
                        } else {
                            self.record_fault(
                                sessions[i].request_id,
                                sessions[i].branch,
                                FaultPhase::Decode,
                                FaultKind::NonFinite,
                                attempt,
                                RecoveryAction::Retried,
                            );
                            true
                        }
                    });
                    if !pending.is_empty() {
                        self.faults.retries += 1;
                        if !sleep.is_zero() {
                            std::thread::sleep(sleep);
                        }
                    }
                }
            }
        }
        let dt = t0.elapsed().as_secs_f64() / n as f64;
        for s in sessions.iter_mut() {
            s.decode_seconds += dt;
        }
        if self.tracer.enabled() {
            // split the cycle into two adjacent engine-track slices:
            // fused forward (everything that isn't sampling) + scatter
            let total = self.tracer.now_us().saturating_sub(trace_t0);
            let scatter = scatter_us.min(total);
            let forward = total - scatter;
            self.tracer.record(TraceEvent {
                ts_us: trace_t0,
                dur_us: forward,
                request_id: 0,
                branch: 0,
                cycle: self.cycle,
                kind: TraceEventKind::CyclePhase(CyclePhaseKind::DecodeForward),
            });
            self.tracer.record(TraceEvent {
                ts_us: trace_t0 + forward,
                dur_us: scatter,
                request_id: 0,
                branch: 0,
                cycle: self.cycle,
                kind: TraceEventKind::CyclePhase(CyclePhaseKind::SamplerScatter),
            });
        }
        errors
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::rwkv::testing::test_model;

    fn engine() -> Engine<RwkvModel> {
        Engine::new(test_model(2, 32, 64, 50))
    }

    #[test]
    fn generates_requested_token_count() {
        let mut e = engine();
        let req = GenRequest::greedy(vec![1, 2, 3], 10);
        let mut s = e.start(1, req, Instant::now()).unwrap();
        let mut finish = None;
        while finish.is_none() {
            finish = e.step_session(&mut s).unwrap();
        }
        assert_eq!(finish, Some(FinishReason::MaxTokens));
        assert_eq!(s.generated.len(), 10);
    }

    #[test]
    fn stop_token_halts_early() {
        let mut e = engine();
        // find what greedy generates first, then use it as the stop token
        let req = GenRequest::greedy(vec![1, 2, 3], 5);
        let mut s = e.start(1, req, Instant::now()).unwrap();
        let first = s.next_token;
        let mut req2 = GenRequest::greedy(vec![1, 2, 3], 50);
        req2.stop_token = Some(first);
        let mut s2 = e.start(2, req2, Instant::now()).unwrap();
        let mut finish = None;
        while finish.is_none() {
            finish = e.step_session(&mut s2).unwrap();
        }
        assert_eq!(finish, Some(FinishReason::StopToken));
        assert_eq!(s2.generated, vec![first]);
    }

    #[test]
    fn greedy_is_deterministic_across_sessions() {
        let mut e = engine();
        let gen = |e: &mut Engine<RwkvModel>| {
            let mut s = e.start(0, GenRequest::greedy(vec![4, 9], 12), Instant::now()).unwrap();
            while e.step_session(&mut s).unwrap().is_none() {}
            s.generated
        };
        assert_eq!(gen(&mut e), gen(&mut e));
    }

    #[test]
    fn chunked_prefill_ticks_match_start() {
        let mut a = engine();
        let mut b = engine();
        let req = GenRequest::greedy(vec![1, 2, 3, 4, 5, 6, 7], 6);
        let sa = a.start(1, req.clone(), Instant::now()).unwrap();
        let mut sb = b.admit(1, req, Instant::now());
        assert!(!sb.is_decoding());
        let mut ticks = 0;
        while !b.prefill_tick(&mut sb, 3).unwrap() {
            ticks += 1;
            assert!(ticks < 10, "prefill_tick failed to make progress");
        }
        assert!(sb.is_decoding());
        assert_eq!(sa.next_token, sb.next_token);
        assert_eq!(sa.state, sb.state);
        assert!(sb.ttft_seconds > 0.0);
        // further ticks are no-ops
        assert!(b.prefill_tick(&mut sb, 3).unwrap());
    }

    #[test]
    fn empty_prompt_uses_bos() {
        let mut e = engine();
        let mut s = e.start(0, GenRequest::greedy(vec![], 3), Instant::now()).unwrap();
        while e.step_session(&mut s).unwrap().is_none() {}
        assert_eq!(s.generated.len(), 3);
    }

    #[test]
    fn forward_batch_matches_forward_loop() {
        let mut a = test_model(2, 32, 64, 50);
        let mut b = test_model(2, 32, 64, 50);
        let mut states_a: Vec<Vec<f32>> = (0..3).map(|_| a.init_state()).collect();
        let mut states_b = states_a.clone();
        let tokens = [3u32, 7, 9];
        let loop_logits: Vec<Vec<f32>> = states_a
            .iter_mut()
            .zip(tokens)
            .map(|(s, t)| a.forward(s, t, Variant::Exact).unwrap())
            .collect();
        let batch_logits: Vec<Vec<f32>> = {
            let mut refs: Vec<&mut Vec<f32>> = states_b.iter_mut().collect();
            let mut flat = Vec::new();
            let outcomes = b.forward_batch(&mut refs, &tokens, Variant::Exact, &mut flat);
            assert!(outcomes.iter().all(|o| o.is_none()));
            assert_eq!(flat.len(), 3 * b.vocab);
            flat.chunks(b.vocab).map(|c| c.to_vec()).collect()
        };
        assert_eq!(loop_logits, batch_logits);
        assert_eq!(states_a, states_b);
    }

    #[test]
    fn default_forward_batch_fills_flat_panel() {
        // a model with no forward_batch override must produce the same
        // flat panel layout as the fused native override
        struct Plain(RwkvModel);
        impl EngineModel for Plain {
            fn vocab(&self) -> usize {
                self.0.vocab
            }
            fn state_len(&self) -> usize {
                EngineModel::state_len(&self.0)
            }
            fn init_state(&self) -> Vec<f32> {
                EngineModel::init_state(&self.0)
            }
            fn forward(
                &mut self,
                state: &mut Vec<f32>,
                token: u32,
                variant: Variant,
            ) -> Result<Vec<f32>> {
                self.0.forward(state, token, variant)
            }
        }
        let mut fused = test_model(2, 32, 64, 50);
        let mut plain = Plain(test_model(2, 32, 64, 50));
        let mut states_f: Vec<Vec<f32>> = (0..3).map(|_| fused.init_state()).collect();
        let mut states_p = states_f.clone();
        let tokens = [2u32, 11, 29];
        let (mut flat_f, mut flat_p) = (Vec::new(), Vec::new());
        {
            let mut refs: Vec<&mut Vec<f32>> = states_f.iter_mut().collect();
            fused.forward_batch(&mut refs, &tokens, Variant::Exact, &mut flat_f);
        }
        {
            let mut refs: Vec<&mut Vec<f32>> = states_p.iter_mut().collect();
            plain.forward_batch(&mut refs, &tokens, Variant::Exact, &mut flat_p);
        }
        assert_eq!(flat_f, flat_p);
        assert_eq!(states_f, states_p);
    }

    #[test]
    fn engine_model_surfaces_hw_clip_totals() {
        let calib: Vec<u32> = (0..64u32).map(|i| (i * 11 + 3) % 50).collect();
        let mut hw = HwModel::from_f32(test_model(2, 32, 64, 50), &calib);
        let mut st = EngineModel::init_state(&hw);
        hw.forward(&mut st, 3, Variant::Exact).unwrap();
        let c1 = hw.clip_events;
        hw.forward(&mut st, 5, Variant::Exact).unwrap();
        let c2 = hw.clip_events;
        // the trait drain reports the lossless cumulative total, then 0
        assert_eq!(EngineModel::take_clip_events(&mut hw), c1 + c2);
        assert_eq!(EngineModel::take_clip_events(&mut hw), 0);
        // non-hw models have nothing to report
        let mut plain = test_model(1, 16, 32, 20);
        assert_eq!(EngineModel::take_clip_events(&mut plain), 0);
    }

    #[test]
    fn weight_stream_bytes_packed_is_half_of_exact() {
        let calib: Vec<u32> = (0..64u32).map(|i| (i * 11 + 3) % 50).collect();
        let base = test_model(2, 32, 64, 50);
        let weights = 2 * (5 * 32 * 32 + 2 * 32 * 64) + 50 * 32;
        let exact_bytes = EngineModel::weight_stream_bytes(&base);
        assert_eq!(exact_bytes, weights as u64 * 4);
        // hw decodes to f32, so it streams exactly the exact backend's bytes
        let hw = HwModel::from_f32(base.clone(), &calib);
        assert_eq!(EngineModel::weight_stream_bytes(&hw), exact_bytes);
        // packed streams the 2-byte words: half
        let pk = PackedModel::from_f32(base, &calib);
        assert_eq!(EngineModel::weight_stream_bytes(&pk), exact_bytes / 2);
    }

    #[test]
    fn backend_model_serves_like_its_direct_backend() {
        let calib: Vec<u32> = (0..64u32).map(|i| (i * 11 + 3) % 50).collect();
        let base = test_model(2, 32, 64, 50);
        let run = |model: BackendModel| {
            let mut e = Engine::new(model);
            let mut s = e.start(0, GenRequest::greedy(vec![3, 1, 4], 8), Instant::now()).unwrap();
            while e.step_session(&mut s).unwrap().is_none() {}
            s.generated
        };
        let direct_hw = {
            let mut e = Engine::new(HwModel::from_f32(base.clone(), &calib));
            let mut s = e.start(0, GenRequest::greedy(vec![3, 1, 4], 8), Instant::now()).unwrap();
            while e.step_session(&mut s).unwrap().is_none() {}
            s.generated
        };
        let hw = run(BackendModel::build(base.clone(), Backend::Hw, &calib));
        assert_eq!(hw, direct_hw);
        // packed is bit-identical to hw, so the served tokens match too
        let packed = run(BackendModel::build(base.clone(), Backend::Packed, &calib));
        assert_eq!(packed, direct_hw, "packed backend tokens diverged from hw");
        let exact = run(BackendModel::build(base, Backend::Exact, &calib));
        assert_eq!(exact.len(), 8);
    }

    #[test]
    fn prefill_rejects_empty_prompt() {
        let mut m = test_model(1, 32, 64, 50);
        let mut state = m.init_state();
        assert!(m.prefill(&mut state, &[], Variant::Exact).is_err());
    }

    #[test]
    fn engine_step_batch_equals_step_session() {
        // two engines over the same model: one driven per session, one
        // through commit_pending + step_batch — identical tokens
        let mut per = engine();
        let mut bat = engine();
        let reqs = [
            GenRequest::greedy(vec![1, 2, 3], 9),
            GenRequest::greedy(vec![4], 9),
            GenRequest::greedy(vec![5, 6], 9),
        ];
        let mut ps: Vec<ActiveSession> = reqs
            .iter()
            .enumerate()
            .map(|(i, r)| per.start(i as u64, r.clone(), Instant::now()).unwrap())
            .collect();
        let mut bs: Vec<ActiveSession> = reqs
            .iter()
            .enumerate()
            .map(|(i, r)| bat.start(i as u64, r.clone(), Instant::now()).unwrap())
            .collect();
        // per-session path
        for s in ps.iter_mut() {
            while per.step_session(s).unwrap().is_none() {}
        }
        // batched path
        let mut done = vec![false; bs.len()];
        loop {
            let mut live: Vec<&mut ActiveSession> = Vec::new();
            for (s, d) in bs.iter_mut().zip(done.iter_mut()) {
                if *d {
                    continue;
                }
                match bat.commit_pending(s) {
                    Some(_) => *d = true,
                    None => live.push(s),
                }
            }
            if live.is_empty() {
                break;
            }
            let errs = bat.step_batch(&mut live);
            assert!(errs.iter().all(|e| e.is_none()));
        }
        for (p, b) in ps.iter().zip(&bs) {
            assert_eq!(p.generated, b.generated);
        }
    }

    #[test]
    fn cached_resume_matches_cold_prefill_bitexact() {
        // second session with the same prompt resumes from the deepest
        // chunk-boundary snapshot and must land on the identical state
        let mut cold = engine();
        let mut warm = Engine::with_cache(
            test_model(2, 32, 64, 50),
            crate::statecache::StateCacheConfig::default(),
        );
        let prompt: Vec<u32> = (0..17u32).map(|t| (t * 3 + 1) % 50).collect();
        let req = GenRequest::greedy(prompt, 5);

        let sc = cold.start(1, req.clone(), Instant::now()).unwrap();

        // first warm session populates boundaries at 4, 8, 12, 16, 17
        let mut s1 = warm.admit(1, req.clone(), Instant::now());
        assert_eq!(s1.cached_prefix_tokens, 0, "cold cache cannot hit");
        while !warm.prefill_tick(&mut s1, 4).unwrap() {}
        assert_eq!(s1.next_token, sc.next_token);
        assert_eq!(s1.state, sc.state);

        // second warm session resumes at 16 (the deepest boundary ≤ 16)
        let mut s2 = warm.admit(2, req.clone(), Instant::now());
        assert_eq!(s2.cached_prefix_tokens, 16);
        assert!(s2.snapshot_pin.is_some(), "resumed session must pin its snapshot");
        while !warm.prefill_tick(&mut s2, 4).unwrap() {}
        assert!(s2.snapshot_pin.is_none(), "pin must release when prefill completes");
        assert_eq!(s2.next_token, sc.next_token);
        assert_eq!(s2.state, sc.state);

        let stats = warm.cache_stats().unwrap();
        assert_eq!(stats.hits, 1);
        assert_eq!(stats.tokens_skipped, 16);
        assert!(stats.inserts >= 5);
    }

    #[test]
    fn cache_disabled_engine_reports_no_stats() {
        let mut e = engine();
        assert!(e.cache_stats().is_none());
        let s = e.start(1, GenRequest::greedy(vec![1, 2, 3], 2), Instant::now()).unwrap();
        assert_eq!(s.cached_prefix_tokens, 0);
        assert!(s.snapshot_pin.is_none());
    }

    #[test]
    fn single_token_prompts_never_hit() {
        // a 1-token prompt caps the lookup at depth 0 — always a miss,
        // and the post-prefill snapshot (depth 1) must not break that
        let mut e = Engine::with_cache(
            test_model(2, 32, 64, 50),
            crate::statecache::StateCacheConfig::default(),
        );
        for _ in 0..2 {
            let s = e.start(1, GenRequest::greedy(vec![7], 2), Instant::now()).unwrap();
            assert_eq!(s.cached_prefix_tokens, 0);
        }
        let stats = e.cache_stats().unwrap();
        assert_eq!(stats.hits, 0);
        assert_eq!(stats.misses, 2);
    }

    #[test]
    fn fork_branches_match_sequential_seeded_runs_bitexact() {
        // THE fork invariant: branch b of one n_best=N request must be
        // bit-identical (tokens AND final state, 0 ULP) to a sequential
        // single-session run of the same request with seed `seed + b`
        let mut e = engine();
        let prompt: Vec<u32> = (0..24u32).map(|t| (t * 7 + 3) % 50).collect();
        let n = 4;
        let mk = |seed: u64, n_best: usize| {
            GenRequest::builder(prompt.clone(), 8)
                .temperature(0.9)
                .top_k(12)
                .seed(seed)
                .n_best(n_best)
                .build()
        };
        let mut solo = Vec::new();
        for b in 0..n as u64 {
            let mut s = e.start(b, mk(40 + b, 1), Instant::now()).unwrap();
            while e.step_session(&mut s).unwrap().is_none() {}
            solo.push(s);
        }
        let mut parent = e.admit(9, mk(40, n), Instant::now());
        while !e.prefill_tick(&mut parent, 5).unwrap() {}
        assert!(parent.is_fork_ready(), "n_best > 1 must end prefill ForkReady");
        let mut branches = e.fork(parent);
        assert_eq!(branches.len(), n);
        for (b, s) in branches.iter_mut().enumerate() {
            assert_eq!(s.branch, b);
            assert!(s.snapshot_pin.is_some(), "branches share the pinned snapshot");
            while e.step_session(s).unwrap().is_none() {}
        }
        for (b, (br, so)) in branches.iter().zip(&solo).enumerate() {
            assert_eq!(br.generated, so.generated, "branch {b}: tokens diverged");
            assert_eq!(br.state, so.state, "branch {b}: state diverged (0 ULP)");
        }
    }

    #[test]
    fn fork_branches_match_sequential_seeded_runs_hw() {
        // same invariant on the hardware-numerics backend
        let calib: Vec<u32> = (0..64u32).map(|i| (i * 11 + 3) % 50).collect();
        let mut e = Engine::new(HwModel::from_f32(test_model(2, 32, 64, 50), &calib));
        let prompt: Vec<u32> = (0..16u32).map(|t| (t * 13 + 2) % 50).collect();
        let n = 3;
        let mk = |seed: u64, n_best: usize| {
            GenRequest::builder(prompt.clone(), 6)
                .temperature(0.8)
                .top_k(10)
                .seed(seed)
                .n_best(n_best)
                .build()
        };
        let mut solo = Vec::new();
        for b in 0..n as u64 {
            let mut s = e.start(b, mk(7 + b, 1), Instant::now()).unwrap();
            while e.step_session(&mut s).unwrap().is_none() {}
            solo.push(s);
        }
        let mut parent = e.admit(9, mk(7, n), Instant::now());
        while !e.prefill_tick(&mut parent, 4).unwrap() {}
        let mut branches = e.fork(parent);
        for s in branches.iter_mut() {
            while e.step_session(s).unwrap().is_none() {}
        }
        for (b, (br, so)) in branches.iter().zip(&solo).enumerate() {
            assert_eq!(br.generated, so.generated, "hw branch {b}: tokens diverged");
            assert_eq!(br.state, so.state, "hw branch {b}: state diverged (0 ULP)");
        }
    }

    #[test]
    fn fork_decode_namespace_skips_repeat_prefill() {
        // a second identical fork request admits straight to ForkReady
        // off the cached decode-state snapshot (zero prefill work), and
        // its branches start bit-identical to the first fork's
        let mut e = Engine::with_cache(
            test_model(2, 32, 64, 50),
            crate::statecache::StateCacheConfig::default(),
        );
        let prompt: Vec<u32> = (0..20u32).map(|t| (t * 3 + 1) % 50).collect();
        let req = GenRequest::builder(prompt.clone(), 4)
            .temperature(0.7)
            .top_k(8)
            .seed(11)
            .n_best(2)
            .build();
        let mut p1 = e.admit(1, req.clone(), Instant::now());
        assert_eq!(p1.cached_prefix_tokens, 0);
        while !e.prefill_tick(&mut p1, 4).unwrap() {}
        let work_after_first = e.prefilled_tokens();
        assert_eq!(work_after_first, prompt.len() as u64);
        let b1 = e.fork(p1);
        // branches pin the adopted decode-state entry
        let stats = e.cache_stats().unwrap();
        assert!(stats.pinned >= 1, "fork branches must pin the decode entry: {stats:?}");

        let p2 = e.admit(2, req, Instant::now());
        assert!(p2.is_fork_ready(), "decode-ns hit must skip prefill entirely");
        assert_eq!(p2.cached_prefix_tokens, prompt.len());
        assert_eq!(e.prefilled_tokens(), work_after_first, "repeat fork did prefill work");
        let b2 = e.fork(p2);
        for (x, y) in b1.iter().zip(&b2) {
            assert_eq!(x.next_token, y.next_token);
            assert_eq!(x.state, y.state);
        }
        // all pins dropped: the decode entry becomes evictable again
        drop(b1);
        drop(b2);
        assert_eq!(e.cache_stats().unwrap().pinned, 0);
    }

    /// Minimal inline fault injection for the guard tests: panics or
    /// poisons the logits on scheduled 1-based `forward` call indices.
    /// (The full deterministic harness is `crate::chaos`; this stays
    /// here so the engine tests don't depend on it.)
    struct Flaky {
        inner: RwkvModel,
        calls: u64,
        panic_on: Vec<u64>,
        nan_on: Vec<u64>,
    }

    impl EngineModel for Flaky {
        fn vocab(&self) -> usize {
            self.inner.vocab
        }
        fn state_len(&self) -> usize {
            EngineModel::state_len(&self.inner)
        }
        fn init_state(&self) -> Vec<f32> {
            EngineModel::init_state(&self.inner)
        }
        fn forward(
            &mut self,
            state: &mut Vec<f32>,
            token: u32,
            variant: Variant,
        ) -> Result<Vec<f32>> {
            self.calls += 1;
            let n = self.calls;
            // fault AFTER the real forward, so the state has genuinely
            // advanced — rollback is what must undo it
            let mut logits = self.inner.forward(state, token, variant)?;
            if self.panic_on.contains(&n) {
                panic!("injected panic at call {n}");
            }
            if self.nan_on.contains(&n) {
                logits[0] = f32::NAN;
            }
            Ok(logits)
        }
    }

    #[test]
    fn prefill_panic_rolls_back_and_retries_bitexact() {
        let mut clean = engine();
        let req = GenRequest::greedy(vec![1, 2, 3, 4, 5, 6], 4);
        let sc = clean.start(1, req.clone(), Instant::now()).unwrap();

        // panic at forward call 3 = mid-chunk, with 2 tokens already
        // folded into the state — the retry must replay from the chunk
        // boundary and land bit-identically with the fault-free run
        let mut e = Engine::new(Flaky {
            inner: test_model(2, 32, 64, 50),
            calls: 0,
            panic_on: vec![3],
            nan_on: vec![],
        });
        e.set_fault_policy(FaultPolicy { retry_backoff_ms: 0, ..FaultPolicy::default() });
        let mut s = e.admit(1, req, Instant::now());
        while !e.prefill_tick(&mut s, 4).unwrap() {}
        assert_eq!(s.next_token, sc.next_token);
        assert_eq!(s.state, sc.state, "retried prefill must be 0 ULP with fault-free");
        let f = e.fault_stats();
        assert_eq!((f.panics_caught, f.retries, f.rollbacks), (1, 1, 1));
    }

    #[test]
    fn retry_backoff_never_sleeps_into_the_deadline() {
        // the deadline-blind-backoff bugfix: a persistent fault with a
        // 200ms backoff base and a 20ms deadline must abandon the retry
        // chain immediately instead of burning >2s of exponential sleeps
        let mut e = Engine::new(Flaky {
            inner: test_model(2, 32, 64, 50),
            calls: 0,
            panic_on: (1..=40).collect(),
            nan_on: vec![],
        });
        e.set_fault_policy(FaultPolicy {
            health_guards: true,
            max_retries: 12,
            retry_backoff_ms: 200,
        });
        let mut req = GenRequest::greedy(vec![1, 2, 3], 4);
        req.deadline = Some(Duration::from_millis(20));
        let t0 = Instant::now();
        let mut s = e.admit(1, req, Instant::now());
        let err = e.prefill_tick(&mut s, 8).unwrap_err();
        assert!(matches!(err, SessionFault::DeadlineExceeded), "got {err}");
        assert!(t0.elapsed() < Duration::from_millis(500), "slept into the deadline");
        let journal = e.journal();
        let events = journal.lock().unwrap().snapshot();
        assert!(
            events.iter().any(|ev| ev.request_id == 1
                && ev.kind == FaultKind::Panic
                && ev.action == RecoveryAction::DeadlineAbandoned),
            "the abandoned retry must be journalled: {events:?}"
        );
    }

    #[test]
    fn redriven_session_continues_bitexact_after_simulated_crash() {
        // fault-free reference at a sampling temperature (the RNG-draw
        // accounting is what redrive must reproduce)
        let mut clean = engine();
        let req = GenRequest::builder(vec![5, 9, 13], 10)
            .temperature(0.9)
            .top_k(12)
            .seed(21)
            .build();
        let mut c = clean.start(1, req.clone(), Instant::now()).unwrap();
        while clean.step_session(&mut c).unwrap().is_none() {}
        assert_eq!(c.generated.len(), 10);
        // crash after 4 committed tokens: rebuild the session the way
        // the supervisor does — prompt extended by the committed prefix,
        // then resume_redriven to re-seed generated/sampler
        let k = 4;
        let mut redo = req.clone();
        redo.prompt.extend_from_slice(&c.generated[..k]);
        let mut e = engine();
        let mut s = e.admit(1, redo, Instant::now());
        e.resume_redriven(&mut s, 0, 1, req.prompt.len(), Instant::now());
        assert_eq!(s.generated, c.generated[..k].to_vec());
        while !e.prefill_tick(&mut s, 4).unwrap() {}
        while e.step_session(&mut s).unwrap().is_none() {}
        assert_eq!(s.generated, c.generated, "redriven continuation must be bit-exact");
        assert_eq!(s.state, c.state, "post-run state must be 0 ULP too");
        assert_eq!(s.redrive_attempt, 1);
        assert!(s.redriven_at.is_some());
    }

    #[test]
    fn decode_nan_isolates_the_poisoned_session() {
        // fail-fast policy: the poisoned member faults Numeric, its
        // batchmate advances bit-exactly with a solo fault-free run
        let mut clean = engine();
        let rb = GenRequest::greedy(vec![4], 3);
        let mut cb = clean.start(1, rb.clone(), Instant::now()).unwrap();
        clean.step_session(&mut cb).unwrap();

        let mut e = Engine::new(Flaky {
            inner: test_model(2, 32, 64, 50),
            calls: 0,
            panic_on: vec![],
            // calls 1-3 prefill A, call 4 prefills B, call 5 = A's first
            // decode step in the batch loop
            nan_on: vec![5],
        });
        e.set_fault_policy(FaultPolicy {
            health_guards: true,
            max_retries: 0,
            retry_backoff_ms: 0,
        });
        let mut sa = e.start(1, GenRequest::greedy(vec![1, 2, 3], 3), Instant::now()).unwrap();
        let mut sb = e.start(2, rb, Instant::now()).unwrap();
        assert!(e.commit_pending(&mut sa).is_none());
        assert!(e.commit_pending(&mut sb).is_none());
        let errs = {
            let mut refs = vec![&mut sa, &mut sb];
            e.step_batch(&mut refs)
        };
        assert!(matches!(errs[0], Some(SessionFault::Numeric)), "got {:?}", errs[0]);
        assert!(errs[1].is_none());
        assert_eq!(sb.next_token, cb.next_token);
        assert_eq!(sb.state, cb.state, "healthy batchmate must be 0 ULP with solo run");
        assert_eq!(e.fault_stats().numeric_faults, 1);
    }

    #[test]
    fn interleaved_equals_sequential() {
        // THE state-isolation invariant: driving two sessions
        // alternately must produce exactly what driving them one after
        // the other produces.
        let mut e = engine();
        let ra = GenRequest::greedy(vec![3, 1, 4], 8);
        let rb = GenRequest::greedy(vec![2, 7], 8);

        // sequential
        let mut sa = e.start(1, ra.clone(), Instant::now()).unwrap();
        while e.step_session(&mut sa).unwrap().is_none() {}
        let mut sb = e.start(2, rb.clone(), Instant::now()).unwrap();
        while e.step_session(&mut sb).unwrap().is_none() {}

        // interleaved
        let mut ia = e.start(3, ra, Instant::now()).unwrap();
        let mut ib = e.start(4, rb, Instant::now()).unwrap();
        let (mut da, mut db) = (false, false);
        while !(da && db) {
            if !da {
                da = e.step_session(&mut ia).unwrap().is_some();
            }
            if !db {
                db = e.step_session(&mut ib).unwrap().is_some();
            }
        }
        assert_eq!(sa.generated, ia.generated);
        assert_eq!(sb.generated, ib.generated);
    }
}
