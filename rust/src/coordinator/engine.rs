//! Generation engine: prefill + decode over either the PJRT runtime or
//! the native Rust forwards (the engine is generic so every model path —
//! exact PJRT, hwapprox PJRT, native f32, native hardware-numerics —
//! serves through the same coordinator).

use std::time::Instant;

use anyhow::Result;

use super::{FinishReason, GenRequest};
use crate::model::sampler::Sampler;
use crate::model::{HwModel, RwkvModel, State};
use crate::runtime::{RwkvRuntime, Variant};

/// Anything that can run RWKV one token at a time with explicit state.
pub trait EngineModel {
    fn vocab(&self) -> usize;
    fn state_len(&self) -> usize;
    fn init_state(&self) -> Vec<f32>;
    /// One step; returns logits and mutates `state` in place.
    fn forward(&mut self, state: &mut Vec<f32>, token: u32, variant: Variant) -> Result<Vec<f32>>;
    /// Optional bulk prefill; default = token-by-token.
    fn prefill(&mut self, state: &mut Vec<f32>, tokens: &[u32], variant: Variant) -> Result<Vec<f32>> {
        let mut logits = Vec::new();
        for &t in tokens {
            logits = self.forward(state, t, variant)?;
        }
        Ok(logits)
    }
}

impl EngineModel for RwkvRuntime {
    fn vocab(&self) -> usize {
        self.manifest.vocab
    }

    fn state_len(&self) -> usize {
        self.manifest.state_len()
    }

    fn init_state(&self) -> Vec<f32> {
        RwkvRuntime::init_state(self)
    }

    fn forward(&mut self, state: &mut Vec<f32>, token: u32, variant: Variant) -> Result<Vec<f32>> {
        let out = self.step(variant, state, token)?;
        *state = out.state;
        Ok(out.logits)
    }

    fn prefill(&mut self, state: &mut Vec<f32>, tokens: &[u32], variant: Variant) -> Result<Vec<f32>> {
        // chunk through the scan executable (exact variant only — the hw
        // artifact has no seq build), then finish with single steps
        let chunk = self.manifest.seq_chunk;
        let vocab = self.manifest.vocab;
        let mut last_logits = Vec::new();
        let mut i = 0;
        if variant == Variant::Exact {
            while tokens.len() - i >= chunk {
                let (logits_flat, new_state) = self.seq_chunk(state, &tokens[i..i + chunk])?;
                *state = new_state;
                last_logits = logits_flat[(chunk - 1) * vocab..].to_vec();
                i += chunk;
            }
        }
        for &t in &tokens[i..] {
            last_logits = self.forward(state, t, variant)?;
        }
        Ok(last_logits)
    }
}

impl EngineModel for RwkvModel {
    fn vocab(&self) -> usize {
        self.vocab
    }

    fn state_len(&self) -> usize {
        self.n_layer * 5 * self.d
    }

    fn init_state(&self) -> Vec<f32> {
        self.new_state().data
    }

    fn forward(&mut self, state: &mut Vec<f32>, token: u32, _variant: Variant) -> Result<Vec<f32>> {
        let mut st = State { data: std::mem::take(state), n_layer: self.n_layer, d: self.d };
        let logits = self.step(&mut st, token);
        *state = st.data;
        Ok(logits)
    }
}

impl EngineModel for HwModel {
    fn vocab(&self) -> usize {
        HwModel::vocab(self)
    }

    fn state_len(&self) -> usize {
        let s = self.new_state();
        s.n_layer * 5 * s.d
    }

    fn init_state(&self) -> Vec<f32> {
        self.new_state().data
    }

    fn forward(&mut self, state: &mut Vec<f32>, token: u32, _variant: Variant) -> Result<Vec<f32>> {
        let proto = self.new_state();
        let mut st = State { data: std::mem::take(state), n_layer: proto.n_layer, d: proto.d };
        let logits = self.step(&mut st, token);
        *state = st.data;
        Ok(logits)
    }
}

/// One in-flight generation (the session): prompt consumed, state held,
/// decode in progress.
pub struct ActiveSession {
    pub request_id: u64,
    pub req: GenRequest,
    pub state: Vec<f32>,
    pub generated: Vec<u32>,
    pub sampler: Sampler,
    pub next_token: u32,
    pub prefill_seconds: f64,
    pub decode_seconds: f64,
    pub enqueued_at: Instant,
    pub started_at: Instant,
}

/// The engine drives sessions over any [`EngineModel`].
pub struct Engine<M: EngineModel> {
    pub model: M,
}

impl<M: EngineModel> Engine<M> {
    pub fn new(model: M) -> Engine<M> {
        Engine { model }
    }

    /// Admit a request: run prefill, sample the first token.
    pub fn start(&mut self, request_id: u64, req: GenRequest, enqueued_at: Instant) -> Result<ActiveSession> {
        let t0 = Instant::now();
        let mut state = self.model.init_state();
        let mut sampler = Sampler::new(req.temperature, req.top_k, req.seed);
        let prompt = if req.prompt.is_empty() { vec![crate::model::tokenizer::BOS] } else { req.prompt.clone() };
        let logits = self.model.prefill(&mut state, &prompt, req.variant)?;
        let next_token = sampler.sample(&logits);
        Ok(ActiveSession {
            request_id,
            req,
            state,
            generated: Vec::new(),
            sampler,
            next_token,
            prefill_seconds: t0.elapsed().as_secs_f64(),
            decode_seconds: 0.0,
            enqueued_at,
            started_at: t0,
        })
    }

    /// One decode step for a session; returns Some(reason) when done.
    pub fn step_session(&mut self, s: &mut ActiveSession) -> Result<Option<FinishReason>> {
        let t0 = Instant::now();
        let tok = s.next_token;
        s.generated.push(tok);
        if s.req.stop_token == Some(tok) {
            s.decode_seconds += t0.elapsed().as_secs_f64();
            return Ok(Some(FinishReason::StopToken));
        }
        if s.generated.len() >= s.req.max_new_tokens {
            s.decode_seconds += t0.elapsed().as_secs_f64();
            return Ok(Some(FinishReason::MaxTokens));
        }
        let logits = self.model.forward(&mut s.state, tok, s.req.variant)?;
        s.next_token = s.sampler.sample(&logits);
        s.decode_seconds += t0.elapsed().as_secs_f64();
        Ok(None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::rwkv::testing::test_model;

    fn engine() -> Engine<RwkvModel> {
        Engine::new(test_model(2, 32, 64, 50))
    }

    #[test]
    fn generates_requested_token_count() {
        let mut e = engine();
        let req = GenRequest::greedy(vec![1, 2, 3], 10);
        let mut s = e.start(1, req, Instant::now()).unwrap();
        let mut finish = None;
        while finish.is_none() {
            finish = e.step_session(&mut s).unwrap();
        }
        assert_eq!(finish, Some(FinishReason::MaxTokens));
        assert_eq!(s.generated.len(), 10);
    }

    #[test]
    fn stop_token_halts_early() {
        let mut e = engine();
        // find what greedy generates first, then use it as the stop token
        let req = GenRequest::greedy(vec![1, 2, 3], 5);
        let mut s = e.start(1, req, Instant::now()).unwrap();
        let first = s.next_token;
        let mut req2 = GenRequest::greedy(vec![1, 2, 3], 50);
        req2.stop_token = Some(first);
        let mut s2 = e.start(2, req2, Instant::now()).unwrap();
        let mut finish = None;
        while finish.is_none() {
            finish = e.step_session(&mut s2).unwrap();
        }
        assert_eq!(finish, Some(FinishReason::StopToken));
        assert_eq!(s2.generated, vec![first]);
    }

    #[test]
    fn greedy_is_deterministic_across_sessions() {
        let mut e = engine();
        let gen = |e: &mut Engine<RwkvModel>| {
            let mut s = e.start(0, GenRequest::greedy(vec![4, 9], 12), Instant::now()).unwrap();
            while e.step_session(&mut s).unwrap().is_none() {}
            s.generated
        };
        assert_eq!(gen(&mut e), gen(&mut e));
    }

    #[test]
    fn empty_prompt_uses_bos() {
        let mut e = engine();
        let mut s = e.start(0, GenRequest::greedy(vec![], 3), Instant::now()).unwrap();
        while e.step_session(&mut s).unwrap().is_none() {}
        assert_eq!(s.generated.len(), 3);
    }

    #[test]
    fn interleaved_equals_sequential() {
        // THE state-isolation invariant: driving two sessions
        // alternately must produce exactly what driving them one after
        // the other produces.
        let mut e = engine();
        let ra = GenRequest::greedy(vec![3, 1, 4], 8);
        let rb = GenRequest::greedy(vec![2, 7], 8);

        // sequential
        let mut sa = e.start(1, ra.clone(), Instant::now()).unwrap();
        while e.step_session(&mut sa).unwrap().is_none() {}
        let mut sb = e.start(2, rb.clone(), Instant::now()).unwrap();
        while e.step_session(&mut sb).unwrap().is_none() {}

        // interleaved
        let mut ia = e.start(3, ra, Instant::now()).unwrap();
        let mut ib = e.start(4, rb, Instant::now()).unwrap();
        let (mut da, mut db) = (false, false);
        while !(da && db) {
            if !da {
                da = e.step_session(&mut ia).unwrap().is_some();
            }
            if !db {
                db = e.step_session(&mut ib).unwrap().is_some();
            }
        }
        assert_eq!(sa.generated, ia.generated);
        assert_eq!(sb.generated, ib.generated);
    }
}
