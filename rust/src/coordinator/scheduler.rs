//! Admission queue + prefill/decode-interleaved continuous batching +
//! worker thread.
//!
//! One worker thread owns the engine (and therefore the PJRT client)
//! exclusively.  Each scheduling cycle it
//!
//! 1. **admits** queued requests up to `max_active` — admission is
//!    bookkeeping plus a prefix-cache lookup (no forward work), so a
//!    request with a huge prompt enters the table instantly, and a
//!    request whose prompt prefix is cached ([`crate::statecache`])
//!    starts prefill at the deepest cached chunk boundary instead of
//!    token 0 — for a shared 1k-token system prompt that collapses
//!    prefill to the unique suffix;
//! 2. **prefills**: every `Prefilling` session consumes at most
//!    `prefill_chunk` prompt tokens via ONE sequence-parallel
//!    [`Engine::prefill_tick`] (one matmul per weight matrix over the
//!    whole chunk, §Perf L3-4), capturing a state snapshot at the chunk
//!    boundary for future prefix reuse.  Bounding the chunk bounds the
//!    cycle time, so a 1k-token prompt spreads over ~`len/chunk` cycles
//!    instead of head-of-line-blocking every decoding session (asserted
//!    by `long_prompt_does_not_stall_decoders` in
//!    `rust/tests/prefill_parity.rs`);
//! 3. **decodes**: advances every `Decoding` session by exactly one
//!    step in admission order — round-robin fairness, no starvation —
//!    via a single fused [`Engine::step_batch`] forward that reuses
//!    each weight matrix across all active sessions (§Perf L3-3);
//! 4. **completes** finished sessions, recording per-session
//!    time-to-first-token into [`Metrics`] — after draining the model's
//!    cumulative 9-bit clip counter and mirroring the prefix-cache
//!    counters into [`Metrics`] (hit rate, tokens skipped, bytes
//!    resident, evictions — the serve report's cache line).
//!
//! Chunked and token-by-token prefill are bit-exact for the native
//! models, as are batched and per-session decode and cached-prefix
//! resume (the cached state IS the state full prefill passes through),
//! so neither scheduling capacity, chunk size nor cache state ever
//! changes a session's tokens (asserted by
//! `prop_interleaving_preserves_outputs` and the parity suites in
//! `rust/tests/`, cache-specifically in `rust/tests/statecache.rs`).

use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Result};

use super::engine::{ActiveSession, Engine, EngineModel};
use super::metrics::Metrics;
use super::{FinishReason, GenRequest, GenResponse};
use crate::statecache::StateCacheConfig;

#[derive(Clone, Copy, Debug)]
pub struct CoordinatorConfig {
    /// maximum concurrently-active sessions (prefilling + decoding)
    pub max_active: usize,
    /// maximum prompt tokens a `Prefilling` session consumes per
    /// scheduling cycle; bounds how long one cycle can stall decode.
    /// 32–128 is the useful range: big enough to amortize each weight
    /// matrix over many tokens, small enough to keep decode latency flat.
    /// Also the granularity of prefix-cache snapshots: every chunk
    /// boundary is a resumable state.
    pub prefill_chunk: usize,
    /// byte budget for the prefix-sharing state cache
    /// ([`crate::statecache`]); 0 disables caching entirely.  Resuming
    /// is bit-exact, so this only trades memory for prefill latency.
    pub state_cache_bytes: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            max_active: 8,
            prefill_chunk: 64,
            state_cache_bytes: StateCacheConfig::default().max_bytes,
        }
    }
}

struct Job {
    id: u64,
    req: GenRequest,
    enqueued_at: Instant,
    reply: Sender<Result<GenResponse>>,
}

/// Handle to a running coordinator.  Cloneable; `generate` is blocking,
/// `submit` is async-style (returns a receiver).
pub struct Coordinator {
    tx: Sender<Job>,
    next_id: std::sync::atomic::AtomicU64,
    pub metrics: Arc<Mutex<Metrics>>,
    worker: Option<JoinHandle<()>>,
}

impl Coordinator {
    /// Spawn the worker thread around an engine model.
    pub fn spawn<M: EngineModel + Send + 'static>(model: M, cfg: CoordinatorConfig) -> Coordinator {
        Self::spawn_with(move || model, cfg)
    }

    /// Spawn with a factory executed *inside* the worker thread — required
    /// for models that are not `Send` (the PJRT runtime holds `Rc`s and
    /// raw pointers; constructing it on the owning thread sidesteps any
    /// cross-thread transfer).
    pub fn spawn_with<M, F>(factory: F, cfg: CoordinatorConfig) -> Coordinator
    where
        M: EngineModel + 'static,
        F: FnOnce() -> M + Send + 'static,
    {
        let (tx, rx) = channel::<Job>();
        let metrics = Arc::new(Mutex::new(Metrics::default()));
        let m2 = metrics.clone();
        let worker = std::thread::spawn(move || {
            let engine = if cfg.state_cache_bytes > 0 {
                Engine::with_cache(factory(), StateCacheConfig { max_bytes: cfg.state_cache_bytes })
            } else {
                Engine::new(factory())
            };
            worker_loop(engine, rx, cfg, m2)
        });
        Coordinator {
            tx,
            next_id: std::sync::atomic::AtomicU64::new(1),
            metrics,
            worker: Some(worker),
        }
    }

    /// Submit a request; returns a receiver for the response.
    pub fn submit(&self, req: GenRequest) -> Receiver<Result<GenResponse>> {
        let (reply, rx) = channel();
        let id = self.next_id.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        self.metrics.lock().unwrap().enqueued += 1;
        let job = Job { id, req, enqueued_at: Instant::now(), reply };
        // if the worker is gone the receiver will simply disconnect
        let _ = self.tx.send(job);
        rx
    }

    /// Blocking generate.
    pub fn generate(&self, req: GenRequest) -> Result<GenResponse> {
        self.submit(req)
            .recv()
            .map_err(|_| anyhow!("coordinator worker terminated"))?
    }

    /// Graceful shutdown: drop the queue and join the worker.
    pub fn shutdown(mut self) {
        drop(self.tx.clone());
        // dropping self.tx happens in Drop; explicitly take the worker
        if let Some(w) = self.worker.take() {
            // close the channel by replacing tx with a dead one
            let (dead, _) = channel();
            self.tx = dead;
            let _ = w.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        // closing tx ends the worker loop once the queue drains
        let (dead, _) = channel();
        self.tx = dead;
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn worker_loop<M: EngineModel>(
    mut engine: Engine<M>,
    rx: Receiver<Job>,
    cfg: CoordinatorConfig,
    metrics: Arc<Mutex<Metrics>>,
) {
    let mut active: Vec<(ActiveSession, Sender<Result<GenResponse>>)> = Vec::new();
    let mut queue: std::collections::VecDeque<Job> = Default::default();
    loop {
        // 1. pull everything currently queued (block only when idle)
        loop {
            match rx.try_recv() {
                Ok(job) => queue.push_back(job),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    if active.is_empty() && queue.is_empty() {
                        return;
                    }
                    break;
                }
            }
        }
        if active.is_empty() && queue.is_empty() {
            // idle: block for the next job (or shut down)
            match rx.recv() {
                Ok(job) => queue.push_back(job),
                Err(_) => return,
            }
        }

        // 2. admit in FIFO order up to max_active — bookkeeping only
        //    (prefill happens chunk-by-chunk in phase 3), so admission
        //    can never stall the sessions already in flight
        while active.len() < cfg.max_active {
            let Some(job) = queue.pop_front() else { break };
            let queue_s = job.enqueued_at.elapsed().as_secs_f64();
            let sess = engine.admit(job.id, job.req, job.enqueued_at);
            {
                let mut m = metrics.lock().unwrap();
                m.admitted += 1;
                m.queue_seconds_total += queue_s;
            }
            active.push((sess, job.reply));
        }

        let mut finished: Vec<(usize, Result<FinishReason>)> = Vec::new();

        // 3. prefill cycle: every Prefilling session consumes one
        //    bounded sequence-parallel chunk of its prompt (§Perf L3-4).
        //    A session whose prompt completes this cycle samples its
        //    first token and joins the decode batch below immediately.
        for (i, (sess, _)) in active.iter_mut().enumerate() {
            if sess.is_decoding() {
                continue;
            }
            if let Err(e) = engine.prefill_tick(sess, cfg.prefill_chunk) {
                finished.push((i, Err(e)));
            }
        }

        // 4. decode cycle: commit every decoding session's pending token
        //    in admission order, then advance all continuing sessions
        //    with ONE batched forward — each weight matrix is streamed
        //    once per cycle and reused across all B sessions instead of
        //    being refetched B times (§Perf L3-3 weight-reuse
        //    amortization).  Sessions still prefilling (or failed above)
        //    are skipped.
        {
            let mut live: Vec<(usize, &mut ActiveSession)> = Vec::new();
            for (i, (sess, _)) in active.iter_mut().enumerate() {
                if !sess.is_decoding() {
                    continue;
                }
                match engine.commit_pending(sess) {
                    Some(reason) => finished.push((i, Ok(reason))),
                    None => live.push((i, sess)),
                }
            }
            if !live.is_empty() {
                let errs = {
                    let mut batch: Vec<&mut ActiveSession> =
                        live.iter_mut().map(|(_, s)| &mut **s).collect();
                    engine.step_batch(&mut batch)
                };
                // per-session outcomes: a failing session finishes with
                // its own error, its batchmates keep generating
                for ((i, _), err) in live.into_iter().zip(errs) {
                    if let Some(e) = err {
                        finished.push((i, Err(e)));
                    }
                }
            }
        }
        finished.sort_by_key(|&(i, _)| i);
        // 5. drain observability counters BEFORE completing, so a
        //    client woken by its reply observes metrics that already
        //    include its session's work: the hardware backend's
        //    cumulative 9-bit clip total for this cycle's prefill +
        //    decode (lossless across split cycles, unlike the per-call
        //    counter), and the prefix cache's counters/gauges (mirrored
        //    wholesale — the worker owns the engine, so the engine-side
        //    totals are authoritative) — both surfaced in the serve
        //    report
        let clips = engine.model.take_clip_events();
        let cache_stats = engine.cache_stats();
        if clips > 0 || cache_stats.is_some() {
            let mut m = metrics.lock().unwrap();
            m.clip_events += clips;
            if let Some(cs) = cache_stats {
                m.prefix_cache_hits = cs.hits;
                m.prefix_cache_misses = cs.misses;
                m.prefix_tokens_skipped = cs.tokens_skipped;
                m.prefix_cache_bytes = cs.bytes_resident;
                m.prefix_cache_entries = cs.entries;
                m.prefix_cache_evictions = cs.evictions;
            }
        }
        // 6. complete (reverse order keeps indices valid)
        for (i, outcome) in finished.into_iter().rev() {
            let (sess, reply) = active.remove(i);
            {
                let mut m = metrics.lock().unwrap();
                m.completed += 1;
                m.tokens_generated += sess.generated.len() as u64;
                m.decode_seconds_total += sess.decode_seconds;
                m.prefill_seconds_total += sess.prefill_seconds;
                // TTFT only for sessions that sampled a first token — a
                // prefill failure completes without one and must not
                // drag the mean toward zero
                if sess.is_decoding() {
                    m.first_tokens += 1;
                    m.ttft_seconds_total += sess.ttft_seconds;
                }
            }
            let resp = outcome.map(|reason| GenResponse {
                request_id: sess.request_id,
                tokens: sess.generated,
                finish: reason,
                prefill_seconds: sess.prefill_seconds,
                decode_seconds: sess.decode_seconds,
                queue_seconds: (sess.started_at - sess.enqueued_at).as_secs_f64(),
                ttft_seconds: sess.ttft_seconds,
                cached_prefix_tokens: sess.cached_prefix_tokens,
            });
            let _ = reply.send(resp);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::rwkv::testing::test_model;

    fn coordinator(max_active: usize) -> Coordinator {
        Coordinator::spawn(
            test_model(2, 32, 64, 50),
            CoordinatorConfig { max_active, ..Default::default() },
        )
    }

    #[test]
    fn serves_one_request() {
        let c = coordinator(4);
        let r = c.generate(GenRequest::greedy(vec![1, 2], 6)).unwrap();
        assert_eq!(r.tokens.len(), 6);
        assert_eq!(r.finish, super::super::FinishReason::MaxTokens);
        assert!(r.ttft_seconds > 0.0, "ttft must be recorded");
        assert!(r.ttft_seconds <= r.queue_seconds + r.prefill_seconds + r.decode_seconds + 1.0);
    }

    #[test]
    fn prompt_longer_than_chunk_is_served_across_cycles() {
        // prompt of 45 tokens at chunk 8 → 6 prefill cycles, then decode;
        // output must match a solo run with whole-prompt prefill
        let prompt: Vec<u32> = (0..45u32).map(|t| (t * 7 + 3) % 50).collect();
        let solo = {
            let c = coordinator(1);
            c.generate(GenRequest::greedy(prompt.clone(), 6)).unwrap().tokens
        };
        let c = Coordinator::spawn(
            test_model(2, 32, 64, 50),
            CoordinatorConfig { max_active: 4, prefill_chunk: 8, ..Default::default() },
        );
        let r = c.generate(GenRequest::greedy(prompt, 6)).unwrap();
        assert_eq!(r.tokens, solo);
        let m = c.metrics.lock().unwrap();
        assert!(m.ttft_seconds_total > 0.0);
    }

    #[test]
    fn concurrent_requests_all_complete() {
        let c = coordinator(3);
        let rxs: Vec<_> = (0..10)
            .map(|i| c.submit(GenRequest::greedy(vec![1 + i as u32], 5)))
            .collect();
        for rx in rxs {
            let r = rx.recv().unwrap().unwrap();
            assert_eq!(r.tokens.len(), 5);
        }
        let m = c.metrics.lock().unwrap();
        assert_eq!(m.completed, 10);
        assert_eq!(m.tokens_generated, 50);
    }

    #[test]
    fn batched_output_matches_solo_output() {
        // continuous batching must not change any session's tokens
        let solo = {
            let c = coordinator(1);
            c.generate(GenRequest::greedy(vec![5, 6, 7], 8)).unwrap().tokens
        };
        let c = coordinator(4);
        // fill the batch with interference
        let _noise1 = c.submit(GenRequest::greedy(vec![9], 8));
        let _noise2 = c.submit(GenRequest::greedy(vec![11, 12], 8));
        let got = c.generate(GenRequest::greedy(vec![5, 6, 7], 8)).unwrap().tokens;
        assert_eq!(got, solo);
    }

    #[test]
    fn shared_prefix_requests_hit_cache_with_identical_tokens() {
        // same 40-token prompt, served back to back: the second request
        // must resume from a cached chunk boundary (prefilling only the
        // tail) and still produce identical tokens; a third request
        // extending the prompt reuses the full-prompt snapshot
        let prompt: Vec<u32> = (0..40u32).map(|t| (t * 3 + 2) % 50).collect();
        let cold = {
            let c = Coordinator::spawn(
                test_model(2, 32, 64, 50),
                CoordinatorConfig { max_active: 4, prefill_chunk: 8, state_cache_bytes: 0 },
            );
            c.generate(GenRequest::greedy(prompt.clone(), 6)).unwrap()
        };
        assert_eq!(cold.cached_prefix_tokens, 0, "cache disabled must never resume");

        let c = Coordinator::spawn(
            test_model(2, 32, 64, 50),
            CoordinatorConfig { max_active: 4, prefill_chunk: 8, ..Default::default() },
        );
        let r1 = c.generate(GenRequest::greedy(prompt.clone(), 6)).unwrap();
        let r2 = c.generate(GenRequest::greedy(prompt.clone(), 6)).unwrap();
        let mut extended = prompt.clone();
        extended.extend_from_slice(&[5, 6]);
        let r3 = c.generate(GenRequest::greedy(extended, 6)).unwrap();
        assert_eq!(r1.cached_prefix_tokens, 0);
        assert_eq!(r1.tokens, cold.tokens);
        // boundaries at 8,16,24,32,40; lookup capped at 39 → resume at 32
        assert_eq!(r2.cached_prefix_tokens, 32);
        assert_eq!(r2.tokens, cold.tokens);
        // the extended prompt reuses the full 40-token snapshot
        assert_eq!(r3.cached_prefix_tokens, 40);
        let m = c.metrics.lock().unwrap();
        assert_eq!(m.prefix_cache_hits, 2);
        assert_eq!(m.prefix_cache_misses, 1);
        assert_eq!(m.prefix_tokens_skipped, 72);
        assert!(m.prefix_cache_entries > 0);
        assert!(m.prefix_cache_bytes > 0);
    }

    #[test]
    fn shutdown_joins_cleanly() {
        let c = coordinator(2);
        let _ = c.generate(GenRequest::greedy(vec![1], 2)).unwrap();
        c.shutdown();
    }

    #[test]
    fn hw_clip_totals_drain_into_metrics() {
        use crate::model::HwModel;
        // per-session clip trajectories are batching-invariant (batched
        // decode and chunked prefill are bit-exact with solo decode), so
        // the coordinator's drained total must equal the sum of solo
        // runs of the same requests
        let calib: Vec<u32> = (0..64u32).map(|i| (i * 11 + 3) % 50).collect();
        let mk = || HwModel::from_f32(test_model(2, 32, 64, 50), &calib);
        let reqs: Vec<GenRequest> = (0..3u32)
            .map(|i| GenRequest::greedy(vec![(i + 1) % 50, (i * 7 + 2) % 50], 6))
            .collect();
        let expected = {
            let mut eng = Engine::new(mk());
            for (i, r) in reqs.iter().enumerate() {
                let mut s = eng.start(i as u64, r.clone(), Instant::now()).unwrap();
                while eng.step_session(&mut s).unwrap().is_none() {}
            }
            eng.model.take_clip_events()
        };
        let c = Coordinator::spawn(
            mk(),
            CoordinatorConfig { max_active: 4, prefill_chunk: 4, ..Default::default() },
        );
        let rxs: Vec<_> = reqs.iter().map(|r| c.submit(r.clone())).collect();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        let m = c.metrics.lock().unwrap();
        assert_eq!(m.clip_events, expected);
    }
}
