//! Bounded admission queue + streaming sessions + the worker loop.
//!
//! One worker thread owns the engine (and therefore the PJRT client)
//! exclusively.  Each scheduling cycle it
//!
//! 1. **reaps** queued jobs and active sessions flagged by
//!    [`GenStream::cancel`] / stream drop or an expired wall-clock
//!    deadline: a queued job terminates without ever taking a slot; an
//!    active session frees its `max_active` slot at this cycle boundary
//!    (pinned snapshots release, partial output returns with
//!    [`super::FinishReason::Cancelled`] or
//!    [`super::FinishReason::DeadlineExceeded`]) — batchmates are
//!    untouched (per-session state isolation is the batching
//!    invariant);
//! 2. **admits** queued requests while `slot_weight`ed capacity
//!    remains under `max_active` (a fork request reserves all its
//!    future branch slots up front), highest [`GenRequest::priority`]
//!    first (FIFO within a level) — admission is bookkeeping plus a
//!    prefix-cache lookup (no forward work), and emits
//!    [`GenEvent::Started`] on the session's stream;
//! 3. **prefills**: every `Prefilling` session consumes at most
//!    `prefill_chunk` prompt tokens via ONE sequence-parallel
//!    [`Engine::prefill_tick`] (§Perf L3-4), so a long prompt cannot
//!    head-of-line-block the decoders;
//! 4. **forks**: a prompt that completed with `n_best > 1` spawns its
//!    branches via [`Engine::fork`] — one prefill, one shared pinned
//!    snapshot, N decoding sessions with seeds `seed + branch`, each
//!    announced with its own [`GenEvent::Started`];
//! 5. **decodes**: commits every decoding session's pending token in
//!    admission order — streaming each as a [`GenEvent::Token`] — then
//!    advances all continuing sessions with a single fused
//!    [`Engine::step_batch`] forward (§Perf L3-3 weight reuse);
//! 6. **completes** finished sessions, emitting the terminal
//!    [`GenEvent::Finished`]/[`GenEvent::Error`] per branch after
//!    folding the session's totals (and the engine's clip/cache/prefill
//!    counters) into [`Metrics`].
//!
//! Chunked and token-by-token prefill are bit-exact for the native
//! models, as are batched and per-session decode, cached-prefix resume
//! and fork-vs-sequential branches, so neither scheduling capacity,
//! chunk size, cache state nor forking ever changes a session's tokens
//! (asserted by the parity suites in `rust/tests/`).
//!
//! Backpressure is explicit: [`Coordinator::submit`] reserves a slot in
//! a queue bounded by [`CoordinatorConfig::max_queue`] and rejects with
//! [`SubmitError::QueueFull`] instead of buffering without bound.
//! Per-priority quotas ([`CoordinatorConfig::priority_quotas`]) bound
//! each level's share of that queue separately, rejecting with
//! [`SubmitError::QuotaExceeded`] so a low-priority flood saturates its
//! own share, never the whole queue.
//! Between reap and admission, an optional shed phase additionally
//! drops the lowest-priority queued requests with
//! [`super::FinishReason::Shed`] whenever the queue exceeds
//! [`CoordinatorConfig::shed_watermark`].
//!
//! The loop itself runs under a supervisor (see the crate-level
//! "Failure model"): per-call model faults are isolated and retried by
//! the engine's guards ([`super::engine::FaultPolicy`]), and a panic
//! escaping them terminates every in-flight session with
//! [`super::FinishReason::WorkerFailed`], rebuilds the engine view, and
//! respawns the loop — no [`GenStream`] can hang on a dead worker.

use std::collections::{BTreeMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Result};

use super::engine::{
    ActiveSession, Backend, BackendModel, Engine, EngineModel, FaultPolicy, SessionFault,
};
use super::journal::{FaultEvent, FaultJournal, FaultKind, FaultPhase, RecoveryAction};
use super::metrics::Metrics;
use super::{FinishReason, GenEvent, GenRequest, GenResponse};
use crate::model::RwkvModel;
use crate::statecache::StateCacheConfig;
use crate::trace::{CyclePhaseKind, TraceEvent, TraceEventKind, Tracer};
use crate::util::json::Json;

/// Poison-tolerant metrics acquisition: `Metrics` is plain counters —
/// every intermediate state is valid — so a panic while the lock was
/// held carries no information, and propagating the poison would brick
/// metrics reporting (and every later `submit`) for the process's
/// remaining lifetime.
fn lock(m: &Mutex<Metrics>) -> MutexGuard<'_, Metrics> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// maximum concurrently-active sessions (prefilling + decoding;
    /// every best-of-n branch counts while it lives)
    pub max_active: usize,
    /// maximum prompt tokens a `Prefilling` session consumes per
    /// scheduling cycle; bounds how long one cycle can stall decode.
    /// 32–128 is the useful range: big enough to amortize each weight
    /// matrix over many tokens, small enough to keep decode latency flat.
    /// Also the granularity of prefix-cache snapshots: every chunk
    /// boundary is a resumable state.
    pub prefill_chunk: usize,
    /// byte budget for the prefix-sharing state cache
    /// ([`crate::statecache`]); 0 disables caching entirely.  Resuming
    /// is bit-exact, so this only trades memory for prefill latency.
    pub state_cache_bytes: usize,
    /// Bound on requests submitted but not yet admitted: one more and
    /// [`Coordinator::submit`] rejects with [`SubmitError::QueueFull`].
    /// Backpressure must be visible at the API boundary — an unbounded
    /// queue just converts overload into silent latency.
    pub max_queue: usize,
    /// How the worker treats model-level faults — panic isolation,
    /// NaN/Inf health guards, rollback-retry (see
    /// [`super::engine::FaultPolicy`] and the crate-level "Failure
    /// model" section).
    pub fault: FaultPolicy,
    /// Overload shedding: while more than this many requests sit in the
    /// admission queue, the worker sheds the lowest-priority queued
    /// request (latest-submitted within the level) each cycle with
    /// [`FinishReason::Shed`] — low-priority work that would only expire
    /// in queue stops wasting prefill cycles, preserving high-priority
    /// goodput.  0 (the default) disables shedding; meaningful values
    /// sit well below `max_queue` (the hard rejection bound).
    pub shed_watermark: usize,
    /// Which native numerics backend [`Coordinator::spawn_native`]
    /// builds (exact f32, decoded-Δ-PoT hw, or packed-Δ-PoT SIMD — see
    /// [`Backend`]).  Ignored by [`Coordinator::spawn`]/`spawn_with`,
    /// whose caller already constructed the model.
    pub backend: Backend,
    /// Capacity of the cycle-level trace ring ([`crate::trace`]): the
    /// newest `trace_events` session-lifecycle and scheduler-phase
    /// events are retained for [`Coordinator::export_trace`].  0
    /// disables tracing entirely (every record path reduces to a
    /// branch on `None`); the default keeps it on —
    /// `benches/trace_overhead.rs` pins the cost under 3% of serving
    /// throughput at the default `max_active`.
    pub trace_events: usize,
    /// Per-priority admission quotas: `(priority level, max queued at
    /// that level)`.  A level listed here rejects further submissions
    /// with [`SubmitError::QuotaExceeded`] once that many of its
    /// requests sit in the admission queue, *even while the global
    /// `max_queue` has room* — so a low-priority flood can never
    /// consume more than its configured share of the queue and starve
    /// high-priority traffic out of admission.  Levels not listed are
    /// bounded only by `max_queue`.  Quotas meter the *queued* phase
    /// (submit → admit): once admitted, a session competes for
    /// `max_active` slots on priority alone, and a supervisor redrive
    /// re-entering the queue is exempt (its first life already paid
    /// for admission).  Empty (the default) disables the mechanism.
    pub priority_quotas: Vec<(i32, usize)>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            max_active: 8,
            prefill_chunk: 64,
            state_cache_bytes: StateCacheConfig::default().max_bytes,
            max_queue: 1024,
            fault: FaultPolicy::default(),
            shed_watermark: 0,
            backend: Backend::default(),
            trace_events: crate::trace::DEFAULT_TRACE_EVENTS,
            priority_quotas: Vec::new(),
        }
    }
}

/// Why [`Coordinator::submit`] refused a request.  Everything that can
/// go wrong *after* admission arrives as [`GenEvent`]s on the stream.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// The bounded admission queue is at [`CoordinatorConfig::max_queue`]:
    /// the service is saturated, back off and retry.
    QueueFull { limit: usize },
    /// This request's priority level is at its configured
    /// [`CoordinatorConfig::priority_quotas`] share of the queue — the
    /// *level* is saturated even though the service as a whole may not
    /// be.  Back off and retry (or resubmit at a higher priority).
    QuotaExceeded { priority: i32, limit: usize },
    /// The coordinator has shut down; no worker will ever serve this.
    ShutDown,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull { limit } => {
                write!(f, "admission queue full ({limit} requests waiting)")
            }
            SubmitError::QuotaExceeded { priority, limit } => {
                write!(f, "priority {priority} is at its queue quota ({limit} requests waiting)")
            }
            SubmitError::ShutDown => write!(f, "coordinator is shut down"),
        }
    }
}

impl std::error::Error for SubmitError {}

struct Job {
    id: u64,
    req: GenRequest,
    enqueued_at: Instant,
    /// Absolute expiry computed at submission from [`GenRequest::deadline`].
    deadline_at: Option<Instant>,
    events: Sender<GenEvent>,
    cancel: Arc<AtomicBool>,
    /// `Some` when this job is a supervisor re-admission of a session
    /// the worker crash failed in flight (see "Failure model" in
    /// [`super`]): `req.prompt` has been extended by the tokens already
    /// streamed to the client, and admission resumes the session via
    /// [`Engine::resume_redriven`] instead of announcing a fresh one.
    redrive: Option<Redrive>,
    /// Priority level this job holds a [`QuotaBook`] queued-count
    /// reservation at — released exactly once, at whichever queue exit
    /// the job takes (admission, reap, shed, or a failed enqueue).
    /// `None` for supervisor redrives, which bypass admission quotas.
    quota: Option<i32>,
}

/// Submit-side per-priority queue accounting backing
/// [`CoordinatorConfig::priority_quotas`].  Shared by every submitter
/// and the worker: `try_reserve` runs in [`Coordinator::submit`],
/// `release` at each queue exit in the worker loop (and on a failed
/// enqueue).  Levels without a configured limit are still counted —
/// the live per-level depth feeds the per-priority metrics gauges.
struct QuotaBook {
    /// `(priority level, max queued at that level)` from config.
    limits: Vec<(i32, usize)>,
    /// Live submitted-but-not-admitted count per level.  Entries are
    /// never removed (levels are few), so the metrics mirror also
    /// drains levels back to 0 instead of dropping them.
    queued: Mutex<BTreeMap<i32, usize>>,
}

impl QuotaBook {
    fn new(limits: Vec<(i32, usize)>) -> QuotaBook {
        QuotaBook { limits, queued: Mutex::new(BTreeMap::new()) }
    }

    /// Reserve one queued slot at `priority`, or `Err(limit)` when the
    /// level is at its configured quota.
    fn try_reserve(&self, priority: i32) -> std::result::Result<(), usize> {
        let mut q = self.queued.lock().unwrap_or_else(PoisonError::into_inner);
        let n = q.entry(priority).or_insert(0);
        if let Some(&(_, limit)) = self.limits.iter().find(|&&(p, _)| p == priority) {
            if *n >= limit {
                return Err(limit);
            }
        }
        *n += 1;
        Ok(())
    }

    fn release(&self, priority: i32) {
        let mut q = self.queued.lock().unwrap_or_else(PoisonError::into_inner);
        if let Some(n) = q.get_mut(&priority) {
            *n = n.saturating_sub(1);
        }
    }

    /// Release the reservation `job` carries, if any (redrives carry
    /// none).  Call exactly once per queue exit.
    fn release_job(&self, job: &Job) {
        if let Some(p) = job.quota {
            self.release(p);
        }
    }

    /// Live queued depth per level, for the metrics gauge mirror.
    fn snapshot(&self) -> Vec<(i32, usize)> {
        let q = self.queued.lock().unwrap_or_else(PoisonError::into_inner);
        q.iter().map(|(&p, &n)| (p, n)).collect()
    }
}

/// Continuation record for a transparent redrive: everything the
/// re-admission needs to stitch the new session onto the crashed one's
/// client-visible history.
struct Redrive {
    /// Best-of-n branch the crashed session was serving (a decoding
    /// branch redrives solo with `n_best` forced to 1).
    branch: usize,
    /// 1-based redrive attempt this job represents.
    attempt: u32,
    /// Length of the client's original prompt; `req.prompt[len..]` is
    /// the replayed committed-token suffix.
    orig_prompt_len: usize,
    /// Timings accumulated before the crash, carried so the final
    /// [`GenResponse`] reports whole-request figures.
    ttft_seconds: f64,
    prefill_seconds: f64,
    decode_seconds: f64,
    /// When the supervisor observed the crash — the anchor for the
    /// resume-after-fault latency metric.
    failed_at: Instant,
}

/// One active slot in the worker: the session plus its client-facing
/// channel ends.  Fork branches share `events`/`cancel`/`deadline_at`
/// with their siblings (cancel reaps the whole request).
struct Slot {
    sess: ActiveSession,
    events: Sender<GenEvent>,
    cancel: Arc<AtomicBool>,
    deadline_at: Option<Instant>,
}

/// Client handle to one streaming session (see the module docs of
/// [`super`] for the event protocol).  Dropping the stream cancels the
/// session unless it already finished — an abandoned generation must
/// not keep burning its `max_active` slot.
#[derive(Debug)]
pub struct GenStream {
    request_id: u64,
    n_best: usize,
    rx: Receiver<GenEvent>,
    cancel: Arc<AtomicBool>,
    /// Which branches have received their terminal event.
    branch_done: Vec<bool>,
    /// Branch 0's terminal when it ended for a whole-request reason
    /// (reaped in queue, shed, worker death) — mirrored onto branches
    /// whose own terminal will never arrive because they were never
    /// forked into existence.
    mirror: Option<GenResponse>,
    closed: bool,
}

impl GenStream {
    pub fn request_id(&self) -> u64 {
        self.request_id
    }

    /// How many branch sub-sessions this stream carries (the request's
    /// clamped `n_best`).
    pub fn n_best(&self) -> usize {
        self.n_best
    }

    /// Ask the worker to stop this request (all branches).  The slot
    /// frees and the partial output is returned with
    /// [`FinishReason::Cancelled`] at the next scheduling-cycle
    /// boundary; cancelling an already-finished stream is a no-op.
    pub fn cancel(&self) {
        self.cancel.store(true, Ordering::Release);
    }

    /// Record one branch's terminal event; closes the stream once every
    /// branch has one.  Branch 0's terminal is kept for mirroring when
    /// it names a whole-request reason (the request may have ended
    /// before its fork branches ever existed).
    fn mark_done(&mut self, branch: usize, resp: Option<&GenResponse>) {
        if let Some(d) = self.branch_done.get_mut(branch) {
            *d = true;
        }
        if branch == 0 {
            if let Some(r) = resp {
                if matches!(
                    r.finish,
                    FinishReason::Cancelled
                        | FinishReason::DeadlineExceeded
                        | FinishReason::Shed
                        | FinishReason::WorkerFailed
                ) {
                    self.mirror = Some(r.clone());
                }
            }
        }
        if self.branch_done.iter().all(|&d| d) {
            self.closed = true;
        }
    }

    /// Next event, blocking.  Returns `None` only once every branch has
    /// terminated — the stream is then exhausted and drop will NOT
    /// cancel anything.
    ///
    /// A disconnected worker channel can never leave a branch without a
    /// terminal: if the sender drops with branches still open (the
    /// request was reaped before its fork, or the worker died harder
    /// than the supervisor could clean up), `recv` synthesizes one
    /// terminal per remaining branch — the branch-0 whole-request
    /// terminal mirrored onto never-born branches when there is one,
    /// a [`GenEvent::Error`] otherwise.  `recv` can therefore never
    /// block forever, and `wait`/`wait_one` always return one outcome
    /// per branch.
    pub fn recv(&mut self) -> Option<GenEvent> {
        if self.closed {
            return None;
        }
        match self.rx.recv() {
            Ok(ev) => {
                match &ev {
                    GenEvent::Finished(r) => self.mark_done(r.branch, Some(r)),
                    GenEvent::Error { branch, .. } => self.mark_done(*branch, None),
                    GenEvent::Started { .. }
                    | GenEvent::Token { .. }
                    | GenEvent::Redriven { .. } => {}
                }
                Some(ev)
            }
            Err(_) => {
                let Some(b) = self.branch_done.iter().position(|&d| !d) else {
                    self.closed = true;
                    return None;
                };
                let ev = match &self.mirror {
                    Some(r0) => {
                        let mut r = r0.clone();
                        r.branch = b;
                        // a never-born branch produced nothing — only
                        // the whole-request reason carries over
                        r.tokens = Vec::new();
                        GenEvent::Finished(r)
                    }
                    None => GenEvent::Error {
                        branch: b,
                        message: "worker connection lost before the branch finished".into(),
                    },
                };
                self.mark_done(b, None);
                Some(ev)
            }
        }
    }

    /// Drain the stream to completion, returning one result per branch
    /// (index = branch).  Every branch gets exactly one outcome:
    /// branches the worker never finished receive the terminal `recv`
    /// synthesizes (the branch-0 whole-request terminal mirrored onto
    /// branches that never existed, or a disconnect error).
    pub fn wait(mut self) -> Vec<Result<GenResponse>> {
        let n = self.n_best;
        let mut out: Vec<Option<Result<GenResponse>>> = (0..n).map(|_| None).collect();
        while let Some(ev) = self.recv() {
            match ev {
                GenEvent::Finished(r) => {
                    if r.branch < n {
                        out[r.branch] = Some(Ok(r));
                    }
                }
                GenEvent::Error { branch, message } => {
                    if branch < n {
                        out[branch] = Some(Err(anyhow!(message)));
                    }
                }
                GenEvent::Started { .. } | GenEvent::Token { .. } | GenEvent::Redriven { .. } => {}
            }
        }
        out.into_iter()
            .map(|o| o.unwrap_or_else(|| Err(anyhow!("stream closed before the branch finished"))))
            .collect()
    }

    /// Drain the stream and return branch 0's response — the blocking
    /// single-result path [`Coordinator::generate`] wraps.
    pub fn wait_one(self) -> Result<GenResponse> {
        self.wait().into_iter().next().expect("n_best is clamped >= 1")
    }
}

impl Drop for GenStream {
    fn drop(&mut self) {
        // cancel-on-drop: if the client walks away mid-generation the
        // worker reaps the session at the next cycle boundary.  `closed`
        // is only true once every branch terminated, so this never
        // cancels finished work.
        if !self.closed {
            self.cancel.store(true, Ordering::Release);
        }
    }
}

/// Handle to a running coordinator.  `submit` returns a streaming
/// [`GenStream`]; `generate` is the blocking wrapper over it.
pub struct Coordinator {
    /// `None` only once `shutdown`/`Drop` has closed the channel — the
    /// ONE close-and-join path both share.
    tx: Option<Sender<Job>>,
    next_id: AtomicU64,
    /// Requests submitted but not yet admitted (channel + worker-local
    /// queue); bounds admission via `max_queue`.
    queue_depth: Arc<AtomicUsize>,
    max_queue: usize,
    /// Mirror of `cfg.max_active`: the fork-width clamp for `n_best`
    /// (every branch occupies an active slot, so a wider fork would
    /// break the concurrency/memory bound `max_active` exists to hold).
    max_active: usize,
    pub metrics: Arc<Mutex<Metrics>>,
    /// Shared with the worker's engine and its supervisor — see
    /// [`Coordinator::fault_journal`].
    journal: Arc<Mutex<FaultJournal>>,
    /// Shared with the worker loop and the engine — see
    /// [`Coordinator::export_trace`].  Disabled (a no-op handle) when
    /// [`CoordinatorConfig::trace_events`] is 0.
    tracer: Tracer,
    /// Per-priority queue accounting shared with the worker — see
    /// [`CoordinatorConfig::priority_quotas`].
    quota: Arc<QuotaBook>,
    worker: Option<JoinHandle<()>>,
}

impl Coordinator {
    /// Spawn the worker thread around an engine model.
    pub fn spawn<M: EngineModel + Send + 'static>(model: M, cfg: CoordinatorConfig) -> Coordinator {
        Self::spawn_with(move || model, cfg)
    }

    /// Spawn the worker around the native backend
    /// [`CoordinatorConfig::backend`] selects: the f32 base model goes
    /// through [`BackendModel::build`] *inside* the worker thread, so
    /// the quantized backends' encode + calibration walk runs off the
    /// caller's thread.  `calib_tokens` feeds the activation-scale
    /// calibration (ignored by the exact backend).
    pub fn spawn_native(
        base: RwkvModel,
        calib_tokens: Vec<u32>,
        cfg: CoordinatorConfig,
    ) -> Coordinator {
        let backend = cfg.backend;
        Self::spawn_with(move || BackendModel::build(base, backend, &calib_tokens), cfg)
    }

    /// Spawn with a factory executed *inside* the worker thread — required
    /// for models that are not `Send` (the PJRT runtime holds `Rc`s and
    /// raw pointers; constructing it on the owning thread sidesteps any
    /// cross-thread transfer).
    pub fn spawn_with<M, F>(factory: F, mut cfg: CoordinatorConfig) -> Coordinator
    where
        M: EngineModel + 'static,
        F: FnOnce() -> M + Send + 'static,
    {
        // max_active = 0 would accept submissions the worker could never
        // admit (clients block forever while the worker spins); clamp
        // once so the submit-side mirror and the worker always agree
        cfg.max_active = cfg.max_active.max(1);
        // the worker closure takes `cfg` by move (it is no longer Copy
        // since priority_quotas); mirror what the submit side needs first
        let (max_queue, max_active) = (cfg.max_queue.max(1), cfg.max_active);
        let quota = Arc::new(QuotaBook::new(cfg.priority_quotas.clone()));
        let (tx, rx) = channel::<Job>();
        let metrics = Arc::new(Mutex::new(Metrics::default()));
        let queue_depth = Arc::new(AtomicUsize::new(0));
        let journal = Arc::new(Mutex::new(FaultJournal::default()));
        let tracer = Tracer::new(cfg.trace_events);
        let m2 = metrics.clone();
        let d2 = queue_depth.clone();
        let j2 = journal.clone();
        let t2 = tracer.clone();
        let q2 = quota.clone();
        let worker = std::thread::spawn(move || {
            let mut engine = if cfg.state_cache_bytes > 0 {
                Engine::with_cache(factory(), StateCacheConfig { max_bytes: cfg.state_cache_bytes })
            } else {
                Engine::new(factory())
            };
            engine.set_fault_policy(cfg.fault);
            engine.set_journal(j2.clone());
            engine.set_tracer(t2.clone());
            // supervisor: the scheduling state (active slots + local
            // queue) lives OUT here, so a panic that escapes the
            // per-call fault guards — a scheduler bug, a panic in
            // commit/fork/accounting — cannot take the client-facing
            // Senders down with the loop.  For every in-flight session
            // the supervisor either re-admits it for a transparent
            // redrive (budget permitting, deadline willing) or
            // terminates it with a typed WorkerFailed terminal — no
            // stream ever hangs — then recovers the engine's serving
            // state (healthy cache snapshots survive) and respawns the
            // loop.  Queued never-admitted jobs ride the crash out
            // untouched: they hold no engine state to lose.
            let mut active: Vec<Slot> = Vec::new();
            let mut queue: VecDeque<Job> = VecDeque::new();
            loop {
                let run = catch_unwind(AssertUnwindSafe(|| {
                    worker_loop(&mut engine, &mut active, &mut queue, &rx, &cfg, &m2, &d2, &t2, &q2)
                }));
                if run.is_ok() {
                    return; // graceful shutdown (queue closed + drained)
                }
                lock(&m2).worker_restarts += 1;
                let crash_cycle = engine.cycle();
                let failed_at = Instant::now();
                let record = |ev: FaultEvent| {
                    j2.lock().unwrap_or_else(PoisonError::into_inner).record(ev);
                };
                // in original admission order so push_front (reversed
                // below) restores each session's queue position
                let mut redriven: Vec<Job> = Vec::new();
                for slot in active.drain(..) {
                    // a session whose last commit was terminal (phase 6)
                    // but that crashed before phase-8 completion is done,
                    // not in flight: every token is already committed and
                    // streamed, so deliver the real terminal — a redrive
                    // here would replay the finished sequence and then
                    // sample one token PAST the terminal
                    let done = &slot.sess;
                    if done.req.stop_token.is_some_and(|t| done.generated.last() == Some(&t)) {
                        complete(slot, Ok(FinishReason::StopToken), &m2, &t2, crash_cycle);
                        continue;
                    }
                    if done.generated.len() >= done.req.max_new_tokens {
                        complete(slot, Ok(FinishReason::MaxTokens), &m2, &t2, crash_cycle);
                        continue;
                    }
                    // a crash must not resurrect work the client already
                    // gave up on — the reap the dead cycle never ran
                    if let Some(reason) = reap_reason(&slot.cancel, slot.deadline_at) {
                        if reason == FinishReason::DeadlineExceeded {
                            record(FaultEvent {
                                request_id: slot.sess.request_id,
                                branch: slot.sess.branch,
                                cycle: crash_cycle,
                                phase: FaultPhase::Worker,
                                kind: FaultKind::WorkerCrash,
                                attempt: slot.sess.redrive_attempt,
                                action: RecoveryAction::DeadlineAbandoned,
                                unix_s: 0.0,
                            });
                        }
                        complete(slot, Ok(reason), &m2, &t2, crash_cycle);
                        continue;
                    }
                    if slot.sess.redrive_attempt >= slot.sess.req.redrive_budget {
                        record(FaultEvent {
                            request_id: slot.sess.request_id,
                            branch: slot.sess.branch,
                            cycle: crash_cycle,
                            phase: FaultPhase::Worker,
                            kind: FaultKind::WorkerCrash,
                            attempt: slot.sess.redrive_attempt,
                            action: RecoveryAction::SessionFailed,
                            unix_s: 0.0,
                        });
                        complete(slot, Ok(FinishReason::WorkerFailed), &m2, &t2, crash_cycle);
                        continue;
                    }
                    // budget left: re-admit transparently.  The stream
                    // stays open; Redriven marks the seam and promises
                    // the next Token continues at seq_idx = replayed_from.
                    let Slot { sess, events, cancel, deadline_at } = slot;
                    record(FaultEvent {
                        request_id: sess.request_id,
                        branch: sess.branch,
                        cycle: crash_cycle,
                        phase: FaultPhase::Worker,
                        kind: FaultKind::WorkerCrash,
                        attempt: sess.redrive_attempt,
                        action: RecoveryAction::Redriven,
                        unix_s: 0.0,
                    });
                    lock(&m2).redrives += 1;
                    t2.instant(
                        sess.request_id,
                        sess.branch as u32,
                        crash_cycle,
                        TraceEventKind::Redriven {
                            attempt: sess.redrive_attempt + 1,
                            replayed_from: sess.generated.len() as u32,
                        },
                    );
                    let _ = events.send(GenEvent::Redriven {
                        branch: sess.branch,
                        attempt: sess.redrive_attempt + 1,
                        replayed_from: sess.generated.len(),
                    });
                    let was_decoding = sess.is_decoding();
                    let mut req = sess.req;
                    // prompt = client prompt ++ every committed token
                    // (idempotent across repeated redrives: `generated`
                    // already holds any previously replayed prefix)
                    req.prompt.truncate(sess.orig_prompt_len);
                    req.prompt.extend_from_slice(&sess.generated);
                    if was_decoding {
                        // a decoding branch redrives solo — its fork
                        // siblings are their own sessions with their own
                        // budgets
                        req.n_best = 1;
                    }
                    d2.fetch_add(1, Ordering::AcqRel);
                    redriven.push(Job {
                        id: sess.request_id,
                        req,
                        enqueued_at: sess.enqueued_at,
                        deadline_at,
                        events,
                        cancel,
                        // a continuation is not a fresh admission — it
                        // must not be quota-rejected out of its own
                        // promised redrive
                        quota: None,
                        redrive: Some(Redrive {
                            branch: sess.branch,
                            attempt: sess.redrive_attempt + 1,
                            orig_prompt_len: sess.orig_prompt_len,
                            ttft_seconds: sess.ttft_seconds,
                            prefill_seconds: sess.prefill_seconds,
                            decode_seconds: sess.decode_seconds,
                            failed_at,
                        }),
                    });
                }
                for job in redriven.into_iter().rev() {
                    queue.push_front(job);
                }
                let (kept, _purged) = engine.recover();
                {
                    let mut m = lock(&m2);
                    m.cache_recovered_snapshots += kept as u64;
                    m.active_sessions = 0;
                    m.queue_depth = d2.load(Ordering::Acquire) as u64;
                }
            }
        });
        Coordinator {
            tx: Some(tx),
            next_id: AtomicU64::new(1),
            queue_depth,
            max_queue,
            max_active,
            metrics,
            journal,
            tracer,
            quota,
            worker: Some(worker),
        }
    }

    /// Snapshot of the structured fault journal, oldest record first —
    /// every engine-guarded fault (retried, failed, or abandoned at the
    /// deadline) and every supervisor redrive decision, attributed to
    /// its (request, branch, cycle, kind).  Bounded: a fault storm
    /// keeps the newest records (see [`FaultJournal`]).
    pub fn fault_journal(&self) -> Vec<FaultEvent> {
        self.journal.lock().unwrap_or_else(PoisonError::into_inner).snapshot()
    }

    /// Snapshot of the bounded trace ring, oldest event first — empty
    /// when tracing is disabled ([`CoordinatorConfig::trace_events`] =
    /// 0).  See [`crate::trace`] for what gets recorded where.
    pub fn trace_events(&self) -> Vec<TraceEvent> {
        self.tracer.snapshot()
    }

    /// The current ring snapshot as a Chrome-trace JSON object
    /// (`{"traceEvents": [...], ...}`) — what
    /// [`Coordinator::export_trace`] writes to disk.  Pure read: the
    /// worker keeps recording while and after the snapshot is taken.
    pub fn export_trace_json(&self) -> Json {
        crate::trace::chrome_trace(&self.tracer.snapshot())
    }

    /// Write the current trace ring as a Chrome-trace JSON file
    /// loadable by Perfetto (<https://ui.perfetto.dev>) or
    /// `chrome://tracing`: sessions render as async spans (queue wait,
    /// prefill, decode and redrive seams per request), scheduler and
    /// engine cycle phases as thread-track slices.
    pub fn export_trace<P: AsRef<Path>>(&self, path: P) -> std::io::Result<()> {
        crate::trace::write_chrome_trace(path.as_ref(), &self.tracer.snapshot())
    }

    /// Submit a request, returning the streaming session handle — or a
    /// typed rejection when the bounded queue is full (backpressure) or
    /// the coordinator is gone.
    ///
    /// `n_best` is clamped to `1..=max_active` here: every fork branch
    /// occupies an active slot, so a wider fork would silently break the
    /// concurrency bound.  The returned stream's
    /// [`GenStream::n_best`] reports the clamped width.
    pub fn submit(&self, mut req: GenRequest) -> std::result::Result<GenStream, SubmitError> {
        let Some(tx) = self.tx.as_ref() else {
            return Err(SubmitError::ShutDown);
        };
        // reserve a queue slot or reject: CAS so concurrent submitters
        // cannot blow past the bound between load and increment
        let mut depth = self.queue_depth.load(Ordering::Relaxed);
        loop {
            if depth >= self.max_queue {
                lock(&self.metrics).rejected += 1;
                return Err(SubmitError::QueueFull { limit: self.max_queue });
            }
            match self.queue_depth.compare_exchange_weak(
                depth,
                depth + 1,
                Ordering::AcqRel,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(now) => depth = now,
            }
        }
        // per-priority quota: the level must also be under its
        // configured queue share (see `CoordinatorConfig::priority_quotas`)
        let priority = req.priority;
        if let Err(limit) = self.quota.try_reserve(priority) {
            self.queue_depth.fetch_sub(1, Ordering::AcqRel);
            let mut m = lock(&self.metrics);
            m.quota_rejected += 1;
            m.prio(priority).quota_rejected += 1;
            return Err(SubmitError::QuotaExceeded { priority, limit });
        }
        // unique-id counter only — no ordering with anything else
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let n_best = req.n_best.clamp(1, self.max_active);
        req.n_best = n_best;
        let enqueued_at = Instant::now();
        let deadline_at = req.deadline.and_then(|d| enqueued_at.checked_add(d));
        let (etx, erx) = channel();
        let cancel = Arc::new(AtomicBool::new(false));
        let job = Job {
            id,
            req,
            enqueued_at,
            deadline_at,
            events: etx,
            cancel: cancel.clone(),
            redrive: None,
            quota: Some(priority),
        };
        if tx.send(job).is_err() {
            self.queue_depth.fetch_sub(1, Ordering::AcqRel);
            self.quota.release(priority);
            return Err(SubmitError::ShutDown);
        }
        {
            let mut m = lock(&self.metrics);
            m.enqueued += 1;
            m.prio(priority).enqueued += 1;
        }
        // the session's async trace span opens here; cycle is 0 because
        // the submit side cannot see the worker's cycle counter
        self.tracer.instant(id, 0, 0, TraceEventKind::Enqueue);
        Ok(GenStream {
            request_id: id,
            n_best,
            rx: erx,
            cancel,
            branch_done: vec![false; n_best],
            mirror: None,
            closed: false,
        })
    }

    /// Blocking generate: submit, drain the stream, return branch 0.
    pub fn generate(&self, req: GenRequest) -> Result<GenResponse> {
        self.submit(req)?.wait_one()
    }

    /// Blocking best-of-n: submit, drain, return every branch's
    /// response (first branch error propagates).
    pub fn generate_all(&self, req: GenRequest) -> Result<Vec<GenResponse>> {
        self.submit(req)?.wait().into_iter().collect()
    }

    /// Graceful shutdown: close the queue and join the worker (also what
    /// `Drop` does — this just makes the join explicit and synchronous
    /// at a call site of the caller's choosing).
    pub fn shutdown(mut self) {
        self.close_and_join();
    }

    /// The single close path: dropping the one `Sender` disconnects the
    /// worker's queue, which exits after draining in-flight sessions.
    /// Idempotent — `shutdown` runs it eagerly, `Drop` runs it again as
    /// a no-op.
    fn close_and_join(&mut self) {
        self.tx = None;
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.close_and_join();
    }
}

/// How many `max_active` slots a session occupies.  A not-yet-forked
/// fork parent reserves all `n_best` slots its branches will take, so
/// the fork in phase 5 can never push the active set past the bound —
/// the moment it forks, the parent's weight n is replaced by n branches
/// of weight 1 and the total is unchanged.
fn slot_weight(sess: &ActiveSession) -> usize {
    if sess.is_decoding() {
        1
    } else {
        sess.req.n_best.max(1)
    }
}

/// What a reap check decided for one queued job or active session.
fn reap_reason(cancel: &AtomicBool, deadline_at: Option<Instant>) -> Option<FinishReason> {
    if cancel.load(Ordering::Acquire) {
        Some(FinishReason::Cancelled)
    } else if matches!(deadline_at, Some(d) if Instant::now() >= d) {
        Some(FinishReason::DeadlineExceeded)
    } else {
        None
    }
}

/// Map a session's exhausted [`SessionFault`] onto its terminal
/// outcome: a numeric fault is a *typed* finish (the tokens generated
/// before the fault are healthy — every committed token passed the
/// guards); panics and model-returned errors surface as stream errors.
fn fault_outcome(f: SessionFault) -> Result<FinishReason> {
    match f {
        SessionFault::Numeric => Ok(FinishReason::NumericFault),
        // a retry abandoned at the deadline is the deadline's typed
        // finish, not an opaque error — the committed tokens are healthy
        SessionFault::DeadlineExceeded => Ok(FinishReason::DeadlineExceeded),
        other => Err(anyhow!(other)),
    }
}

/// Static name for a session outcome — the `reason` arg of the trace
/// ring's [`TraceEventKind::Terminal`] marker.
fn finish_name(outcome: &Result<FinishReason>) -> &'static str {
    match outcome {
        Ok(r) => r.as_str(),
        Err(_) => "error",
    }
}

/// Terminal [`GenResponse`] for a job that dies in queue (reaped, shed,
/// or failed without admission).  Redrive-aware: a requeued redrive
/// already streamed tokens and burned prefill/decode time in its first
/// life — its queued terminal must report them, on its own branch.
fn job_response(job: &Job, finish: FinishReason) -> GenResponse {
    let (branch, tokens, prefill_seconds, decode_seconds, ttft_seconds) = match &job.redrive {
        Some(rd) => (
            rd.branch,
            job.req.prompt[rd.orig_prompt_len..].to_vec(),
            rd.prefill_seconds,
            rd.decode_seconds,
            rd.ttft_seconds,
        ),
        None => (0, Vec::new(), 0.0, 0.0, 0.0),
    };
    GenResponse {
        request_id: job.id,
        branch,
        tokens,
        finish,
        prefill_seconds,
        decode_seconds,
        queue_seconds: job.enqueued_at.elapsed().as_secs_f64(),
        ttft_seconds,
        cached_prefix_tokens: 0,
    }
}

/// Fold a finished session into `Metrics` and emit its terminal event
/// (plus the trace ring's [`TraceEventKind::Terminal`] marker closing
/// the session's async span).
fn complete(
    slot: Slot,
    outcome: Result<FinishReason>,
    metrics: &Arc<Mutex<Metrics>>,
    tracer: &Tracer,
    cycle: u64,
) {
    let Slot { sess, events, .. } = slot;
    {
        let mut m = lock(metrics);
        m.completed += 1;
        m.prio(sess.req.priority).completed += 1;
        m.tokens_generated += sess.generated.len() as u64;
        m.decode_seconds_total += sess.decode_seconds;
        m.prefill_seconds_total += sess.prefill_seconds;
        // TTFT only for sessions that sampled a first token — a prefill
        // failure or pre-decode reap completes without one and must not
        // drag the mean toward zero.  Checked via the recorded value,
        // not the phase: a redriven session reaped mid-replay carries
        // its pre-crash TTFT without being Decoding yet.  The histogram
        // folds at the same single point, so a redriven session (whose
        // first life never reaches `complete`) counts its whole-request
        // TTFT exactly once.
        if sess.ttft_seconds > 0.0 {
            m.first_tokens += 1;
            m.ttft_seconds_total += sess.ttft_seconds;
            m.ttft_hist.record_seconds(sess.ttft_seconds);
        }
        if sess.redrive_attempt > 0
            && matches!(&outcome, Ok(FinishReason::MaxTokens | FinishReason::StopToken))
        {
            m.redrives_completed += 1;
        }
        match &outcome {
            Ok(FinishReason::NumericFault) => m.numeric_faulted += 1,
            Ok(FinishReason::WorkerFailed) => m.worker_failed += 1,
            Ok(FinishReason::Shed) => m.shed += 1,
            Ok(FinishReason::Cancelled) => m.cancelled += 1,
            Ok(FinishReason::DeadlineExceeded) => m.deadline_exceeded += 1,
            _ => {}
        }
    }
    tracer.instant(
        sess.request_id,
        sess.branch as u32,
        cycle,
        TraceEventKind::Terminal { reason: finish_name(&outcome) },
    );
    match outcome {
        Ok(reason) => {
            let _ = events.send(GenEvent::Finished(GenResponse {
                request_id: sess.request_id,
                branch: sess.branch,
                tokens: sess.generated,
                finish: reason,
                prefill_seconds: sess.prefill_seconds,
                decode_seconds: sess.decode_seconds,
                queue_seconds: (sess.started_at - sess.enqueued_at).as_secs_f64(),
                ttft_seconds: sess.ttft_seconds,
                cached_prefix_tokens: sess.cached_prefix_tokens,
            }));
        }
        Err(e) => {
            let _ = events.send(GenEvent::Error { branch: sess.branch, message: format!("{e:#}") });
        }
    }
}

/// The scheduling loop proper.  `active` and `queue` are owned by the
/// supervisor in [`Coordinator::spawn_with`] — they must survive a
/// panicking cycle so the supervisor can terminate every session they
/// hold with a typed event instead of letting the Senders die silently.
fn worker_loop<M: EngineModel>(
    engine: &mut Engine<M>,
    active: &mut Vec<Slot>,
    queue: &mut VecDeque<Job>,
    rx: &Receiver<Job>,
    cfg: &CoordinatorConfig,
    metrics: &Arc<Mutex<Metrics>>,
    queue_depth: &Arc<AtomicUsize>,
    tracer: &Tracer,
    quota: &Arc<QuotaBook>,
) {
    loop {
        // scheduling-cycle counter: the `cycle` axis of fault-journal
        // attribution (idle blocking below still counts as one cycle —
        // the loop only comes back around when there is work)
        engine.begin_cycle();
        let cycle = engine.cycle();

        // 1a. pull everything currently queued (block only when idle)
        loop {
            match rx.try_recv() {
                Ok(job) => queue.push_back(job),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    if active.is_empty() && queue.is_empty() {
                        return;
                    }
                    break;
                }
            }
        }
        if active.is_empty() && queue.is_empty() {
            // idle: block for the next job (or shut down)
            match rx.recv() {
                Ok(job) => queue.push_back(job),
                Err(_) => return,
            }
        }
        // the admission span opens AFTER the idle block: time spent
        // parked on an empty queue is not scheduling work, and folding
        // it in would make every first-request cycle look pathological
        let t_admission = tracer.now_us();

        // 1b. reap queued jobs whose stream was cancelled/dropped or
        //     whose deadline expired before admission: terminate with
        //     the proper reason, zero tokens, never taking a slot
        {
            let mut i = 0;
            while i < queue.len() {
                let reason = reap_reason(&queue[i].cancel, queue[i].deadline_at);
                let Some(reason) = reason else {
                    i += 1;
                    continue;
                };
                let job = queue.remove(i).expect("index in bounds");
                queue_depth.fetch_sub(1, Ordering::AcqRel);
                quota.release_job(&job);
                {
                    let mut m = lock(metrics);
                    m.completed += 1;
                    m.prio(job.req.priority).completed += 1;
                    match reason {
                        FinishReason::Cancelled => m.cancelled += 1,
                        _ => m.deadline_exceeded += 1,
                    }
                }
                // close the async trace span a queued death leaves open
                tracer.instant(
                    job.id,
                    0,
                    cycle,
                    TraceEventKind::Terminal { reason: finish_name(&Ok(reason)) },
                );
                let _ = job.events.send(GenEvent::Finished(job_response(&job, reason)));
            }
        }

        // 1c. shed under overload: while the queue sits above the
        //     watermark, drop the lowest-priority queued request
        //     (latest-submitted within that level — it has waited the
        //     least) with a typed Shed terminal and zero tokens.  This
        //     runs after the reap so a cancelled/expired job still gets
        //     its proper reason, and before admission so shed work
        //     never takes a slot or a prefill cycle.
        while cfg.shed_watermark > 0 && queue.len() > cfg.shed_watermark {
            // requeued redrives are not shed candidates: their tokens
            // are already streamed and the client was promised a
            // continuation — shedding one would break the event
            // contract to shave queue depth it barely contributes to
            let victim = (0..queue.len())
                .filter(|&i| queue[i].redrive.is_none())
                .min_by_key(|&i| (queue[i].req.priority, std::cmp::Reverse(i)));
            let Some(victim) = victim else {
                break; // only redrives queued: nothing sheddable
            };
            let job = queue.remove(victim).expect("index in bounds");
            queue_depth.fetch_sub(1, Ordering::AcqRel);
            quota.release_job(&job);
            {
                let mut m = lock(metrics);
                m.completed += 1;
                m.shed += 1;
                let p = m.prio(job.req.priority);
                p.completed += 1;
                p.shed += 1;
            }
            tracer.instant(
                job.id,
                0,
                cycle,
                TraceEventKind::Terminal { reason: finish_name(&Ok(FinishReason::Shed)) },
            );
            let _ = job.events.send(GenEvent::Finished(job_response(&job, FinishReason::Shed)));
        }

        // 2. reap active sessions: cancellation and deadlines take
        //    effect at this cycle boundary — the slot frees, pinned
        //    snapshots release with the session, partial tokens return.
        //    Reaping precedes admission so a freed slot is refilled in
        //    the SAME cycle, not one cycle late.
        {
            let mut i = 0;
            while i < active.len() {
                let reason = reap_reason(&active[i].cancel, active[i].deadline_at);
                let Some(reason) = reason else {
                    i += 1;
                    continue;
                };
                let slot = active.remove(i);
                complete(slot, Ok(reason), metrics, tracer, cycle);
            }
        }

        // 3. admit while slots remain — highest priority first, FIFO
        //    within a level; bookkeeping only (prefill happens
        //    chunk-by-chunk in phase 4), so admission can never stall
        //    the sessions already in flight.  Slots are counted by
        //    [`slot_weight`]: a fork request reserves all n_best of its
        //    future branch slots at admission, so the active set never
        //    exceeds max_active even mid-fork.  Admission stops at the
        //    first candidate that doesn't fit (no thinner-job bypass:
        //    that would starve wide forks behind a stream of singles).
        let mut used: usize = active.iter().map(|sl| slot_weight(&sl.sess)).sum();
        while !queue.is_empty() {
            let best = (0..queue.len())
                .max_by_key(|&i| (queue[i].req.priority, std::cmp::Reverse(i)))
                .expect("queue is non-empty");
            let weight = queue[best].req.n_best.max(1);
            if used + weight > cfg.max_active {
                break;
            }
            used += weight;
            let job = queue.remove(best).expect("index in bounds");
            queue_depth.fetch_sub(1, Ordering::AcqRel);
            quota.release_job(&job);
            let priority = job.req.priority;
            let queue_s = job.enqueued_at.elapsed().as_secs_f64();
            let mut sess = engine.admit(job.id, job.req, job.enqueued_at);
            match job.redrive {
                Some(rd) => {
                    // continuation, not a fresh request: no Started (the
                    // client saw one in the session's first life), no
                    // admitted/queue-wait accounting (already counted),
                    // and the session is stitched onto its streamed
                    // history — `seq_idx` continues at replayed_from
                    engine.resume_redriven(
                        &mut sess,
                        rd.branch,
                        rd.attempt,
                        rd.orig_prompt_len,
                        rd.failed_at,
                    );
                    sess.ttft_seconds = rd.ttft_seconds;
                    sess.prefill_seconds += rd.prefill_seconds;
                    sess.decode_seconds += rd.decode_seconds;
                    tracer.instant(
                        sess.request_id,
                        sess.branch as u32,
                        cycle,
                        TraceEventKind::Admit {
                            cached_prefix_tokens: sess.cached_prefix_tokens as u32,
                            redrive: true,
                        },
                    );
                }
                None => {
                    {
                        let mut m = lock(metrics);
                        m.admitted += 1;
                        m.prio(priority).admitted += 1;
                        m.queue_seconds_total += queue_s;
                        // same single accounting point as `admitted`, so
                        // the histogram's count stays equal to it — a
                        // redrive re-admission never lands here
                        m.queue_wait_hist.record_seconds(queue_s);
                    }
                    tracer.instant(
                        sess.request_id,
                        0,
                        cycle,
                        TraceEventKind::Admit {
                            cached_prefix_tokens: sess.cached_prefix_tokens as u32,
                            redrive: false,
                        },
                    );
                    let _ = job.events.send(GenEvent::Started {
                        branch: 0,
                        cached_prefix_tokens: sess.cached_prefix_tokens,
                    });
                }
            }
            active.push(Slot {
                sess,
                events: job.events,
                cancel: job.cancel,
                deadline_at: job.deadline_at,
            });
        }

        tracer.span(t_admission, 0, 0, cycle, TraceEventKind::CyclePhase(CyclePhaseKind::Admission));

        // 4. prefill cycle: every Prefilling session consumes one
        //    bounded sequence-parallel chunk of its prompt (§Perf L3-4).
        //    A session whose prompt completes this cycle samples its
        //    first token and joins the decode batch below immediately.
        let t_prefill = tracer.now_us();
        let mut did_prefill = false;
        {
            let mut failed: Vec<(usize, Result<FinishReason>)> = Vec::new();
            let mut chunk_secs: Vec<f64> = Vec::new();
            for (i, slot) in active.iter_mut().enumerate() {
                if !slot.sess.is_prefilling() {
                    continue;
                }
                let t_chunk = Instant::now();
                let tick = engine.prefill_tick(&mut slot.sess, cfg.prefill_chunk);
                chunk_secs.push(t_chunk.elapsed().as_secs_f64());
                if let Err(f) = tick {
                    failed.push((i, fault_outcome(f)));
                }
            }
            if !chunk_secs.is_empty() {
                did_prefill = true;
                let mut m = lock(metrics);
                for s in chunk_secs {
                    m.prefill_chunk_hist.record_seconds(s);
                }
            }
            for (i, outcome) in failed.into_iter().rev() {
                let slot = active.remove(i);
                complete(slot, outcome, metrics, tracer, cycle);
            }
        }
        if did_prefill {
            // skipped on pure-decode cycles: an empty zero-length slice
            // every cycle would evict real events from the ring
            tracer.span(t_prefill, 0, 0, cycle, TraceEventKind::CyclePhase(CyclePhaseKind::Prefill));
        }

        // 5. fork cycle: prompts that completed with n_best > 1 spawn
        //    their decoding branches — ONE prefill total, one shared
        //    pinned snapshot, distinct sampler seeds.  Branches join at
        //    the tail of the active list and decode this same cycle.
        {
            let mut i = 0;
            while i < active.len() {
                if !active[i].sess.is_fork_ready() {
                    i += 1;
                    continue;
                }
                let Slot { sess, events, cancel, deadline_at } = active.remove(i);
                let cached = sess.cached_prefix_tokens;
                for branch in engine.fork(sess) {
                    if branch.branch > 0 {
                        // branch 0 inherited the parent's Started event
                        let _ = events.send(GenEvent::Started {
                            branch: branch.branch,
                            cached_prefix_tokens: cached,
                        });
                    }
                    active.push(Slot {
                        sess: branch,
                        events: events.clone(),
                        cancel: cancel.clone(),
                        deadline_at,
                    });
                }
            }
        }

        // 6. decode cycle: commit every decoding session's pending token
        //    in admission order — each commit streams a Token event —
        //    then advance all continuing sessions with ONE batched
        //    forward (§Perf L3-3 weight-reuse amortization).  Sessions
        //    still prefilling are skipped.
        let mut finished: Vec<(usize, Result<FinishReason>)> = Vec::new();
        // did this cycle run a batched decode forward?  Each one streams
        // every weight plane exactly once regardless of batch width —
        // the weight-reuse fact the traffic metric below accounts
        let mut did_decode = false;
        // inter-token gaps and this cycle's fused-forward duration,
        // folded into the histograms under ONE lock in phase 7
        let mut token_gaps: Vec<f64> = Vec::new();
        let mut decode_cycle_s: Option<f64> = None;
        {
            let mut live: Vec<(usize, &mut ActiveSession)> = Vec::new();
            for (i, slot) in active.iter_mut().enumerate() {
                if !slot.sess.is_decoding() {
                    continue;
                }
                let outcome = engine.commit_pending(&mut slot.sess);
                let tok = *slot.sess.generated.last().expect("commit_pending pushed a token");
                let _ = slot.events.send(GenEvent::Token {
                    branch: slot.sess.branch,
                    token: tok,
                    seq_idx: slot.sess.generated.len() - 1,
                });
                // inter-token gap: commit-to-commit on the same session.
                // The clock starts at the SECOND commit (TTFT owns the
                // first) and resets across a redrive seam, so the crash
                // stall shows up in redrive_resume_seconds, not here.
                let now = Instant::now();
                if let Some(prev) = slot.sess.last_token_at.replace(now) {
                    token_gaps.push((now - prev).as_secs_f64());
                }
                // first NOVEL token after a redrive (replayed tokens are
                // never re-committed): close out the resume-after-fault
                // latency window opened at the crash
                if let Some(failed_at) = slot.sess.redriven_at.take() {
                    let mut m = lock(metrics);
                    m.redrives_resumed += 1;
                    m.redrive_resume_seconds_total += failed_at.elapsed().as_secs_f64();
                }
                match outcome {
                    Some(reason) => finished.push((i, Ok(reason))),
                    None => live.push((i, &mut slot.sess)),
                }
            }
            if !live.is_empty() {
                did_decode = true;
                let t_step = Instant::now();
                let errs = {
                    let mut batch: Vec<&mut ActiveSession> =
                        live.iter_mut().map(|(_, s)| &mut **s).collect();
                    engine.step_batch(&mut batch)
                };
                decode_cycle_s = Some(t_step.elapsed().as_secs_f64());
                // per-session outcomes: a faulting session finishes with
                // its own typed terminal, its batchmates keep generating
                for ((i, _), err) in live.into_iter().zip(errs) {
                    if let Some(f) = err {
                        finished.push((i, fault_outcome(f)));
                    }
                }
            }
        }
        finished.sort_by_key(|&(i, _)| i);
        // 7. drain observability counters BEFORE completing, so a client
        //    woken by its terminal event observes metrics that already
        //    include its session's work: the hw backend's cumulative
        //    9-bit clip total, the engine's ground-truth prefilled-token
        //    count, the prefix/decode cache counters (mirrored wholesale
        //    — the worker owns the engine, so the engine-side totals are
        //    authoritative), and the pressure gauges
        let t_maint = tracer.now_us();
        {
            let mut m = lock(metrics);
            m.clip_events += engine.model.take_clip_events();
            if did_decode {
                m.decode_cycles += 1;
                m.weight_bytes_streamed += engine.model.weight_stream_bytes();
            }
            for g in &token_gaps {
                m.inter_token_hist.record_seconds(*g);
            }
            if let Some(s) = decode_cycle_s {
                m.decode_cycle_hist.record_seconds(s);
            }
            let (trace_recorded, trace_dropped) = tracer.stats();
            m.trace_events = trace_recorded;
            m.trace_events_dropped = trace_dropped;
            m.prompt_tokens_prefilled = engine.prefilled_tokens();
            let fs = engine.fault_stats();
            m.fault_retries = fs.retries;
            m.fault_rollbacks = fs.rollbacks;
            m.panics_caught = fs.panics_caught;
            m.numeric_faults_detected = fs.numeric_faults;
            if let Some(cs) = engine.cache_stats() {
                m.prefix_cache_hits = cs.hits;
                m.prefix_cache_misses = cs.misses;
                m.prefix_tokens_skipped = cs.tokens_skipped;
                m.prefix_cache_bytes = cs.bytes_resident;
                m.prefix_cache_entries = cs.entries;
                m.prefix_cache_evictions = cs.evictions;
                m.prefix_cache_pinned = cs.pinned;
                m.prefix_cache_quarantined = cs.quarantined;
            }
            {
                let j = engine.journal();
                let j = j.lock().unwrap_or_else(PoisonError::into_inner);
                m.fault_events = j.recorded();
                m.fault_events_dropped = j.dropped();
            }
            m.queue_depth = queue_depth.load(Ordering::Acquire) as u64;
            m.active_sessions = (active.len() - finished.len()) as u64;
            // per-level queued gauges mirror the quota book (drained
            // levels report 0 — the book keeps every level it has seen)
            for (level, queued) in quota.snapshot() {
                m.prio(level).queued = queued as u64;
            }
        }
        tracer.span(t_maint, 0, 0, cycle, TraceEventKind::CyclePhase(CyclePhaseKind::Maintenance));
        // 8. complete (reverse order keeps indices valid)
        for (i, outcome) in finished.into_iter().rev() {
            let slot = active.remove(i);
            complete(slot, outcome, metrics, tracer, cycle);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::rwkv::testing::test_model;

    fn coordinator(max_active: usize) -> Coordinator {
        Coordinator::spawn(
            test_model(2, 32, 64, 50),
            CoordinatorConfig { max_active, ..Default::default() },
        )
    }

    #[test]
    fn serves_one_request() {
        let c = coordinator(4);
        let r = c.generate(GenRequest::greedy(vec![1, 2], 6)).unwrap();
        assert_eq!(r.tokens.len(), 6);
        assert_eq!(r.finish, super::super::FinishReason::MaxTokens);
        assert_eq!(r.branch, 0);
        assert!(r.ttft_seconds > 0.0, "ttft must be recorded");
        assert!(r.ttft_seconds <= r.queue_seconds + r.prefill_seconds + r.decode_seconds + 1.0);
    }

    #[test]
    fn priority_quota_rejects_and_releases() {
        let c = Coordinator::spawn(
            test_model(2, 32, 64, 50),
            CoordinatorConfig {
                max_active: 1,
                priority_quotas: vec![(-1, 0), (0, 1)],
                ..Default::default()
            },
        );
        // a level with quota 0 can never queue, independent of global room
        let err = c
            .submit(GenRequest::builder(vec![1, 2], 4).priority(-1).build())
            .err()
            .expect("quota 0 must reject");
        assert_eq!(err, SubmitError::QuotaExceeded { priority: -1, limit: 0 });
        // admission releases the reservation: sequential requests at a
        // quota-1 level all pass because each one leaves the queue
        // before the next submits
        for _ in 0..3 {
            let r = c.generate(GenRequest::builder(vec![1, 2], 4).priority(0).build()).unwrap();
            assert_eq!(r.tokens.len(), 4);
        }
        let m = c.metrics.lock().unwrap().clone();
        assert_eq!(m.quota_rejected, 1);
        assert_eq!(m.per_priority[&-1].quota_rejected, 1);
        let p0 = &m.per_priority[&0];
        assert_eq!((p0.enqueued, p0.admitted, p0.completed), (3, 3, 3));
        c.shutdown();
    }

    #[test]
    fn stream_delivers_every_token_before_finished() {
        let c = coordinator(2);
        let mut stream = c.submit(GenRequest::greedy(vec![1, 2, 3], 7)).unwrap();
        let mut started = false;
        let mut streamed: Vec<u32> = Vec::new();
        let mut finished: Option<GenResponse> = None;
        while let Some(ev) = stream.recv() {
            match ev {
                GenEvent::Started { branch, cached_prefix_tokens } => {
                    assert_eq!(branch, 0);
                    assert_eq!(cached_prefix_tokens, 0);
                    assert!(!started, "exactly one Started");
                    started = true;
                }
                GenEvent::Token { branch, token, seq_idx } => {
                    assert_eq!(branch, 0);
                    assert!(finished.is_none(), "no tokens after Finished");
                    assert_eq!(seq_idx, streamed.len(), "tokens arrive in order");
                    streamed.push(token);
                }
                GenEvent::Finished(r) => {
                    assert!(finished.is_none());
                    finished = Some(r);
                }
                GenEvent::Error { message, .. } => panic!("unexpected error: {message}"),
                GenEvent::Redriven { .. } => panic!("no redrive in a fault-free run"),
            }
        }
        assert!(started);
        let r = finished.expect("stream must finish");
        assert_eq!(r.tokens.len(), 7);
        assert_eq!(streamed, r.tokens, "every sampled token was streamed before Finished");
        assert!(stream.recv().is_none(), "stream is exhausted");
    }

    #[test]
    fn prompt_longer_than_chunk_is_served_across_cycles() {
        // prompt of 45 tokens at chunk 8 → 6 prefill cycles, then decode;
        // output must match a solo run with whole-prompt prefill
        let prompt: Vec<u32> = (0..45u32).map(|t| (t * 7 + 3) % 50).collect();
        let solo = {
            let c = coordinator(1);
            c.generate(GenRequest::greedy(prompt.clone(), 6)).unwrap().tokens
        };
        let c = Coordinator::spawn(
            test_model(2, 32, 64, 50),
            CoordinatorConfig { max_active: 4, prefill_chunk: 8, ..Default::default() },
        );
        let r = c.generate(GenRequest::greedy(prompt, 6)).unwrap();
        assert_eq!(r.tokens, solo);
        let m = c.metrics.lock().unwrap();
        assert!(m.ttft_seconds_total > 0.0);
        assert_eq!(m.prompt_tokens_prefilled, 45);
    }

    #[test]
    fn concurrent_requests_all_complete() {
        let c = coordinator(3);
        let rxs: Vec<_> = (0..10)
            .map(|i| c.submit(GenRequest::greedy(vec![1 + i as u32], 5)).unwrap())
            .collect();
        for rx in rxs {
            let r = rx.wait_one().unwrap();
            assert_eq!(r.tokens.len(), 5);
        }
        let m = c.metrics.lock().unwrap();
        assert_eq!(m.completed, 10);
        assert_eq!(m.tokens_generated, 50);
        assert_eq!(m.active_sessions, 0);
        assert_eq!(m.queue_depth, 0);
    }

    #[test]
    fn batched_output_matches_solo_output() {
        // continuous batching must not change any session's tokens
        let solo = {
            let c = coordinator(1);
            c.generate(GenRequest::greedy(vec![5, 6, 7], 8)).unwrap().tokens
        };
        let c = coordinator(4);
        // fill the batch with interference
        let _noise1 = c.submit(GenRequest::greedy(vec![9], 8)).unwrap();
        let _noise2 = c.submit(GenRequest::greedy(vec![11, 12], 8)).unwrap();
        let got = c.generate(GenRequest::greedy(vec![5, 6, 7], 8)).unwrap().tokens;
        assert_eq!(got, solo);
    }

    #[test]
    fn shared_prefix_requests_hit_cache_with_identical_tokens() {
        // same 40-token prompt, served back to back: the second request
        // must resume from a cached chunk boundary (prefilling only the
        // tail) and still produce identical tokens; a third request
        // extending the prompt reuses the full-prompt snapshot
        let prompt: Vec<u32> = (0..40u32).map(|t| (t * 3 + 2) % 50).collect();
        let cold = {
            let c = Coordinator::spawn(
                test_model(2, 32, 64, 50),
                CoordinatorConfig {
                    max_active: 4,
                    prefill_chunk: 8,
                    state_cache_bytes: 0,
                    ..Default::default()
                },
            );
            c.generate(GenRequest::greedy(prompt.clone(), 6)).unwrap()
        };
        assert_eq!(cold.cached_prefix_tokens, 0, "cache disabled must never resume");

        let c = Coordinator::spawn(
            test_model(2, 32, 64, 50),
            CoordinatorConfig { max_active: 4, prefill_chunk: 8, ..Default::default() },
        );
        let r1 = c.generate(GenRequest::greedy(prompt.clone(), 6)).unwrap();
        let r2 = c.generate(GenRequest::greedy(prompt.clone(), 6)).unwrap();
        let mut extended = prompt.clone();
        extended.extend_from_slice(&[5, 6]);
        let r3 = c.generate(GenRequest::greedy(extended, 6)).unwrap();
        assert_eq!(r1.cached_prefix_tokens, 0);
        assert_eq!(r1.tokens, cold.tokens);
        // boundaries at 8,16,24,32,40; lookup capped at 39 → resume at 32
        assert_eq!(r2.cached_prefix_tokens, 32);
        assert_eq!(r2.tokens, cold.tokens);
        // the extended prompt reuses the full 40-token snapshot
        assert_eq!(r3.cached_prefix_tokens, 40);
        let m = c.metrics.lock().unwrap();
        assert_eq!(m.prefix_cache_hits, 2);
        assert_eq!(m.prefix_cache_misses, 1);
        assert_eq!(m.prefix_tokens_skipped, 72);
        assert!(m.prefix_cache_entries > 0);
        assert!(m.prefix_cache_bytes > 0);
    }

    #[test]
    fn shutdown_joins_cleanly() {
        let c = coordinator(2);
        let _ = c.generate(GenRequest::greedy(vec![1], 2)).unwrap();
        c.shutdown();
    }

    #[test]
    fn submit_after_worker_death_is_impossible_by_construction() {
        // the sender lives exactly as long as the Coordinator: dropping
        // it is the one close path, so ShutDown is unreachable through a
        // live handle — this pins the close-and-join refactor
        let c = coordinator(1);
        let s = c.submit(GenRequest::greedy(vec![1], 2)).unwrap();
        let r = s.wait_one().unwrap();
        assert_eq!(r.tokens.len(), 2);
        c.shutdown();
    }

    #[test]
    fn best_of_n_through_coordinator_matches_sequential() {
        let prompt: Vec<u32> = (0..12u32).map(|t| (t * 5 + 1) % 50).collect();
        let n = 4usize;
        let mk = |seed: u64, n_best: usize| {
            GenRequest::builder(prompt.clone(), 6)
                .temperature(0.9)
                .top_k(16)
                .seed(seed)
                .n_best(n_best)
                .build()
        };
        let solo: Vec<Vec<u32>> = (0..n as u64)
            .map(|b| coordinator(1).generate(mk(30 + b, 1)).unwrap().tokens)
            .collect();
        let c = coordinator(8);
        let rs = c.generate_all(mk(30, n)).unwrap();
        assert_eq!(rs.len(), n);
        for (b, r) in rs.iter().enumerate() {
            assert_eq!(r.branch, b);
            assert_eq!(r.tokens, solo[b], "branch {b} diverged from its sequential run");
        }
        // exactly one prompt prefill for all n branches
        let m = c.metrics.lock().unwrap();
        assert_eq!(m.prompt_tokens_prefilled, prompt.len() as u64);
        assert_eq!(m.first_tokens, n as u64);
    }

    #[test]
    fn n_best_is_clamped_to_max_active() {
        // a fork wider than max_active would break the concurrency and
        // memory bound the slot limit exists to hold — submit clamps it
        let c = coordinator(2);
        let req = GenRequest::builder(vec![1, 2, 3], 3)
            .temperature(0.5)
            .top_k(4)
            .seed(1)
            .n_best(64)
            .build();
        let stream = c.submit(req).unwrap();
        assert_eq!(stream.n_best(), 2, "fork width must clamp to max_active");
        let rs: Vec<GenResponse> = stream
            .wait()
            .into_iter()
            .collect::<Result<Vec<_>>>()
            .unwrap();
        assert_eq!(rs.len(), 2);
        assert_eq!(rs[1].branch, 1);
        let m = c.metrics.lock().unwrap();
        assert_eq!(m.first_tokens, 2, "exactly the clamped branch count decodes");
    }

    #[test]
    fn disconnected_sender_synthesizes_one_terminal_per_open_branch() {
        // the stream-hang regression: if the worker's Sender dies with
        // branches still open, recv must synthesize terminals — never
        // block forever, never return None early
        let mk_resp = |branch: usize, finish: FinishReason, tokens: Vec<u32>| GenResponse {
            request_id: 1,
            branch,
            tokens,
            finish,
            prefill_seconds: 0.0,
            decode_seconds: 0.0,
            queue_seconds: 0.0,
            ttft_seconds: 0.0,
            cached_prefix_tokens: 0,
        };
        let mk_stream = |n: usize, rx| GenStream {
            request_id: 1,
            n_best: n,
            rx,
            cancel: Arc::new(AtomicBool::new(false)),
            branch_done: vec![false; n],
            mirror: None,
            closed: false,
        };

        // whole-request terminal on branch 0 → mirrored (empty tokens)
        // onto the never-born branches 1 and 2
        let (tx, rx) = channel();
        let mut s = mk_stream(3, rx);
        tx.send(GenEvent::Started { branch: 0, cached_prefix_tokens: 0 }).unwrap();
        tx.send(GenEvent::Finished(mk_resp(0, FinishReason::WorkerFailed, vec![7]))).unwrap();
        drop(tx);
        let mut finishes = Vec::new();
        while let Some(ev) = s.recv() {
            if let GenEvent::Finished(r) = ev {
                finishes.push((r.branch, r.finish, r.tokens));
            }
        }
        assert_eq!(
            finishes,
            vec![
                (0, FinishReason::WorkerFailed, vec![7]),
                (1, FinishReason::WorkerFailed, vec![]),
                (2, FinishReason::WorkerFailed, vec![]),
            ]
        );
        assert!(s.recv().is_none(), "exhausted stream stays exhausted");

        // no whole-request terminal at all → typed Error per branch,
        // and wait() still returns one outcome per branch
        let (tx, rx) = channel();
        let s = mk_stream(2, rx);
        tx.send(GenEvent::Started { branch: 0, cached_prefix_tokens: 0 }).unwrap();
        drop(tx);
        let outcomes = s.wait();
        assert_eq!(outcomes.len(), 2);
        for (b, o) in outcomes.iter().enumerate() {
            let e = o.as_ref().expect_err("open branch must surface a disconnect error");
            assert!(
                e.to_string().contains("worker connection lost"),
                "branch {b}: unexpected error {e}"
            );
        }
    }

    #[test]
    fn native_backends_serve_identically_and_report_traffic() {
        // spawn_native over each backend: packed tokens must equal hw
        // tokens (one value grid), and the per-decode-cycle weight
        // traffic must show the 2-byte-vs-4-byte cut
        let calib: Vec<u32> = (0..64u32).map(|i| (i * 11 + 3) % 50).collect();
        let mk = |backend| {
            Coordinator::spawn_native(
                test_model(2, 32, 64, 50),
                calib.clone(),
                CoordinatorConfig { max_active: 2, backend, ..Default::default() },
            )
        };
        let run = |c: &Coordinator| c.generate(GenRequest::greedy(vec![1, 2, 3], 6)).unwrap();
        let ch = mk(Backend::Hw);
        let hw_tokens = run(&ch).tokens;
        let (hw_cycles, hw_bytes) = {
            let m = ch.metrics.lock().unwrap();
            (m.decode_cycles, m.weight_bytes_streamed)
        };
        assert!(hw_cycles > 0, "decode cycles must be counted");
        let cp = mk(Backend::Packed);
        let packed_tokens = run(&cp).tokens;
        assert_eq!(packed_tokens, hw_tokens, "packed serving diverged from hw");
        let m = cp.metrics.lock().unwrap();
        assert!(m.decode_cycles > 0);
        let hw_per_cycle = hw_bytes / hw_cycles;
        let packed_per_cycle = m.weight_bytes_streamed / m.decode_cycles;
        assert_eq!(packed_per_cycle * 2, hw_per_cycle, "packed must stream half the bytes");
        drop(m);
        // the exact backend serves fine too (different numerics, so
        // only the shape is asserted) and streams the f32 figure
        let ce = mk(Backend::Exact);
        let r = run(&ce);
        assert_eq!(r.tokens.len(), 6);
        let m = ce.metrics.lock().unwrap();
        assert_eq!(m.weight_bytes_streamed / m.decode_cycles, hw_per_cycle);
    }

    #[test]
    fn hw_clip_totals_drain_into_metrics() {
        use crate::model::HwModel;
        // per-session clip trajectories are batching-invariant (batched
        // decode and chunked prefill are bit-exact with solo decode), so
        // the coordinator's drained total must equal the sum of solo
        // runs of the same requests
        let calib: Vec<u32> = (0..64u32).map(|i| (i * 11 + 3) % 50).collect();
        let mk = || HwModel::from_f32(test_model(2, 32, 64, 50), &calib);
        let reqs: Vec<GenRequest> = (0..3u32)
            .map(|i| GenRequest::greedy(vec![(i + 1) % 50, (i * 7 + 2) % 50], 6))
            .collect();
        let expected = {
            let mut eng = Engine::new(mk());
            for (i, r) in reqs.iter().enumerate() {
                let mut s = eng.start(i as u64, r.clone(), Instant::now()).unwrap();
                while eng.step_session(&mut s).unwrap().is_none() {}
            }
            eng.model.take_clip_events()
        };
        let c = Coordinator::spawn(
            mk(),
            CoordinatorConfig { max_active: 4, prefill_chunk: 4, ..Default::default() },
        );
        let rxs: Vec<_> = reqs.iter().map(|r| c.submit(r.clone()).unwrap()).collect();
        for rx in rxs {
            rx.wait_one().unwrap();
        }
        let m = c.metrics.lock().unwrap();
        assert_eq!(m.clip_events, expected);
    }
}
