//! L3 serving coordinator — the streaming request path.
//!
//! # Session lifecycle: submit → events → finish/cancel
//!
//! The API is a **streaming session** per request.  [`Coordinator::submit`]
//! reserves a slot in a *bounded* admission queue (or rejects with
//! [`SubmitError::QueueFull`] — backpressure is explicit, the queue never
//! grows without bound) and returns a [`scheduler::GenStream`] handle that
//! yields [`GenEvent`]s as the worker makes progress:
//!
//! 1. [`GenEvent::Started`] — the session was admitted (branch 0) or a
//!    best-of-n branch was forked (branches 1..n), reporting how many
//!    prompt tokens were skipped via cached state;
//! 2. one [`GenEvent::Token`] per sampled token, in order, *as it is
//!    committed* — a client renders tokens live instead of waiting for
//!    the whole generation;
//! 3. one terminal event per branch: [`GenEvent::Finished`] with the
//!    aggregated [`GenResponse`], or [`GenEvent::Error`].  This holds
//!    even when the worker never sent one: a request reaped *before its
//!    branches exist* (cancelled, expired or shed while still queued,
//!    or before the fork) terminates on branch 0 only, and
//!    [`scheduler::GenStream::recv`] synthesizes the missing branch
//!    terminals from that whole-request terminal (or a disconnect
//!    error) once the worker's channel closes — `recv` never blocks
//!    forever and `None` means every branch already terminated.
//!
//! A stream can be ended early: [`scheduler::GenStream::cancel`] (or simply
//! dropping the stream) flags the session, and the worker reaps it at the
//! next scheduling-cycle boundary — the `max_active` slot frees, pinned
//! snapshots release, batchmates are untouched, and the partial output
//! comes back with [`FinishReason::Cancelled`].  A request can also carry
//! a wall-clock deadline ([`GenRequestBuilder::deadline`]) enforced by the
//! scheduler at the same boundaries, queued or active, finishing with
//! [`FinishReason::DeadlineExceeded`].  [`Coordinator::generate`] remains
//! a thin blocking wrapper over the stream for callers that only want the
//! final response.
//!
//! # Best-of-n: forking decode off one shared RWKV state
//!
//! A request built with [`GenRequestBuilder::n_best`]` = N` prefills its
//! prompt **once**, snapshots the post-prompt recurrent state (O(1)
//! bytes — `n_layer * 5 * d` floats, the RWKV property this crate is
//! about), and forks N decoding branches off that one pinned snapshot,
//! each with sampler seed `seed + branch`.  Every branch streams as an
//! independent sub-session (its own `Started`/`Token`/`Finished` events,
//! tagged by `branch`) and is bit-exact with a sequential single-session
//! run of the same request at that seed (`rust/tests/streaming.rs`,
//! `rust/benches/fork.rs`).  Where a Transformer would clone an O(T) KV
//! cache per branch, forking an RWKV state is a fixed-size copy; the
//! snapshot also lands in the state cache's *decode namespace* (state +
//! last-token logits), so an identical later fork request skips prefill
//! entirely.
//!
//! # Scheduling underneath
//!
//! One worker thread owns the engine exclusively and runs vLLM-style
//! continuous batching: each cycle it reaps cancelled/expired sessions,
//! admits queued requests (highest [`GenRequestBuilder::priority`] first,
//! FIFO within a level) up to `max_active`, advances every prefilling
//! session by one bounded sequence-parallel chunk (§Perf L3-4 — long
//! prompts cannot head-of-line-block decoders), forks any prompt that
//! just completed with `n_best > 1`, and advances all decoding sessions
//! with ONE fused batched forward (§Perf L3-3 weight reuse).  Admission
//! consults the prefix-sharing state cache ([`crate::statecache`]) so
//! shared prompts resume from the deepest cached snapshot; resume and
//! batching are bit-exact, so none of this machinery ever changes a
//! session's tokens.
//!
//! # Failure model
//!
//! The serving layer treats the model as untrusted arithmetic: a panic
//! or a NaN anywhere in a forward pass must cost at most the faulting
//! *session*, never the worker, its batchmates, or a later request that
//! happens to share a cached prefix.  Faults are handled at three
//! nested scopes, innermost first:
//!
//! 1. **Per-call isolation + retry** ([`engine`], [`FaultPolicy`]).
//!    Every scheduler-driven model call (`prefill_tick` chunks,
//!    `step_batch` decode cycles) runs under `catch_unwind`, and —
//!    when `health_guards` is on — its output logits and recurrent
//!    states are scanned for NaN/±Inf ([`crate::model::panel_all_finite`]).
//!    A panic or a poisoned panel rolls the affected sessions back to
//!    their last cycle-boundary snapshot (an O(1)-byte state copy — the
//!    RWKV property that makes retry nearly free) and retries up to
//!    `max_retries` times with exponential backoff.  Un-faulted
//!    batchmates are sampled from their own logits before any retry, so
//!    they advance exactly once and stay bit-exact with a fault-free
//!    run.  Errors the model *returns* (`Err`, e.g. a dead PJRT
//!    runtime) are treated as deliberate and are not retried.
//! 2. **Per-session typed terminals** ([`scheduler`]).  A session whose
//!    retries are exhausted finishes — it does not hang and does not
//!    kill the worker.  Persistent NaN/Inf ends the branch with
//!    [`GenEvent::Finished`] / [`FinishReason::NumericFault`] carrying
//!    the tokens generated so far; a persistent panic ends it with
//!    [`GenEvent::Error`].  Either way the slot frees and pinned
//!    snapshots release at the same cycle boundary as any other reap.
//! 3. **Worker supervision + transparent redrive** ([`scheduler`]).  A
//!    panic that escapes the per-call guards (scheduler bug, panic in
//!    commit/accounting) is caught by a supervisor wrapped around the
//!    whole loop, which respawns the loop and *self-heals* the work it
//!    was carrying instead of punting to the client:
//!
//!    * **Redrive budget.**  Every in-flight session with remaining
//!      [`GenRequest::redrive_budget`] (default 1) is re-admitted
//!      automatically — zero client re-submissions.  The session keeps
//!      its original request id, enqueued-at timestamp, priority, and
//!      relative queue position (redriven sessions re-enter at the
//!      *front* of the queue in their original order, ahead of work
//!      that was queued behind them when they were first admitted).  A
//!      session whose budget is spent finishes with
//!      [`FinishReason::WorkerFailed`] exactly as before; budget 0
//!      opts a request out of redrive entirely.  Queued-but-never-
//!      admitted requests simply survive the respawn untouched — they
//!      lost no state, so they spend no budget.
//!    * **Deadline interaction.**  A redrive never outlives the
//!      session's wall-clock deadline: if the deadline expired while
//!      the worker was down, the session finishes
//!      [`FinishReason::DeadlineExceeded`] rather than being redriven,
//!      and a redriven session remains subject to the same deadline
//!      reaping as any other.
//!    * **Event-stream continuity contract.**  The [`GenStream`] stays
//!      open across the redrive.  Already-delivered `Token` events are
//!      never re-sent or contradicted: the committed healthy prefix is
//!      preserved verbatim, and `seq_idx` continues from where it
//!      stopped with no gaps and no duplicates.  A
//!      [`GenEvent::Redriven`] marks the seam — `replayed_from` is the
//!      number of tokens already committed (the next `Token` carries
//!      `seq_idx == replayed_from`).  Under the hood the session is
//!      re-admitted with its prompt *extended* by the committed tokens
//!      and its sampler fast-forwarded by the same count; chunked
//!      prefill is bit-exact with stepwise decode, so the continued
//!      generation is 0-ULP identical to an un-faulted run
//!      (`rust/tests/chaos.rs`, `rust/benches/chaos.rs`).
//!    * **Warm-cache recovery.**  The respawned engine keeps every
//!      state-cache entry that passes a non-finite scan (pins cleared,
//!      recency preserved) and drops only poisoned ones, so a redriven
//!      session resumes from its deepest healthy cached prefix and
//!      replays only the suffix since the last chunk boundary — the
//!      O(1)-byte RWKV state makes crash recovery a snapshot restore,
//!      not an O(T) recompute.
//!
//!    As a last-resort backstop, [`GenStream`] also synthesizes
//!    terminal events for any branch whose channel disconnects without
//!    one.
//!
//! Every fault handled at any scope is additionally recorded in a
//! bounded structured **fault journal** ([`journal`]) — request id,
//! branch, scheduling cycle, phase, fault kind, retry attempt, recovery
//! action, wall-clock — queryable via [`Coordinator::fault_journal`]
//! and summarized in [`Metrics::report`].
//!
//! The prefix cache is guarded independently: the store refuses to
//! admit a snapshot containing a non-finite value and can purge any
//! poisoned residents ([`crate::statecache`] — "snapshot quarantine"),
//! so one faulting session can never replay a poisoned state into
//! healthy traffic behind a shared prompt.  Under overload, a queue
//! past [`CoordinatorConfig::shed_watermark`] sheds its lowest-priority
//! queued requests with [`FinishReason::Shed`] instead of letting
//! deadline-doomed work waste prefill cycles.  All of this is exercised
//! by the deterministic fault-injection harness in [`crate::chaos`]
//! (`rust/tests/chaos.rs`, `rust/benches/chaos.rs`).
//!
//! # Observability
//!
//! Four instruments, each answering a question the others cannot; all
//! of them allocation-free (or bounded) on the hot path so they can
//! stay on in production:
//!
//! * **Counters** ([`Metrics`]) — *how much, in total*: tokens,
//!   admissions, cache hits, faults, derived throughput rates.  Plain
//!   `u64`/`f64` fields behind one mutex, folded at phase boundaries;
//!   rendered by [`Metrics::report`] (human) and [`Metrics::to_json`]
//!   (structured).  Counters hide distribution: a good mean coexists
//!   with a terrible tail.
//! * **Latency histograms** ([`crate::trace::LatencyHistogram`], five
//!   of them inside `Metrics`) — *what the distribution looks like*:
//!   p50/p90/p99/max of TTFT, inter-token gap, queue wait, prefill
//!   chunk and decode cycle.  Fixed ~4 KB log-bucketed arrays (≤12.5%
//!   relative bucket error, exact below 16 µs); recording is an index
//!   computation and an increment — no allocation, no sort.
//! * **Trace ring** ([`crate::trace::Tracer`], sized by
//!   [`CoordinatorConfig::trace_events`]) — *where THIS request's time
//!   went*: a bounded ring of typed events spanning enqueue →
//!   admission (with cache-resume depth) → each prefill chunk → first
//!   token → fork → fault/redrive seams → terminal, plus per-cycle
//!   scheduler phase timings.  Exported as Perfetto-loadable Chrome
//!   trace JSON via [`Coordinator::export_trace`].  Faults in the ring
//!   carry the same `(request, cycle, phase)` attribution as the fault
//!   journal, so a trace anomaly cross-references to its journal
//!   record directly.
//! * **Fault journal** ([`journal`]) — *what went wrong and what the
//!   recovery did*: the durable, queryable record described above.
//!   The ring may evict an old fault under event pressure; the journal
//!   keeps its own (deeper) retention and is the source of truth for
//!   fault forensics.
//!
//! Overhead contract: with tracing enabled at the default ring size,
//! end-to-end serving throughput at the default `max_active` stays
//! within 3% of the tracing-off configuration —
//! `rust/benches/trace_overhead.rs` measures and (in CI) asserts it.
//!
//! # Network serving
//!
//! [`crate::net::Server`] puts this API on a TCP socket: a
//! dependency-free (std::net) HTTP/1.1 front-end whose
//! `POST /v1/generate` maps a JSON body onto [`GenRequest`] and streams
//! the session's [`GenEvent`]s back as Server-Sent Events — one frame
//! per event, in order, bit-identical (tokens and `seq_idx`) to the
//! in-process stream (`rust/tests/http.rs`).
//!
//! * **Wire format.**  The request body is a JSON object: `prompt`
//!   (array of token ids, or a string when the server carries an
//!   encoder), `max_new_tokens`, and optionally `temperature`, `top_k`,
//!   `seed`, `n_best`, `stop_token`, `redrive_budget`, `priority`,
//!   `deadline_ms`.  The response is `Content-Type: text/event-stream`:
//!   one `event: started|token|redriven|finished|error` frame per
//!   [`GenEvent`], each with a `data:` JSON payload mirroring the
//!   event's fields (`finished` carries the full [`GenResponse`], with
//!   the reason as [`FinishReason::as_str`]).  The connection closes
//!   after the last branch's terminal frame.
//! * **Header contract.**  `X-Priority: <i32>` and
//!   `X-Deadline-Ms: <u64>` override the body's `priority` /
//!   `deadline_ms` — the transport-level knobs a gateway sets without
//!   parsing the body.
//! * **Error mapping.**  Malformed JSON or missing fields → `400`;
//!   oversized body → `413`; unknown route → `404`; wrong method →
//!   `405`.  Typed [`SubmitError`]s map to status + `Retry-After`:
//!   [`SubmitError::QueueFull`] and [`SubmitError::QuotaExceeded`] →
//!   `429`, [`SubmitError::ShutDown`] → `503`.  A client disconnect
//!   mid-stream drops the server-side [`GenStream`], cancelling the
//!   session at the next cycle boundary — slot freed, pinned snapshots
//!   released, exactly as for an in-process drop.
//! * **Quota semantics.**  [`CoordinatorConfig::priority_quotas`]
//!   bounds each priority level's share of the admission queue; a
//!   level at its share gets `429` while other levels keep admitting —
//!   the isolation `rust/benches/serve_http.rs` floods and asserts
//!   end to end.  `GET /metrics` serves [`Metrics::to_json`]
//!   (including the per-priority slices) and `GET /trace` the
//!   Chrome-trace export.
//!
//! * [`engine`]    — prefill/decode/fork over any [`EngineModel`]; owns
//!   the prefix + decode-state cache and the fault policy above, and
//!   records the model-side trace events (prefill chunks, first token,
//!   forward/scatter split).
//! * [`scheduler`] — bounded queue, cancellation/deadlines, shedding,
//!   event streaming, the supervised worker loop; records the
//!   queue/admission/terminal trace events and folds the histograms.
//! * [`metrics`]   — latency/throughput/cache/pressure/fault counters
//!   plus the five tail-latency histograms.

pub mod engine;
pub mod journal;
pub mod metrics;
pub mod scheduler;

pub use engine::{
    Backend, BackendModel, Engine, EngineModel, FaultPolicy, FaultStats, SessionFault,
    SessionPhase,
};
pub use journal::{FaultEvent, FaultJournal, FaultKind, FaultPhase, RecoveryAction};
pub use metrics::{Metrics, PriorityCounters};
pub use scheduler::{Coordinator, CoordinatorConfig, GenStream, SubmitError};

use std::time::Duration;

use crate::runtime::Variant;

/// A generation request.  Construct simple greedy requests with
/// [`GenRequest::greedy`]; everything else through [`GenRequest::builder`].
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
    pub temperature: f32,
    pub top_k: usize,
    pub seed: u64,
    pub variant: Variant,
    /// stop generation when this token is produced (e.g. BOS)
    pub stop_token: Option<u32>,
    /// Wall-clock budget measured from submission; the scheduler reaps
    /// the session (queued or active) once it expires, finishing with
    /// [`FinishReason::DeadlineExceeded`] and whatever tokens exist.
    pub deadline: Option<Duration>,
    /// Admission priority: higher admits first; FIFO within a level.
    pub priority: i32,
    /// Best-of-n: fork this many decoding branches off ONE prompt
    /// prefill, each with sampler seed `seed + branch`.  1 = ordinary
    /// single-branch request.  [`Coordinator::submit`] clamps this to
    /// `1..=max_active` — every branch occupies an active slot, so a
    /// wider fork would break the concurrency bound.
    pub n_best: usize,
    /// How many times the supervisor may transparently re-admit this
    /// request after a worker crash fails it in flight (see the module
    /// docs, "Worker supervision + transparent redrive").  0 opts out:
    /// a crash surfaces [`FinishReason::WorkerFailed`] immediately.
    pub redrive_budget: u32,
}

impl GenRequest {
    pub fn greedy(prompt: Vec<u32>, max_new_tokens: usize) -> GenRequest {
        GenRequest {
            prompt,
            max_new_tokens,
            temperature: 0.0,
            top_k: 0,
            seed: 0,
            variant: Variant::Exact,
            stop_token: None,
            deadline: None,
            priority: 0,
            n_best: 1,
            redrive_budget: 1,
        }
    }

    /// Builder over [`GenRequest::greedy`] defaults.
    pub fn builder(prompt: Vec<u32>, max_new_tokens: usize) -> GenRequestBuilder {
        GenRequestBuilder { req: GenRequest::greedy(prompt, max_new_tokens) }
    }
}

/// Fluent construction for the non-default request knobs:
/// `GenRequest::builder(prompt, 32).deadline(d).priority(3).n_best(8).build()`.
#[derive(Clone, Debug)]
pub struct GenRequestBuilder {
    req: GenRequest,
}

impl GenRequestBuilder {
    pub fn temperature(mut self, t: f32) -> Self {
        self.req.temperature = t;
        self
    }

    pub fn top_k(mut self, k: usize) -> Self {
        self.req.top_k = k;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.req.seed = seed;
        self
    }

    pub fn variant(mut self, v: Variant) -> Self {
        self.req.variant = v;
        self
    }

    pub fn stop_token(mut self, t: u32) -> Self {
        self.req.stop_token = Some(t);
        self
    }

    /// Wall-clock deadline from submission (see [`GenRequest::deadline`]).
    pub fn deadline(mut self, d: Duration) -> Self {
        self.req.deadline = Some(d);
        self
    }

    /// Admission priority: higher admits first (see [`GenRequest::priority`]).
    pub fn priority(mut self, p: i32) -> Self {
        self.req.priority = p;
        self
    }

    /// Fork `n` best-of-n branches off one prompt prefill (clamped ≥ 1
    /// here; [`Coordinator::submit`] additionally clamps to `max_active`).
    pub fn n_best(mut self, n: usize) -> Self {
        self.req.n_best = n.max(1);
        self
    }

    /// Crash-redrive budget (see [`GenRequest::redrive_budget`]; default 1).
    pub fn redrive_budget(mut self, n: u32) -> Self {
        self.req.redrive_budget = n;
        self
    }

    pub fn build(self) -> GenRequest {
        self.req
    }
}

/// Why a generation finished.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    MaxTokens,
    StopToken,
    /// Client called [`GenStream::cancel`] or dropped the stream; the
    /// response carries the tokens generated up to the reap boundary.
    Cancelled,
    /// The request's wall-clock [`GenRequest::deadline`] expired.
    DeadlineExceeded,
    /// The model produced NaN/±Inf and every rollback-retry reproduced
    /// it ([`FaultPolicy`]); the response carries the healthy tokens
    /// generated before the fault.  The poisoned state never reaches
    /// the prefix cache.
    NumericFault,
    /// The worker thread died with the session in flight and its
    /// [`GenRequest::redrive_budget`] was already spent (or 0), so the
    /// supervisor terminated it while respawning the loop.  No
    /// partial-cycle output is trusted: the response carries whatever
    /// was committed at the last healthy cycle boundary.  Sessions
    /// with budget left are transparently redriven instead and never
    /// see this reason.
    WorkerFailed,
    /// Shed from the admission queue under overload: the queue exceeded
    /// [`CoordinatorConfig::shed_watermark`] and this request had the
    /// lowest priority (latest-submitted within the level).  Always
    /// zero tokens — shedding happens before any prefill work.
    Shed,
}

impl FinishReason {
    /// Stable lowercase wire name — the `finish_reason` field of SSE
    /// `finished` frames, the trace ring's terminal label, and the
    /// bench JSON vocabulary all spell outcomes this way.
    pub fn as_str(self) -> &'static str {
        match self {
            FinishReason::MaxTokens => "max_tokens",
            FinishReason::StopToken => "stop_token",
            FinishReason::Cancelled => "cancelled",
            FinishReason::DeadlineExceeded => "deadline_exceeded",
            FinishReason::NumericFault => "numeric_fault",
            FinishReason::WorkerFailed => "worker_failed",
            FinishReason::Shed => "shed",
        }
    }
}

/// Incremental progress of one streaming session, delivered through
/// [`GenStream`].  `branch` is 0 for ordinary requests; best-of-n
/// requests interleave events of all `n_best` branches on one stream.
#[derive(Clone, Debug)]
pub enum GenEvent {
    /// The session was admitted (branch 0) or forked (branches 1..n);
    /// prefill begins after `cached_prefix_tokens` skipped tokens.
    Started { branch: usize, cached_prefix_tokens: usize },
    /// One sampled token was committed as output: `seq_idx` is its
    /// 0-based position in the branch's generated sequence.
    Token { branch: usize, token: u32, seq_idx: usize },
    /// The worker crashed with this branch in flight and the supervisor
    /// transparently re-admitted it (non-terminal; see the module docs).
    /// `attempt` counts redrives of this session (1 = first redrive);
    /// `replayed_from` is the committed-token count being resumed from —
    /// the next `Token` on this branch carries `seq_idx == replayed_from`,
    /// continuing the stream with no gaps or duplicates.
    Redriven { branch: usize, attempt: u32, replayed_from: usize },
    /// Terminal: the branch finished; the aggregated per-branch response.
    Finished(GenResponse),
    /// Terminal: the branch failed.
    Error { branch: usize, message: String },
}

/// A finished generation (one best-of-n branch = one response).
#[derive(Clone, Debug)]
pub struct GenResponse {
    pub request_id: u64,
    /// Which best-of-n branch this is (0 for ordinary requests).
    pub branch: usize,
    pub tokens: Vec<u32>,
    pub finish: FinishReason,
    pub prefill_seconds: f64,
    pub decode_seconds: f64,
    pub queue_seconds: f64,
    /// Time-to-first-token: enqueue → first sampled token, including
    /// queueing and chunked prefill as interleaved with other sessions.
    pub ttft_seconds: f64,
    /// Prompt tokens whose prefill was skipped by resuming from a
    /// cached prefix state (0 = cold prefill from token 0).  Comparing
    /// `ttft_seconds` across requests with zero and nonzero values here
    /// is the cache's measured benefit (`rust/benches/statecache.rs`).
    pub cached_prefix_tokens: usize,
}

impl GenResponse {
    pub fn decode_tokens_per_sec(&self) -> f64 {
        if self.decode_seconds > 0.0 {
            self.tokens.len() as f64 / self.decode_seconds
        } else {
            0.0
        }
    }
}
