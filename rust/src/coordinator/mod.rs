//! L3 serving coordinator — the request path.
//!
//! The paper's system serves sustained single-token decode (batch 1); a
//! production deployment still needs admission, fair scheduling across
//! concurrent sessions, state management and metrics, so the coordinator
//! implements vLLM-style *continuous batching at the session level*: a
//! worker thread owns the PJRT runtime exclusively and advances every
//! active session by one decode step per scheduling cycle — fused into a
//! single batched forward so each weight matrix is streamed once per
//! cycle and reused across all B sessions (the software analog of the
//! paper's on-chip weight reuse) — admitting queued requests as slots
//! free up.  Prefill is interleaved the same way: an admitted session
//! consumes one bounded sequence-parallel chunk of its prompt per cycle
//! (§Perf L3-4) instead of running the whole prompt inline at
//! admission, so a long prompt cannot head-of-line-block the decoders;
//! time-to-first-token is surfaced per response and in [`Metrics`].
//!
//! # The admission path and the prefix cache
//!
//! Admission itself does no forward work; it does two cheap things:
//! BOS-pad an empty prompt, and ask the prefix-sharing state cache
//! ([`crate::statecache`]) for the deepest snapshot whose token prefix
//! matches the prompt.  On a hit the session's recurrent state is
//! restored from the snapshot (copy-on-write — the shared entry is
//! pinned, the session mutates a private copy) and prefill starts at
//! the matched depth; on a miss it starts at token 0.  Every prefill
//! chunk boundary then captures a snapshot, so a 1k-token prompt leaves
//! resumable states at `prefill_chunk` granularity behind it — the next
//! request sharing that system prompt prefills only its unique suffix,
//! collapsing its time-to-first-token.  This is the serving-layer
//! dividend of the paper's core premise: RWKV state is O(1) bytes per
//! session (`n_layer * 5 * d` floats, no KV growth), so caching *many*
//! of them is feasible where a Transformer KV prefix cache is not.
//! Per-response [`GenResponse::cached_prefix_tokens`] and the cache
//! counters in [`Metrics`] make the effect observable; resume is
//! bit-exact with full prefill (`rust/tests/statecache.rs`), so the
//! cache changes latency, never tokens.
//!
//! * [`engine`]    — prefill (chunked through the `seq` executable) +
//!   step decode against [`crate::runtime::RwkvRuntime`]; owns the
//!   prefix cache.
//! * [`scheduler`] — admission queue + round-robin step scheduler.
//! * [`metrics`]   — latency/throughput/cache counters.

pub mod engine;
pub mod metrics;
pub mod scheduler;

pub use engine::{Engine, EngineModel, SessionPhase};
pub use metrics::Metrics;
pub use scheduler::{Coordinator, CoordinatorConfig};

use crate::runtime::Variant;

/// A generation request.
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
    pub temperature: f32,
    pub top_k: usize,
    pub seed: u64,
    pub variant: Variant,
    /// stop generation when this token is produced (e.g. BOS)
    pub stop_token: Option<u32>,
}

impl GenRequest {
    pub fn greedy(prompt: Vec<u32>, max_new_tokens: usize) -> GenRequest {
        GenRequest {
            prompt,
            max_new_tokens,
            temperature: 0.0,
            top_k: 0,
            seed: 0,
            variant: Variant::Exact,
            stop_token: None,
        }
    }
}

/// Why a generation finished.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    MaxTokens,
    StopToken,
}

/// A finished generation.
#[derive(Clone, Debug)]
pub struct GenResponse {
    pub request_id: u64,
    pub tokens: Vec<u32>,
    pub finish: FinishReason,
    pub prefill_seconds: f64,
    pub decode_seconds: f64,
    pub queue_seconds: f64,
    /// Time-to-first-token: enqueue → first sampled token, including
    /// queueing and chunked prefill as interleaved with other sessions.
    pub ttft_seconds: f64,
    /// Prompt tokens whose prefill was skipped by resuming from a
    /// cached prefix state (0 = cold prefill from token 0).  Comparing
    /// `ttft_seconds` across requests with zero and nonzero values here
    /// is the cache's measured benefit (`rust/benches/statecache.rs`).
    pub cached_prefix_tokens: usize,
}

impl GenResponse {
    pub fn decode_tokens_per_sec(&self) -> f64 {
        if self.decode_seconds > 0.0 {
            self.tokens.len() as f64 / self.decode_seconds
        } else {
            0.0
        }
    }
}
