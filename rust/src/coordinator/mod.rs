//! L3 serving coordinator — the streaming request path.
//!
//! # Session lifecycle: submit → events → finish/cancel
//!
//! The API is a **streaming session** per request.  [`Coordinator::submit`]
//! reserves a slot in a *bounded* admission queue (or rejects with
//! [`SubmitError::QueueFull`] — backpressure is explicit, the queue never
//! grows without bound) and returns a [`scheduler::GenStream`] handle that
//! yields [`GenEvent`]s as the worker makes progress:
//!
//! 1. [`GenEvent::Started`] — the session was admitted (branch 0) or a
//!    best-of-n branch was forked (branches 1..n), reporting how many
//!    prompt tokens were skipped via cached state;
//! 2. one [`GenEvent::Token`] per sampled token, in order, *as it is
//!    committed* — a client renders tokens live instead of waiting for
//!    the whole generation;
//! 3. one terminal event per branch: [`GenEvent::Finished`] with the
//!    aggregated [`GenResponse`], or [`GenEvent::Error`].  One caveat: a
//!    request reaped *before its branches exist* (cancelled or expired
//!    while still queued, or before the fork) terminates on branch 0
//!    only and the stream then ends — raw `recv()` consumers must treat
//!    stream exhaustion (`None`) as terminal for any remaining
//!    branches; [`scheduler::GenStream::wait`] already mirrors the
//!    branch-0 terminal onto them.
//!
//! A stream can be ended early: [`scheduler::GenStream::cancel`] (or simply
//! dropping the stream) flags the session, and the worker reaps it at the
//! next scheduling-cycle boundary — the `max_active` slot frees, pinned
//! snapshots release, batchmates are untouched, and the partial output
//! comes back with [`FinishReason::Cancelled`].  A request can also carry
//! a wall-clock deadline ([`GenRequestBuilder::deadline`]) enforced by the
//! scheduler at the same boundaries, queued or active, finishing with
//! [`FinishReason::DeadlineExceeded`].  [`Coordinator::generate`] remains
//! a thin blocking wrapper over the stream for callers that only want the
//! final response.
//!
//! # Best-of-n: forking decode off one shared RWKV state
//!
//! A request built with [`GenRequestBuilder::n_best`]` = N` prefills its
//! prompt **once**, snapshots the post-prompt recurrent state (O(1)
//! bytes — `n_layer * 5 * d` floats, the RWKV property this crate is
//! about), and forks N decoding branches off that one pinned snapshot,
//! each with sampler seed `seed + branch`.  Every branch streams as an
//! independent sub-session (its own `Started`/`Token`/`Finished` events,
//! tagged by `branch`) and is bit-exact with a sequential single-session
//! run of the same request at that seed (`rust/tests/streaming.rs`,
//! `rust/benches/fork.rs`).  Where a Transformer would clone an O(T) KV
//! cache per branch, forking an RWKV state is a fixed-size copy; the
//! snapshot also lands in the state cache's *decode namespace* (state +
//! last-token logits), so an identical later fork request skips prefill
//! entirely.
//!
//! # Scheduling underneath
//!
//! One worker thread owns the engine exclusively and runs vLLM-style
//! continuous batching: each cycle it reaps cancelled/expired sessions,
//! admits queued requests (highest [`GenRequestBuilder::priority`] first,
//! FIFO within a level) up to `max_active`, advances every prefilling
//! session by one bounded sequence-parallel chunk (§Perf L3-4 — long
//! prompts cannot head-of-line-block decoders), forks any prompt that
//! just completed with `n_best > 1`, and advances all decoding sessions
//! with ONE fused batched forward (§Perf L3-3 weight reuse).  Admission
//! consults the prefix-sharing state cache ([`crate::statecache`]) so
//! shared prompts resume from the deepest cached snapshot; resume and
//! batching are bit-exact, so none of this machinery ever changes a
//! session's tokens.
//!
//! * [`engine`]    — prefill/decode/fork over any [`EngineModel`]; owns
//!   the prefix + decode-state cache.
//! * [`scheduler`] — bounded queue, cancellation/deadlines, event
//!   streaming, the worker loop.
//! * [`metrics`]   — latency/throughput/cache/pressure counters.

pub mod engine;
pub mod metrics;
pub mod scheduler;

pub use engine::{Engine, EngineModel, SessionPhase};
pub use metrics::Metrics;
pub use scheduler::{Coordinator, CoordinatorConfig, GenStream, SubmitError};

use std::time::Duration;

use crate::runtime::Variant;

/// A generation request.  Construct simple greedy requests with
/// [`GenRequest::greedy`]; everything else through [`GenRequest::builder`].
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
    pub temperature: f32,
    pub top_k: usize,
    pub seed: u64,
    pub variant: Variant,
    /// stop generation when this token is produced (e.g. BOS)
    pub stop_token: Option<u32>,
    /// Wall-clock budget measured from submission; the scheduler reaps
    /// the session (queued or active) once it expires, finishing with
    /// [`FinishReason::DeadlineExceeded`] and whatever tokens exist.
    pub deadline: Option<Duration>,
    /// Admission priority: higher admits first; FIFO within a level.
    pub priority: i32,
    /// Best-of-n: fork this many decoding branches off ONE prompt
    /// prefill, each with sampler seed `seed + branch`.  1 = ordinary
    /// single-branch request.  [`Coordinator::submit`] clamps this to
    /// `1..=max_active` — every branch occupies an active slot, so a
    /// wider fork would break the concurrency bound.
    pub n_best: usize,
}

impl GenRequest {
    pub fn greedy(prompt: Vec<u32>, max_new_tokens: usize) -> GenRequest {
        GenRequest {
            prompt,
            max_new_tokens,
            temperature: 0.0,
            top_k: 0,
            seed: 0,
            variant: Variant::Exact,
            stop_token: None,
            deadline: None,
            priority: 0,
            n_best: 1,
        }
    }

    /// Builder over [`GenRequest::greedy`] defaults.
    pub fn builder(prompt: Vec<u32>, max_new_tokens: usize) -> GenRequestBuilder {
        GenRequestBuilder { req: GenRequest::greedy(prompt, max_new_tokens) }
    }
}

/// Fluent construction for the non-default request knobs:
/// `GenRequest::builder(prompt, 32).deadline(d).priority(3).n_best(8).build()`.
#[derive(Clone, Debug)]
pub struct GenRequestBuilder {
    req: GenRequest,
}

impl GenRequestBuilder {
    pub fn temperature(mut self, t: f32) -> Self {
        self.req.temperature = t;
        self
    }

    pub fn top_k(mut self, k: usize) -> Self {
        self.req.top_k = k;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.req.seed = seed;
        self
    }

    pub fn variant(mut self, v: Variant) -> Self {
        self.req.variant = v;
        self
    }

    pub fn stop_token(mut self, t: u32) -> Self {
        self.req.stop_token = Some(t);
        self
    }

    /// Wall-clock deadline from submission (see [`GenRequest::deadline`]).
    pub fn deadline(mut self, d: Duration) -> Self {
        self.req.deadline = Some(d);
        self
    }

    /// Admission priority: higher admits first (see [`GenRequest::priority`]).
    pub fn priority(mut self, p: i32) -> Self {
        self.req.priority = p;
        self
    }

    /// Fork `n` best-of-n branches off one prompt prefill (clamped ≥ 1
    /// here; [`Coordinator::submit`] additionally clamps to `max_active`).
    pub fn n_best(mut self, n: usize) -> Self {
        self.req.n_best = n.max(1);
        self
    }

    pub fn build(self) -> GenRequest {
        self.req
    }
}

/// Why a generation finished.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    MaxTokens,
    StopToken,
    /// Client called [`GenStream::cancel`] or dropped the stream; the
    /// response carries the tokens generated up to the reap boundary.
    Cancelled,
    /// The request's wall-clock [`GenRequest::deadline`] expired.
    DeadlineExceeded,
}

/// Incremental progress of one streaming session, delivered through
/// [`GenStream`].  `branch` is 0 for ordinary requests; best-of-n
/// requests interleave events of all `n_best` branches on one stream.
#[derive(Clone, Debug)]
pub enum GenEvent {
    /// The session was admitted (branch 0) or forked (branches 1..n);
    /// prefill begins after `cached_prefix_tokens` skipped tokens.
    Started { branch: usize, cached_prefix_tokens: usize },
    /// One sampled token was committed as output: `seq_idx` is its
    /// 0-based position in the branch's generated sequence.
    Token { branch: usize, token: u32, seq_idx: usize },
    /// Terminal: the branch finished; the aggregated per-branch response.
    Finished(GenResponse),
    /// Terminal: the branch failed.
    Error { branch: usize, message: String },
}

/// A finished generation (one best-of-n branch = one response).
#[derive(Clone, Debug)]
pub struct GenResponse {
    pub request_id: u64,
    /// Which best-of-n branch this is (0 for ordinary requests).
    pub branch: usize,
    pub tokens: Vec<u32>,
    pub finish: FinishReason,
    pub prefill_seconds: f64,
    pub decode_seconds: f64,
    pub queue_seconds: f64,
    /// Time-to-first-token: enqueue → first sampled token, including
    /// queueing and chunked prefill as interleaved with other sessions.
    pub ttft_seconds: f64,
    /// Prompt tokens whose prefill was skipped by resuming from a
    /// cached prefix state (0 = cold prefill from token 0).  Comparing
    /// `ttft_seconds` across requests with zero and nonzero values here
    /// is the cache's measured benefit (`rust/benches/statecache.rs`).
    pub cached_prefix_tokens: usize,
}

impl GenResponse {
    pub fn decode_tokens_per_sec(&self) -> f64 {
        if self.decode_seconds > 0.0 {
            self.tokens.len() as f64 / self.decode_seconds
        } else {
            0.0
        }
    }
}
