//! Structured fault journal: a bounded ring of typed [`FaultEvent`]
//! records replacing counters-only fault observability.
//!
//! Counters ([`super::Metrics`], [`super::engine::FaultStats`]) say *how
//! many* faults happened; the journal says *which request*, on *which
//! scheduling cycle*, in *which phase*, of *what kind*, on *which retry
//! attempt*, and *what the serving stack did about it* — the tuple an
//! operator needs to attribute a bad terminal to its root cause.  The
//! engine records per-call faults (guarded prefill chunks and decode
//! cycles), the supervisor records worker-scope crashes and the redrive
//! decision taken for each in-flight session, and
//! [`super::Coordinator::fault_journal`] hands the ring to callers; the
//! chaos bench serializes the aggregate counts into `BENCH_chaos.json`.
//!
//! The ring is bounded (`FaultJournal::with_capacity`): a fault storm
//! overwrites the oldest records and counts them in `dropped` rather
//! than growing without bound on the serving path — the same
//! discipline as the bounded admission queue.

use std::collections::VecDeque;
use std::time::{SystemTime, UNIX_EPOCH};

/// Which serving phase the fault interrupted.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultPhase {
    /// A guarded [`super::engine::Engine::prefill_tick`] chunk.
    Prefill,
    /// A guarded [`super::engine::Engine::step_batch`] decode cycle.
    Decode,
    /// Outside the per-call guards: the worker loop itself died and the
    /// supervisor handled the session.
    Worker,
}

/// What went wrong.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// The model panicked inside a guarded call.
    Panic,
    /// NaN/±Inf in a logits or state panel (health guards).
    NonFinite,
    /// The model *returned* an error (e.g. a dead runtime) — deliberate,
    /// never retried.
    ModelError,
    /// A panic escaped the per-call guards and killed the worker loop;
    /// the supervisor records one event per affected in-flight session.
    WorkerCrash,
}

/// What the serving stack did about the fault.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RecoveryAction {
    /// Rolled the session(s) back to the last-good snapshot and re-ran
    /// the call.
    Retried,
    /// Retry budget exhausted (or the fault is non-retryable): the
    /// session finished with a typed terminal.
    SessionFailed,
    /// The retry was abandoned because its backoff sleep would cross
    /// the session's deadline; the session finished
    /// [`super::FinishReason::DeadlineExceeded`].
    DeadlineAbandoned,
    /// The supervisor re-admitted the session for a transparent redrive
    /// ([`super::GenRequest::redrive_budget`]).
    Redriven,
}

/// One journalled fault: the full attribution tuple.
#[derive(Clone, Debug)]
pub struct FaultEvent {
    pub request_id: u64,
    /// Best-of-n branch index (0 for ordinary sessions).
    pub branch: usize,
    /// Engine scheduling cycle on which the fault fired (see
    /// [`super::engine::Engine::cycle`]).
    pub cycle: u64,
    pub phase: FaultPhase,
    pub kind: FaultKind,
    /// Retry attempt the fault interrupted (0 = first try; for
    /// [`FaultKind::WorkerCrash`] the session's redrive attempt so far).
    pub attempt: u32,
    pub action: RecoveryAction,
    /// Wall-clock seconds since the UNIX epoch at record time.
    pub unix_s: f64,
}

/// Bounded ring buffer of [`FaultEvent`]s (see the module docs).
#[derive(Clone, Debug)]
pub struct FaultJournal {
    events: VecDeque<FaultEvent>,
    cap: usize,
    recorded: u64,
    dropped: u64,
}

/// Default ring capacity: generous for attribution, bounded for a
/// fault storm (each record is a few dozen bytes).
const DEFAULT_CAP: usize = 256;

impl Default for FaultJournal {
    fn default() -> Self {
        FaultJournal::with_capacity(DEFAULT_CAP)
    }
}

impl FaultJournal {
    pub fn with_capacity(cap: usize) -> FaultJournal {
        FaultJournal {
            events: VecDeque::with_capacity(cap.max(1).min(DEFAULT_CAP)),
            cap: cap.max(1),
            recorded: 0,
            dropped: 0,
        }
    }

    /// Append one event, evicting the oldest when the ring is full.
    pub fn record(&mut self, mut ev: FaultEvent) {
        ev.unix_s = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_secs_f64())
            .unwrap_or(0.0);
        if self.events.len() == self.cap {
            self.events.pop_front();
            self.dropped += 1;
        }
        self.events.push_back(ev);
        self.recorded += 1;
    }

    /// Events currently resident, oldest first.
    pub fn snapshot(&self) -> Vec<FaultEvent> {
        self.events.iter().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Cumulative events ever recorded (resident + overwritten).
    pub fn recorded(&self) -> u64 {
        self.recorded
    }

    /// Events overwritten after the ring filled.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(id: u64) -> FaultEvent {
        FaultEvent {
            request_id: id,
            branch: 0,
            cycle: id,
            phase: FaultPhase::Decode,
            kind: FaultKind::Panic,
            attempt: 0,
            action: RecoveryAction::Retried,
            unix_s: 0.0,
        }
    }

    #[test]
    fn ring_bounds_and_counts() {
        let mut j = FaultJournal::with_capacity(3);
        for i in 0..5 {
            j.record(ev(i));
        }
        assert_eq!(j.len(), 3);
        assert_eq!(j.recorded(), 5);
        assert_eq!(j.dropped(), 2);
        let ids: Vec<u64> = j.snapshot().iter().map(|e| e.request_id).collect();
        assert_eq!(ids, vec![2, 3, 4], "oldest records are the ones overwritten");
        assert!(j.snapshot().iter().all(|e| e.unix_s > 0.0), "wall-clock stamped at record");
    }
}
