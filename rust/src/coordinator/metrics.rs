//! Serving metrics: counters + derived rates + tail-latency histograms,
//! printable as a report ([`Metrics::report`]) or serializable as
//! structured JSON ([`Metrics::to_json`]).

use std::collections::BTreeMap;

use crate::trace::LatencyHistogram;
use crate::util::json::Json;

/// Per-priority-level slice of the serving counters, keyed by
/// [`super::GenRequest::priority`] in [`Metrics::per_priority`].  This
/// is what makes quota behavior observable: under a low-priority flood
/// the flooded level shows `quota_rejected` growth and a pinned
/// `queued` gauge while the high-priority level's `admitted` /
/// `completed` keep tracking its `enqueued` — the isolation claim the
/// HTTP load harness asserts by reading these back over `/metrics`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PriorityCounters {
    /// Gauge: requests of this level currently queued (submitted but
    /// not admitted) — the live count metered against the level's
    /// `CoordinatorConfig::priority_quotas` share.
    pub queued: u64,
    /// Requests of this level accepted by `submit`.
    pub enqueued: u64,
    /// Requests of this level that took an active slot.
    pub admitted: u64,
    /// Sessions of this level that reached a terminal event (queued
    /// deaths included, like the global `completed`).
    pub completed: u64,
    /// Requests of this level shed from the queue under overload.
    pub shed: u64,
    /// Submissions of this level rejected with
    /// `SubmitError::QuotaExceeded` (level at its queue share).
    pub quota_rejected: u64,
}

#[derive(Clone, Debug, Default)]
pub struct Metrics {
    /// Requests accepted by `submit` (one per request, regardless of
    /// `n_best`).
    pub enqueued: u64,
    /// Requests that took an active slot (one per request; the fork
    /// into branches happens after admission).
    pub admitted: u64,
    /// *Sessions* that reached a terminal event.  Every best-of-n
    /// branch counts, so compare against `admitted` × `n_best` (or
    /// `first_tokens`), never against `enqueued`.
    pub completed: u64,
    pub tokens_generated: u64,
    pub prefill_seconds_total: f64,
    pub decode_seconds_total: f64,
    pub queue_seconds_total: f64,
    /// Sessions that actually produced a first token (finished prefill).
    /// A session whose prefill errors completes without one, so TTFT
    /// means are taken over this count, not `completed`.
    pub first_tokens: u64,
    /// Sum over sessions counted in `first_tokens` of time-to-first-token
    /// (enqueue → first sampled token, i.e. queueing + chunked prefill as
    /// actually interleaved with other sessions' decode).
    pub ttft_seconds_total: f64,
    /// Activations that clipped at the hardware backend's 9-bit rails,
    /// drained losslessly from the model every scheduling cycle (large
    /// values mean a bad calibration).  Always 0 for non-hw models.
    pub clip_events: u64,
    /// Submissions rejected at the bounded admission queue
    /// (`SubmitError::QueueFull`) — sustained growth means the service
    /// is saturated and callers should back off.
    pub rejected: u64,
    /// Submissions rejected because their priority level was at its
    /// configured queue share (`SubmitError::QuotaExceeded`) — distinct
    /// from `rejected`: the *level* is saturated, not the service.
    pub quota_rejected: u64,
    /// Per-priority-level counter slices (see [`PriorityCounters`]);
    /// levels appear on first use and persist.  Mirrored into
    /// [`Metrics::to_json`] under `per_priority` and summarized on the
    /// report's `quota:` line.
    pub per_priority: BTreeMap<i32, PriorityCounters>,
    /// Sessions reaped by client `cancel()` or stream drop, whether
    /// still queued or already active (partial tokens are returned with
    /// `FinishReason::Cancelled`).  Per *session*, like `completed`:
    /// cancelling a best-of-n request mid-decode reaps every live
    /// branch, counting each.
    pub cancelled: u64,
    /// Sessions that ran out their wall-clock deadline before finishing
    /// (`FinishReason::DeadlineExceeded`); per session, like `completed`.
    pub deadline_exceeded: u64,
    /// Prompt tokens actually consumed by prefill forwards.  Cached
    /// resumes and shared-state forks skip work, so this counter is the
    /// ground truth for "how much prefill did we really do" — the fork
    /// bench's 1/N assertion reads it.
    pub prompt_tokens_prefilled: u64,
    /// Gauge: requests submitted but not yet admitted (bounded by
    /// `CoordinatorConfig::max_queue`).
    pub queue_depth: u64,
    /// Gauge: sessions currently holding an active slot (prefilling,
    /// fork-pending or decoding; every fork branch counts).
    pub active_sessions: u64,
    /// Admissions that resumed from a cached prompt-prefix state
    /// (mirror of the engine's `statecache` counters, refreshed every
    /// scheduling cycle; all 0 with the cache disabled).
    pub prefix_cache_hits: u64,
    /// Admissions that found no usable cached prefix.
    pub prefix_cache_misses: u64,
    /// Prompt tokens whose prefill was skipped entirely by resuming
    /// from cached states — the cache's value, in tokens.
    pub prefix_tokens_skipped: u64,
    /// Gauge: bytes of state snapshots currently resident.
    pub prefix_cache_bytes: u64,
    /// Gauge: state snapshots currently resident.
    pub prefix_cache_entries: u64,
    /// Snapshots evicted by LRU under byte-budget pressure.
    pub prefix_cache_evictions: u64,
    /// Gauge: cache entries pinned by live sessions (resuming prefills
    /// and fork branches sharing a decode-state snapshot).
    pub prefix_cache_pinned: u64,
    /// Snapshots the cache refused at insert or purged after a health
    /// guard tripped because they contained NaN/±Inf (mirror of the
    /// store's quarantine counter — see the `statecache` docs).
    pub prefix_cache_quarantined: u64,
    /// Requests shed from the admission queue under overload
    /// (`FinishReason::Shed`, past `CoordinatorConfig::shed_watermark`).
    pub shed: u64,
    /// Times the supervisor caught a worker-loop panic and respawned
    /// the loop on a recovered engine view.
    pub worker_restarts: u64,
    /// Sessions (active) and requests (queued) terminated with
    /// `FinishReason::WorkerFailed` by the supervisor; per session,
    /// like `completed`.
    pub worker_failed: u64,
    /// Sessions that finished with `FinishReason::NumericFault` after
    /// exhausting their rollback-retries; per session.
    pub numeric_faulted: u64,
    /// Guarded model calls re-run after a transient fault (mirror of
    /// the engine's cumulative `FaultStats`, refreshed every cycle).
    pub fault_retries: u64,
    /// Session states restored from their last-good snapshot.
    pub fault_rollbacks: u64,
    /// Model panics caught by the engine's per-call guards (each may
    /// cover several batched sessions).
    pub panics_caught: u64,
    /// Non-finite logits/state panels detected by the health guards
    /// (counted per poisoned session per attempt).
    pub numeric_faults_detected: u64,
    /// In-flight sessions the supervisor transparently re-admitted
    /// after a worker crash (one per session per crash — a session
    /// crashed twice with budget 2 counts twice).
    pub redrives: u64,
    /// Redriven sessions that went on to finish cleanly
    /// (`MaxTokens`/`StopToken`) — `redrives` minus these is the
    /// still-in-flight + subsequently-failed remainder.
    pub redrives_completed: u64,
    /// Redriven sessions that committed their first post-crash token.
    pub redrives_resumed: u64,
    /// Sum over `redrives_resumed` of crash-handled → first-token-
    /// after-fault seconds (the client-visible stall a crash causes).
    pub redrive_resume_seconds_total: f64,
    /// State-cache snapshots that survived supervisor crash recoveries
    /// (cumulative over restarts; the warm prefix a redriven session
    /// resumes from).
    pub cache_recovered_snapshots: u64,
    /// Fault-journal records ever written (mirror of
    /// [`super::FaultJournal::recorded`], refreshed every cycle).
    pub fault_events: u64,
    /// Fault-journal records overwritten after the bounded ring filled.
    pub fault_events_dropped: u64,
    /// Scheduling cycles that ran a batched decode forward (the
    /// denominator of [`Metrics::weight_bytes_per_cycle`]).
    pub decode_cycles: u64,
    /// Bytes of weight-plane traffic the decode forwards streamed
    /// (`EngineModel::weight_stream_bytes` per decode cycle): the
    /// exact/hw backends stream 4 B per weight, the packed backend 2 —
    /// the traffic cut that makes packed the throughput configuration.
    /// 0 for models that don't expose their plane footprint (PJRT).
    pub weight_bytes_streamed: u64,
    /// Trace-ring records ever written (mirror of the coordinator's
    /// [`crate::trace::Tracer`] ring, refreshed every cycle; 0 with
    /// tracing disabled).
    pub trace_events: u64,
    /// Trace-ring records overwritten after the bounded ring filled.
    pub trace_events_dropped: u64,
    /// Time-to-first-token distribution (enqueue → first sampled token)
    /// over the sessions counted in `first_tokens`.  Fixed ~4 KB
    /// log-bucketed histogram ([`LatencyHistogram`]) — percentiles where
    /// `ttft_seconds_total` only gives a mean.
    pub ttft_hist: LatencyHistogram,
    /// Gap between consecutive committed tokens of one session (the
    /// streaming smoothness tail).  Redrive seams are excluded: the gap
    /// clock resets on resume, so a crash stall never pollutes the
    /// steady-state distribution (it is visible in `ttft_hist` /
    /// `redrive_resume_seconds_total` instead).
    pub inter_token_hist: LatencyHistogram,
    /// Queue wait (submit → admission) per admission; counts exactly
    /// the admissions folded into `queue_seconds_total` (a crash
    /// redrive re-enters neither).
    pub queue_wait_hist: LatencyHistogram,
    /// Duration of one bounded prefill chunk (one session, one cycle).
    pub prefill_chunk_hist: LatencyHistogram,
    /// Duration of one fused batched decode forward + sample cycle.
    pub decode_cycle_hist: LatencyHistogram,
}

impl Metrics {
    /// The counter slice for one priority level, created on first use.
    pub fn prio(&mut self, level: i32) -> &mut PriorityCounters {
        self.per_priority.entry(level).or_default()
    }

    /// Decode throughput over completed work (tokens/s of engine time).
    pub fn decode_tokens_per_sec(&self) -> f64 {
        if self.decode_seconds_total > 0.0 {
            self.tokens_generated as f64 / self.decode_seconds_total
        } else {
            0.0
        }
    }

    pub fn mean_queue_seconds(&self) -> f64 {
        if self.admitted > 0 {
            self.queue_seconds_total / self.admitted as f64
        } else {
            0.0
        }
    }

    /// Mean time-to-first-token over sessions that produced one.
    pub fn mean_ttft_seconds(&self) -> f64 {
        if self.first_tokens > 0 {
            self.ttft_seconds_total / self.first_tokens as f64
        } else {
            0.0
        }
    }

    /// Mean crash-handled → first-token-after-fault stall over redriven
    /// sessions that resumed.
    pub fn mean_redrive_resume_seconds(&self) -> f64 {
        if self.redrives_resumed > 0 {
            self.redrive_resume_seconds_total / self.redrives_resumed as f64
        } else {
            0.0
        }
    }

    /// Mean weight bytes streamed per decode cycle — compare across
    /// backends at the same model size: packed reads half the exact
    /// backend's figure.
    pub fn weight_bytes_per_cycle(&self) -> f64 {
        if self.decode_cycles > 0 {
            self.weight_bytes_streamed as f64 / self.decode_cycles as f64
        } else {
            0.0
        }
    }

    /// Fraction of admissions that resumed from a cached prefix.
    pub fn prefix_cache_hit_rate(&self) -> f64 {
        let total = self.prefix_cache_hits + self.prefix_cache_misses;
        if total > 0 {
            self.prefix_cache_hits as f64 / total as f64
        } else {
            0.0
        }
    }

    pub fn report(&self) -> String {
        let (ttft_p50, ttft_p90, ttft_p99, ttft_max) = self.ttft_hist.summary_ms();
        let (itl_p50, itl_p90, itl_p99, itl_max) = self.inter_token_hist.summary_ms();
        let quota_line = if self.per_priority.is_empty() {
            format!("{} rejected over quota (no per-priority traffic yet)", self.quota_rejected)
        } else {
            let levels = self
                .per_priority
                .iter()
                .map(|(lvl, p)| {
                    format!(
                        "p{lvl}: {} queued, {}/{}/{} enq/adm/done, {} shed, {} quota-rejected",
                        p.queued, p.enqueued, p.admitted, p.completed, p.shed, p.quota_rejected
                    )
                })
                .collect::<Vec<_>>()
                .join("; ");
            format!("{} rejected over quota; {levels}", self.quota_rejected)
        };
        format!(
            "requests: {} enqueued / {} admitted, {} sessions completed\n\
             pressure: {} queued / {} active now, {} rejected (queue full), \
             {} cancelled, {} deadline-exceeded\n\
             tokens:   {} generated\n\
             decode:   {:.1} tok/s (engine time)\n\
             traffic:  {} weight B streamed / {} decode cycles ({:.0} B per cycle)\n\
             prefill:  {:.3} s total ({} prompt tokens forwarded)\n\
             ttft:     {:.4} s mean (enqueue -> first token)\n\
             queueing: {:.4} s mean wait\n\
             quota:    {}\n\
             latency:  ttft p50 {:.2} ms / p90 {:.2} / p99 {:.2} / max {:.2} ms\n\
             latency:  inter-token p50 {:.3} ms / p90 {:.3} / p99 {:.3} / max {:.3} ms\n\
             latency:  queue p50 {:.2} / p99 {:.2} ms; prefill-chunk p50 {:.2} / p99 {:.2} ms; \
             decode-cycle p50 {:.2} / p99 {:.2} ms\n\
             cache:    {} hits / {} misses ({:.0}% hit rate), \
             {} prompt tokens skipped, {} snapshots / {} B resident ({} pinned), {} evictions\n\
             faults:   {} panics caught, {} non-finite panels, {} retries / {} rollbacks, \
             {} numeric-faulted sessions, {} shed, {} worker restarts ({} sessions failed), \
             {} snapshots quarantined\n\
             healing:  {} redrives ({} completed), {:.4} s mean resume-after-fault, \
             {} snapshots survived recovery, {} journal records ({} dropped)\n\
             clips:    {} activations at the 9-bit rails",
            self.enqueued,
            self.admitted,
            self.completed,
            self.queue_depth,
            self.active_sessions,
            self.rejected,
            self.cancelled,
            self.deadline_exceeded,
            self.tokens_generated,
            self.decode_tokens_per_sec(),
            self.weight_bytes_streamed,
            self.decode_cycles,
            self.weight_bytes_per_cycle(),
            self.prefill_seconds_total,
            self.prompt_tokens_prefilled,
            self.mean_ttft_seconds(),
            self.mean_queue_seconds(),
            quota_line,
            ttft_p50,
            ttft_p90,
            ttft_p99,
            ttft_max,
            itl_p50,
            itl_p90,
            itl_p99,
            itl_max,
            self.queue_wait_hist.percentile_us(0.50) as f64 / 1e3,
            self.queue_wait_hist.percentile_us(0.99) as f64 / 1e3,
            self.prefill_chunk_hist.percentile_us(0.50) as f64 / 1e3,
            self.prefill_chunk_hist.percentile_us(0.99) as f64 / 1e3,
            self.decode_cycle_hist.percentile_us(0.50) as f64 / 1e3,
            self.decode_cycle_hist.percentile_us(0.99) as f64 / 1e3,
            self.prefix_cache_hits,
            self.prefix_cache_misses,
            self.prefix_cache_hit_rate() * 100.0,
            self.prefix_tokens_skipped,
            self.prefix_cache_entries,
            self.prefix_cache_bytes,
            self.prefix_cache_pinned,
            self.prefix_cache_evictions,
            self.panics_caught,
            self.numeric_faults_detected,
            self.fault_retries,
            self.fault_rollbacks,
            self.numeric_faulted,
            self.shed,
            self.worker_restarts,
            self.worker_failed,
            self.prefix_cache_quarantined,
            self.redrives,
            self.redrives_completed,
            self.mean_redrive_resume_seconds(),
            self.cache_recovered_snapshots,
            self.fault_events,
            self.fault_events_dropped,
            self.clip_events,
        )
    }

    /// Structured snapshot for benches and demos (`BENCH_*.json`
    /// fields, machine-readable serve reports) — every counter, the
    /// derived rates, and per-histogram latency percentiles.
    /// [`Metrics::report`] stays the human view of the same data.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("enqueued", self.enqueued)
            .set("admitted", self.admitted)
            .set("completed", self.completed)
            .set("tokens_generated", self.tokens_generated)
            .set("prefill_seconds_total", self.prefill_seconds_total)
            .set("decode_seconds_total", self.decode_seconds_total)
            .set("queue_seconds_total", self.queue_seconds_total)
            .set("first_tokens", self.first_tokens)
            .set("ttft_seconds_total", self.ttft_seconds_total)
            .set("clip_events", self.clip_events)
            .set("rejected", self.rejected)
            .set("quota_rejected", self.quota_rejected)
            .set("cancelled", self.cancelled)
            .set("deadline_exceeded", self.deadline_exceeded)
            .set("prompt_tokens_prefilled", self.prompt_tokens_prefilled)
            .set("queue_depth", self.queue_depth)
            .set("active_sessions", self.active_sessions)
            .set("prefix_cache_hits", self.prefix_cache_hits)
            .set("prefix_cache_misses", self.prefix_cache_misses)
            .set("prefix_tokens_skipped", self.prefix_tokens_skipped)
            .set("prefix_cache_bytes", self.prefix_cache_bytes)
            .set("prefix_cache_entries", self.prefix_cache_entries)
            .set("prefix_cache_evictions", self.prefix_cache_evictions)
            .set("prefix_cache_pinned", self.prefix_cache_pinned)
            .set("prefix_cache_quarantined", self.prefix_cache_quarantined)
            .set("shed", self.shed)
            .set("worker_restarts", self.worker_restarts)
            .set("worker_failed", self.worker_failed)
            .set("numeric_faulted", self.numeric_faulted)
            .set("fault_retries", self.fault_retries)
            .set("fault_rollbacks", self.fault_rollbacks)
            .set("panics_caught", self.panics_caught)
            .set("numeric_faults_detected", self.numeric_faults_detected)
            .set("redrives", self.redrives)
            .set("redrives_completed", self.redrives_completed)
            .set("redrives_resumed", self.redrives_resumed)
            .set("redrive_resume_seconds_total", self.redrive_resume_seconds_total)
            .set("cache_recovered_snapshots", self.cache_recovered_snapshots)
            .set("fault_events", self.fault_events)
            .set("fault_events_dropped", self.fault_events_dropped)
            .set("decode_cycles", self.decode_cycles)
            .set("weight_bytes_streamed", self.weight_bytes_streamed)
            .set("trace_events", self.trace_events)
            .set("trace_events_dropped", self.trace_events_dropped)
            .set("decode_tokens_per_sec", self.decode_tokens_per_sec())
            .set("mean_queue_seconds", self.mean_queue_seconds())
            .set("mean_ttft_seconds", self.mean_ttft_seconds())
            .set("mean_redrive_resume_seconds", self.mean_redrive_resume_seconds())
            .set("weight_bytes_per_cycle", self.weight_bytes_per_cycle())
            .set("prefix_cache_hit_rate", self.prefix_cache_hit_rate());
        let mut latency = Json::obj();
        latency
            .set("ttft", self.ttft_hist.to_json())
            .set("inter_token", self.inter_token_hist.to_json())
            .set("queue_wait", self.queue_wait_hist.to_json())
            .set("prefill_chunk", self.prefill_chunk_hist.to_json())
            .set("decode_cycle", self.decode_cycle_hist.to_json());
        j.set("latency", latency);
        // per-priority slices keyed by the level's decimal string —
        // what the HTTP load harness reads back to assert quota
        // isolation end to end
        let mut pp = Json::obj();
        for (lvl, p) in &self.per_priority {
            let mut o = Json::obj();
            o.set("queued", p.queued)
                .set("enqueued", p.enqueued)
                .set("admitted", p.admitted)
                .set("completed", p.completed)
                .set("shed", p.shed)
                .set("quota_rejected", p.quota_rejected);
            pp.set(&lvl.to_string(), o);
        }
        j.set("per_priority", pp);
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rates_guard_div_zero() {
        let m = Metrics::default();
        assert_eq!(m.decode_tokens_per_sec(), 0.0);
        assert_eq!(m.mean_queue_seconds(), 0.0);
        assert_eq!(m.mean_ttft_seconds(), 0.0);
        assert_eq!(m.prefix_cache_hit_rate(), 0.0);
        assert_eq!(m.weight_bytes_per_cycle(), 0.0);
    }

    #[test]
    fn report_contains_counts() {
        let m = Metrics {
            enqueued: 3,
            admitted: 2,
            completed: 1,
            tokens_generated: 42,
            prefill_seconds_total: 0.5,
            decode_seconds_total: 2.0,
            queue_seconds_total: 0.1,
            first_tokens: 1,
            ttft_seconds_total: 0.25,
            clip_events: 7,
            rejected: 4,
            cancelled: 5,
            deadline_exceeded: 6,
            prompt_tokens_prefilled: 512,
            queue_depth: 9,
            active_sessions: 3,
            prefix_cache_hits: 3,
            prefix_cache_misses: 1,
            prefix_tokens_skipped: 3072,
            prefix_cache_bytes: 40960,
            prefix_cache_entries: 16,
            prefix_cache_evictions: 2,
            prefix_cache_pinned: 5,
            prefix_cache_quarantined: 11,
            shed: 12,
            worker_restarts: 13,
            worker_failed: 14,
            numeric_faulted: 15,
            fault_retries: 17,
            fault_rollbacks: 18,
            panics_caught: 19,
            numeric_faults_detected: 20,
            redrives: 21,
            redrives_completed: 8,
            redrives_resumed: 2,
            redrive_resume_seconds_total: 0.5,
            cache_recovered_snapshots: 23,
            fault_events: 24,
            fault_events_dropped: 25,
            decode_cycles: 10,
            weight_bytes_streamed: 20480,
            ..Default::default()
        };
        let r = m.report();
        assert!(r.contains("42 generated"));
        assert!(r.contains("21.0 tok/s"));
        assert!(r.contains("20480 weight B streamed / 10 decode cycles (2048 B per cycle)"));
        assert_eq!(m.weight_bytes_per_cycle(), 2048.0);
        assert!(r.contains("0.2500 s mean (enqueue -> first token)"));
        assert!(r.contains("7 activations at the 9-bit rails"));
        assert!(r.contains("9 queued / 3 active now, 4 rejected (queue full), 5 cancelled, 6 deadline-exceeded"));
        assert!(r.contains("512 prompt tokens forwarded"));
        assert!(r.contains("3 hits / 1 misses (75% hit rate)"));
        assert!(r.contains("3072 prompt tokens skipped"));
        assert!(r.contains("16 snapshots / 40960 B resident (5 pinned), 2 evictions"));
        assert!(r.contains(
            "19 panics caught, 20 non-finite panels, 17 retries / 18 rollbacks, \
             15 numeric-faulted sessions, 12 shed, 13 worker restarts (14 sessions failed), \
             11 snapshots quarantined"
        ));
        assert!(r.contains(
            "21 redrives (8 completed), 0.2500 s mean resume-after-fault, \
             23 snapshots survived recovery, 24 journal records (25 dropped)"
        ));
        assert_eq!(m.prefix_cache_hit_rate(), 0.75);
    }

    #[test]
    fn report_prints_latency_percentiles() {
        let mut m = Metrics::default();
        // 100 TTFT observations 1..=100 ms, inter-token 1..=100 µs
        for i in 1..=100u64 {
            m.ttft_hist.record_us(i * 1000);
            m.inter_token_hist.record_us(i);
        }
        m.queue_wait_hist.record_us(500);
        m.prefill_chunk_hist.record_us(2_000);
        m.decode_cycle_hist.record_us(3_000);
        let r = m.report();
        assert!(r.contains("latency:  ttft p50"), "missing ttft latency line:\n{r}");
        assert!(r.contains("latency:  inter-token p50"), "missing inter-token line:\n{r}");
        assert!(r.contains("decode-cycle p50"), "missing cycle line:\n{r}");
        // p50 of 1..=100 ms is the bucket containing 51 ms; exact-ish
        let p50_ms = m.ttft_hist.percentile_us(0.50) as f64 / 1e3;
        assert!((44.0..=51.0).contains(&p50_ms), "ttft p50 {p50_ms} ms");
        // inter-token values < 16 µs..100 µs: p99 bucket holds 100 µs
        let (lo, hi) = m.inter_token_hist.percentile_range_us(0.99);
        assert!(lo <= 100 && 100 < hi);
    }

    #[test]
    fn report_and_json_carry_per_priority_slices() {
        let mut m = Metrics { quota_rejected: 4, ..Default::default() };
        *m.prio(5) = PriorityCounters {
            queued: 1,
            enqueued: 10,
            admitted: 9,
            completed: 8,
            shed: 0,
            quota_rejected: 0,
        };
        *m.prio(-1) = PriorityCounters {
            queued: 2,
            enqueued: 6,
            admitted: 2,
            completed: 2,
            shed: 1,
            quota_rejected: 4,
        };
        let r = m.report();
        assert!(r.contains("quota:    4 rejected over quota"), "missing quota line:\n{r}");
        assert!(r.contains("p-1: 2 queued, 6/2/2 enq/adm/done, 1 shed, 4 quota-rejected"), "{r}");
        assert!(r.contains("p5: 1 queued, 10/9/8 enq/adm/done, 0 shed, 0 quota-rejected"), "{r}");
        let back = crate::util::json::parse(&m.to_json().to_string()).unwrap();
        assert_eq!(back.req("quota_rejected").unwrap().as_usize().unwrap(), 4);
        let pp = back.req("per_priority").unwrap();
        assert_eq!(pp.req("5").unwrap().req("admitted").unwrap().as_usize().unwrap(), 9);
        assert_eq!(pp.req("-1").unwrap().req("quota_rejected").unwrap().as_usize().unwrap(), 4);
    }

    #[test]
    fn to_json_roundtrips_counters_and_latency() {
        let mut m = Metrics {
            enqueued: 3,
            admitted: 2,
            tokens_generated: 42,
            decode_seconds_total: 2.0,
            ..Default::default()
        };
        for _ in 0..10 {
            m.ttft_hist.record_us(10_000);
        }
        let j = m.to_json();
        let back = crate::util::json::parse(&j.to_string()).unwrap();
        assert_eq!(back.req("enqueued").unwrap().as_usize().unwrap(), 3);
        assert_eq!(back.req("tokens_generated").unwrap().as_usize().unwrap(), 42);
        assert_eq!(back.req("decode_tokens_per_sec").unwrap().as_f64().unwrap(), 21.0);
        let ttft = back.req("latency").unwrap().req("ttft").unwrap();
        assert_eq!(ttft.req("count").unwrap().as_usize().unwrap(), 10);
        let p50 = ttft.req("p50_ms").unwrap().as_f64().unwrap();
        assert!((8.75..=10.0).contains(&p50), "p50_ms {p50} outside bucket bound");
    }
}
