//! Serving metrics: counters + derived rates, printable as a report.

#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub enqueued: u64,
    pub admitted: u64,
    pub completed: u64,
    pub tokens_generated: u64,
    pub prefill_seconds_total: f64,
    pub decode_seconds_total: f64,
    pub queue_seconds_total: f64,
    /// Sessions that actually produced a first token (finished prefill).
    /// A session whose prefill errors completes without one, so TTFT
    /// means are taken over this count, not `completed`.
    pub first_tokens: u64,
    /// Sum over sessions counted in `first_tokens` of time-to-first-token
    /// (enqueue → first sampled token, i.e. queueing + chunked prefill as
    /// actually interleaved with other sessions' decode).
    pub ttft_seconds_total: f64,
    /// Activations that clipped at the hardware backend's 9-bit rails,
    /// drained losslessly from the model every scheduling cycle (large
    /// values mean a bad calibration).  Always 0 for non-hw models.
    pub clip_events: u64,
    /// Admissions that resumed from a cached prompt-prefix state
    /// (mirror of the engine's `statecache` counters, refreshed every
    /// scheduling cycle; all 0 with the cache disabled).
    pub prefix_cache_hits: u64,
    /// Admissions that found no usable cached prefix.
    pub prefix_cache_misses: u64,
    /// Prompt tokens whose prefill was skipped entirely by resuming
    /// from cached states — the cache's value, in tokens.
    pub prefix_tokens_skipped: u64,
    /// Gauge: bytes of state snapshots currently resident.
    pub prefix_cache_bytes: u64,
    /// Gauge: state snapshots currently resident.
    pub prefix_cache_entries: u64,
    /// Snapshots evicted by LRU under byte-budget pressure.
    pub prefix_cache_evictions: u64,
}

impl Metrics {
    /// Decode throughput over completed work (tokens/s of engine time).
    pub fn decode_tokens_per_sec(&self) -> f64 {
        if self.decode_seconds_total > 0.0 {
            self.tokens_generated as f64 / self.decode_seconds_total
        } else {
            0.0
        }
    }

    pub fn mean_queue_seconds(&self) -> f64 {
        if self.admitted > 0 {
            self.queue_seconds_total / self.admitted as f64
        } else {
            0.0
        }
    }

    /// Mean time-to-first-token over sessions that produced one.
    pub fn mean_ttft_seconds(&self) -> f64 {
        if self.first_tokens > 0 {
            self.ttft_seconds_total / self.first_tokens as f64
        } else {
            0.0
        }
    }

    /// Fraction of admissions that resumed from a cached prefix.
    pub fn prefix_cache_hit_rate(&self) -> f64 {
        let total = self.prefix_cache_hits + self.prefix_cache_misses;
        if total > 0 {
            self.prefix_cache_hits as f64 / total as f64
        } else {
            0.0
        }
    }

    pub fn report(&self) -> String {
        format!(
            "requests: {} enqueued / {} admitted / {} completed\n\
             tokens:   {} generated\n\
             decode:   {:.1} tok/s (engine time)\n\
             prefill:  {:.3} s total\n\
             ttft:     {:.4} s mean (enqueue -> first token)\n\
             queueing: {:.4} s mean wait\n\
             cache:    {} hits / {} misses ({:.0}% hit rate), \
             {} prompt tokens skipped, {} snapshots / {} B resident, {} evictions\n\
             clips:    {} activations at the 9-bit rails",
            self.enqueued,
            self.admitted,
            self.completed,
            self.tokens_generated,
            self.decode_tokens_per_sec(),
            self.prefill_seconds_total,
            self.mean_ttft_seconds(),
            self.mean_queue_seconds(),
            self.prefix_cache_hits,
            self.prefix_cache_misses,
            self.prefix_cache_hit_rate() * 100.0,
            self.prefix_tokens_skipped,
            self.prefix_cache_entries,
            self.prefix_cache_bytes,
            self.prefix_cache_evictions,
            self.clip_events,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rates_guard_div_zero() {
        let m = Metrics::default();
        assert_eq!(m.decode_tokens_per_sec(), 0.0);
        assert_eq!(m.mean_queue_seconds(), 0.0);
        assert_eq!(m.mean_ttft_seconds(), 0.0);
        assert_eq!(m.prefix_cache_hit_rate(), 0.0);
    }

    #[test]
    fn report_contains_counts() {
        let m = Metrics {
            enqueued: 3,
            admitted: 2,
            completed: 1,
            tokens_generated: 42,
            prefill_seconds_total: 0.5,
            decode_seconds_total: 2.0,
            queue_seconds_total: 0.1,
            first_tokens: 1,
            ttft_seconds_total: 0.25,
            clip_events: 7,
            prefix_cache_hits: 3,
            prefix_cache_misses: 1,
            prefix_tokens_skipped: 3072,
            prefix_cache_bytes: 40960,
            prefix_cache_entries: 16,
            prefix_cache_evictions: 2,
        };
        let r = m.report();
        assert!(r.contains("42 generated"));
        assert!(r.contains("21.0 tok/s"));
        assert!(r.contains("0.2500 s mean (enqueue -> first token)"));
        assert!(r.contains("7 activations at the 9-bit rails"));
        assert!(r.contains("3 hits / 1 misses (75% hit rate)"));
        assert!(r.contains("3072 prompt tokens skipped"));
        assert!(r.contains("16 snapshots / 40960 B resident, 2 evictions"));
        assert_eq!(m.prefix_cache_hit_rate(), 0.75);
    }
}
