//! The TCP serving tier: an accept loop feeding a bounded
//! connection-handler pool, a three-route router, and the
//! request-body → [`GenRequest`] translation.
//!
//! Threading model.  `Server::bind` spawns one accept thread plus
//! `handlers` worker threads.  Accepted sockets flow through a
//! `sync_channel(backlog)`: when every handler is busy and the backlog
//! is full, the accept thread answers `503` inline and closes — the
//! transport sheds load instead of queueing connections invisibly,
//! mirroring the coordinator's bounded-admission-queue philosophy.
//!
//! Cancellation.  A streaming handler owns the session's
//! [`crate::coordinator::GenStream`]; when the client disconnects, the next SSE write
//! fails with `BrokenPipe` (Rust ignores SIGPIPE), the handler returns,
//! and dropping the stream cancels the session at the next cycle
//! boundary — active slot and prefix-cache pins are reclaimed without
//! any server-side bookkeeping.

use std::collections::BTreeMap;
use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, TrySendError};
use std::sync::{Arc, Mutex, PoisonError};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::coordinator::{Coordinator, GenEvent, GenRequest, GenResponse, SubmitError};
use crate::net::http::{self, HttpError, Request};
use crate::util::json::{parse_bytes, Json};

/// Turns a `"prompt": "text"` string into token ids.  Optional: a
/// server without one only accepts `"prompt": [ids...]` and answers
/// `400` to string prompts, which is the right default for a tier that
/// may not have the tokenizer loaded (benches, tests).
pub type Encoder = Arc<dyn Fn(&str) -> crate::Result<Vec<u32>> + Send + Sync>;

/// Knobs for [`Server::bind_with`].
#[derive(Clone)]
pub struct ServerConfig {
    /// Connection-handler threads.  Each streaming request occupies one
    /// for its whole lifetime, so this caps concurrent SSE streams;
    /// size it at least `max_active + max_queue` to let the
    /// coordinator, not the transport, be the admission authority.
    pub handlers: usize,
    /// Accepted-but-unhandled connections allowed to wait; beyond this
    /// the accept loop sheds with an inline `503`.
    pub backlog: usize,
    /// Request-body cap; larger `Content-Length` is refused with `413`
    /// before any body bytes are read.
    pub max_body_bytes: usize,
    /// See [`Encoder`].
    pub encoder: Option<Encoder>,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig { handlers: 32, backlog: 64, max_body_bytes: 1 << 20, encoder: None }
    }
}

/// The HTTP/SSE front-end.  Owns the accept + handler threads;
/// dropping it (or calling [`Server::shutdown`]) stops accepting,
/// drains in-flight handlers, and joins everything.
pub struct Server {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    handlers: Vec<JoinHandle<()>>,
}

struct HandlerCtx {
    coordinator: Arc<Coordinator>,
    max_body: usize,
    encoder: Option<Encoder>,
}

impl Server {
    /// Bind with default config.  `addr` may be `"127.0.0.1:0"` for an
    /// ephemeral port — read it back with [`Server::addr`].
    pub fn bind(addr: impl ToSocketAddrs, coordinator: Arc<Coordinator>) -> std::io::Result<Server> {
        Server::bind_with(addr, coordinator, ServerConfig::default())
    }

    pub fn bind_with(
        addr: impl ToSocketAddrs,
        coordinator: Arc<Coordinator>,
        cfg: ServerConfig,
    ) -> std::io::Result<Server> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let (tx, rx) = sync_channel::<TcpStream>(cfg.backlog.max(1));
        let rx = Arc::new(Mutex::new(rx));
        let ctx = Arc::new(HandlerCtx {
            coordinator,
            max_body: cfg.max_body_bytes.max(1),
            encoder: cfg.encoder.clone(),
        });
        let mut handlers = Vec::new();
        for _ in 0..cfg.handlers.max(1) {
            let rx = rx.clone();
            let ctx = ctx.clone();
            handlers.push(std::thread::spawn(move || handler_loop(&rx, &ctx)));
        }
        let stop2 = stop.clone();
        let accept = std::thread::spawn(move || {
            for stream in listener.incoming() {
                if stop2.load(Ordering::Acquire) {
                    return; // tx drops here → handlers drain and exit
                }
                let Ok(stream) = stream else { continue };
                match tx.try_send(stream) {
                    Ok(()) => {}
                    Err(TrySendError::Full(mut stream)) => {
                        // every handler busy AND the backlog full: shed at
                        // the transport instead of queueing invisibly
                        let _ = http::write_error(
                            &mut stream,
                            &HttpError::new(503, "server connection backlog is full"),
                        );
                    }
                    Err(TrySendError::Disconnected(_)) => return,
                }
            }
        });
        Ok(Server { addr: local, stop, accept: Some(accept), handlers })
    }

    /// The bound address (resolves `:0` ephemeral ports).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, finish in-flight connections, join all threads.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        let Some(accept) = self.accept.take() else { return };
        self.stop.store(true, Ordering::Release);
        // unblock the accept loop: it re-checks `stop` per connection
        let _ = TcpStream::connect(self.addr);
        let _ = accept.join();
        for h in self.handlers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

fn handler_loop(rx: &Arc<Mutex<Receiver<TcpStream>>>, ctx: &HandlerCtx) {
    loop {
        // hold the lock only for the recv; streaming happens unlocked
        let stream = match rx.lock().unwrap_or_else(PoisonError::into_inner).recv() {
            Ok(s) => s,
            Err(_) => return, // accept thread gone and queue drained
        };
        handle_connection(stream, ctx);
    }
}

/// One connection = one request = one response (`Connection: close`).
fn handle_connection(stream: TcpStream, ctx: &HandlerCtx) {
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let mut writer = stream;
    let req = match http::read_request(&mut reader, ctx.max_body) {
        Ok(Some(req)) => req,
        Ok(None) => return, // connected and left without a request
        Err(e) => {
            let _ = http::write_error(&mut writer, &e);
            return;
        }
    };
    if let Err(e) = route(&req, &mut writer, ctx) {
        let _ = http::write_error(&mut writer, &e);
    }
}

fn route(req: &Request, w: &mut TcpStream, ctx: &HandlerCtx) -> std::result::Result<(), HttpError> {
    match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/v1/generate") => generate(req, w, ctx),
        ("GET", "/metrics") => {
            let m = ctx
                .coordinator
                .metrics
                .lock()
                .unwrap_or_else(PoisonError::into_inner)
                .clone();
            http::write_json(w, &m.to_json()).map_err(client_gone)
        }
        ("GET", "/trace") => {
            http::write_json(w, &ctx.coordinator.export_trace_json()).map_err(client_gone)
        }
        (method, "/v1/generate" | "/metrics" | "/trace") => Err(HttpError::new(
            405,
            format!("method {method} not allowed on {}", req.path),
        )),
        (method, path) => Err(HttpError::new(404, format!("no route {method} {path}"))),
    }
}

/// A write failure after routing means the client hung up; there is
/// nobody left to answer, so swallow it (the caller's error write will
/// fail the same way and is also ignored).
fn client_gone(_: std::io::Error) -> HttpError {
    HttpError::new(500, "client disconnected")
}

fn generate(req: &Request, w: &mut TcpStream, ctx: &HandlerCtx) -> std::result::Result<(), HttpError> {
    let gen_req = parse_gen_request(&req.body, &req.headers, ctx.encoder.as_ref())?;
    let mut stream = ctx.coordinator.submit(gen_req).map_err(submit_error)?;
    // From here on the status line is already committed: stream until
    // the session ends or the client disconnects.  A failed write drops
    // `stream`, which cancels the session at the next cycle boundary.
    if http::write_sse_headers(w).is_err() {
        return Ok(());
    }
    while let Some(ev) = stream.recv() {
        let (name, data) = event_frame(&ev);
        if http::write_sse_event(w, name, &data).is_err() {
            return Ok(());
        }
    }
    Ok(())
}

fn submit_error(e: SubmitError) -> HttpError {
    let status = match e {
        SubmitError::QueueFull { .. } | SubmitError::QuotaExceeded { .. } => 429,
        SubmitError::ShutDown => 503,
    };
    HttpError::new(status, e.to_string())
}

/// SSE wire form of one [`GenEvent`] — names and fields documented in
/// the coordinator module docs ("Network serving").
fn event_frame(ev: &GenEvent) -> (&'static str, Json) {
    let mut data = Json::obj();
    match ev {
        GenEvent::Started { branch, cached_prefix_tokens } => {
            data.set("branch", *branch).set("cached_prefix_tokens", *cached_prefix_tokens);
            ("started", data)
        }
        GenEvent::Token { branch, token, seq_idx } => {
            data.set("branch", *branch)
                .set("token", *token as u64)
                .set("seq_idx", *seq_idx);
            ("token", data)
        }
        GenEvent::Redriven { branch, attempt, replayed_from } => {
            data.set("branch", *branch)
                .set("attempt", *attempt as u64)
                .set("replayed_from", *replayed_from);
            ("redriven", data)
        }
        GenEvent::Finished(r) => ("finished", response_json(r)),
        GenEvent::Error { branch, message } => {
            data.set("branch", *branch).set("message", message.as_str());
            ("error", data)
        }
    }
}

fn response_json(r: &GenResponse) -> Json {
    let mut data = Json::obj();
    data.set("request_id", r.request_id)
        .set("branch", r.branch)
        .set("tokens", Json::Arr(r.tokens.iter().map(|&t| Json::from(t as u64)).collect()))
        .set("finish_reason", r.finish.as_str())
        .set("prefill_seconds", r.prefill_seconds)
        .set("decode_seconds", r.decode_seconds)
        .set("queue_seconds", r.queue_seconds)
        .set("ttft_seconds", r.ttft_seconds)
        .set("cached_prefix_tokens", r.cached_prefix_tokens);
    data
}

/// Translate a `POST /v1/generate` body + headers into a [`GenRequest`].
///
/// Body (JSON object): `prompt` (required: array of token ids, or a
/// string when the server has an [`Encoder`]), `max_new_tokens`
/// (required), and optional `temperature`, `top_k`, `seed`, `n_best`,
/// `stop_token`, `redrive_budget`, `priority`, `deadline_ms`.
/// `X-Priority` / `X-Deadline-Ms` headers override the body fields.
/// Every malformed input is a `400` with a field-specific message —
/// public so unit tests can exercise the mapping without a socket.
pub fn parse_gen_request(
    body: &[u8],
    headers: &BTreeMap<String, String>,
    encoder: Option<&Encoder>,
) -> std::result::Result<GenRequest, HttpError> {
    let bad = |msg: String| HttpError::new(400, msg);
    let json = parse_bytes(body).map_err(|e| bad(format!("body is not valid JSON: {e}")))?;
    let prompt = match json.get("prompt") {
        Some(Json::Str(text)) => match encoder {
            Some(enc) => {
                enc(text).map_err(|e| bad(format!("encoding string prompt: {e}")))?
            }
            None => {
                return Err(bad(
                    "string prompts need a server-side tokenizer; send \"prompt\" as an array of token ids".into(),
                ))
            }
        },
        // strict element-wise conversion: `as_u32_vec` float-casts, which
        // would silently saturate a negative id to 0 instead of rejecting
        Some(Json::Arr(items)) => items
            .iter()
            .map(|v| {
                let n = v
                    .as_i64()
                    .map_err(|e| bad(format!("\"prompt\" must be an array of token ids: {e}")))?;
                u32::try_from(n)
                    .map_err(|_| bad(format!("\"prompt\" token id {n} is out of range")))
            })
            .collect::<std::result::Result<Vec<u32>, HttpError>>()?,
        Some(_) => return Err(bad("\"prompt\" must be an array of token ids or a string".into())),
        None => return Err(bad("missing required field \"prompt\"".into())),
    };
    let max_new_tokens = json
        .get("max_new_tokens")
        .ok_or_else(|| bad("missing required field \"max_new_tokens\"".into()))?
        .as_usize()
        .map_err(|e| bad(format!("\"max_new_tokens\": {e}")))?;
    let mut b = GenRequest::builder(prompt, max_new_tokens);
    if let Some(v) = json.get("temperature") {
        b = b.temperature(v.as_f64().map_err(|e| bad(format!("\"temperature\": {e}")))? as f32);
    }
    if let Some(v) = json.get("top_k") {
        b = b.top_k(v.as_usize().map_err(|e| bad(format!("\"top_k\": {e}")))?);
    }
    if let Some(v) = json.get("seed") {
        b = b.seed(v.as_i64().map_err(|e| bad(format!("\"seed\": {e}")))? as u64);
    }
    if let Some(v) = json.get("n_best") {
        b = b.n_best(v.as_usize().map_err(|e| bad(format!("\"n_best\": {e}")))?);
    }
    if let Some(v) = json.get("stop_token") {
        let t = v.as_i64().map_err(|e| bad(format!("\"stop_token\": {e}")))?;
        b = b.stop_token(u32::try_from(t).map_err(|_| bad(format!("\"stop_token\": {t} out of range")))?);
    }
    if let Some(v) = json.get("redrive_budget") {
        let n = v.as_usize().map_err(|e| bad(format!("\"redrive_budget\": {e}")))?;
        b = b.redrive_budget(n as u32);
    }
    if let Some(v) = json.get("priority") {
        b = b.priority(v.as_i64().map_err(|e| bad(format!("\"priority\": {e}")))? as i32);
    }
    if let Some(v) = json.get("deadline_ms") {
        let ms = v.as_i64().map_err(|e| bad(format!("\"deadline_ms\": {e}")))?;
        let ms = u64::try_from(ms).map_err(|_| bad(format!("\"deadline_ms\": {ms} is negative")))?;
        b = b.deadline(Duration::from_millis(ms));
    }
    // headers override the body — lets a proxy/admission layer reclass
    // traffic without rewriting the JSON
    if let Some(v) = headers.get("x-priority") {
        let p: i32 = v
            .parse()
            .map_err(|_| bad(format!("X-Priority header {v:?} is not an integer")))?;
        b = b.priority(p);
    }
    if let Some(v) = headers.get("x-deadline-ms") {
        let ms: u64 = v
            .parse()
            .map_err(|_| bad(format!("X-Deadline-Ms header {v:?} is not a non-negative integer")))?;
        b = b.deadline(Duration::from_millis(ms));
    }
    Ok(b.build())
}
