//! Minimal HTTP/1.1 primitives for the serving front-end — request
//! reading (request line + headers + `Content-Length` body, all
//! bounded), response writing (fixed JSON bodies and SSE streams), and
//! the typed status error everything in the router maps onto.
//!
//! Deliberately not a general HTTP implementation: one request per
//! connection, `Connection: close` on every response (so SSE bodies
//! are close-delimited and need no chunked encoding), no keep-alive,
//! no chunked *requests*, no TLS.  That subset is exactly what the
//! load harness and a curl client need, with zero dependencies beyond
//! `std::net`.

use std::collections::BTreeMap;
use std::io::{BufRead, Read, Write};

use crate::util::json::Json;

/// Cap on the request line + headers combined — a client that streams
/// an unbounded header section is cut off with `431`-ish failure (we
/// report 400) instead of growing a String without bound.
pub const MAX_HEAD_BYTES: usize = 16 * 1024;

/// One parsed request.  Header names are lowercased (HTTP headers are
/// case-insensitive); values keep their bytes minus surrounding
/// whitespace.
#[derive(Clone, Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub headers: BTreeMap<String, String>,
    pub body: Vec<u8>,
}

impl Request {
    /// Case-insensitive header lookup (names are stored lowercased).
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.get(&name.to_ascii_lowercase()).map(String::as_str)
    }
}

/// Typed failure while reading, parsing, or routing a request:
/// `status` goes on the status line, `message` into the JSON error
/// body.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HttpError {
    pub status: u16,
    pub message: String,
}

impl HttpError {
    pub fn new(status: u16, message: impl Into<String>) -> HttpError {
        HttpError { status, message: message.into() }
    }
}

impl std::fmt::Display for HttpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} {}: {}", self.status, status_text(self.status), self.message)
    }
}

impl std::error::Error for HttpError {}

/// Reason phrase for the status codes this tier emits.
pub fn status_text(code: u16) -> &'static str {
    match code {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Read one request off the stream.  `Ok(None)` is a clean EOF before
/// any bytes (client connected and left) — not an error, nothing to
/// answer.  `Err` carries the status the caller should write back
/// (400 malformed, 413 over `max_body`).
pub fn read_request<R: BufRead>(
    r: &mut R,
    max_body: usize,
) -> std::result::Result<Option<Request>, HttpError> {
    let mut head_bytes = 0usize;
    let mut line = String::new();
    // request line; tolerate one leading empty line (RFC 7230 §3.5)
    let request_line = loop {
        line.clear();
        match r.read_line(&mut line) {
            Ok(0) => return Ok(None),
            Ok(n) => head_bytes += n,
            Err(e) => return Err(HttpError::new(400, format!("reading request line: {e}"))),
        }
        if head_bytes > MAX_HEAD_BYTES {
            return Err(HttpError::new(400, "request head too large"));
        }
        let t = line.trim_end_matches(['\r', '\n']);
        if !t.is_empty() {
            break t.to_string();
        }
    };
    let mut parts = request_line.split_whitespace();
    let (method, path, version) = match (parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(p), Some(v)) if v.starts_with("HTTP/1.") => {
            (m.to_string(), p.to_string(), v)
        }
        _ => return Err(HttpError::new(400, format!("malformed request line {request_line:?}"))),
    };
    let _ = version;
    let mut headers = BTreeMap::new();
    loop {
        line.clear();
        match r.read_line(&mut line) {
            Ok(0) => return Err(HttpError::new(400, "EOF inside headers")),
            Ok(n) => head_bytes += n,
            Err(e) => return Err(HttpError::new(400, format!("reading headers: {e}"))),
        }
        if head_bytes > MAX_HEAD_BYTES {
            return Err(HttpError::new(400, "request head too large"));
        }
        let t = line.trim_end_matches(['\r', '\n']);
        if t.is_empty() {
            break;
        }
        let Some((name, value)) = t.split_once(':') else {
            return Err(HttpError::new(400, format!("malformed header {t:?}")));
        };
        headers.insert(name.trim().to_ascii_lowercase(), value.trim().to_string());
    }
    // body: Content-Length only (no chunked requests in this subset)
    let body = match headers.get("content-length") {
        None => Vec::new(),
        Some(v) => {
            let n: usize = v
                .parse()
                .map_err(|_| HttpError::new(400, format!("bad Content-Length {v:?}")))?;
            if n > max_body {
                return Err(HttpError::new(
                    413,
                    format!("body is {n} bytes, limit {max_body}"),
                ));
            }
            let mut body = vec![0u8; n];
            r.read_exact(&mut body)
                .map_err(|e| HttpError::new(400, format!("reading {n}-byte body: {e}")))?;
            body
        }
    };
    Ok(Some(Request { method, path, headers, body }))
}

/// Write a complete response.  Every response closes the connection
/// (one request per connection keeps the server stateless per socket
/// and makes SSE bodies close-delimited).
pub fn write_response(
    w: &mut impl Write,
    status: u16,
    content_type: &str,
    extra_headers: &[(&str, &str)],
    body: &[u8],
) -> std::io::Result<()> {
    write!(
        w,
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n",
        status,
        status_text(status),
        content_type,
        body.len()
    )?;
    for (name, value) in extra_headers {
        write!(w, "{name}: {value}\r\n")?;
    }
    w.write_all(b"\r\n")?;
    w.write_all(body)?;
    w.flush()
}

/// Write `e` as a JSON error response.  Backpressure statuses (429 /
/// 503) carry `Retry-After` so well-behaved clients pace themselves.
pub fn write_error(w: &mut impl Write, e: &HttpError) -> std::io::Result<()> {
    let mut body = Json::obj();
    body.set("error", e.message.as_str()).set("status", e.status as u64);
    let extra: &[(&str, &str)] =
        if matches!(e.status, 429 | 503) { &[("Retry-After", "1")] } else { &[] };
    write_response(w, e.status, "application/json", extra, body.to_string().as_bytes())
}

/// Write a 200 with a JSON body.
pub fn write_json(w: &mut impl Write, body: &Json) -> std::io::Result<()> {
    write_response(w, 200, "application/json", &[], body.to_string().as_bytes())
}

/// Open an SSE response: after this, the body is a sequence of
/// [`write_sse_event`] frames until the connection closes.
pub fn write_sse_headers(w: &mut impl Write) -> std::io::Result<()> {
    w.write_all(
        b"HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\nCache-Control: no-cache\r\nConnection: close\r\n\r\n",
    )?;
    w.flush()
}

/// One SSE frame: `event: <name>` + a single `data:` line.  The JSON
/// writer escapes every control character (see `util::json`), so the
/// payload can never contain a raw newline that would break framing —
/// that guarantee is what lets `data` stay a single line.  Flushes per
/// frame: a token event must reach the client when it is committed,
/// not when a buffer happens to fill.
pub fn write_sse_event(w: &mut impl Write, event: &str, data: &Json) -> std::io::Result<()> {
    write!(w, "event: {event}\ndata: {}\n\n", data.to_string())?;
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::BufReader;

    fn req(text: &str) -> std::result::Result<Option<Request>, HttpError> {
        read_request(&mut BufReader::new(text.as_bytes()), 1024)
    }

    #[test]
    fn parses_request_with_body() {
        let r = req("POST /v1/generate HTTP/1.1\r\nHost: x\r\nX-Priority: 3\r\nContent-Length: 4\r\n\r\nabcd")
            .unwrap()
            .unwrap();
        assert_eq!(r.method, "POST");
        assert_eq!(r.path, "/v1/generate");
        assert_eq!(r.header("x-priority"), Some("3"));
        assert_eq!(r.header("X-Priority"), Some("3"), "lookup is case-insensitive");
        assert_eq!(r.body, b"abcd");
    }

    #[test]
    fn clean_eof_is_none() {
        assert!(req("").unwrap().is_none());
    }

    #[test]
    fn oversized_body_is_413() {
        let e = req("POST / HTTP/1.1\r\nContent-Length: 999999\r\n\r\n").unwrap_err();
        assert_eq!(e.status, 413);
    }

    #[test]
    fn garbage_is_400() {
        for bad in [
            "nonsense\r\n\r\n",
            "GET /\r\n\r\n",
            "POST / HTTP/1.1\r\nContent-Length: zero\r\n\r\n",
            "POST / HTTP/1.1\r\nno-colon-here\r\n\r\n",
            "POST / HTTP/1.1\r\nContent-Length: 10\r\n\r\nshort",
        ] {
            let e = req(bad).unwrap_err();
            assert_eq!(e.status, 400, "{bad:?}");
        }
    }

    #[test]
    fn unbounded_head_is_rejected() {
        let mut text = String::from("GET / HTTP/1.1\r\n");
        for i in 0..2000 {
            text.push_str(&format!("X-Filler-{i}: aaaaaaaaaaaaaaaa\r\n"));
        }
        text.push_str("\r\n");
        let e = req(&text).unwrap_err();
        assert_eq!(e.status, 400);
        assert!(e.message.contains("too large"));
    }

    #[test]
    fn sse_frame_shape() {
        let mut out = Vec::new();
        let mut data = Json::obj();
        data.set("token", 7u64).set("text", "hi\nthere");
        write_sse_event(&mut out, "token", &data).unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.starts_with("event: token\ndata: {"));
        assert!(s.ends_with("\n\n"));
        // exactly one blank-line frame terminator: the escaped \n in the
        // payload must NOT have produced a raw newline
        assert_eq!(s.matches('\n').count(), 3, "{s:?}");
    }

    #[test]
    fn error_responses_carry_retry_after_on_backpressure() {
        let mut out = Vec::new();
        write_error(&mut out, &HttpError::new(429, "queue full")).unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.starts_with("HTTP/1.1 429 Too Many Requests\r\n"));
        assert!(s.contains("Retry-After: 1\r\n"));
        let mut out = Vec::new();
        write_error(&mut out, &HttpError::new(400, "nope")).unwrap();
        assert!(!String::from_utf8(out).unwrap().contains("Retry-After"));
    }
}
