//! Network serving tier: a dependency-free HTTP/1.1 + SSE front-end
//! over the in-process [`crate::coordinator::Coordinator`].
//!
//! [`Server::bind`] runs an accept loop with a bounded handler pool;
//! `POST /v1/generate` streams [`crate::coordinator::GenEvent`]s as
//! SSE frames, `GET /metrics` and `GET /trace` expose the
//! coordinator's observability surfaces.  The full wire contract
//! (routes, body fields, header overrides, status mapping, quota
//! semantics) lives in the coordinator module docs under "Network
//! serving"; the load harness that drives this tier over real sockets
//! is [`crate::loadgen`].
//!
//! Built on `std::net` only — no async runtime, no HTTP crate.  One
//! thread per in-flight connection, which matches the coordinator's
//! scale (tens of concurrent sessions, admission-bounded), keeps
//! cancellation trivial (client disconnect → write error → `GenStream`
//! drop → session reaped), and adds nothing to the dependency graph.

pub mod http;
pub mod server;

pub use http::{HttpError, Request};
pub use server::{parse_gen_request, Encoder, Server, ServerConfig};
