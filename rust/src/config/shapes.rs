//! RWKV-4 model shapes (the published family plus the tiny served model).
//!
//! The simulator and the analytic baselines need *shapes only* — byte
//! traffic and cycle counts are functions of tensor dimensions, never of
//! weight values (DESIGN.md §2).



/// Architecture shape of an RWKV-4 model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ModelShape {
    pub name: &'static str,
    pub n_layer: usize,
    pub d_model: usize,
    pub d_ffn: usize,
    pub vocab: usize,
}

impl ModelShape {
    pub const fn new(
        name: &'static str,
        n_layer: usize,
        d_model: usize,
        d_ffn: usize,
        vocab: usize,
    ) -> Self {
        Self { name, n_layer, d_model, d_ffn, vocab }
    }

    /// Parameters held in *matrices* (Δ-PoT quantized, streamed per token
    /// in large-model mode).  Mirrors `python/compile/config.py`.
    pub fn matrix_params(&self) -> u64 {
        let (d, f, v, n) = (
            self.d_model as u64,
            self.d_ffn as u64,
            self.vocab as u64,
            self.n_layer as u64,
        );
        let per_layer = 4 * d * d + 2 * d * f + d * d;
        v * d * 2 + n * per_layer
    }

    /// Parameters held in *vectors* (9-bit uniform, resident on chip).
    pub fn vector_params(&self) -> u64 {
        let (d, n) = (self.d_model as u64, self.n_layer as u64);
        n * (5 * d + 2 * d + 4 * d) + 4 * d
    }

    pub fn n_params(&self) -> u64 {
        self.matrix_params() + self.vector_params()
    }

    /// Bytes that must cross HBM per generated token when matrix weights
    /// are streamed at `bits_per_weight` (9 for Δ-PoT, 16 for FP16 ...).
    pub fn stream_bytes_per_token(&self, bits_per_weight: f64) -> f64 {
        self.matrix_params() as f64 * bits_per_weight / 8.0
    }

    /// MAC count of one token's forward pass (matrix ops only; the
    /// element-wise/nonlinear work is accounted separately by the sim).
    pub fn macs_per_token(&self) -> u64 {
        self.matrix_params()
    }
}

/// The model served end-to-end (must match `python/compile/config.py::TINY`).
pub const TINY_SHAPE: ModelShape = ModelShape::new("tiny-1m", 4, 128, 512, 128);

/// Published RWKV-4 family, as evaluated in the paper's Figs 7–8.
pub const PAPER_SHAPES: [ModelShape; 5] = [
    ModelShape::new("rwkv4-169m", 12, 768, 3072, 50277),
    ModelShape::new("rwkv4-430m", 24, 1024, 4096, 50277),
    ModelShape::new("rwkv4-1b5", 24, 2048, 8192, 50277),
    ModelShape::new("rwkv4-3b", 32, 2560, 10240, 50277),
    ModelShape::new("rwkv4-7b", 32, 4096, 16384, 50277),
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_plus_vector_is_total() {
        for s in PAPER_SHAPES {
            assert_eq!(s.n_params(), s.matrix_params() + s.vector_params());
            assert!(s.vector_params() < s.matrix_params() / 100);
        }
    }

    #[test]
    fn stream_bytes_scale_with_bits() {
        let s = PAPER_SHAPES[0];
        let b9 = s.stream_bytes_per_token(9.0);
        let b16 = s.stream_bytes_per_token(16.0);
        assert!((b16 / b9 - 16.0 / 9.0).abs() < 1e-12);
    }

    #[test]
    fn tiny_matches_python_param_count() {
        // python: TINY.n_params == 988_672 (checked in python tests)
        assert_eq!(TINY_SHAPE.n_params(), crate::model::tiny_expected_params());
    }
}
