//! Accelerator configurations (HFRWKV_0/1, HFRWKV*_0/1) and FPGA platform
//! specifications (Alveo U50 / U280), straight from §5.1 and §5.3.1.



/// FPGA card the design is implemented on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Platform {
    AlveoU50,
    AlveoU280,
}

impl Platform {
    /// Rated HBM2 bandwidth (GB/s) — §5.1.
    pub fn hbm_bandwidth_gbps(self) -> f64 {
        match self {
            Platform::AlveoU50 => 201.0,
            Platform::AlveoU280 => 460.0,
        }
    }

    /// HBM capacity in bytes (both cards carry 8 GB of HBM2).
    pub fn hbm_capacity_bytes(self) -> u64 {
        8 * (1 << 30)
    }

    /// Total on-board resources: (LUT, FF, DSP, BRAM36, URAM288).
    pub fn resources(self) -> super::super::sim::resources::ResourceVector {
        use crate::sim::resources::ResourceVector;
        match self {
            Platform::AlveoU50 => ResourceVector {
                lut: 872_000,
                ff: 1_743_000,
                dsp: 5_952,
                bram: 1_344,
                uram: 640,
            },
            Platform::AlveoU280 => ResourceVector {
                lut: 1_304_000,
                ff: 2_607_000,
                dsp: 9_024,
                bram: 2_016,
                uram: 960,
            },
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Platform::AlveoU50 => "Alveo U50",
            Platform::AlveoU280 => "Alveo U280",
        }
    }
}

/// One deployed accelerator configuration (one Table 2 column).
#[derive(Clone, Copy, Debug)]
pub struct AccelConfig {
    pub name: &'static str,
    pub platform: Platform,
    /// On-chip clock in Hz (350 MHz on U50, 400 MHz on U280).
    pub freq_hz: f64,
    /// `d` — number of parallel PMAC units in the MV processing array.
    pub pmac_count: usize,
    /// ATAC addition-tree parallelism of the LayerNorm module.
    pub tree_parallelism: usize,
    /// Replicated Unsigned Division Units (all configs: 128).
    pub divu_count: usize,
    /// Replicated Exponential–Sigmoid Units (all configs: 128).
    pub exps_count: usize,
    /// Whether matrix weights are fully resident on chip (the `_0`
    /// small-model configs) or streamed through the ping-pong URAM
    /// double buffer (`_1`).
    pub weights_resident: bool,
    /// URAM ping-pong buffer size per bank, bytes (only `_1` configs).
    pub chunk_bytes: usize,
    /// Fraction of rated HBM bandwidth sustained (measured by the paper:
    /// 99.95% on U50, 99.64% on U280).
    pub bandwidth_efficiency: f64,
}

impl AccelConfig {
    /// Effective streaming bandwidth in bytes/s.
    pub fn effective_bandwidth(&self) -> f64 {
        self.platform.hbm_bandwidth_gbps() * 1e9 * self.bandwidth_efficiency
    }

    /// Cycles available per second.
    pub fn cycles_per_second(&self) -> f64 {
        self.freq_hz
    }
}

/// The paper's four deployed configurations (Table 2).
pub const HFRWKV_CONFIGS: [AccelConfig; 4] = [
    AccelConfig {
        name: "HFRWKV_0",
        platform: Platform::AlveoU50,
        freq_hz: 350e6,
        pmac_count: 384,
        tree_parallelism: 256,
        divu_count: 128,
        exps_count: 128,
        weights_resident: true,
        chunk_bytes: 0,
        bandwidth_efficiency: 0.9995,
    },
    AccelConfig {
        name: "HFRWKV_1",
        platform: Platform::AlveoU50,
        freq_hz: 350e6,
        pmac_count: 512,
        tree_parallelism: 512,
        divu_count: 128,
        exps_count: 128,
        weights_resident: false,
        // 64 URAM (288 Kb = 36 KB each) per ping-pong bank: Table 2 lists
        // 128 URAM for HFRWKV_1 = 2 banks x 64.
        chunk_bytes: 64 * 36 * 1024,
        bandwidth_efficiency: 0.9995,
    },
    AccelConfig {
        name: "HFRWKV*_0",
        platform: Platform::AlveoU280,
        freq_hz: 400e6,
        pmac_count: 768,
        tree_parallelism: 256,
        divu_count: 128,
        exps_count: 128,
        weights_resident: true,
        chunk_bytes: 0,
        bandwidth_efficiency: 0.9964,
    },
    AccelConfig {
        name: "HFRWKV*_1",
        platform: Platform::AlveoU280,
        freq_hz: 400e6,
        pmac_count: 1024,
        tree_parallelism: 512,
        divu_count: 128,
        exps_count: 128,
        weights_resident: false,
        // Table 2: 256 URAM = 2 banks x 128.
        chunk_bytes: 128 * 36 * 1024,
        bandwidth_efficiency: 0.9964,
    },
];

/// Look up a config by its Table 2 name.
pub fn config_by_name(name: &str) -> Option<&'static AccelConfig> {
    HFRWKV_CONFIGS.iter().find(|c| c.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn effective_bandwidth_below_rated() {
        for c in HFRWKV_CONFIGS {
            assert!(c.effective_bandwidth() < c.platform.hbm_bandwidth_gbps() * 1e9);
            assert!(c.effective_bandwidth() > c.platform.hbm_bandwidth_gbps() * 0.99e9);
        }
    }

    #[test]
    fn streaming_configs_have_chunks() {
        for c in HFRWKV_CONFIGS {
            assert_eq!(c.weights_resident, c.chunk_bytes == 0);
        }
    }

    #[test]
    fn lookup_by_name() {
        assert!(config_by_name("HFRWKV*_1").is_some());
        assert!(config_by_name("nope").is_none());
    }
}
