//! Model shapes, accelerator configurations and platform specifications.
//!
//! The four accelerator configs mirror the paper's Table 2 columns:
//! HFRWKV_0 / HFRWKV_1 on the Alveo U50 and HFRWKV*_0 / HFRWKV*_1 on the
//! Alveo U280 (§5.3.1).

pub mod accel;
pub mod shapes;

pub use accel::{AccelConfig, Platform, HFRWKV_CONFIGS};
pub use shapes::{ModelShape, PAPER_SHAPES};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn four_paper_configs_exist() {
        assert_eq!(HFRWKV_CONFIGS.len(), 4);
        let names: Vec<&str> = HFRWKV_CONFIGS.iter().map(|c| c.name).collect();
        assert_eq!(names, ["HFRWKV_0", "HFRWKV_1", "HFRWKV*_0", "HFRWKV*_1"]);
    }

    #[test]
    fn paper_dsp_structure_holds() {
        // DSP = d + 2*128*(tree/512) + 1 reproduces Table 2 exactly:
        // 641 / 1025 / 1025 / 1537 (see sim::resources).
        for c in HFRWKV_CONFIGS {
            let dsp = c.pmac_count + 256 * c.tree_parallelism / 256 + 1;
            match c.name {
                "HFRWKV_0" => assert_eq!(dsp, 641),
                "HFRWKV_1" => assert_eq!(dsp, 1025),
                "HFRWKV*_0" => assert_eq!(dsp, 1025),
                "HFRWKV*_1" => assert_eq!(dsp, 1537),
                _ => unreachable!(),
            }
        }
    }

    #[test]
    fn paper_shapes_param_counts() {
        // within 12% of the nominal names (169M, 430M, 1B5, 3B, 7B)
        let nominal = [169e6, 430e6, 1.5e9, 3.0e9, 7.0e9];
        for (s, n) in PAPER_SHAPES.iter().zip(nominal) {
            let p = s.n_params() as f64;
            assert!((p - n).abs() / n < 0.25, "{}: {p} vs {n}", s.name);
        }
    }

    #[test]
    fn hbm_bandwidth_specs() {
        assert_eq!(Platform::AlveoU50.hbm_bandwidth_gbps(), 201.0);
        assert_eq!(Platform::AlveoU280.hbm_bandwidth_gbps(), 460.0);
    }
}
