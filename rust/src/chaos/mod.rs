//! Deterministic fault injection: make any [`EngineModel`] misbehave on
//! a seeded schedule.
//!
//! [`ChaosModel`] wraps a real model and, per guarded call (`forward`,
//! `forward_batch`, `prefill_chunk`), draws from a private [`Rng64`]
//! whether to inject a fault and which kind: a **panic** (thrown *after*
//! the real call, so session state has genuinely advanced and only
//! rollback can undo it), **NaN logits** (one victim slot of the
//! returned/written panel), **NaN state** (scribbled into one victim's
//! recurrent state), or **latency** (a sleep before the call, exercising
//! timeout/deadline paths without corrupting anything).
//!
//! The draw sequence is a pure function of the seed and the call
//! sequence: one uniform draw per call, plus one kind-draw (and for
//! batch calls one victim-draw) when the call faults.  Engine-level
//! tests drive a fully deterministic call sequence, so the whole fault
//! schedule — and therefore every retry and rollback — replays exactly
//! (`rust/tests/chaos.rs`).  Under the threaded coordinator the *cycle
//! boundaries* depend on timing, so coordinator soaks assert the
//! fault-tolerance invariants (every request reaches exactly one
//! terminal, gauges drain to zero, the cache holds no poison) rather
//! than exact counts.
//!
//! The injection log is shared behind an `Arc` so a test can keep a
//! handle while the coordinator owns the model on its worker thread.

use std::sync::{Arc, Mutex, PoisonError};
use std::time::Duration;

use anyhow::Result;

use crate::coordinator::EngineModel;
use crate::runtime::Variant;
use crate::Rng64;

/// What to inject, how often, on what schedule.
#[derive(Clone, Copy, Debug)]
pub struct ChaosConfig {
    /// Seed of the injection schedule (same seed + same call sequence =
    /// same faults, bit for bit).
    pub seed: u64,
    /// Per-call probability of injecting a fault, in [0, 1].  0 makes
    /// the wrapper a bit-exact passthrough.
    pub fault_rate: f64,
    /// Enable panic injection.
    pub panics: bool,
    /// Enable NaN-in-logits injection.
    pub nan_logits: bool,
    /// Enable NaN-in-state injection.
    pub nan_state: bool,
    /// Enable latency injection (sleep `latency_ms` before the call).
    pub latency: bool,
    pub latency_ms: u64,
}

impl Default for ChaosConfig {
    fn default() -> Self {
        ChaosConfig {
            seed: 0,
            fault_rate: 0.0,
            panics: true,
            nan_logits: true,
            nan_state: true,
            latency: false,
            latency_ms: 1,
        }
    }
}

/// Cumulative injection counters (shared — see [`ChaosModel::log`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct InjectionLog {
    /// Guarded calls seen (faulted or not).
    pub calls: u64,
    pub panics: u64,
    pub nan_logits: u64,
    pub nan_state: u64,
    pub latency: u64,
}

impl InjectionLog {
    /// Total corrupting injections (latency excluded — it delays but
    /// never corrupts).
    pub fn corruptions(&self) -> u64 {
        self.panics + self.nan_logits + self.nan_state
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Fault {
    Panic,
    NanLogits,
    NanState,
    Latency,
}

/// A fault-injecting [`EngineModel`] wrapper (see the module docs).
pub struct ChaosModel<M: EngineModel> {
    inner: M,
    cfg: ChaosConfig,
    rng: Rng64,
    log: Arc<Mutex<InjectionLog>>,
}

fn locked(log: &Arc<Mutex<InjectionLog>>) -> std::sync::MutexGuard<'_, InjectionLog> {
    // the log is plain counters — always valid even if a panicking
    // injection poisoned the mutex
    log.lock().unwrap_or_else(PoisonError::into_inner)
}

impl<M: EngineModel> ChaosModel<M> {
    pub fn new(inner: M, cfg: ChaosConfig) -> ChaosModel<M> {
        ChaosModel { inner, cfg, rng: Rng64::new(cfg.seed), log: Arc::default() }
    }

    /// Snapshot of the injection counters.
    pub fn log(&self) -> InjectionLog {
        *locked(&self.log)
    }

    /// Shared handle to the counters — keep one before handing the
    /// model to a coordinator, which owns it on the worker thread.
    pub fn log_handle(&self) -> Arc<Mutex<InjectionLog>> {
        Arc::clone(&self.log)
    }

    /// One schedule step: decide this call's fault.  Exactly one
    /// uniform draw per call (plus one kind-draw when faulting), so the
    /// schedule stays aligned with the call index no matter what fired
    /// before.
    fn draw(&mut self) -> Option<Fault> {
        locked(&self.log).calls += 1;
        let faulted = self.rng.next_f64() < self.cfg.fault_rate;
        let mut kinds: Vec<Fault> = Vec::with_capacity(4);
        if self.cfg.panics {
            kinds.push(Fault::Panic);
        }
        if self.cfg.nan_logits {
            kinds.push(Fault::NanLogits);
        }
        if self.cfg.nan_state {
            kinds.push(Fault::NanState);
        }
        if self.cfg.latency {
            kinds.push(Fault::Latency);
        }
        if !faulted || kinds.is_empty() {
            return None;
        }
        Some(kinds[self.rng.below(kinds.len())])
    }

    /// Pre-call side of a fault (latency fires here; everything else
    /// fires after the real call so the state has genuinely advanced).
    fn before(&mut self, fault: Option<Fault>) {
        if fault == Some(Fault::Latency) {
            locked(&self.log).latency += 1;
            std::thread::sleep(Duration::from_millis(self.cfg.latency_ms));
        }
    }

    /// Post-call side: corrupt the outputs.  The log is bumped BEFORE a
    /// panic is thrown, so counters stay truthful across unwinds.
    fn after(
        &mut self,
        fault: Option<Fault>,
        logits: &mut [f32],
        state: &mut [f32],
    ) {
        match fault {
            Some(Fault::Panic) => {
                locked(&self.log).panics += 1;
                panic!("chaos: injected panic");
            }
            Some(Fault::NanLogits) => {
                locked(&self.log).nan_logits += 1;
                if let Some(x) = logits.first_mut() {
                    *x = f32::NAN;
                }
            }
            Some(Fault::NanState) => {
                locked(&self.log).nan_state += 1;
                if !state.is_empty() {
                    let i = self.rng.below(state.len());
                    state[i] = f32::NAN;
                }
            }
            Some(Fault::Latency) | None => {}
        }
    }
}

impl<M: EngineModel> EngineModel for ChaosModel<M> {
    fn vocab(&self) -> usize {
        self.inner.vocab()
    }

    fn state_len(&self) -> usize {
        self.inner.state_len()
    }

    fn init_state(&self) -> Vec<f32> {
        self.inner.init_state()
    }

    fn forward(&mut self, state: &mut Vec<f32>, token: u32, variant: Variant) -> Result<Vec<f32>> {
        let fault = self.draw();
        self.before(fault);
        let mut logits = self.inner.forward(state, token, variant)?;
        self.after(fault, &mut logits, state);
        Ok(logits)
    }

    fn forward_batch(
        &mut self,
        states: &mut [&mut Vec<f32>],
        tokens: &[u32],
        variant: Variant,
        logits: &mut Vec<f32>,
    ) -> Vec<Option<anyhow::Error>> {
        let fault = self.draw();
        self.before(fault);
        let outcomes = self.inner.forward_batch(states, tokens, variant, logits);
        // one victim slot per faulting batch call — the batchmates'
        // outputs stay pristine, which is exactly what the engine's
        // per-session isolation must preserve
        if fault == Some(Fault::NanLogits) || fault == Some(Fault::NanState) {
            let vocab = self.inner.vocab();
            let victim = self.rng.below(states.len().max(1));
            match fault {
                Some(Fault::NanLogits) => {
                    locked(&self.log).nan_logits += 1;
                    if let Some(x) = logits.get_mut(victim * vocab) {
                        *x = f32::NAN;
                    }
                }
                _ => {
                    locked(&self.log).nan_state += 1;
                    if let Some(s) = states.get_mut(victim) {
                        if let Some(x) = s.first_mut() {
                            *x = f32::NAN;
                        }
                    }
                }
            }
        } else {
            self.after(fault, &mut [], &mut []);
        }
        outcomes
    }

    fn prefill_chunk(
        &mut self,
        state: &mut Vec<f32>,
        tokens: &[u32],
        variant: Variant,
    ) -> Result<Vec<f32>> {
        let fault = self.draw();
        self.before(fault);
        let mut logits = self.inner.prefill_chunk(state, tokens, variant)?;
        self.after(fault, &mut logits, state);
        Ok(logits)
    }

    fn take_clip_events(&mut self) -> u64 {
        self.inner.take_clip_events()
    }

    fn snapshot_state(&mut self, state: &[f32]) -> Vec<f32> {
        self.inner.snapshot_state(state)
    }

    fn restore_state(&mut self, snapshot: &[f32], state: &mut Vec<f32>) {
        self.inner.restore_state(snapshot, state)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::rwkv::testing::test_model;

    fn chaos(rate: f64, seed: u64) -> ChaosModel<crate::model::RwkvModel> {
        ChaosModel::new(
            test_model(2, 32, 64, 50),
            ChaosConfig { seed, fault_rate: rate, ..ChaosConfig::default() },
        )
    }

    #[test]
    fn zero_rate_is_bitexact_passthrough() {
        let mut raw = test_model(2, 32, 64, 50);
        let mut wrapped = chaos(0.0, 9);
        let mut sr = EngineModel::init_state(&raw);
        let mut sw = wrapped.init_state();
        for t in [1u32, 5, 9, 2] {
            let lr = raw.forward(&mut sr, t, Variant::Exact).unwrap();
            let lw = wrapped.forward(&mut sw, t, Variant::Exact).unwrap();
            assert_eq!(lr, lw);
        }
        assert_eq!(sr, sw);
        let log = wrapped.log();
        assert_eq!(log.calls, 4);
        assert_eq!(log.corruptions(), 0);
    }

    #[test]
    fn schedule_is_deterministic_at_fixed_seed() {
        // NaN-only faults so the call sequence itself never diverges
        let cfg = ChaosConfig {
            seed: 42,
            fault_rate: 0.5,
            panics: false,
            nan_logits: true,
            nan_state: false,
            ..ChaosConfig::default()
        };
        let run = || {
            let mut m = ChaosModel::new(test_model(2, 32, 64, 50), cfg);
            let mut st = m.init_state();
            let logits: Vec<Vec<f32>> = (0..20u32)
                .map(|t| m.forward(&mut st, t % 50, Variant::Exact).unwrap())
                .collect();
            (logits, st, m.log())
        };
        let (la, sa, ga) = run();
        let (lb, sb, gb) = run();
        // bitwise comparison must include the NaNs, so compare bits
        let bits = |ls: &[Vec<f32>]| -> Vec<u32> {
            ls.iter().flatten().map(|x| x.to_bits()).collect()
        };
        assert_eq!(bits(&la), bits(&lb));
        assert_eq!(sa, sb);
        assert_eq!(ga, gb);
        assert!(ga.nan_logits > 0, "rate 0.5 over 20 calls must fault: {ga:?}");
    }

    #[test]
    fn injected_panic_is_counted_before_unwinding() {
        let mut m = ChaosModel::new(
            test_model(2, 32, 64, 50),
            ChaosConfig {
                seed: 3,
                fault_rate: 1.0, // every call faults
                panics: true,
                nan_logits: false,
                nan_state: false,
                ..ChaosConfig::default()
            },
        );
        let handle = m.log_handle();
        let mut st = m.init_state();
        let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            m.forward(&mut st, 1, Variant::Exact)
        }));
        assert!(out.is_err(), "rate 1.0 with only panics enabled must panic");
        let log = *handle.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        assert_eq!((log.calls, log.panics), (1, 1));
    }

    #[test]
    fn nan_state_injection_poisons_exactly_one_slot() {
        let mut m = ChaosModel::new(
            test_model(2, 32, 64, 50),
            ChaosConfig {
                seed: 5,
                fault_rate: 1.0,
                panics: false,
                nan_logits: false,
                nan_state: true,
                ..ChaosConfig::default()
            },
        );
        let mut a = m.init_state();
        let mut b = m.init_state();
        let mut logits = Vec::new();
        let outcomes = {
            let mut refs = vec![&mut a, &mut b];
            let tokens = [1u32, 2];
            m.forward_batch(&mut refs, &tokens, Variant::Exact, &mut logits)
        };
        assert!(outcomes.iter().all(|o| o.is_none()));
        let poisoned = [&a, &b]
            .iter()
            .filter(|s| s.iter().any(|x| !x.is_finite()))
            .count();
        assert_eq!(poisoned, 1, "exactly one victim state");
        assert!(logits.iter().all(|x| x.is_finite()), "logits untouched by NanState");
        assert_eq!(m.log().nan_state, 1);
    }
}
