//! Deterministic fault injection: make any [`EngineModel`] misbehave on
//! a seeded schedule.
//!
//! [`ChaosModel`] wraps a real model and, per guarded call (`forward`,
//! `forward_batch`, `prefill_chunk`), draws from a private [`Rng64`]
//! whether to inject a fault and which kind: a **panic** (thrown *after*
//! the real call, so session state has genuinely advanced and only
//! rollback can undo it), **NaN logits** (one victim slot of the
//! returned/written panel), **NaN state** (scribbled into one victim's
//! recurrent state), **latency** (a sleep before the call, exercising
//! timeout/deadline paths without corrupting anything), or a **fatal
//! model error** (the call *returns* `Err` instead of executing — the
//! dead-runtime failure mode of a PJRT backend whose device vanished;
//! deliberate and non-retryable, unlike a panic or a NaN).
//!
//! Orthogonally, [`ChaosConfig::worker_kill_every`] panics out of
//! `take_clip_events` — a call the worker loop makes *outside* the
//! engine's per-call fault guards — so the panic escapes to the
//! supervisor and exercises the crash-redrive path end to end.
//!
//! The draw sequence is a pure function of the seed and the call
//! sequence: one uniform draw per call, plus one kind-draw (and for
//! batch calls one victim-draw) when the call faults.  Engine-level
//! tests drive a fully deterministic call sequence, so the whole fault
//! schedule — and therefore every retry and rollback — replays exactly
//! (`rust/tests/chaos.rs`).  Under the threaded coordinator the *cycle
//! boundaries* depend on timing, so coordinator soaks assert the
//! fault-tolerance invariants (every request reaches exactly one
//! terminal, gauges drain to zero, the cache holds no poison) rather
//! than exact counts.
//!
//! The injection log is shared behind an `Arc` so a test can keep a
//! handle while the coordinator owns the model on its worker thread.

use std::sync::{Arc, Mutex, PoisonError};
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::coordinator::EngineModel;
use crate::runtime::Variant;
use crate::Rng64;

/// What to inject, how often, on what schedule.
#[derive(Clone, Copy, Debug)]
pub struct ChaosConfig {
    /// Seed of the injection schedule (same seed + same call sequence =
    /// same faults, bit for bit).
    pub seed: u64,
    /// Per-call probability of injecting a fault, in [0, 1].  0 makes
    /// the wrapper a bit-exact passthrough.
    pub fault_rate: f64,
    /// Enable panic injection.
    pub panics: bool,
    /// Enable NaN-in-logits injection.
    pub nan_logits: bool,
    /// Enable NaN-in-state injection.
    pub nan_state: bool,
    /// Enable latency injection (sleep `latency_ms` before the call).
    pub latency: bool,
    pub latency_ms: u64,
    /// Enable fatal model errors: the call returns `Err` without
    /// executing, like a runtime whose device died.  The engine never
    /// retries a model-returned error, so the session fails typed on
    /// the first injection.
    pub fatal: bool,
    /// Every Nth `take_clip_events` call panics (0 disables) — a
    /// worker-scope crash outside the per-call guards, forcing the
    /// supervisor's redrive/recovery path.  Scheduled by call count,
    /// not by `fault_rate`.
    pub worker_kill_every: u64,
}

impl Default for ChaosConfig {
    fn default() -> Self {
        ChaosConfig {
            seed: 0,
            fault_rate: 0.0,
            panics: true,
            nan_logits: true,
            nan_state: true,
            latency: false,
            latency_ms: 1,
            fatal: false,
            worker_kill_every: 0,
        }
    }
}

/// Cumulative injection counters (shared — see [`ChaosModel::log`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct InjectionLog {
    /// Guarded calls seen (faulted or not).
    pub calls: u64,
    pub panics: u64,
    pub nan_logits: u64,
    pub nan_state: u64,
    pub latency: u64,
    /// Fatal model errors returned (the call never executed).
    pub fatal: u64,
    /// Worker-scope kills thrown out of `take_clip_events`.
    pub worker_kills: u64,
}

impl InjectionLog {
    /// Total corrupting injections (latency excluded — it delays but
    /// never corrupts; fatal errors and worker kills excluded too —
    /// they abort cleanly rather than corrupting any panel).
    pub fn corruptions(&self) -> u64 {
        self.panics + self.nan_logits + self.nan_state
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Fault {
    Panic,
    NanLogits,
    NanState,
    Latency,
    Fatal,
}

/// A fault-injecting [`EngineModel`] wrapper (see the module docs).
pub struct ChaosModel<M: EngineModel> {
    inner: M,
    cfg: ChaosConfig,
    rng: Rng64,
    log: Arc<Mutex<InjectionLog>>,
    /// `take_clip_events` calls seen — the `worker_kill_every` schedule
    /// axis (deliberately not `fault_rate`-driven: one kill per N
    /// scheduling cycles, deterministic in the cycle count).
    clip_calls: u64,
}

fn locked(log: &Arc<Mutex<InjectionLog>>) -> std::sync::MutexGuard<'_, InjectionLog> {
    // the log is plain counters — always valid even if a panicking
    // injection poisoned the mutex
    log.lock().unwrap_or_else(PoisonError::into_inner)
}

impl<M: EngineModel> ChaosModel<M> {
    pub fn new(inner: M, cfg: ChaosConfig) -> ChaosModel<M> {
        ChaosModel { inner, cfg, rng: Rng64::new(cfg.seed), log: Arc::default(), clip_calls: 0 }
    }

    /// Snapshot of the injection counters.
    pub fn log(&self) -> InjectionLog {
        *locked(&self.log)
    }

    /// Shared handle to the counters — keep one before handing the
    /// model to a coordinator, which owns it on the worker thread.
    pub fn log_handle(&self) -> Arc<Mutex<InjectionLog>> {
        Arc::clone(&self.log)
    }

    /// One schedule step: decide this call's fault.  Exactly one
    /// uniform draw per call (plus one kind-draw when faulting), so the
    /// schedule stays aligned with the call index no matter what fired
    /// before.
    fn draw(&mut self) -> Option<Fault> {
        locked(&self.log).calls += 1;
        let faulted = self.rng.next_f64() < self.cfg.fault_rate;
        let mut kinds: Vec<Fault> = Vec::with_capacity(4);
        if self.cfg.panics {
            kinds.push(Fault::Panic);
        }
        if self.cfg.nan_logits {
            kinds.push(Fault::NanLogits);
        }
        if self.cfg.nan_state {
            kinds.push(Fault::NanState);
        }
        if self.cfg.latency {
            kinds.push(Fault::Latency);
        }
        // pushed last so enabling `fatal` never re-maps the kind-draw
        // of a schedule that ran without it
        if self.cfg.fatal {
            kinds.push(Fault::Fatal);
        }
        if !faulted || kinds.is_empty() {
            return None;
        }
        Some(kinds[self.rng.below(kinds.len())])
    }

    /// Pre-call side of a fault (latency fires here; everything else
    /// fires after the real call so the state has genuinely advanced).
    fn before(&mut self, fault: Option<Fault>) {
        if fault == Some(Fault::Latency) {
            locked(&self.log).latency += 1;
            std::thread::sleep(Duration::from_millis(self.cfg.latency_ms));
        }
    }

    /// Post-call side: corrupt the outputs.  The log is bumped BEFORE a
    /// panic is thrown, so counters stay truthful across unwinds.
    fn after(
        &mut self,
        fault: Option<Fault>,
        logits: &mut [f32],
        state: &mut [f32],
    ) {
        match fault {
            Some(Fault::Panic) => {
                locked(&self.log).panics += 1;
                panic!("chaos: injected panic");
            }
            Some(Fault::NanLogits) => {
                locked(&self.log).nan_logits += 1;
                if let Some(x) = logits.first_mut() {
                    *x = f32::NAN;
                }
            }
            Some(Fault::NanState) => {
                locked(&self.log).nan_state += 1;
                if !state.is_empty() {
                    let i = self.rng.below(state.len());
                    state[i] = f32::NAN;
                }
            }
            Some(Fault::Latency) | None => {}
        }
    }
}

impl<M: EngineModel> EngineModel for ChaosModel<M> {
    fn vocab(&self) -> usize {
        self.inner.vocab()
    }

    fn state_len(&self) -> usize {
        self.inner.state_len()
    }

    fn init_state(&self) -> Vec<f32> {
        self.inner.init_state()
    }

    fn forward(&mut self, state: &mut Vec<f32>, token: u32, variant: Variant) -> Result<Vec<f32>> {
        let fault = self.draw();
        if fault == Some(Fault::Fatal) {
            // a dead runtime returns without executing — state untouched
            locked(&self.log).fatal += 1;
            return Err(anyhow!("chaos: injected fatal runtime error (device lost)"));
        }
        self.before(fault);
        let mut logits = self.inner.forward(state, token, variant)?;
        self.after(fault, &mut logits, state);
        Ok(logits)
    }

    fn forward_batch(
        &mut self,
        states: &mut [&mut Vec<f32>],
        tokens: &[u32],
        variant: Variant,
        logits: &mut Vec<f32>,
    ) -> Vec<Option<anyhow::Error>> {
        let fault = self.draw();
        if fault == Some(Fault::Fatal) {
            // one victim member's outcome becomes a model-returned
            // error; its batchmates' outputs stay pristine (the engine
            // must isolate, not retry — model errors are deliberate)
            locked(&self.log).fatal += 1;
            let victim = self.rng.below(states.len().max(1));
            let mut outcomes = self.inner.forward_batch(states, tokens, variant, logits);
            if let Some(o) = outcomes.get_mut(victim) {
                *o = Some(anyhow!("chaos: injected fatal runtime error (device lost)"));
            }
            return outcomes;
        }
        self.before(fault);
        let outcomes = self.inner.forward_batch(states, tokens, variant, logits);
        // one victim slot per faulting batch call — the batchmates'
        // outputs stay pristine, which is exactly what the engine's
        // per-session isolation must preserve
        if fault == Some(Fault::NanLogits) || fault == Some(Fault::NanState) {
            let vocab = self.inner.vocab();
            let victim = self.rng.below(states.len().max(1));
            match fault {
                Some(Fault::NanLogits) => {
                    locked(&self.log).nan_logits += 1;
                    if let Some(x) = logits.get_mut(victim * vocab) {
                        *x = f32::NAN;
                    }
                }
                _ => {
                    locked(&self.log).nan_state += 1;
                    if let Some(s) = states.get_mut(victim) {
                        if let Some(x) = s.first_mut() {
                            *x = f32::NAN;
                        }
                    }
                }
            }
        } else {
            self.after(fault, &mut [], &mut []);
        }
        outcomes
    }

    fn prefill_chunk(
        &mut self,
        state: &mut Vec<f32>,
        tokens: &[u32],
        variant: Variant,
    ) -> Result<Vec<f32>> {
        let fault = self.draw();
        if fault == Some(Fault::Fatal) {
            locked(&self.log).fatal += 1;
            return Err(anyhow!("chaos: injected fatal runtime error (device lost)"));
        }
        self.before(fault);
        let mut logits = self.inner.prefill_chunk(state, tokens, variant)?;
        self.after(fault, &mut logits, state);
        Ok(logits)
    }

    fn take_clip_events(&mut self) -> u64 {
        self.clip_calls += 1;
        if self.cfg.worker_kill_every > 0
            && self.clip_calls % self.cfg.worker_kill_every == 0
        {
            // outside the per-call guards: this panic reaches the
            // supervisor, which redrives in-flight sessions (budget
            // permitting) and warm-recovers the cache
            locked(&self.log).worker_kills += 1;
            panic!("chaos: injected worker kill");
        }
        self.inner.take_clip_events()
    }

    fn snapshot_state(&mut self, state: &[f32]) -> Vec<f32> {
        self.inner.snapshot_state(state)
    }

    fn restore_state(&mut self, snapshot: &[f32], state: &mut Vec<f32>) {
        self.inner.restore_state(snapshot, state)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::rwkv::testing::test_model;

    fn chaos(rate: f64, seed: u64) -> ChaosModel<crate::model::RwkvModel> {
        ChaosModel::new(
            test_model(2, 32, 64, 50),
            ChaosConfig { seed, fault_rate: rate, ..ChaosConfig::default() },
        )
    }

    #[test]
    fn zero_rate_is_bitexact_passthrough() {
        let mut raw = test_model(2, 32, 64, 50);
        let mut wrapped = chaos(0.0, 9);
        let mut sr = EngineModel::init_state(&raw);
        let mut sw = wrapped.init_state();
        for t in [1u32, 5, 9, 2] {
            let lr = raw.forward(&mut sr, t, Variant::Exact).unwrap();
            let lw = wrapped.forward(&mut sw, t, Variant::Exact).unwrap();
            assert_eq!(lr, lw);
        }
        assert_eq!(sr, sw);
        let log = wrapped.log();
        assert_eq!(log.calls, 4);
        assert_eq!(log.corruptions(), 0);
    }

    #[test]
    fn schedule_is_deterministic_at_fixed_seed() {
        // NaN-only faults so the call sequence itself never diverges
        let cfg = ChaosConfig {
            seed: 42,
            fault_rate: 0.5,
            panics: false,
            nan_logits: true,
            nan_state: false,
            ..ChaosConfig::default()
        };
        let run = || {
            let mut m = ChaosModel::new(test_model(2, 32, 64, 50), cfg);
            let mut st = m.init_state();
            let logits: Vec<Vec<f32>> = (0..20u32)
                .map(|t| m.forward(&mut st, t % 50, Variant::Exact).unwrap())
                .collect();
            (logits, st, m.log())
        };
        let (la, sa, ga) = run();
        let (lb, sb, gb) = run();
        // bitwise comparison must include the NaNs, so compare bits
        let bits = |ls: &[Vec<f32>]| -> Vec<u32> {
            ls.iter().flatten().map(|x| x.to_bits()).collect()
        };
        assert_eq!(bits(&la), bits(&lb));
        assert_eq!(sa, sb);
        assert_eq!(ga, gb);
        assert!(ga.nan_logits > 0, "rate 0.5 over 20 calls must fault: {ga:?}");
    }

    #[test]
    fn injected_panic_is_counted_before_unwinding() {
        let mut m = ChaosModel::new(
            test_model(2, 32, 64, 50),
            ChaosConfig {
                seed: 3,
                fault_rate: 1.0, // every call faults
                panics: true,
                nan_logits: false,
                nan_state: false,
                ..ChaosConfig::default()
            },
        );
        let handle = m.log_handle();
        let mut st = m.init_state();
        let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            m.forward(&mut st, 1, Variant::Exact)
        }));
        assert!(out.is_err(), "rate 1.0 with only panics enabled must panic");
        let log = *handle.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        assert_eq!((log.calls, log.panics), (1, 1));
    }

    #[test]
    fn fatal_fault_returns_error_without_executing() {
        let mut m = ChaosModel::new(
            test_model(2, 32, 64, 50),
            ChaosConfig {
                seed: 7,
                fault_rate: 1.0,
                panics: false,
                nan_logits: false,
                nan_state: false,
                fatal: true,
                ..ChaosConfig::default()
            },
        );
        let mut st = m.init_state();
        let before = st.clone();
        let err = m.forward(&mut st, 1, Variant::Exact).unwrap_err();
        assert!(err.to_string().contains("chaos: injected fatal"), "{err}");
        assert_eq!(st, before, "a dead runtime never advances state");
        let log = m.log();
        assert_eq!(log.fatal, 1);
        assert_eq!(log.corruptions(), 0, "a fatal error aborts cleanly, it corrupts nothing");
    }

    #[test]
    fn worker_kill_fires_every_nth_clip_drain() {
        let mut m = ChaosModel::new(
            test_model(2, 32, 64, 50),
            ChaosConfig { worker_kill_every: 3, ..ChaosConfig::default() },
        );
        for i in 1..=7u64 {
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                m.take_clip_events()
            }));
            assert_eq!(r.is_err(), i % 3 == 0, "call {i} on a kill-every-3 schedule");
        }
        assert_eq!(m.log().worker_kills, 2);
    }

    #[test]
    fn nan_state_injection_poisons_exactly_one_slot() {
        let mut m = ChaosModel::new(
            test_model(2, 32, 64, 50),
            ChaosConfig {
                seed: 5,
                fault_rate: 1.0,
                panics: false,
                nan_logits: false,
                nan_state: true,
                ..ChaosConfig::default()
            },
        );
        let mut a = m.init_state();
        let mut b = m.init_state();
        let mut logits = Vec::new();
        let outcomes = {
            let mut refs = vec![&mut a, &mut b];
            let tokens = [1u32, 2];
            m.forward_batch(&mut refs, &tokens, Variant::Exact, &mut logits)
        };
        assert!(outcomes.iter().all(|o| o.is_none()));
        let poisoned = [&a, &b]
            .iter()
            .filter(|s| s.iter().any(|x| !x.is_finite()))
            .count();
        assert_eq!(poisoned, 1, "exactly one victim state");
        assert!(logits.iter().all(|x| x.is_finite()), "logits untouched by NanState");
        assert_eq!(m.log().nan_state, 1);
    }
}
