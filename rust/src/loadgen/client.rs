//! Minimal blocking HTTP/SSE client — just enough protocol to drive
//! [`crate::net::Server`] over a real TCP socket from the load harness
//! and the integration tests.  Deliberately mirrors the server's
//! subset: one request per connection, close-delimited bodies.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Instant;

use anyhow::{bail, Context};

use crate::util::json::{parse_bytes, Json};
use crate::Result;

/// One received SSE frame, stamped at arrival (the server flushes per
/// frame, so `at` is a faithful per-event receive time).
#[derive(Clone, Debug)]
pub struct SseEvent {
    pub event: String,
    pub data: Json,
    pub at: Instant,
}

/// An in-flight `POST /v1/generate`.  Dropping it mid-stream closes
/// the socket, which the server turns into a session cancel — the
/// harness's early-cancel mix is literally `drop(conn)`.
pub struct GenConnection {
    status: u16,
    headers: BTreeMap<String, String>,
    reader: BufReader<TcpStream>,
}

/// POST `body` to `/v1/generate` with optional extra headers and read
/// the response head.  Status 200 means an SSE stream follows
/// ([`GenConnection::next_event`]); anything else carries a JSON error
/// body ([`GenConnection::read_body_json`]).
pub fn post_generate(
    addr: SocketAddr,
    body: &Json,
    headers: &[(&str, String)],
) -> Result<GenConnection> {
    let mut stream = TcpStream::connect(addr)?;
    let body_bytes = body.to_string().into_bytes();
    let mut head = format!(
        "POST /v1/generate HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\nContent-Length: {}\r\n",
        body_bytes.len()
    );
    for (name, value) in headers {
        head.push_str(name);
        head.push_str(": ");
        head.push_str(value);
        head.push_str("\r\n");
    }
    head.push_str("\r\n");
    stream.write_all(head.as_bytes())?;
    stream.write_all(&body_bytes)?;
    stream.flush()?;
    let mut reader = BufReader::new(stream);
    let (status, headers) = read_response_head(&mut reader)?;
    Ok(GenConnection { status, headers, reader })
}

impl GenConnection {
    pub fn status(&self) -> u16 {
        self.status
    }

    /// Response header, case-insensitive.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.get(&name.to_ascii_lowercase()).map(String::as_str)
    }

    /// Read the rest of the (close-delimited) body and parse it as
    /// JSON — for non-200 error responses.
    pub fn read_body_json(mut self) -> Result<Json> {
        let mut body = Vec::new();
        self.reader.read_to_end(&mut body)?;
        parse_bytes(&body)
    }

    /// The next SSE frame, or `None` once the server closes the stream
    /// (or the frame is unreadable — either way the stream is over).
    pub fn next_event(&mut self) -> Option<SseEvent> {
        let mut event = String::new();
        let mut data: Option<String> = None;
        let mut line = String::new();
        loop {
            line.clear();
            match self.reader.read_line(&mut line) {
                Ok(0) | Err(_) => return None,
                Ok(_) => {}
            }
            let t = line.trim_end_matches(['\r', '\n']);
            if t.is_empty() {
                if let Some(payload) = data.take() {
                    let at = Instant::now();
                    let data = crate::util::json::parse(&payload).ok()?;
                    return Some(SseEvent { event: std::mem::take(&mut event), data, at });
                }
                continue; // stray blank line before any field
            }
            if let Some(v) = t.strip_prefix("event: ") {
                event = v.to_string();
            } else if let Some(v) = t.strip_prefix("data: ") {
                data = Some(v.to_string());
            }
        }
    }
}

/// GET `path` and parse the JSON body — the `/metrics` and `/trace`
/// readback used by the harness and CI assertions.
pub fn get_json(addr: SocketAddr, path: &str) -> Result<Json> {
    let mut stream = TcpStream::connect(addr)?;
    write!(stream, "GET {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n")?;
    stream.flush()?;
    let mut reader = BufReader::new(stream);
    let (status, _) = read_response_head(&mut reader)?;
    let mut body = Vec::new();
    reader.read_to_end(&mut body)?;
    if status != 200 {
        bail!("GET {path} -> {status}: {}", String::from_utf8_lossy(&body));
    }
    parse_bytes(&body)
}

/// Write raw request bytes and read back (status, headers, body) —
/// lets tests exercise malformed requests the typed helpers cannot
/// produce (bad routes, oversized bodies, invalid JSON).
pub fn raw_request(
    addr: SocketAddr,
    request: &[u8],
) -> Result<(u16, BTreeMap<String, String>, Vec<u8>)> {
    let mut stream = TcpStream::connect(addr)?;
    stream.write_all(request)?;
    stream.flush()?;
    let mut reader = BufReader::new(stream);
    let (status, headers) = read_response_head(&mut reader)?;
    let mut body = Vec::new();
    reader.read_to_end(&mut body)?;
    Ok((status, headers, body))
}

fn read_response_head(
    r: &mut BufReader<TcpStream>,
) -> Result<(u16, BTreeMap<String, String>)> {
    let mut line = String::new();
    r.read_line(&mut line)?;
    let status: u16 = line
        .split_whitespace()
        .nth(1)
        .with_context(|| format!("malformed status line {line:?}"))?
        .parse()
        .with_context(|| format!("malformed status line {line:?}"))?;
    let mut headers = BTreeMap::new();
    loop {
        line.clear();
        if r.read_line(&mut line)? == 0 {
            bail!("EOF inside response headers");
        }
        let t = line.trim_end_matches(['\r', '\n']);
        if t.is_empty() {
            break;
        }
        if let Some((name, value)) = t.split_once(':') {
            headers.insert(name.trim().to_ascii_lowercase(), value.trim().to_string());
        }
    }
    Ok((status, headers))
}
