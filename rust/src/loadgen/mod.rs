//! Open-loop realistic-traffic load harness for the HTTP serving tier.
//!
//! **Open-loop** means arrival times are drawn up front from the
//! traffic model and requests fire at those times regardless of how
//! the server is coping — unlike a closed loop (fixed client count,
//! next request after the last response), which self-throttles under
//! overload and hides queueing collapse.  Tail latencies and
//! goodput-under-SLO are only honest under open-loop load.
//!
//! The traffic model, all driven by the crate's deterministic
//! [`Rng64`]:
//!
//! * **Arrivals** — Poisson (exponential inter-arrival, `-ln(U)/λ`),
//!   optionally modulated by an on/off [`Burst`] square wave.
//! * **Prompt lengths** — lognormal (`exp(μ + σ·N(0,1))`), matching
//!   the heavy right tail of real prompt-length distributions.
//! * **Prefix sharing** — each prompt starts with a system prompt
//!   drawn Zipf(`s`) from a fixed pool, so a few prefixes dominate and
//!   the prefix cache has something realistic to hit on.
//! * **Request mixes** — a fraction of best-of-n fan-out requests and
//!   a fraction of early client cancels (the connection is dropped
//!   after a few tokens, exercising disconnect-reaping end to end).
//!
//! [`run_open_loop`] drives one traffic class against a live server
//! address and returns a [`LoadReport`] of TTFT / inter-token tails
//! and goodput-under-SLO.  Multi-class experiments (e.g. the quota
//! isolation bench) run one `run_open_loop` per class on separate
//! threads against the same address.

pub mod client;

pub use client::{get_json, post_generate, raw_request, GenConnection, SseEvent};

use std::net::SocketAddr;
use std::time::{Duration, Instant};

use crate::util::json::Json;
use crate::Rng64;

/// On/off rate modulation: within each `period_s`, the first
/// `duty` fraction runs at `base_rate * peak`, the rest at
/// `base_rate / peak` — mean rate stays near the base while the
/// harness alternates overload bursts with quiet valleys.
#[derive(Clone, Copy, Debug)]
pub struct Burst {
    pub period_s: f64,
    /// Fraction of the period in the high-rate phase, in (0, 1).
    pub duty: f64,
    /// Rate multiplier of the high phase (and divisor of the low one).
    pub peak: f64,
}

/// One traffic class.  Defaults are sized for the tiny test model —
/// benches override what they sweep.
#[derive(Clone, Debug)]
pub struct TrafficConfig {
    pub seed: u64,
    pub n_requests: usize,
    /// Mean arrival rate λ (requests/second).
    pub arrivals_per_sec: f64,
    pub burst: Option<Burst>,
    /// Lognormal ln-space mean of the unique prompt-suffix length.
    pub prompt_len_mu: f64,
    pub prompt_len_sigma: f64,
    pub max_prompt_len: usize,
    /// Shared system-prompt pool size (Zipf-distributed pick).
    pub system_prompts: usize,
    pub system_prompt_len: usize,
    /// Zipf exponent; larger = more mass on the top prefixes.
    pub zipf_s: f64,
    /// Token ids are drawn uniformly below this.
    pub vocab: u32,
    pub max_new_tokens: usize,
    /// Fraction of requests submitted as best-of-`n_best` fan-outs.
    pub best_of_frac: f64,
    pub n_best: usize,
    /// Fraction of requests whose client disconnects mid-stream.
    pub cancel_frac: f64,
    /// How many token events a cancelling client reads first.
    pub cancel_after_tokens: usize,
    /// Sent as `X-Priority` on every request of this class.
    pub priority: i32,
    pub deadline_ms: Option<u64>,
}

impl Default for TrafficConfig {
    fn default() -> TrafficConfig {
        TrafficConfig {
            seed: 0x1A0D,
            n_requests: 32,
            arrivals_per_sec: 50.0,
            burst: None,
            prompt_len_mu: 2.3,
            prompt_len_sigma: 0.7,
            max_prompt_len: 48,
            system_prompts: 8,
            system_prompt_len: 12,
            zipf_s: 1.1,
            vocab: 50,
            max_new_tokens: 8,
            best_of_frac: 0.0,
            n_best: 2,
            cancel_frac: 0.0,
            cancel_after_tokens: 2,
            priority: 0,
            deadline_ms: None,
        }
    }
}

/// Service-level objective the goodput figure is measured against.
#[derive(Clone, Copy, Debug)]
pub struct Slo {
    /// A request is "good" only if its TTFT is at or under this.
    pub ttft_ms: f64,
}

/// Aggregated outcome of one [`run_open_loop`] traffic class.
#[derive(Clone, Debug, Default)]
pub struct LoadReport {
    pub submitted: usize,
    /// Got at least one terminal `finished` frame.
    pub completed: usize,
    /// Completions whose every branch finished `max_tokens`/`stop_token`
    /// (not shed, not deadline-expired, not faulted).
    pub completed_ok: usize,
    /// 429/503 at submission.
    pub rejected: usize,
    /// Transport failures and unexpected statuses.
    pub errors: usize,
    /// Streams the harness dropped on purpose (cancel mix).
    pub client_cancelled: usize,
    pub tokens_received: usize,
    /// Sorted seconds-based samples (milliseconds), ready for percentiles.
    pub ttft_ms: Vec<f64>,
    pub inter_token_ms: Vec<f64>,
    pub wall_seconds: f64,
    /// `completed_ok` requests that also met the TTFT SLO.
    pub slo_met: usize,
    /// `slo_met / wall_seconds` — completions-per-second that a client
    /// under the SLO actually experienced as served.
    pub goodput_rps: f64,
}

impl LoadReport {
    pub fn ttft_p50(&self) -> f64 {
        percentile(&self.ttft_ms, 0.50)
    }

    pub fn ttft_p99(&self) -> f64 {
        percentile(&self.ttft_ms, 0.99)
    }

    pub fn inter_token_p50(&self) -> f64 {
        percentile(&self.inter_token_ms, 0.50)
    }

    pub fn inter_token_p99(&self) -> f64 {
        percentile(&self.inter_token_ms, 0.99)
    }

    /// Flat JSON for bench reports (no raw sample arrays).
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("submitted", self.submitted)
            .set("completed", self.completed)
            .set("completed_ok", self.completed_ok)
            .set("rejected", self.rejected)
            .set("errors", self.errors)
            .set("client_cancelled", self.client_cancelled)
            .set("tokens_received", self.tokens_received)
            .set("ttft_p50_ms", self.ttft_p50())
            .set("ttft_p99_ms", self.ttft_p99())
            .set("inter_token_p50_ms", self.inter_token_p50())
            .set("inter_token_p99_ms", self.inter_token_p99())
            .set("wall_seconds", self.wall_seconds)
            .set("slo_met", self.slo_met)
            .set("goodput_rps", self.goodput_rps);
        j
    }
}

/// Floor-rank percentile over a sorted slice; NaN when empty (the JSON
/// writer serializes non-finite as `null`, so empty cells stay visible
/// in reports instead of faking a 0).
fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let idx = ((sorted.len() as f64 - 1.0) * p).floor() as usize;
    sorted[idx]
}

/// Draw the arrival-time offsets (seconds from harness start) for one
/// class.  Pure function of the config — the schedule is fixed before
/// any request fires, which is what makes the loop open.
pub fn arrival_offsets(cfg: &TrafficConfig, rng: &mut Rng64) -> Vec<f64> {
    let mut t = 0.0f64;
    let mut out = Vec::with_capacity(cfg.n_requests);
    for _ in 0..cfg.n_requests {
        let rate = match &cfg.burst {
            None => cfg.arrivals_per_sec,
            Some(b) => {
                let phase = (t / b.period_s.max(1e-9)).fract();
                if phase < b.duty {
                    cfg.arrivals_per_sec * b.peak
                } else {
                    cfg.arrivals_per_sec / b.peak.max(1e-9)
                }
            }
        };
        // exponential inter-arrival: -ln(U)/λ
        t += -(rng.next_f64().max(1e-12)).ln() / rate.max(1e-9);
        out.push(t);
    }
    out
}

/// Inverse-CDF Zipf sample: rank `1..=n` with weight `1/rank^s`.
pub fn zipf_rank(rng: &mut Rng64, cdf: &[f64]) -> usize {
    let u = rng.next_f64() * cdf.last().copied().unwrap_or(1.0);
    match cdf.iter().position(|&c| u < c) {
        Some(i) => i + 1,
        None => cdf.len(),
    }
}

/// Cumulative (unnormalised) Zipf weights for [`zipf_rank`].
pub fn zipf_cdf(n: usize, s: f64) -> Vec<f64> {
    let mut acc = 0.0;
    (1..=n.max(1))
        .map(|r| {
            acc += (r as f64).powf(-s);
            acc
        })
        .collect()
}

/// Lognormal prompt-suffix length, clamped to `1..=max`.
fn lognormal_len(rng: &mut Rng64, mu: f64, sigma: f64, max: usize) -> usize {
    let len = (mu + sigma * rng.normal()).exp().round() as i64;
    (len.max(1) as usize).min(max.max(1))
}

/// Everything one request thread needs, precomputed deterministically.
struct RequestSpec {
    start_s: f64,
    prompt: Vec<u32>,
    n_best: usize,
    cancel_after: Option<usize>,
    priority: i32,
    deadline_ms: Option<u64>,
    max_new_tokens: usize,
}

fn build_specs(cfg: &TrafficConfig) -> Vec<RequestSpec> {
    let mut rng = Rng64::new(cfg.seed);
    let offsets = arrival_offsets(cfg, &mut rng);
    let cdf = zipf_cdf(cfg.system_prompts, cfg.zipf_s);
    offsets
        .into_iter()
        .map(|start_s| {
            // shared system prefix: deterministic tokens per pool rank,
            // so equal ranks produce byte-identical prefixes to cache on
            let rank = zipf_rank(&mut rng, &cdf) as u32;
            let mut prompt: Vec<u32> = (0..cfg.system_prompt_len as u32)
                .map(|i| (rank.wrapping_mul(2654435761).wrapping_add(i)) % cfg.vocab.max(1))
                .collect();
            let suffix = lognormal_len(&mut rng, cfg.prompt_len_mu, cfg.prompt_len_sigma, cfg.max_prompt_len);
            prompt.extend((0..suffix).map(|_| (rng.next_u64() % cfg.vocab.max(1) as u64) as u32));
            let n_best = if rng.next_f64() < cfg.best_of_frac { cfg.n_best.max(1) } else { 1 };
            let cancel_after =
                (rng.next_f64() < cfg.cancel_frac).then_some(cfg.cancel_after_tokens.max(1));
            RequestSpec {
                start_s,
                prompt,
                n_best,
                cancel_after,
                priority: cfg.priority,
                deadline_ms: cfg.deadline_ms,
                max_new_tokens: cfg.max_new_tokens,
            }
        })
        .collect()
}

#[derive(Default)]
struct Outcome {
    status: u16,
    ttft_s: Option<f64>,
    gaps_s: Vec<f64>,
    tokens: usize,
    finished: bool,
    all_branches_ok: bool,
    cancelled: bool,
}

fn run_request(addr: SocketAddr, spec: &RequestSpec, t0: Instant) -> Outcome {
    let elapsed = t0.elapsed().as_secs_f64();
    if spec.start_s > elapsed {
        std::thread::sleep(Duration::from_secs_f64(spec.start_s - elapsed));
    }
    let mut body = Json::obj();
    body.set(
        "prompt",
        Json::Arr(spec.prompt.iter().map(|&t| Json::from(t as u64)).collect()),
    )
    .set("max_new_tokens", spec.max_new_tokens);
    if spec.n_best > 1 {
        body.set("n_best", spec.n_best);
    }
    if let Some(ms) = spec.deadline_ms {
        body.set("deadline_ms", ms);
    }
    // priority rides the header, exercising the override path every time
    let headers = [("X-Priority", spec.priority.to_string())];
    let submit_at = Instant::now();
    let mut conn = match post_generate(addr, &body, &headers) {
        Ok(c) => c,
        Err(_) => return Outcome::default(), // status 0 = transport error
    };
    let mut out = Outcome { status: conn.status(), all_branches_ok: true, ..Outcome::default() };
    if out.status != 200 {
        return out;
    }
    let mut last_token_at: Option<Instant> = None;
    while let Some(ev) = conn.next_event() {
        match ev.event.as_str() {
            "token" => {
                out.tokens += 1;
                match last_token_at {
                    None => out.ttft_s = Some(ev.at.duration_since(submit_at).as_secs_f64()),
                    Some(prev) => out.gaps_s.push(ev.at.duration_since(prev).as_secs_f64()),
                }
                last_token_at = Some(ev.at);
                if spec.cancel_after == Some(out.tokens) {
                    out.cancelled = true;
                    return out; // dropping `conn` closes the socket mid-stream
                }
            }
            "finished" => {
                out.finished = true;
                let ok = matches!(
                    ev.data.get("finish_reason").and_then(|r| r.as_str().ok()),
                    Some("max_tokens" | "stop_token")
                );
                out.all_branches_ok &= ok;
            }
            "error" => out.all_branches_ok = false,
            _ => {}
        }
    }
    out
}

/// Fire one traffic class at `addr` on its precomputed open-loop
/// schedule (one thread per request, sleeping until its arrival time)
/// and aggregate the outcomes.
pub fn run_open_loop(addr: SocketAddr, cfg: &TrafficConfig, slo: &Slo) -> LoadReport {
    let specs = build_specs(cfg);
    let t0 = Instant::now();
    let handles: Vec<_> = specs
        .into_iter()
        .map(|spec| std::thread::spawn(move || run_request(addr, &spec, t0)))
        .collect();
    let outcomes: Vec<Outcome> = handles
        .into_iter()
        .map(|h| h.join().unwrap_or_default())
        .collect();
    let wall = t0.elapsed().as_secs_f64().max(1e-9);
    let mut report = LoadReport { submitted: outcomes.len(), wall_seconds: wall, ..LoadReport::default() };
    for o in &outcomes {
        report.tokens_received += o.tokens;
        if o.cancelled {
            report.client_cancelled += 1;
        } else if o.finished {
            report.completed += 1;
            let ok = o.all_branches_ok;
            if ok {
                report.completed_ok += 1;
            }
            if let Some(ttft) = o.ttft_s {
                if ok && ttft * 1e3 <= slo.ttft_ms {
                    report.slo_met += 1;
                }
            }
        } else if matches!(o.status, 429 | 503) {
            report.rejected += 1;
        } else {
            report.errors += 1;
        }
        if let Some(ttft) = o.ttft_s {
            report.ttft_ms.push(ttft * 1e3);
        }
        report.inter_token_ms.extend(o.gaps_s.iter().map(|g| g * 1e3));
    }
    report.ttft_ms.sort_by(|a, b| a.total_cmp(b));
    report.inter_token_ms.sort_by(|a, b| a.total_cmp(b));
    report.goodput_rps = report.slo_met as f64 / wall;
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrivals_are_deterministic_and_monotone() {
        let cfg = TrafficConfig { n_requests: 200, arrivals_per_sec: 100.0, ..TrafficConfig::default() };
        let a = arrival_offsets(&cfg, &mut Rng64::new(9));
        let b = arrival_offsets(&cfg, &mut Rng64::new(9));
        assert_eq!(a, b);
        assert!(a.windows(2).all(|w| w[1] > w[0]), "offsets strictly increase");
        // mean inter-arrival should be near 1/λ = 10ms
        let mean = a.last().unwrap() / a.len() as f64;
        assert!((0.005..0.02).contains(&mean), "mean inter-arrival {mean}");
    }

    #[test]
    fn burst_compresses_on_phase_arrivals() {
        let burst = Burst { period_s: 1.0, duty: 0.5, peak: 10.0 };
        let cfg = TrafficConfig {
            n_requests: 300,
            arrivals_per_sec: 20.0,
            burst: Some(burst),
            ..TrafficConfig::default()
        };
        let offsets = arrival_offsets(&cfg, &mut Rng64::new(3));
        let (mut on, mut off) = (0usize, 0usize);
        for t in &offsets {
            if t.fract() < burst.duty {
                on += 1;
            } else {
                off += 1;
            }
        }
        assert!(on > off * 4, "bursty arrivals cluster in the on-phase: {on} vs {off}");
    }

    #[test]
    fn zipf_rank_one_dominates() {
        let cdf = zipf_cdf(16, 1.2);
        let mut rng = Rng64::new(5);
        let mut counts = [0usize; 16];
        for _ in 0..4000 {
            counts[zipf_rank(&mut rng, &cdf) - 1] += 1;
        }
        assert!(counts[0] > counts[7] * 4, "rank 1 ({}) >> rank 8 ({})", counts[0], counts[7]);
        assert!(counts.iter().all(|&c| c > 0), "every rank appears");
    }

    #[test]
    fn specs_reuse_system_prefixes_and_bound_lengths() {
        let cfg = TrafficConfig { n_requests: 64, cancel_frac: 0.25, best_of_frac: 0.25, ..TrafficConfig::default() };
        let specs = build_specs(&cfg);
        assert_eq!(specs.len(), 64);
        let mut prefixes = std::collections::BTreeSet::new();
        for s in &specs {
            assert!(s.prompt.len() <= cfg.system_prompt_len + cfg.max_prompt_len);
            assert!(s.prompt.len() > cfg.system_prompt_len);
            assert!(s.prompt.iter().all(|&t| t < cfg.vocab));
            prefixes.insert(s.prompt[..cfg.system_prompt_len].to_vec());
        }
        assert!(
            prefixes.len() <= cfg.system_prompts,
            "only {} distinct system prefixes possible, saw {}",
            cfg.system_prompts,
            prefixes.len()
        );
        assert!(prefixes.len() > 1, "Zipf pool actually varies");
        assert!(specs.iter().any(|s| s.cancel_after.is_some()));
        assert!(specs.iter().any(|s| s.n_best > 1));
    }

    #[test]
    fn percentile_floor_rank() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.5), 2.0);
        assert_eq!(percentile(&xs, 0.99), 3.0);
        assert!(percentile(&[], 0.5).is_nan());
    }
}
