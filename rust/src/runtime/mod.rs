//! PJRT runtime: load the AOT artifacts, compile once, execute on the
//! request path with device-resident weight buffers.
//!
//! Interchange is HLO *text* (see `python/compile/aot.py` and
//! /opt/xla-example/README.md for the 64-bit-proto-id gotcha).  Three
//! executables are compiled at startup:
//!
//! * `step`    — one token through the Pallas-kernel model variant
//! * `step_hw` — one token through the hardware-approximation variant
//! * `seq`     — a SEQ_CHUNK-token scan (bulk scoring / prefill)
//!
//! Weights upload once as `PjRtBuffer`s and are reused across every call
//! (`execute_b`), so the steady-state step cost is two small transfers
//! (state in, logits+state out) — this was the biggest single win of the
//! L3 perf pass (EXPERIMENTS.md §Perf).
//!
//! The PJRT client depends on the offline-vendored `xla` crate, which is
//! not available as a registry dependency; builds without the `pjrt`
//! cargo feature get an API-identical stub whose `load` errors, so every
//! native-model path (the default serving configuration) still compiles
//! and runs.  Enabling `pjrt` additionally requires adding the vendored
//! crate to `rust/Cargo.toml` (e.g. `xla = { path = "../vendor/xla" }`);
//! the feature flag alone cannot supply the dependency.

mod artifact;
#[cfg(feature = "pjrt")]
mod client;
#[cfg(not(feature = "pjrt"))]
#[path = "client_stub.rs"]
mod client;

pub use artifact::Manifest;
pub use client::RwkvRuntime;

/// Which compiled model variant to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    /// exact numerics with the Pallas kernels lowered in
    Exact,
    /// every nonlinearity through the paper's hardware approximations
    HwApprox,
}

/// Output of one step execution.
#[derive(Clone, Debug)]
pub struct StepOutput {
    pub logits: Vec<f32>,
    pub state: Vec<f32>,
}
