//! PJRT runtime: load the AOT artifacts, compile once, execute on the
//! request path with device-resident weight buffers.
//!
//! Interchange is HLO *text* (see `python/compile/aot.py` and
//! /opt/xla-example/README.md for the 64-bit-proto-id gotcha).  Three
//! executables are compiled at startup:
//!
//! * `step`    — one token through the Pallas-kernel model variant
//! * `step_hw` — one token through the hardware-approximation variant
//! * `seq`     — a SEQ_CHUNK-token scan (bulk scoring / prefill)
//!
//! Weights upload once as `PjRtBuffer`s and are reused across every call
//! (`execute_b`), so the steady-state step cost is two small transfers
//! (state in, logits+state out) — this was the biggest single win of the
//! L3 perf pass (EXPERIMENTS.md §Perf).

mod artifact;
mod client;

pub use artifact::Manifest;
pub use client::{RwkvRuntime, StepOutput, Variant};
