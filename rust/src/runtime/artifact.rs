//! `artifacts/manifest.json` — the ABI between the Python build layer and
//! this runtime: parameter order/shapes, state shape, artifact file names.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::{self, Json};

#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub n_layer: usize,
    pub d_model: usize,
    pub d_ffn: usize,
    pub vocab: usize,
    pub n_params: u64,
    pub seq_chunk: usize,
    pub pp_init: f32,
    pub param_order: Vec<ParamSpec>,
    pub step_hlo: PathBuf,
    pub step_hw_hlo: PathBuf,
    pub seq_hlo: PathBuf,
    pub weights: PathBuf,
    pub eval_data: PathBuf,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let j = json::parse_file(&dir.join("manifest.json"))
            .context("loading artifacts/manifest.json — run `make artifacts` first")?;
        let cfg = j.req("config")?;
        let arts = j.req("artifacts")?;
        let file = |key: &str| -> Result<PathBuf> {
            Ok(dir.join(arts.req(key)?.as_str()?))
        };
        let param_order = j
            .req("param_order")?
            .as_arr()?
            .iter()
            .map(|p| {
                Ok(ParamSpec {
                    name: p.req("name")?.as_str()?.to_string(),
                    shape: p
                        .req("shape")?
                        .as_arr()?
                        .iter()
                        .map(|v| v.as_usize())
                        .collect::<Result<_>>()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest {
            dir: dir.to_path_buf(),
            n_layer: cfg.req("n_layer")?.as_usize()?,
            d_model: cfg.req("d_model")?.as_usize()?,
            d_ffn: cfg.req("d_ffn")?.as_usize()?,
            vocab: cfg.req("vocab")?.as_usize()?,
            n_params: j.req("n_params")?.as_f64()? as u64,
            seq_chunk: j.req("seq_chunk")?.as_usize()?,
            pp_init: j.req("pp_init")?.as_f64()? as f32,
            param_order,
            step_hlo: file("step")?,
            step_hw_hlo: file("step_hw")?,
            seq_hlo: file("seq")?,
            weights: file("weights")?,
            eval_data: file("eval_data")?,
        })
    }

    pub fn state_len(&self) -> usize {
        self.n_layer * 5 * self.d_model
    }

    /// Fresh initial state vector (pp rows filled with `pp_init`) — the
    /// single source of the state layout for both the real PJRT client
    /// and the featureless stub.
    pub fn init_state(&self) -> Vec<f32> {
        let mut s = vec![0f32; self.state_len()];
        let d = self.d_model;
        for l in 0..self.n_layer {
            for i in 0..d {
                s[(l * 5 + 4) * d + i] = self.pp_init;
            }
        }
        s
    }

    /// Load the eval data JSON.
    pub fn load_eval_data(&self) -> Result<Json> {
        json::parse_file(&self.eval_data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_real_manifest_if_present() {
        let dir = Path::new("artifacts");
        if !dir.join("manifest.json").exists() {
            return;
        }
        let m = Manifest::load(dir).unwrap();
        assert_eq!(m.d_model, 128);
        assert_eq!(m.n_layer, 4);
        assert_eq!(m.state_len(), 4 * 5 * 128);
        assert_eq!(m.n_params, crate::model::tiny_expected_params());
        // param order covers emb first, head last (the AOT flattening)
        assert_eq!(m.param_order.first().unwrap().name, "emb");
        assert_eq!(m.param_order.last().unwrap().name, "head");
        assert!(m.step_hlo.exists() && m.seq_hlo.exists() && m.weights.exists());
    }

    #[test]
    fn missing_dir_errors_helpfully() {
        let err = Manifest::load(Path::new("/nonexistent")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
