//! Stub PJRT client for builds without the `pjrt` cargo feature.
//!
//! The real client (`client.rs`) wraps the offline-vendored `xla` crate,
//! which cannot be expressed as a registry dependency.  This stub keeps
//! the exact public API — `RwkvRuntime`, its methods, and the shared
//! [`Variant`]/[`StepOutput`] types from the parent module — so every
//! caller (engine, eval scorer, harness cross-checks, CLI) compiles
//! unchanged; the only behavioural difference is that [`RwkvRuntime::load`]
//! returns an error, which each of those paths already handles (they guard
//! on artifact presence and surface `Result`s).

use std::path::Path;

use anyhow::{bail, Result};

use super::artifact::Manifest;
use super::{StepOutput, Variant};
use crate::model::weights::WeightFile;

const UNAVAILABLE: &str = "PJRT runtime unavailable: hfrwkv was built without the `pjrt` \
     feature (the offline `xla` crate is not in this build's dependency graph)";

/// Stub runtime.  Never constructible — `load` always errors — but the
/// type and its surface stay identical to the real client so the
/// coordinator/eval/harness code is feature-independent.
pub struct RwkvRuntime {
    pub manifest: Manifest,
}

impl RwkvRuntime {
    /// Always errors in stub builds.
    pub fn load(_dir: &Path) -> Result<RwkvRuntime> {
        bail!(UNAVAILABLE);
    }

    /// Replace the device-resident weights (unreachable in stub builds).
    pub fn swap_weights(&mut self, _weights: &WeightFile) -> Result<()> {
        bail!(UNAVAILABLE);
    }

    /// Fresh initial state vector.
    pub fn init_state(&self) -> Vec<f32> {
        self.manifest.init_state()
    }

    /// Execute one token step (unreachable in stub builds).
    pub fn step(&self, _variant: Variant, _state: &[f32], _token: u32) -> Result<StepOutput> {
        bail!(UNAVAILABLE);
    }

    /// Execute a SEQ_CHUNK-token chunk (unreachable in stub builds).
    pub fn seq_chunk(&self, _state: &[f32], _tokens: &[u32]) -> Result<(Vec<f32>, Vec<f32>)> {
        bail!(UNAVAILABLE);
    }

    pub fn platform_name(&self) -> String {
        "stub (built without the pjrt feature)".to_string()
    }
}
