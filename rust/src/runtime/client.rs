//! PJRT client wrapper: compile the HLO-text artifacts once, keep weights
//! device-resident, execute token steps / sequence chunks.

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use super::artifact::Manifest;
use super::{StepOutput, Variant};
use crate::model::weights::WeightFile;

/// The compiled runtime.  NOT Sync: PJRT buffers are used from the
/// owning coordinator thread (the engine thread owns this exclusively).
pub struct RwkvRuntime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    step_exe: xla::PjRtLoadedExecutable,
    step_hw_exe: xla::PjRtLoadedExecutable,
    seq_exe: xla::PjRtLoadedExecutable,
    /// device-resident parameter buffers, in manifest order
    params: Vec<xla::PjRtBuffer>,
}

impl RwkvRuntime {
    /// Load artifacts from `dir`, compile all three executables, and
    /// upload the weights.
    pub fn load(dir: &Path) -> Result<RwkvRuntime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(to_anyhow)?;
        let compile = |path: &Path| -> Result<xla::PjRtLoadedExecutable> {
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("bad path"))?,
            )
            .map_err(to_anyhow)
            .with_context(|| format!("parsing {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client.compile(&comp).map_err(to_anyhow)
        };
        let step_exe = compile(&manifest.step_hlo)?;
        let step_hw_exe = compile(&manifest.step_hw_hlo)?;
        let seq_exe = compile(&manifest.seq_hlo)?;

        let weights = WeightFile::load(&manifest.weights)?;
        let params = Self::upload_params(&client, &manifest, &weights)?;
        Ok(RwkvRuntime { manifest, client, step_exe, step_hw_exe, seq_exe, params })
    }

    /// Upload a full parameter set (manifest order) as device buffers.
    fn upload_params(
        client: &xla::PjRtClient,
        manifest: &Manifest,
        weights: &WeightFile,
    ) -> Result<Vec<xla::PjRtBuffer>> {
        manifest
            .param_order
            .iter()
            .map(|spec| {
                let t = weights.get(&spec.name)?;
                if t.shape != spec.shape {
                    bail!("{}: shape {:?} != manifest {:?}", spec.name, t.shape, spec.shape);
                }
                client
                    .buffer_from_host_buffer::<f32>(&t.data, &spec.shape, None)
                    .map_err(to_anyhow)
            })
            .collect()
    }

    /// Replace the device-resident weights (e.g. with a fake-quantized
    /// set for the Table 1 ablation through the PJRT path).
    pub fn swap_weights(&mut self, weights: &WeightFile) -> Result<()> {
        self.params = Self::upload_params(&self.client, &self.manifest, weights)?;
        Ok(())
    }

    /// Fresh initial state vector.
    pub fn init_state(&self) -> Vec<f32> {
        self.manifest.init_state()
    }

    fn exe(&self, variant: Variant) -> &xla::PjRtLoadedExecutable {
        match variant {
            Variant::Exact => &self.step_exe,
            Variant::HwApprox => &self.step_hw_exe,
        }
    }

    /// Execute one token step.
    pub fn step(&self, variant: Variant, state: &[f32], token: u32) -> Result<StepOutput> {
        let m = &self.manifest;
        if state.len() != m.state_len() {
            bail!("state length {} != {}", state.len(), m.state_len());
        }
        let state_buf = self
            .client
            .buffer_from_host_buffer::<f32>(state, &[m.n_layer, 5, m.d_model], None)
            .map_err(to_anyhow)?;
        let token_buf = self
            .client
            .buffer_from_host_buffer::<i32>(&[token as i32], &[], None)
            .map_err(to_anyhow)?;
        let mut args: Vec<&xla::PjRtBuffer> = self.params.iter().collect();
        args.push(&state_buf);
        args.push(&token_buf);
        let result = self.exe(variant).execute_b(&args).map_err(to_anyhow)?;
        let lit = result[0][0].to_literal_sync().map_err(to_anyhow)?;
        let (logits, state) = lit.to_tuple2().map_err(to_anyhow)?;
        Ok(StepOutput {
            logits: logits.to_vec::<f32>().map_err(to_anyhow)?,
            state: state.to_vec::<f32>().map_err(to_anyhow)?,
        })
    }

    /// Execute a SEQ_CHUNK-token chunk: returns per-position logits
    /// (flattened [T, vocab]) and the carried state.
    pub fn seq_chunk(&self, state: &[f32], tokens: &[u32]) -> Result<(Vec<f32>, Vec<f32>)> {
        let m = &self.manifest;
        if tokens.len() != m.seq_chunk {
            bail!("seq chunk must be exactly {} tokens", m.seq_chunk);
        }
        let state_buf = self
            .client
            .buffer_from_host_buffer::<f32>(state, &[m.n_layer, 5, m.d_model], None)
            .map_err(to_anyhow)?;
        let toks: Vec<i32> = tokens.iter().map(|&t| t as i32).collect();
        let tok_buf = self
            .client
            .buffer_from_host_buffer::<i32>(&toks, &[toks.len()], None)
            .map_err(to_anyhow)?;
        let mut args: Vec<&xla::PjRtBuffer> = self.params.iter().collect();
        args.push(&state_buf);
        args.push(&tok_buf);
        let result = self.seq_exe.execute_b(&args).map_err(to_anyhow)?;
        let lit = result[0][0].to_literal_sync().map_err(to_anyhow)?;
        let (logits, state) = lit.to_tuple2().map_err(to_anyhow)?;
        Ok((
            logits.to_vec::<f32>().map_err(to_anyhow)?,
            state.to_vec::<f32>().map_err(to_anyhow)?,
        ))
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }
}

fn to_anyhow(e: xla::Error) -> anyhow::Error {
    anyhow!("{e}")
}
