//! HFRWKV command-line interface — the L3 leader entrypoint.
//!
//! Subcommands regenerate each paper artifact or serve the trained model:
//!
//! ```text
//! hfrwkv table1 [--limit N] [--no-hw]     Table 1 quantization ablation
//! hfrwkv table2                            Table 2 resource utilization
//! hfrwkv fig7 [--detail]                   Fig 7 throughput grid
//! hfrwkv fig8                              Fig 8 energy efficiency
//! hfrwkv headline                          abstract's headline ratios
//! hfrwkv ablation                          design-choice ablations
//! hfrwkv serve [--requests N] [--hw]       serve the tiny model via PJRT
//! hfrwkv all                               everything except serve
//! ```

use std::path::Path;

use hfrwkv::coordinator::{Coordinator, CoordinatorConfig, GenRequest};
use hfrwkv::harness::{ablation, fig7, fig8, headline, table1, table2};
use hfrwkv::model::Tokenizer;
use hfrwkv::runtime::{RwkvRuntime, Variant};

/// Tiny argv helper (clap is unavailable offline).
struct Args {
    rest: Vec<String>,
}

impl Args {
    fn parse() -> (String, Args) {
        let mut it = std::env::args().skip(1);
        let cmd = it.next().unwrap_or_else(|| "help".to_string());
        (cmd, Args { rest: it.collect() })
    }

    fn flag(&self, name: &str) -> bool {
        self.rest.iter().any(|a| a == name)
    }

    fn value(&self, name: &str) -> Option<&str> {
        self.rest
            .iter()
            .position(|a| a == name)
            .and_then(|i| self.rest.get(i + 1))
            .map(|s| s.as_str())
    }
}

fn main() {
    let (cmd, args) = Args::parse();
    let result = match cmd.as_str() {
        "table1" => cmd_table1(&args),
        "table2" => table2::run().map(|t| println!("{t}")),
        "fig7" => fig7::report(&fig7::run(), args.flag("--detail")).map(|t| println!("{t}")),
        "fig8" => fig8::report(&fig8::run()).map(|t| println!("{t}")),
        "headline" => headline::report(&headline::run()).map(|t| println!("{t}")),
        "ablation" => ablation::run().map(|t| println!("{t}")),
        "serve" => cmd_serve(&args),
        "all" => cmd_all(&args),
        _ => {
            eprintln!("usage: hfrwkv <table1|table2|fig7|fig8|headline|ablation|serve|all> [flags]");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn artifacts_dir() -> &'static Path {
    Path::new("artifacts")
}

fn cmd_table1(args: &Args) -> hfrwkv::Result<()> {
    let limit = args.value("--limit").map(|v| v.parse().unwrap());
    let include_hw = !args.flag("--no-hw");
    println!("Table 1 — quantization ablation on the trained tiny model");
    let rows = table1::run(artifacts_dir(), limit, include_hw)?;
    println!("{}", table1::report(&rows)?);
    if args.flag("--pjrt") {
        println!("cross-path check (same ablation through the compiled PJRT executable):");
        for (name, ppl) in table1::run_pjrt_crosscheck(artifacts_dir(), 2000)? {
            println!("  {name:<16} stream ppl {ppl:.3}");
        }
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> hfrwkv::Result<()> {
    let n_requests: usize = args.value("--requests").map(|v| v.parse().unwrap()).unwrap_or(8);
    let variant = if args.flag("--hw") { Variant::HwApprox } else { Variant::Exact };

    println!("loading artifacts + compiling PJRT executables ...");
    let manifest = hfrwkv::runtime::Manifest::load(artifacts_dir())?;
    let eval_data = manifest.load_eval_data()?;
    let tokenizer = Tokenizer::from_json(eval_data.req("vocab")?)?;

    // the PJRT runtime is constructed inside the worker thread (not Send)
    let coord = Coordinator::spawn_with(
        || RwkvRuntime::load(Path::new("artifacts")).expect("runtime load"),
        CoordinatorConfig { max_active: 4, ..Default::default() },
    );
    let prompts = [
        "alice has a red hat . the hat of alice is",
        "three plus four is",
        "bob likes carol . so carol",
        "dave has a blue cup . the cup of dave is",
    ];
    let t0 = std::time::Instant::now();
    let mut rxs = Vec::new();
    for i in 0..n_requests {
        // BOS-prefix: documents are BOS-led in the training corpus
        let mut prompt = vec![hfrwkv::model::tokenizer::BOS];
        prompt.extend(tokenizer.encode(prompts[i % prompts.len()]).unwrap());
        let req = GenRequest::builder(prompt, 16).variant(variant).build();
        rxs.push(coord.submit(req)?);
    }
    for (i, rx) in rxs.into_iter().enumerate() {
        let r = rx.wait_one()?;
        println!(
            "[{i}] {:>6.1} tok/s decode, {:.1} ms prefill, {:.1} ms ttft: {}",
            r.decode_tokens_per_sec(),
            r.prefill_seconds * 1e3,
            r.ttft_seconds * 1e3,
            tokenizer.decode(&r.tokens)
        );
    }
    let wall = t0.elapsed().as_secs_f64();
    // poison-tolerant: a worker panic must not take the report down too
    let m = coord.metrics.lock().unwrap_or_else(|e| e.into_inner()).clone();
    println!("\n{}", m.report());
    println!("wall time {wall:.2}s → {:.1} tok/s aggregate",
             m.tokens_generated as f64 / wall);
    Ok(())
}

fn cmd_all(args: &Args) -> hfrwkv::Result<()> {
    println!("== Table 2 ==\n{}", table2::run()?);
    println!("== Fig 7 ==\n{}", fig7::report(&fig7::run(), true)?);
    println!("== Fig 8 ==\n{}", fig8::report(&fig8::run())?);
    println!("== Headlines ==\n{}", headline::report(&headline::run())?);
    println!("== Ablations ==\n{}", ablation::run()?);
    cmd_table1(args)?;
    Ok(())
}
