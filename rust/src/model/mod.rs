//! RWKV-4 inference in Rust: weights container (HFWT reader), the f32
//! reference forward pass, the hardware-numerics forward pass built on
//! [`crate::arith`] + [`crate::quant`], tokenizer and sampler.
//!
//! Two Rust forwards exist alongside the PJRT path:
//!
//! * [`rwkv::RwkvModel`] — plain f32, bit-for-bit the same math as the
//!   JAX `exact` variant (validated against the HLO executable in
//!   `rust/tests/golden_parity.rs`).  The Table 1 ablation runs here
//!   (fake-quantized weights, f32 activations).
//! * [`rwkv_hw::HwModel`] — the paper's datapath: Δ-PoT matrices, 9-bit
//!   activations, EXP-LUT/PWL-sigmoid/DIVU nonlinearities, ATAC-identity
//!   LayerNorm.  This measures the full W9A9 + approximation stack.

pub mod rwkv;
pub mod rwkv_hw;
pub mod sampler;
pub mod tokenizer;
pub mod weights;

pub use rwkv::{RwkvModel, State};
pub use rwkv_hw::HwModel;
pub use sampler::Sampler;
pub use tokenizer::Tokenizer;
pub use weights::WeightFile;

/// Parameter count of the tiny served model — must equal
/// `python/compile/config.py::TINY.n_params` (cross-checked in tests).
pub fn tiny_expected_params() -> u64 {
    890_880
}
