//! RWKV-4 inference in Rust: ONE generic layer walk behind swappable
//! numerics backends, plus the weights container (HFWT reader),
//! tokenizer and sampler.
//!
//! # Architecture: one walk, many numerics
//!
//! The paper's accelerator has a single datapath — the PE array plus the
//! EXP–σ and DIVU units — and realizes its configurations by swapping
//! *numerics*, not control flow (§3–§4).  This module mirrors that:
//! [`forward`] holds the only RWKV layer walk in the crate
//! ([`forward::forward_panel`], a `[*, width]`-activation-panel walk
//! whose width-1 batch is the decode step, width-B batch is batched
//! decode, and width-T sequence is chunked prefill), generic over the
//! [`forward::Numerics`] backend trait.  Backends:
//!
//! * [`rwkv::RwkvModel`] — the exact backend: plain f32 math, f32 weight
//!   matrices, optional uniform activation fake-quant.  Bit-for-bit the
//!   same math as the JAX `exact` variant (validated against the HLO
//!   executable in `rust/tests/golden_parity.rs`); the Table 1 software
//!   ablation rows (fake-quantized weights, W9A9 activations) run here
//!   (§5.2).
//! * [`rwkv_hw::HwModel`] — the hardware backend, i.e. the paper's full
//!   datapath: Δ-PoT matrices (§3.2) decoded to f32 at load, per-site
//!   9-bit activations at calibrated per-layer scales, EXP-LUT /
//!   PWL-sigmoid / DIVU nonlinearities (§4), ATAC-identity LayerNorm.
//!   This is the "Proposed+HW" Table 1 row, with 9-bit clip-event
//!   observability — bit-faithful, but it streams full f32 planes.
//! * [`rwkv_packed::PackedModel`] — the throughput backend: the SAME
//!   value grid and elementwise units as `HwModel`, but the matrices
//!   stay PACKED (9-bit Δ-PoT words, 2 bytes/weight streamed) and the
//!   matmuls decode in-register with runtime-dispatched AVX2 kernels
//!   ([`packed_gemm`]) — half the weight traffic per decode cycle, the
//!   paper's memory-bottleneck argument replayed in software.  Logits
//!   are bit-identical to `HwModel`'s (`rust/tests/packed_parity.rs`).
//! * the calibration tap (internal to `rwkv_hw`) — a site-observer
//!   backend whose quantization hook records per-site activation maxima
//!   instead of rounding; `HwModel::from_f32` / `PackedModel::from_f32`
//!   resolve its output into the per-layer scale table.
//!
//! | backend | weights streamed | elementwise | role |
//! |---|---|---|---|
//! | [`RwkvModel`] | f32 (4 B/w) | f32 | exact reference, software ablations |
//! | [`HwModel`] | decoded Δ-PoT f32 (4 B/w) | integer units | bit-faithful accuracy model |
//! | [`PackedModel`] | packed Δ-PoT (2 B/w) | integer units | throughput configuration |
//!
//! Because every execution shape on every backend is the same walk,
//! decode / batched decode / chunked prefill are bit-exact with each
//! other by construction (asserted in `rust/tests/{batch,prefill}_parity.rs`
//! and `rust/tests/forward_core.rs`), and a new execution feature lands
//! once in [`forward`] instead of once per shape per backend.  The PJRT
//! runtime path (`crate::runtime`) sits alongside as the compiled-HLO
//! cross-check.
//!
//! # State is snapshot-cheap
//!
//! [`State`] is `n_layer * 5 * d` f32s — fixed-size, independent of how
//! many tokens produced it.  Both shape-invariance and that O(1) size
//! are load-bearing for the serving layer's prefix cache
//! (`crate::statecache`): any state captured at a prefill chunk
//! boundary is bit-identical to the state a differently-chunked (or
//! token-by-token) prefill passes through, so it can be snapshotted at
//! tens of kilobytes and later resumed by another session with zero
//! numeric drift.  The capture/restore seam is
//! `EngineModel::{snapshot_state, restore_state}`
//! (`crate::coordinator::engine`), defaulting to a verbatim copy of the
//! flat state vector every backend here uses.

pub mod forward;
pub mod packed_gemm;
pub mod rwkv;
pub mod rwkv_hw;
pub mod rwkv_packed;
pub mod sampler;
pub mod tokenizer;
pub mod weights;

pub use forward::{panel_all_finite, Columns, HeadMode, MatId, Numerics, Site};
pub use rwkv::{RwkvModel, State};
pub use rwkv_hw::{HwModel, LayerScales};
pub use rwkv_packed::PackedModel;
pub use sampler::Sampler;
pub use tokenizer::Tokenizer;
pub use weights::WeightFile;

/// Parameter count of the tiny served model — must equal
/// `python/compile/config.py::TINY.n_params` (cross-checked in tests).
pub fn tiny_expected_params() -> u64 {
    890_880
}
