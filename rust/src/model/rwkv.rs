//! RWKV-4 f32 model: weights, the exact-numerics backend of the ONE
//! generic layer walk ([`crate::model::forward`]), and the shared
//! [`matvec`]/[`matmul`] PE-array kernels.  Bit-for-bit the same math as
//! the JAX `exact` variant (`python/compile/model.py::step`), validated
//! against the AOT HLO executable in `rust/tests/golden_parity.rs`.
//!
//! Every execution shape ([`RwkvModel::step`], [`RwkvModel::step_batch`],
//! [`RwkvModel::prefill_chunk`]) is a thin wrapper that runs
//! [`forward_panel`](crate::model::forward::forward_panel) with this
//! model as the [`Numerics`] backend — there is no per-shape forward
//! body here.
//!
//! # Perf notes
//!
//! * §Perf L3-1 ([`matvec`]): the dot product runs 8 independent
//!   accumulators so LLVM can vectorize (see the function doc).
//! * §Perf L3-3 ([`matmul`] / [`RwkvModel::step_batch`]): batched decode
//!   stacks the B active sessions' activations into a `[B, d]` panel and
//!   runs ONE matmul per weight matrix instead of B matvecs.  The kernel
//!   loops weight *rows* in the outer loop and blocks the panel columns
//!   in groups of four, so each weight chunk loaded into registers feeds
//!   four sessions' accumulators before being evicted — the software
//!   analog of the paper's on-chip weight reuse (chunked double buffering
//!   exists so every weight word fetched does as much MAC work as
//!   possible; here every weight row streamed from cache does B columns
//!   of MAC work).  Per-column accumulation order is kept identical to
//!   [`matvec`], so batched decode is bit-exact with sequential decode
//!   (asserted in `rust/tests/batch_parity.rs`).
//! * §Perf L3-4 ([`RwkvModel::prefill_chunk`]): sequence-parallel
//!   prefill.  RWKV's dual formulation makes the seven projections per
//!   block *time*-parallel — only the tiny elementwise WKV / token-shift
//!   recurrence is inherently sequential — so a prompt chunk of T tokens
//!   is laid out as a `[T, d]` panel and every weight matrix is streamed
//!   ONCE per chunk through the same [`matmul`] row-panel kernel instead
//!   of once per token.  The recurrence runs as a cheap elementwise loop
//!   between projections, and the head projection runs only on the last
//!   token (token-by-token prefill computes — and discards — full logits
//!   for every prompt token).  Per-column op order is identical to
//!   [`matvec`]/[`RwkvModel::step`], so chunked prefill is bit-exact
//!   with token-by-token prefill at any T (asserted in
//!   `rust/tests/prefill_parity.rs`); `rust/benches/prefill.rs` measures
//!   the resulting prefill speedup.

use anyhow::{bail, Result};

use super::forward::{self, Columns, HeadMode, MatId, Numerics, Site};
use super::weights::WeightFile;
use crate::quant::Scheme;

pub const PP_INIT: f32 = -1e30;

/// Recurrent state: per layer, 5 rows of d (att_x_prev, ffn_x_prev, aa,
/// bb, pp), flattened `[n_layer * 5 * d]` in the artifact layout.
#[derive(Clone, Debug, PartialEq)]
pub struct State {
    pub data: Vec<f32>,
    pub n_layer: usize,
    pub d: usize,
}

impl State {
    pub fn new(n_layer: usize, d: usize) -> State {
        let mut data = vec![0f32; n_layer * 5 * d];
        for l in 0..n_layer {
            for i in 0..d {
                data[(l * 5 + 4) * d + i] = PP_INIT;
            }
        }
        State { data, n_layer, d }
    }

    #[inline]
    pub fn row(&self, layer: usize, r: usize) -> &[f32] {
        let o = (layer * 5 + r) * self.d;
        &self.data[o..o + self.d]
    }

    #[inline]
    pub fn row_mut(&mut self, layer: usize, r: usize) -> &mut [f32] {
        let o = (layer * 5 + r) * self.d;
        &mut self.data[o..o + self.d]
    }
}

/// Per-layer parameters (slices into owned storage).
#[derive(Clone, Debug)]
pub struct Block {
    pub ln1_w: Vec<f32>,
    pub ln1_b: Vec<f32>,
    pub ln2_w: Vec<f32>,
    pub ln2_b: Vec<f32>,
    pub att_decay: Vec<f32>, // raw; effective w = -exp(raw)
    pub att_first: Vec<f32>,
    pub att_mix_k: Vec<f32>,
    pub att_mix_v: Vec<f32>,
    pub att_mix_r: Vec<f32>,
    pub att_key: Vec<f32>,        // [d, d]
    pub att_value: Vec<f32>,      // [d, d]
    pub att_receptance: Vec<f32>, // [d, d]
    pub att_output: Vec<f32>,     // [d, d]
    pub ffn_mix_k: Vec<f32>,
    pub ffn_mix_r: Vec<f32>,
    pub ffn_key: Vec<f32>,        // [f, d]
    pub ffn_receptance: Vec<f32>, // [d, d]
    pub ffn_value: Vec<f32>,      // [d, f]
}

/// The full model.
#[derive(Clone, Debug)]
pub struct RwkvModel {
    pub n_layer: usize,
    pub d: usize,
    pub f: usize,
    pub vocab: usize,
    pub emb: Vec<f32>, // [v, d]
    pub ln0_w: Vec<f32>,
    pub ln0_b: Vec<f32>,
    pub blocks: Vec<Block>,
    pub ln_out_w: Vec<f32>,
    pub ln_out_b: Vec<f32>,
    pub head: Vec<f32>, // [v, d]
    /// When set, every LayerNorm/projection output is quantized to this
    /// many bits at a dynamic per-vector scale — the "A9" half of the
    /// paper's W9A9 ablation protocol (§5.2).  None = f32 activations.
    pub act_bits: Option<u32>,
}

/// Quantize a vector in place at `bits` with dynamic max-abs scale.
#[inline]
pub fn act_quant(xs: &mut [f32], bits: Option<u32>) {
    let Some(bits) = bits else { return };
    let qmax = ((1i64 << (bits - 1)) - 1) as f32;
    let s = xs.iter().fold(0f32, |m, &x| m.max(x.abs()));
    if s == 0.0 {
        return;
    }
    for x in xs.iter_mut() {
        *x = (*x / s * qmax).round() * s / qmax;
    }
}

// ---------------------------------------------------------------------------
// primitive ops
// ---------------------------------------------------------------------------

pub fn layernorm(x: &[f32], w: &[f32], b: &[f32], out: &mut [f32]) {
    let d = x.len() as f32;
    let mu = x.iter().sum::<f32>() / d;
    let var = x.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / d;
    let inv = 1.0 / (var + 1e-5).sqrt();
    for i in 0..x.len() {
        out[i] = (x[i] - mu) * inv * w[i] + b[i];
    }
}

/// w[m,l] @ x[l] -> out[m]
///
/// Perf note (§Perf L3-1): the dot product runs 8 independent
/// accumulators so LLVM can vectorize — serial `acc += a*b` is an
/// ordered float reduction the compiler must not reassociate, which
/// capped the original version at ~1.7 GMAC/s.
pub fn matvec(w: &[f32], x: &[f32], out: &mut [f32]) {
    let l = x.len();
    debug_assert_eq!(w.len(), out.len() * l);
    for (r, o) in out.iter_mut().enumerate() {
        let row = &w[r * l..(r + 1) * l];
        let mut acc = [0f32; 8];
        let chunks = l / 8;
        for c in 0..chunks {
            let rb = &row[c * 8..c * 8 + 8];
            let xb = &x[c * 8..c * 8 + 8];
            for k in 0..8 {
                acc[k] += rb[k] * xb[k];
            }
        }
        let mut tail = 0f32;
        for k in chunks * 8..l {
            tail += row[k] * x[k];
        }
        *o = reduce8(acc, tail);
    }
}

/// Reduce the 8 accumulators exactly like [`matvec`] does — one shared
/// expression so the batched kernel cannot drift from the sequential
/// one.  `pub(crate)` so the packed backend's scalar oracle
/// (`model::packed_gemm`) reduces through the very same expression.
#[inline]
pub(crate) fn reduce8(acc: [f32; 8], tail: f32) -> f32 {
    (acc[0] + acc[4]) + (acc[1] + acc[5]) + (acc[2] + acc[6]) + (acc[3] + acc[7]) + tail
}

/// w[m,l] @ xs[b,l]ᵀ -> out[b,m]: the batched-decode twin of [`matvec`].
///
/// `xs` holds B activation columns back to back (`xs[j*l..(j+1)*l]` is
/// session j's vector); `out` is laid out the same way per session.
///
/// Perf note (§Perf L3-3): the outer loop walks weight rows and the
/// panel columns are blocked four at a time, so each 8-wide weight chunk
/// is loaded once and multiplied into four sessions' accumulators —
/// B-fold weight reuse instead of streaming the matrix once per session.
/// Each column keeps the exact [`matvec`] accumulation order (8
/// accumulators, same reduction tree), so per-column results are
/// bit-exact with the sequential path at any B.
pub fn matmul(w: &[f32], xs: &[f32], out: &mut [f32], b: usize) {
    if b == 0 {
        return;
    }
    let l = xs.len() / b;
    let m = out.len() / b;
    // hard asserts: unlike matvec, the extra `b` parameter lets slice
    // lengths disagree, which would silently misindex in release builds
    assert_eq!(xs.len(), b * l, "xs must hold exactly b columns");
    assert_eq!(out.len(), b * m, "out must hold exactly b columns");
    assert_eq!(w.len(), m * l, "w shape inconsistent with xs/out panels");
    let chunks = l / 8;
    for r in 0..m {
        let row = &w[r * l..(r + 1) * l];
        let mut j = 0usize;
        while j + 4 <= b {
            let x0 = &xs[j * l..(j + 1) * l];
            let x1 = &xs[(j + 1) * l..(j + 2) * l];
            let x2 = &xs[(j + 2) * l..(j + 3) * l];
            let x3 = &xs[(j + 3) * l..(j + 4) * l];
            let mut a0 = [0f32; 8];
            let mut a1 = [0f32; 8];
            let mut a2 = [0f32; 8];
            let mut a3 = [0f32; 8];
            for c in 0..chunks {
                let o = c * 8;
                let rb = &row[o..o + 8];
                let b0 = &x0[o..o + 8];
                let b1 = &x1[o..o + 8];
                let b2 = &x2[o..o + 8];
                let b3 = &x3[o..o + 8];
                for k in 0..8 {
                    a0[k] += rb[k] * b0[k];
                    a1[k] += rb[k] * b1[k];
                    a2[k] += rb[k] * b2[k];
                    a3[k] += rb[k] * b3[k];
                }
            }
            let (mut t0, mut t1, mut t2, mut t3) = (0f32, 0f32, 0f32, 0f32);
            for k in chunks * 8..l {
                t0 += row[k] * x0[k];
                t1 += row[k] * x1[k];
                t2 += row[k] * x2[k];
                t3 += row[k] * x3[k];
            }
            out[j * m + r] = reduce8(a0, t0);
            out[(j + 1) * m + r] = reduce8(a1, t1);
            out[(j + 2) * m + r] = reduce8(a2, t2);
            out[(j + 3) * m + r] = reduce8(a3, t3);
            j += 4;
        }
        while j < b {
            let x = &xs[j * l..(j + 1) * l];
            let mut acc = [0f32; 8];
            for c in 0..chunks {
                let o = c * 8;
                let rb = &row[o..o + 8];
                let xb = &x[o..o + 8];
                for k in 0..8 {
                    acc[k] += rb[k] * xb[k];
                }
            }
            let mut tail = 0f32;
            for k in chunks * 8..l {
                tail += row[k] * x[k];
            }
            out[j * m + r] = reduce8(acc, tail);
            j += 1;
        }
    }
}

#[inline]
pub(crate) fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

impl RwkvModel {
    /// Assemble from a loaded weight file (artifact naming convention).
    pub fn from_weights(wf: &WeightFile) -> Result<RwkvModel> {
        let meta = &wf.meta;
        let (n_layer, d, f, vocab) = match (
            meta.get("n_layer"),
            meta.get("d_model"),
            meta.get("d_ffn"),
            meta.get("vocab"),
        ) {
            (Some(n), Some(d), Some(f), Some(v)) => (
                n.as_usize()?,
                d.as_usize()?,
                f.as_usize()?,
                v.as_usize()?,
            ),
            _ => bail!("weight file missing model meta"),
        };
        let get = |name: &str| -> Result<Vec<f32>> { Ok(wf.get(name)?.data.clone()) };
        let mut blocks = Vec::with_capacity(n_layer);
        for i in 0..n_layer {
            let b = |suffix: &str| format!("blocks.{i}.{suffix}");
            blocks.push(Block {
                ln1_w: get(&b("ln1.weight"))?,
                ln1_b: get(&b("ln1.bias"))?,
                ln2_w: get(&b("ln2.weight"))?,
                ln2_b: get(&b("ln2.bias"))?,
                att_decay: get(&b("att.time_decay"))?,
                att_first: get(&b("att.time_first"))?,
                att_mix_k: get(&b("att.time_mix_k"))?,
                att_mix_v: get(&b("att.time_mix_v"))?,
                att_mix_r: get(&b("att.time_mix_r"))?,
                att_key: get(&b("att.key"))?,
                att_value: get(&b("att.value"))?,
                att_receptance: get(&b("att.receptance"))?,
                att_output: get(&b("att.output"))?,
                ffn_mix_k: get(&b("ffn.time_mix_k"))?,
                ffn_mix_r: get(&b("ffn.time_mix_r"))?,
                ffn_key: get(&b("ffn.key"))?,
                ffn_receptance: get(&b("ffn.receptance"))?,
                ffn_value: get(&b("ffn.value"))?,
            });
        }
        Ok(RwkvModel {
            n_layer,
            d,
            f,
            vocab,
            emb: get("emb")?,
            ln0_w: get("ln0.weight")?,
            ln0_b: get("ln0.bias")?,
            blocks,
            ln_out_w: get("ln_out.weight")?,
            ln_out_b: get("ln_out.bias")?,
            head: get("head")?,
            act_bits: None,
        })
    }

    pub fn new_state(&self) -> State {
        State::new(self.n_layer, self.d)
    }

    /// Fake-quantize every *matrix* weight under `scheme` (the Table 1
    /// protocol: vector/additive weights stay 9-bit-uniform ≈ lossless at
    /// f32, matching §3.2's mixed-precision split).
    pub fn quantize_matrices(&mut self, scheme: Scheme) {
        use crate::quant::fake_quant;
        fake_quant(&mut self.emb, scheme);
        fake_quant(&mut self.head, scheme);
        for b in &mut self.blocks {
            fake_quant(&mut b.att_key, scheme);
            fake_quant(&mut b.att_value, scheme);
            fake_quant(&mut b.att_receptance, scheme);
            fake_quant(&mut b.att_output, scheme);
            fake_quant(&mut b.ffn_key, scheme);
            fake_quant(&mut b.ffn_receptance, scheme);
            fake_quant(&mut b.ffn_value, scheme);
        }
    }

    /// One autoregressive step: returns logits, updates `state` in
    /// place.  A width-1 batch panel through the generic walk.
    ///
    /// Perf note (§Perf L3-2): scratch lives in the walk's thread-local
    /// [`ScratchPanels`](crate::model::forward::ScratchPanels), so the
    /// step allocates nothing but the returned logits vector.
    pub fn step(&self, state: &mut State, token: u32) -> Vec<f32> {
        let mut logits = Vec::new();
        forward::with_scratch(|buf| {
            forward::forward_panel(
                self,
                Columns::Batch(std::slice::from_mut(state)),
                &[token],
                HeadMode::PerColumn,
                buf,
                &mut logits,
            )
        });
        logits
    }

    /// Batched autoregressive step: advance B independent sessions one
    /// token each, sharing every weight-matrix pass across the batch.
    ///
    /// `states[j]` and `tokens[j]` belong to session j; returns one
    /// logits vector per session, in order.  The elementwise WKV
    /// recurrence runs per session; the seven projections per block run
    /// as single [`matmul`]s over the `[B, d]` activation panel, so each
    /// weight matrix is streamed once per decode cycle instead of B
    /// times (§Perf L3-3).  Results are bit-exact with calling
    /// [`RwkvModel::step`] per session.
    pub fn step_batch(&self, states: &mut [State], tokens: &[u32]) -> Vec<Vec<f32>> {
        forward::with_scratch(|buf| {
            let mut flat = Vec::new();
            forward::forward_panel(
                self,
                Columns::Batch(states),
                tokens,
                HeadMode::PerColumn,
                buf,
                &mut flat,
            );
            flat.chunks(self.vocab).map(|c| c.to_vec()).collect()
        })
    }

    /// [`RwkvModel::step_batch`] writing one flat `[B * vocab]` logits
    /// panel into a caller-owned buffer — the allocation-free engine
    /// decode path (the panel is reused across decode cycles).
    pub fn step_batch_into(&self, states: &mut [State], tokens: &[u32], logits: &mut Vec<f32>) {
        forward::with_scratch(|buf| {
            forward::forward_panel(
                self,
                Columns::Batch(states),
                tokens,
                HeadMode::PerColumn,
                buf,
                logits,
            )
        });
    }

    /// Sequence-parallel chunked prefill: consume `tokens` (a slice of
    /// the prompt), leaving `state` exactly as T calls to
    /// [`RwkvModel::step`] would, and return the logits of the LAST
    /// token of the chunk.
    ///
    /// The chunk is laid out as a `[T, d]` sequence panel through the
    /// generic walk: per block, each of the seven weight matrices runs
    /// as ONE [`matmul`] over all T token columns (§Perf L3-4 weight
    /// reuse), while token shift and the WKV recurrence — the only
    /// sequential parts of RWKV's dual formulation — run as cheap
    /// elementwise loops over t between the projections, and the head
    /// projects only the last token.  Per-column op order matches
    /// [`matvec`], so chunked prefill is bit-exact with token-by-token
    /// prefill at any T.  Callers bound T (the serving layer feeds
    /// 32–128-token chunks) to bound per-cycle latency and scratch.
    pub fn prefill_chunk(&self, state: &mut State, tokens: &[u32]) -> Vec<f32> {
        let mut logits = Vec::new();
        forward::with_scratch(|buf| {
            forward::forward_panel(
                self,
                Columns::Seq(state),
                tokens,
                HeadMode::LastColumn,
                buf,
                &mut logits,
            )
        });
        logits
    }

    /// Log-softmax of logits (for scoring).
    pub fn log_softmax(logits: &[f32]) -> Vec<f32> {
        let max = logits.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
        let lse = logits.iter().map(|&v| (v - max).exp()).sum::<f32>().ln() + max;
        logits.iter().map(|&v| v - lse).collect()
    }
}

/// The exact-numerics backend (§5.2 software rows): plain f32 LayerNorm,
/// exp, sigmoid and division; the f32 weight matrices; optional uniform
/// activation fake-quant ([`RwkvModel::act_bits`], the "A9" half of the
/// W9A9 protocol) at every site except the residual — the hardware
/// datapath's extra residual re-quantization has no software-row analog.
impl Numerics for RwkvModel {
    fn n_layer(&self) -> usize {
        self.n_layer
    }

    fn d(&self) -> usize {
        self.d
    }

    fn f(&self) -> usize {
        self.f
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn block(&self, l: usize) -> &Block {
        &self.blocks[l]
    }

    fn ln0(&self) -> (&[f32], &[f32]) {
        (&self.ln0_w, &self.ln0_b)
    }

    fn ln_out(&self) -> (&[f32], &[f32]) {
        (&self.ln_out_w, &self.ln_out_b)
    }

    fn embed(&self, tok: u32, out: &mut [f32]) {
        let d = self.d;
        out.copy_from_slice(&self.emb[tok as usize * d..(tok as usize + 1) * d]);
    }

    fn gemm(&self, l: usize, mat: MatId, xs: &[f32], out: &mut [f32], width: usize) {
        let w: &[f32] = match mat {
            MatId::AttKey => &self.blocks[l].att_key,
            MatId::AttValue => &self.blocks[l].att_value,
            MatId::AttReceptance => &self.blocks[l].att_receptance,
            MatId::AttOutput => &self.blocks[l].att_output,
            MatId::FfnKey => &self.blocks[l].ffn_key,
            MatId::FfnReceptance => &self.blocks[l].ffn_receptance,
            MatId::FfnValue => &self.blocks[l].ffn_value,
            MatId::Head => &self.head,
        };
        matmul(w, xs, out, width);
    }

    fn layernorm(&self, x: &[f32], w: &[f32], b: &[f32], out: &mut [f32]) {
        layernorm(x, w, b, out);
    }

    fn quant(&self, _l: usize, site: Site, xs: &mut [f32]) {
        if site != Site::Resid {
            act_quant(xs, self.act_bits);
        }
    }

    fn exp(&self, x: f32) -> f32 {
        x.exp()
    }

    fn sigmoid(&self, x: f32) -> f32 {
        sigmoid(x)
    }

    fn div(&self, num: f32, den: f32) -> f32 {
        num / den
    }
}

/// Deterministic random tiny models for tests and benches (no artifacts
/// required).  Kept out of `#[cfg(test)]` so integration tests and bench
/// binaries can use it.
pub mod testing {
    use super::*;

    /// A deterministic random tiny model.
    pub fn test_model(n_layer: usize, d: usize, f: usize, vocab: usize) -> RwkvModel {
        let mut rng = crate::Rng64::new(42);
        let mut randv = |n: usize, s: f32| -> Vec<f32> {
            (0..n).map(|_| rng.normal() as f32 * s).collect()
        };
        let blocks = (0..n_layer)
            .map(|_| Block {
                ln1_w: vec![1.0; d],
                ln1_b: vec![0.0; d],
                ln2_w: vec![1.0; d],
                ln2_b: vec![0.0; d],
                att_decay: (0..d).map(|i| -5.0 + 4.0 * i as f32 / d as f32).collect(),
                att_first: vec![0.3f32.ln(); d],
                att_mix_k: vec![0.5; d],
                att_mix_v: vec![0.5; d],
                att_mix_r: vec![0.5; d],
                att_key: randv(d * d, 0.08),
                att_value: randv(d * d, 0.08),
                att_receptance: randv(d * d, 0.08),
                att_output: randv(d * d, 0.04),
                ffn_mix_k: vec![0.5; d],
                ffn_mix_r: vec![0.5; d],
                ffn_key: randv(f * d, 0.08),
                ffn_receptance: randv(d * d, 0.08),
                ffn_value: randv(d * f, 0.03),
            })
            .collect();
        RwkvModel {
            n_layer,
            d,
            f,
            vocab,
            emb: randv(vocab * d, 0.02),
            ln0_w: vec![1.0; d],
            ln0_b: vec![0.0; d],
            blocks,
            ln_out_w: vec![1.0; d],
            ln_out_b: vec![0.0; d],
            head: randv(vocab * d, 0.02),
            act_bits: None,
        }
    }
}

#[cfg(test)]
pub mod tests {
    use super::*;
    pub use super::testing::test_model;

    #[test]
    fn step_produces_finite_logits() {
        let m = test_model(2, 32, 64, 50);
        let mut s = m.new_state();
        for t in 0..20 {
            let logits = m.step(&mut s, t % 50);
            assert_eq!(logits.len(), 50);
            assert!(logits.iter().all(|v| v.is_finite()), "t={t}");
        }
    }

    #[test]
    fn state_distinguishes_histories() {
        let m = test_model(2, 32, 64, 50);
        let mut s1 = m.new_state();
        let mut s2 = m.new_state();
        m.step(&mut s1, 3);
        m.step(&mut s2, 7);
        let l1 = m.step(&mut s1, 5);
        let l2 = m.step(&mut s2, 5);
        let diff: f32 = l1.iter().zip(&l2).map(|(a, b)| (a - b).abs()).fold(0.0, f32::max);
        assert!(diff > 1e-5);
    }

    #[test]
    fn deterministic_given_state() {
        let m = test_model(2, 32, 64, 50);
        let mut s1 = m.new_state();
        let mut s2 = m.new_state();
        let l1 = m.step(&mut s1, 9);
        let l2 = m.step(&mut s2, 9);
        assert_eq!(l1, l2);
        assert_eq!(s1, s2);
    }

    #[test]
    fn log_softmax_normalizes() {
        let lp = RwkvModel::log_softmax(&[1.0, 2.0, 3.0]);
        let total: f32 = lp.iter().map(|v| v.exp()).sum();
        assert!((total - 1.0).abs() < 1e-5);
    }

    #[test]
    fn quantize_matrices_changes_weights_not_vectors() {
        let mut m = test_model(1, 16, 32, 20);
        let decay = m.blocks[0].att_decay.clone();
        let key_before = m.blocks[0].att_key.clone();
        m.quantize_matrices(Scheme::Pot);
        assert_eq!(m.blocks[0].att_decay, decay);
        assert_ne!(m.blocks[0].att_key, key_before);
    }

    #[test]
    fn matmul_is_per_column_matvec() {
        // exercise the 4-column block, the remainder columns, and the
        // non-multiple-of-8 tail of the dot product
        let mut rng = crate::Rng64::new(9);
        for (m, l, b) in [(5, 12, 1), (7, 16, 3), (9, 19, 4), (11, 33, 7), (4, 8, 9)] {
            let w: Vec<f32> = (0..m * l).map(|_| rng.normal() as f32 * 0.2).collect();
            let xs: Vec<f32> = (0..b * l).map(|_| rng.normal() as f32 * 0.5).collect();
            let mut out = vec![0f32; b * m];
            matmul(&w, &xs, &mut out, b);
            let mut col = vec![0f32; m];
            for j in 0..b {
                matvec(&w, &xs[j * l..(j + 1) * l], &mut col);
                for r in 0..m {
                    assert_eq!(
                        out[j * m + r].to_bits(),
                        col[r].to_bits(),
                        "m={m} l={l} b={b} col {j} row {r}"
                    );
                }
            }
        }
    }

    #[test]
    fn step_batch_bitexact_with_step() {
        // d and f chosen to exercise the vector-tail paths too
        let m = test_model(2, 36, 52, 41);
        let b = 5;
        let mut seq: Vec<State> = (0..b).map(|_| m.new_state()).collect();
        let mut bat: Vec<State> = (0..b).map(|_| m.new_state()).collect();
        // diverge the histories before batching
        for j in 0..b {
            m.step(&mut seq[j], (j * 3 % 41) as u32);
            m.step(&mut bat[j], (j * 3 % 41) as u32);
        }
        for t in 0..8 {
            let tokens: Vec<u32> = (0..b).map(|j| ((t * 7 + j * 5) % 41) as u32).collect();
            let batch_logits = m.step_batch(&mut bat, &tokens);
            for j in 0..b {
                let seq_logits = m.step(&mut seq[j], tokens[j]);
                assert_eq!(seq_logits, batch_logits[j], "t={t} session {j}");
                assert_eq!(seq[j], bat[j], "t={t} session {j} state");
            }
        }
    }

    #[test]
    fn step_batch_quantized_activations_bitexact() {
        let mut m = test_model(2, 32, 64, 50);
        m.act_bits = Some(9);
        let b = 3;
        let mut seq: Vec<State> = (0..b).map(|_| m.new_state()).collect();
        let mut bat: Vec<State> = (0..b).map(|_| m.new_state()).collect();
        for t in 0..6 {
            let tokens: Vec<u32> = (0..b).map(|j| ((t * 11 + j * 17) % 50) as u32).collect();
            let batch_logits = m.step_batch(&mut bat, &tokens);
            for j in 0..b {
                let seq_logits = m.step(&mut seq[j], tokens[j]);
                assert_eq!(seq_logits, batch_logits[j], "t={t} session {j}");
            }
        }
    }

    #[test]
    fn prefill_chunk_bitexact_with_step_loop() {
        // d/f chosen to exercise the non-multiple-of-8 kernel tails
        let m = test_model(2, 36, 52, 41);
        for t_len in [1usize, 2, 7, 33] {
            let tokens: Vec<u32> = (0..t_len).map(|t| ((t * 13 + 5) % 41) as u32).collect();
            let mut s_step = m.new_state();
            let mut last = Vec::new();
            for &t in &tokens {
                last = m.step(&mut s_step, t);
            }
            let mut s_chunk = m.new_state();
            let chunk_logits = m.prefill_chunk(&mut s_chunk, &tokens);
            assert_eq!(last, chunk_logits, "T={t_len} logits");
            assert_eq!(s_step, s_chunk, "T={t_len} state");
        }
    }

    #[test]
    fn prefill_chunk_splits_are_bitexact() {
        // chunk boundaries must be invisible: 1×T == chunks of any split
        let m = test_model(2, 32, 64, 50);
        let tokens: Vec<u32> = (0..45).map(|t| ((t * 7 + 3) % 50) as u32).collect();
        let mut s_whole = m.new_state();
        let whole = m.prefill_chunk(&mut s_whole, &tokens);
        for split in [1usize, 8, 16, 44] {
            let mut s = m.new_state();
            let mut last = Vec::new();
            for c in tokens.chunks(split) {
                last = m.prefill_chunk(&mut s, c);
            }
            assert_eq!(whole, last, "split={split} logits");
            assert_eq!(s_whole, s, "split={split} state");
        }
    }

    #[test]
    fn prefill_chunk_quantized_activations_bitexact() {
        let mut m = test_model(2, 32, 64, 50);
        m.act_bits = Some(9);
        let tokens: Vec<u32> = (0..19).map(|t| ((t * 11 + 2) % 50) as u32).collect();
        let mut s_step = m.new_state();
        let mut last = Vec::new();
        for &t in &tokens {
            last = m.step(&mut s_step, t);
        }
        let mut s_chunk = m.new_state();
        let chunk_logits = m.prefill_chunk(&mut s_chunk, &tokens);
        assert_eq!(last, chunk_logits);
        assert_eq!(s_step, s_chunk);
    }

    #[test]
    #[should_panic(expected = "at least one token")]
    fn prefill_chunk_rejects_empty() {
        let m = test_model(1, 16, 32, 20);
        let mut s = m.new_state();
        m.prefill_chunk(&mut s, &[]);
    }

    #[test]
    fn step_batch_empty_is_empty() {
        let m = test_model(1, 16, 32, 20);
        let logits = m.step_batch(&mut [], &[]);
        assert!(logits.is_empty());
    }

    #[test]
    fn long_rollout_stays_finite() {
        let m = test_model(2, 32, 64, 50);
        let mut s = m.new_state();
        let mut tok = 1u32;
        for _ in 0..500 {
            let logits = m.step(&mut s, tok);
            tok = logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0 as u32;
            assert!(logits.iter().all(|v| v.is_finite()));
        }
    }
}
