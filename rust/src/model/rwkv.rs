//! RWKV-4 f32 forward pass — the Rust twin of the JAX `exact` variant
//! (`python/compile/model.py::step`).  Validated against the AOT HLO
//! executable in `rust/tests/golden_parity.rs`.

use anyhow::{bail, Result};

use super::weights::WeightFile;
use crate::quant::Scheme;

pub const PP_INIT: f32 = -1e30;

/// Recurrent state: per layer, 5 rows of d (att_x_prev, ffn_x_prev, aa,
/// bb, pp), flattened `[n_layer * 5 * d]` in the artifact layout.
#[derive(Clone, Debug, PartialEq)]
pub struct State {
    pub data: Vec<f32>,
    pub n_layer: usize,
    pub d: usize,
}

impl State {
    pub fn new(n_layer: usize, d: usize) -> State {
        let mut data = vec![0f32; n_layer * 5 * d];
        for l in 0..n_layer {
            for i in 0..d {
                data[(l * 5 + 4) * d + i] = PP_INIT;
            }
        }
        State { data, n_layer, d }
    }

    #[inline]
    pub fn row(&self, layer: usize, r: usize) -> &[f32] {
        let o = (layer * 5 + r) * self.d;
        &self.data[o..o + self.d]
    }

    #[inline]
    pub fn row_mut(&mut self, layer: usize, r: usize) -> &mut [f32] {
        let o = (layer * 5 + r) * self.d;
        &mut self.data[o..o + self.d]
    }
}

/// Per-layer parameters (slices into owned storage).
#[derive(Clone, Debug)]
pub struct Block {
    pub ln1_w: Vec<f32>,
    pub ln1_b: Vec<f32>,
    pub ln2_w: Vec<f32>,
    pub ln2_b: Vec<f32>,
    pub att_decay: Vec<f32>, // raw; effective w = -exp(raw)
    pub att_first: Vec<f32>,
    pub att_mix_k: Vec<f32>,
    pub att_mix_v: Vec<f32>,
    pub att_mix_r: Vec<f32>,
    pub att_key: Vec<f32>,        // [d, d]
    pub att_value: Vec<f32>,      // [d, d]
    pub att_receptance: Vec<f32>, // [d, d]
    pub att_output: Vec<f32>,     // [d, d]
    pub ffn_mix_k: Vec<f32>,
    pub ffn_mix_r: Vec<f32>,
    pub ffn_key: Vec<f32>,        // [f, d]
    pub ffn_receptance: Vec<f32>, // [d, d]
    pub ffn_value: Vec<f32>,      // [d, f]
}

/// The full model.
#[derive(Clone, Debug)]
pub struct RwkvModel {
    pub n_layer: usize,
    pub d: usize,
    pub f: usize,
    pub vocab: usize,
    pub emb: Vec<f32>, // [v, d]
    pub ln0_w: Vec<f32>,
    pub ln0_b: Vec<f32>,
    pub blocks: Vec<Block>,
    pub ln_out_w: Vec<f32>,
    pub ln_out_b: Vec<f32>,
    pub head: Vec<f32>, // [v, d]
    /// When set, every LayerNorm/projection output is quantized to this
    /// many bits at a dynamic per-vector scale — the "A9" half of the
    /// paper's W9A9 ablation protocol (§5.2).  None = f32 activations.
    pub act_bits: Option<u32>,
}

/// Quantize a vector in place at `bits` with dynamic max-abs scale.
#[inline]
pub fn act_quant(xs: &mut [f32], bits: Option<u32>) {
    let Some(bits) = bits else { return };
    let qmax = ((1i64 << (bits - 1)) - 1) as f32;
    let s = xs.iter().fold(0f32, |m, &x| m.max(x.abs()));
    if s == 0.0 {
        return;
    }
    for x in xs.iter_mut() {
        *x = (*x / s * qmax).round() * s / qmax;
    }
}

// ---------------------------------------------------------------------------
// primitive ops
// ---------------------------------------------------------------------------

pub fn layernorm(x: &[f32], w: &[f32], b: &[f32], out: &mut [f32]) {
    let d = x.len() as f32;
    let mu = x.iter().sum::<f32>() / d;
    let var = x.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / d;
    let inv = 1.0 / (var + 1e-5).sqrt();
    for i in 0..x.len() {
        out[i] = (x[i] - mu) * inv * w[i] + b[i];
    }
}

/// w[m,l] @ x[l] -> out[m]
///
/// Perf note (§Perf L3-1): the dot product runs 8 independent
/// accumulators so LLVM can vectorize — serial `acc += a*b` is an
/// ordered float reduction the compiler must not reassociate, which
/// capped the original version at ~1.7 GMAC/s.
pub fn matvec(w: &[f32], x: &[f32], out: &mut [f32]) {
    let l = x.len();
    debug_assert_eq!(w.len(), out.len() * l);
    for (r, o) in out.iter_mut().enumerate() {
        let row = &w[r * l..(r + 1) * l];
        let mut acc = [0f32; 8];
        let chunks = l / 8;
        for c in 0..chunks {
            let rb = &row[c * 8..c * 8 + 8];
            let xb = &x[c * 8..c * 8 + 8];
            for k in 0..8 {
                acc[k] += rb[k] * xb[k];
            }
        }
        let mut tail = 0f32;
        for k in chunks * 8..l {
            tail += row[k] * x[k];
        }
        *o = (acc[0] + acc[4]) + (acc[1] + acc[5]) + (acc[2] + acc[6]) + (acc[3] + acc[7]) + tail;
    }
}

#[inline]
fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

impl RwkvModel {
    /// Assemble from a loaded weight file (artifact naming convention).
    pub fn from_weights(wf: &WeightFile) -> Result<RwkvModel> {
        let meta = &wf.meta;
        let (n_layer, d, f, vocab) = match (
            meta.get("n_layer"),
            meta.get("d_model"),
            meta.get("d_ffn"),
            meta.get("vocab"),
        ) {
            (Some(n), Some(d), Some(f), Some(v)) => (
                n.as_usize()?,
                d.as_usize()?,
                f.as_usize()?,
                v.as_usize()?,
            ),
            _ => bail!("weight file missing model meta"),
        };
        let get = |name: &str| -> Result<Vec<f32>> { Ok(wf.get(name)?.data.clone()) };
        let mut blocks = Vec::with_capacity(n_layer);
        for i in 0..n_layer {
            let b = |suffix: &str| format!("blocks.{i}.{suffix}");
            blocks.push(Block {
                ln1_w: get(&b("ln1.weight"))?,
                ln1_b: get(&b("ln1.bias"))?,
                ln2_w: get(&b("ln2.weight"))?,
                ln2_b: get(&b("ln2.bias"))?,
                att_decay: get(&b("att.time_decay"))?,
                att_first: get(&b("att.time_first"))?,
                att_mix_k: get(&b("att.time_mix_k"))?,
                att_mix_v: get(&b("att.time_mix_v"))?,
                att_mix_r: get(&b("att.time_mix_r"))?,
                att_key: get(&b("att.key"))?,
                att_value: get(&b("att.value"))?,
                att_receptance: get(&b("att.receptance"))?,
                att_output: get(&b("att.output"))?,
                ffn_mix_k: get(&b("ffn.time_mix_k"))?,
                ffn_mix_r: get(&b("ffn.time_mix_r"))?,
                ffn_key: get(&b("ffn.key"))?,
                ffn_receptance: get(&b("ffn.receptance"))?,
                ffn_value: get(&b("ffn.value"))?,
            });
        }
        Ok(RwkvModel {
            n_layer,
            d,
            f,
            vocab,
            emb: get("emb")?,
            ln0_w: get("ln0.weight")?,
            ln0_b: get("ln0.bias")?,
            blocks,
            ln_out_w: get("ln_out.weight")?,
            ln_out_b: get("ln_out.bias")?,
            head: get("head")?,
            act_bits: None,
        })
    }

    pub fn new_state(&self) -> State {
        State::new(self.n_layer, self.d)
    }

    /// Fake-quantize every *matrix* weight under `scheme` (the Table 1
    /// protocol: vector/additive weights stay 9-bit-uniform ≈ lossless at
    /// f32, matching §3.2's mixed-precision split).
    pub fn quantize_matrices(&mut self, scheme: Scheme) {
        use crate::quant::fake_quant;
        fake_quant(&mut self.emb, scheme);
        fake_quant(&mut self.head, scheme);
        for b in &mut self.blocks {
            fake_quant(&mut b.att_key, scheme);
            fake_quant(&mut b.att_value, scheme);
            fake_quant(&mut b.att_receptance, scheme);
            fake_quant(&mut b.att_output, scheme);
            fake_quant(&mut b.ffn_key, scheme);
            fake_quant(&mut b.ffn_receptance, scheme);
            fake_quant(&mut b.ffn_value, scheme);
        }
    }

    /// One autoregressive step: returns logits, updates `state` in place.
    ///
    /// Perf note (§Perf L3-2): scratch buffers are reused via a
    /// thread-local (10 allocations/step otherwise — ~8% of a step on
    /// the tiny model).
    pub fn step(&self, state: &mut State, token: u32) -> Vec<f32> {
        SCRATCH.with(|cell| {
            let mut slot = cell.borrow_mut();
            let buf = match slot.as_mut() {
                Some(b) if b.fits(self.d, self.f) => slot.as_mut().unwrap(),
                _ => {
                    *slot = Some(Buffers::new(self.d, self.f));
                    slot.as_mut().unwrap()
                }
            };
            self.step_buf(state, token, buf)
        })
    }

    /// Step with caller-provided scratch (allocation-free hot path).
    pub fn step_buf(&self, state: &mut State, token: u32, buf: &mut Buffers) -> Vec<f32> {
        let d = self.d;
        let mut x = vec![0f32; d];
        // embedding + ln0
        let emb_row = &self.emb[token as usize * d..(token as usize + 1) * d];
        layernorm(emb_row, &self.ln0_w, &self.ln0_b, &mut x);

        for (l, blk) in self.blocks.iter().enumerate() {
            self.time_mixing(blk, l, &x, state, buf);
            for i in 0..d {
                x[i] += buf.dx[i];
            }
            self.channel_mixing(blk, l, &x, state, buf);
            for i in 0..d {
                x[i] += buf.dx[i];
            }
        }

        let mut xn = vec![0f32; d];
        layernorm(&x, &self.ln_out_w, &self.ln_out_b, &mut xn);
        let mut logits = vec![0f32; self.vocab];
        matvec(&self.head, &xn, &mut logits);
        logits
    }

    fn time_mixing(&self, blk: &Block, l: usize, x: &[f32], state: &mut State, buf: &mut Buffers) {
        let d = self.d;
        layernorm(x, &blk.ln1_w, &blk.ln1_b, &mut buf.xn);
        act_quant(&mut buf.xn, self.act_bits);
        {
            let xp = state.row(l, 0);
            for i in 0..d {
                buf.xk[i] = buf.xn[i] * blk.att_mix_k[i] + xp[i] * (1.0 - blk.att_mix_k[i]);
                buf.xv[i] = buf.xn[i] * blk.att_mix_v[i] + xp[i] * (1.0 - blk.att_mix_v[i]);
                buf.xr[i] = buf.xn[i] * blk.att_mix_r[i] + xp[i] * (1.0 - blk.att_mix_r[i]);
            }
        }
        state.row_mut(l, 0).copy_from_slice(&buf.xn);
        matvec(&blk.att_receptance, &buf.xr, &mut buf.r);
        matvec(&blk.att_key, &buf.xk, &mut buf.k);
        matvec(&blk.att_value, &buf.xv, &mut buf.v);
        act_quant(&mut buf.k, self.act_bits);
        act_quant(&mut buf.v, self.act_bits);

        for i in 0..d {
            let r = sigmoid(buf.r[i]);
            let (k, v) = (buf.k[i], buf.v[i]);
            let aa = state.row(l, 2)[i];
            let bb = state.row(l, 3)[i];
            let pp = state.row(l, 4)[i];
            let w_eff = -blk.att_decay[i].exp();
            let u = blk.att_first[i];

            // output branch
            let ww = u + k;
            let qq = pp.max(ww);
            let e1 = (pp - qq).exp();
            let e2 = (ww - qq).exp();
            let wkv = (e1 * aa + e2 * v) / (e1 * bb + e2);

            // state branch
            let ww = pp + w_eff;
            let qq = ww.max(k);
            let e1 = (ww - qq).exp();
            let e2 = (k - qq).exp();
            state.row_mut(l, 2)[i] = e1 * aa + e2 * v;
            state.row_mut(l, 3)[i] = e1 * bb + e2;
            state.row_mut(l, 4)[i] = qq;

            buf.gated_d[i] = r * wkv;
        }
        act_quant(&mut buf.gated_d, self.act_bits);
        matvec(&blk.att_output, &buf.gated_d, &mut buf.dx);
    }

    fn channel_mixing(&self, blk: &Block, l: usize, x: &[f32], state: &mut State, buf: &mut Buffers) {
        let d = self.d;
        layernorm(x, &blk.ln2_w, &blk.ln2_b, &mut buf.xn);
        act_quant(&mut buf.xn, self.act_bits);
        {
            let xp = state.row(l, 1);
            for i in 0..d {
                buf.xk[i] = buf.xn[i] * blk.ffn_mix_k[i] + xp[i] * (1.0 - blk.ffn_mix_k[i]);
                buf.xr[i] = buf.xn[i] * blk.ffn_mix_r[i] + xp[i] * (1.0 - blk.ffn_mix_r[i]);
            }
        }
        state.row_mut(l, 1).copy_from_slice(&buf.xn);
        matvec(&blk.ffn_receptance, &buf.xr, &mut buf.r);
        matvec(&blk.ffn_key, &buf.xk, &mut buf.kf);
        for v in buf.kf.iter_mut() {
            let relu = v.max(0.0);
            *v = relu * relu;
        }
        act_quant(&mut buf.kf, self.act_bits);
        matvec(&blk.ffn_value, &buf.kf, &mut buf.dx);
        for i in 0..d {
            buf.dx[i] *= sigmoid(buf.r[i]);
        }
    }

    /// Log-softmax of logits (for scoring).
    pub fn log_softmax(logits: &[f32]) -> Vec<f32> {
        let max = logits.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
        let lse = logits.iter().map(|&v| (v - max).exp()).sum::<f32>().ln() + max;
        logits.iter().map(|&v| v - lse).collect()
    }
}

thread_local! {
    static SCRATCH: std::cell::RefCell<Option<Buffers>> = const { std::cell::RefCell::new(None) };
}

/// Scratch buffers reused across steps (perf: no per-step allocation).
pub struct Buffers {
    xn: Vec<f32>,
    xk: Vec<f32>,
    xv: Vec<f32>,
    xr: Vec<f32>,
    r: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    kf: Vec<f32>,
    gated_d: Vec<f32>,
    dx: Vec<f32>,
}

impl Buffers {
    pub fn new(d: usize, f: usize) -> Buffers {
        Buffers {
            xn: vec![0.0; d],
            xk: vec![0.0; d],
            xv: vec![0.0; d],
            xr: vec![0.0; d],
            r: vec![0.0; d],
            k: vec![0.0; d],
            v: vec![0.0; d],
            kf: vec![0.0; f],
            gated_d: vec![0.0; d],
            dx: vec![0.0; d],
        }
    }

    fn fits(&self, d: usize, f: usize) -> bool {
        self.xn.len() == d && self.kf.len() == f
    }
}

/// Deterministic random tiny models for tests and benches (no artifacts
/// required).  Kept out of `#[cfg(test)]` so integration tests and bench
/// binaries can use it.
pub mod testing {
    use super::*;

    /// A deterministic random tiny model.
    pub fn test_model(n_layer: usize, d: usize, f: usize, vocab: usize) -> RwkvModel {
        let mut rng = crate::Rng64::new(42);
        let mut randv = |n: usize, s: f32| -> Vec<f32> {
            (0..n).map(|_| rng.normal() as f32 * s).collect()
        };
        let blocks = (0..n_layer)
            .map(|_| Block {
                ln1_w: vec![1.0; d],
                ln1_b: vec![0.0; d],
                ln2_w: vec![1.0; d],
                ln2_b: vec![0.0; d],
                att_decay: (0..d).map(|i| -5.0 + 4.0 * i as f32 / d as f32).collect(),
                att_first: vec![0.3f32.ln(); d],
                att_mix_k: vec![0.5; d],
                att_mix_v: vec![0.5; d],
                att_mix_r: vec![0.5; d],
                att_key: randv(d * d, 0.08),
                att_value: randv(d * d, 0.08),
                att_receptance: randv(d * d, 0.08),
                att_output: randv(d * d, 0.04),
                ffn_mix_k: vec![0.5; d],
                ffn_mix_r: vec![0.5; d],
                ffn_key: randv(f * d, 0.08),
                ffn_receptance: randv(d * d, 0.08),
                ffn_value: randv(d * f, 0.03),
            })
            .collect();
        RwkvModel {
            n_layer,
            d,
            f,
            vocab,
            emb: randv(vocab * d, 0.02),
            ln0_w: vec![1.0; d],
            ln0_b: vec![0.0; d],
            blocks,
            ln_out_w: vec![1.0; d],
            ln_out_b: vec![0.0; d],
            head: randv(vocab * d, 0.02),
            act_bits: None,
        }
    }
}

#[cfg(test)]
pub mod tests {
    use super::*;
    pub use super::testing::test_model;

    #[test]
    fn step_produces_finite_logits() {
        let m = test_model(2, 32, 64, 50);
        let mut s = m.new_state();
        for t in 0..20 {
            let logits = m.step(&mut s, t % 50);
            assert_eq!(logits.len(), 50);
            assert!(logits.iter().all(|v| v.is_finite()), "t={t}");
        }
    }

    #[test]
    fn state_distinguishes_histories() {
        let m = test_model(2, 32, 64, 50);
        let mut s1 = m.new_state();
        let mut s2 = m.new_state();
        m.step(&mut s1, 3);
        m.step(&mut s2, 7);
        let l1 = m.step(&mut s1, 5);
        let l2 = m.step(&mut s2, 5);
        let diff: f32 = l1.iter().zip(&l2).map(|(a, b)| (a - b).abs()).fold(0.0, f32::max);
        assert!(diff > 1e-5);
    }

    #[test]
    fn deterministic_given_state() {
        let m = test_model(2, 32, 64, 50);
        let mut s1 = m.new_state();
        let mut s2 = m.new_state();
        let l1 = m.step(&mut s1, 9);
        let l2 = m.step(&mut s2, 9);
        assert_eq!(l1, l2);
        assert_eq!(s1, s2);
    }

    #[test]
    fn log_softmax_normalizes() {
        let lp = RwkvModel::log_softmax(&[1.0, 2.0, 3.0]);
        let total: f32 = lp.iter().map(|v| v.exp()).sum();
        assert!((total - 1.0).abs() < 1e-5);
    }

    #[test]
    fn quantize_matrices_changes_weights_not_vectors() {
        let mut m = test_model(1, 16, 32, 20);
        let decay = m.blocks[0].att_decay.clone();
        let key_before = m.blocks[0].att_key.clone();
        m.quantize_matrices(Scheme::Pot);
        assert_eq!(m.blocks[0].att_decay, decay);
        assert_ne!(m.blocks[0].att_key, key_before);
    }

    #[test]
    fn long_rollout_stays_finite() {
        let m = test_model(2, 32, 64, 50);
        let mut s = m.new_state();
        let mut tok = 1u32;
        for _ in 0..500 {
            let logits = m.step(&mut s, tok);
            tok = logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0 as u32;
            assert!(logits.iter().all(|v| v.is_finite()));
        }
    }
}
