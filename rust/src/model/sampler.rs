//! Token sampling: greedy / temperature / top-k, seeded and deterministic.

use crate::Rng64;

#[derive(Clone, Debug)]
pub struct Sampler {
    pub temperature: f32,
    pub top_k: usize,
    rng: Rng64,
}

impl Sampler {
    pub fn greedy() -> Sampler {
        Sampler { temperature: 0.0, top_k: 0, rng: Rng64::new(0) }
    }

    pub fn new(temperature: f32, top_k: usize, seed: u64) -> Sampler {
        Sampler { temperature, top_k, rng: Rng64::new(seed) }
    }

    /// Advance the RNG as if `n` tokens had already been sampled,
    /// without needing their logits.  [`Sampler::sample`] consumes
    /// exactly one `next_f64` draw per call at `temperature > 0` and
    /// none at all in greedy mode, so burning `n` draws reproduces the
    /// sampler state of a run that committed `n` tokens — the property
    /// the coordinator's transparent redrive relies on to continue a
    /// half-generated session bit-exactly after a worker crash.
    pub fn fast_forward(&mut self, n: usize) {
        if self.temperature <= 0.0 {
            return;
        }
        for _ in 0..n {
            self.rng.next_f64();
        }
    }

    /// Sample a token id from raw logits.
    pub fn sample(&mut self, logits: &[f32]) -> u32 {
        if self.temperature <= 0.0 {
            return argmax(logits) as u32;
        }
        // top-k filter — total_cmp: a NaN logit (misconfigured variant)
        // must not panic the scheduler thread mid-sort
        let mut idx: Vec<usize> = (0..logits.len()).collect();
        idx.sort_by(|&a, &b| logits[b].total_cmp(&logits[a]));
        let k = if self.top_k == 0 { logits.len() } else { self.top_k.min(logits.len()) };
        let kept = &idx[..k];
        // softmax over kept at temperature
        let max = kept.iter().map(|&i| logits[i]).fold(f32::NEG_INFINITY, f32::max);
        let mut probs: Vec<f64> = kept
            .iter()
            .map(|&i| (((logits[i] - max) / self.temperature) as f64).exp())
            .collect();
        let total: f64 = probs.iter().sum();
        for p in probs.iter_mut() {
            *p /= total;
        }
        let mut r = self.rng.next_f64();
        for (p, &i) in probs.iter().zip(kept) {
            if r < *p {
                return i as u32;
            }
            r -= *p;
        }
        *kept.last().unwrap() as u32
    }
}

/// Index of the largest element under IEEE total order (NaN-safe: a NaN
/// logit yields a deterministic index instead of panicking — NaN sorts
/// above every number, so callers still get *a* token and the serving
/// thread survives a numerically-broken model variant).
pub fn argmax(v: &[f32]) -> usize {
    v.iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_argmax() {
        let mut s = Sampler::greedy();
        assert_eq!(s.sample(&[0.1, 3.0, -1.0]), 1);
    }

    #[test]
    fn top_k_restricts_support() {
        let mut s = Sampler::new(1.0, 2, 7);
        let logits = [5.0f32, 4.9, -100.0, -100.0];
        for _ in 0..200 {
            let t = s.sample(&logits);
            assert!(t < 2, "{t}");
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let logits: Vec<f32> = (0..16).map(|i| (i as f32 * 0.3).sin()).collect();
        let mut a = Sampler::new(0.8, 8, 99);
        let mut b = Sampler::new(0.8, 8, 99);
        for _ in 0..50 {
            assert_eq!(a.sample(&logits), b.sample(&logits));
        }
    }

    #[test]
    fn temperature_zero_is_greedy_regardless_of_seed() {
        let logits = [1.0f32, 0.0, 2.0];
        for seed in 0..5 {
            let mut s = Sampler::new(0.0, 3, seed);
            assert_eq!(s.sample(&logits), 2);
        }
    }

    #[test]
    fn nan_logits_do_not_panic() {
        // finishes the PR-3 total_cmp sweep (util/bench, eval): greedy
        // argmax and the top-k sort both survive NaN logits
        let logits = [0.5f32, f32::NAN, 1.5, f32::NAN];
        let mut greedy = Sampler::greedy();
        let g = greedy.sample(&logits);
        assert!((g as usize) < logits.len());
        assert_eq!(g, greedy.sample(&logits), "NaN handling must be deterministic");
        let mut topk = Sampler::new(0.9, 2, 3);
        for _ in 0..50 {
            let t = topk.sample(&logits);
            assert!((t as usize) < logits.len());
        }
    }

    #[test]
    fn fast_forward_matches_sampling_n_tokens() {
        // the redrive contract: burning n draws == sampling n tokens,
        // for every temperature/top_k mode
        let logits: Vec<f32> = (0..24).map(|i| (i as f32 * 0.7).cos()).collect();
        for (t, k) in [(0.8f32, 8usize), (1.2, 0), (0.0, 0)] {
            let mut replayed = Sampler::new(t, k, 77);
            let mut resumed = Sampler::new(t, k, 77);
            for _ in 0..9 {
                replayed.sample(&logits);
            }
            resumed.fast_forward(9);
            for _ in 0..5 {
                assert_eq!(replayed.sample(&logits), resumed.sample(&logits));
            }
        }
    }

    #[test]
    fn distribution_roughly_follows_logits() {
        let mut s = Sampler::new(1.0, 0, 123);
        let logits = [2.0f32, 0.0];
        let n = 5000;
        let ones = (0..n).filter(|_| s.sample(&logits) == 0).count() as f64 / n as f64;
        // p(0) = e^2/(e^2+1) ≈ 0.881
        assert!((ones - 0.881).abs() < 0.03, "{ones}");
    }
}
