//! The ONE RWKV layer walk, generic over a numerics backend.
//!
//! The paper's accelerator executes a single datapath — the PE array
//! plus the EXP–σ and DIVU units — and merely swaps *numerics* between
//! the exact and the W9A9 hybrid-precision configurations (§3–§4).
//! This module is the software mirror of that fact: every execution
//! shape the crate serves is the same `[*, width]`-panel walk,
//!
//! * decode step        = a batch panel of width 1,
//! * batched decode     = a batch panel of width B ([`Columns::Batch`],
//!   one independent session state per column, §Perf L3-3 weight reuse),
//! * chunked prefill    = a sequence panel of width T ([`Columns::Seq`],
//!   one session state threaded through the columns in token order,
//!   §Perf L3-4 sequence parallelism),
//! * calibration        = a sequence panel driven by a site-observer
//!   backend that records activation maxima instead of quantizing,
//!
//! parameterized by a [`Numerics`] backend that supplies LayerNorm,
//! per-site activation quantization, exp/sigmoid, division, and the
//! weight-matrix set.  [`crate::model::RwkvModel`] implements the exact
//! backend (f32, optional uniform activation fake-quant — the Table 1
//! software rows); [`crate::model::HwModel`] implements the hardware
//! backend (Δ-PoT matrices, per-site 9-bit activations at calibrated
//! scales, EXP-LUT/PWL-σ/DIVU, ATAC LayerNorm — the "Proposed+HW" row).
//!
//! # Bit-exactness contract
//!
//! Per-column op order is identical across panel widths and modes: each
//! column of every [`Numerics::gemm`] call runs the exact
//! `rwkv::matvec` accumulation order (eight interleaved accumulators +
//! tail, reduced in a fixed tree), token shift reads the same values
//! whether they come from a carried state row (batch / first sequence
//! column) or the previous panel column (later sequence columns), and
//! the WKV recurrence body is written once.  Decode, batched decode and
//! chunked prefill are therefore bit-exact with each other on EVERY
//! backend — asserted in `rust/tests/batch_parity.rs`,
//! `rust/tests/prefill_parity.rs` and `rust/tests/forward_core.rs`
//! (which also anchors the walk against an independently written naive
//! reference forward).  Backends that store weights in a different
//! format (the packed Δ-PoT backend) uphold the same contract by
//! decoding to the identical f32 value grid inside their `gemm` and
//! accumulating in the identical order — `rust/tests/packed_parity.rs`
//! pins that at 0 ULP against a scalar oracle.

use super::rwkv::{Block, State};

/// Activation-quantization sites, one per hook point in the walk
/// (§3.2's W9A9 protocol quantizes activations entering each PE-array
/// pass plus the layer residual).  The exact backend applies its
/// optional uniform fake-quant at every site except [`Site::Resid`];
/// the hardware backend applies per-layer calibrated 9-bit quantization
/// at all of them; the calibration tap records per-site maxima.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Site {
    /// normed input of time mixing (after ln1)
    AttXn,
    /// key projection output
    AttK,
    /// value projection output
    AttV,
    /// r·wkv entering the output projection
    AttGated,
    /// normed input of channel mixing (after ln2)
    FfnXn,
    /// squared-ReLU FFN hidden entering the value projection
    FfnK2,
    /// layer output after the channel-mixing residual add
    Resid,
}

/// Names the eight weight-*matrix* planes the PE array consumes: the
/// seven per-layer projections plus the output head.  The walk hands
/// [`Numerics::gemm`] a `MatId` instead of a borrowed f32 slice so a
/// backend is free to store the plane however it likes — contiguous
/// f32 (exact), decoded Δ-PoT f32 (hw), or packed 16-bit Δ-PoT codes
/// consumed in-register (packed).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MatId {
    /// `att.key` `[d, d]`
    AttKey,
    /// `att.value` `[d, d]`
    AttValue,
    /// `att.receptance` `[d, d]`
    AttReceptance,
    /// `att.output` `[d, d]`
    AttOutput,
    /// `ffn.key` `[f, d]`
    FfnKey,
    /// `ffn.receptance` `[d, d]`
    FfnReceptance,
    /// `ffn.value` `[d, f]`
    FfnValue,
    /// output head `[vocab, d]` (layer index ignored)
    Head,
}

/// A numerics backend: everything the generic walk does not hard-code.
///
/// Model shape and the *vector* weights (LayerNorm affine, mix factors,
/// decay/first) come from [`Numerics::block`] and friends; the seven
/// per-layer matrices, the embedding and the head are consumed through
/// [`Numerics::gemm`] / [`Numerics::embed`] so a backend can substitute
/// quantized — or packed — copies and its own kernels; the five op
/// hooks select the elementwise arithmetic (exact f32 vs the integer
/// approximation units).
///
/// Hooks take `&self` so one walk invocation can interleave them
/// freely; backends that accumulate observability state (clip counters,
/// calibration maxima) use interior mutability.
pub trait Numerics {
    fn n_layer(&self) -> usize;
    fn d(&self) -> usize;
    fn f(&self) -> usize;
    fn vocab(&self) -> usize;

    /// Vector weights of layer `l` (shared storage with the f32 model).
    fn block(&self, l: usize) -> &Block;
    /// Embedding-LayerNorm affine (w, b).
    fn ln0(&self) -> (&[f32], &[f32]);
    /// Output-LayerNorm affine (w, b).
    fn ln_out(&self) -> (&[f32], &[f32]);
    /// Write embedding row `tok` (length `d`) into `out`.
    fn embed(&self, tok: u32, out: &mut [f32]);
    /// Matrix-panel multiply: `out[c] = mat · xs[c]` for each of
    /// `width` columns, where `mat` is plane `mat` of layer `l`
    /// (`l` is ignored for [`MatId::Head`]).  Every implementation
    /// MUST reproduce the `rwkv::matmul` per-column accumulation
    /// order bit-exactly — this is the seam the bit-exactness
    /// contract (module docs) rests on.  `width == 1` is the decode
    /// matvec.
    fn gemm(&self, l: usize, mat: MatId, xs: &[f32], out: &mut [f32], width: usize);

    /// LayerNorm `x → out` with affine (w, b).
    fn layernorm(&self, x: &[f32], w: &[f32], b: &[f32], out: &mut [f32]);
    /// Quantize (or observe) one activation vector at `site` of layer
    /// `l`, in place.
    fn quant(&self, l: usize, site: Site, xs: &mut [f32]);
    /// WKV exponential (callers only feed `x <= 0`, running-max form).
    fn exp(&self, x: f32) -> f32;
    fn sigmoid(&self, x: f32) -> f32;
    /// WKV division `num / den`.
    fn div(&self, num: f32, den: f32) -> f32;
}

/// How the panel's columns map onto recurrent state.
pub enum Columns<'a> {
    /// B independent sessions, one column each, advanced one token
    /// (batched decode; width 1 is the single autoregressive step).
    Batch(&'a mut [State]),
    /// One session, T token columns consumed in sequence order
    /// (chunked prefill / calibration).
    Seq(&'a mut State),
}

impl Columns<'_> {
    /// Token-shift source for column `c`: the previous token's normed
    /// activation — the per-session carried state row in batch mode; the
    /// previous panel column in sequence mode (the carried row for the
    /// chunk's first column).
    fn shift_src<'b>(
        &'b self,
        c: usize,
        l: usize,
        row: usize,
        xn: &'b [f32],
        d: usize,
    ) -> &'b [f32] {
        match self {
            Columns::Batch(states) => states[c].row(l, row),
            Columns::Seq(state) => {
                if c == 0 {
                    state.row(l, row)
                } else {
                    &xn[(c - 1) * d..c * d]
                }
            }
        }
    }
}

/// What the head projection runs over.
pub enum HeadMode {
    /// Logits for every column (decode: each session needs its sample).
    PerColumn,
    /// Logits for the last column only (prefill: earlier prompt columns'
    /// logits would be computed and thrown away).
    LastColumn,
    /// No head at all (calibration taps the layer stack only).
    Skip,
}

/// Scratch panels for the generic walk — the ONE scratch struct behind
/// every execution shape, sized by panel width on demand (so a single
/// thread-local serves width-1 decode, width-B batches and width-T
/// prefill chunks without per-call allocation).  Column `c` of a
/// `d`-stride panel lives at `p[c*d..(c+1)*d]` (`c*f` for the FFN
/// hidden).
pub struct ScratchPanels {
    pub(crate) x: Vec<f32>,
    pub(crate) xn: Vec<f32>,
    pub(crate) xk: Vec<f32>,
    pub(crate) xv: Vec<f32>,
    pub(crate) xr: Vec<f32>,
    pub(crate) r: Vec<f32>,
    pub(crate) k: Vec<f32>,
    pub(crate) v: Vec<f32>,
    pub(crate) kf: Vec<f32>,
    pub(crate) gated: Vec<f32>,
    pub(crate) dx: Vec<f32>,
    /// per-layer effective decay `-exp(att_decay)`, hoisted once per
    /// layer (the same f32 value every column would compute inline, so
    /// bit-exactness is untouched)
    pub(crate) w_eff: Vec<f32>,
}

impl ScratchPanels {
    pub fn new() -> ScratchPanels {
        ScratchPanels {
            x: Vec::new(),
            xn: Vec::new(),
            xk: Vec::new(),
            xv: Vec::new(),
            xr: Vec::new(),
            r: Vec::new(),
            k: Vec::new(),
            v: Vec::new(),
            kf: Vec::new(),
            gated: Vec::new(),
            dx: Vec::new(),
            w_eff: Vec::new(),
        }
    }

    /// Size every panel for a (d, f, width) walk.  Panels are pure
    /// outputs (fully written before any read each call), so when the
    /// size is already right this is free — no per-call re-zeroing.
    fn ensure(&mut self, d: usize, f: usize, width: usize) {
        for p in [
            &mut self.x,
            &mut self.xn,
            &mut self.xk,
            &mut self.xv,
            &mut self.xr,
            &mut self.r,
            &mut self.k,
            &mut self.v,
            &mut self.gated,
            &mut self.dx,
        ] {
            if p.len() != width * d {
                p.clear();
                p.resize(width * d, 0.0);
            }
        }
        if self.kf.len() != width * f {
            self.kf.clear();
            self.kf.resize(width * f, 0.0);
        }
        if self.w_eff.len() != d {
            self.w_eff.clear();
            self.w_eff.resize(d, 0.0);
        }
    }
}

impl Default for ScratchPanels {
    fn default() -> ScratchPanels {
        ScratchPanels::new()
    }
}

thread_local! {
    static SCRATCH: std::cell::RefCell<ScratchPanels> =
        std::cell::RefCell::new(ScratchPanels::new());
}

/// Run `f` with the thread-local scratch panels (perf: the walk itself
/// never allocates; see §Perf L3-2).  Not reentrant — the walk never
/// nests, and callers must not call back into a model forward from `f`.
pub fn with_scratch<R>(f: impl FnOnce(&mut ScratchPanels) -> R) -> R {
    SCRATCH.with(|cell| f(&mut cell.borrow_mut()))
}

/// Numeric health scan: true iff every float in the panel is finite
/// (no NaN, no ±Inf).  This is the hook the serving layer's health
/// guards run over logits panels and recurrent states after every
/// engine call ([`crate::coordinator::FaultPolicy`]), and the check the
/// state cache applies before a snapshot becomes resident — a single
/// non-finite value in an RWKV state poisons every token the session
/// will ever produce, so it must be caught at the panel boundary.
///
/// Branch-free accumulation (an f32 is non-finite iff its exponent
/// field is all ones) so the scan vectorizes; it is O(len) loads + one
/// `min` each, negligible next to the O(d²) walk that produced the
/// panel.
pub fn panel_all_finite(xs: &[f32]) -> bool {
    const EXP: u32 = 0x7f80_0000;
    xs.iter()
        .fold(u32::MAX, |acc, x| acc.min((x.to_bits() & EXP) ^ EXP))
        != 0
}

/// THE layer walk.  Consumes `tokens` (one per column), advances the
/// state(s) per `cols`, and writes logits into `logits` per `head`
/// (resized to `width * vocab` for [`HeadMode::PerColumn`], `vocab` for
/// [`HeadMode::LastColumn`], cleared for [`HeadMode::Skip`]).
///
/// See the module docs for the bit-exactness contract; per-column op
/// order is the original `rwkv::matvec` single-step order at every
/// width, in both column modes, on every backend.
pub fn forward_panel<N: Numerics>(
    nm: &N,
    cols: Columns,
    tokens: &[u32],
    head: HeadMode,
    buf: &mut ScratchPanels,
    logits: &mut Vec<f32>,
) {
    let mut cols = cols;
    let d = nm.d();
    let width = match &cols {
        Columns::Batch(states) => {
            assert_eq!(tokens.len(), states.len(), "one token per session");
            states.len()
        }
        Columns::Seq(_) => {
            assert!(!tokens.is_empty(), "prefill_chunk requires at least one token");
            tokens.len()
        }
    };
    if width == 0 {
        logits.clear();
        return;
    }
    buf.ensure(d, nm.f(), width);

    // embedding + ln0, per column (the xn panel is dead until layer 0's
    // time mixing, so it doubles as the raw-embedding scratch)
    {
        let (w0, b0) = nm.ln0();
        for (c, &tok) in tokens.iter().enumerate() {
            let o = c * d;
            nm.embed(tok, &mut buf.xn[o..o + d]);
            nm.layernorm(&buf.xn[o..o + d], w0, b0, &mut buf.x[o..o + d]);
        }
    }

    for l in 0..nm.n_layer() {
        time_mixing(nm, l, &mut cols, width, buf);
        for i in 0..width * d {
            buf.x[i] += buf.dx[i];
        }
        channel_mixing(nm, l, &mut cols, width, buf);
        for i in 0..width * d {
            buf.dx[i] = nm.sigmoid(buf.r[i]) * buf.dx[i];
            buf.x[i] += buf.dx[i];
        }
        for c in 0..width {
            let o = c * d;
            nm.quant(l, Site::Resid, &mut buf.x[o..o + d]);
        }
    }

    // head projection
    let (w, b) = nm.ln_out();
    let vocab = nm.vocab();
    match head {
        HeadMode::PerColumn => {
            for c in 0..width {
                let o = c * d;
                nm.layernorm(&buf.x[o..o + d], w, b, &mut buf.xn[o..o + d]);
            }
            if logits.len() != width * vocab {
                logits.clear();
                logits.resize(width * vocab, 0.0);
            }
            nm.gemm(0, MatId::Head, &buf.xn[..width * d], logits, width);
        }
        HeadMode::LastColumn => {
            let o = (width - 1) * d;
            nm.layernorm(&buf.x[o..o + d], w, b, &mut buf.xn[o..o + d]);
            if logits.len() != vocab {
                logits.clear();
                logits.resize(vocab, 0.0);
            }
            // width-1 gemm ≡ matvec (rwkv::matmul_is_per_column_matvec)
            nm.gemm(0, MatId::Head, &buf.xn[o..o + d], logits, 1);
        }
        HeadMode::Skip => logits.clear(),
    }
}

/// Time mixing over the panel: per column LayerNorm → quant → token
/// shift, then ONE [`Numerics::gemm`] per projection over all columns, with the
/// elementwise WKV recurrence between them.  Writes the attention
/// residual into `buf.dx`.
fn time_mixing<N: Numerics>(
    nm: &N,
    l: usize,
    cols: &mut Columns,
    width: usize,
    buf: &mut ScratchPanels,
) {
    let d = nm.d();
    let blk = nm.block(l);
    let ScratchPanels { x, xn, xk, xv, xr, r, k, v, gated, dx, w_eff, .. } = buf;

    for c in 0..width {
        let o = c * d;
        nm.layernorm(&x[o..o + d], &blk.ln1_w, &blk.ln1_b, &mut xn[o..o + d]);
        nm.quant(l, Site::AttXn, &mut xn[o..o + d]);
        {
            let xp = cols.shift_src(c, l, 0, xn, d);
            for i in 0..d {
                let xni = xn[o + i];
                xk[o + i] = xni * blk.att_mix_k[i] + xp[i] * (1.0 - blk.att_mix_k[i]);
                xv[o + i] = xni * blk.att_mix_v[i] + xp[i] * (1.0 - blk.att_mix_v[i]);
                xr[o + i] = xni * blk.att_mix_r[i] + xp[i] * (1.0 - blk.att_mix_r[i]);
            }
        }
        if let Columns::Batch(states) = cols {
            states[c].row_mut(l, 0).copy_from_slice(&xn[o..o + d]);
        }
    }
    if let Columns::Seq(state) = cols {
        let last = (width - 1) * d;
        state.row_mut(l, 0).copy_from_slice(&xn[last..last + d]);
    }

    nm.gemm(l, MatId::AttReceptance, xr, r, width);
    nm.gemm(l, MatId::AttKey, xk, k, width);
    nm.gemm(l, MatId::AttValue, xv, v, width);
    for c in 0..width {
        let o = c * d;
        nm.quant(l, Site::AttK, &mut k[o..o + d]);
        nm.quant(l, Site::AttV, &mut v[o..o + d]);
    }

    // effective decay is column-invariant: hoist it so the panel pays d
    // exp() calls per layer instead of width×d (same f32 value every
    // column, so bit-exactness is untouched)
    for i in 0..d {
        w_eff[i] = -blk.att_decay[i].exp();
    }

    // the WKV recurrence: per independent session column in batch mode,
    // sequentially through the shared state in sequence mode — the ONLY
    // place the two modes' state threading differs, and it differs by
    // which `State` each column resolves to, not by op order
    for c in 0..width {
        let o = c * d;
        let st: &mut State = match cols {
            Columns::Batch(states) => &mut states[c],
            Columns::Seq(state) => &mut **state,
        };
        for i in 0..d {
            let rr = nm.sigmoid(r[o + i]);
            let (ki, vi) = (k[o + i], v[o + i]);
            let aa = st.row(l, 2)[i];
            let bb = st.row(l, 3)[i];
            let pp = st.row(l, 4)[i];
            let u = blk.att_first[i];

            // output branch
            let ww = u + ki;
            let qq = pp.max(ww);
            let e1 = nm.exp(pp - qq);
            let e2 = nm.exp(ww - qq);
            let wkv = nm.div(e1 * aa + e2 * vi, e1 * bb + e2);

            // state branch
            let ww = pp + w_eff[i];
            let qq = ww.max(ki);
            let e1 = nm.exp(ww - qq);
            let e2 = nm.exp(ki - qq);
            st.row_mut(l, 2)[i] = e1 * aa + e2 * vi;
            st.row_mut(l, 3)[i] = e1 * bb + e2;
            st.row_mut(l, 4)[i] = qq;

            gated[o + i] = rr * wkv;
        }
        nm.quant(l, Site::AttGated, &mut gated[o..o + d]);
    }
    nm.gemm(l, MatId::AttOutput, gated, dx, width);
}

/// Channel mixing over the panel — same structure as [`time_mixing`]
/// with the FFN weights and the single-row token shift.  Writes the
/// pre-gate FFN residual into `buf.dx`; the caller applies the
/// receptance sigmoid gate and the residual add (one fused elementwise
/// pass in [`forward_panel`]).
fn channel_mixing<N: Numerics>(
    nm: &N,
    l: usize,
    cols: &mut Columns,
    width: usize,
    buf: &mut ScratchPanels,
) {
    let d = nm.d();
    let f = nm.f();
    let blk = nm.block(l);
    let ScratchPanels { x, xn, xk, xr, r, kf, dx, .. } = buf;

    for c in 0..width {
        let o = c * d;
        nm.layernorm(&x[o..o + d], &blk.ln2_w, &blk.ln2_b, &mut xn[o..o + d]);
        nm.quant(l, Site::FfnXn, &mut xn[o..o + d]);
        {
            let xp = cols.shift_src(c, l, 1, xn, d);
            for i in 0..d {
                let xni = xn[o + i];
                xk[o + i] = xni * blk.ffn_mix_k[i] + xp[i] * (1.0 - blk.ffn_mix_k[i]);
                xr[o + i] = xni * blk.ffn_mix_r[i] + xp[i] * (1.0 - blk.ffn_mix_r[i]);
            }
        }
        if let Columns::Batch(states) = cols {
            states[c].row_mut(l, 1).copy_from_slice(&xn[o..o + d]);
        }
    }
    if let Columns::Seq(state) = cols {
        let last = (width - 1) * d;
        state.row_mut(l, 1).copy_from_slice(&xn[last..last + d]);
    }

    nm.gemm(l, MatId::FfnReceptance, xr, r, width);
    nm.gemm(l, MatId::FfnKey, xk, kf, width);
    for kv in kf.iter_mut() {
        let relu = kv.max(0.0);
        *kv = relu * relu;
    }
    for c in 0..width {
        let of = c * f;
        nm.quant(l, Site::FfnK2, &mut kf[of..of + f]);
    }
    nm.gemm(l, MatId::FfnValue, kf, dx, width);
}

#[cfg(test)]
mod tests {
    use super::panel_all_finite;

    #[test]
    fn finite_scan_accepts_normal_panels() {
        assert!(panel_all_finite(&[]));
        assert!(panel_all_finite(&[0.0, -0.0, 1.5, -3.25e20, f32::MIN_POSITIVE, f32::MAX]));
        // subnormals are finite
        assert!(panel_all_finite(&[1e-45, -1e-45]));
    }

    #[test]
    fn finite_scan_flags_every_non_finite_class() {
        for bad in [f32::NAN, -f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            let mut xs = vec![1.0f32; 65];
            assert!(panel_all_finite(&xs));
            for i in [0, 31, 64] {
                xs[i] = bad;
                assert!(!panel_all_finite(&xs), "missed {bad} at {i}");
                xs[i] = 1.0;
            }
        }
    }
}
