//! Packed Δ-PoT matmul kernels: the PE-array pass that consumes 9-bit
//! storage words directly instead of pre-decoded f32 planes.
//!
//! Two implementations of ONE arithmetic:
//!
//! * [`packed_gemm_ref`] — the scalar decode-on-the-fly oracle.  Per
//!   column it is literally `rwkv::matvec` with `row[k]` replaced by
//!   `lut[row[k]]` (the plane's 512-entry decode table): 8 interleaved
//!   accumulators, same multiply order (`weight * x`), same tail loop,
//!   same `reduce8` reduction tree.  Because the LUT holds exactly the
//!   values the hw backend's decoded planes hold, `packed_gemm_ref` is
//!   bit-identical to `rwkv::matmul` over the decoded plane.
//! * `gemm_avx2` — the AVX2 throughput kernel.  Weights decode
//!   in-register (exponent-field bit construction: `2^(1-dq0)` and
//!   `2^(1-dq0-dq1)` are built by shifting the biased exponent into
//!   place, zero-masked via `cmpeq`/`andnot`, summed with one exact
//!   `_mm256_add_ps`, scaled by γ with one `_mm256_mul_ps` — the same
//!   single rounding step the scalar `DpotCode::value` performs — and
//!   signed by XORing bit 8 of the word into the sign bit, which is
//!   exactly a ±1 multiply under IEEE sign-symmetric rounding).  Lane k
//!   of each SIMD accumulator is scalar accumulator `acc[k]`, and the
//!   final reduction extracts lanes and reuses the scalar `reduce8`
//!   expression — so the SIMD kernel is 0-ULP identical to the oracle,
//!   not merely close.  No FMA anywhere: explicit mul/add intrinsics are
//!   never contraction-fused by LLVM, while a fused multiply-add would
//!   round differently and break the parity contract.
//!
//! [`packed_gemm`] dispatches between them at runtime
//! (`is_x86_feature_detected!("avx2")`), so the same binary is correct
//! on any x86-64 and on non-x86 hosts; building with
//! `--features no-simd` forces the scalar path everywhere (the CI
//! matrix leg that keeps the fallback from rotting).
//!
//! `rust/tests/packed_parity.rs` pins SIMD == oracle at 0 ULP across
//! decode (w=1), batch (w∈2..8) and sequence-panel shapes, including
//! ragged non-multiple-of-8 inner dimensions.

use super::rwkv::reduce8;
use crate::quant::PackedPlane;

/// True when the AVX2 packed kernel will be used for [`packed_gemm`]
/// calls on this host (false on non-x86-64, on pre-AVX2 CPUs, and under
/// `--features no-simd`).
pub fn simd_active() -> bool {
    #[cfg(all(target_arch = "x86_64", not(feature = "no-simd")))]
    {
        std::arch::is_x86_feature_detected!("avx2")
    }
    #[cfg(not(all(target_arch = "x86_64", not(feature = "no-simd"))))]
    {
        false
    }
}

/// Packed-plane panel multiply: `out[j] = plane · xs[j]` for each of
/// `b` columns (`xs[j*cols..]`, `out[j*rows..]` — the same panel layout
/// as `rwkv::matmul`).  Runtime-dispatches to the AVX2 kernel or the
/// scalar oracle; both produce bit-identical panels.
pub fn packed_gemm(p: &PackedPlane, xs: &[f32], out: &mut [f32], b: usize) {
    if b == 0 {
        return;
    }
    check_panels(p, xs, out, b);
    #[cfg(all(target_arch = "x86_64", not(feature = "no-simd")))]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            // SAFETY: AVX2 presence just checked; panel shapes checked
            unsafe { avx2::gemm_avx2(p, xs, out, b) };
            return;
        }
    }
    packed_gemm_ref(p, xs, out, b);
}

/// The scalar decode-on-the-fly oracle (see module docs).  Public so
/// the parity suite and benches can pin the SIMD kernel against it.
pub fn packed_gemm_ref(p: &PackedPlane, xs: &[f32], out: &mut [f32], b: usize) {
    if b == 0 {
        return;
    }
    check_panels(p, xs, out, b);
    let (l, m) = (p.cols, p.rows);
    let lut: &[f32; 512] = p.lut[..512].try_into().expect("plane LUT is 512 entries");
    let chunks = l / 8;
    for r in 0..m {
        let row = &p.codes[r * l..(r + 1) * l];
        for j in 0..b {
            let x = &xs[j * l..(j + 1) * l];
            let mut acc = [0f32; 8];
            for c in 0..chunks {
                let o = c * 8;
                let rb = &row[o..o + 8];
                let xb = &x[o..o + 8];
                for k in 0..8 {
                    // 9-bit words: `as usize & 511` is a no-op on real
                    // planes but lets the compiler drop the bounds check
                    acc[k] += lut[rb[k] as usize & 511] * xb[k];
                }
            }
            let mut tail = 0f32;
            for k in chunks * 8..l {
                tail += lut[row[k] as usize & 511] * x[k];
            }
            out[j * m + r] = reduce8(acc, tail);
        }
    }
}

/// Shared hard asserts (the `b` parameter lets slice lengths disagree,
/// which would silently misindex in release builds — same rationale as
/// `rwkv::matmul`).
fn check_panels(p: &PackedPlane, xs: &[f32], out: &mut [f32], b: usize) {
    assert_eq!(xs.len(), b * p.cols, "xs must hold exactly b columns");
    assert_eq!(out.len(), b * p.rows, "out must hold exactly b columns");
    assert_eq!(p.codes.len(), p.rows * p.cols, "plane shape inconsistent");
    assert!(p.lut.len() >= 512, "plane LUT must cover all 9-bit words");
}

#[cfg(all(target_arch = "x86_64", not(feature = "no-simd")))]
mod avx2 {
    use super::super::rwkv::reduce8;
    use crate::quant::PackedPlane;
    use std::arch::x86_64::*;

    /// Decode 8 packed words to the plane's f32 value grid, in-register.
    ///
    /// Bit-exact with `lut[w]` for every word the encoder emits (the
    /// only divergence is non-canonical words with `dq0 == 0` and the
    /// sign bit set, which would decode to `-0.0` instead of `+0.0` —
    /// `DpotTensor::encode` never produces them; asserted exhaustively
    /// in the tests below).
    ///
    /// # Safety
    /// Caller must ensure AVX2 is available and `codes` points at 8
    /// readable u16s.
    #[inline(always)]
    unsafe fn decode8(codes: *const u16, gamma: __m256) -> __m256 {
        let raw = _mm_loadu_si128(codes as *const __m128i);
        let w = _mm256_cvtepu16_epi32(raw);
        let fmask = _mm256_set1_epi32(0xF);
        let dq0 = _mm256_and_si256(_mm256_srli_epi32::<4>(w), fmask);
        let dq1 = _mm256_and_si256(w, fmask);
        let zero = _mm256_setzero_si256();
        // 2^(1-dq0) has biased exponent 128 - dq0; build it directly in
        // the exponent field.  2^(1-dq0-dq1) has exponent >= 98, so both
        // terms are normal floats — no subnormal edge cases.
        let e0 = _mm256_sub_epi32(_mm256_set1_epi32(128), dq0);
        let p0 = _mm256_slli_epi32::<23>(e0);
        let p1 = _mm256_slli_epi32::<23>(_mm256_sub_epi32(e0, dq1));
        let z0 = _mm256_cmpeq_epi32(dq0, zero);
        let z1 = _mm256_cmpeq_epi32(dq1, zero);
        let p0 = _mm256_andnot_si256(z0, p0);
        let p1 = _mm256_andnot_si256(_mm256_or_si256(z0, z1), p1);
        // exact: p0 and p1 are powers of two at most 2^15 apart
        let mag = _mm256_add_ps(_mm256_castsi256_ps(p0), _mm256_castsi256_ps(p1));
        // the ONE rounding step, identical to the scalar `mag * gamma`
        let v = _mm256_mul_ps(mag, gamma);
        // word bit 8 (sign) -> f32 bit 31; XOR == multiply by ±1
        let sbit = _mm256_slli_epi32::<23>(_mm256_and_si256(w, _mm256_set1_epi32(0x100)));
        _mm256_xor_ps(v, _mm256_castsi256_ps(sbit))
    }

    /// Lane-extract reduction: lane k of `acc` is scalar accumulator
    /// `acc[k]`, reduced through the very same [`reduce8`] expression.
    #[inline(always)]
    unsafe fn reduce8_avx(acc: __m256, tail: f32) -> f32 {
        let mut a = [0f32; 8];
        _mm256_storeu_ps(a.as_mut_ptr(), acc);
        reduce8(a, tail)
    }

    /// One weight row dotted with `NC` panel columns starting at column
    /// `j`: each 8-word chunk decodes ONCE and multiplies into all `NC`
    /// columns' accumulators (the packed analog of `rwkv::matmul`'s
    /// weight-reuse blocking — here the amortized work is the decode,
    /// not just the load).
    ///
    /// Deliberately NOT `#[target_feature]` (const-generic fns and
    /// target_feature interact poorly across toolchains); `inline(always)`
    /// into the `#[target_feature(enable = "avx2")]` driver gives the
    /// intrinsics the right ISA at codegen.
    ///
    /// # Safety
    /// AVX2 must be available; `row.len() == l`; columns `j..j+NC` of
    /// `xs` must be in-bounds.
    #[inline(always)]
    unsafe fn dot_block<const NC: usize>(
        row: &[u16],
        lut: &[f32; 512],
        gamma: __m256,
        xs: &[f32],
        j: usize,
        l: usize,
    ) -> [f32; NC] {
        let chunks = l / 8;
        let mut acc = [_mm256_setzero_ps(); NC];
        for c in 0..chunks {
            let o = c * 8;
            let wv = decode8(row.as_ptr().add(o), gamma);
            for k in 0..NC {
                let xv = _mm256_loadu_ps(xs.as_ptr().add((j + k) * l + o));
                // mul order weight*x, matching matvec/the oracle
                acc[k] = _mm256_add_ps(acc[k], _mm256_mul_ps(wv, xv));
            }
        }
        let mut res = [0f32; NC];
        for k in 0..NC {
            let mut tail = 0f32;
            for i in chunks * 8..l {
                tail += lut[row[i] as usize & 511] * xs[(j + k) * l + i];
            }
            res[k] = reduce8_avx(acc[k], tail);
        }
        res
    }

    /// The AVX2 driver: weight rows outer, panel columns blocked 4-wide
    /// then singly (same shape as `rwkv::matmul`; per-column results are
    /// blocking-invariant, so this is a pure reuse choice).
    ///
    /// # Safety
    /// Caller must ensure AVX2 is available and panel shapes were
    /// checked (`check_panels` in the parent module).
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn gemm_avx2(p: &PackedPlane, xs: &[f32], out: &mut [f32], b: usize) {
        let (l, m) = (p.cols, p.rows);
        let lut: &[f32; 512] = p.lut[..512].try_into().expect("plane LUT is 512 entries");
        let gamma = _mm256_set1_ps(p.gamma);
        for r in 0..m {
            let row = &p.codes[r * l..(r + 1) * l];
            let mut j = 0usize;
            while j + 4 <= b {
                let res = dot_block::<4>(row, lut, gamma, xs, j, l);
                for k in 0..4 {
                    out[(j + k) * m + r] = res[k];
                }
                j += 4;
            }
            while j < b {
                let res = dot_block::<1>(row, lut, gamma, xs, j, l);
                out[j * m + r] = res[0];
                j += 1;
            }
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;
        use crate::quant::DpotTensor;

        /// Every canonical 9-bit word (the encoder never sets the sign
        /// bit when dq0 == 0) must decode in-register to exactly the
        /// LUT / `DpotCode::value` grid, across several scales.
        #[test]
        fn decode8_matches_lut_exhaustively() {
            if !std::arch::is_x86_feature_detected!("avx2") {
                eprintln!("skipping: no AVX2 on this host");
                return;
            }
            let canonical: Vec<u16> =
                (0..512u16).filter(|w| !((w >> 4) & 0xF == 0 && w >> 8 == 1)).collect();
            for gamma in [1.0f32, 0.37, 3.25e-3, 117.0] {
                let lut: Vec<f32> = (0..512u16)
                    .map(|w| crate::quant::DpotCode::unpack(w).value(gamma))
                    .collect();
                let g = unsafe { _mm256_set1_ps(gamma) };
                for chunk in canonical.chunks(8) {
                    let mut words = [0u16; 8];
                    words[..chunk.len()].copy_from_slice(chunk);
                    let mut got = [0f32; 8];
                    unsafe {
                        let v = decode8(words.as_ptr(), g);
                        _mm256_storeu_ps(got.as_mut_ptr(), v);
                    }
                    for (k, &w) in chunk.iter().enumerate() {
                        assert_eq!(
                            got[k].to_bits(),
                            lut[w as usize].to_bits(),
                            "word {w:#05x} gamma {gamma}: {} vs {}",
                            got[k],
                            lut[w as usize]
                        );
                    }
                }
            }
        }

        /// The SIMD driver must equal the scalar oracle bit-for-bit on
        /// ragged shapes (tail columns, tail inner dims).
        #[test]
        fn gemm_avx2_matches_oracle() {
            if !std::arch::is_x86_feature_detected!("avx2") {
                eprintln!("skipping: no AVX2 on this host");
                return;
            }
            let mut rng = crate::Rng64::new(21);
            for (m, l, b) in [(5, 12, 1), (7, 16, 3), (9, 19, 4), (11, 33, 7), (4, 8, 9)] {
                let w: Vec<f32> = (0..m * l).map(|_| rng.normal() as f32 * 0.2).collect();
                let p = crate::quant::PackedPlane::from_tensor(&DpotTensor::encode(&w, m, l));
                let xs: Vec<f32> = (0..b * l).map(|_| rng.normal() as f32 * 0.5).collect();
                let mut simd = vec![0f32; b * m];
                let mut oracle = vec![0f32; b * m];
                unsafe { gemm_avx2(&p, &xs, &mut simd, b) };
                super::super::packed_gemm_ref(&p, &xs, &mut oracle, b);
                for i in 0..b * m {
                    assert_eq!(
                        simd[i].to_bits(),
                        oracle[i].to_bits(),
                        "m={m} l={l} b={b} elem {i}"
                    );
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::rwkv::matmul;
    use crate::quant::DpotTensor;

    /// The oracle over packed codes must be bit-identical to the f32
    /// `matmul` over the decoded plane — this chains the packed backend
    /// into the existing exact/hw bit-exactness contract.
    #[test]
    fn oracle_matches_f32_matmul_over_decoded_plane() {
        let mut rng = crate::Rng64::new(33);
        for (m, l, b) in [(6, 8, 1), (5, 13, 2), (16, 24, 5), (3, 40, 8)] {
            let w: Vec<f32> = (0..m * l).map(|_| rng.normal() as f32 * 0.4).collect();
            let t = DpotTensor::encode(&w, m, l);
            let p = PackedPlane::from_tensor(&t);
            let dec = t.decode();
            let xs: Vec<f32> = (0..b * l).map(|_| rng.normal() as f32).collect();
            let mut packed = vec![0f32; b * m];
            let mut exact = vec![0f32; b * m];
            packed_gemm_ref(&p, &xs, &mut packed, b);
            matmul(&dec, &xs, &mut exact, b);
            for i in 0..b * m {
                assert_eq!(
                    packed[i].to_bits(),
                    exact[i].to_bits(),
                    "m={m} l={l} b={b} elem {i}"
                );
            }
        }
    }

    /// The runtime dispatcher must agree with the oracle whatever path
    /// it picked on this host.
    #[test]
    fn dispatcher_matches_oracle() {
        let mut rng = crate::Rng64::new(44);
        let (m, l, b) = (14, 29, 6);
        let w: Vec<f32> = (0..m * l).map(|_| rng.normal() as f32 * 0.3).collect();
        let p = PackedPlane::encode(&w, m, l);
        let xs: Vec<f32> = (0..b * l).map(|_| rng.normal() as f32).collect();
        let mut got = vec![0f32; b * m];
        let mut want = vec![0f32; b * m];
        packed_gemm(&p, &xs, &mut got, b);
        packed_gemm_ref(&p, &xs, &mut want, b);
        for i in 0..b * m {
            assert_eq!(got[i].to_bits(), want[i].to_bits(), "elem {i} (simd={})", simd_active());
        }
    }

    #[test]
    fn zero_width_panel_is_noop() {
        let p = PackedPlane::encode(&[0.5f32; 6], 2, 3);
        packed_gemm(&p, &[], &mut [], 0);
        packed_gemm_ref(&p, &[], &mut [], 0);
    }
}
