//! Packed-numerics RWKV backend: the SAME W9A9 value grid as
//! [`HwModel`], stored and executed the way the accelerator stores it —
//! 9-bit Δ-PoT words streamed straight into the matmul (§3.1's URAM
//! layout, replayed in software as the throughput configuration).
//!
//! [`HwModel`] decodes every Δ-PoT plane back to f32 at load, so it is
//! bit-faithful but strictly *slower* than the exact backend (same
//! traffic, extra elementwise units).  [`PackedModel`] keeps the planes
//! packed ([`PackedPlane`]: 2 bytes/weight instead of 4) and runs the
//! AVX2 decode-in-register kernels ([`crate::model::packed_gemm`]) —
//! halving weight traffic per decode cycle, which is exactly the
//! paper's memory-bottleneck argument (§Perf L3-3) replayed in
//! software.  `rust/benches/quant_serve.rs` asserts the resulting
//! tokens/sec beat the exact f32 backend at equal batch.
//!
//! Construction shares [`HwModel`]'s pipeline verbatim (same vector
//! quantization, same calibration walk, same scale resolution — the
//! `pub(crate)` helpers in `rwkv_hw`), and every elementwise hook runs
//! the same integer units, so PackedModel logits and states are
//! BIT-IDENTICAL to HwModel's (`rust/tests/packed_parity.rs`): one
//! value grid, two storage formats, and only the fast one streams
//! half the bytes.

use std::cell::Cell;

use super::forward::{self, Columns, HeadMode, MatId, Numerics, Site};
use super::packed_gemm::packed_gemm;
use super::rwkv::{Block, RwkvModel, State};
use super::rwkv_hw::{
    hw_div, hw_exp, hw_layernorm, hw_sigmoid, quant9, quantize_vector_weights,
    resolve_layer_scales, HwModel, LayerScales,
};
use crate::arith::{Divu, ExpSigmoidUnit};
use crate::quant::PackedPlane;

/// The seven per-layer packed weight planes.
struct PackedBlock {
    att_key: PackedPlane,
    att_value: PackedPlane,
    att_receptance: PackedPlane,
    att_output: PackedPlane,
    ffn_key: PackedPlane,
    ffn_receptance: PackedPlane,
    ffn_value: PackedPlane,
}

/// The packed-numerics model (see module docs).
pub struct PackedModel {
    /// vector-quantized base (same transform as [`HwModel`]'s step 2)
    base: RwkvModel,
    blocks: Vec<PackedBlock>,
    emb: PackedPlane,
    head: PackedPlane,
    scales: Vec<LayerScales>,
    exps: ExpSigmoidUnit,
    divu: Divu,
    /// clips during the LAST forward call (see [`HwModel::clip_events`])
    pub clip_events: u64,
    clip_total: u64,
    clips: Cell<u64>,
}

impl PackedModel {
    /// Build from an f32 model; `calib_tokens` drives the activation
    /// scale calibration.  The pipeline is step-for-step [`HwModel::from_f32`]
    /// — matrices encoded from the ORIGINAL f32 weights (re-encoding
    /// decoded values would shift every plane's γ), then vector
    /// quantization, then calibration — so the two backends resolve
    /// identical scales and identical weight grids.
    pub fn from_f32(base: RwkvModel, calib_tokens: &[u32]) -> PackedModel {
        let d = base.d;
        let f = base.f;
        let v = base.vocab;
        // 1. encode every matrix in Δ-PoT and keep the PACKED codes
        let emb = PackedPlane::encode(&base.emb, v, d);
        let head = PackedPlane::encode(&base.head, v, d);
        let blocks = base
            .blocks
            .iter()
            .map(|b| PackedBlock {
                att_key: PackedPlane::encode(&b.att_key, d, d),
                att_value: PackedPlane::encode(&b.att_value, d, d),
                att_receptance: PackedPlane::encode(&b.att_receptance, d, d),
                att_output: PackedPlane::encode(&b.att_output, d, d),
                ffn_key: PackedPlane::encode(&b.ffn_key, f, d),
                ffn_receptance: PackedPlane::encode(&b.ffn_receptance, d, d),
                ffn_value: PackedPlane::encode(&b.ffn_value, d, f),
            })
            .collect();
        // 2. additive/vector weights: 9-bit uniform, in place
        let mut base = base;
        quantize_vector_weights(&mut base);
        // 3-4. calibrate and resolve per-layer activation scales
        let scales = resolve_layer_scales(&base, calib_tokens);

        PackedModel {
            base,
            blocks,
            emb,
            head,
            scales,
            exps: ExpSigmoidUnit::new(),
            divu: Divu::new(),
            clip_events: 0,
            clip_total: 0,
            clips: Cell::new(0),
        }
    }

    /// Build alongside an [`HwModel`] from one f32 model (convenience
    /// for parity tests and backend comparisons).
    pub fn with_hw_twin(base: RwkvModel, calib_tokens: &[u32]) -> (PackedModel, HwModel) {
        (
            PackedModel::from_f32(base.clone(), calib_tokens),
            HwModel::from_f32(base, calib_tokens),
        )
    }

    pub fn new_state(&self) -> State {
        self.base.new_state()
    }

    pub fn vocab(&self) -> usize {
        self.base.vocab
    }

    pub fn n_layer(&self) -> usize {
        self.base.n_layer
    }

    pub fn d(&self) -> usize {
        self.base.d
    }

    pub fn f(&self) -> usize {
        self.base.f
    }

    /// Per-layer calibrated activation scales.
    pub fn scales(&self) -> &[LayerScales] {
        &self.scales
    }

    /// Bytes of weight-plane traffic one full decode cycle streams (the
    /// seven layer matrices + the head; the embedding is a row gather,
    /// not a streamed plane): 2 bytes per packed weight, vs 4 on the
    /// f32 backends — the ~2× traffic cut `Metrics` surfaces.
    pub fn decode_cycle_weight_bytes(&self) -> u64 {
        let mut total = self.head.storage_bytes();
        for b in &self.blocks {
            total += b.att_key.storage_bytes()
                + b.att_value.storage_bytes()
                + b.att_receptance.storage_bytes()
                + b.att_output.storage_bytes()
                + b.ffn_key.storage_bytes()
                + b.ffn_receptance.storage_bytes()
                + b.ffn_value.storage_bytes();
        }
        total
    }

    /// Drain the cumulative 9-bit clip counter (see
    /// [`HwModel::take_clip_events`]).
    pub fn take_clip_events(&mut self) -> u64 {
        std::mem::take(&mut self.clip_total)
    }

    fn finish_clips(&mut self) {
        let c = self.clips.take();
        self.clip_events = c;
        self.clip_total += c;
    }

    /// One autoregressive step: a width-1 batch panel through the
    /// generic walk on the packed kernels.
    pub fn step(&mut self, state: &mut State, token: u32) -> Vec<f32> {
        let mut logits = Vec::new();
        forward::with_scratch(|buf| {
            forward::forward_panel(
                &*self,
                Columns::Batch(std::slice::from_mut(state)),
                &[token],
                HeadMode::PerColumn,
                buf,
                &mut logits,
            )
        });
        self.finish_clips();
        logits
    }

    /// Batched autoregressive step: B sessions share ONE packed-plane
    /// pass per matrix — each 8-word chunk is decoded once and feeds
    /// every column's accumulators, so the decode cost amortizes with
    /// batch exactly like the weight loads do.  Bit-exact with
    /// [`PackedModel::step`] per session at any B.
    pub fn step_batch(&mut self, states: &mut [State], tokens: &[u32]) -> Vec<Vec<f32>> {
        let mut flat = Vec::new();
        forward::with_scratch(|buf| {
            forward::forward_panel(
                &*self,
                Columns::Batch(states),
                tokens,
                HeadMode::PerColumn,
                buf,
                &mut flat,
            )
        });
        self.finish_clips();
        flat.chunks(self.base.vocab).map(|c| c.to_vec()).collect()
    }

    /// [`PackedModel::step_batch`] writing one flat `[B * vocab]`
    /// logits panel into a caller-owned buffer (the allocation-free
    /// engine decode path).
    pub fn step_batch_into(&mut self, states: &mut [State], tokens: &[u32], logits: &mut Vec<f32>) {
        forward::with_scratch(|buf| {
            forward::forward_panel(
                &*self,
                Columns::Batch(states),
                tokens,
                HeadMode::PerColumn,
                buf,
                logits,
            )
        });
        self.finish_clips();
    }

    /// Sequence-parallel chunked prefill on the packed kernels (§Perf
    /// L3-4): one packed pass per matrix per chunk, head on the last
    /// token only.  Bit-exact with T calls to [`PackedModel::step`].
    pub fn prefill_chunk(&mut self, state: &mut State, tokens: &[u32]) -> Vec<f32> {
        let mut logits = Vec::new();
        forward::with_scratch(|buf| {
            forward::forward_panel(
                &*self,
                Columns::Seq(state),
                tokens,
                HeadMode::LastColumn,
                buf,
                &mut logits,
            )
        });
        self.finish_clips();
        logits
    }
}

/// The packed-numerics backend hooks: identical elementwise arithmetic
/// to [`HwModel`] (shared free functions over the same integer units),
/// with `gemm` running on packed planes.
impl Numerics for PackedModel {
    fn n_layer(&self) -> usize {
        self.base.n_layer
    }

    fn d(&self) -> usize {
        self.base.d
    }

    fn f(&self) -> usize {
        self.base.f
    }

    fn vocab(&self) -> usize {
        self.base.vocab
    }

    fn block(&self, l: usize) -> &Block {
        &self.base.blocks[l]
    }

    fn ln0(&self) -> (&[f32], &[f32]) {
        (&self.base.ln0_w, &self.base.ln0_b)
    }

    fn ln_out(&self) -> (&[f32], &[f32]) {
        (&self.base.ln_out_w, &self.base.ln_out_b)
    }

    fn embed(&self, tok: u32, out: &mut [f32]) {
        // LUT row decode: bit-identical to the hw backend's decoded
        // embedding rows
        self.emb.decode_row(tok as usize, out);
    }

    fn gemm(&self, l: usize, mat: MatId, xs: &[f32], out: &mut [f32], width: usize) {
        let p: &PackedPlane = match mat {
            MatId::AttKey => &self.blocks[l].att_key,
            MatId::AttValue => &self.blocks[l].att_value,
            MatId::AttReceptance => &self.blocks[l].att_receptance,
            MatId::AttOutput => &self.blocks[l].att_output,
            MatId::FfnKey => &self.blocks[l].ffn_key,
            MatId::FfnReceptance => &self.blocks[l].ffn_receptance,
            MatId::FfnValue => &self.blocks[l].ffn_value,
            MatId::Head => &self.head,
        };
        packed_gemm(p, xs, out, width);
    }

    fn layernorm(&self, x: &[f32], w: &[f32], b: &[f32], out: &mut [f32]) {
        hw_layernorm(&self.divu, x, w, b, out);
    }

    fn quant(&self, l: usize, site: Site, xs: &mut [f32]) {
        let mut clips = 0u64;
        quant9(xs, self.scales[l].site(site), &mut clips);
        self.clips.set(self.clips.get() + clips);
    }

    fn exp(&self, x: f32) -> f32 {
        hw_exp(&self.exps, x)
    }

    fn sigmoid(&self, x: f32) -> f32 {
        hw_sigmoid(&self.exps, x)
    }

    fn div(&self, num: f32, den: f32) -> f32 {
        hw_div(&self.divu, num, den)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::rwkv::testing::test_model;

    fn calib_tokens() -> Vec<u32> {
        let mut rng = crate::Rng64::new(77);
        (0..128).map(|_| rng.below(50) as u32).collect()
    }

    #[test]
    fn packed_step_bitexact_with_hw() {
        let m = test_model(2, 32, 64, 50);
        let (mut pk, mut hw) = PackedModel::with_hw_twin(m, &calib_tokens());
        assert_eq!(pk.scales(), hw.scales(), "construction pipelines diverged");
        let mut sp = pk.new_state();
        let mut sh = hw.new_state();
        for t in 0..30 {
            let tok = (t * 7 % 50) as u32;
            let lp = pk.step(&mut sp, tok);
            let lh = hw.step(&mut sh, tok);
            for (i, (a, b)) in lp.iter().zip(&lh).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "t={t} logit {i}: {a} vs {b}");
            }
            assert_eq!(sp, sh, "t={t} state");
            assert_eq!(pk.clip_events, hw.clip_events, "t={t} clips");
        }
    }

    #[test]
    fn packed_long_rollout_stable() {
        let m = test_model(2, 32, 64, 50);
        let mut pk = PackedModel::from_f32(m, &calib_tokens());
        let mut s = pk.new_state();
        let mut tok = 1u32;
        for _ in 0..200 {
            let logits = pk.step(&mut s, tok);
            assert!(logits.iter().all(|v| v.is_finite()));
            tok = logits.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1)).unwrap().0 as u32;
        }
    }

    #[test]
    fn decode_cycle_weight_bytes_is_two_per_weight() {
        let (n_layer, d, f, vocab) = (2usize, 32usize, 64usize, 50usize);
        let pk = PackedModel::from_f32(test_model(n_layer, d, f, vocab), &calib_tokens());
        let weights = n_layer * (5 * d * d + 2 * d * f) + vocab * d;
        assert_eq!(pk.decode_cycle_weight_bytes(), weights as u64 * 2);
    }

    #[test]
    fn clip_total_accumulates_and_drains() {
        let m = test_model(1, 16, 32, 50);
        let mut pk = PackedModel::from_f32(m, &calib_tokens());
        let mut s = pk.new_state();
        let mut per_call = 0u64;
        for t in 0..8 {
            pk.step(&mut s, (t % 20) as u32);
            per_call += pk.clip_events;
        }
        assert_eq!(pk.take_clip_events(), per_call);
        assert_eq!(pk.take_clip_events(), 0);
    }
}
