//! Hardware-numerics RWKV forward: the full W9A9 + approximation stack
//! the accelerator executes (§3 + §4).
//!
//! * matrix weights   → Δ-PoT codes (values exactly realizable by the
//!   PMAC shift-add datapath; `quant::DpotTensor`)
//! * additive weights → 9-bit uniform symmetric
//! * activations      → 9-bit uniform at per-site scales collected by a
//!   calibration pass (offline in the real flow, at construction here)
//! * exp / sigmoid    → the integer EXP–σ unit (256-entry LUT / eq 9 PWL)
//! * division         → the integer DIVU (LOD + 4×4-bit 2D-LUT)
//! * LayerNorm        → ATAC single-pass identity (eq 12) + DIVU
//!
//! This is the model whose accuracy the "Proposed+HW" Table 1 row
//! reports; the fake-quant-only rows run on the f32 forward instead.

use std::collections::HashMap;

use super::rwkv::{matmul, matvec, BatchBuffers, RwkvModel, State};
use crate::arith::{Divu, ExpSigmoidUnit};
use crate::quant::DpotTensor;

/// Per-site activation scale table: (layer, site) -> max-abs seen.
/// Used only during the calibration pass; the hot path reads the
/// resolved [`LayerScales`] instead.
type ScaleMap = HashMap<(usize, &'static str), f32>;

/// Per-layer activation scales, one field per quantization site,
/// resolved from the calibration [`ScaleMap`] at construction.  The old
/// hot path did a HashMap lookup per site per layer per step; this is a
/// direct indexed load (`self.scales[l].att_k`).
#[derive(Clone, Copy, Debug)]
struct LayerScales {
    att_xn: f32,
    att_k: f32,
    att_v: f32,
    att_gated: f32,
    ffn_xn: f32,
    ffn_k2: f32,
    resid: f32,
}

/// The hardware-numerics model.
pub struct HwModel {
    base: RwkvModel,
    /// decoded Δ-PoT matrices, same layout as the f32 ones
    q: QuantizedMats,
    scales: Vec<LayerScales>,
    exps: ExpSigmoidUnit,
    divu: Divu,
    /// count of activations that clipped at the 9-bit rails during the
    /// last step (observability; large values mean a bad calibration)
    pub clip_events: u64,
}

struct QuantizedMats {
    emb: Vec<f32>,
    head: Vec<f32>,
    blocks: Vec<QBlock>,
}

struct QBlock {
    att_key: Vec<f32>,
    att_value: Vec<f32>,
    att_receptance: Vec<f32>,
    att_output: Vec<f32>,
    ffn_key: Vec<f32>,
    ffn_receptance: Vec<f32>,
    ffn_value: Vec<f32>,
}

fn dpot_decode_all(w: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    DpotTensor::encode(w, rows, cols).decode()
}

fn quant9(xs: &mut [f32], scale: f32, clips: &mut u64) {
    let qmax = 255.0f32;
    let s = scale.max(1e-12);
    for x in xs.iter_mut() {
        let q = (*x / s * qmax).round();
        if q.abs() > qmax {
            *clips += 1;
        }
        *x = q.clamp(-qmax, qmax) * s / qmax;
    }
}

impl HwModel {
    /// Build from an f32 model; `calib_tokens` drives the activation-scale
    /// calibration pass (a slice of the training stream in the real flow).
    pub fn from_f32(base: RwkvModel, calib_tokens: &[u32]) -> HwModel {
        let d = base.d;
        let f = base.f;
        let v = base.vocab;
        // 1. encode every matrix in Δ-PoT and keep the realized values
        let q = QuantizedMats {
            emb: dpot_decode_all(&base.emb, v, d),
            head: dpot_decode_all(&base.head, v, d),
            blocks: base
                .blocks
                .iter()
                .map(|b| QBlock {
                    att_key: dpot_decode_all(&b.att_key, d, d),
                    att_value: dpot_decode_all(&b.att_value, d, d),
                    att_receptance: dpot_decode_all(&b.att_receptance, d, d),
                    att_output: dpot_decode_all(&b.att_output, d, d),
                    ffn_key: dpot_decode_all(&b.ffn_key, f, d),
                    ffn_receptance: dpot_decode_all(&b.ffn_receptance, d, d),
                    ffn_value: dpot_decode_all(&b.ffn_value, d, f),
                })
                .collect(),
        };
        // 2. additive weights: 9-bit uniform (done by value, in place on
        //    the base copy so the HW forward reads quantized vectors)
        let mut base = base;
        let mut clips = 0u64;
        for b in &mut base.blocks {
            for v in [
                &mut b.att_first,
                &mut b.att_mix_k,
                &mut b.att_mix_v,
                &mut b.att_mix_r,
                &mut b.ffn_mix_k,
                &mut b.ffn_mix_r,
                &mut b.ln1_w,
                &mut b.ln1_b,
                &mut b.ln2_w,
                &mut b.ln2_b,
            ] {
                let s = v.iter().fold(0f32, |m, &x| m.max(x.abs()));
                quant9(v, s, &mut clips);
            }
            // decay is consumed as -exp(decay): quantize the raw value
            let s = b.att_decay.iter().fold(0f32, |m, &x| m.max(x.abs()));
            quant9(&mut b.att_decay, s, &mut clips);
        }

        // 3. calibration pass on the f32 path to collect per-site maxima
        let mut site_max = ScaleMap::new();
        {
            let probe = base.clone();
            let mut st = probe.new_state();
            let mut collector = |l: usize, site: &'static str, xs: &[f32]| {
                let m = xs.iter().fold(0f32, |a, &b| a.max(b.abs()));
                let e = site_max.entry((l, site)).or_insert(0.0);
                *e = e.max(m);
            };
            let mut x = vec![0f32; d];
            for &tok in calib_tokens.iter().take(512) {
                // replicate the forward, recording maxima at the
                // quantization sites (uses the f32 math — calibration
                // happens before quantization in the real flow too)
                probe_step(&probe, &mut st, tok, &mut x, &mut collector);
            }
            // safety margin
            for v in site_max.values_mut() {
                *v *= 1.1;
            }
        }
        // 4. resolve the site map into the per-layer struct the hot path
        //    indexes directly (4.0 = uncalibrated-site fallback)
        let site = |l: usize, name: &'static str| *site_max.get(&(l, name)).unwrap_or(&4.0);
        let scales: Vec<LayerScales> = (0..base.n_layer)
            .map(|l| LayerScales {
                att_xn: site(l, "att_xn"),
                att_k: site(l, "att_k"),
                att_v: site(l, "att_v"),
                att_gated: site(l, "att_gated"),
                ffn_xn: site(l, "ffn_xn"),
                ffn_k2: site(l, "ffn_k2"),
                resid: site(l, "resid"),
            })
            .collect();

        HwModel { base, q, scales, exps: ExpSigmoidUnit::new(), divu: Divu::new(), clip_events: 0 }
    }

    pub fn new_state(&self) -> State {
        self.base.new_state()
    }

    pub fn vocab(&self) -> usize {
        self.base.vocab
    }

    pub fn n_layer(&self) -> usize {
        self.base.n_layer
    }

    pub fn d(&self) -> usize {
        self.base.d
    }

    /// LayerNorm in the ATAC identity form with DIVU division.
    fn hw_layernorm(&self, x: &[f32], w: &[f32], b: &[f32], out: &mut [f32]) {
        let d = x.len() as f64;
        let s1: f64 = x.iter().map(|&v| v as f64).sum();
        let s2: f64 = x.iter().map(|&v| (v as f64) * (v as f64)).sum();
        let mu = s1 / d;
        let sigma = (s2 / d - mu * mu + 1e-5).max(1e-12).sqrt();
        for i in 0..x.len() {
            let num = x[i] as f64 - mu;
            let q = if num >= 0.0 {
                self.divu.div_f64(num, sigma, 12)
            } else {
                -self.divu.div_f64(-num, sigma, 12)
            };
            out[i] = (q as f32) * w[i] + b[i];
        }
    }

    #[inline]
    fn hw_exp(&self, x: f32) -> f32 {
        // WKV always feeds x <= 0 (running-max); clamp guards the domain
        self.exps.exp_f64(x.clamp(-60.0, 0.0) as f64) as f32
    }

    #[inline]
    fn hw_sigmoid(&self, x: f32) -> f32 {
        self.exps.sigmoid_f64(x as f64) as f32
    }

    #[inline]
    fn hw_div(&self, num: f32, den: f32) -> f32 {
        let s = if (num < 0.0) ^ (den < 0.0) { -1.0 } else { 1.0 };
        let n = num.abs().max(1e-9) as f64;
        let d = den.abs().max(1e-9) as f64;
        s * self.divu.div_f64(n, d, 12) as f32
    }

    /// One autoregressive step on the hardware datapath.
    pub fn step(&mut self, state: &mut State, token: u32) -> Vec<f32> {
        let d = self.base.d;
        let f = self.base.f;
        let mut clips = 0u64;
        let mut x = vec![0f32; d];
        let emb_row = &self.q.emb[token as usize * d..(token as usize + 1) * d];
        self.hw_layernorm(emb_row, &self.base.ln0_w, &self.base.ln0_b, &mut x);

        let mut xn = vec![0f32; d];
        let mut xk = vec![0f32; d];
        let mut xv = vec![0f32; d];
        let mut xr = vec![0f32; d];
        let mut r = vec![0f32; d];
        let mut k = vec![0f32; d];
        let mut v = vec![0f32; d];
        let mut kf = vec![0f32; f];
        let mut gated = vec![0f32; f.max(d)];
        let mut dx = vec![0f32; d];

        for l in 0..self.base.n_layer {
            let blk = &self.base.blocks[l];
            let qb = &self.q.blocks[l];
            let sc = self.scales[l];

            // ---- time mixing ------------------------------------------------
            self.hw_layernorm(&x, &blk.ln1_w, &blk.ln1_b, &mut xn);
            quant9(&mut xn, sc.att_xn, &mut clips);
            {
                let xp = state.row(l, 0);
                for i in 0..d {
                    xk[i] = xn[i] * blk.att_mix_k[i] + xp[i] * (1.0 - blk.att_mix_k[i]);
                    xv[i] = xn[i] * blk.att_mix_v[i] + xp[i] * (1.0 - blk.att_mix_v[i]);
                    xr[i] = xn[i] * blk.att_mix_r[i] + xp[i] * (1.0 - blk.att_mix_r[i]);
                }
            }
            state.row_mut(l, 0).copy_from_slice(&xn);
            matvec(&qb.att_receptance, &xr, &mut r);
            matvec(&qb.att_key, &xk, &mut k);
            matvec(&qb.att_value, &xv, &mut v);
            quant9(&mut k, sc.att_k, &mut clips);
            quant9(&mut v, sc.att_v, &mut clips);

            for i in 0..d {
                let rr = self.hw_sigmoid(r[i]);
                let aa = state.row(l, 2)[i];
                let bb = state.row(l, 3)[i];
                let pp = state.row(l, 4)[i];
                let w_eff = -blk.att_decay[i].exp();
                let u = blk.att_first[i];

                let ww = u + k[i];
                let qq = pp.max(ww);
                let e1 = self.hw_exp(pp - qq);
                let e2 = self.hw_exp(ww - qq);
                let wkv = self.hw_div(e1 * aa + e2 * v[i], e1 * bb + e2);

                let ww = pp + w_eff;
                let qq = ww.max(k[i]);
                let e1 = self.hw_exp(ww - qq);
                let e2 = self.hw_exp(k[i] - qq);
                state.row_mut(l, 2)[i] = e1 * aa + e2 * v[i];
                state.row_mut(l, 3)[i] = e1 * bb + e2;
                state.row_mut(l, 4)[i] = qq;
                gated[i] = rr * wkv;
            }
            quant9(&mut gated[..d], sc.att_gated, &mut clips);
            matvec(&qb.att_output, &gated[..d], &mut dx);
            for i in 0..d {
                x[i] += dx[i];
            }

            // ---- channel mixing ---------------------------------------------
            self.hw_layernorm(&x, &blk.ln2_w, &blk.ln2_b, &mut xn);
            quant9(&mut xn, sc.ffn_xn, &mut clips);
            {
                let xp = state.row(l, 1);
                for i in 0..d {
                    xk[i] = xn[i] * blk.ffn_mix_k[i] + xp[i] * (1.0 - blk.ffn_mix_k[i]);
                    xr[i] = xn[i] * blk.ffn_mix_r[i] + xp[i] * (1.0 - blk.ffn_mix_r[i]);
                }
            }
            state.row_mut(l, 1).copy_from_slice(&xn);
            matvec(&qb.ffn_receptance, &xr, &mut r);
            matvec(&qb.ffn_key, &xk, &mut kf);
            for kv in kf.iter_mut() {
                let relu = kv.max(0.0);
                *kv = relu * relu;
            }
            quant9(&mut kf, sc.ffn_k2, &mut clips);
            matvec(&qb.ffn_value, &kf, &mut dx);
            for i in 0..d {
                dx[i] = self.hw_sigmoid(r[i]) * dx[i];
            }
            for i in 0..d {
                x[i] += dx[i];
            }
            quant9(&mut x, sc.resid, &mut clips);
        }

        self.hw_layernorm(&x, &self.base.ln_out_w, &self.base.ln_out_b, &mut xn);
        let mut logits = vec![0f32; self.base.vocab];
        matvec(&self.q.head, &xn, &mut logits);
        self.clip_events = clips;
        logits
    }

    /// Batched autoregressive step on the hardware datapath: the B
    /// sessions share one [`matmul`] per Δ-PoT matrix (B-fold weight
    /// reuse, §Perf L3-3) while every per-site 9-bit quantization,
    /// LUT/PWL nonlinearity and the WKV recurrence run column-wise per
    /// session — so each column is bit-exact with [`HwModel::step`].
    /// `clip_events` afterwards holds the clip total across this call's
    /// whole batch (the same observability signal, aggregated).  Note:
    /// like the sequential [`HwModel::step`], each call overwrites the
    /// counter — if an engine splits one decode cycle into several
    /// variant groups, only the last group's total is visible.
    pub fn step_batch(&mut self, states: &mut [State], tokens: &[u32]) -> Vec<Vec<f32>> {
        HW_BATCH_SCRATCH.with(|cell| {
            let mut panels = cell.borrow_mut();
            self.step_batch_panels(states, tokens, &mut panels)
        })
    }

    fn step_batch_panels(
        &mut self,
        states: &mut [State],
        tokens: &[u32],
        panels: &mut BatchBuffers,
    ) -> Vec<Vec<f32>> {
        let b = states.len();
        assert_eq!(tokens.len(), b, "one token per session");
        if b == 0 {
            return Vec::new();
        }
        let d = self.base.d;
        let f = self.base.f;
        let mut clips = 0u64;
        panels.ensure(d, f, b);
        let BatchBuffers { x, xn, xk, xv, xr, r, k, v, kf, gated_d: gated, dx } = panels;

        for (j, &tok) in tokens.iter().enumerate() {
            let o = j * d;
            let emb_row = &self.q.emb[tok as usize * d..(tok as usize + 1) * d];
            self.hw_layernorm(emb_row, &self.base.ln0_w, &self.base.ln0_b, &mut x[o..o + d]);
        }

        for l in 0..self.base.n_layer {
            let blk = &self.base.blocks[l];
            let qb = &self.q.blocks[l];
            let sc = self.scales[l];

            // ---- time mixing --------------------------------------------
            for (j, st) in states.iter_mut().enumerate() {
                let o = j * d;
                self.hw_layernorm(&x[o..o + d], &blk.ln1_w, &blk.ln1_b, &mut xn[o..o + d]);
                quant9(&mut xn[o..o + d], sc.att_xn, &mut clips);
                {
                    let xp = st.row(l, 0);
                    for i in 0..d {
                        let xni = xn[o + i];
                        xk[o + i] = xni * blk.att_mix_k[i] + xp[i] * (1.0 - blk.att_mix_k[i]);
                        xv[o + i] = xni * blk.att_mix_v[i] + xp[i] * (1.0 - blk.att_mix_v[i]);
                        xr[o + i] = xni * blk.att_mix_r[i] + xp[i] * (1.0 - blk.att_mix_r[i]);
                    }
                }
                st.row_mut(l, 0).copy_from_slice(&xn[o..o + d]);
            }
            matmul(&qb.att_receptance, &xr, &mut *r, b);
            matmul(&qb.att_key, &xk, &mut *k, b);
            matmul(&qb.att_value, &xv, &mut *v, b);
            for j in 0..b {
                let o = j * d;
                quant9(&mut k[o..o + d], sc.att_k, &mut clips);
                quant9(&mut v[o..o + d], sc.att_v, &mut clips);
            }

            for (j, st) in states.iter_mut().enumerate() {
                let o = j * d;
                for i in 0..d {
                    let rr = self.hw_sigmoid(r[o + i]);
                    let aa = st.row(l, 2)[i];
                    let bb = st.row(l, 3)[i];
                    let pp = st.row(l, 4)[i];
                    let w_eff = -blk.att_decay[i].exp();
                    let u = blk.att_first[i];

                    let ww = u + k[o + i];
                    let qq = pp.max(ww);
                    let e1 = self.hw_exp(pp - qq);
                    let e2 = self.hw_exp(ww - qq);
                    let wkv = self.hw_div(e1 * aa + e2 * v[o + i], e1 * bb + e2);

                    let ww = pp + w_eff;
                    let qq = ww.max(k[o + i]);
                    let e1 = self.hw_exp(ww - qq);
                    let e2 = self.hw_exp(k[o + i] - qq);
                    st.row_mut(l, 2)[i] = e1 * aa + e2 * v[o + i];
                    st.row_mut(l, 3)[i] = e1 * bb + e2;
                    st.row_mut(l, 4)[i] = qq;
                    gated[o + i] = rr * wkv;
                }
                quant9(&mut gated[o..o + d], sc.att_gated, &mut clips);
            }
            matmul(&qb.att_output, &gated, &mut *dx, b);
            for i in 0..b * d {
                x[i] += dx[i];
            }

            // ---- channel mixing -----------------------------------------
            for (j, st) in states.iter_mut().enumerate() {
                let o = j * d;
                self.hw_layernorm(&x[o..o + d], &blk.ln2_w, &blk.ln2_b, &mut xn[o..o + d]);
                quant9(&mut xn[o..o + d], sc.ffn_xn, &mut clips);
                {
                    let xp = st.row(l, 1);
                    for i in 0..d {
                        let xni = xn[o + i];
                        xk[o + i] = xni * blk.ffn_mix_k[i] + xp[i] * (1.0 - blk.ffn_mix_k[i]);
                        xr[o + i] = xni * blk.ffn_mix_r[i] + xp[i] * (1.0 - blk.ffn_mix_r[i]);
                    }
                }
                st.row_mut(l, 1).copy_from_slice(&xn[o..o + d]);
            }
            matmul(&qb.ffn_receptance, &xr, &mut *r, b);
            matmul(&qb.ffn_key, &xk, &mut *kf, b);
            for kv in kf.iter_mut() {
                let relu = kv.max(0.0);
                *kv = relu * relu;
            }
            for j in 0..b {
                let of = j * f;
                quant9(&mut kf[of..of + f], sc.ffn_k2, &mut clips);
            }
            matmul(&qb.ffn_value, &kf, &mut *dx, b);
            for i in 0..b * d {
                dx[i] = self.hw_sigmoid(r[i]) * dx[i];
                x[i] += dx[i];
            }
            for j in 0..b {
                let o = j * d;
                quant9(&mut x[o..o + d], sc.resid, &mut clips);
            }
        }

        for j in 0..b {
            let o = j * d;
            let (w, bias) = (&self.base.ln_out_w, &self.base.ln_out_b);
            self.hw_layernorm(&x[o..o + d], w, bias, &mut xn[o..o + d]);
        }
        let mut logits = vec![0f32; b * self.base.vocab];
        matmul(&self.q.head, &xn, &mut logits, b);
        self.clip_events = clips;
        logits.chunks(self.base.vocab).map(|c| c.to_vec()).collect()
    }

    /// Sequence-parallel chunked prefill on the hardware datapath
    /// (§Perf L3-4): the chunk's T prompt tokens share ONE [`matmul`]
    /// per Δ-PoT matrix, while every per-site 9-bit quantization (at the
    /// same column-wise per-layer scales), LUT/PWL nonlinearity, token
    /// shift and the WKV recurrence run per token column in t order —
    /// bit-exact with T calls to [`HwModel::step`].  `clip_events`
    /// afterwards holds the clip total aggregated across the whole
    /// chunk (each call overwrites the counter, like the other steps).
    pub fn prefill_chunk(&mut self, state: &mut State, tokens: &[u32]) -> Vec<f32> {
        HW_BATCH_SCRATCH.with(|cell| {
            let mut panels = cell.borrow_mut();
            self.prefill_chunk_panels(state, tokens, &mut panels)
        })
    }

    fn prefill_chunk_panels(
        &mut self,
        state: &mut State,
        tokens: &[u32],
        panels: &mut BatchBuffers,
    ) -> Vec<f32> {
        let t_len = tokens.len();
        assert!(t_len > 0, "prefill_chunk requires at least one token");
        let d = self.base.d;
        let f = self.base.f;
        let mut clips = 0u64;
        panels.ensure(d, f, t_len);
        let BatchBuffers { x, xn, xk, xv, xr, r, k, v, kf, gated_d: gated, dx } = panels;

        for (t, &tok) in tokens.iter().enumerate() {
            let o = t * d;
            let emb_row = &self.q.emb[tok as usize * d..(tok as usize + 1) * d];
            self.hw_layernorm(emb_row, &self.base.ln0_w, &self.base.ln0_b, &mut x[o..o + d]);
        }

        for l in 0..self.base.n_layer {
            let blk = &self.base.blocks[l];
            let qb = &self.q.blocks[l];
            let sc = self.scales[l];

            // ---- time mixing --------------------------------------------
            for t in 0..t_len {
                let o = t * d;
                self.hw_layernorm(&x[o..o + d], &blk.ln1_w, &blk.ln1_b, &mut xn[o..o + d]);
                quant9(&mut xn[o..o + d], sc.att_xn, &mut clips);
                for i in 0..d {
                    let xni = xn[o + i];
                    // token shift: the previous token's normed column
                    // (the carried state row for the chunk's first token)
                    let xp = if t == 0 { state.row(l, 0)[i] } else { xn[o - d + i] };
                    xk[o + i] = xni * blk.att_mix_k[i] + xp * (1.0 - blk.att_mix_k[i]);
                    xv[o + i] = xni * blk.att_mix_v[i] + xp * (1.0 - blk.att_mix_v[i]);
                    xr[o + i] = xni * blk.att_mix_r[i] + xp * (1.0 - blk.att_mix_r[i]);
                }
            }
            let last = (t_len - 1) * d;
            state.row_mut(l, 0).copy_from_slice(&xn[last..last + d]);
            matmul(&qb.att_receptance, &xr, &mut *r, t_len);
            matmul(&qb.att_key, &xk, &mut *k, t_len);
            matmul(&qb.att_value, &xv, &mut *v, t_len);
            for t in 0..t_len {
                let o = t * d;
                quant9(&mut k[o..o + d], sc.att_k, &mut clips);
                quant9(&mut v[o..o + d], sc.att_v, &mut clips);
            }

            // sequential WKV recurrence, in token order.  −exp(decay) is
            // t-invariant: hoist it to d exp() calls per layer instead
            // of T×d (same f32 value each t → still bit-exact with step)
            let w_effs: Vec<f32> = blk.att_decay.iter().map(|&a| -a.exp()).collect();
            for t in 0..t_len {
                let o = t * d;
                for i in 0..d {
                    let rr = self.hw_sigmoid(r[o + i]);
                    let aa = state.row(l, 2)[i];
                    let bb = state.row(l, 3)[i];
                    let pp = state.row(l, 4)[i];
                    let w_eff = w_effs[i];
                    let u = blk.att_first[i];

                    let ww = u + k[o + i];
                    let qq = pp.max(ww);
                    let e1 = self.hw_exp(pp - qq);
                    let e2 = self.hw_exp(ww - qq);
                    let wkv = self.hw_div(e1 * aa + e2 * v[o + i], e1 * bb + e2);

                    let ww = pp + w_eff;
                    let qq = ww.max(k[o + i]);
                    let e1 = self.hw_exp(ww - qq);
                    let e2 = self.hw_exp(k[o + i] - qq);
                    state.row_mut(l, 2)[i] = e1 * aa + e2 * v[o + i];
                    state.row_mut(l, 3)[i] = e1 * bb + e2;
                    state.row_mut(l, 4)[i] = qq;
                    gated[o + i] = rr * wkv;
                }
                quant9(&mut gated[o..o + d], sc.att_gated, &mut clips);
            }
            matmul(&qb.att_output, &gated, &mut *dx, t_len);
            for i in 0..t_len * d {
                x[i] += dx[i];
            }

            // ---- channel mixing -----------------------------------------
            for t in 0..t_len {
                let o = t * d;
                self.hw_layernorm(&x[o..o + d], &blk.ln2_w, &blk.ln2_b, &mut xn[o..o + d]);
                quant9(&mut xn[o..o + d], sc.ffn_xn, &mut clips);
                for i in 0..d {
                    let xni = xn[o + i];
                    let xp = if t == 0 { state.row(l, 1)[i] } else { xn[o - d + i] };
                    xk[o + i] = xni * blk.ffn_mix_k[i] + xp * (1.0 - blk.ffn_mix_k[i]);
                    xr[o + i] = xni * blk.ffn_mix_r[i] + xp * (1.0 - blk.ffn_mix_r[i]);
                }
            }
            state.row_mut(l, 1).copy_from_slice(&xn[last..last + d]);
            matmul(&qb.ffn_receptance, &xr, &mut *r, t_len);
            matmul(&qb.ffn_key, &xk, &mut *kf, t_len);
            for kv in kf.iter_mut() {
                let relu = kv.max(0.0);
                *kv = relu * relu;
            }
            for t in 0..t_len {
                let of = t * f;
                quant9(&mut kf[of..of + f], sc.ffn_k2, &mut clips);
            }
            matmul(&qb.ffn_value, &kf, &mut *dx, t_len);
            for i in 0..t_len * d {
                dx[i] = self.hw_sigmoid(r[i]) * dx[i];
                x[i] += dx[i];
            }
            for t in 0..t_len {
                let o = t * d;
                quant9(&mut x[o..o + d], sc.resid, &mut clips);
            }
        }

        // head projection on the LAST token only
        let o = (t_len - 1) * d;
        let (w, bias) = (&self.base.ln_out_w, &self.base.ln_out_b);
        self.hw_layernorm(&x[o..o + d], w, bias, &mut xn[o..o + d]);
        let mut logits = vec![0f32; self.base.vocab];
        matvec(&self.q.head, &xn[o..o + d], &mut logits);
        self.clip_events = clips;
        logits
    }
}

thread_local! {
    // own thread-local (separate from rwkv's BATCH_SCRATCH, which is
    // private to that module) reusing the same panel struct
    static HW_BATCH_SCRATCH: std::cell::RefCell<BatchBuffers> =
        std::cell::RefCell::new(BatchBuffers::new());
}

/// Calibration probe: replicate the f32 forward, reporting activations at
/// every quantization site.
fn probe_step(
    m: &RwkvModel,
    state: &mut State,
    token: u32,
    x: &mut Vec<f32>,
    collect: &mut impl FnMut(usize, &'static str, &[f32]),
) {
    use super::rwkv::layernorm;
    let d = m.d;
    let f = m.f;
    let emb_row = &m.emb[token as usize * d..(token as usize + 1) * d];
    layernorm(emb_row, &m.ln0_w, &m.ln0_b, x);
    let mut xn = vec![0f32; d];
    let mut xk = vec![0f32; d];
    let mut xv = vec![0f32; d];
    let mut xr = vec![0f32; d];
    let mut r = vec![0f32; d];
    let mut k = vec![0f32; d];
    let mut v = vec![0f32; d];
    let mut kf = vec![0f32; f];
    let mut gated = vec![0f32; f.max(d)];
    let mut dx = vec![0f32; d];
    for l in 0..m.n_layer {
        let blk = &m.blocks[l];
        layernorm(x, &blk.ln1_w, &blk.ln1_b, &mut xn);
        collect(l, "att_xn", &xn);
        {
            let xp = state.row(l, 0);
            for i in 0..d {
                xk[i] = xn[i] * blk.att_mix_k[i] + xp[i] * (1.0 - blk.att_mix_k[i]);
                xv[i] = xn[i] * blk.att_mix_v[i] + xp[i] * (1.0 - blk.att_mix_v[i]);
                xr[i] = xn[i] * blk.att_mix_r[i] + xp[i] * (1.0 - blk.att_mix_r[i]);
            }
        }
        state.row_mut(l, 0).copy_from_slice(&xn);
        matvec(&blk.att_receptance, &xr, &mut r);
        matvec(&blk.att_key, &xk, &mut k);
        matvec(&blk.att_value, &xv, &mut v);
        collect(l, "att_k", &k);
        collect(l, "att_v", &v);
        for i in 0..d {
            let rr = 1.0 / (1.0 + (-r[i]).exp());
            let aa = state.row(l, 2)[i];
            let bb = state.row(l, 3)[i];
            let pp = state.row(l, 4)[i];
            let w_eff = -blk.att_decay[i].exp();
            let u = blk.att_first[i];
            let ww = u + k[i];
            let qq = pp.max(ww);
            let e1 = (pp - qq).exp();
            let e2 = (ww - qq).exp();
            let wkv = (e1 * aa + e2 * v[i]) / (e1 * bb + e2);
            let ww = pp + w_eff;
            let qq = ww.max(k[i]);
            let e1 = (ww - qq).exp();
            let e2 = (k[i] - qq).exp();
            state.row_mut(l, 2)[i] = e1 * aa + e2 * v[i];
            state.row_mut(l, 3)[i] = e1 * bb + e2;
            state.row_mut(l, 4)[i] = qq;
            gated[i] = rr * wkv;
        }
        collect(l, "att_gated", &gated[..d]);
        matvec(&blk.att_output, &gated[..d], &mut dx);
        for i in 0..d {
            x[i] += dx[i];
        }
        layernorm(x, &blk.ln2_w, &blk.ln2_b, &mut xn);
        collect(l, "ffn_xn", &xn);
        {
            let xp = state.row(l, 1);
            for i in 0..d {
                xk[i] = xn[i] * blk.ffn_mix_k[i] + xp[i] * (1.0 - blk.ffn_mix_k[i]);
                xr[i] = xn[i] * blk.ffn_mix_r[i] + xp[i] * (1.0 - blk.ffn_mix_r[i]);
            }
        }
        state.row_mut(l, 1).copy_from_slice(&xn);
        matvec(&blk.ffn_receptance, &xr, &mut r);
        matvec(&blk.ffn_key, &xk, &mut kf);
        for kv in kf.iter_mut() {
            let relu = kv.max(0.0);
            *kv = relu * relu;
        }
        collect(l, "ffn_k2", &kf);
        matvec(&blk.ffn_value, &kf, &mut dx);
        for i in 0..d {
            dx[i] *= 1.0 / (1.0 + (-r[i]).exp());
            x[i] += dx[i];
        }
        collect(l, "resid", x);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::rwkv::testing::test_model;

    fn calib_tokens() -> Vec<u32> {
        let mut rng = crate::Rng64::new(77);
        (0..128).map(|_| rng.below(50) as u32).collect()
    }

    #[test]
    fn hw_step_finite_and_close_to_f32() {
        let m = test_model(2, 32, 64, 50);
        let mut hw = HwModel::from_f32(m.clone(), &calib_tokens());
        let mut sf = m.new_state();
        let mut sh = hw.new_state();
        let mut max_rel = 0f32;
        for t in 0..30 {
            let tok = (t * 7 % 50) as u32;
            let lf = m.step(&mut sf, tok);
            let lh = hw.step(&mut sh, tok);
            assert!(lh.iter().all(|v| v.is_finite()));
            // compare top-1 agreement rather than absolute values: the
            // approximation stack shifts logits but should usually keep
            // the argmax
            let top_f = argmax(&lf);
            let top_h = argmax(&lh);
            let diff = lf
                .iter()
                .zip(&lh)
                .map(|(a, b)| (a - b).abs())
                .fold(0f32, f32::max);
            max_rel = max_rel.max(diff);
            let _ = (top_f, top_h);
        }
        // logit drift bounded (hardware error envelope, small random model)
        assert!(max_rel < 1.0, "{max_rel}");
    }

    fn argmax(v: &[f32]) -> usize {
        v.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0
    }

    #[test]
    fn clip_events_tracked_and_low() {
        let m = test_model(2, 32, 64, 50);
        let mut hw = HwModel::from_f32(m, &calib_tokens());
        let mut s = hw.new_state();
        let mut total = 0u64;
        for t in 0..20 {
            hw.step(&mut s, (t % 50) as u32);
            total += hw.clip_events;
        }
        // calibrated scales must keep clipping rare (< 1% of activations)
        let acts_per_step = 2 * 32 * 8; // rough
        assert!(total < (20 * acts_per_step) / 100, "{total}");
    }

    #[test]
    fn hw_prefill_chunk_bitexact_with_step_loop() {
        let m = test_model(2, 32, 64, 50);
        let calib = calib_tokens();
        let mut hw_step = HwModel::from_f32(m.clone(), &calib);
        let mut hw_chunk = HwModel::from_f32(m, &calib);
        for t_len in [1usize, 3, 17, 40] {
            let tokens: Vec<u32> = (0..t_len).map(|t| ((t * 7 + 1) % 50) as u32).collect();
            let mut s_step = hw_step.new_state();
            let mut last = Vec::new();
            let mut clips = 0u64;
            for &t in &tokens {
                last = hw_step.step(&mut s_step, t);
                clips += hw_step.clip_events;
            }
            let mut s_chunk = hw_chunk.new_state();
            let chunk_logits = hw_chunk.prefill_chunk(&mut s_chunk, &tokens);
            assert_eq!(last, chunk_logits, "T={t_len} logits");
            assert_eq!(s_step, s_chunk, "T={t_len} state");
            // clip observability: chunk total == sum of per-step counts
            assert_eq!(hw_chunk.clip_events, clips, "T={t_len} clip totals");
        }
    }

    #[test]
    fn hw_long_rollout_stable() {
        let m = test_model(2, 32, 64, 50);
        let mut hw = HwModel::from_f32(m, &calib_tokens());
        let mut s = hw.new_state();
        let mut tok = 1u32;
        for _ in 0..200 {
            let logits = hw.step(&mut s, tok);
            tok = argmax(&logits) as u32;
            assert!(logits.iter().all(|v| v.is_finite()));
        }
    }
}
