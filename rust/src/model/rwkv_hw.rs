//! Hardware-numerics RWKV backend: the full W9A9 + approximation stack
//! the accelerator executes (§3 + §4), plugged into the ONE generic
//! layer walk ([`crate::model::forward`]).
//!
//! * matrix weights   → Δ-PoT codes (values exactly realizable by the
//!   PMAC shift-add datapath; `quant::DpotTensor`)
//! * additive weights → 9-bit uniform symmetric
//! * activations      → 9-bit uniform at per-site scales collected by a
//!   calibration pass (offline in the real flow, at construction here)
//! * exp / sigmoid    → the integer EXP–σ unit (256-entry LUT / eq 9 PWL)
//! * division         → the integer DIVU (LOD + 4×4-bit 2D-LUT)
//! * LayerNorm        → ATAC single-pass identity (eq 12) + DIVU
//!
//! [`HwModel`] implements [`Numerics`], so every execution shape —
//! decode, batched decode, chunked prefill — is the same walk the exact
//! backend runs, with these hooks swapped in; there is no hand-copied
//! hardware forward.  The calibration pass is a site-observer backend
//! ([`Numerics::quant`] records maxima instead of rounding) over the
//! very same walk.
//!
//! This is the model whose accuracy the "Proposed+HW" Table 1 row
//! reports; the fake-quant-only rows run on the f32 backend instead.

use std::cell::{Cell, RefCell};
use std::collections::HashMap;

use super::forward::{self, Columns, HeadMode, MatId, Numerics, Site};
use super::rwkv::matmul;
use super::rwkv::{Block, RwkvModel, State};
use crate::arith::{Divu, ExpSigmoidUnit};
use crate::quant::DpotTensor;

/// Per-site activation maxima: (layer, site) -> max-abs seen.  Used only
/// during the calibration pass; the hot path reads the resolved
/// [`LayerScales`] instead.
type ScaleMap = HashMap<(usize, Site), f32>;

/// Per-layer activation scales, one field per quantization [`Site`],
/// resolved from the calibration site map at construction.  The old
/// hot path did a HashMap lookup per site per layer per step; this is a
/// direct indexed load.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LayerScales {
    pub att_xn: f32,
    pub att_k: f32,
    pub att_v: f32,
    pub att_gated: f32,
    pub ffn_xn: f32,
    pub ffn_k2: f32,
    pub resid: f32,
}

impl LayerScales {
    /// The scale for one quantization site.
    pub fn site(&self, s: Site) -> f32 {
        match s {
            Site::AttXn => self.att_xn,
            Site::AttK => self.att_k,
            Site::AttV => self.att_v,
            Site::AttGated => self.att_gated,
            Site::FfnXn => self.ffn_xn,
            Site::FfnK2 => self.ffn_k2,
            Site::Resid => self.resid,
        }
    }
}

/// Calibration sequence-chunk width (boundaries are invisible to the
/// recorded maxima — asserted in the tests below — so this only bounds
/// scratch memory).
const CALIB_CHUNK: usize = 128;

/// The hardware-numerics model.
pub struct HwModel {
    base: RwkvModel,
    /// decoded Δ-PoT matrices, same layout as the f32 ones
    q: QuantizedMats,
    scales: Vec<LayerScales>,
    exps: ExpSigmoidUnit,
    divu: Divu,
    /// count of activations that clipped at the 9-bit rails during the
    /// LAST forward call (observability; large values mean a bad
    /// calibration).  Each call overwrites this — engines that split a
    /// decode cycle into several calls should drain the lossless
    /// cumulative counter via [`HwModel::take_clip_events`] instead.
    pub clip_events: u64,
    /// cumulative clips since the last [`HwModel::take_clip_events`]
    clip_total: u64,
    /// in-flight counter the `Numerics::quant` hook bumps during a walk
    /// (`&self` there — interior mutability), folded into the two
    /// counters above when the wrapping call finishes
    clips: Cell<u64>,
}

struct QuantizedMats {
    emb: Vec<f32>,
    head: Vec<f32>,
    blocks: Vec<QBlock>,
}

struct QBlock {
    att_key: Vec<f32>,
    att_value: Vec<f32>,
    att_receptance: Vec<f32>,
    att_output: Vec<f32>,
    ffn_key: Vec<f32>,
    ffn_receptance: Vec<f32>,
    ffn_value: Vec<f32>,
}

fn dpot_decode_all(w: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    DpotTensor::encode(w, rows, cols).decode()
}

/// 9-bit uniform symmetric quantization at a fixed scale, counting rail
/// clips.  `pub(crate)` because the packed backend applies the very
/// same activation grid ([`crate::model::PackedModel`]).
pub(crate) fn quant9(xs: &mut [f32], scale: f32, clips: &mut u64) {
    let qmax = 255.0f32;
    let s = scale.max(1e-12);
    for x in xs.iter_mut() {
        let q = (*x / s * qmax).round();
        if q.abs() > qmax {
            *clips += 1;
        }
        *x = q.clamp(-qmax, qmax) * s / qmax;
    }
}

/// Calibration pass: drive the calib stream through the SAME generic
/// walk with the site-observer backend ([`CalibTap`] records max-abs at
/// every quantization site instead of rounding), in bounded sequence
/// chunks.  Chunk boundaries are invisible to the walk, so the maxima
/// are bit-identical to a token-by-token pass — i.e. exactly what the
/// pre-refactor hand-replayed calibration forward collected.  Returns
/// the per-site maxima with the 1.1 safety margin applied.
fn calibrate(base: &RwkvModel, calib_tokens: &[u32], chunk: usize) -> ScaleMap {
    let tap = CalibTap { m: base, site_max: RefCell::new(HashMap::new()) };
    let mut st = base.new_state();
    let mut sink = Vec::new();
    forward::with_scratch(|buf| {
        for c in calib_tokens.chunks(chunk.max(1)) {
            forward::forward_panel(&tap, Columns::Seq(&mut st), c, HeadMode::Skip, buf, &mut sink);
        }
    });
    let mut site_max = tap.site_max.into_inner();
    for v in site_max.values_mut() {
        *v *= 1.1;
    }
    site_max
}

/// Step 2 of the W9A9 construction pipeline: quantize the additive /
/// vector weights 9-bit uniform, in place on the base model, returning
/// the (discarded-by-convention) clip count.  Shared verbatim between
/// [`HwModel::from_f32`] and the packed backend so both resolve the
/// SAME quantized-vector model — any drift here would break their
/// bit-exact logit parity.
pub(crate) fn quantize_vector_weights(base: &mut RwkvModel) -> u64 {
    let mut clips = 0u64;
    for b in &mut base.blocks {
        for v in [
            &mut b.att_first,
            &mut b.att_mix_k,
            &mut b.att_mix_v,
            &mut b.att_mix_r,
            &mut b.ffn_mix_k,
            &mut b.ffn_mix_r,
            &mut b.ln1_w,
            &mut b.ln1_b,
            &mut b.ln2_w,
            &mut b.ln2_b,
        ] {
            let s = v.iter().fold(0f32, |m, &x| m.max(x.abs()));
            quant9(v, s, &mut clips);
        }
        // decay is consumed as -exp(decay): quantize the raw value
        let s = b.att_decay.iter().fold(0f32, |m, &x| m.max(x.abs()));
        quant9(&mut b.att_decay, s, &mut clips);
    }
    clips
}

/// Steps 3–4 of the construction pipeline: run the calibration tap over
/// (at most 512 tokens of) the calib stream and resolve the site map
/// into the per-layer scale structs the hot path indexes directly
/// (4.0 = uncalibrated-site fallback).  Shared between the hw and
/// packed backends — see [`quantize_vector_weights`].
pub(crate) fn resolve_layer_scales(base: &RwkvModel, calib_tokens: &[u32]) -> Vec<LayerScales> {
    let calib = &calib_tokens[..calib_tokens.len().min(512)];
    let site_max = calibrate(base, calib, CALIB_CHUNK);
    let site = |l: usize, s: Site| *site_max.get(&(l, s)).unwrap_or(&4.0);
    (0..base.n_layer)
        .map(|l| LayerScales {
            att_xn: site(l, Site::AttXn),
            att_k: site(l, Site::AttK),
            att_v: site(l, Site::AttV),
            att_gated: site(l, Site::AttGated),
            ffn_xn: site(l, Site::FfnXn),
            ffn_k2: site(l, Site::FfnK2),
            resid: site(l, Site::Resid),
        })
        .collect()
}

/// LayerNorm in the ATAC identity form with DIVU division — the §4 eq 12
/// single-pass form.  Free function over the unit so every hardware-grid
/// backend (hw, packed) shares ONE implementation.
pub(crate) fn hw_layernorm(divu: &Divu, x: &[f32], w: &[f32], b: &[f32], out: &mut [f32]) {
    let d = x.len() as f64;
    let s1: f64 = x.iter().map(|&v| v as f64).sum();
    let s2: f64 = x.iter().map(|&v| (v as f64) * (v as f64)).sum();
    let mu = s1 / d;
    let sigma = (s2 / d - mu * mu + 1e-5).max(1e-12).sqrt();
    for i in 0..x.len() {
        let num = x[i] as f64 - mu;
        let q = if num >= 0.0 {
            divu.div_f64(num, sigma, 12)
        } else {
            -divu.div_f64(-num, sigma, 12)
        };
        out[i] = (q as f32) * w[i] + b[i];
    }
}

/// The integer EXP unit. WKV always feeds `x <= 0` (running-max); the
/// clamp guards the domain.
#[inline]
pub(crate) fn hw_exp(exps: &ExpSigmoidUnit, x: f32) -> f32 {
    exps.exp_f64(x.clamp(-60.0, 0.0) as f64) as f32
}

/// The PWL sigmoid unit (§4 eq 9).
#[inline]
pub(crate) fn hw_sigmoid(exps: &ExpSigmoidUnit, x: f32) -> f32 {
    exps.sigmoid_f64(x as f64) as f32
}

/// DIVU division with sign split and denominator floor.
#[inline]
pub(crate) fn hw_div(divu: &Divu, num: f32, den: f32) -> f32 {
    let s = if (num < 0.0) ^ (den < 0.0) { -1.0 } else { 1.0 };
    let n = num.abs().max(1e-9) as f64;
    let d = den.abs().max(1e-9) as f64;
    s * divu.div_f64(n, d, 12) as f32
}

impl HwModel {
    /// Build from an f32 model; `calib_tokens` drives the activation-scale
    /// calibration pass (a slice of the training stream in the real flow).
    pub fn from_f32(base: RwkvModel, calib_tokens: &[u32]) -> HwModel {
        let d = base.d;
        let f = base.f;
        let v = base.vocab;
        // 1. encode every matrix in Δ-PoT and keep the realized values
        let q = QuantizedMats {
            emb: dpot_decode_all(&base.emb, v, d),
            head: dpot_decode_all(&base.head, v, d),
            blocks: base
                .blocks
                .iter()
                .map(|b| QBlock {
                    att_key: dpot_decode_all(&b.att_key, d, d),
                    att_value: dpot_decode_all(&b.att_value, d, d),
                    att_receptance: dpot_decode_all(&b.att_receptance, d, d),
                    att_output: dpot_decode_all(&b.att_output, d, d),
                    ffn_key: dpot_decode_all(&b.ffn_key, f, d),
                    ffn_receptance: dpot_decode_all(&b.ffn_receptance, d, d),
                    ffn_value: dpot_decode_all(&b.ffn_value, d, f),
                })
                .collect(),
        };
        // 2. additive weights: 9-bit uniform (done by value, in place on
        //    the base copy so the HW forward reads quantized vectors)
        let mut base = base;
        quantize_vector_weights(&mut base);

        // 3–4. calibration (the site-observer tap over the generic walk;
        //    f32 matrices + quantized vectors — calibration happens
        //    before activation quantization in the real flow too) and
        //    resolution into the indexed per-layer scales
        let scales = resolve_layer_scales(&base, calib_tokens);

        HwModel {
            base,
            q,
            scales,
            exps: ExpSigmoidUnit::new(),
            divu: Divu::new(),
            clip_events: 0,
            clip_total: 0,
            clips: Cell::new(0),
        }
    }

    pub fn new_state(&self) -> State {
        self.base.new_state()
    }

    pub fn vocab(&self) -> usize {
        self.base.vocab
    }

    pub fn n_layer(&self) -> usize {
        self.base.n_layer
    }

    pub fn d(&self) -> usize {
        self.base.d
    }

    pub fn f(&self) -> usize {
        self.base.f
    }

    /// Per-layer calibrated activation scales, one entry per layer.
    pub fn scales(&self) -> &[LayerScales] {
        &self.scales
    }

    /// Drain the cumulative 9-bit clip counter: the total across every
    /// forward call since the last drain.  Unlike the per-call
    /// [`HwModel::clip_events`] field — which each call overwrites, so
    /// split decode cycles lose counts — the drained total is lossless;
    /// the coordinator folds it into `Metrics::clip_events` and the
    /// serve report.
    pub fn take_clip_events(&mut self) -> u64 {
        std::mem::take(&mut self.clip_total)
    }

    /// Fold the walk's in-flight clip count into the per-call and
    /// cumulative counters (called once per public forward call).
    fn finish_clips(&mut self) {
        let c = self.clips.take();
        self.clip_events = c;
        self.clip_total += c;
    }

    /// One autoregressive step on the hardware datapath: a width-1
    /// batch panel through the generic walk.
    pub fn step(&mut self, state: &mut State, token: u32) -> Vec<f32> {
        let mut logits = Vec::new();
        forward::with_scratch(|buf| {
            forward::forward_panel(
                &*self,
                Columns::Batch(std::slice::from_mut(state)),
                &[token],
                HeadMode::PerColumn,
                buf,
                &mut logits,
            )
        });
        self.finish_clips();
        logits
    }

    /// Batched autoregressive step on the hardware datapath: the B
    /// sessions share one [`matmul`](crate::model::rwkv::matmul) per
    /// Δ-PoT matrix (B-fold weight reuse, §Perf L3-3) while every
    /// per-site 9-bit quantization, LUT/PWL nonlinearity and the WKV
    /// recurrence run column-wise per session — bit-exact with
    /// [`HwModel::step`] per session at any B.  `clip_events` afterwards
    /// holds this call's whole-batch clip total; the cumulative drain
    /// ([`HwModel::take_clip_events`]) additionally preserves it across
    /// calls.
    pub fn step_batch(&mut self, states: &mut [State], tokens: &[u32]) -> Vec<Vec<f32>> {
        let mut flat = Vec::new();
        forward::with_scratch(|buf| {
            forward::forward_panel(
                &*self,
                Columns::Batch(states),
                tokens,
                HeadMode::PerColumn,
                buf,
                &mut flat,
            )
        });
        self.finish_clips();
        flat.chunks(self.base.vocab).map(|c| c.to_vec()).collect()
    }

    /// [`HwModel::step_batch`] writing one flat `[B * vocab]` logits
    /// panel into a caller-owned buffer (the allocation-free engine
    /// decode path).
    pub fn step_batch_into(&mut self, states: &mut [State], tokens: &[u32], logits: &mut Vec<f32>) {
        forward::with_scratch(|buf| {
            forward::forward_panel(
                &*self,
                Columns::Batch(states),
                tokens,
                HeadMode::PerColumn,
                buf,
                logits,
            )
        });
        self.finish_clips();
    }

    /// Sequence-parallel chunked prefill on the hardware datapath
    /// (§Perf L3-4): a `[T, d]` sequence panel through the generic walk
    /// — ONE matmul per Δ-PoT matrix per chunk, per-site 9-bit
    /// quantization at the same column-wise per-layer scales, head on
    /// the last token only.  Bit-exact with T calls to
    /// [`HwModel::step`]; `clip_events` afterwards holds the whole
    /// chunk's clip total.
    pub fn prefill_chunk(&mut self, state: &mut State, tokens: &[u32]) -> Vec<f32> {
        let mut logits = Vec::new();
        forward::with_scratch(|buf| {
            forward::forward_panel(
                &*self,
                Columns::Seq(state),
                tokens,
                HeadMode::LastColumn,
                buf,
                &mut logits,
            )
        });
        self.finish_clips();
        logits
    }
}

/// The hardware-numerics backend hooks (§3–§4): ATAC LayerNorm, the
/// EXP-LUT / PWL-σ / DIVU units, Δ-PoT matrices, and per-site 9-bit
/// activation quantization at the calibrated [`LayerScales`] (clips
/// counted through an interior-mutability cell, folded into the public
/// counters after each call).
impl Numerics for HwModel {
    fn n_layer(&self) -> usize {
        self.base.n_layer
    }

    fn d(&self) -> usize {
        self.base.d
    }

    fn f(&self) -> usize {
        self.base.f
    }

    fn vocab(&self) -> usize {
        self.base.vocab
    }

    fn block(&self, l: usize) -> &Block {
        &self.base.blocks[l]
    }

    fn ln0(&self) -> (&[f32], &[f32]) {
        (&self.base.ln0_w, &self.base.ln0_b)
    }

    fn ln_out(&self) -> (&[f32], &[f32]) {
        (&self.base.ln_out_w, &self.base.ln_out_b)
    }

    fn embed(&self, tok: u32, out: &mut [f32]) {
        let d = self.base.d;
        out.copy_from_slice(&self.q.emb[tok as usize * d..(tok as usize + 1) * d]);
    }

    fn gemm(&self, l: usize, mat: MatId, xs: &[f32], out: &mut [f32], width: usize) {
        let w: &[f32] = match mat {
            MatId::AttKey => &self.q.blocks[l].att_key,
            MatId::AttValue => &self.q.blocks[l].att_value,
            MatId::AttReceptance => &self.q.blocks[l].att_receptance,
            MatId::AttOutput => &self.q.blocks[l].att_output,
            MatId::FfnKey => &self.q.blocks[l].ffn_key,
            MatId::FfnReceptance => &self.q.blocks[l].ffn_receptance,
            MatId::FfnValue => &self.q.blocks[l].ffn_value,
            MatId::Head => &self.q.head,
        };
        matmul(w, xs, out, width);
    }

    fn layernorm(&self, x: &[f32], w: &[f32], b: &[f32], out: &mut [f32]) {
        hw_layernorm(&self.divu, x, w, b, out);
    }

    fn quant(&self, l: usize, site: Site, xs: &mut [f32]) {
        let mut clips = 0u64;
        quant9(xs, self.scales[l].site(site), &mut clips);
        self.clips.set(self.clips.get() + clips);
    }

    fn exp(&self, x: f32) -> f32 {
        hw_exp(&self.exps, x)
    }

    fn sigmoid(&self, x: f32) -> f32 {
        hw_sigmoid(&self.exps, x)
    }

    fn div(&self, num: f32, den: f32) -> f32 {
        hw_div(&self.divu, num, den)
    }
}

/// Site-observer tap: the calibration backend.  Every hook DELEGATES to
/// the wrapped model's own exact-backend [`Numerics`] impl — so the walk
/// it observes is, by construction and not by copy, the f32 forward the
/// pre-refactor replica replayed by hand — except [`Numerics::quant`],
/// which records the max-abs activation per (layer, site) instead of
/// rounding.  (Recording replaces the base's quant outright, so the tap
/// observes unquantized f32 activations even if the base carries
/// `act_bits` — exactly what the pre-refactor replica did.)
struct CalibTap<'a> {
    m: &'a RwkvModel,
    site_max: RefCell<ScaleMap>,
}

impl Numerics for CalibTap<'_> {
    fn n_layer(&self) -> usize {
        Numerics::n_layer(self.m)
    }

    fn d(&self) -> usize {
        Numerics::d(self.m)
    }

    fn f(&self) -> usize {
        Numerics::f(self.m)
    }

    fn vocab(&self) -> usize {
        Numerics::vocab(self.m)
    }

    fn block(&self, l: usize) -> &Block {
        self.m.block(l)
    }

    fn ln0(&self) -> (&[f32], &[f32]) {
        self.m.ln0()
    }

    fn ln_out(&self) -> (&[f32], &[f32]) {
        self.m.ln_out()
    }

    fn embed(&self, tok: u32, out: &mut [f32]) {
        Numerics::embed(self.m, tok, out);
    }

    fn gemm(&self, l: usize, mat: MatId, xs: &[f32], out: &mut [f32], width: usize) {
        Numerics::gemm(self.m, l, mat, xs, out, width);
    }

    fn layernorm(&self, x: &[f32], w: &[f32], b: &[f32], out: &mut [f32]) {
        Numerics::layernorm(self.m, x, w, b, out);
    }

    fn quant(&self, l: usize, site: Site, xs: &mut [f32]) {
        let mx = xs.iter().fold(0f32, |a, &b| a.max(b.abs()));
        let mut map = self.site_max.borrow_mut();
        let e = map.entry((l, site)).or_insert(0.0);
        *e = e.max(mx);
    }

    fn exp(&self, x: f32) -> f32 {
        self.m.exp(x)
    }

    fn sigmoid(&self, x: f32) -> f32 {
        Numerics::sigmoid(self.m, x)
    }

    fn div(&self, num: f32, den: f32) -> f32 {
        self.m.div(num, den)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::rwkv::testing::test_model;

    fn calib_tokens() -> Vec<u32> {
        let mut rng = crate::Rng64::new(77);
        (0..128).map(|_| rng.below(50) as u32).collect()
    }

    #[test]
    fn hw_step_finite_and_close_to_f32() {
        let m = test_model(2, 32, 64, 50);
        let mut hw = HwModel::from_f32(m.clone(), &calib_tokens());
        let mut sf = m.new_state();
        let mut sh = hw.new_state();
        let mut max_rel = 0f32;
        for t in 0..30 {
            let tok = (t * 7 % 50) as u32;
            let lf = m.step(&mut sf, tok);
            let lh = hw.step(&mut sh, tok);
            assert!(lh.iter().all(|v| v.is_finite()));
            // compare top-1 agreement rather than absolute values: the
            // approximation stack shifts logits but should usually keep
            // the argmax
            let top_f = argmax(&lf);
            let top_h = argmax(&lh);
            let diff = lf
                .iter()
                .zip(&lh)
                .map(|(a, b)| (a - b).abs())
                .fold(0f32, f32::max);
            max_rel = max_rel.max(diff);
            let _ = (top_f, top_h);
        }
        // logit drift bounded (hardware error envelope, small random model)
        assert!(max_rel < 1.0, "{max_rel}");
    }

    fn argmax(v: &[f32]) -> usize {
        v.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1)).unwrap().0
    }

    #[test]
    fn clip_events_tracked_and_low() {
        let m = test_model(2, 32, 64, 50);
        let mut hw = HwModel::from_f32(m, &calib_tokens());
        let mut s = hw.new_state();
        let mut total = 0u64;
        for t in 0..20 {
            hw.step(&mut s, (t % 50) as u32);
            total += hw.clip_events;
        }
        // calibrated scales must keep clipping rare (< 1% of activations)
        let acts_per_step = 2 * 32 * 8; // rough
        assert!(total < (20 * acts_per_step) / 100, "{total}");
    }

    #[test]
    fn clip_total_accumulates_and_drains() {
        let m = test_model(2, 32, 64, 50);
        let mut hw = HwModel::from_f32(m, &calib_tokens());
        let mut s = hw.new_state();
        let mut per_call_sum = 0u64;
        for t in 0..12 {
            hw.step(&mut s, (t % 50) as u32);
            per_call_sum += hw.clip_events;
        }
        // the cumulative counter preserves what the per-call field
        // loses across split decode cycles
        assert_eq!(hw.take_clip_events(), per_call_sum);
        assert_eq!(hw.take_clip_events(), 0, "drain must reset the total");
    }

    #[test]
    fn calibration_tap_chunk_invariant_and_deterministic() {
        // the pre-refactor calibration replica walked the calib stream
        // token by token; the tap's sequence chunking must be invisible
        // (bit-equal maxima at every site), which pins the tap to the
        // replica's resolved LayerScales — the walk at width 1 is the
        // single-step forward the replica replayed
        let m = test_model(2, 32, 64, 50);
        let calib = calib_tokens();
        let by_token = calibrate(&m, &calib, 1);
        let chunked = calibrate(&m, &calib, 128);
        let ragged = calibrate(&m, &calib, 17);
        // all 7 sites of both layers observed
        assert_eq!(by_token.len(), 2 * 7);
        assert_eq!(chunked.len(), by_token.len());
        assert_eq!(ragged.len(), by_token.len());
        for (k, v) in &by_token {
            assert_eq!(v.to_bits(), chunked[k].to_bits(), "site {k:?}");
            assert_eq!(v.to_bits(), ragged[k].to_bits(), "site {k:?}");
        }
        // and from_f32 resolves them deterministically
        let a = HwModel::from_f32(m.clone(), &calib);
        let b = HwModel::from_f32(m, &calib);
        assert_eq!(a.scales, b.scales);
        assert!(a.scales.iter().all(|sc| {
            [sc.att_xn, sc.att_k, sc.att_v, sc.att_gated, sc.ffn_xn, sc.ffn_k2, sc.resid]
                .iter()
                .all(|&s| s.is_finite() && s > 0.0)
        }));
    }

    #[test]
    fn hw_prefill_chunk_bitexact_with_step_loop() {
        let m = test_model(2, 32, 64, 50);
        let calib = calib_tokens();
        let mut hw_step = HwModel::from_f32(m.clone(), &calib);
        let mut hw_chunk = HwModel::from_f32(m, &calib);
        for t_len in [1usize, 3, 17, 40] {
            let tokens: Vec<u32> = (0..t_len).map(|t| ((t * 7 + 1) % 50) as u32).collect();
            let mut s_step = hw_step.new_state();
            let mut last = Vec::new();
            let mut clips = 0u64;
            for &t in &tokens {
                last = hw_step.step(&mut s_step, t);
                clips += hw_step.clip_events;
            }
            let mut s_chunk = hw_chunk.new_state();
            let chunk_logits = hw_chunk.prefill_chunk(&mut s_chunk, &tokens);
            assert_eq!(last, chunk_logits, "T={t_len} logits");
            assert_eq!(s_step, s_chunk, "T={t_len} state");
            // clip observability: chunk total == sum of per-step counts
            assert_eq!(hw_chunk.clip_events, clips, "T={t_len} clip totals");
        }
    }

    #[test]
    fn hw_long_rollout_stable() {
        let m = test_model(2, 32, 64, 50);
        let mut hw = HwModel::from_f32(m, &calib_tokens());
        let mut s = hw.new_state();
        let mut tok = 1u32;
        for _ in 0..200 {
            let logits = hw.step(&mut s, tok);
            tok = argmax(&logits) as u32;
            assert!(logits.iter().all(|v| v.is_finite()));
        }
    }
}
