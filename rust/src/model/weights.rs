//! HFWT tensor-container reader (writer lives in
//! `python/compile/serialize.py`; keep the two in sync).
//!
//! Layout: magic `HFWT1\n` | u64-LE header length | JSON header | data.
//! Header: `{"tensors":[{"name","dtype","shape","offset","nbytes"}],
//! "meta":{...}}`, offsets relative to the data section, 64-byte aligned.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::{self, Json};

const MAGIC: &[u8] = b"HFWT1\n";

/// One named tensor (f32-converted view + original dtype/shape).
#[derive(Clone, Debug)]
pub struct Tensor {
    pub name: String,
    pub dtype: String,
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// (rows, cols) of a 2-D tensor.
    pub fn dims2(&self) -> Result<(usize, usize)> {
        match self.shape.as_slice() {
            [r, c] => Ok((*r, *c)),
            s => bail!("{}: expected 2-D, got {s:?}", self.name),
        }
    }
}

/// A loaded weight file.
#[derive(Debug)]
pub struct WeightFile {
    pub tensors: HashMap<String, Tensor>,
    pub meta: Json,
}

impl WeightFile {
    pub fn load(path: &Path) -> Result<WeightFile> {
        let raw = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
        if raw.len() < MAGIC.len() + 8 || &raw[..MAGIC.len()] != MAGIC {
            bail!("{}: not an HFWT file", path.display());
        }
        let hlen = u64::from_le_bytes(raw[6..14].try_into().unwrap()) as usize;
        let header_end = 14 + hlen;
        let header = json::parse(std::str::from_utf8(&raw[14..header_end])?)?;
        let data = &raw[header_end..];

        let mut tensors = HashMap::new();
        for e in header.req("tensors")?.as_arr()? {
            let name = e.req("name")?.as_str()?.to_string();
            let dtype = e.req("dtype")?.as_str()?.to_string();
            let shape: Vec<usize> = e
                .req("shape")?
                .as_arr()?
                .iter()
                .map(|v| v.as_usize())
                .collect::<Result<_>>()?;
            let offset = e.req("offset")?.as_usize()?;
            let nbytes = e.req("nbytes")?.as_usize()?;
            let bytes = data
                .get(offset..offset + nbytes)
                .ok_or_else(|| anyhow!("{name}: data out of range"))?;
            let n: usize = shape.iter().product::<usize>().max(1);
            let values = match dtype.as_str() {
                "float32" => bytes
                    .chunks_exact(4)
                    .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
                    .collect::<Vec<f32>>(),
                "int8" => bytes.iter().map(|&b| b as i8 as f32).collect(),
                "int32" => bytes
                    .chunks_exact(4)
                    .map(|b| i32::from_le_bytes(b.try_into().unwrap()) as f32)
                    .collect(),
                d => bail!("{name}: unsupported dtype {d}"),
            };
            if values.len() != n && !shape.is_empty() {
                bail!("{name}: {} values for shape {shape:?}", values.len());
            }
            tensors.insert(name.clone(), Tensor { name, dtype, shape, data: values });
        }
        let meta = header.get("meta").cloned().unwrap_or(Json::obj());
        Ok(WeightFile { tensors, meta })
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors.get(name).ok_or_else(|| anyhow!("missing tensor {name:?}"))
    }

    pub fn total_params(&self) -> u64 {
        self.tensors.values().map(|t| t.len() as u64).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    /// Write a minimal HFWT file (mirrors the python writer).
    pub fn write_test_file(path: &Path, tensors: &[(&str, Vec<usize>, Vec<f32>)]) {
        let mut entries = Vec::new();
        let mut blob: Vec<u8> = Vec::new();
        for (name, shape, data) in tensors {
            let offset = blob.len();
            for v in data {
                blob.extend_from_slice(&v.to_le_bytes());
            }
            let mut e = Json::obj();
            e.set("name", *name)
                .set("dtype", "float32")
                .set("shape", shape.iter().map(|&s| s as u64).collect::<Vec<u64>>())
                .set("offset", offset)
                .set("nbytes", data.len() * 4);
            entries.push(e);
            while blob.len() % 64 != 0 {
                blob.push(0);
            }
        }
        let mut header = Json::obj();
        header.set("tensors", Json::Arr(entries)).set("meta", Json::obj());
        let hs = header.to_string();
        let mut f = std::fs::File::create(path).unwrap();
        f.write_all(MAGIC).unwrap();
        f.write_all(&(hs.len() as u64).to_le_bytes()).unwrap();
        f.write_all(hs.as_bytes()).unwrap();
        f.write_all(&blob).unwrap();
    }

    #[test]
    fn roundtrip_via_test_writer() {
        let dir = std::env::temp_dir().join("hfwt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("w.bin");
        write_test_file(
            &p,
            &[
                ("a", vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]),
                ("b", vec![2], vec![-1.5, 0.25]),
            ],
        );
        let wf = WeightFile::load(&p).unwrap();
        assert_eq!(wf.get("a").unwrap().dims2().unwrap(), (2, 3));
        assert_eq!(wf.get("b").unwrap().data, vec![-1.5, 0.25]);
        assert_eq!(wf.total_params(), 8);
        assert!(wf.get("nope").is_err());
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("hfwt_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.bin");
        std::fs::write(&p, b"NOTMAGIC........").unwrap();
        assert!(WeightFile::load(&p).is_err());
    }

    #[test]
    fn loads_real_artifact_if_present() {
        let p = Path::new("artifacts/tiny.weights.bin");
        if !p.exists() {
            return; // artifact-gated; integration tests cover this
        }
        let wf = WeightFile::load(p).unwrap();
        assert_eq!(wf.total_params(), crate::model::tiny_expected_params());
        assert_eq!(wf.get("emb").unwrap().dims2().unwrap(), (128, 128));
    }
}
