//! Cycle-accurate accelerator simulator (§4 architecture, §5.3 results).
//!
//! The simulator composes the paper's own latency formulas into a
//! per-token cycle count, overlaps weight streaming with compute through
//! the ping-pong double-buffer model, and layers resource (Table 2) and
//! energy (Fig 8) models on top.
//!
//! * [`timing`]    — closed-form cycle counts for every operation class
//!   (MVM `(l+4)·⌈m/d⌉`, element-wise `⌈l/d⌉+4`, ATAC `⌈d/P⌉+9`, complex
//!   unit passes) and the per-RWKV-block schedule.
//! * [`memory`]    — HBM channel + URAM ping-pong double-buffer bridge;
//!   includes a discrete-event simulation used to validate the
//!   closed-form overlap model.
//! * [`resources`] — LUT/FF/DSP/BRAM/URAM cost model → Table 2.
//! * [`energy`]    — static + per-resource dynamic power → Fig 8.
//! * [`accel`]     — ties it together: `AccelSim::evaluate(shape)` returns
//!   throughput, utilization and the compute/transfer breakdown.

pub mod accel;
pub mod energy;
pub mod memory;
pub mod resources;
pub mod timing;

pub use accel::{AccelSim, TokenReport};
pub use energy::power_watts;
pub use resources::{resource_usage, ResourceVector};
