//! FPGA resource model → Table 2.
//!
//! The DSP column follows an exact structural identity visible in the
//! paper's numbers:  `DSP = d + 256·(P/256) + 1`
//! (one DSP per PMAC accumulator, one per complex unit — doubled in the
//! P=512 configs whose wider LayerNorm datapath pairs each unit with a
//! squaring DSP — plus one for the mean-square multiply):
//!   384+256+1 = 641, 512+512+1 = 1025, 768+256+1 = 1025, 1024+512+1 = 1537 ✓
//!
//! LUT/FF/BRAM/URAM use per-module structural costs with coefficients
//! fitted once against the paper's four columns (within a few percent;
//! the table2 harness prints model vs paper side by side).

use crate::config::{AccelConfig, ModelShape};

/// A bundle of FPGA resource counts.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ResourceVector {
    pub lut: u64,
    pub ff: u64,
    pub dsp: u64,
    pub bram: u64,
    pub uram: u64,
}

impl ResourceVector {
    pub fn add(&self, o: &ResourceVector) -> ResourceVector {
        ResourceVector {
            lut: self.lut + o.lut,
            ff: self.ff + o.ff,
            dsp: self.dsp + o.dsp,
            bram: self.bram + o.bram,
            uram: self.uram + o.uram,
        }
    }

    /// Utilization fractions against a platform's totals.
    pub fn utilization(&self, total: &ResourceVector) -> [f64; 5] {
        [
            self.lut as f64 / total.lut as f64,
            self.ff as f64 / total.ff as f64,
            self.dsp as f64 / total.dsp as f64,
            self.bram as f64 / total.bram as f64,
            self.uram as f64 / total.uram as f64,
        ]
    }

    pub fn fits_in(&self, total: &ResourceVector) -> bool {
        self.lut <= total.lut
            && self.ff <= total.ff
            && self.dsp <= total.dsp
            && self.bram <= total.bram
            && self.uram <= total.uram
    }
}

// Per-unit structural costs (UltraScale+ LUT6/FF pairs), fitted once by
// solving Table 2's four columns for the structural model
// `base + units + a·d + b·2P + s·streaming` (residuals < 1%, see the
// table2 harness).  A PMAC = 3 barrel shifters + shift-add + 16-bit
// accumulator; a DIVU = 2 LODs + 256×9b LUT + recombine; an EXP–σ unit =
// ShiftAddition + 256×9b LUT + PWL mux.
const LUT_PER_PMAC: u64 = 84;
const FF_PER_PMAC: u64 = 52;
const LUT_PER_DIVU: u64 = 140;
const FF_PER_DIVU: u64 = 130;
const LUT_PER_EXPS: u64 = 120;
const FF_PER_EXPS: u64 = 110;
/// adder-tree node cost per lane of tree parallelism (9→16-bit adders;
/// two ATAC paths, so this multiplies 2·P)
const LUT_PER_TREE_LANE: u64 = 30;
const FF_PER_TREE_LANE: u64 = 26;
/// controller + activate-value buffer mux + AXI/HBM plumbing (fixed)
const LUT_BASE: u64 = 15_000;
const FF_BASE: u64 = 18_700;
/// memory bridge + ping-pong double-buffer control (streaming configs)
const LUT_STREAMING: u64 = 16_640;
const FF_STREAMING: u64 = 21_700;

/// BRAM36 blocks for the activation-value buffer and the unit ROMs.
fn bram_blocks(cfg: &AccelConfig, streaming: bool) -> u64 {
    // unit LUT ROMs: one BRAM per 2 complex units (256×9b fits easily)
    let roms = ((cfg.divu_count + cfg.exps_count) / 8) as u64;
    // activation buffer: resident configs only buffer a d_model-scale
    // working set (tiny); streaming configs also hold all vector weights
    // + per-layer activations for the largest supported model (7B:
    // d=4096) → the paper jumps 45 → 637.
    let act = if streaming { 605 } else { 13 };
    roms + act
}

/// URAM288 banks: weight residency for `_0` configs (the 169M model's
/// hot matrices), ping-pong streaming banks for `_1`.
fn uram_banks(cfg: &AccelConfig) -> u64 {
    const URAM_BYTES: u64 = 288 * 1024 / 8; // 36 KB
    if cfg.weights_resident {
        // enough banks to double-buffer the largest resident layer of the
        // 169M model at 9 b/weight: U50_0 = 96, U280*_0 = 192 in Table 2 —
        // structural: 2 banks per HBM pseudo-channel group feeding the
        // array, scaled by array width
        (cfg.pmac_count / 4) as u64
    } else {
        2 * (cfg.chunk_bytes as u64 / URAM_BYTES)
    }
}

/// Full resource usage of a configuration (one Table 2 column).
pub fn resource_usage(cfg: &AccelConfig) -> ResourceVector {
    let d = cfg.pmac_count as u64;
    let p = cfg.tree_parallelism as u64;
    let streaming = !cfg.weights_resident;
    let lut = LUT_BASE
        + d * LUT_PER_PMAC
        + cfg.divu_count as u64 * LUT_PER_DIVU
        + cfg.exps_count as u64 * LUT_PER_EXPS
        + 2 * p * LUT_PER_TREE_LANE // two ATAC paths
        + if streaming { LUT_STREAMING } else { 0 };
    let ff = FF_BASE
        + d * FF_PER_PMAC
        + cfg.divu_count as u64 * FF_PER_DIVU
        + cfg.exps_count as u64 * FF_PER_EXPS
        + 2 * p * FF_PER_TREE_LANE
        + if streaming { FF_STREAMING } else { 0 };
    let dsp = d + 256 * (p / 256) + 1;
    ResourceVector {
        lut,
        ff,
        dsp,
        bram: bram_blocks(cfg, streaming),
        uram: uram_banks(cfg),
    }
}

/// Paper's measured Table 2 numbers, for side-by-side comparison.
pub fn paper_table2(name: &str) -> Option<ResourceVector> {
    Some(match name {
        "HFRWKV_0" => ResourceVector { lut: 95_718, ff: 82_719, dsp: 641, bram: 45, uram: 96 },
        "HFRWKV_1" => ResourceVector { lut: 137_631, ff: 124_350, dsp: 1_025, bram: 637, uram: 128 },
        "HFRWKV*_0" => ResourceVector { lut: 126_956, ff: 102_809, dsp: 1_025, bram: 45, uram: 192 },
        "HFRWKV*_1" => ResourceVector { lut: 182_372, ff: 151_158, dsp: 1_537, bram: 637, uram: 256 },
        _ => return None,
    })
}

/// Bytes of on-chip storage needed to hold a model fully resident
/// (9-bit matrices + 9-bit vectors) — determines `_0` config eligibility.
pub fn resident_bytes(shape: &ModelShape) -> u64 {
    (shape.matrix_params() + shape.vector_params()) * 9 / 8
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Platform, HFRWKV_CONFIGS};

    #[test]
    fn dsp_matches_paper_exactly() {
        for cfg in &HFRWKV_CONFIGS {
            let got = resource_usage(cfg).dsp;
            let want = paper_table2(cfg.name).unwrap().dsp;
            assert_eq!(got, want, "{}", cfg.name);
        }
    }

    #[test]
    fn uram_matches_paper_exactly() {
        for cfg in &HFRWKV_CONFIGS {
            let got = resource_usage(cfg).uram;
            let want = paper_table2(cfg.name).unwrap().uram;
            assert_eq!(got, want, "{}", cfg.name);
        }
    }

    #[test]
    fn lut_ff_within_fit_tolerance() {
        for cfg in &HFRWKV_CONFIGS {
            let got = resource_usage(cfg);
            let want = paper_table2(cfg.name).unwrap();
            let lut_err = (got.lut as f64 - want.lut as f64).abs() / want.lut as f64;
            let ff_err = (got.ff as f64 - want.ff as f64).abs() / want.ff as f64;
            assert!(lut_err < 0.02, "{} lut {} vs {}", cfg.name, got.lut, want.lut);
            assert!(ff_err < 0.02, "{} ff {} vs {}", cfg.name, got.ff, want.ff);
        }
    }

    #[test]
    fn everything_fits_on_its_platform() {
        for cfg in &HFRWKV_CONFIGS {
            let usage = resource_usage(cfg);
            assert!(usage.fits_in(&cfg.platform.resources()), "{}", cfg.name);
        }
    }

    #[test]
    fn utilization_fractions_sane() {
        let cfg = &HFRWKV_CONFIGS[0];
        let u = resource_usage(cfg).utilization(&Platform::AlveoU50.resources());
        for frac in u {
            assert!(frac > 0.0 && frac < 0.6, "{frac}");
        }
    }
}
