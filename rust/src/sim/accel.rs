//! Top-level accelerator simulation: per-token latency, throughput,
//! bandwidth utilization and power for any (config, model shape) pair.

use super::{energy, memory, resources, timing};
use crate::config::{AccelConfig, ModelShape};

/// Everything the harness needs about one (config, shape) evaluation.
#[derive(Clone, Copy, Debug)]
pub struct TokenReport {
    pub cycles: u64,
    pub seconds: f64,
    pub tokens_per_sec: f64,
    pub compute_cycles: u64,
    pub transfer_cycles: u64,
    pub bandwidth_utilization: f64,
    pub power_watts: f64,
    pub tokens_per_joule: f64,
    /// true when the model fits on chip under this config's policy
    pub feasible: bool,
}

/// The accelerator simulator for one deployed configuration.
#[derive(Clone, Copy, Debug)]
pub struct AccelSim {
    pub cfg: AccelConfig,
    /// fine-grained pipelining enabled (the paper's design; ablation
    /// benches flip this off)
    pub pipelined: bool,
    /// weight bit width as streamed/stored (9 = Δ-PoT; 16 for the fp16
    /// what-if ablation)
    pub weight_bits: f64,
}

impl AccelSim {
    pub fn new(cfg: &AccelConfig) -> Self {
        Self { cfg: *cfg, pipelined: true, weight_bits: 9.0 }
    }

    /// Simulate sustained single-token decode (batch 1, the paper's
    /// measurement protocol).
    pub fn evaluate(&self, shape: &ModelShape) -> TokenReport {
        let compute = timing::token_compute_cycles(shape, &self.cfg, self.pipelined);
        let stream_bytes = if self.cfg.weights_resident {
            0.0
        } else {
            shape.stream_bytes_per_token(self.weight_bits)
        };
        let sched = memory::schedule_token(&self.cfg, compute, stream_bytes);
        let seconds = sched.total_cycles as f64 / self.cfg.freq_hz;
        let tokens_per_sec = 1.0 / seconds;
        let bytes_per_sec = stream_bytes / seconds;
        let power = energy::power_watts(&self.cfg, bytes_per_sec);

        // Feasibility.  The paper's "_0 fully on-chip" claim cannot mean
        // all 169M matrix weights in 23 MB of URAM+BRAM (impossible at 9
        // bits) — the §4.1 text keeps "vector weights and historical
        // values" on-chip with matrices cached/prefetched; we adopt that
        // reading: _0 needs vectors+activations on chip (always true for
        // the supported sizes) and the matrices in HBM, with the hot
        // working set per layer fitting the URAM budget.
        let feasible = if self.cfg.weights_resident {
            let plat = self.cfg.platform.resources();
            let uram_bytes = plat.uram * 36 * 1024;
            // one d×d layer tile + all vector weights URAM-cacheable,
            // and the array sized for this model (Table 2's "Support
            // Size": the _0 configs serve only the 169M-class shapes,
            // d_model ≤ 2·PMACs)
            let layer_tile = (shape.d_model as u64 * shape.d_model as u64) * 9 / 8;
            let vectors = shape.vector_params() * 9 / 8;
            layer_tile + vectors <= uram_bytes
                && shape.d_model <= 2 * self.cfg.pmac_count
                && shape.stream_bytes_per_token(self.weight_bits)
                    <= self.cfg.platform.hbm_capacity_bytes() as f64
        } else {
            shape.stream_bytes_per_token(self.weight_bits)
                <= self.cfg.platform.hbm_capacity_bytes() as f64
        };

        TokenReport {
            cycles: sched.total_cycles,
            seconds,
            tokens_per_sec,
            compute_cycles: sched.compute_cycles,
            transfer_cycles: sched.transfer_cycles,
            bandwidth_utilization: sched.bandwidth_utilization,
            power_watts: power,
            tokens_per_joule: tokens_per_sec / power,
            feasible,
        }
    }

    /// The config the paper deploys for this model size: `_0` for 169M,
    /// `_1` otherwise (§5.3.1 "Support Size").
    pub fn deployed_for(platform_is_u280: bool, shape: &ModelShape) -> AccelSim {
        use crate::config::HFRWKV_CONFIGS;
        let small = shape.name.contains("169m") || shape.name.contains("tiny");
        let idx = match (platform_is_u280, small) {
            (false, true) => 0,
            (false, false) => 1,
            (true, true) => 2,
            (true, false) => 3,
        };
        AccelSim::new(&HFRWKV_CONFIGS[idx])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{HFRWKV_CONFIGS, PAPER_SHAPES};

    #[test]
    fn anchor_169m_throughput_band() {
        // DESIGN §8 anchor: HFRWKV_0 at 169M ≈ 1000 tok/s (±30%)
        let r = AccelSim::new(&HFRWKV_CONFIGS[0]).evaluate(&PAPER_SHAPES[0]);
        assert!((700.0..1400.0).contains(&r.tokens_per_sec), "{}", r.tokens_per_sec);
    }

    #[test]
    fn anchor_7b_transfer_bound() {
        // 7B on U280_1: transfer-bound, ~55-60 tok/s, util > 99%
        let r = AccelSim::new(&HFRWKV_CONFIGS[3]).evaluate(&PAPER_SHAPES[4]);
        assert!((40.0..80.0).contains(&r.tokens_per_sec), "{}", r.tokens_per_sec);
        assert!(r.bandwidth_utilization > 0.99);
    }

    #[test]
    fn u280_beats_u50_everywhere() {
        for shape in &PAPER_SHAPES {
            let u50 = AccelSim::deployed_for(false, shape).evaluate(shape);
            let u280 = AccelSim::deployed_for(true, shape).evaluate(shape);
            assert!(u280.tokens_per_sec > u50.tokens_per_sec, "{}", shape.name);
        }
    }

    #[test]
    fn hfrwkv_star_ratio_matches_paper_at_169m() {
        // paper: HFRWKV* is 59.8/26.74 = 2.236× HFRWKV at 169M — this
        // ratio is pure (d, freq) arithmetic and must reproduce tightly.
        let a = AccelSim::new(&HFRWKV_CONFIGS[0]).evaluate(&PAPER_SHAPES[0]);
        let b = AccelSim::new(&HFRWKV_CONFIGS[2]).evaluate(&PAPER_SHAPES[0]);
        let ratio = b.tokens_per_sec / a.tokens_per_sec;
        assert!((ratio - 2.236).abs() / 2.236 < 0.12, "{ratio}");
    }

    #[test]
    fn fp16_streaming_ablation_slower() {
        // streaming fp16 instead of Δ-PoT9 must cost ~16/9 in the
        // transfer-bound regime — the quantization bandwidth win.
        let mut sim = AccelSim::new(&HFRWKV_CONFIGS[3]);
        let q9 = sim.evaluate(&PAPER_SHAPES[4]);
        sim.weight_bits = 16.0;
        let f16 = sim.evaluate(&PAPER_SHAPES[4]);
        let ratio = q9.tokens_per_sec / f16.tokens_per_sec;
        assert!((ratio - 16.0 / 9.0).abs() < 0.25, "{ratio}");
    }

    #[test]
    fn pipelining_ablation_helps() {
        let mut sim = AccelSim::new(&HFRWKV_CONFIGS[0]);
        let on = sim.evaluate(&PAPER_SHAPES[0]);
        sim.pipelined = false;
        let off = sim.evaluate(&PAPER_SHAPES[0]);
        assert!(on.tokens_per_sec > off.tokens_per_sec);
    }

    #[test]
    fn feasibility_flags() {
        // 7B can never be URAM-resident; it does fit HBM when streamed.
        let r0 = AccelSim::new(&HFRWKV_CONFIGS[0]).evaluate(&PAPER_SHAPES[4]);
        assert!(!r0.feasible);
        let r1 = AccelSim::new(&HFRWKV_CONFIGS[1]).evaluate(&PAPER_SHAPES[4]);
        assert!(r1.feasible);
    }

    #[test]
    fn power_and_energy_consistent() {
        let r = AccelSim::new(&HFRWKV_CONFIGS[1]).evaluate(&PAPER_SHAPES[1]);
        assert!((r.tokens_per_joule - r.tokens_per_sec / r.power_watts).abs() < 1e-9);
    }
}
