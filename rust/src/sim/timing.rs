//! Closed-form cycle counts for every operation class, straight from the
//! paper, and the per-RWKV-block schedule that composes them.
//!
//! §4.2: MVM on d PMACs over W[m,l]: `(l+4)·⌈m/d⌉` cycles (pipeline
//!        init/drain overhead of 4); element-wise over l: `⌈l/d⌉+4`.
//! §4.3: DIVU is a 3-stage pipeline, ×128 replicated.
//! §4.4: EXP–σ is a short pipeline (4 stages), ×128 replicated.
//! §4.5: one ATAC reduction over d elements at tree parallelism P:
//!        `⌈d/P⌉+9` cycles; the mean and variance paths run in parallel.

use crate::config::{AccelConfig, ModelShape};
use crate::arith::divu::DIVU_STAGES;
use crate::arith::exp_sigmoid::EXPS_STAGES;

#[inline]
fn ceil_div(a: usize, b: usize) -> u64 {
    ((a + b - 1) / b) as u64
}

/// Matrix-vector multiply W[m,l]·x — mode 1 of the MV array.
pub fn mvm_cycles(m: usize, l: usize, d: usize) -> u64 {
    (l as u64 + 4) * ceil_div(m, d)
}

/// One element-wise pass over an l-vector — modes 2/3 of the MV array.
pub fn elementwise_cycles(l: usize, d: usize) -> u64 {
    ceil_div(l, d) + 4
}

/// One pass of an l-vector through the replicated complex units.
pub fn complex_cycles(l: usize, units: usize, stages: u32) -> u64 {
    ceil_div(l, units) + stages as u64
}

/// Full LayerNorm of a d-vector: parallel ATAC paths, subtract-sqrt, then
/// the normalization stream through the DIVUs (Fig 6).
pub fn layernorm_cycles(d: usize, tree_p: usize, divu_count: usize) -> u64 {
    let atac = ceil_div(d, tree_p) + 9; // both paths run in parallel
    let sqrt_stage = 4;
    let stream = complex_cycles(d, divu_count, DIVU_STAGES);
    atac + sqrt_stage + stream
}

/// Cycle breakdown of one token through one RWKV block + amortized head.
#[derive(Clone, Copy, Debug, Default)]
pub struct BlockCycles {
    pub mvm: u64,
    pub elementwise: u64,
    pub complex: u64,
    pub layernorm: u64,
}

impl BlockCycles {
    pub fn total_serial(&self) -> u64 {
        self.mvm + self.elementwise + self.complex + self.layernorm
    }

    /// Pipelined total: the element-wise and complex passes overlap with
    /// MVM streaming (fine-grained pipelining, §4.1 "fine-grained
    /// pipelining enables batched processing of element-wise operations").
    /// LayerNorm gates the block entry and cannot overlap (data dependency
    /// on the full normalized vector).
    pub fn total_pipelined(&self) -> u64 {
        self.mvm.max(self.elementwise + self.complex) + self.layernorm
    }
}

/// Cycles for one RWKV block (time mixing + channel mixing).
pub fn block_cycles(shape: &ModelShape, cfg: &AccelConfig) -> BlockCycles {
    let (dm, df) = (shape.d_model, shape.d_ffn);
    let d = cfg.pmac_count;
    let mut c = BlockCycles::default();

    // ---- time mixing -----------------------------------------------------
    c.layernorm += layernorm_cycles(dm, cfg.tree_parallelism, cfg.divu_count);
    // token-shift: xk/xv/xr each = 2 muls + 1 add on the element-wise array
    c.elementwise += 9 * elementwise_cycles(dm, d);
    // r/k/v projections
    c.mvm += 3 * mvm_cycles(dm, dm, d);
    // sigmoid(r)
    c.complex += complex_cycles(dm, cfg.exps_count, EXPS_STAGES);
    // WKV (eq 2, stabilized): 4 exponentials, 1 division, ~12 element-wise
    c.complex += 4 * complex_cycles(dm, cfg.exps_count, EXPS_STAGES);
    c.complex += complex_cycles(dm, cfg.divu_count, DIVU_STAGES);
    c.elementwise += 12 * elementwise_cycles(dm, d);
    // r ⊙ wkv, output projection, residual add
    c.elementwise += elementwise_cycles(dm, d);
    c.mvm += mvm_cycles(dm, dm, d);
    c.elementwise += elementwise_cycles(dm, d);

    // ---- channel mixing ----------------------------------------------------
    c.layernorm += layernorm_cycles(dm, cfg.tree_parallelism, cfg.divu_count);
    // token-shift: xk/xr
    c.elementwise += 6 * elementwise_cycles(dm, d);
    // key projection to FFN width + relu² (element-wise over df)
    c.mvm += mvm_cycles(df, dm, d);
    c.elementwise += 2 * elementwise_cycles(df, d);
    // receptance + sigmoid
    c.mvm += mvm_cycles(dm, dm, d);
    c.complex += complex_cycles(dm, cfg.exps_count, EXPS_STAGES);
    // value projection back + gate + residual
    c.mvm += mvm_cycles(dm, df, d);
    c.elementwise += 2 * elementwise_cycles(dm, d);

    c
}

/// Cycles for the head (final LayerNorm + vocab projection).
pub fn head_cycles(shape: &ModelShape, cfg: &AccelConfig) -> BlockCycles {
    BlockCycles {
        mvm: mvm_cycles(shape.vocab, shape.d_model, cfg.pmac_count),
        layernorm: layernorm_cycles(shape.d_model, cfg.tree_parallelism, cfg.divu_count),
        ..Default::default()
    }
}

/// Total compute cycles for one token (all blocks + embedding LN + head).
pub fn token_compute_cycles(shape: &ModelShape, cfg: &AccelConfig, pipelined: bool) -> u64 {
    let blk = block_cycles(shape, cfg);
    let head = head_cycles(shape, cfg);
    let ln0 = layernorm_cycles(shape.d_model, cfg.tree_parallelism, cfg.divu_count);
    let per_block = if pipelined { blk.total_pipelined() } else { blk.total_serial() };
    let head_c = if pipelined { head.total_pipelined() } else { head.total_serial() };
    ln0 + shape.n_layer as u64 * per_block + head_c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{HFRWKV_CONFIGS, PAPER_SHAPES};

    #[test]
    fn mvm_formula_square_matches_paper() {
        // paper: (l+4)·(l/d) for square matrices
        assert_eq!(mvm_cycles(768, 768, 384), (768 + 4) * 2);
        assert_eq!(mvm_cycles(512, 512, 512), 512 + 4);
    }

    #[test]
    fn elementwise_formula_matches_paper() {
        // paper: l/d + 4
        assert_eq!(elementwise_cycles(512, 512), 1 + 4);
        assert_eq!(elementwise_cycles(4096, 1024), 4 + 4);
    }

    #[test]
    fn layernorm_dominated_by_atac_at_small_p() {
        let small = layernorm_cycles(4096, 256, 128);
        let large = layernorm_cycles(4096, 512, 128);
        assert!(small > large);
    }

    #[test]
    fn pipelined_never_slower_than_serial() {
        for shape in &PAPER_SHAPES {
            for cfg in &HFRWKV_CONFIGS {
                let b = block_cycles(shape, cfg);
                assert!(b.total_pipelined() <= b.total_serial());
                assert!(b.total_pipelined() >= b.mvm);
            }
        }
    }

    #[test]
    fn compute_cycles_monotone_in_model_size() {
        let cfg = &HFRWKV_CONFIGS[1];
        let mut prev = 0;
        for shape in &PAPER_SHAPES {
            let c = token_compute_cycles(shape, cfg, true);
            assert!(c > prev, "{}: {c} vs {prev}", shape.name);
            prev = c;
        }
    }

    #[test]
    fn known_169m_magnitude() {
        // sanity anchor for the whole model: 169M on HFRWKV_0 (d=384,
        // 350 MHz) must land near ~340k cycles/token → ~1000 tok/s.
        let c = token_compute_cycles(&PAPER_SHAPES[0], &HFRWKV_CONFIGS[0], true);
        assert!((250_000..500_000).contains(&c), "{c}");
    }
}
