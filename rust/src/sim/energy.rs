//! Power / energy model → Fig 8.
//!
//! Substitution for Vivado power reports (DESIGN.md §2): a standard
//! static + dynamic decomposition.  Dynamic power is per-resource-class
//! toggle energy × utilization × clock, with UltraScale+-typical
//! coefficients chosen so total board power lands in the 17–26 W band a
//! Vivado report gives for designs of this size.  Energy-efficiency
//! *ratios* (the paper's claim) depend only on these being held fixed
//! across configs.

use super::resources::{resource_usage, ResourceVector};
use crate::config::AccelConfig;

/// Static (leakage + HBM PHY idle) power per card, watts.
pub const STATIC_W: f64 = 9.0;
/// HBM access energy, picojoules per byte (HBM2 ≈ 3 pJ/bit, controller
/// overhead folded in).
pub const HBM_PJ_PER_BYTE: f64 = 25.0;

// Dynamic power coefficients, watts per unit per GHz at the observed
// toggle rates (fitted to Vivado-typical reports for arithmetic-dense
// UltraScale+ designs).
const W_PER_LUT_GHZ: f64 = 160e-6;
const W_PER_FF_GHZ: f64 = 40e-6;
const W_PER_DSP_GHZ: f64 = 4.0e-3;
const W_PER_BRAM_GHZ: f64 = 14.0e-3;
const W_PER_URAM_GHZ: f64 = 45.0e-3;

/// Dynamic logic power of a resource vector at `freq_hz`, watts.
pub fn dynamic_watts(usage: &ResourceVector, freq_hz: f64) -> f64 {
    let ghz = freq_hz / 1e9;
    ghz * (usage.lut as f64 * W_PER_LUT_GHZ
        + usage.ff as f64 * W_PER_FF_GHZ
        + usage.dsp as f64 * W_PER_DSP_GHZ
        + usage.bram as f64 * W_PER_BRAM_GHZ
        + usage.uram as f64 * W_PER_URAM_GHZ)
}

/// Total board power while streaming `bytes_per_sec` from HBM.
pub fn power_watts(cfg: &AccelConfig, bytes_per_sec: f64) -> f64 {
    let usage = resource_usage(cfg);
    STATIC_W + dynamic_watts(&usage, cfg.freq_hz) + bytes_per_sec * HBM_PJ_PER_BYTE * 1e-12
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HFRWKV_CONFIGS;

    #[test]
    fn power_in_vivado_typical_band() {
        for cfg in &HFRWKV_CONFIGS {
            // worst case: full rated bandwidth
            let p = power_watts(cfg, cfg.effective_bandwidth());
            assert!((14.0..55.0).contains(&p), "{}: {p} W", cfg.name);
        }
    }

    #[test]
    fn streaming_configs_draw_more() {
        let p0 = power_watts(&HFRWKV_CONFIGS[0], 0.0);
        let p1 = power_watts(&HFRWKV_CONFIGS[1], HFRWKV_CONFIGS[1].effective_bandwidth());
        assert!(p1 > p0);
    }

    #[test]
    fn u280_draws_more_than_u50() {
        let u50 = power_watts(&HFRWKV_CONFIGS[1], 201e9);
        let u280 = power_watts(&HFRWKV_CONFIGS[3], 460e9);
        assert!(u280 > u50, "{u280} vs {u50}");
    }

    #[test]
    fn hbm_term_scales_linearly() {
        let cfg = &HFRWKV_CONFIGS[1];
        let a = power_watts(cfg, 0.0);
        let b = power_watts(cfg, 100e9);
        let c = power_watts(cfg, 200e9);
        assert!((2.0 * (b - a) - (c - a)).abs() < 1e-9);
    }
}
