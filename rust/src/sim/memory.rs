//! Memory bridge: HBM streaming + URAM ping-pong double buffering (§4.1).
//!
//! Large-model mode streams Δ-PoT matrix weights from HBM in chunks sized
//! to one URAM bank; while chunk *i* is being computed on, chunk *i+1*
//! transfers into the other bank.  Steady state is therefore
//! `max(compute, transfer)` per chunk plus a one-chunk fill at the start
//! of every token — the closed form below.  A discrete-event simulation
//! of the same pipeline validates the closed form (they must agree
//! cycle-for-cycle; see the tests).

use crate::config::AccelConfig;

/// Result of scheduling one token's weight stream against its compute.
#[derive(Clone, Copy, Debug)]
pub struct OverlapReport {
    /// total cycles for the token
    pub total_cycles: u64,
    /// cycles HBM was actually transferring
    pub transfer_cycles: u64,
    /// cycles the compute array was busy
    pub compute_cycles: u64,
    /// achieved HBM utilization (transfer / total)
    pub bandwidth_utilization: f64,
    /// number of chunks streamed
    pub n_chunks: usize,
}

/// Convert a byte count to HBM transfer cycles at this config's clock.
pub fn transfer_cycles(cfg: &AccelConfig, bytes: f64) -> u64 {
    let secs = bytes / cfg.effective_bandwidth();
    (secs * cfg.freq_hz).ceil() as u64
}

/// Closed-form double-buffer overlap: compute and transfer split evenly
/// across `n_chunks`; steady state interleaves, so
/// `total = fill + Σ max(c_i, t_i) = t_chunk + (n-1)·max + max(last)`.
pub fn overlap_closed_form(
    compute_cycles: u64,
    transfer_cycles: u64,
    n_chunks: usize,
) -> u64 {
    if n_chunks == 0 || transfer_cycles == 0 {
        return compute_cycles;
    }
    let n = n_chunks as u64;
    let t_chunk = transfer_cycles / n;
    let c_chunk = compute_cycles / n;
    // first chunk must fully arrive before compute starts (fill), then
    // n per-chunk slots run at the slower of the two rates
    t_chunk + n * t_chunk.max(c_chunk)
        + (transfer_cycles % n).min(1) // ragged remainder guard
}

/// Discrete-event model of the same ping-pong pipeline: two buffers,
/// transfer engine and compute engine as independent resources.
pub fn overlap_event_sim(compute_cycles: u64, transfer_cycles: u64, n_chunks: usize) -> u64 {
    if n_chunks == 0 || transfer_cycles == 0 {
        return compute_cycles;
    }
    let n = n_chunks as u64;
    let t_chunk = transfer_cycles / n;
    let c_chunk = compute_cycles / n;
    // two resources (transfer engine, compute array) + two buffers:
    // transfer of chunk i may start only once chunk i-2's compute freed
    // its ping-pong bank; compute of chunk i needs its transfer done.
    let mut t_done = vec![0u64; n_chunks];
    let mut c_done = vec![0u64; n_chunks];
    let (mut t_free, mut c_free) = (0u64, 0u64);
    for i in 0..n_chunks {
        let bank_free = if i >= 2 { c_done[i - 2] } else { 0 };
        let t_start = t_free.max(bank_free);
        t_done[i] = t_start + t_chunk;
        t_free = t_done[i];
        let c_start = c_free.max(t_done[i]);
        c_done[i] = c_start + c_chunk;
        c_free = c_done[i];
    }
    c_done[n_chunks - 1]
}

/// Schedule one token: resident configs pay no transfer; streaming
/// configs overlap the Δ-PoT weight stream with compute.
pub fn schedule_token(
    cfg: &AccelConfig,
    compute_cycles: u64,
    stream_bytes: f64,
) -> OverlapReport {
    if cfg.weights_resident || stream_bytes == 0.0 {
        return OverlapReport {
            total_cycles: compute_cycles,
            transfer_cycles: 0,
            compute_cycles,
            bandwidth_utilization: 0.0,
            n_chunks: 0,
        };
    }
    let t = transfer_cycles(cfg, stream_bytes);
    let n_chunks = ((stream_bytes / cfg.chunk_bytes as f64).ceil() as usize).max(1);
    let total = overlap_closed_form(compute_cycles, t, n_chunks);
    OverlapReport {
        total_cycles: total,
        transfer_cycles: t,
        compute_cycles,
        bandwidth_utilization: t as f64 / total as f64,
        n_chunks,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HFRWKV_CONFIGS;

    #[test]
    fn event_sim_validates_closed_form() {
        // the discrete-event pipeline and the closed form must agree to
        // within one chunk of slack for a spread of ratios
        for &(c, t, n) in &[
            (1_000_000u64, 2_000_000u64, 100usize),
            (2_000_000, 1_000_000, 100),
            (1_000_000, 1_000_000, 64),
            (500_000, 5_000_000, 32),
            (5_000_000, 500_000, 32),
            (100, 100, 1),
        ] {
            let ev = overlap_event_sim(c, t, n);
            let cf = overlap_closed_form(c, t, n);
            let chunk = (t / n as u64).max(c / n as u64).max(1);
            assert!(
                (ev as i64 - cf as i64).unsigned_abs() <= chunk + 2,
                "c={c} t={t} n={n}: event {ev} vs closed {cf}"
            );
        }
    }

    #[test]
    fn transfer_bound_utilization_near_one() {
        // 7B-like: transfer 2× compute → utilization must approach 1
        let r = overlap_closed_form(1_000_000, 2_000_000, 128);
        let util = 2_000_000f64 / r as f64;
        assert!(util > 0.97, "{util}");
    }

    #[test]
    fn compute_bound_costs_one_fill() {
        // compute 10× transfer → total = fill + compute
        let c = 10_000_000u64;
        let t = 1_000_000u64;
        let n = 100;
        let total = overlap_closed_form(c, t, n);
        assert!(total <= c + t / n as u64 + (c / n as u64) + 2, "{total}");
        assert!(total >= c);
    }

    #[test]
    fn resident_config_pays_no_transfer() {
        let cfg = &HFRWKV_CONFIGS[0]; // HFRWKV_0, resident
        let r = schedule_token(cfg, 123_456, 1e9);
        assert_eq!(r.total_cycles, 123_456);
        assert_eq!(r.transfer_cycles, 0);
    }

    #[test]
    fn streaming_config_hits_paper_bandwidth_utilization() {
        // E6: at 7B the paper reports 99.95% (U50) bandwidth utilization —
        // the schedule must be transfer-bound with util ≥ 0.99 there.
        let cfg = &HFRWKV_CONFIGS[1]; // HFRWKV_1 on U50
        let shape = crate::config::PAPER_SHAPES[4]; // 7B
        let compute = crate::sim::timing::token_compute_cycles(&shape, cfg, true);
        let bytes = shape.stream_bytes_per_token(9.0);
        let r = schedule_token(cfg, compute, bytes);
        assert!(r.bandwidth_utilization > 0.99, "{}", r.bandwidth_utilization);
    }

    #[test]
    fn transfer_cycles_units() {
        let cfg = &HFRWKV_CONFIGS[1]; // 350 MHz, ~201 GB/s
        // 201 GB at ~201GB/s ≈ 1 s ≈ 350M cycles
        let t = transfer_cycles(cfg, 201e9);
        assert!((t as f64 - 350e6 / 0.9995).abs() / 350e6 < 0.01, "{t}");
    }
}
