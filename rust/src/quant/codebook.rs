//! Nearest-level codebook lookup (binary search over sorted levels).
//!
//! This is the hot inner loop of weight quantization: `nearest` is called
//! once per weight element.  The perf pass replaced a linear scan with
//! `partition_point` binary search (see EXPERIMENTS.md §Perf).

use super::schemes::{self, Scheme};

/// A sorted set of nonnegative magnitude levels with max = 1.0.
#[derive(Clone, Debug)]
pub struct Codebook {
    levels: Vec<f32>,
}

impl Codebook {
    pub fn new(mut levels: Vec<f32>) -> Self {
        levels.sort_by(|a, b| a.partial_cmp(b).unwrap());
        levels.dedup();
        assert!(!levels.is_empty());
        Self { levels }
    }

    /// Build the codebook for a scheme (panics on Fp32/LogQ which have no
    /// nearest-level semantics — LogQ rounds in the log domain).
    pub fn for_scheme(scheme: Scheme) -> Self {
        let lv = match scheme {
            Scheme::Rtn => schemes::rtn_levels(),
            Scheme::Pot => schemes::pot_levels(),
            Scheme::Apot => schemes::apot_levels(),
            Scheme::Dpot => schemes::dpot_levels(),
            Scheme::Fp32 | Scheme::LogQ => {
                panic!("no codebook for {scheme:?}")
            }
        };
        Self::new(lv.into_iter().map(|x| x as f32).collect())
    }

    pub fn levels(&self) -> &[f32] {
        &self.levels
    }

    /// Nearest level to `y` (expects 0 <= y <= 1; values above 1 clamp to
    /// the top level).  Ties round toward the lower level, matching
    /// numpy's `searchsorted`-based python mirror.
    #[inline]
    pub fn nearest(&self, y: f32) -> f32 {
        let lv = &self.levels;
        let idx = lv.partition_point(|&l| l < y).clamp(1, lv.len() - 1);
        let (lo, hi) = (lv[idx - 1], lv[idx]);
        if y - lo < hi - y {
            lo
        } else {
            hi
        }
    }

    /// Mean-squared reconstruction error of this codebook on `data`
    /// (normalized by per-slice max-abs) — used by ablation benches.
    pub fn mse(&self, data: &[f32]) -> f64 {
        let scale = data.iter().fold(0f32, |m, &x| m.max(x.abs()));
        if scale == 0.0 {
            return 0.0;
        }
        data.iter()
            .map(|&x| {
                let q = self.nearest(x.abs() / scale) * scale * x.signum();
                ((x - q) as f64).powi(2)
            })
            .sum::<f64>()
            / data.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nearest_is_truly_nearest() {
        let cb = Codebook::for_scheme(Scheme::Dpot);
        let mut rng = crate::Rng64::new(5);
        for _ in 0..2000 {
            let y = rng.next_f64() as f32;
            let got = cb.nearest(y);
            let brute = cb
                .levels()
                .iter()
                .copied()
                .min_by(|a, b| {
                    (a - y).abs().partial_cmp(&(b - y).abs()).unwrap()
                })
                .unwrap();
            assert!((got - y).abs() <= (brute - y).abs() + 1e-7, "y={y}");
        }
    }

    #[test]
    fn nearest_clamps_out_of_range() {
        let cb = Codebook::for_scheme(Scheme::Rtn);
        assert_eq!(cb.nearest(2.0), 1.0);
        assert_eq!(cb.nearest(0.0), 0.0);
    }

    #[test]
    fn mse_zero_on_exact_levels() {
        let cb = Codebook::new(vec![0.0, 0.5, 1.0]);
        let data = [0.0f32, 0.5, 1.0, -0.5, -1.0];
        assert!(cb.mse(&data) < 1e-12);
    }

    #[test]
    fn dpot_lower_mse_than_pot_on_gaussian() {
        let mut rng = crate::Rng64::new(9);
        let data: Vec<f32> = (0..50_000).map(|_| rng.normal() as f32 * 0.02).collect();
        let dpot = Codebook::for_scheme(Scheme::Dpot).mse(&data);
        let pot = Codebook::for_scheme(Scheme::Pot).mse(&data);
        assert!(dpot < pot * 0.25, "dpot {dpot} pot {pot}");
    }
}
