//! Packed Δ-PoT weight planes: the storage format the throughput
//! backend ([`crate::model::PackedModel`]) streams at inference time.
//!
//! [`crate::quant::DpotTensor`] keeps one `DpotCode` struct (3 bytes +
//! padding) per weight and is decoded to f32 before any matmul touches
//! it; a [`PackedPlane`] keeps the 9-bit storage word itself
//! (`DpotCode::pack`: `sign<<8 | dq0<<4 | dq1`) in a dense `Vec<u16>`,
//! 2 bytes per weight — half the f32 traffic per decode cycle — and the
//! packed kernels ([`crate::model::packed_gemm`]) consume the words
//! directly, decoding in-register.
//!
//! Each plane also carries a 512-entry f32 lookup table
//! (`lut[word] == DpotCode::unpack(word).value(gamma)`): the scalar
//! oracle kernel and the SIMD kernel's remainder loops decode through
//! it, and because `pack`/`unpack` round-trip exactly, `lut[pack(c)]`
//! is bit-identical to the `c.value(gamma)` grid the hw backend's
//! decoded planes hold — the anchor of the packed↔hw 0-ULP parity.

use super::dpot::{DpotCode, DpotTensor};

/// One weight matrix stored as packed Δ-PoT codes plus its decode LUT.
#[derive(Clone, Debug)]
pub struct PackedPlane {
    /// row-major `[rows * cols]` packed 9-bit words (in u16 storage)
    pub codes: Vec<u16>,
    /// `lut[w] = unpack(w).value(gamma)` for every 9-bit word (512
    /// entries, so any `codes` element indexes in-bounds)
    pub lut: Vec<f32>,
    /// per-tensor scale (max|w| / 1.5, the top Δ-PoT magnitude)
    pub gamma: f32,
    pub rows: usize,
    pub cols: usize,
}

impl PackedPlane {
    /// Encode a row-major `rows x cols` f32 matrix (via
    /// [`DpotTensor::encode`], so the realized value grid is the same
    /// one the hw backend decodes).
    pub fn encode(w: &[f32], rows: usize, cols: usize) -> PackedPlane {
        PackedPlane::from_tensor(&DpotTensor::encode(w, rows, cols))
    }

    /// Pack an already-encoded tensor.
    pub fn from_tensor(t: &DpotTensor) -> PackedPlane {
        let codes: Vec<u16> = t.codes.iter().map(|c| c.pack()).collect();
        let lut: Vec<f32> =
            (0..512u16).map(|w| DpotCode::unpack(w).value(t.gamma)).collect();
        PackedPlane { codes, lut, gamma: t.gamma, rows: t.rows, cols: t.cols }
    }

    /// Decode one row into `out` (length `cols`) through the LUT —
    /// exactly the values the packed kernels compute with.
    pub fn decode_row(&self, r: usize, out: &mut [f32]) {
        let row = &self.codes[r * self.cols..(r + 1) * self.cols];
        for (o, &w) in out.iter_mut().zip(row) {
            *o = self.lut[w as usize];
        }
    }

    /// Decode the whole plane (tests / parity anchors only — the hot
    /// path never materializes this).
    pub fn decode(&self) -> Vec<f32> {
        self.codes.iter().map(|&w| self.lut[w as usize]).collect()
    }

    /// Bytes actually streamed per full pass over the plane: 2 per
    /// weight (the u16 words; the 2 KiB LUT stays cache-resident).
    pub fn storage_bytes(&self) -> u64 {
        self.codes.len() as u64 * 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn packed_decode_matches_tensor_decode_bitexact() {
        let mut rng = crate::Rng64::new(11);
        let w: Vec<f32> = (0..37 * 23).map(|_| rng.normal() as f32 * 0.3).collect();
        let t = DpotTensor::encode(&w, 37, 23);
        let p = PackedPlane::from_tensor(&t);
        let dt = t.decode();
        let dp = p.decode();
        assert_eq!(dt.len(), dp.len());
        for (i, (a, b)) in dt.iter().zip(&dp).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "weight {i}: {a} vs {b}");
        }
        // row decode agrees with the flat decode
        let mut row = vec![0f32; 23];
        p.decode_row(5, &mut row);
        assert_eq!(&dp[5 * 23..6 * 23], &row[..]);
    }

    #[test]
    fn lut_matches_value_grid_for_every_canonical_word() {
        let t = DpotTensor::encode(&[0.9f32, -0.4, 0.0, 0.2], 2, 2);
        let p = PackedPlane::from_tensor(&t);
        for dq0 in 0..16u8 {
            for dq1 in 0..16u8 {
                for sign in [-1i8, 1] {
                    let c = DpotCode { sign: if dq0 == 0 { 0 } else { sign }, dq0, dq1 };
                    assert_eq!(
                        p.lut[c.pack() as usize].to_bits(),
                        c.value(t.gamma).to_bits(),
                        "{c:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn storage_is_two_bytes_per_weight() {
        let p = PackedPlane::encode(&[0.5f32; 64], 8, 8);
        assert_eq!(p.storage_bytes(), 128);
    }
}
