//! Fixed-point value type used by the bit-accurate hardware models.
//!
//! The accelerator keeps activations at 9 bits (sign + 8) and runs the
//! complex-function units at 16-bit internal precision (§3.2).  `Fixed`
//! carries an `i32` raw value + fractional-bit count and saturates on
//! conversion, mirroring the RTL's overflow-protection ("not explicitly
//! shown in the diagram", §4.2 — here it is).

/// A saturating fixed-point value: `value = raw * 2^-frac`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Fixed {
    pub raw: i32,
    pub frac: u8,
}

impl Fixed {
    /// Quantize an f64 into a `bits`-wide signed fixed-point value with
    /// `frac` fractional bits (round-to-nearest, saturating).
    pub fn from_f64(x: f64, bits: u32, frac: u8) -> Self {
        let max = (1i64 << (bits - 1)) - 1;
        let raw = (x * (1u64 << frac) as f64).round() as i64;
        Self { raw: raw.clamp(-max, max) as i32, frac }
    }

    pub fn to_f64(self) -> f64 {
        self.raw as f64 / (1u64 << self.frac) as f64
    }

    /// Re-scale to a different fractional precision (arithmetic shift —
    /// exactly what the RTL's alignment barrel shifters do).
    pub fn rescale(self, frac: u8) -> Self {
        let raw = if frac >= self.frac {
            (self.raw as i64) << (frac - self.frac)
        } else {
            (self.raw as i64) >> (self.frac - frac)
        };
        Self { raw: sat16x(raw, 32), frac }
    }

    /// Saturating add at the given bit width.
    pub fn sat_add(self, other: Fixed, bits: u32) -> Self {
        assert_eq!(self.frac, other.frac);
        let sum = self.raw as i64 + other.raw as i64;
        Fixed { raw: sat16x(sum, bits), frac: self.frac }
    }
}

/// Saturate an i64 into a `bits`-wide signed integer.
pub fn sat16x(x: i64, bits: u32) -> i32 {
    let max = if bits >= 32 { i32::MAX as i64 } else { (1i64 << (bits - 1)) - 1 };
    x.clamp(-max, max) as i32
}

/// Saturate into the 16-bit internal width of the complex units.
#[inline]
pub fn sat16(x: i64) -> i32 {
    sat16x(x, 16)
}

/// Saturate into the 9-bit activation width.
#[inline]
pub fn sat9(x: i64) -> i32 {
    sat16x(x, 9)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_within_half_ulp() {
        for frac in [4u8, 8, 12] {
            for i in -100..100 {
                let x = i as f64 * 0.37;
                let f = Fixed::from_f64(x, 16, frac);
                let ulp = 1.0 / (1u64 << frac) as f64;
                if x.abs() < (1 << (15 - frac)) as f64 {
                    assert!((f.to_f64() - x).abs() <= ulp / 2.0 + 1e-12);
                }
            }
        }
    }

    #[test]
    fn saturation_at_bit_width() {
        let f = Fixed::from_f64(1e9, 16, 8);
        assert_eq!(f.raw, (1 << 15) - 1);
        let f = Fixed::from_f64(-1e9, 9, 0);
        assert_eq!(f.raw, -255);
    }

    #[test]
    fn rescale_shifts() {
        let f = Fixed { raw: 256, frac: 8 }; // 1.0
        assert_eq!(f.rescale(12).raw, 4096);
        assert_eq!(f.rescale(4).raw, 16);
        assert!((f.rescale(12).to_f64() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn sat_add_saturates() {
        let a = Fixed { raw: 30_000, frac: 8 };
        let b = Fixed { raw: 10_000, frac: 8 };
        assert_eq!(a.sat_add(b, 16).raw, 32_767);
    }

    #[test]
    fn sat9_range() {
        assert_eq!(sat9(300), 255);
        assert_eq!(sat9(-300), -255);
        assert_eq!(sat9(100), 100);
    }
}
