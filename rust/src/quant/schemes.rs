//! Codebook level-set constructors for every quantization scheme of the
//! Table 1 ablation.  Levels are *nonnegative magnitudes normalized so the
//! largest is 1.0*; per-tensor scaling maps max|w| onto that top level.
//!
//! These must match `python/compile/quantize.py` bit-for-bit — a golden
//! test compares against `artifacts/quant_codebooks.json`.



/// Quantization scheme selector (Table 1 rows; Fp32 = the FP16 baseline
/// row, lossless at our f32 working precision).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Scheme {
    Fp32,
    Rtn,
    Pot,
    LogQ,
    Apot,
    Dpot,
}

impl Scheme {
    pub const ALL_QUANT: [Scheme; 5] =
        [Scheme::Rtn, Scheme::Pot, Scheme::LogQ, Scheme::Apot, Scheme::Dpot];

    pub fn name(self) -> &'static str {
        match self {
            Scheme::Fp32 => "FP16",
            Scheme::Rtn => "RTN",
            Scheme::Pot => "PoT",
            Scheme::LogQ => "LogQ",
            Scheme::Apot => "APoT",
            Scheme::Dpot => "Proposed",
        }
    }
}

/// RTN: uniform symmetric 9-bit — 255 positive levels plus zero.
pub fn rtn_levels() -> Vec<f64> {
    (0..=255).map(|i| i as f64 / 255.0).collect()
}

/// PoT: {0} ∪ {2^-e} for e in 0..256 (sign + 8-bit exponent; deep
/// underflow collapses to ~0 exactly like the paper's single-term format).
pub fn pot_levels() -> Vec<f64> {
    let mut lv: Vec<f64> = (0..256).map(|e| (-(e as f64)).exp2()).collect();
    lv.push(0.0);
    lv.sort_by(|a, b| a.partial_cmp(b).unwrap());
    lv.dedup();
    lv
}

/// APoT (eq 4) with k=4, n=2: p_i ∈ {0, 2^-i, 2^-(i+2), ..., 2^-(i+28)}.
pub fn apot_levels() -> Vec<f64> {
    let term = |i: u32| -> Vec<f64> {
        let mut v = vec![0.0];
        for j in 0..15u32 {
            v.push((-(i as f64) - 2.0 * j as f64).exp2());
        }
        v
    };
    let (t0, t1) = (term(0), term(1));
    let mut lv: Vec<f64> = t0
        .iter()
        .flat_map(|a| t1.iter().map(move |b| a + b))
        .collect();
    lv.sort_by(|a, b| a.partial_cmp(b).unwrap());
    lv.dedup();
    let max = *lv.last().unwrap();
    lv.iter().map(|x| x / max).collect()
}

/// Δ-PoT (eq 5–6) with k0=k1=4: level = 2·(p0 + p1),
/// p0 = 2^-dq0 (dq0∈1..15; 0 ⇒ p0=0), p1 = p0·2^-dq1 (dq1∈1..15; 0 ⇒ 0).
pub fn dpot_levels() -> Vec<f64> {
    let mut lv = vec![0.0f64];
    for dq0 in 1..16u32 {
        let p0 = (-(dq0 as f64)).exp2();
        lv.push(2.0 * p0);
        for dq1 in 1..16u32 {
            lv.push(2.0 * (p0 + p0 * (-(dq1 as f64)).exp2()));
        }
    }
    lv.sort_by(|a, b| a.partial_cmp(b).unwrap());
    lv.dedup();
    let max = *lv.last().unwrap();
    lv.iter().map(|x| x / max).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rtn_uniform_spacing() {
        let lv = rtn_levels();
        assert_eq!(lv.len(), 256);
        for w in lv.windows(2) {
            assert!((w[1] - w[0] - 1.0 / 255.0).abs() < 1e-15);
        }
    }

    #[test]
    fn pot_levels_are_pure_powers() {
        for &l in pot_levels().iter().filter(|&&l| l > 0.0) {
            let e = l.log2().round();
            assert!((l - e.exp2()).abs() < 1e-300);
        }
    }

    #[test]
    fn dpot_denser_than_apot_near_top() {
        // the paper's argument: Δ-PoT's unit-stride exponents give denser
        // levels in the high-magnitude region than APoT's stride-2.
        let count_above = |lv: &[f64], t: f64| lv.iter().filter(|&&x| x >= t).count();
        let d = dpot_levels();
        let a = apot_levels();
        assert!(count_above(&d, 0.25) > count_above(&a, 0.25));
    }

    #[test]
    fn paper_example_value_representable() {
        // §3.1 example: γ(2^0 + 2^-2) = 1.25γ is exactly 2γ(2^-1 + 2^-3).
        // In normalized (max=1) coordinates: 1.25/1.5.
        let target = 1.25 / 1.5;
        assert!(
            dpot_levels().iter().any(|&l| (l - target).abs() < 1e-12),
            "Δ-PoT must represent the paper's example exactly"
        );
    }

    #[test]
    fn level_sets_sorted_unique_max1() {
        for lv in [rtn_levels(), pot_levels(), apot_levels(), dpot_levels()] {
            assert!(lv.windows(2).all(|w| w[1] > w[0]));
            assert_eq!(*lv.last().unwrap(), 1.0);
            assert_eq!(lv[0], 0.0);
        }
    }

    #[test]
    fn dpot_count_within_9bit_budget() {
        let n = dpot_levels().len();
        assert!(n <= 1 + 15 * 16, "{n}");
        assert!(n >= 100, "{n}");
    }
}
