//! Quantization (§3): the Δ-PoT scheme, its PACKED inference-time
//! storage, the Table 1 comparators (RTN, PoT, LogQ, APoT), fixed-point
//! helpers, and fake-quant application to whole weight sets.
//!
//! # The packed inference path
//!
//! The serving hot path consumes Δ-PoT weights in three stages:
//!
//! 1. [`DpotTensor::encode`] maps each f32 matrix to 9-bit codes
//!    (`sign · 2γ · (2^-dq0 + 2^-dq0-dq1)`, eqs 5–6) with one f32 γ per
//!    tensor — 241 distinct magnitudes, nearest-code assignment.
//! 2. [`PackedPlane`] stores the packed words ([`DpotCode::pack`]:
//!    `sign<<8 | dq0<<4 | dq1`) in a dense `Vec<u16>` — **2 bytes per
//!    weight streamed** vs 4 for f32, the traffic cut that makes the
//!    quantized model the *throughput* configuration (the paper's 9-bit
//!    URAM layout rounds to 16-bit words in software so SIMD lanes stay
//!    aligned; the on-disk/URAM format is still 9 bits + γ,
//!    [`DpotTensor::storage_bits`]).  Each plane carries a 512-entry
//!    decode LUT pinning the exact f32 value grid.
//! 3. `model::packed_gemm` multiplies straight on the words — AVX2
//!    in-register decode with a scalar decode-through-LUT oracle, both
//!    bit-identical to f32 matmul over the decoded plane.
//!
//! # Which weights stay f32 (the RWKVQuant hybrid argument)
//!
//! Only the seven per-layer projection matrices, the embedding and the
//! head are Δ-PoT coded.  The *vector* weights — LayerNorm affines,
//! token-shift mix factors, decay/first — quantize 9-bit **uniform**
//! and are retained at f32 precision in storage: they are O(d) per
//! layer (negligible traffic next to the O(d²) planes), and RWKVQuant's
//! analysis (PAPERS.md) shows RWKV's highly non-uniform vector weights
//! are exactly where exponent-grid (PoT-family) quantizers fail — its
//! hybrid scheme keeps vector-class parameters on a finer grid for the
//! same reason the paper's per-site scheme leaves them out of the
//! Δ-PoT budget.  Activations are 9-bit uniform at per-site calibrated
//! scales (`model::rwkv_hw`), never stored.
//!
//! Every scheme is held to the same 9-bit storage budget the paper's
//! ablation uses ("equivalent W9A9"): RTN = sign+8 uniform, PoT/LogQ =
//! sign + 8-bit exponent, APoT/Δ-PoT = sign + two 4-bit terms.

mod codebook;
mod dpot;
pub mod fixed;
mod packed;
mod schemes;

pub use codebook::Codebook;
pub use dpot::{DpotCode, DpotTensor, DPOT_K0, DPOT_K1};
pub use fixed::Fixed;
pub use packed::PackedPlane;
pub use schemes::{apot_levels, dpot_levels, pot_levels, rtn_levels, Scheme};

/// Fake-quantize a weight tensor in place under `scheme` (per-tensor
/// max-abs scale).  Returns the scale used.
pub fn fake_quant(w: &mut [f32], scheme: Scheme) -> f32 {
    let scale = w.iter().fold(0f32, |m, &x| m.max(x.abs()));
    if scale == 0.0 {
        return 0.0;
    }
    match scheme {
        Scheme::Fp32 => {}
        Scheme::LogQ => {
            // log-domain rounding (assignment differs from PoT's
            // nearest-in-linear even though the level set is identical)
            for x in w.iter_mut() {
                if *x == 0.0 {
                    continue;
                }
                let y = (x.abs() / scale) as f64;
                let e = (-y.log2()).round().clamp(0.0, 255.0);
                let lv = (-e).exp2();
                *x = x.signum() * (lv as f32) * scale;
            }
        }
        _ => {
            let cb = Codebook::for_scheme(scheme);
            for x in w.iter_mut() {
                let y = x.abs() / scale;
                *x = x.signum() * cb.nearest(y) * scale;
            }
        }
    }
    scale
}

/// Uniform symmetric quantization of activations (paper §3.2: 9 bits).
/// Returns the dequantized value grid the hardware would see.
pub fn quant_activation(x: f32, scale: f32, bits: u32) -> f32 {
    if scale <= 0.0 {
        return 0.0;
    }
    let qmax = ((1i32 << (bits - 1)) - 1) as f32;
    let q = (x / scale * qmax).round().clamp(-qmax, qmax);
    q * scale / qmax
}

/// Vector form of [`quant_activation`].
pub fn quant_activations(xs: &mut [f32], scale: f32, bits: u32) {
    for x in xs.iter_mut() {
        *x = quant_activation(*x, scale, bits);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fake_quant_preserves_sign_and_bound() {
        let mut rng = crate::Rng64::new(3);
        for scheme in [Scheme::Rtn, Scheme::Pot, Scheme::LogQ, Scheme::Apot, Scheme::Dpot] {
            let orig: Vec<f32> = (0..512).map(|_| rng.normal() as f32 * 0.05).collect();
            let mut w = orig.clone();
            fake_quant(&mut w, scheme);
            let max = orig.iter().fold(0f32, |m, &x| m.max(x.abs()));
            for (a, b) in orig.iter().zip(&w) {
                assert!(b.abs() <= max * 1.0001, "{scheme:?}");
                assert!(a.signum() == b.signum() || *b == 0.0, "{scheme:?}: {a} -> {b}");
            }
        }
    }

    #[test]
    fn fp32_scheme_is_identity() {
        let mut w = vec![0.1f32, -0.5, 0.025];
        let orig = w.clone();
        fake_quant(&mut w, Scheme::Fp32);
        assert_eq!(w, orig);
    }

    #[test]
    fn activation_quant_grid() {
        // 9-bit: 255 positive levels; error <= scale/255/2
        let scale = 4.0f32;
        for i in 0..1000 {
            let x = -4.0 + 8.0 * (i as f32) / 1000.0;
            let q = quant_activation(x, scale, 9);
            assert!((q - x).abs() <= scale / 255.0 / 2.0 + 1e-6);
        }
    }

    #[test]
    fn activation_quant_saturates() {
        assert_eq!(quant_activation(100.0, 1.0, 9), 1.0);
        assert_eq!(quant_activation(-100.0, 1.0, 9), -1.0);
    }

    #[test]
    fn mse_ordering_matches_paper_story() {
        // Table 1 at codebook level: dpot ~ rtn << pot; dpot < logq.
        let mut rng = crate::Rng64::new(11);
        let w: Vec<f32> = (0..100_000).map(|_| rng.normal() as f32 * 0.02).collect();
        let mse = |scheme: Scheme| -> f64 {
            let mut q = w.clone();
            fake_quant(&mut q, scheme);
            w.iter().zip(&q).map(|(a, b)| ((a - b) as f64).powi(2)).sum::<f64>()
                / w.len() as f64
        };
        let (rtn, pot, logq, dpot) =
            (mse(Scheme::Rtn), mse(Scheme::Pot), mse(Scheme::LogQ), mse(Scheme::Dpot));
        assert!(dpot < pot * 0.25, "dpot {dpot} pot {pot}");
        assert!(dpot < logq * 0.25, "dpot {dpot} logq {logq}");
        assert!(rtn < pot, "rtn {rtn} pot {pot}");
    }
}
