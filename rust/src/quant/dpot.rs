//! Δ-PoT code-level encoding (§3.1, eqs 5–6): the storage format the
//! accelerator actually keeps in URAM and feeds to the PMAC shift-add
//! datapath — not just the fake-quant value grid.
//!
//! Each weight is `sign · 2γ · (p0 + p1)` with `p0 = 2^-dq0` (0 if dq0=0)
//! and `p1 = p0 · 2^-dq1` (0 if dq1=0).  With k0 = k1 = 4 the stored code
//! is 9 bits: 1 sign + 4 + 4 — the differential encoding (`dq1` is the
//! *difference* q1 - q0) is what widens the representable exponent range
//! at fixed bits.

use std::sync::OnceLock;

pub const DPOT_K0: u32 = 4;
pub const DPOT_K1: u32 = 4;

/// One encoded weight: (sign ∈ {-1,0,1}, dq0 ∈ 0..16, dq1 ∈ 0..16).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DpotCode {
    pub sign: i8,
    pub dq0: u8,
    pub dq1: u8,
}

impl DpotCode {
    pub const ZERO: DpotCode = DpotCode { sign: 0, dq0: 0, dq1: 0 };

    /// Decode to the magnitude level in [0, 1.5] (before γ scaling):
    /// 2·(p0 + p1).
    #[inline]
    pub fn magnitude(self) -> f64 {
        if self.dq0 == 0 {
            return 0.0;
        }
        let p0 = (-(self.dq0 as f64)).exp2();
        let p1 = if self.dq1 == 0 { 0.0 } else { p0 * (-(self.dq1 as f64)).exp2() };
        2.0 * (p0 + p1)
    }

    /// Decode to a signed value given the tensor scale γ.
    #[inline]
    pub fn value(self, gamma: f32) -> f32 {
        self.sign as f32 * self.magnitude() as f32 * gamma
    }

    /// Pack into the 9-bit storage word (sign | dq0 | dq1).
    pub fn pack(self) -> u16 {
        let s = if self.sign < 0 { 1u16 } else { 0 };
        (s << 8) | ((self.dq0 as u16) << 4) | self.dq1 as u16
    }

    pub fn unpack(w: u16) -> Self {
        let dq0 = ((w >> 4) & 0xF) as u8;
        let dq1 = (w & 0xF) as u8;
        let sign = if dq0 == 0 { 0 } else if (w >> 8) & 1 == 1 { -1 } else { 1 };
        DpotCode { sign, dq0, dq1 }
    }
}

/// Sorted (magnitude, code) table for nearest-code encoding: 241
/// distinct magnitudes, built ONCE behind a `OnceLock` — a whole-model
/// load encodes ~`n_layer·7 + 2` tensors and used to re-allocate and
/// re-sort this table for every one of them.  (Magnitudes are finite by
/// construction, so `total_cmp` orders them identically to the partial
/// order while staying NaN-total.)
fn code_table() -> &'static [(f64, DpotCode)] {
    static TABLE: OnceLock<Vec<(f64, DpotCode)>> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut t = vec![(0.0, DpotCode::ZERO)];
        for dq0 in 1..16u8 {
            for dq1 in 0..16u8 {
                let c = DpotCode { sign: 1, dq0, dq1 };
                t.push((c.magnitude(), c));
            }
        }
        t.sort_by(|a, b| a.0.total_cmp(&b.0));
        t.dedup_by(|a, b| a.0 == b.0);
        t
    })
}

/// A whole tensor encoded in Δ-PoT: code planes + per-tensor γ.
///
/// γ is chosen so max|w| maps to the largest representable magnitude
/// (2·(2^-1 + 2^-2) = 1.5), exactly like the fake-quant path.
#[derive(Clone, Debug)]
pub struct DpotTensor {
    pub codes: Vec<DpotCode>,
    pub gamma: f32,
    pub rows: usize,
    pub cols: usize,
}

impl DpotTensor {
    /// Encode a row-major `rows x cols` matrix.
    pub fn encode(w: &[f32], rows: usize, cols: usize) -> Self {
        assert_eq!(w.len(), rows * cols);
        let table = code_table();
        let max = w.iter().fold(0f32, |m, &x| m.max(x.abs()));
        let top = table.last().unwrap().0 as f32; // 1.5
        let gamma = if max == 0.0 { 1.0 } else { max / top };
        let codes = w
            .iter()
            .map(|&x| {
                if x == 0.0 || max == 0.0 {
                    return DpotCode::ZERO;
                }
                let y = (x.abs() / gamma) as f64;
                let idx = table.partition_point(|&(m, _)| m < y).clamp(1, table.len() - 1);
                let (lo, hi) = (table[idx - 1], table[idx]);
                let mut c = if y - lo.0 < hi.0 - y { lo.1 } else { hi.1 };
                if c.dq0 != 0 {
                    c.sign = if x < 0.0 { -1 } else { 1 };
                }
                c
            })
            .collect();
        Self { codes, gamma, rows, cols }
    }

    /// Decode back to f32 (the values the PMAC arithmetic realizes).
    pub fn decode(&self) -> Vec<f32> {
        self.codes.iter().map(|c| c.value(self.gamma)).collect()
    }

    /// Storage footprint in bits (9 per weight + γ).
    pub fn storage_bits(&self) -> u64 {
        self.codes.len() as u64 * 9 + 32
    }

    #[inline]
    pub fn code(&self, r: usize, c: usize) -> DpotCode {
        self.codes[r * self.cols + c]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_unpack_roundtrip() {
        for dq0 in 0..16u8 {
            for dq1 in 0..16u8 {
                for sign in [-1i8, 1] {
                    let c = DpotCode { sign: if dq0 == 0 { 0 } else { sign }, dq0, dq1 };
                    assert_eq!(DpotCode::unpack(c.pack()), c);
                }
            }
        }
    }

    #[test]
    fn zero_code_is_zero() {
        assert_eq!(DpotCode::ZERO.magnitude(), 0.0);
        assert_eq!(DpotCode { sign: 0, dq0: 0, dq1: 7 }.magnitude(), 0.0);
    }

    #[test]
    fn encode_decode_matches_fake_quant() {
        // code-level encode→decode must land on the same grid as the
        // fake-quant path (same level set, same scale rule)
        let mut rng = crate::Rng64::new(4);
        let w: Vec<f32> = (0..1024).map(|_| rng.normal() as f32 * 0.1).collect();
        let enc = DpotTensor::encode(&w, 32, 32);
        let dec = enc.decode();
        let mut fq = w.clone();
        super::super::fake_quant(&mut fq, super::super::Scheme::Dpot);
        for (a, b) in dec.iter().zip(&fq) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn encode_error_bounded() {
        let mut rng = crate::Rng64::new(8);
        let w: Vec<f32> = (0..4096).map(|_| rng.normal() as f32).collect();
        let enc = DpotTensor::encode(&w, 64, 64);
        let dec = enc.decode();
        let max = w.iter().fold(0f32, |m, &x| m.max(x.abs()));
        for (a, b) in w.iter().zip(&dec) {
            // worst gap in the Δ-PoT level set is < 25% of magnitude near
            // the top and absolute 2γ·2^-15 near zero
            assert!((a - b).abs() <= 0.15 * max, "{a} -> {b}");
        }
    }

    #[test]
    fn storage_is_nine_bits_per_weight() {
        let enc = DpotTensor::encode(&[0.5f32; 64], 8, 8);
        assert_eq!(enc.storage_bits(), 64 * 9 + 32);
    }
}
