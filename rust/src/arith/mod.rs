//! Bit-accurate models of the HFRWKV function units (§4).
//!
//! Everything in this module operates on integers exactly the way the RTL
//! would: barrel shifts, saturating adds, LUT reads — no floating point on
//! any datapath (f32/f64 appear only in constructors that *fill* LUTs,
//! which is a ROM-generation step, and in test oracles).
//!
//! * [`lod`]         — leading-one detector, Algorithm 1.
//! * [`shift_add`]   — barrel shifter + ShiftAddition unit (×log₂e, PWL
//!   slopes as dyadic-fraction sums).
//! * [`divu`]        — unsigned division unit (Fig 5a): LOD normalize,
//!   4×4-bit 2D-LUT mantissa divide, exponent recombination.
//! * [`exp_sigmoid`] — reusable EXP–σ unit (Fig 5b): mode 0 = e^x via
//!   256-entry EXP-LUT, mode 1 = sigmoid via eq (9) PWL.
//! * [`pmac`]        — Δ-PoT multiplier (Fig 4c) + PMAC accumulation and
//!   the three MV-array modes (§4.2).
//! * [`adder_tree`]  — ATAC (addition tree + accumulator) reductions and
//!   the integer LayerNorm datapath (Fig 6).

pub mod adder_tree;
pub mod divu;
pub mod exp_sigmoid;
pub mod lod;
pub mod pmac;
pub mod shift_add;

pub use adder_tree::{atac_sum, isqrt, LayerNormUnit};
pub use divu::Divu;
pub use exp_sigmoid::ExpSigmoidUnit;
pub use lod::lod;
pub use pmac::{dpot_mul, MvArray, Pmac};
