//! Barrel shifter + the shared ShiftAddition unit (§4.4).
//!
//! "All fixed-constant multiplications — whether by log₂e or by segment
//! slopes — are replaced by a dedicated ShiftAddition unit [which]
//! dynamically selects and combines bit-shifted operands."  A constant is
//! expressed as a short signed sum of dyadic fractions ±2^-k; multiplying
//! is then a handful of barrel shifts and adds.

/// Arithmetic barrel shift: positive `sh` shifts left, negative right.
/// Mirrors a bidirectional barrel shifter with sign extension.
#[inline]
pub fn barrel(x: i64, sh: i32) -> i64 {
    if sh >= 64 {
        0
    } else if sh >= 0 {
        x << sh
    } else if sh <= -64 {
        if x < 0 { -1 } else { 0 }
    } else {
        x >> (-sh)
    }
}

/// One term of a shift-add constant: `sign * 2^shift` (shift may be
/// negative for fractional terms).
#[derive(Clone, Copy, Debug)]
pub struct DyadicTerm {
    pub sign: i8,
    pub shift: i32,
}

/// A constant expressed as Σ sign·2^shift, evaluated by the ShiftAddition
/// unit.  `apply` computes x·constant exactly in integer arithmetic.
#[derive(Clone, Debug)]
pub struct ShiftAddConst {
    pub terms: Vec<DyadicTerm>,
}

impl ShiftAddConst {
    pub fn new(terms: &[(i8, i32)]) -> Self {
        Self { terms: terms.iter().map(|&(sign, shift)| DyadicTerm { sign, shift }).collect() }
    }

    /// The constant's value (for tests / documentation).
    pub fn value(&self) -> f64 {
        self.terms.iter().map(|t| t.sign as f64 * (t.shift as f64).exp2()).sum()
    }

    /// x · constant via shifts and adds (exact when all shifts >= 0;
    /// truncating like the RTL when fractional).
    #[inline]
    pub fn apply(&self, x: i64) -> i64 {
        self.terms
            .iter()
            .map(|t| t.sign as i64 * barrel(x, t.shift))
            .sum()
    }
}

/// log₂e ≈ 1.0111₂ = 1 + 1/2 - 1/16 (paper eq 8: "a single addition, one
/// subtraction, and two shift operations").
pub fn log2e_const() -> ShiftAddConst {
    ShiftAddConst::new(&[(1, 0), (1, -1), (-1, -4)])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn barrel_both_directions() {
        assert_eq!(barrel(5, 3), 40);
        assert_eq!(barrel(40, -3), 5);
        assert_eq!(barrel(-40, -3), -5);
        assert_eq!(barrel(-1, -10), -1); // arithmetic shift keeps sign
        assert_eq!(barrel(123, 64), 0);
    }

    #[test]
    fn log2e_value() {
        assert!((log2e_const().value() - 1.4375).abs() < 1e-15);
        // binary: 1.0111
        assert!((1.4375f64 - (1.0 + 0.25 + 0.125 + 0.0625)).abs() < 1e-15);
    }

    #[test]
    fn apply_matches_multiplication_for_integral_terms() {
        let c = ShiftAddConst::new(&[(1, 2), (1, 0), (-1, 1)]); // 4+1-2 = 3
        for x in -100i64..100 {
            assert_eq!(c.apply(x), 3 * x);
        }
    }

    #[test]
    fn apply_log2e_truncation_error_bounded() {
        // applying to a Q8.8 value: error vs exact multiply is < 2 ulp
        let c = log2e_const();
        for i in -32_768i64..32_768 {
            let got = c.apply(i);
            let want = (i as f64 * 1.4375).floor();
            assert!((got as f64 - want).abs() <= 2.0, "i={i} got={got} want={want}");
        }
    }
}
