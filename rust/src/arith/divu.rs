//! Unsigned Division Unit (§4.3, Fig 5a).
//!
//! Three pipeline stages:
//!  1. normalization + LOD:  X = 2^k1·x, Y = 2^k2·y with x,y ∈ [1,2)
//!  2. fractional division:  x/y from a 256-entry 2D-LUT indexed by the
//!     four MSBs after each leading one (4×4-bit indexing, 8-bit output)
//!  3. recombination:        Q = (x/y) << (k1 - k2)
//!
//! The LUT is a ROM generated once at construction — the only place float
//! math appears.  The datapath itself is integer shifts and a table read.

use super::lod::lod;

/// Pipeline depth (cycles) of the unit — used by the cycle model.
pub const DIVU_STAGES: u32 = 3;

/// The unsigned division unit with its 2D mantissa LUT.
pub struct Divu {
    /// lut[mx*16+my] = round( (16+mx)/(16+my) * 256 ), 9-bit values
    /// in [128, 496] stored in u16 ROM words.
    lut: [u16; 256],
}

impl Default for Divu {
    fn default() -> Self {
        Self::new()
    }
}

impl Divu {
    pub fn new() -> Self {
        let mut lut = [0u16; 256];
        for mx in 0..16u32 {
            for my in 0..16u32 {
                let q = (16 + mx) as f64 / (16 + my) as f64;
                lut[(mx * 16 + my) as usize] = (q * 256.0).round() as u16;
            }
        }
        Self { lut }
    }

    /// 4-bit mantissa index: the four bits right below the leading one.
    #[inline]
    fn mantissa4(x: u32, k: u32) -> u32 {
        if k >= 4 {
            (x >> (k - 4)) & 0xF
        } else {
            (x << (4 - k)) & 0xF
        }
    }

    /// Divide two nonzero unsigned integers; result returned as a raw
    /// fixed-point value with `out_frac` fractional bits.
    ///
    /// Returns 0 when the dividend is 0; saturates when the denominator
    /// is 0 (the RTL guards this upstream).
    pub fn div(&self, x: u32, y: u32, out_frac: u8) -> i64 {
        if x == 0 {
            return 0;
        }
        let Some(k2) = lod(y, 32) else {
            return i64::MAX; // divide-by-zero guard
        };
        let k1 = lod(x, 32).unwrap();
        // stage 2: LUT mantissa division (8-bit fractional quotient)
        let mx = Self::mantissa4(x, k1);
        let my = Self::mantissa4(y, k2);
        let frac = self.lut[(mx * 16 + my) as usize] as i64;
        // stage 3: recombination — Q = frac · 2^(k1-k2-8+out_frac)
        let sh = k1 as i32 - k2 as i32 - 8 + out_frac as i32;
        super::shift_add::barrel(frac, sh)
    }

    /// Signed wrapper: sign-bit separation happens before the DIVU
    /// (paper §4.3), recombined on the way out.
    pub fn div_signed(&self, x: i32, y: i32, out_frac: u8) -> i64 {
        let s = if (x < 0) ^ (y < 0) { -1 } else { 1 };
        s * self.div(x.unsigned_abs(), y.unsigned_abs(), out_frac)
    }

    /// Float convenience view for model-level use: divide two positive
    /// reals carried at `in_frac` fixed-point bits.
    pub fn div_f64(&self, x: f64, y: f64, in_frac: u8) -> f64 {
        let xi = (x * (1u64 << in_frac) as f64).round() as i64;
        let yi = (y * (1u64 << in_frac) as f64).round() as i64;
        if xi <= 0 {
            return 0.0;
        }
        if yi <= 0 {
            return f64::INFINITY;
        }
        const OF: u8 = 24;
        self.div(xi as u32, yi as u32, OF) as f64 / (1u64 << OF) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lut_is_256_entries_of_9bit() {
        let d = Divu::new();
        for &v in d.lut.iter() {
            assert!((128..=496).contains(&v), "{v}");
        }
    }

    #[test]
    fn exact_on_powers_of_two() {
        let d = Divu::new();
        for k1 in 0..16 {
            for k2 in 0..16 {
                let got = d.div(1 << k1, 1 << k2, 16);
                let want = ((1u64 << 16) as f64 * 2f64.powi(k1 - k2)) as i64;
                assert_eq!(got, want, "2^{k1}/2^{k2}");
            }
        }
    }

    #[test]
    fn relative_error_within_lut_bound() {
        // 4-bit mantissa truncation: worst-case relative error ~ 2·2^-5
        // on each operand plus LUT rounding → < 13% overall (matches the
        // python algorithmic reference bound).
        let d = Divu::new();
        let mut rng = crate::Rng64::new(2);
        for _ in 0..20_000 {
            let x = (rng.next_u64() % 65_535 + 1) as u32;
            let y = (rng.next_u64() % 65_535 + 1) as u32;
            let got = d.div(x, y, 20) as f64 / (1u64 << 20) as f64;
            let want = x as f64 / y as f64;
            let rel = (got - want).abs() / want;
            assert!(rel <= 0.13, "x={x} y={y} got={got} want={want} rel={rel}");
        }
    }

    #[test]
    fn signed_division_signs() {
        let d = Divu::new();
        assert!(d.div_signed(-100, 10, 8) < 0);
        assert!(d.div_signed(100, -10, 8) < 0);
        assert!(d.div_signed(-100, -10, 8) > 0);
    }

    #[test]
    fn zero_dividend_and_divisor() {
        let d = Divu::new();
        assert_eq!(d.div(0, 5, 8), 0);
        assert_eq!(d.div(5, 0, 8), i64::MAX);
    }

    #[test]
    fn div_f64_view() {
        let d = Divu::new();
        let got = d.div_f64(3.0, 2.0, 12);
        assert!((got - 1.5).abs() / 1.5 < 0.13);
    }
}
