//! Reusable Exponential–Sigmoid unit (§4.4, Fig 5b).
//!
//! One datapath, two modes selected by `mode`:
//!
//! * mode 0 — base-e exponentiation via eq (8): e^x = 2^(x·log₂e) with
//!   log₂e ≈ 1.0111₂ applied by the ShiftAddition unit, integer part by
//!   barrel shift, fractional part through a 256-entry EXP-LUT;
//! * mode 1 — sigmoid via the eq (9) five-segment PWL, slopes 1/4, 1/8,
//!   1/32 as single barrel shifts, intercepts from the σ-LUT.
//!
//! I/O convention: inputs are Q8.8 (16-bit internal precision per §3.2),
//! outputs are Q1.15 in [0, 1) — both nonlinearities in RWKV consume
//! values in (0, 1] after the running-max stabilization.

use super::shift_add::{barrel, log2e_const, ShiftAddConst};

/// Pipeline depth (cycles) of the unit — used by the cycle model.
pub const EXPS_STAGES: u32 = 4;

/// Input fixed point: Q8.8.
pub const IN_FRAC: u8 = 8;
/// Output fixed point: Q1.15.
pub const OUT_FRAC: u8 = 15;

pub struct ExpSigmoidUnit {
    /// exp_lut[v] = round(2^(v/256) · 256) ∈ [256, 511]
    exp_lut: [u16; 256],
    log2e: ShiftAddConst,
}

impl Default for ExpSigmoidUnit {
    fn default() -> Self {
        Self::new()
    }
}

impl ExpSigmoidUnit {
    pub fn new() -> Self {
        let mut exp_lut = [0u16; 256];
        for (v, e) in exp_lut.iter_mut().enumerate() {
            *e = ((v as f64 / 256.0).exp2() * 256.0).round() as u16;
        }
        Self { exp_lut, log2e: log2e_const() }
    }

    /// mode 0: e^x for Q8.8 input, Q1.15 output (saturates at 0x7FFF for
    /// x ≥ 0 — the WKV datapath only ever feeds x ≤ 0 here).
    pub fn exp_q(&self, x_q88: i32) -> u16 {
        // y = x · log2e, still Q8.8 (shift-add: x + x>>1 - x>>4)
        let y = self.log2e.apply(x_q88 as i64);
        // u = floor(y) (integer part), v = fractional 8 bits
        let u = y >> 8;
        let v = (y & 0xFF) as usize;
        let lut = self.exp_lut[v] as i64; // 2^(v/256) in Q8 (256..511)
        // out_q15 = lut · 2^(u+7): Q8 LUT → Q15 needs <<7, then ±u
        let raw = barrel(lut, (u + 7) as i32);
        raw.clamp(0, 0x7FFF) as u16
    }

    /// mode 1: σ(x) for Q8.8 input, Q1.15 output, eq (9) verbatim.
    pub fn sigmoid_q(&self, x_q88: i32) -> u16 {
        let ax = x_q88.unsigned_abs() as i64; // |x| in Q8.8
        // thresholds in Q8.8: 5.0=1280, 2.375=608, 1.0=256
        let pos: i64 = if ax >= 1280 {
            0x8000 // 1.0 in Q1.15 (clamped below)
        } else if ax >= 608 {
            // 0.03125·x + 0.84375 → (ax<<2) + 27648   [slope 1/32: ·2^7/32]
            (ax << 2) + 27_648
        } else if ax >= 256 {
            // 0.125·x + 0.625 → (ax<<4) + 20480
            (ax << 4) + 20_480
        } else {
            // 0.25·x + 0.5 → (ax<<5) + 16384
            (ax << 5) + 16_384
        };
        let pos = pos.min(0x7FFF);
        let out = if x_q88 >= 0 { pos } else { 0x8000 - pos };
        out.clamp(0, 0x7FFF) as u16
    }

    /// Float views used by the hardware-numerics forward pass.
    pub fn exp_f64(&self, x: f64) -> f64 {
        let xq = (x * 256.0).round().clamp(i32::MIN as f64, i32::MAX as f64) as i32;
        self.exp_q(xq) as f64 / 32_768.0
    }

    pub fn sigmoid_f64(&self, x: f64) -> f64 {
        let xq = (x * 256.0).round().clamp(-65_536.0, 65_536.0) as i32;
        self.sigmoid_q(xq) as f64 / 32_768.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exp_lut_range() {
        let u = ExpSigmoidUnit::new();
        assert_eq!(u.exp_lut[0], 256);
        assert_eq!(u.exp_lut[255], ((255.0f64 / 256.0).exp2() * 256.0).round() as u16);
        assert!(u.exp_lut.windows(2).all(|w| w[1] >= w[0]));
    }

    #[test]
    fn exp_negative_domain_accuracy() {
        // the WKV recurrence only evaluates e^x for x <= 0; total error
        // (log2e rounding + LUT) must stay within ~4.5% relative or one
        // output ulp (2^-15), matching the python reference bound.
        let u = ExpSigmoidUnit::new();
        for i in 0..4000 {
            let x = -10.0 * (i as f64) / 4000.0;
            let got = u.exp_f64(x);
            let want = x.exp();
            let err = (got - want).abs();
            assert!(
                err / want <= 0.045 || err <= 2.0 / 32_768.0,
                "x={x} got={got} want={want}"
            );
        }
    }

    #[test]
    fn exp_zero_is_one_minus_ulp() {
        let u = ExpSigmoidUnit::new();
        // e^0 = 1.0 saturates to 0x7FFF = 1 - 2^-15
        assert_eq!(u.exp_q(0), 0x7FFF);
    }

    #[test]
    fn exp_saturates_positive() {
        let u = ExpSigmoidUnit::new();
        assert_eq!(u.exp_q(10 * 256), 0x7FFF);
    }

    #[test]
    fn exp_underflows_to_zero() {
        let u = ExpSigmoidUnit::new();
        assert_eq!(u.exp_q(-40 * 256), 0);
    }

    #[test]
    fn sigmoid_matches_pwl_reference() {
        // integer datapath == eq (9) evaluated in floats, to 1 ulp
        let u = ExpSigmoidUnit::new();
        let pwl = |x: f64| -> f64 {
            let ax = x.abs();
            let pos = if ax >= 5.0 {
                1.0
            } else if ax >= 2.375 {
                0.03125 * ax + 0.84375
            } else if ax >= 1.0 {
                0.125 * ax + 0.625
            } else {
                0.25 * ax + 0.5
            };
            if x >= 0.0 { pos } else { 1.0 - pos }
        };
        for i in -2000..2000 {
            // evaluate the float PWL on the Q8.8-quantized input so both
            // sides see the same segment-boundary decisions
            let x = (i as f64 / 100.0 * 256.0).round() / 256.0;
            let got = u.sigmoid_f64(x);
            let want = pwl(x).min(1.0 - 1.0 / 32_768.0);
            assert!((got - want).abs() <= 2.0 / 32_768.0 + 1e-9, "x={x} {got} {want}");
        }
    }

    #[test]
    fn sigmoid_true_error_bound() {
        let u = ExpSigmoidUnit::new();
        for i in -3000..3000 {
            let x = i as f64 / 100.0;
            let got = u.sigmoid_f64(x);
            let want = 1.0 / (1.0 + (-x).exp());
            assert!((got - want).abs() <= 0.0190 + 2.0 / 32_768.0, "x={x}");
        }
    }

    #[test]
    fn sigmoid_symmetry_in_integers() {
        let u = ExpSigmoidUnit::new();
        for x in (-1280i32..1280).step_by(7) {
            let a = u.sigmoid_q(x) as i32;
            let b = u.sigmoid_q(-x) as i32;
            assert!((a + b - 0x8000).abs() <= 1, "x={x}");
        }
    }
}
