//! Δ-PoT Multiplication Accumulator (PMAC) and the Matrix-Vector
//! Processing Array (§4.2, Fig 4).
//!
//! The Δ-PoT multiplier (Fig 4c) replaces a DSP multiply with barrel
//! shifts: a weight `sign·2γ·(2^-dq0 + 2^-(dq0+dq1))` times an activation
//! `a` is `sign·((a << (15-dq0)) + (a << (15-dq0-dq1)))` at 15 extra
//! fractional bits, with the per-tensor `2γ` folded into the output
//! scale.  Accumulation runs in 16-bit registers with saturation
//! ("overflow protection", §4.2); a per-tensor post-shift chosen at
//! calibration keeps typical sums in range.

use crate::quant::{DpotCode, DpotTensor};

/// Extra fractional bits carried by the shift-add product.
pub const PROD_FRAC: u32 = 15;

/// Δ-PoT multiply: activation raw value (9-bit domain) × code, returning
/// the exact shift-add product at `frac(a) + PROD_FRAC` fractional bits.
#[inline]
pub fn dpot_mul(a: i32, code: DpotCode) -> i64 {
    if code.sign == 0 || code.dq0 == 0 {
        return 0;
    }
    let a = a as i64;
    let s0 = PROD_FRAC as i32 - code.dq0 as i32;
    let t0 = super::shift_add::barrel(a, s0);
    let t = if code.dq1 == 0 {
        t0
    } else {
        t0 + super::shift_add::barrel(a, s0 - code.dq1 as i32)
    };
    code.sign as i64 * t
}

/// One PMAC unit: Δ-PoT multiplier + 16-bit saturating accumulator.
#[derive(Clone, Debug, Default)]
pub struct Pmac {
    acc: i32,
    /// Right-shift applied to each product before accumulation (chosen at
    /// calibration so row sums fit 16 bits).
    pub post_shift: u32,
    /// Number of times the accumulator clipped (observability for tests
    /// and for the calibration loop).
    pub sat_events: u64,
}

impl Pmac {
    pub fn new(post_shift: u32) -> Self {
        Self { acc: 0, post_shift, sat_events: 0 }
    }

    pub fn reset(&mut self) {
        self.acc = 0;
    }

    /// Multiply-accumulate one (activation, code) pair.
    #[inline]
    pub fn mac(&mut self, a: i32, code: DpotCode) {
        let p = dpot_mul(a, code) >> self.post_shift;
        let sum = self.acc as i64 + p;
        let clipped = sum.clamp(-32_767, 32_767);
        if clipped != sum {
            self.sat_events += 1;
        }
        self.acc = clipped as i32;
    }

    pub fn acc(&self) -> i32 {
        self.acc
    }
}

/// The parallel matrix-vector processing array with its three modes.
///
/// Mode 1 (AC on):   matrix-vector product, one column broadcast per
///                   cycle — latency (l+4)·⌈m/d⌉ cycles.
/// Mode 2 (AC off):  element-wise multiply — latency ⌈l/d⌉+4.
/// Mode 3:           element-wise add via the adder array.
pub struct MvArray {
    /// d — number of PMAC units operating in parallel.
    pub width: usize,
    pub post_shift: u32,
    pub sat_events: u64,
}

impl MvArray {
    pub fn new(width: usize, post_shift: u32) -> Self {
        Self { width, post_shift, sat_events: 0 }
    }

    /// Mode 1: `W @ x` where W is a Δ-PoT-encoded `rows × cols` tensor and
    /// `x` holds quantized activations (raw 9-bit values at `x_frac`).
    ///
    /// Returns raw accumulator values; the caller applies the combined
    /// output scale `2γ·x_scale·2^(post_shift - PROD_FRAC)`.
    pub fn matvec(&mut self, w: &DpotTensor, x: &[i32]) -> Vec<i32> {
        assert_eq!(w.cols, x.len());
        let mut out = vec![0i32; w.rows];
        // row blocks of `width` PMACs; within a block, stream columns —
        // the reordering of Fig 3 (every PMAC sees x[j] the same cycle).
        //
        // Perf note (§Perf L3-3): the common case never saturates, so a
        // fast path accumulates unclamped while tracking the running
        // extrema; only rows that would clip re-run the exact per-add
        // saturating loop.  Bit-exact by construction: when no partial
        // sum leaves the rails, per-add clamping is the identity.
        for (block_start, chunk) in
            (0..w.rows).step_by(self.width).zip(out.chunks_mut(self.width))
        {
            for (i, o) in chunk.iter_mut().enumerate() {
                let r = block_start + i;
                let row = &w.codes[r * w.cols..(r + 1) * w.cols];
                let mut sum = 0i64;
                let (mut lo, mut hi) = (0i64, 0i64);
                for (&xv, &code) in x.iter().zip(row) {
                    sum += dpot_mul(xv, code) >> self.post_shift;
                    lo = lo.min(sum);
                    hi = hi.max(sum);
                }
                if lo >= -32_767 && hi <= 32_767 {
                    *o = sum as i32;
                } else {
                    // exact saturating replay
                    let mut pmac = Pmac::new(self.post_shift);
                    for (&xv, &code) in x.iter().zip(row) {
                        pmac.mac(xv, code);
                    }
                    self.sat_events += pmac.sat_events;
                    *o = pmac.acc();
                }
            }
        }
        out
    }

    /// Mode 2: element-wise multiply of quantized activations with Δ-PoT
    /// codes (AC disabled — products pass straight through).
    pub fn elementwise_mul(&self, codes: &[DpotCode], x: &[i32]) -> Vec<i64> {
        assert_eq!(codes.len(), x.len());
        codes.iter().zip(x).map(|(&c, &a)| dpot_mul(a, c)).collect()
    }

    /// Mode 3: element-wise saturating add (9-bit domain).
    pub fn elementwise_add(&self, a: &[i32], b: &[i32]) -> Vec<i32> {
        a.iter()
            .zip(b)
            .map(|(&x, &y)| crate::quant::fixed::sat16x(x as i64 + y as i64, 16))
            .collect()
    }
}

/// Helper for model-level use: full quantized matvec with scales.
///
/// `x_f32` is quantized to 9 bits at `x_scale`, multiplied against the
/// Δ-PoT tensor, and the result is returned in f32 (the output scale
/// restores γ, the activation scale and the post-shift).
pub fn matvec_quantized(
    arr: &mut MvArray,
    w: &DpotTensor,
    x_f32: &[f32],
    x_scale: f32,
) -> Vec<f32> {
    let qmax = 255.0f32;
    let xq: Vec<i32> = x_f32
        .iter()
        .map(|&v| (v / x_scale * qmax).round().clamp(-qmax, qmax) as i32)
        .collect();
    let raw = arr.matvec(w, &xq);
    let scale = w.gamma * (x_scale / qmax)
        * (arr.post_shift as f64).exp2() as f32
        / (PROD_FRAC as f64).exp2() as f32
        * 2.0;
    raw.into_iter().map(|r| r as f32 * scale).collect()
}

/// Pick the smallest post-shift that avoids saturation on a calibration
/// input (binary scan, mirrors the offline calibration pass).
pub fn calibrate_post_shift(w: &DpotTensor, x: &[i32]) -> u32 {
    for shift in 0..24 {
        let mut arr = MvArray::new(64, shift);
        let _ = arr.matvec(w, x);
        if arr.sat_events == 0 {
            return shift;
        }
    }
    24
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::DpotTensor;

    fn encode(vals: &[f32], rows: usize, cols: usize) -> DpotTensor {
        DpotTensor::encode(vals, rows, cols)
    }

    #[test]
    fn dpot_mul_matches_decoded_value() {
        // integer shift-add == a · magnitude · 2^PROD_FRAC exactly,
        // because every Δ-PoT magnitude is dyadic with ≤ 15 frac bits...
        // (truncation can occur for dq0+dq1 > 15; allow 1 ulp per term)
        let mut rng = crate::Rng64::new(3);
        for _ in 0..5000 {
            let a = (rng.below(511) as i32) - 255;
            let dq0 = 1 + rng.below(15) as u8;
            let dq1 = rng.below(16) as u8;
            let sign = if rng.next_f64() < 0.5 { -1i8 } else { 1 };
            let code = DpotCode { sign, dq0, dq1 };
            let got = dpot_mul(a, code) as f64;
            // magnitude()/2 = p0+p1 (the format's 2× lives in the output
            // scale), so the product models a·sign·(p0+p1)·2^15
            let want = a as f64 * code.sign as f64 * (code.magnitude() / 2.0) * 32_768.0;
            assert!((got - want).abs() <= 2.0, "a={a} code={code:?} {got} {want}");
        }
    }

    #[test]
    fn zero_activation_or_code_gives_zero() {
        assert_eq!(dpot_mul(0, DpotCode { sign: 1, dq0: 3, dq1: 2 }), 0);
        assert_eq!(dpot_mul(123, DpotCode::ZERO), 0);
    }

    #[test]
    fn matvec_matches_float_reference() {
        let mut rng = crate::Rng64::new(7);
        let (rows, cols) = (32, 48);
        let w: Vec<f32> = (0..rows * cols).map(|_| rng.normal() as f32 * 0.1).collect();
        let x: Vec<f32> = (0..cols).map(|_| rng.normal() as f32).collect();
        let enc = encode(&w, rows, cols);
        let wq = enc.decode();
        let x_scale = x.iter().fold(0f32, |m, &v| m.max(v.abs()));

        // post-shift 14 keeps worst-case 48-element row sums inside the
        // 16-bit accumulators (verified by the sat_events assert below)
        let mut arr = MvArray::new(8, 14);
        let got = matvec_quantized(&mut arr, &enc, &x, x_scale);

        // reference: dequantized weights × quantized activations
        let xq: Vec<f32> = x
            .iter()
            .map(|&v| (v / x_scale * 255.0).round() / 255.0 * x_scale)
            .collect();
        for r in 0..rows {
            let want: f32 = (0..cols).map(|c| wq[r * cols + c] * xq[c]).sum();
            let tol = 0.02 * x_scale + (arr.post_shift as f64).exp2() as f32
                * enc.gamma * x_scale / 255.0 / 16_384.0
                + want.abs() * 0.02;
            assert!(
                (got[r] - want).abs() <= tol.max(0.05),
                "row {r}: {} vs {want}",
                got[r]
            );
        }
        assert_eq!(arr.sat_events, 0);
    }

    #[test]
    fn accumulator_saturates_and_counts() {
        let w: Vec<f32> = vec![1.0; 256];
        let enc = encode(&w, 1, 256);
        let x = vec![255i32; 256];
        let mut arr = MvArray::new(4, 0); // no post-shift → must clip
        let out = arr.matvec(&enc, &x);
        assert_eq!(out[0], 32_767);
        assert!(arr.sat_events > 0);
    }

    #[test]
    fn calibration_removes_saturation() {
        let w: Vec<f32> = vec![1.0; 256];
        let enc = encode(&w, 1, 256);
        let x = vec![255i32; 256];
        let shift = calibrate_post_shift(&enc, &x);
        let mut arr = MvArray::new(4, shift);
        let _ = arr.matvec(&enc, &x);
        assert_eq!(arr.sat_events, 0);
        assert!(shift >= 7, "shift {shift}");
    }

    #[test]
    fn elementwise_modes() {
        let arr = MvArray::new(4, 0);
        let codes = [DpotCode { sign: 1, dq0: 1, dq1: 0 }; 4]; // 0.5·2=1.0 weight
        let x = [10, -20, 30, -40];
        let prods = arr.elementwise_mul(&codes, &x);
        for (p, &xi) in prods.iter().zip(&x) {
            assert_eq!(*p, (xi as i64) << 14); // a·2^-1·2^15
        }
        let sums = arr.elementwise_add(&[100, -200], &[50, -50]);
        assert_eq!(sums, vec![150, -250]);
    }
}
