//! Leading-One Detector — Algorithm 1 of the paper, verbatim: a
//! hierarchical binary search that halves the inspected window each stage
//! (log₂k stages for k-bit inputs; the paper reports 58% lower logic depth
//! than sequential detection at 16 bits).

/// Position of the most significant '1' in the low `width` bits of `x`,
/// or `None` when that slice is zero (the paper returns -1).
///
/// `width` must be a power of two (8/16/32), matching the hardware's
/// stage structure.
pub fn lod(x: u32, width: u32) -> Option<u32> {
    debug_assert!(width.is_power_of_two() && width <= 32);
    let mut d: u32 = if width == 32 { x } else { x & ((1u32 << width) - 1) };
    let mut p: u32 = 0;
    let mut w = width;
    // Algorithm 1: while w > 1, test the upper half, keep the half with
    // the leading one, accumulate the position offset.
    while w > 1 {
        let h = w / 2;
        let upper = d >> h; // d[w-1:h]
        if upper != 0 {
            d = upper;
            p += h;
        } else {
            d &= (1u32 << h) - 1; // d[h-1:0]
        }
        w = h;
    }
    if d == 1 {
        Some(p)
    } else {
        None
    }
}

/// Number of pipeline stages of the LOD for a `width`-bit input
/// (one per halving) — used by the cycle model.
pub fn lod_stages(width: u32) -> u32 {
    width.trailing_zeros()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_leading_zeros_32() {
        let mut rng = crate::Rng64::new(1);
        for _ in 0..10_000 {
            let x = rng.next_u64() as u32;
            let want = if x == 0 { None } else { Some(31 - x.leading_zeros()) };
            assert_eq!(lod(x, 32), want, "x={x:#x}");
        }
    }

    #[test]
    fn matches_at_16_bits() {
        for x in 0..=u16::MAX as u32 {
            let want = if x == 0 { None } else { Some(31 - x.leading_zeros()) };
            assert_eq!(lod(x, 16), want);
        }
    }

    #[test]
    fn masks_above_width() {
        // bits above `width` must be ignored
        assert_eq!(lod(0x1_0001, 16), Some(0));
        assert_eq!(lod(0xFF00_0001, 8), Some(0));
    }

    #[test]
    fn zero_returns_none() {
        for w in [8, 16, 32] {
            assert_eq!(lod(0, w), None);
        }
    }

    #[test]
    fn powers_of_two_exact() {
        for p in 0..32 {
            assert_eq!(lod(1u32 << p, 32), Some(p));
        }
    }

    #[test]
    fn stage_count() {
        assert_eq!(lod_stages(16), 4);
        assert_eq!(lod_stages(32), 5);
    }
}
