//! ATAC (Addition-Tree + ACcumulator) reductions and the integer
//! LayerNorm datapath (§4.5, Fig 6).
//!
//! The LayerNorm module runs two parallel ATAC paths (Σx and Σx²), applies
//! the identity σ² = E[x²] − E[x]² (eq 12), takes an integer square root,
//! and streams `(x − μ)/σ` out through a DIVU.  Latency of one reduction
//! is ⌈d/P⌉ + 9 cycles at tree parallelism P.

use super::divu::Divu;

/// Pipelined addition-tree + accumulator reduction over i64 (wide
/// accumulators: with 9-bit inputs and d ≤ 16k the sums need ≤ 23 bits;
/// the squares path needs ≤ 31).  Returns (sum, cycles).
pub fn atac_sum(xs: &[i64], parallelism: usize) -> (i64, u64) {
    assert!(parallelism.is_power_of_two());
    let mut acc = 0i64;
    let mut blocks = 0u64;
    for chunk in xs.chunks(parallelism) {
        // the tree reduces one P-wide block per cycle
        acc += chunk.iter().sum::<i64>();
        blocks += 1;
    }
    // +9: tree depth (log2 P ≤ 9 at P=512) pipeline fill — paper's ⌈d/P⌉+9
    (acc, blocks + 9)
}

/// Integer square root (floor) via digit-by-digit (non-restoring) method —
/// the "subtract-square-root module" of Fig 6.
pub fn isqrt(x: u64) -> u32 {
    if x == 0 {
        return 0;
    }
    let mut op = x;
    let mut res: u64 = 0;
    let mut one: u64 = 1 << ((63 - x.leading_zeros() as u64) & !1);
    while one != 0 {
        if op >= res + one {
            op -= res + one;
            res = (res >> 1) + one;
        } else {
            res >>= 1;
        }
        one >>= 2;
    }
    res as u32
}

/// The full LayerNorm hardware datapath operating on 9-bit quantized
/// inputs (raw values at `in_frac` fractional bits).
pub struct LayerNormUnit {
    pub tree_parallelism: usize,
    divu: Divu,
    /// cycles spent by the last `forward` call (for the cycle model
    /// cross-check in sim::ln_module)
    pub last_cycles: u64,
}

impl LayerNormUnit {
    pub fn new(tree_parallelism: usize) -> Self {
        Self { tree_parallelism, divu: Divu::new(), last_cycles: 0 }
    }

    /// Normalize `x_raw` (9-bit values, `in_frac` frac bits); returns raw
    /// outputs at `out_frac` frac bits: (x−μ)/σ, no affine (γ/β applied
    /// by the element-wise array downstream).
    pub fn forward(&mut self, x_raw: &[i32], in_frac: u8, out_frac: u8) -> Vec<i32> {
        let d = x_raw.len() as i64;
        // two parallel ATAC paths
        let (s1, c1) = atac_sum(&x_raw.iter().map(|&v| v as i64).collect::<Vec<_>>(),
                                self.tree_parallelism);
        let (s2, c2) = atac_sum(
            &x_raw.iter().map(|&v| (v as i64) * (v as i64)).collect::<Vec<_>>(),
            self.tree_parallelism,
        );
        self.last_cycles = c1.max(c2) + super::divu::DIVU_STAGES as u64 + 2;

        // mean in raw units scaled by d (keep everything integral:
        // compare d²·var = d·Σx² − (Σx)²)
        let var_d2 = d * s2 - s1 * s1; // ≥ 0 up to rounding
        let var_d2 = var_d2.max(0) as u64;
        // σ·d = sqrt(d²·var); add d²·ε in raw² units
        let eps_raw2 = ((1u64 << (2 * in_frac)) as f64 * 1e-5 * (d * d) as f64) as u64;
        let sigma_d = isqrt(var_d2 + eps_raw2) as i64; // σ·d in raw units
        // per-element: (x·d − Σx) / (σ·d), via DIVU (signed)
        x_raw
            .iter()
            .map(|&v| {
                let num = v as i64 * d - s1;
                let q = self
                    .divu
                    .div_signed(num.clamp(i32::MIN as i64, i32::MAX as i64) as i32,
                                sigma_d.clamp(1, i32::MAX as i64) as i32,
                                out_frac);
                crate::quant::fixed::sat16x(q, 16)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn atac_sum_correct_and_cycle_formula() {
        let xs: Vec<i64> = (0..1000).collect();
        let (s, c) = atac_sum(&xs, 256);
        assert_eq!(s, 999 * 1000 / 2);
        assert_eq!(c, (1000 + 255) / 256 + 9);
    }

    #[test]
    fn isqrt_exact_on_squares() {
        for i in 0..2000u64 {
            assert_eq!(isqrt(i * i), i as u32);
            if i >= 1 {
                assert_eq!(isqrt(i * i + 1), i as u32); // floor (i²+1 < (i+1)² for i≥1)
                assert_eq!(isqrt(i * i - 1), i as u32 - 1);
            }
        }
        assert_eq!(isqrt(u32::MAX as u64 * u32::MAX as u64), u32::MAX);
    }

    #[test]
    fn layernorm_close_to_float_reference() {
        let mut rng = crate::Rng64::new(6);
        let d = 512;
        let in_frac = 6u8;
        let xf: Vec<f64> = (0..d).map(|_| rng.normal() * 2.0).collect();
        let xr: Vec<i32> = xf
            .iter()
            .map(|&v| ((v * 64.0).round() as i64).clamp(-255, 255) as i32)
            .collect();
        let mut unit = LayerNormUnit::new(256);
        let out = unit.forward(&xr, in_frac, 8);

        // float reference on the *quantized* inputs
        let xq: Vec<f64> = xr.iter().map(|&v| v as f64 / 64.0).collect();
        let mu = xq.iter().sum::<f64>() / d as f64;
        let var = xq.iter().map(|v| (v - mu) * (v - mu)).sum::<f64>() / d as f64;
        let sd = (var + 1e-5).sqrt();
        for (o, x) in out.iter().zip(&xq) {
            let want = (x - mu) / sd;
            let got = *o as f64 / 256.0;
            // DIVU's 4-bit mantissa dominates the error envelope
            assert!(
                (got - want).abs() <= 0.13 * want.abs() + 0.05,
                "{got} vs {want}"
            );
        }
    }

    #[test]
    fn layernorm_constant_input_is_finite_zero() {
        let xr = vec![100i32; 256];
        let mut unit = LayerNormUnit::new(256);
        let out = unit.forward(&xr, 6, 8);
        for o in out {
            assert!(o.abs() <= 1, "{o}");
        }
    }

    #[test]
    fn layernorm_cycles_tracked() {
        let xr = vec![1i32; 1024];
        let mut unit = LayerNormUnit::new(512);
        let _ = unit.forward(&xr, 6, 8);
        assert_eq!(unit.last_cycles, (1024 / 512 + 9) + 3 + 2);
    }
}
