//! Design-choice ablations beyond the paper (DESIGN.md §6, last row):
//!
//! * double buffering on/off (serial transfer-then-compute)
//! * fine-grained pipelining on/off
//! * Δ-PoT 9-bit vs fp16 streaming (the bandwidth win of §3)
//! * ATAC tree-parallelism sweep
//! * MV-array width (d) sweep
//! * Δ-PoT (k0,k1) codebook allocation sweep (reconstruction MSE)

use anyhow::Result;

use super::{render_table, write_result};
use crate::config::{AccelConfig, HFRWKV_CONFIGS, PAPER_SHAPES};
use crate::sim::{memory, timing, AccelSim};
use crate::util::json::Json;

pub fn run() -> Result<String> {
    let mut out = String::new();
    let mut j = Json::obj();

    // ---- double buffering ----------------------------------------------------
    let shape = &PAPER_SHAPES[4]; // 7B, streaming regime
    let cfg = &HFRWKV_CONFIGS[3];
    let compute = timing::token_compute_cycles(shape, cfg, true);
    let bytes = shape.stream_bytes_per_token(9.0);
    let t_cycles = memory::transfer_cycles(cfg, bytes);
    let n_chunks = (bytes / cfg.chunk_bytes as f64).ceil() as usize;
    let overlapped = memory::overlap_closed_form(compute, t_cycles, n_chunks);
    let serial = compute + t_cycles;
    out.push_str(&format!(
        "double buffering @7B/U280: overlapped {overlapped} cy vs serial {serial} cy \
         → {:.2}x speedup\n",
        serial as f64 / overlapped as f64
    ));
    j.set("double_buffer_speedup", serial as f64 / overlapped as f64);

    // ---- pipelining -----------------------------------------------------------
    // measured on the *resident* configs: streaming configs are
    // transfer-bound, where compute pipelining is hidden by the overlap
    let mut rows = Vec::new();
    for (cfg_idx, shape) in [(0usize, &PAPER_SHAPES[0]), (2, &PAPER_SHAPES[0])] {
        let mut sim = AccelSim::new(&HFRWKV_CONFIGS[cfg_idx]);
        let on = sim.evaluate(shape).tokens_per_sec;
        sim.pipelined = false;
        let off = sim.evaluate(shape).tokens_per_sec;
        rows.push(vec![
            format!("{} @{}", HFRWKV_CONFIGS[cfg_idx].name, shape.name),
            format!("{on:.1}"),
            format!("{off:.1}"),
            format!("{:.2}x", on / off),
        ]);
    }
    out.push_str("\nfine-grained pipelining (compute-bound resident configs):\n");
    out.push_str(&render_table(&["config", "pipelined", "serial", "gain"], &rows));

    // ---- weight bit-width (the Δ-PoT bandwidth win) -----------------------------
    let mut rows = Vec::new();
    for bits in [9.0, 12.0, 16.0] {
        let mut sim = AccelSim::new(&HFRWKV_CONFIGS[3]);
        sim.weight_bits = bits;
        let r = sim.evaluate(&PAPER_SHAPES[4]);
        rows.push(vec![
            format!("{bits:.0}-bit"),
            format!("{:.1}", r.tokens_per_sec),
            format!("{:.1}%", r.bandwidth_utilization * 100.0),
        ]);
    }
    out.push_str("\nstreamed weight width @7B/U280:\n");
    out.push_str(&render_table(&["width", "tok/s", "BW util"], &rows));

    // ---- ATAC tree parallelism sweep ------------------------------------------
    let mut rows = Vec::new();
    for p in [64usize, 128, 256, 512, 1024] {
        let c = timing::layernorm_cycles(4096, p, 128);
        rows.push(vec![p.to_string(), c.to_string()]);
    }
    out.push_str("\nLayerNorm latency vs tree parallelism (d=4096):\n");
    out.push_str(&render_table(&["P", "cycles"], &rows));

    // ---- MV array width sweep ---------------------------------------------------
    let mut rows = Vec::new();
    for d in [128usize, 256, 384, 512, 768, 1024, 2048] {
        let cfg = AccelConfig { pmac_count: d, ..*&HFRWKV_CONFIGS[1] };
        let cycles = timing::token_compute_cycles(&PAPER_SHAPES[0], &cfg, true);
        rows.push(vec![d.to_string(), cycles.to_string(),
            format!("{:.0}", cfg.freq_hz / cycles as f64)]);
    }
    out.push_str("\nMV-array width sweep @169M (350 MHz):\n");
    out.push_str(&render_table(&["d (PMACs)", "cycles/token", "tok/s"], &rows));

    // ---- Δ-PoT allocation sweep --------------------------------------------------
    let mut rng = crate::Rng64::new(31);
    let data: Vec<f32> = (0..100_000).map(|_| rng.normal() as f32 * 0.02).collect();
    let mut rows = Vec::new();
    for (k0, k1) in [(2u32, 2u32), (3, 3), (4, 4), (5, 3), (3, 5), (6, 2)] {
        let levels = dpot_levels_k(k0, k1);
        let cb = crate::quant::Codebook::new(levels.into_iter().map(|x| x as f32).collect());
        let mse = cb.mse(&data);
        rows.push(vec![
            format!("k0={k0},k1={k1} ({} bits)", 1 + k0 + k1),
            format!("{mse:.3e}"),
        ]);
    }
    out.push_str("\nΔ-PoT (k0,k1) allocation sweep — gaussian reconstruction MSE:\n");
    out.push_str(&render_table(&["allocation", "MSE"], &rows));

    write_result("ablation", &j)?;
    Ok(out)
}

/// Δ-PoT level set for arbitrary (k0, k1) — the "arbitrary allocation"
/// flexibility the paper claims over APoT (§3.1).
pub fn dpot_levels_k(k0: u32, k1: u32) -> Vec<f64> {
    let mut lv = vec![0.0f64];
    for dq0 in 1..(1u32 << k0) {
        let p0 = (-(dq0 as f64)).exp2();
        lv.push(2.0 * p0);
        for dq1 in 1..(1u32 << k1) {
            lv.push(2.0 * (p0 + p0 * (-(dq1 as f64)).exp2()));
        }
    }
    lv.sort_by(|a, b| a.partial_cmp(b).unwrap());
    lv.dedup();
    let max = *lv.last().unwrap();
    lv.iter().map(|x| x / max).collect()
}

#[cfg(test)]
mod tests {
    #[test]
    fn dpot_levels_k_generalizes_default() {
        let general = super::dpot_levels_k(4, 4);
        let fixed = crate::quant::dpot_levels();
        assert_eq!(general.len(), fixed.len());
        for (a, b) in general.iter().zip(&fixed) {
            assert!((a - b).abs() < 1e-15);
        }
    }
}
