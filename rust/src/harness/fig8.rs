//! E4 — Fig 8: energy efficiency (tokens/J) of every platform across the
//! five model sizes.

use anyhow::Result;

use super::{render_table, write_result};
use crate::baselines::ALL_BASELINES;
use crate::config::PAPER_SHAPES;
use crate::sim::AccelSim;
use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct Fig8Row {
    pub model: String,
    pub tokens_per_joule: Vec<(String, f64)>,
    pub fpga_power: [f64; 2], // U50, U280 watts
}

pub fn run() -> Vec<Fig8Row> {
    PAPER_SHAPES
        .iter()
        .map(|shape| {
            let mut cols = Vec::new();
            for b in &ALL_BASELINES {
                cols.push((b.name.to_string(), b.tokens_per_joule(shape)));
            }
            let u50 = AccelSim::deployed_for(false, shape).evaluate(shape);
            let u280 = AccelSim::deployed_for(true, shape).evaluate(shape);
            cols.push(("HFRWKV".to_string(), u50.tokens_per_joule));
            cols.push(("HFRWKV*".to_string(), u280.tokens_per_joule));
            Fig8Row {
                model: shape.name.to_string(),
                tokens_per_joule: cols,
                fpga_power: [u50.power_watts, u280.power_watts],
            }
        })
        .collect()
}

/// Paper's quoted energy anchors.
pub fn anchor_ratios(rows: &[Fig8Row]) -> Vec<(String, f64, f64)> {
    let get = |row: usize, name: &str| -> f64 {
        rows[row]
            .tokens_per_joule
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, v)| *v)
            .unwrap()
    };
    vec![
        // headline pairings (see EXPERIMENTS.md E5 discussion)
        ("169M HFRWKV*/CPU".into(), get(0, "HFRWKV*") / get(0, "CPU i7-12650H"), 139.17),
        ("169M HFRWKV*/2080Ti".into(), get(0, "HFRWKV*") / get(0, "RTX 2080Ti"), 171.36),
    ]
}

pub fn report(rows: &[Fig8Row]) -> Result<String> {
    let mut headers: Vec<&str> = vec!["model"];
    for (name, _) in &rows[0].tokens_per_joule {
        headers.push(Box::leak(name.clone().into_boxed_str()));
    }
    let body: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            let mut row = vec![r.model.clone()];
            row.extend(r.tokens_per_joule.iter().map(|(_, v)| format!("{v:.3}")));
            row
        })
        .collect();
    let mut out = String::from("Fig 8 — energy efficiency (tokens/J)\n");
    out.push_str(&render_table(&headers, &body));
    out.push_str("\nFPGA board power (W): ");
    for r in rows {
        out.push_str(&format!(
            "{}: U50 {:.1}/U280 {:.1}  ",
            r.model, r.fpga_power[0], r.fpga_power[1]
        ));
    }
    out.push('\n');
    out.push_str("\nenergy anchors vs paper:\n");
    let anchors = anchor_ratios(rows);
    let body: Vec<Vec<String>> = anchors
        .iter()
        .map(|(l, ours, paper)| {
            vec![
                l.clone(),
                format!("{ours:.1}"),
                format!("{paper:.2}"),
                format!("{:+.0}%", 100.0 * (ours / paper - 1.0)),
            ]
        })
        .collect();
    out.push_str(&render_table(&["anchor", "ours", "paper", "delta"], &body));

    let mut j = Json::obj();
    let rows_json: Vec<Json> = rows
        .iter()
        .map(|r| {
            let mut o = Json::obj();
            o.set("model", r.model.as_str());
            for (n, v) in &r.tokens_per_joule {
                o.set(n, *v);
            }
            o.set("power_u50", r.fpga_power[0]).set("power_u280", r.fpga_power[1]);
            o
        })
        .collect();
    j.set("rows", Json::Arr(rows_json));
    write_result("fig8", &j)?;
    Ok(out)
}
