//! Experiment harness: regenerates every table and figure of the paper's
//! evaluation section (DESIGN.md §6 experiment index).
//!
//! Every generator both prints the paper-formatted rows and returns a
//! [`crate::util::json::Json`] blob that the CLI writes under `results/`.

pub mod ablation;
pub mod fig7;
pub mod fig8;
pub mod headline;
pub mod table1;
pub mod table2;

use crate::util::json::Json;

/// Render an aligned text table.
pub fn render_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: Vec<String>, widths: &[usize]| -> String {
        cells
            .iter()
            .zip(widths)
            .map(|(c, w)| format!("{c:>w$}", w = w))
            .collect::<Vec<_>>()
            .join("  ")
    };
    out.push_str(&fmt_row(headers.iter().map(|s| s.to_string()).collect(), &widths));
    out.push('\n');
    out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row.clone(), &widths));
        out.push('\n');
    }
    out
}

/// Write a result blob under `results/<name>.json`.
pub fn write_result(name: &str, j: &Json) -> crate::Result<std::path::PathBuf> {
    std::fs::create_dir_all("results")?;
    let path = std::path::PathBuf::from(format!("results/{name}.json"));
    std::fs::write(&path, j.to_string())?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let t = render_table(
            &["name", "value"],
            &[
                vec!["a".into(), "1.0".into()],
                vec!["longer".into(), "2.25".into()],
            ],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("name") && lines[0].contains("value"));
        assert!(lines[3].contains("longer"));
    }
}
