//! E3 — Fig 7: throughput (tokens/s) of CPU, three GPUs, HFRWKV and
//! HFRWKV* across the five RWKV-4 model sizes, plus the paper's quoted
//! ratio anchors for side-by-side verification.

use anyhow::Result;

use super::{render_table, write_result};
use crate::baselines::ALL_BASELINES;
use crate::config::PAPER_SHAPES;
use crate::sim::AccelSim;
use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct Fig7Row {
    pub model: String,
    pub tokens_per_sec: Vec<(String, f64)>, // platform -> tok/s
    pub bandwidth_utilization: [f64; 2],    // U50, U280
}

pub fn run() -> Vec<Fig7Row> {
    PAPER_SHAPES
        .iter()
        .map(|shape| {
            let mut cols = Vec::new();
            for b in &ALL_BASELINES {
                cols.push((b.name.to_string(), b.tokens_per_sec(shape)));
            }
            let u50 = AccelSim::deployed_for(false, shape).evaluate(shape);
            let u280 = AccelSim::deployed_for(true, shape).evaluate(shape);
            cols.push(("HFRWKV".to_string(), u50.tokens_per_sec));
            cols.push(("HFRWKV*".to_string(), u280.tokens_per_sec));
            Fig7Row {
                model: shape.name.to_string(),
                tokens_per_sec: cols,
                bandwidth_utilization: [u50.bandwidth_utilization, u280.bandwidth_utilization],
            }
        })
        .collect()
}

/// Paper's quoted ratio anchors: (label, ours, paper).
pub fn anchor_ratios(rows: &[Fig7Row]) -> Vec<(String, f64, f64)> {
    let get = |row: usize, name: &str| -> f64 {
        rows[row]
            .tokens_per_sec
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, v)| *v)
            .unwrap()
    };
    vec![
        ("169M HFRWKV/CPU".into(), get(0, "HFRWKV") / get(0, "CPU i7-12650H"), 26.74),
        ("169M HFRWKV/2080Ti".into(), get(0, "HFRWKV") / get(0, "RTX 2080Ti"), 14.46),
        ("169M HFRWKV/3090".into(), get(0, "HFRWKV") / get(0, "RTX 3090"), 9.37),
        ("169M HFRWKV/A100".into(), get(0, "HFRWKV") / get(0, "A100"), 6.51),
        ("169M HFRWKV*/CPU".into(), get(0, "HFRWKV*") / get(0, "CPU i7-12650H"), 59.8),
        ("169M HFRWKV*/2080Ti".into(), get(0, "HFRWKV*") / get(0, "RTX 2080Ti"), 32.33),
        ("169M HFRWKV*/3090".into(), get(0, "HFRWKV*") / get(0, "RTX 3090"), 20.95),
        ("169M HFRWKV*/A100".into(), get(0, "HFRWKV*") / get(0, "A100"), 14.55),
        ("7B HFRWKV/3090".into(), get(4, "HFRWKV") / get(4, "RTX 3090"), 0.55),
        ("7B HFRWKV/A100".into(), get(4, "HFRWKV") / get(4, "A100"), 0.45),
        ("7B HFRWKV*/A100".into(), get(4, "HFRWKV*") / get(4, "A100"), 1.03),
    ]
}

pub fn report(rows: &[Fig7Row], detail: bool) -> Result<String> {
    let mut headers: Vec<&str> = vec!["model"];
    for (name, _) in &rows[0].tokens_per_sec {
        headers.push(Box::leak(name.clone().into_boxed_str()));
    }
    let body: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            let mut row = vec![r.model.clone()];
            row.extend(r.tokens_per_sec.iter().map(|(_, v)| format!("{v:.1}")));
            row
        })
        .collect();
    let mut out = String::from("Fig 7 — throughput (tokens/s), batch 1 sustained decode\n");
    out.push_str(&render_table(&headers, &body));

    out.push_str("\nratio anchors vs paper:\n");
    let anchors = anchor_ratios(rows);
    let body: Vec<Vec<String>> = anchors
        .iter()
        .map(|(l, ours, paper)| {
            vec![
                l.clone(),
                format!("{ours:.2}"),
                format!("{paper:.2}"),
                format!("{:+.0}%", 100.0 * (ours / paper - 1.0)),
            ]
        })
        .collect();
    out.push_str(&render_table(&["anchor", "ours", "paper", "delta"], &body));

    if detail {
        out.push_str("\nE6 — HBM bandwidth utilization (streaming configs):\n");
        for r in rows {
            out.push_str(&format!(
                "  {:<12} U50 {:.2}%  U280 {:.2}%   (paper: 99.95% / 99.64%)\n",
                r.model,
                r.bandwidth_utilization[0] * 100.0,
                r.bandwidth_utilization[1] * 100.0
            ));
        }
    }

    let mut j = Json::obj();
    let rows_json: Vec<Json> = rows
        .iter()
        .map(|r| {
            let mut o = Json::obj();
            o.set("model", r.model.as_str());
            for (n, v) in &r.tokens_per_sec {
                o.set(n, *v);
            }
            o
        })
        .collect();
    let anchors_json: Vec<Json> = anchors
        .iter()
        .map(|(l, ours, paper)| {
            let mut o = Json::obj();
            o.set("anchor", l.as_str()).set("ours", *ours).set("paper", *paper);
            o
        })
        .collect();
    j.set("rows", Json::Arr(rows_json)).set("anchors", Json::Arr(anchors_json));
    write_result("fig7", &j)?;
    Ok(out)
}
