//! E5 — the abstract's headline claims: best-case throughput and energy
//! ratios vs CPU and GPU across the full evaluation grid.

use anyhow::Result;

use super::{fig7, fig8, render_table, write_result};
use crate::util::json::Json;

pub struct Headline {
    pub label: String,
    pub ours: f64,
    pub paper: f64,
}

pub fn run() -> Vec<Headline> {
    let t = fig7::run();
    let e = fig8::run();
    // Best ratio across the grid, excluding rows where the baseline fell
    // off its VRAM/PCIe cliff (7B does not fit the 2080Ti; comparing
    // against a spilled baseline would overstate the win far beyond the
    // paper's own protocol, which quotes the GPU headline at 169M).
    let spilled = |row: &fig7::Fig7Row, base: &str| -> bool {
        base.contains("2080Ti") && row.model.contains("7b")
    };
    let best_ratio = |rows: &[fig7::Fig7Row], fpga: &str, base: &str| -> f64 {
        rows.iter()
            .filter(|r| !spilled(r, base))
            .map(|r| {
                let f = r.tokens_per_sec.iter().find(|(n, _)| n == fpga).unwrap().1;
                let b = r.tokens_per_sec.iter().find(|(n, _)| n == base).unwrap().1;
                f / b
            })
            .fold(0.0, f64::max)
    };
    let best_energy = |rows: &[fig8::Fig8Row], fpga: &str, base: &str| -> f64 {
        rows.iter()
            .filter(|r| !(base.contains("2080Ti") && r.model.contains("7b")))
            .map(|r| {
                let f = r.tokens_per_joule.iter().find(|(n, _)| n == fpga).unwrap().1;
                let b = r.tokens_per_joule.iter().find(|(n, _)| n == base).unwrap().1;
                f / b
            })
            .fold(0.0, f64::max)
    };
    vec![
        Headline {
            label: "throughput vs CPU (63.48x)".into(),
            ours: best_ratio(&t, "HFRWKV*", "CPU i7-12650H"),
            paper: 63.48,
        },
        Headline {
            label: "energy vs CPU (139.17x)".into(),
            ours: best_energy(&e, "HFRWKV*", "CPU i7-12650H"),
            paper: 139.17,
        },
        Headline {
            label: "throughput vs GPU (32.33x)".into(),
            ours: best_ratio(&t, "HFRWKV*", "RTX 2080Ti"),
            paper: 32.33,
        },
        Headline {
            label: "energy vs GPU (171.36x)".into(),
            ours: best_energy(&e, "HFRWKV*", "RTX 2080Ti"),
            paper: 171.36,
        },
    ]
}

pub fn report(rows: &[Headline]) -> Result<String> {
    let body: Vec<Vec<String>> = rows
        .iter()
        .map(|h| {
            vec![
                h.label.clone(),
                format!("{:.2}", h.ours),
                format!("{:.2}", h.paper),
                format!("{:+.0}%", 100.0 * (h.ours / h.paper - 1.0)),
            ]
        })
        .collect();
    let table = render_table(&["headline", "ours", "paper", "delta"], &body);
    let mut j = Json::obj();
    let arr: Vec<Json> = rows
        .iter()
        .map(|h| {
            let mut o = Json::obj();
            o.set("label", h.label.as_str()).set("ours", h.ours).set("paper", h.paper);
            o
        })
        .collect();
    j.set("headlines", Json::Arr(arr));
    write_result("headline", &j)?;
    Ok(table)
}
