//! E1 — Table 1: perplexity + accuracy of the trained model under every
//! quantization scheme (FP16 baseline, RTN, PoT, LogQ, APoT, Proposed
//! Δ-PoT, plus a "Proposed+HW" row running the full bit-accurate
//! hardware datapath).
//!
//! Protocol mirrors §5.2: matrix weights are fake-quantized per scheme at
//! the W9A9-equivalent budget; evaluation is held-out LAMBADA-style ppl +
//! last-word accuracy and six multiple-choice suites.

use std::path::Path;

use anyhow::Result;

use super::{render_table, write_result};
use crate::eval::{self, McItem};
use crate::model::{HwModel, RwkvModel, WeightFile};
use crate::quant::Scheme;
use crate::runtime::Manifest;
use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct Table1Row {
    pub name: String,
    /// held-out continuous-stream perplexity (low-variance delta signal)
    pub stream_ppl: f64,
    pub ppl: f64,
    pub lambada_acc: f64,
    pub suite_accs: Vec<(String, f64)>,
}

impl Table1Row {
    pub fn average_acc(&self) -> f64 {
        let mut accs: Vec<f64> = self.suite_accs.iter().map(|(_, a)| *a).collect();
        accs.push(self.lambada_acc);
        accs.iter().sum::<f64>() / accs.len() as f64
    }
}

fn eval_model<S: eval::Scorer>(
    name: &str,
    model: &mut S,
    stream: &[u32],
    docs: &[Vec<u32>],
    suites: &[(String, Vec<McItem>)],
) -> Table1Row {
    let stream_ppl = eval::stream_ppl(model, stream);
    let (ppl, lacc) = eval::eval_lambada(model, docs);
    let suite_accs = suites
        .iter()
        .map(|(n, items)| (n.clone(), eval::eval_suite(model, items)))
        .collect();
    Table1Row { name: name.to_string(), stream_ppl, ppl, lambada_acc: lacc, suite_accs }
}

/// Run the full ablation.  `limit` caps docs/items per suite (None = all).
pub fn run(artifacts: &Path, limit: Option<usize>, include_hw: bool) -> Result<Vec<Table1Row>> {
    let manifest = Manifest::load(artifacts)?;
    let weights = WeightFile::load(&manifest.weights)?;
    let base = RwkvModel::from_weights(&weights)?;
    let eval_json = manifest.load_eval_data()?;
    let (mut docs, mut suites) = eval::parse_eval_data(&eval_json)?;
    let mut stream = eval::parse_valid_stream(&eval_json).unwrap_or_default();
    if stream.is_empty() {
        stream = docs.iter().flatten().copied().collect();
    }
    if let Some(n) = limit {
        docs.truncate(n);
        stream.truncate((n * 30).max(500));
        for (_, items) in suites.iter_mut() {
            items.truncate(n);
        }
    }

    let mut rows = Vec::new();
    for scheme in [Scheme::Fp32, Scheme::Rtn, Scheme::Pot, Scheme::LogQ, Scheme::Apot, Scheme::Dpot] {
        let mut m = base.clone();
        m.quantize_matrices(scheme);
        // §5.2 protocol: quantized rows run W9A9 (9-bit activations too);
        // the FP16 baseline row runs full precision.
        if scheme != Scheme::Fp32 {
            m.act_bits = Some(9);
        }
        rows.push(eval_model(scheme.name(), &mut m, &stream, &docs, &suites));
    }
    if include_hw {
        // the full datapath: Δ-PoT matrices + 9-bit activations +
        // LUT/PWL/DIVU nonlinearities, calibrated on a training slice
        let calib: Vec<u32> = stream.iter().copied().take(512).collect();
        let mut hw = HwModel::from_f32(base.clone(), &calib);
        rows.push(eval_model("Proposed+HW", &mut hw, &stream, &docs, &suites));
        // calibration-health observability: the cumulative clip drain is
        // lossless across the row's many forward calls (the per-call
        // counter would only show the last document's)
        println!(
            "Proposed+HW: {} activations clipped at the 9-bit rails during evaluation",
            hw.take_clip_events()
        );
    }
    Ok(rows)
}

/// Cross-path check: score the held-out stream through the *compiled
/// PJRT executable* with FP32 and Δ-PoT-quantized weights swapped into
/// the device buffers.  Returns (name, stream_ppl) rows; the Δ-PoT row
/// must match the native-forward Proposed row to f32 tolerance.
pub fn run_pjrt_crosscheck(artifacts: &Path, stream_cap: usize) -> Result<Vec<(String, f64)>> {
    use crate::eval::PjrtScorer;
    use crate::runtime::{RwkvRuntime, Variant};

    let mut runtime = RwkvRuntime::load(artifacts)?;
    let eval_json = runtime.manifest.load_eval_data()?;
    let mut stream = eval::parse_valid_stream(&eval_json).unwrap_or_default();
    stream.truncate(stream_cap);

    let mut rows = Vec::new();
    for (name, scheme) in [("FP16 (PJRT)", Scheme::Fp32), ("Proposed (PJRT)", Scheme::Dpot)] {
        let mut weights = WeightFile::load(&runtime.manifest.weights)?;
        if scheme != Scheme::Fp32 {
            // quantize matrix tensors in the weight file (same protocol
            // as RwkvModel::quantize_matrices)
            for t in weights.tensors.values_mut() {
                let is_matrix = t.shape.len() == 2;
                if is_matrix {
                    crate::quant::fake_quant(&mut t.data, scheme);
                }
            }
        }
        runtime.swap_weights(&weights)?;
        let mut scorer = PjrtScorer { runtime: &runtime, variant: Variant::Exact };
        rows.push((name.to_string(), eval::stream_ppl(&mut scorer, &stream)));
    }
    Ok(rows)
}

/// Print + persist.
pub fn report(rows: &[Table1Row]) -> Result<String> {
    let mut headers = vec!["Precision", "stream ppl", "lambada ppl", "lambada acc"];
    if let Some(r) = rows.first() {
        for (n, _) in &r.suite_accs {
            headers.push(Box::leak(n.clone().into_boxed_str()));
        }
    }
    headers.push("Average acc");
    let body: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            let mut row = vec![
                r.name.clone(),
                format!("{:.3}", r.stream_ppl),
                format!("{:.2}", r.ppl),
                format!("{:.2}", r.lambada_acc * 100.0),
            ];
            for (_, a) in &r.suite_accs {
                row.push(format!("{:.1}", a * 100.0));
            }
            row.push(format!("{:.2}", r.average_acc() * 100.0));
            row
        })
        .collect();
    let table = render_table(&headers, &body);

    let mut j = Json::obj();
    let rows_json: Vec<Json> = rows
        .iter()
        .map(|r| {
            let mut o = Json::obj();
            o.set("name", r.name.as_str())
                .set("stream_ppl", r.stream_ppl)
                .set("ppl", r.ppl)
                .set("lambada_acc", r.lambada_acc)
                .set("average_acc", r.average_acc());
            for (n, a) in &r.suite_accs {
                o.set(n, *a);
            }
            o
        })
        .collect();
    j.set("rows", Json::Arr(rows_json));
    write_result("table1", &j)?;
    Ok(table)
}
