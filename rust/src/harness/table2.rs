//! E2 — Table 2: resource utilization of the four deployed configs,
//! model vs the paper's measured numbers.

use anyhow::Result;

use super::{render_table, write_result};
use crate::config::HFRWKV_CONFIGS;
use crate::sim::resources::{paper_table2, resource_usage};
use crate::util::json::Json;

pub fn run() -> Result<String> {
    let mut rows = Vec::new();
    let mut j_rows = Vec::new();
    for cfg in &HFRWKV_CONFIGS {
        let got = resource_usage(cfg);
        let want = paper_table2(cfg.name).unwrap();
        let total = cfg.platform.resources();
        let pct = |x: u64, t: u64| format!("{x} ({:.0}%)", 100.0 * x as f64 / t as f64);
        rows.push(vec![
            cfg.name.to_string(),
            cfg.platform.name().to_string(),
            format!("{:.0}MHz", cfg.freq_hz / 1e6),
            pct(got.lut, total.lut),
            pct(got.ff, total.ff),
            pct(got.dsp, total.dsp),
            pct(got.bram, total.bram),
            pct(got.uram, total.uram),
        ]);
        rows.push(vec![
            "  (paper)".to_string(),
            String::new(),
            String::new(),
            want.lut.to_string(),
            want.ff.to_string(),
            want.dsp.to_string(),
            want.bram.to_string(),
            want.uram.to_string(),
        ]);
        let mut o = Json::obj();
        o.set("config", cfg.name)
            .set("lut", got.lut)
            .set("ff", got.ff)
            .set("dsp", got.dsp)
            .set("bram", got.bram)
            .set("uram", got.uram)
            .set("paper_lut", want.lut)
            .set("paper_ff", want.ff)
            .set("paper_dsp", want.dsp)
            .set("paper_bram", want.bram)
            .set("paper_uram", want.uram);
        j_rows.push(o);
    }
    let table = render_table(
        &["Config", "Platform", "Freq", "LUT", "FF", "DSP", "BRAM", "URAM"],
        &rows,
    );
    let mut j = Json::obj();
    j.set("rows", Json::Arr(j_rows));
    write_result("table2", &j)?;
    Ok(table)
}
