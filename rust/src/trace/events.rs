//! Typed trace events and the bounded ring they live in.
//!
//! A [`TraceEvent`] is a fixed-size record — no `String`, no `Vec`, no
//! allocation on the record path (terminal reasons are `&'static str`
//! names).  The [`TraceRing`] follows the fault journal's discipline
//! ([`crate::coordinator::journal::FaultJournal`]): bounded capacity,
//! oldest-first eviction under pressure, cumulative `recorded`/`dropped`
//! counters so an overwritten storm is visible rather than silent.

use std::collections::VecDeque;

use crate::coordinator::journal::{FaultKind, FaultPhase, RecoveryAction};

/// Default ring capacity ([`crate::coordinator::CoordinatorConfig::trace_events`]):
/// a few minutes of serving at typical event rates (~10 events/cycle),
/// ~1 MB resident.
pub const DEFAULT_TRACE_EVENTS: usize = 16_384;

/// A scheduler/engine cycle segment, traced once per cycle when active.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CyclePhaseKind {
    /// Queue pull + queued-reap + shed + admission (scheduler).
    Admission,
    /// The chunked-prefill tick over all prefilling sessions (scheduler;
    /// per-session chunks additionally appear as
    /// [`TraceEventKind::PrefillChunk`] spans).
    Prefill,
    /// The fused batched decode forward inside
    /// [`crate::coordinator::Engine::step_batch`], retries included.
    DecodeForward,
    /// Sampling each session's next token from the decode panel.
    SamplerScatter,
    /// Post-cycle bookkeeping: stat drains, cache/journal mirrors,
    /// gauges, completions (scheduler).
    Maintenance,
}

impl CyclePhaseKind {
    pub fn name(self) -> &'static str {
        match self {
            CyclePhaseKind::Admission => "admission",
            CyclePhaseKind::Prefill => "prefill_tick",
            CyclePhaseKind::DecodeForward => "decode_forward",
            CyclePhaseKind::SamplerScatter => "sampler_scatter",
            CyclePhaseKind::Maintenance => "maintenance",
        }
    }
}

/// What a [`TraceEvent`] records.  Session-lifecycle kinds carry the
/// owning request id in the event header; cycle-phase events use
/// request id 0 (the scheduler/engine tracks).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceEventKind {
    /// The request entered the admission queue ([`crate::coordinator::Coordinator::submit`]).
    Enqueue,
    /// The request left the queue for an active slot; `redrive` marks a
    /// supervisor re-admission after a worker crash.
    Admit { cached_prefix_tokens: u32, redrive: bool },
    /// One bounded chunk of prompt prefill: token positions `from..to`.
    PrefillChunk { from: u32, to: u32 },
    /// The first token of the session was sampled (the TTFT point).
    FirstToken,
    /// The prompt forked into `branches` best-of-n decode branches.
    Fork { branches: u32 },
    /// The supervisor re-admitted this session after a worker crash;
    /// cross-reference the fault journal's `WorkerCrash` record at the
    /// same `(request, cycle)`.
    Redriven { attempt: u32, replayed_from: u32 },
    /// Mirror of a fault-journal record — the same attribution tuple,
    /// placed on the session's timeline.
    Fault { phase: FaultPhase, kind: FaultKind, attempt: u32, action: RecoveryAction },
    /// The branch terminated; `reason` is the `FinishReason` name (or
    /// `"error"` for an error terminal).
    Terminal { reason: &'static str },
    /// One cycle segment (request id 0); see [`CyclePhaseKind`].
    CyclePhase(CyclePhaseKind),
}

/// One trace record: fixed size, ~48 bytes, allocation-free.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TraceEvent {
    /// Microseconds since the tracer's epoch (coordinator spawn).
    pub ts_us: u64,
    /// Span duration (0 for instant events).
    pub dur_us: u64,
    /// Owning request, or 0 for cycle-scope events.
    pub request_id: u64,
    /// Best-of-n branch (0 for ordinary sessions and cycle-scope events).
    pub branch: u32,
    /// Engine scheduling cycle the event belongs to.
    pub cycle: u64,
    pub kind: TraceEventKind,
}

/// Bounded ring of [`TraceEvent`]s (see the module docs).
#[derive(Clone, Debug)]
pub struct TraceRing {
    events: VecDeque<TraceEvent>,
    cap: usize,
    recorded: u64,
    dropped: u64,
}

impl TraceRing {
    pub fn with_capacity(cap: usize) -> TraceRing {
        TraceRing {
            events: VecDeque::with_capacity(cap.max(1).min(DEFAULT_TRACE_EVENTS)),
            cap: cap.max(1),
            recorded: 0,
            dropped: 0,
        }
    }

    /// Append one event, evicting the oldest when the ring is full.
    pub fn push(&mut self, ev: TraceEvent) {
        if self.events.len() == self.cap {
            self.events.pop_front();
            self.dropped += 1;
        }
        self.events.push_back(ev);
        self.recorded += 1;
    }

    /// Events currently resident, oldest first.
    pub fn snapshot(&self) -> Vec<TraceEvent> {
        self.events.iter().copied().collect()
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Cumulative events ever recorded (resident + overwritten).
    pub fn recorded(&self) -> u64 {
        self.recorded
    }

    /// Events overwritten after the ring filled.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(i: u64) -> TraceEvent {
        TraceEvent {
            ts_us: i,
            dur_us: 0,
            request_id: i,
            branch: 0,
            cycle: i,
            kind: TraceEventKind::Enqueue,
        }
    }

    #[test]
    fn ring_bounds_and_counts() {
        let mut r = TraceRing::with_capacity(3);
        for i in 0..5 {
            r.push(ev(i));
        }
        assert_eq!(r.len(), 3);
        assert_eq!(r.recorded(), 5);
        assert_eq!(r.dropped(), 2);
        let ids: Vec<u64> = r.snapshot().iter().map(|e| e.request_id).collect();
        assert_eq!(ids, vec![2, 3, 4], "oldest records are the ones overwritten");
    }
}
