//! Chrome-trace-format exporter: turn a [`TraceEvent`] snapshot into a
//! JSON object loadable by Perfetto (<https://ui.perfetto.dev>) or
//! `chrome://tracing`.
//!
//! Layout of the exported trace (all on pid 1, "hfrwkv-coordinator"):
//!
//! * **sessions** — each request is one *async* span (`ph: "b"/"e"`,
//!   `id` = request id) opened at enqueue and closed at the terminal,
//!   with instant markers (`ph: "n"`) for admission, first token,
//!   forks, faults and redrive seams.  In Perfetto each request renders
//!   as its own horizontal track: queue wait, prefill and decode are
//!   directly legible, and a redriven request visibly restarts.
//! * **tid 1 "scheduler"** — per-cycle complete slices (`ph: "X"`) for
//!   the admission, prefill-tick and maintenance segments.
//! * **tid 2 "engine"** — per-cycle decode-forward and sampler-scatter
//!   slices plus one slice per session prefill chunk, i.e. where model
//!   FLOPs actually went.
//!
//! Timestamps are microseconds since the tracer epoch, sorted before
//! export so `ts` is monotonic (the validity contract pinned by
//! `rust/tests/trace.rs`).

use std::path::Path;

use crate::util::json::Json;

use super::{CyclePhaseKind, TraceEvent, TraceEventKind};

const PID: u64 = 1;
const TID_SCHEDULER: u64 = 1;
const TID_ENGINE: u64 = 2;

fn base(ph: &str, name: &str, ts_us: u64) -> Json {
    let mut j = Json::obj();
    j.set("ph", ph).set("name", name).set("pid", PID).set("ts", ts_us);
    j
}

fn meta(name: &str, tid: Option<u64>, value: &str) -> Json {
    let mut j = base("M", name, 0);
    if let Some(tid) = tid {
        j.set("tid", tid);
    }
    let mut args = Json::obj();
    args.set("name", value);
    j.set("args", args);
    j
}

/// Session-track async event (`b`/`e`/`n`): matched by (cat, id, name).
fn session_event(ph: &str, name: &str, ev: &TraceEvent, args: Json) -> Json {
    let mut j = base(ph, name, ev.ts_us);
    j.set("cat", "session").set("id", ev.request_id).set("args", args);
    j
}

/// Thread-track complete slice (`X`) with a duration.
fn slice(name: &str, tid: u64, ev: &TraceEvent, args: Json) -> Json {
    let mut j = base("X", name, ev.ts_us);
    j.set("tid", tid).set("dur", ev.dur_us).set("args", args);
    j
}

fn args_of(ev: &TraceEvent) -> Json {
    let mut a = Json::obj();
    a.set("cycle", ev.cycle).set("branch", ev.branch as u64);
    match ev.kind {
        TraceEventKind::Admit { cached_prefix_tokens, redrive } => {
            a.set("cached_prefix_tokens", cached_prefix_tokens as u64).set("redrive", redrive);
        }
        TraceEventKind::PrefillChunk { from, to } => {
            a.set("from", from as u64).set("to", to as u64).set("request", ev.request_id);
        }
        TraceEventKind::Fork { branches } => {
            a.set("branches", branches as u64);
        }
        TraceEventKind::Redriven { attempt, replayed_from } => {
            a.set("attempt", attempt as u64).set("replayed_from", replayed_from as u64);
        }
        TraceEventKind::Fault { phase, kind, attempt, action } => {
            a.set("phase", format!("{phase:?}"))
                .set("kind", format!("{kind:?}"))
                .set("attempt", attempt as u64)
                .set("action", format!("{action:?}"));
        }
        TraceEventKind::Terminal { reason } => {
            a.set("reason", reason);
        }
        TraceEventKind::Enqueue
        | TraceEventKind::FirstToken
        | TraceEventKind::CyclePhase(_) => {}
    }
    a
}

/// Build the Chrome trace object
/// (`{"traceEvents": [...], "displayTimeUnit": "ms"}`) from a ring
/// snapshot.  Pure function of the events — callers that want a file use
/// [`write_chrome_trace`] or [`crate::coordinator::Coordinator::export_trace`].
pub fn chrome_trace(events: &[TraceEvent]) -> Json {
    let mut sorted: Vec<&TraceEvent> = events.iter().collect();
    sorted.sort_by_key(|e| e.ts_us);

    let mut out = vec![
        meta("process_name", None, "hfrwkv-coordinator"),
        meta("thread_name", Some(TID_SCHEDULER), "scheduler"),
        meta("thread_name", Some(TID_ENGINE), "engine"),
    ];
    for ev in sorted {
        let args = args_of(ev);
        out.push(match ev.kind {
            TraceEventKind::Enqueue => session_event("b", "session", ev, args),
            TraceEventKind::Terminal { .. } => session_event("e", "session", ev, args),
            TraceEventKind::Admit { .. } => session_event("n", "admit", ev, args),
            TraceEventKind::FirstToken => session_event("n", "first_token", ev, args),
            TraceEventKind::Fork { .. } => session_event("n", "fork", ev, args),
            TraceEventKind::Redriven { .. } => session_event("n", "redriven", ev, args),
            TraceEventKind::Fault { .. } => session_event("n", "fault", ev, args),
            TraceEventKind::PrefillChunk { .. } => slice("prefill_chunk", TID_ENGINE, ev, args),
            TraceEventKind::CyclePhase(phase) => {
                let tid = match phase {
                    CyclePhaseKind::DecodeForward | CyclePhaseKind::SamplerScatter => TID_ENGINE,
                    _ => TID_SCHEDULER,
                };
                slice(phase.name(), tid, ev, args)
            }
        });
    }

    let mut trace = Json::obj();
    trace.set("traceEvents", out).set("displayTimeUnit", "ms");
    trace
}

/// Serialize [`chrome_trace`] to a file.
pub fn write_chrome_trace(path: &Path, events: &[TraceEvent]) -> std::io::Result<()> {
    std::fs::write(path, chrome_trace(events).to_string())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    fn ev(ts: u64, id: u64, kind: TraceEventKind) -> TraceEvent {
        TraceEvent { ts_us: ts, dur_us: 5, request_id: id, branch: 0, cycle: 1, kind }
    }

    #[test]
    fn export_shape_and_ordering() {
        // deliberately out of order: exporter must sort by ts
        let events = vec![
            ev(90, 7, TraceEventKind::Terminal { reason: "max_tokens" }),
            ev(10, 7, TraceEventKind::Enqueue),
            ev(20, 0, TraceEventKind::CyclePhase(CyclePhaseKind::Admission)),
            ev(30, 7, TraceEventKind::PrefillChunk { from: 0, to: 8 }),
            ev(40, 7, TraceEventKind::FirstToken),
            ev(50, 0, TraceEventKind::CyclePhase(CyclePhaseKind::DecodeForward)),
        ];
        let j = chrome_trace(&events);
        let s = j.to_string();
        let back = parse(&s).unwrap();
        let arr = back.req("traceEvents").unwrap().as_arr().unwrap();
        // 3 metadata + 6 events
        assert_eq!(arr.len(), 9);
        let mut last_ts = 0.0;
        for e in arr {
            let ts = e.req("ts").unwrap().as_f64().unwrap();
            assert!(ts >= last_ts, "ts not monotonic");
            last_ts = ts;
        }
        // async begin/end pair for the session, matched on id
        let phs: Vec<&str> =
            arr.iter().map(|e| e.req("ph").unwrap().as_str().unwrap()).collect();
        assert_eq!(phs.iter().filter(|p| **p == "b").count(), 1);
        assert_eq!(phs.iter().filter(|p| **p == "e").count(), 1);
        // decode_forward lands on the engine thread, admission on scheduler
        for e in arr {
            match e.req("name").unwrap().as_str().unwrap() {
                "decode_forward" | "prefill_chunk" => {
                    assert_eq!(e.req("tid").unwrap().as_usize().unwrap(), 2)
                }
                "admission" => assert_eq!(e.req("tid").unwrap().as_usize().unwrap(), 1),
                _ => {}
            }
        }
    }
}
