//! Cycle-level tracing and tail-latency histograms for the serving
//! stack.
//!
//! The coordinator's counters ([`crate::coordinator::Metrics`]) answer
//! "how much, in total"; this module answers the two questions counters
//! cannot: *what is the latency distribution* (tail percentiles, not
//! means) and *where did this specific request's time go*.
//!
//! * [`LatencyHistogram`] — fixed-size log-bucketed (HDR-style)
//!   histograms, ~4 KB each, allocation-free on the record path.  Five
//!   of them live inside `Metrics` (TTFT, inter-token, queue wait,
//!   prefill chunk, decode cycle) and surface as `latency:` lines in
//!   [`crate::coordinator::Metrics::report`] plus structured
//!   percentiles in `Metrics::to_json`.
//! * [`TraceEvent`] / [`TraceRing`] — a bounded ring of typed,
//!   fixed-size events recording each session's lifecycle (enqueue →
//!   admit → prefill chunks → first token → fork → redrive seams →
//!   terminal) and each scheduler cycle's phase timings, recorded at
//!   the `Instant` capture points the scheduler/engine already own.
//! * [`Tracer`] — the shared handle threaded through scheduler and
//!   engine.  A disabled tracer is a `None` and every record call is a
//!   branch-on-None no-op; an enabled one stamps events against a
//!   single epoch so all timelines line up.  Enabled by default
//!   ([`crate::coordinator::CoordinatorConfig::trace_events`]);
//!   `benches/trace_overhead.rs` pins the enabled-vs-disabled
//!   throughput delta under 3% at `max_active = 8`.
//! * [`export`] — Chrome-trace-format JSON
//!   ([`crate::coordinator::Coordinator::export_trace`], Perfetto /
//!   `chrome://tracing` loadable): sessions as async spans, scheduler
//!   and engine cycle phases as thread-track slices.

pub mod export;
pub mod histogram;

mod events;

pub use events::{
    CyclePhaseKind, TraceEvent, TraceEventKind, TraceRing, DEFAULT_TRACE_EVENTS,
};
pub use export::{chrome_trace, write_chrome_trace};
pub use histogram::LatencyHistogram;

use std::sync::{Arc, Mutex, PoisonError};
use std::time::Instant;

#[derive(Debug)]
struct TracerInner {
    epoch: Instant,
    ring: Mutex<TraceRing>,
}

/// Shared tracing handle: cheap to clone, safe to record from the
/// worker thread while the submit side reads snapshots.  `Default` (and
/// [`Tracer::disabled`]) is the off state: no ring, no epoch, and every
/// record path reduces to one `Option` check.
#[derive(Clone, Debug, Default)]
pub struct Tracer {
    inner: Option<Arc<TracerInner>>,
}

impl Tracer {
    /// An enabled tracer with a ring of `capacity` events; `capacity
    /// == 0` yields the disabled tracer.
    pub fn new(capacity: usize) -> Tracer {
        if capacity == 0 {
            return Tracer::disabled();
        }
        Tracer {
            inner: Some(Arc::new(TracerInner {
                epoch: Instant::now(),
                ring: Mutex::new(TraceRing::with_capacity(capacity)),
            })),
        }
    }

    pub fn disabled() -> Tracer {
        Tracer { inner: None }
    }

    pub fn enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// Microseconds since the tracer epoch — the `ts` domain of every
    /// event.  0 when disabled, so span starts cost nothing off.
    #[inline]
    pub fn now_us(&self) -> u64 {
        match &self.inner {
            Some(inner) => inner.epoch.elapsed().as_micros() as u64,
            None => 0,
        }
    }

    /// Record a fully-formed event (explicit `ts`/`dur` — what the
    /// engine's forward/scatter split uses).  No-op when disabled.
    pub fn record(&self, ev: TraceEvent) {
        if let Some(inner) = &self.inner {
            inner.ring.lock().unwrap_or_else(PoisonError::into_inner).push(ev);
        }
    }

    /// Record an instant event stamped now.  No-op when disabled.
    #[inline]
    pub fn instant(&self, request_id: u64, branch: u32, cycle: u64, kind: TraceEventKind) {
        if self.inner.is_some() {
            let ts_us = self.now_us();
            self.record(TraceEvent { ts_us, dur_us: 0, request_id, branch, cycle, kind });
        }
    }

    /// Record a span that began at `start_us` (a prior [`Tracer::now_us`])
    /// and ends now.  No-op when disabled.
    #[inline]
    pub fn span(&self, start_us: u64, request_id: u64, branch: u32, cycle: u64, kind: TraceEventKind) {
        if self.inner.is_some() {
            let dur_us = self.now_us().saturating_sub(start_us);
            self.record(TraceEvent { ts_us: start_us, dur_us, request_id, branch, cycle, kind });
        }
    }

    /// Resident events, oldest first (empty when disabled).
    pub fn snapshot(&self) -> Vec<TraceEvent> {
        match &self.inner {
            Some(inner) => {
                inner.ring.lock().unwrap_or_else(PoisonError::into_inner).snapshot()
            }
            None => Vec::new(),
        }
    }

    /// Cumulative `(recorded, dropped)` ring counters.
    pub fn stats(&self) -> (u64, u64) {
        match &self.inner {
            Some(inner) => {
                let ring = inner.ring.lock().unwrap_or_else(PoisonError::into_inner);
                (ring.recorded(), ring.dropped())
            }
            None => (0, 0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_tracer_records_nothing() {
        let t = Tracer::disabled();
        assert!(!t.enabled());
        assert_eq!(t.now_us(), 0);
        t.instant(1, 0, 0, TraceEventKind::Enqueue);
        t.span(0, 1, 0, 0, TraceEventKind::CyclePhase(CyclePhaseKind::Admission));
        assert!(t.snapshot().is_empty());
        assert_eq!(t.stats(), (0, 0));
        assert!(!Tracer::new(0).enabled(), "capacity 0 is the off switch");
    }

    #[test]
    fn enabled_tracer_stamps_monotonic_events() {
        let t = Tracer::new(64);
        assert!(t.enabled());
        let start = t.now_us();
        t.instant(7, 0, 1, TraceEventKind::Enqueue);
        std::thread::sleep(std::time::Duration::from_millis(1));
        t.span(start, 7, 0, 1, TraceEventKind::PrefillChunk { from: 0, to: 8 });
        let evs = t.snapshot();
        assert_eq!(evs.len(), 2);
        assert!(evs[0].ts_us >= start);
        assert_eq!(evs[1].ts_us, start);
        assert!(evs[1].dur_us >= 1000, "span saw the 1 ms sleep");
        let (recorded, dropped) = t.stats();
        assert_eq!((recorded, dropped), (2, 0));
    }

    #[test]
    fn clones_share_one_ring() {
        let t = Tracer::new(8);
        let t2 = t.clone();
        t2.instant(1, 0, 0, TraceEventKind::FirstToken);
        assert_eq!(t.snapshot().len(), 1);
    }
}
