//! Fixed-footprint log-bucketed latency histograms (HDR-style).
//!
//! A [`LatencyHistogram`] is a `[u64; 496]` bucket array plus three
//! scalars — no allocation ever, neither at construction nor on the
//! record path — so one can live inside [`crate::coordinator::Metrics`]
//! under the scheduler's existing metrics lock without changing the
//! hot-path cost model.
//!
//! Bucketing: values are microseconds.  Values below 16 µs get exact
//! 1 µs buckets; above that, every power of two splits into
//! `2^SUB_BITS = 8` sub-buckets, so a bucket's width is 1/8 of its
//! lower bound.  Percentile estimates therefore carry at most 12.5%
//! relative quantization error (and are *exact* below 16 µs) — plenty
//! for tail-latency reporting, at ~4 KB per histogram.
//! [`LatencyHistogram::percentile_range_us`] exposes the bucket bounds
//! so tests can assert the error contract against a sort-based oracle
//! (`rust/tests/trace.rs`).

use crate::util::json::Json;

const SUB_BITS: u32 = 3;
const SUB: usize = 1 << SUB_BITS;

/// Total bucket count: 8 exact unit buckets below `2^SUB_BITS`, then 8
/// sub-buckets for each of the remaining 61 octaves of the u64 range.
pub const BUCKETS: usize = SUB + (64 - SUB_BITS as usize) * SUB;

/// Bucket index for a microsecond value.  Continuous: buckets 0..16 are
/// the exact values 0..16, then log-linear.
#[inline]
fn bucket_index(v: u64) -> usize {
    if v < SUB as u64 {
        v as usize
    } else {
        let msb = 63 - v.leading_zeros();
        let sub = ((v >> (msb - SUB_BITS)) & (SUB as u64 - 1)) as usize;
        SUB + ((msb - SUB_BITS) as usize) * SUB + sub
    }
}

/// Inclusive lower bound of bucket `idx` (the value `percentile_us`
/// reports when the rank lands in that bucket).
fn bucket_lo(idx: usize) -> u64 {
    if idx < SUB {
        idx as u64
    } else {
        let octave = (idx - SUB) / SUB;
        let sub = (idx - SUB) % SUB;
        ((SUB + sub) as u64) << octave
    }
}

/// Exclusive upper bound of bucket `idx`.
fn bucket_hi(idx: usize) -> u64 {
    if idx + 1 < BUCKETS {
        bucket_lo(idx + 1)
    } else {
        u64::MAX
    }
}

/// Log-bucketed latency histogram over microsecond values.  ~4 KB,
/// fixed size, allocation-free; `Default` is the empty histogram.
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    buckets: [u64; BUCKETS],
    count: u64,
    sum_us: u64,
    max_us: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram { buckets: [0; BUCKETS], count: 0, sum_us: 0, max_us: 0 }
    }
}

impl LatencyHistogram {
    /// Record one microsecond observation.  O(1), no allocation.
    #[inline]
    pub fn record_us(&mut self, v: u64) {
        self.buckets[bucket_index(v)] += 1;
        self.count += 1;
        self.sum_us = self.sum_us.saturating_add(v);
        self.max_us = self.max_us.max(v);
    }

    /// Record a duration in seconds (the unit the serving stack's
    /// `Instant::elapsed().as_secs_f64()` call sites already hold).
    #[inline]
    pub fn record_seconds(&mut self, s: f64) {
        let us = if s <= 0.0 { 0 } else { (s * 1e6).min(u64::MAX as f64) as u64 };
        self.record_us(us);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Largest recorded value, exact (not bucket-quantized).
    pub fn max_us(&self) -> u64 {
        self.max_us
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64
        }
    }

    /// The `[lo, hi)` bucket bounds containing the p-quantile
    /// observation.  The rank convention matches
    /// [`crate::util::bench::percentile_sorted`]: element
    /// `min(floor(n*p), n-1)` of the sorted observations — so a
    /// sort-based oracle over the same samples must land inside the
    /// returned half-open range (the proptest contract).
    pub fn percentile_range_us(&self, p: f64) -> (u64, u64) {
        if self.count == 0 {
            return (0, 0);
        }
        let rank = ((self.count as f64 * p) as u64).min(self.count - 1) + 1;
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return (bucket_lo(i), bucket_hi(i));
            }
        }
        // unreachable: seen == count >= rank by the clamp above
        (self.max_us, u64::MAX)
    }

    /// p-quantile estimate: the lower bound of the containing bucket
    /// (exact below 16 µs; within 12.5% of the true value above).
    pub fn percentile_us(&self, p: f64) -> u64 {
        self.percentile_range_us(p).0
    }

    pub fn percentile_seconds(&self, p: f64) -> f64 {
        self.percentile_us(p) as f64 / 1e6
    }

    /// `(p50, p90, p99, max)` in milliseconds — the serve-report line.
    pub fn summary_ms(&self) -> (f64, f64, f64, f64) {
        (
            self.percentile_us(0.50) as f64 / 1e3,
            self.percentile_us(0.90) as f64 / 1e3,
            self.percentile_us(0.99) as f64 / 1e3,
            self.max_us as f64 / 1e3,
        )
    }

    /// Structured summary for `Metrics::to_json` / `BENCH_*.json`.
    pub fn to_json(&self) -> Json {
        let (p50, p90, p99, max) = self.summary_ms();
        let mut j = Json::obj();
        j.set("count", self.count)
            .set("mean_ms", self.mean_us() / 1e3)
            .set("p50_ms", p50)
            .set("p90_ms", p90)
            .set("p99_ms", p99)
            .set("max_ms", max);
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_are_continuous_and_cover_u64() {
        // every bucket's exclusive hi is the next bucket's inclusive lo,
        // starting at 0 and ending at u64::MAX
        assert_eq!(bucket_lo(0), 0);
        for i in 0..BUCKETS - 1 {
            assert_eq!(bucket_hi(i), bucket_lo(i + 1), "gap after bucket {i}");
            assert!(bucket_lo(i) < bucket_hi(i), "empty bucket {i}");
        }
        assert_eq!(bucket_hi(BUCKETS - 1), u64::MAX);
    }

    #[test]
    fn index_maps_into_its_own_bounds() {
        for v in
            [0u64, 1, 7, 8, 15, 16, 17, 100, 1000, 4095, 4096, 1 << 20, u64::MAX / 2, u64::MAX]
        {
            let i = bucket_index(v);
            assert!(i < BUCKETS);
            assert!(bucket_lo(i) <= v, "v={v} below bucket {i} lo");
            assert!(v < bucket_hi(i) || i == BUCKETS - 1, "v={v} above bucket {i} hi");
        }
    }

    #[test]
    fn exact_below_sixteen_us() {
        let mut h = LatencyHistogram::default();
        for v in 0..16u64 {
            h.record_us(v);
        }
        for v in 0..16u64 {
            let p = (v as f64 + 0.5) / 16.0;
            assert_eq!(h.percentile_us(p), v);
        }
    }

    #[test]
    fn percentiles_track_known_distribution() {
        let mut h = LatencyHistogram::default();
        for v in 1..=1000u64 {
            h.record_us(v * 100); // 100 µs .. 100 ms, uniform
        }
        let (lo50, hi50) = h.percentile_range_us(0.50);
        assert!(lo50 <= 50_100 && 50_100 < hi50, "p50 range [{lo50},{hi50})");
        let (lo99, hi99) = h.percentile_range_us(0.99);
        assert!(lo99 <= 99_100 && 99_100 < hi99, "p99 range [{lo99},{hi99})");
        assert_eq!(h.max_us(), 100_000);
        assert_eq!(h.count(), 1000);
        // quantization error contract: lower bound within 12.5%
        assert!(h.percentile_us(0.50) as f64 >= 50_100.0 * 0.875);
    }

    #[test]
    fn record_seconds_saturates_and_rounds() {
        let mut h = LatencyHistogram::default();
        h.record_seconds(-1.0); // clamps to 0
        h.record_seconds(0.0015); // 1500 µs
        h.record_seconds(f64::MAX); // saturates instead of overflowing
        assert_eq!(h.count(), 3);
        assert_eq!(h.percentile_us(0.0), 0);
        assert_eq!(h.max_us(), u64::MAX);
    }

    #[test]
    fn empty_histogram_reports_zeros() {
        let h = LatencyHistogram::default();
        assert_eq!(h.percentile_range_us(0.99), (0, 0));
        assert_eq!(h.summary_ms(), (0.0, 0.0, 0.0, 0.0));
        assert!(h.is_empty());
        let j = h.to_json();
        assert_eq!(j.req("count").unwrap().as_usize().unwrap(), 0);
    }
}
