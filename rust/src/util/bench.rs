//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Warmup + repeated timed batches, reporting min/median/mean/p95 with a
//! simple adaptive iteration count so short operations are measured in
//! batches large enough to dominate timer overhead.  Every `cargo bench`
//! target is a `harness = false` binary built on this.

use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    /// nanoseconds per iteration
    pub min_ns: f64,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub p95_ns: f64,
    pub iters: u64,
}

impl BenchStats {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.median_ns * 1e-9)
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark `f`, returning per-iteration statistics.  The closure's
/// return value is passed through `std::hint::black_box` to defeat DCE.
pub fn bench<T>(name: &str, mut f: impl FnMut() -> T) -> BenchStats {
    // pilot run to size batches at ~10ms each
    let t0 = Instant::now();
    std::hint::black_box(f());
    let pilot = t0.elapsed().max(Duration::from_nanos(30));
    let batch = ((10e-3 / pilot.as_secs_f64()).ceil() as u64).clamp(1, 1_000_000);

    // warmup
    let warm_end = Instant::now() + Duration::from_millis(100);
    while Instant::now() < warm_end {
        std::hint::black_box(f());
    }

    // timed batches (up to 24 samples or ~0.6 s, whichever first)
    let mut samples = Vec::new();
    let deadline = Instant::now() + Duration::from_millis(600);
    while samples.len() < 24 && (samples.len() < 6 || Instant::now() < deadline) {
        let t = Instant::now();
        for _ in 0..batch {
            std::hint::black_box(f());
        }
        samples.push(t.elapsed().as_secs_f64() * 1e9 / batch as f64);
    }
    // total_cmp: a NaN sample (pathological timer) must not panic the
    // whole bench run mid-sort
    samples.sort_by(|a, b| a.total_cmp(b));
    let n = samples.len();
    let stats = BenchStats {
        name: name.to_string(),
        min_ns: samples[0],
        median_ns: samples[n / 2],
        mean_ns: samples.iter().sum::<f64>() / n as f64,
        p95_ns: percentile_sorted(&samples, 0.95),
        iters: batch * n as u64,
    };
    println!(
        "{:<44} {:>12} /iter  (min {}, p95 {}, {} iters)",
        stats.name,
        fmt_ns(stats.median_ns),
        fmt_ns(stats.min_ns),
        fmt_ns(stats.p95_ns),
        stats.iters
    );
    stats
}

/// Print a section header in bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Percentile of an already-sorted sample set, floor-rank convention:
/// `sorted[floor(n * p)]`, clamped to the last element.  The ONE
/// percentile definition shared by the bench harness ([`bench`]'s p95),
/// the serving benches' latency sweeps, and the histogram proptest
/// oracle in `rust/tests/trace.rs` — so a bench-side figure and a
/// `Metrics` histogram figure can never disagree by convention.
///
/// Panics on an empty slice (a percentile of nothing is a caller bug).
pub fn percentile_sorted<T: Copy>(sorted: &[T], p: f64) -> T {
    assert!(!sorted.is_empty(), "percentile of an empty sample set");
    sorted[((sorted.len() as f64 * p) as usize).min(sorted.len() - 1)]
}

/// Machine-readable benchmark record: named scalar results accumulated
/// during a bench run and written as `BENCH_<name>.json`, so successive
/// PRs can diff performance trajectories without parsing stdout.
pub struct BenchReport {
    name: String,
    entries: Vec<(String, f64)>,
}

impl BenchReport {
    pub fn new(name: &str) -> BenchReport {
        BenchReport { name: name.to_string(), entries: Vec::new() }
    }

    /// Record one named scalar (tok/s, speedup, latency ms, ...).
    pub fn record(&mut self, key: &str, value: f64) -> &mut Self {
        self.entries.push((key.to_string(), value));
        self
    }

    /// Write `BENCH_<name>.json` into the current directory.
    pub fn write(&self) -> std::io::Result<std::path::PathBuf> {
        self.write_in(std::path::Path::new("."))
    }

    /// Write `BENCH_<name>.json` into `dir`; returns the path.
    pub fn write_in(&self, dir: &std::path::Path) -> std::io::Result<std::path::PathBuf> {
        use crate::util::json::Json;
        let mut results = Json::obj();
        for (k, v) in &self.entries {
            results.set(k, *v);
        }
        let mut j = Json::obj();
        j.set("bench", self.name.as_str());
        j.set("results", results);
        let path = dir.join(format!("BENCH_{}.json", self.name));
        std::fs::write(&path, j.to_string())?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let s = bench("spin", || {
            let mut x = 0u64;
            for i in 0..100 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert!(s.min_ns > 0.0);
        assert!(s.median_ns >= s.min_ns);
        assert!(s.p95_ns >= s.median_ns);
    }

    #[test]
    fn throughput_computation() {
        let s = BenchStats {
            name: "x".into(),
            min_ns: 100.0,
            median_ns: 100.0,
            mean_ns: 100.0,
            p95_ns: 100.0,
            iters: 1,
        };
        assert!((s.throughput(1.0) - 1e7).abs() < 1.0);
    }

    #[test]
    fn bench_report_roundtrips_through_json() {
        let mut r = BenchReport::new("unit_test");
        r.record("tok_s_b8", 1234.5).record("speedup_b8", 3.25);
        let dir = std::env::temp_dir();
        let path = r.write_in(&dir).unwrap();
        let j = crate::util::json::parse_file(&path).unwrap();
        assert_eq!(j.req("bench").unwrap().as_str().unwrap(), "unit_test");
        let res = j.req("results").unwrap();
        assert!((res.req("speedup_b8").unwrap().as_f64().unwrap() - 3.25).abs() < 1e-12);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn percentile_sorted_floor_convention() {
        let xs: Vec<u64> = (0..100).collect();
        assert_eq!(percentile_sorted(&xs, 0.0), 0);
        assert_eq!(percentile_sorted(&xs, 0.5), 50);
        assert_eq!(percentile_sorted(&xs, 0.95), 95);
        assert_eq!(percentile_sorted(&xs, 1.0), 99, "p100 clamps to max");
        assert_eq!(percentile_sorted(&[7.5f64], 0.99), 7.5);
    }

    #[test]
    fn fmt_ns_ranges() {
        assert!(fmt_ns(5.0).contains("ns"));
        assert!(fmt_ns(5e3).contains("µs"));
        assert!(fmt_ns(5e6).contains("ms"));
        assert!(fmt_ns(5e9).contains(" s"));
    }
}
