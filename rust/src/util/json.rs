//! Minimal, complete JSON implementation (parser + writer).
//!
//! Used for every artifact interchange file: `manifest.json`,
//! `eval_data.json`, `quant_codebooks.json`, `paper_shapes.json`, and for
//! emitting experiment results.  Supports the full JSON grammar (RFC 8259)
//! minus \u surrogate pairs outside the BMP (sufficient for our ASCII
//! artifacts; surrogate pairs are still decoded).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value.  Objects use a BTreeMap so output is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- constructors ---------------------------------------------------
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, v: impl Into<Json>) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), v.into());
        } else {
            panic!("set() on non-object");
        }
        self
    }

    // ---- accessors -------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with the key name — for required fields.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_i64(&self) -> Result<i64> {
        Ok(self.as_f64()? as i64)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array"),
        }
    }

    /// Array of numbers → Vec<f64>.
    pub fn as_f64_vec(&self) -> Result<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    /// Array of numbers → Vec<u32> (token lists).
    pub fn as_u32_vec(&self) -> Result<Vec<u32>> {
        self.as_arr()?.iter().map(|v| Ok(v.as_f64()? as u32)).collect()
    }

    // ---- serialization ----------------------------------------------------
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if !x.is_finite() {
                    // JSON has no NaN/Infinity literal; `null` keeps the
                    // document parseable (RFC 8259) instead of emitting
                    // `NaN`, which every strict parser rejects
                    out.push_str("null");
                } else if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    e.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, e)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    e.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---- parsing ---------------------------------------------------------------

pub fn parse(text: &str) -> Result<Json> {
    parse_bytes(text.as_bytes())
}

/// Parse raw bytes (e.g. an HTTP body straight off the socket).  Invalid
/// UTF-8 inside strings is an `Err`, never a panic.
pub fn parse_bytes(bytes: &[u8]) -> Result<Json> {
    let mut p = Parser { b: bytes, i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != bytes.len() {
        bail!("trailing garbage at byte {}", p.i);
    }
    Ok(v)
}

/// Parse a JSON file.
pub fn parse_file(path: &std::path::Path) -> Result<Json> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow!("reading {}: {e}", path.display()))?;
    parse(&text)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected EOF"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}, found {:?}",
                  c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            m.insert(k, self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected , or }} found {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected , or ] found {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let cp = self.hex4()?;
                            if (0xD800..0xDC00).contains(&cp) {
                                // surrogate pair
                                self.eat(b'\\')?;
                                self.eat(b'u')?;
                                let lo = self.hex4()?;
                                let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                s.push(char::from_u32(c).ok_or_else(|| anyhow!("bad surrogate"))?);
                            } else {
                                s.push(char::from_u32(cp).ok_or_else(|| anyhow!("bad codepoint"))?);
                            }
                        }
                        _ => bail!("bad escape \\{}", e as char),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                c => {
                    // multi-byte UTF-8: copy raw bytes.  Bounds-checked —
                    // untrusted network payloads can truncate a sequence
                    // mid-character, which must be an Err, not a panic
                    let start = self.i - 1;
                    let len = if c >= 0xF0 { 4 } else if c >= 0xE0 { 3 } else { 2 };
                    let bytes = self
                        .b
                        .get(start..start + len)
                        .ok_or_else(|| anyhow!("truncated UTF-8 sequence at byte {start}"))?;
                    self.i = start + len;
                    s.push_str(std::str::from_utf8(bytes)?);
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.peek()?;
            self.i += 1;
            v = v * 16
                + match c {
                    b'0'..=b'9' => (c - b'0') as u32,
                    b'a'..=b'f' => (c - b'a' + 10) as u32,
                    b'A'..=b'F' => (c - b'A' + 10) as u32,
                    _ => bail!("bad hex digit"),
                };
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|e| anyhow!("bad number {s:?}: {e}"))?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a":1,"b":[true,null,-2.5e3],"c":"hi\nthere","d":{"x":0}}"#;
        let v = parse(src).unwrap();
        let v2 = parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        assert_eq!(v.get("a").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(v.get("b").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "hi\nthere");
    }

    #[test]
    fn parses_python_json_output() {
        // shapes like python's json.dump emits (spaces after : and ,)
        let src = r#"{ "name": "tiny-1m", "n_layer": 4, "vals": [1, 2, 3] }"#;
        let v = parse(src).unwrap();
        assert_eq!(v.req("n_layer").unwrap().as_usize().unwrap(), 4);
        assert_eq!(v.req("vals").unwrap().as_u32_vec().unwrap(), vec![1, 2, 3]);
    }

    #[test]
    fn unicode_escapes() {
        let v = parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Aé");
        let v = parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "😀");
    }

    #[test]
    fn raw_utf8_passthrough() {
        let v = parse("\"héllo — ok\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo — ok");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("123 456").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn number_formats() {
        for (s, want) in [("0", 0.0), ("-1", -1.0), ("3.5", 3.5),
                          ("1e-5", 1e-5), ("-2.5E+3", -2500.0)] {
            assert_eq!(parse(s).unwrap().as_f64().unwrap(), want, "{s}");
        }
    }

    #[test]
    fn builder_api() {
        let mut o = Json::obj();
        o.set("x", 1.5).set("name", "hf").set("list", vec![1u64, 2, 3]);
        let s = o.to_string();
        let back = parse(&s).unwrap();
        assert_eq!(back.req("x").unwrap().as_f64().unwrap(), 1.5);
    }

    #[test]
    fn integers_serialized_without_fraction() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.25).to_string(), "3.25");
    }

    #[test]
    fn hostile_prompt_roundtrips() {
        // everything a network client can put in a prompt string must
        // survive serialize → parse bit-for-bit: quotes, backslashes,
        // raw control characters, DEL, newlines, tabs, emoji
        let hostile = "quote:\" backslash:\\ nl:\n cr:\r tab:\t nul:\u{0} bell:\u{7} esc:\u{1b} del:\u{7f} emoji:😀 sse-breaker:\n\ndata: fake";
        let mut o = Json::obj();
        o.set("prompt", hostile);
        let wire = o.to_string();
        // the serialized form must not contain a raw control character
        // (they would break SSE framing and strict parsers alike)
        assert!(!wire.chars().any(|c| (c as u32) < 0x20), "raw control char in {wire:?}");
        let back = parse(&wire).unwrap();
        assert_eq!(back.req("prompt").unwrap().as_str().unwrap(), hostile);
    }

    #[test]
    fn nonfinite_numbers_serialize_as_null() {
        // f64::NAN would otherwise print as `NaN` — invalid JSON that
        // poisons /metrics responses the load harness parses back
        for x in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let s = Json::Num(x).to_string();
            assert_eq!(s, "null", "{x} -> {s}");
            assert!(parse(&s).is_ok());
        }
        let mut o = Json::obj();
        o.set("ok", 1.5).set("bad", f64::NAN);
        assert!(parse(&o.to_string()).is_ok());
    }

    #[test]
    fn truncated_utf8_is_err_not_panic() {
        // a string whose multi-byte sequence is cut off at EOF used to
        // slice out of bounds; network bodies make this reachable
        for src in ["\"\u{e9}x\"", "\"abc\u{1F600}d\""] {
            for cut in 1..src.len() {
                let _ = parse_bytes(&src.as_bytes()[..cut]); // must not panic
            }
            let mut bytes = src.as_bytes().to_vec();
            bytes.truncate(bytes.len() - 3); // chop mid-character
            assert!(parse_bytes(&bytes).is_err(), "{src:?}");
        }
        // an invalid continuation byte inside a string is Err too
        assert!(parse_bytes(b"\"a\xE2\x28\xA1b\"").is_err());
    }
}
