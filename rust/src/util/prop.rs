//! Miniature property-based testing layer (proptest is unavailable
//! offline).  Seeded generation + bounded shrinking for the invariant
//! tests in `rust/tests/proptests.rs`.
//!
//! A property is a closure over a generated value; on failure the runner
//! shrinks by re-generating from "smaller" generator parameters (halving
//! sizes) and reports the smallest failing case found.

use crate::Rng64;

/// Generation context: an RNG plus a size budget that shrinks on failure.
pub struct Gen {
    pub rng: Rng64,
    pub size: usize,
}

impl Gen {
    pub fn i32_in(&mut self, lo: i32, hi: i32) -> i32 {
        lo + (self.rng.next_f64() * ((hi - lo) as f64 + 1.0)) as i32
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below(hi - lo + 1)
    }

    pub fn f32_normal(&mut self, scale: f32) -> f32 {
        self.rng.normal() as f32 * scale
    }

    pub fn vec_f32(&mut self, len: usize, scale: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32_normal(scale)).collect()
    }

    pub fn vec_i32(&mut self, len: usize, lo: i32, hi: i32) -> Vec<i32> {
        (0..len).map(|_| self.i32_in(lo, hi)).collect()
    }

    /// A length scaled by the shrink budget (≥ 1).
    pub fn sized_len(&mut self, max: usize) -> usize {
        self.usize_in(1, max.min(self.size).max(1))
    }
}

/// Run `cases` random cases of a property.  On failure, retries with
/// halved size budgets to find a smaller counterexample, then panics with
/// the seed so the case can be replayed deterministically.
pub fn check(name: &str, cases: u64, prop: impl Fn(&mut Gen) -> Result<(), String>) {
    for case in 0..cases {
        let seed = 0x5EED_0000 + case;
        let mut g = Gen { rng: Rng64::new(seed), size: 256 };
        if let Err(msg) = prop(&mut g) {
            // shrink: halve the size budget until the property passes
            let mut smallest = (256usize, msg.clone());
            let mut size = 128usize;
            while size >= 1 {
                let mut g = Gen { rng: Rng64::new(seed), size };
                match prop(&mut g) {
                    Err(m) => smallest = (size, m),
                    Ok(()) => break,
                }
                if size == 1 {
                    break;
                }
                size /= 2;
            }
            panic!(
                "property {name:?} failed (seed {seed:#x}, smallest size {}):\n  {}",
                smallest.0, smallest.1
            );
        }
    }
}

/// Assert-style helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add commutes", 50, |g| {
            let (a, b) = (g.i32_in(-1000, 1000), g.i32_in(-1000, 1000));
            if a + b == b + a {
                Ok(())
            } else {
                Err("math broke".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_panics_with_seed() {
        check("always fails", 3, |g| {
            let len = g.sized_len(64);
            let v = g.vec_i32(len, 0, 10);
            Err(format!("len {}", v.len()))
        });
    }

    #[test]
    fn generators_respect_bounds() {
        let mut g = Gen { rng: Rng64::new(1), size: 64 };
        for _ in 0..1000 {
            let x = g.i32_in(-5, 5);
            assert!((-5..=5).contains(&x));
            let u = g.usize_in(2, 9);
            assert!((2..=9).contains(&u));
            let l = g.sized_len(1000);
            assert!((1..=64).contains(&l));
        }
    }
}
