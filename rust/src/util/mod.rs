//! From-scratch substrates the offline environment forces us to own:
//! a JSON parser/serializer ([`json`]), a micro-benchmark statistics
//! harness ([`bench`]), and a miniature property-based testing layer
//! ([`prop`]).  No external crates beyond `xla` and `anyhow` exist in
//! this build, so these are first-class parts of the system inventory
//! (DESIGN.md §5).

pub mod bench;
pub mod json;
pub mod prop;
