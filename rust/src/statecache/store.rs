//! The snapshot store: prefix trie + byte budget + LRU + pinning.

use std::sync::Arc;

use super::trie::Trie;
use super::StateCacheConfig;
use crate::model::panel_all_finite;

/// An immutable cached RWKV state: the flat `[n_layer * 5 * d]` vector
/// captured after `tokens` prompt tokens were folded in.  Shared
/// copy-on-write via [`SnapshotRef`]: the store keeps one `Arc`, every
/// borrowing session clones the handle (cheap) and materializes a
/// private mutable copy of the floats only when its prefill resumes.
#[derive(Debug)]
pub struct Snapshot {
    state: Vec<f32>,
    tokens: usize,
    /// Last-token logits, carried only by *decode-state* snapshots (the
    /// fork/best-of-n path): a prefix snapshot's future prefill
    /// recomputes the logits anyway, but a fork has to sample each
    /// branch's first token without re-running any of the prompt.
    /// Empty for ordinary prefix snapshots.
    logits: Vec<f32>,
}

impl Snapshot {
    /// Bytes this snapshot holds resident: the state floats, carried
    /// logits (if any) and the trie key tokens (all 4 bytes/element).
    /// This is the exact quantity the store's budget accounting sums.
    pub fn cost_bytes(&self) -> usize {
        (self.state.len() + self.logits.len() + self.tokens) * 4
    }
}

/// Shared handle to a cached snapshot.  Holding one *pins* the entry:
/// the store never evicts a snapshot whose `Arc` is still held outside
/// the store (a live session may be about to — or already did — resume
/// from it, and `Metrics` would misreport a borrowed entry as gone).
#[derive(Clone, Debug)]
pub struct SnapshotRef(Arc<Snapshot>);

impl SnapshotRef {
    /// The cached flat state, immutable (copy before mutating).
    pub fn state(&self) -> &[f32] {
        &self.0.state
    }

    /// How many prompt tokens this state has folded in.
    pub fn tokens(&self) -> usize {
        self.0.tokens
    }

    /// Last-token logits, non-empty only for decode-state snapshots
    /// (see [`Snapshot`]).
    pub fn logits(&self) -> &[f32] {
        &self.0.logits
    }

    /// A snapshot handle not owned by any store.  The fork path builds
    /// one even with the cache disabled, so the N branches of a
    /// best-of-n request always share ONE pinned copy of the
    /// post-prompt state; [`StateStore::adopt`] can later make the same
    /// `Arc` resident without another copy.
    pub fn detached(state: Vec<f32>, tokens: usize, logits: Vec<f32>) -> SnapshotRef {
        SnapshotRef(Arc::new(Snapshot { state, tokens, logits }))
    }
}

/// Monotonic counters + gauges, folded into the serving `Metrics` every
/// scheduling cycle and surfaced in the serve report.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CacheStats {
    /// Admissions that resumed from a cached prefix.
    pub hits: u64,
    /// Admissions that found no usable prefix (including prompts too
    /// short to ever hit: prefill work was needed either way).
    pub misses: u64,
    /// Prompt tokens whose prefill was skipped by resuming — the cache's
    /// whole value, in tokens.
    pub tokens_skipped: u64,
    /// Snapshots newly captured (dedup re-captures don't count).
    pub inserts: u64,
    /// Snapshots evicted by LRU under byte pressure.
    pub evictions: u64,
    /// Snapshots rejected because they exceed the whole budget or every
    /// resident byte is pinned by live sessions.
    pub rejected: u64,
    /// Gauge: bytes currently resident (exactly the sum of live entries'
    /// [`Snapshot::cost_bytes`]).
    pub bytes_resident: u64,
    /// Gauge: live cached snapshots.
    pub entries: u64,
    /// Gauge: resident snapshots currently pinned by a live
    /// [`SnapshotRef`] held outside the store (resuming sessions,
    /// fork branches sharing a decode state) — these are skipped by
    /// eviction, so `bytes_resident` can only shrink to the pinned sum.
    pub pinned: u64,
    /// Snapshots refused at insert — or purged after a health guard
    /// tripped — because their state or logits contained NaN/±Inf.
    /// The quarantine rule (module docs): non-finite floats never
    /// become, or stay, resident.
    pub quarantined: u64,
}

struct Entry {
    snap: Arc<Snapshot>,
    /// Which class trie and node this entry is attached to.
    class_slot: usize,
    node: usize,
    /// LRU stamp: larger = more recently used.
    last_used: u64,
}

/// What [`StateStore::insert_entry`] did with a candidate snapshot.
enum InsertOutcome {
    /// Newly resident (the returned `Arc` is the stored one).
    Inserted(Arc<Snapshot>),
    /// The key was already cached: recency refreshed, resident `Arc`
    /// returned, candidate never materialized.
    Dedup(Arc<Snapshot>),
    /// Over budget (or everything resident is pinned): not stored.
    Rejected,
}

/// Prefix-sharing state cache.
///
/// Keys are `(class, token prefix)` — `class` discriminates state
/// spaces that share a token vocabulary but not a numerics trajectory
/// (the engine passes the model variant, so an `Exact` state is never
/// resumed by a `HwApprox` session).  The engine additionally
/// partitions the class space with a high *decode-namespace* bit:
/// decode-state snapshots (post-prompt state + last-token logits, the
/// fork/best-of-n path) live in their own tries and never collide with
/// prefix snapshots.  Values are [`Snapshot`]s behind `Arc` handles;
/// capacity is a byte budget with LRU eviction that skips pinned
/// entries.
pub struct StateStore {
    cfg: StateCacheConfig,
    /// One trie per class, linearly scanned (two classes in practice).
    classes: Vec<(u32, Trie)>,
    entries: Vec<Option<Entry>>,
    free: Vec<usize>,
    bytes: usize,
    /// Live entry count, maintained incrementally — `stats()` runs on
    /// the scheduler's per-cycle path, so everything except the pinned
    /// gauge (which must read `Arc` counts) avoids O(entries) scans.
    live: usize,
    clock: u64,
    stats: CacheStats,
}

impl StateStore {
    pub fn new(cfg: StateCacheConfig) -> StateStore {
        StateStore {
            cfg,
            classes: Vec::new(),
            entries: Vec::new(),
            free: Vec::new(),
            bytes: 0,
            live: 0,
            clock: 0,
            stats: CacheStats::default(),
        }
    }

    fn class_slot(&mut self, class: u32) -> usize {
        if let Some(i) = self.classes.iter().position(|(c, _)| *c == class) {
            return i;
        }
        self.classes.push((class, Trie::new()));
        self.classes.len() - 1
    }

    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    /// Live snapshot count (O(1) — maintained on insert/evict).
    pub fn len(&self) -> usize {
        self.live
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Bytes currently resident.
    pub fn bytes_resident(&self) -> usize {
        self.bytes
    }

    /// Counters + refreshed gauges.  The pinned gauge is the one
    /// O(entries) walk here (pin state lives in `Arc` counts, which the
    /// store cannot observe incrementally); entry counts are bounded by
    /// the byte budget, so the walk is trivial next to a forward pass.
    pub fn stats(&self) -> CacheStats {
        let mut s = self.stats;
        s.bytes_resident = self.bytes as u64;
        s.entries = self.len() as u64;
        s.pinned = self
            .entries
            .iter()
            .flatten()
            .filter(|e| Arc::strong_count(&e.snap) > 1)
            .count() as u64;
        s
    }

    /// Shared lookup body: deepest entry for `prompt` at depth
    /// ≤ `max_tokens` — pure search, no recency bump, no stats (a probe
    /// the caller then rejects must leave the LRU order untouched, or
    /// never-used entries would be freshened by failed probes).
    fn find(&self, class: u32, prompt: &[u32], max_tokens: usize) -> Option<(usize, usize)> {
        self.classes
            .iter()
            .find(|(c, _)| *c == class)
            .and_then(|(_, trie)| trie.longest_entry(prompt, max_tokens))
            .map(|(entry_id, _, depth)| (entry_id, depth))
    }

    /// Consume a successful [`StateStore::find`]: bump recency, count
    /// the hit and the skipped tokens, hand out the shared handle.
    fn take_hit(&mut self, entry_id: usize, depth: usize) -> SnapshotRef {
        let stamp = self.tick();
        let e = self.entries[entry_id].as_mut().expect("trie entry ids are live");
        e.last_used = stamp;
        self.stats.hits += 1;
        self.stats.tokens_skipped += depth as u64;
        SnapshotRef(Arc::clone(&e.snap))
    }

    /// Deepest cached state for `prompt` at depth ≤ `max_tokens`,
    /// bumping its recency.  The engine caps `max_tokens` at
    /// `prompt.len() - 1` so at least one token is always prefilled —
    /// the sampler needs the last prompt token's logits, which prefix
    /// snapshots deliberately don't carry.
    pub fn lookup(&mut self, class: u32, prompt: &[u32], max_tokens: usize) -> Option<SnapshotRef> {
        match self.find(class, prompt, max_tokens) {
            Some((entry_id, depth)) => Some(self.take_hit(entry_id, depth)),
            None => {
                self.stats.misses += 1;
                None
            }
        }
    }

    /// Exact-key probe for a secondary namespace: hits only an entry at
    /// exactly `key` (a shallower prefix entry is useless to the decode
    /// fast path, which needs the *post-prompt* state).  On success it
    /// counts a hit and credits the whole key as skipped; a miss is
    /// free — no counters, no recency perturbation — because the engine
    /// probes the decode-state namespace *before* the prefix namespace
    /// on fork requests, and that extra probe must not double-count
    /// misses against the hit rate.
    pub fn lookup_exact(&mut self, class: u32, key: &[u32]) -> Option<SnapshotRef> {
        match self.find(class, key, key.len()) {
            Some((entry_id, depth)) if depth == key.len() => Some(self.take_hit(entry_id, depth)),
            _ => None,
        }
    }

    /// Cache the state reached after `prefix` tokens.  `snapshot` is
    /// only invoked when the snapshot will actually become resident —
    /// dedup (prefix already cached: recency refresh only) and budget
    /// rejection both skip the copy, so `snapshot_len` (the flat length
    /// the closure's vector will have, i.e. the model's state length)
    /// prices the entry up front.  Returns true if a new snapshot
    /// became resident.
    pub fn insert_with(
        &mut self,
        class: u32,
        prefix: &[u32],
        snapshot_len: usize,
        snapshot: impl FnOnce() -> Vec<f32>,
    ) -> bool {
        let cost = (snapshot_len + prefix.len()) * 4;
        let tokens = prefix.len();
        matches!(
            self.insert_entry(class, prefix, cost, || {
                Arc::new(Snapshot { state: snapshot(), tokens, logits: Vec::new() })
            }),
            InsertOutcome::Inserted(_)
        )
    }

    /// Adopt an externally-built snapshot (the fork path's detached
    /// post-prompt decode state) into the store under `(class,
    /// prefix)`, sharing the same `Arc` — no float copy.  Returns the
    /// handle every caller should pin: on dedup the already-resident
    /// entry (so pin accounting tracks the resident `Arc`), otherwise
    /// `snap` itself — also when the budget rejects residency (the
    /// caller's branches still share the detached copy; it just isn't
    /// reusable by future requests).
    pub fn adopt(&mut self, class: u32, prefix: &[u32], snap: SnapshotRef) -> SnapshotRef {
        let cost = snap.0.cost_bytes();
        match self.insert_entry(class, prefix, cost, || Arc::clone(&snap.0)) {
            InsertOutcome::Inserted(a) | InsertOutcome::Dedup(a) => SnapshotRef(a),
            InsertOutcome::Rejected => snap,
        }
    }

    /// Shared insert machinery: `cost` prices the entry before `make`
    /// materializes (or clones a handle to) the snapshot, so dedup and
    /// budget rejection never touch the floats.
    fn insert_entry(
        &mut self,
        class: u32,
        prefix: &[u32],
        cost: usize,
        make: impl FnOnce() -> Arc<Snapshot>,
    ) -> InsertOutcome {
        if prefix.is_empty() {
            return InsertOutcome::Rejected; // the init state is free — never cache it
        }
        let class_slot = self.class_slot(class);
        let node = self.classes[class_slot].1.insert_key(prefix);
        if let Some(entry_id) = self.classes[class_slot].1.entry_at(node) {
            let stamp = self.tick();
            let e = self.entries[entry_id].as_mut().expect("live entry");
            e.last_used = stamp;
            return InsertOutcome::Dedup(Arc::clone(&e.snap));
        }
        if cost > self.cfg.max_bytes || !self.evict_down_to(self.cfg.max_bytes - cost) {
            // undo the structural node we just created (it has no entry)
            self.classes[class_slot].1.prune_from(node);
            self.stats.rejected += 1;
            return InsertOutcome::Rejected;
        }
        let snap = make();
        debug_assert_eq!(
            snap.cost_bytes(),
            cost,
            "cost hint must match the materialized snapshot"
        );
        if !(panel_all_finite(&snap.state) && panel_all_finite(&snap.logits)) {
            // quarantine at the door: a snapshot carrying NaN/±Inf must
            // never become resident, or one poisoned capture would
            // propagate the fault into every future resuming session
            self.classes[class_slot].1.prune_from(node);
            self.stats.quarantined += 1;
            return InsertOutcome::Rejected;
        }
        let shared = Arc::clone(&snap);
        let entry = Entry { snap, class_slot, node, last_used: self.tick() };
        let entry_id = match self.free.pop() {
            Some(id) => {
                self.entries[id] = Some(entry);
                id
            }
            None => {
                self.entries.push(Some(entry));
                self.entries.len() - 1
            }
        };
        self.bytes += cost;
        self.live += 1;
        self.classes[class_slot].1.set_entry(node, entry_id);
        self.stats.inserts += 1;
        InsertOutcome::Inserted(shared)
    }

    /// Remove every resident snapshot whose state or logits contain
    /// NaN/±Inf, returning how many were purged.  The insert-time scan
    /// keeps poison out of the store under normal operation, so this is
    /// the belt-and-braces sweep the engine runs when a health guard
    /// trips mid-flight: once one non-finite panel has been observed,
    /// residency-time trust is gone too.  Pinned entries are purged as
    /// well — holders keep their `Arc` (a resuming session copies the
    /// floats before mutating and re-validates on its own cycle), the
    /// store just stops handing the snapshot to future requests.
    pub fn purge_non_finite(&mut self) -> usize {
        let mut removed = 0usize;
        for i in 0..self.entries.len() {
            let poisoned = self.entries[i].as_ref().is_some_and(|e| {
                !(panel_all_finite(&e.snap.state) && panel_all_finite(&e.snap.logits))
            });
            if !poisoned {
                continue;
            }
            let e = self.entries[i].take().expect("checked live above");
            self.free.push(i);
            self.bytes -= e.snap.cost_bytes();
            self.live -= 1;
            let removed_id = self.classes[e.class_slot].1.remove_entry(e.node);
            debug_assert_eq!(removed_id, Some(i));
            self.stats.quarantined += 1;
            removed += 1;
        }
        removed
    }

    /// Crash-recovery sweep for a supervisor respawn: selectively
    /// re-admit residents instead of dropping the store.  Every entry
    /// passing the non-finite scan survives — its trie position, bytes,
    /// and `last_used` recency stamp untouched, so a redriven session
    /// resumes from its deepest healthy cached prefix and LRU order is
    /// unchanged — while poisoned entries are purged.  Pins are the
    /// caller's to clear: the supervisor drops its dead sessions (and
    /// their snapshot `Arc`s) before calling this, so survivors come
    /// back unpinned automatically.  Returns `(kept, purged)`.
    pub fn recover(&mut self) -> (usize, usize) {
        let purged = self.purge_non_finite();
        (self.live, purged)
    }

    /// Diagnostic scan: resident snapshots currently carrying
    /// non-finite values.  Always 0 under the quarantine rule — the
    /// chaos soak asserts exactly that after every faulted run.
    pub fn scan_non_finite(&self) -> usize {
        self.entries
            .iter()
            .flatten()
            .filter(|e| !(panel_all_finite(&e.snap.state) && panel_all_finite(&e.snap.logits)))
            .count()
    }

    /// Evict least-recently-used unpinned entries until at most `target`
    /// bytes are resident.  Returns false — evicting NOTHING — if pinned
    /// entries make the target unreachable: a doomed insert must not
    /// flush still-hot evictable snapshots on its way to rejection.
    /// Otherwise each round removes the global LRU victim, so the
    /// eviction *order* is exact LRU over unpinned entries.
    fn evict_down_to(&mut self, target: usize) -> bool {
        if self.bytes <= target {
            return true; // steady state: no scan, no eviction
        }
        // feasibility next: can unpinned bytes alone get us there?
        let evictable: usize = self
            .entries
            .iter()
            .flatten()
            .filter(|e| Arc::strong_count(&e.snap) == 1)
            .map(|e| e.snap.cost_bytes())
            .sum();
        if self.bytes.saturating_sub(evictable) > target {
            return false;
        }
        while self.bytes > target {
            let victim = self
                .entries
                .iter()
                .enumerate()
                .filter_map(|(i, e)| e.as_ref().map(|e| (i, e)))
                // pinned = an Arc handle lives outside the store
                .filter(|(_, e)| Arc::strong_count(&e.snap) == 1)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(i, _)| i);
            let Some(i) = victim else {
                return false;
            };
            let e = self.entries[i].take().expect("victim is live");
            self.free.push(i);
            self.bytes -= e.snap.cost_bytes();
            self.live -= 1;
            let removed = self.classes[e.class_slot].1.remove_entry(e.node);
            debug_assert_eq!(removed, Some(i));
            self.stats.evictions += 1;
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(max_bytes: usize) -> StateCacheConfig {
        StateCacheConfig { max_bytes }
    }

    fn state(fill: f32, len: usize) -> Vec<f32> {
        vec![fill; len]
    }

    /// cost of a snapshot with `s` state floats over a `t`-token key
    fn cost(s: usize, t: usize) -> usize {
        (s + t) * 4
    }

    #[test]
    fn lookup_returns_deepest_cached_prefix() {
        let mut st = StateStore::new(cfg(1 << 20));
        assert!(st.insert_with(0, &[1, 2], 8, || state(0.2, 8)));
        assert!(st.insert_with(0, &[1, 2, 3, 4], 8, || state(0.4, 8)));
        let hit = st.lookup(0, &[1, 2, 3, 4, 5, 6], 5).unwrap();
        assert_eq!(hit.tokens(), 4);
        assert_eq!(hit.state(), &state(0.4, 8)[..]);
        // the cap excludes the deep snapshot
        let hit = st.lookup(0, &[1, 2, 3, 4, 5, 6], 3).unwrap();
        assert_eq!(hit.tokens(), 2);
        assert!(st.lookup(0, &[9, 9], 2).is_none());
        let s = st.stats();
        assert_eq!((s.hits, s.misses, s.tokens_skipped), (2, 1, 6));
    }

    #[test]
    fn classes_are_isolated() {
        let mut st = StateStore::new(cfg(1 << 20));
        assert!(st.insert_with(0, &[1, 2, 3], 4, || state(1.0, 4)));
        assert!(st.lookup(1, &[1, 2, 3, 4], 3).is_none());
        assert!(st.insert_with(1, &[1, 2, 3], 4, || state(2.0, 4)));
        assert_eq!(st.lookup(0, &[1, 2, 3, 4], 3).unwrap().state()[0], 1.0);
        assert_eq!(st.lookup(1, &[1, 2, 3, 4], 3).unwrap().state()[0], 2.0);
    }

    #[test]
    fn dedup_insert_refreshes_recency_without_cloning() {
        let mut st = StateStore::new(cfg(1 << 20));
        assert!(st.insert_with(0, &[7, 8], 4, || state(1.0, 4)));
        let mut cloned = false;
        assert!(!st.insert_with(0, &[7, 8], 4, || {
            cloned = true;
            state(9.0, 4)
        }));
        assert!(!cloned, "dedup insert must not materialize a snapshot");
        assert_eq!(st.len(), 1);
        assert_eq!(st.stats().inserts, 1);
    }

    #[test]
    fn lru_eviction_order_is_exact() {
        // budget fits exactly two 4-float/2-token snapshots
        let mut st = StateStore::new(cfg(2 * cost(4, 2)));
        assert!(st.insert_with(0, &[1, 1], 4, || state(1.0, 4)));
        assert!(st.insert_with(0, &[2, 2], 4, || state(2.0, 4)));
        // touch [1,1] so [2,2] is the LRU victim
        assert!(st.lookup(0, &[1, 1, 5], 2).is_some());
        assert!(st.insert_with(0, &[3, 3], 4, || state(3.0, 4)));
        assert!(st.lookup(0, &[1, 1, 5], 2).is_some(), "recently used must survive");
        assert!(st.lookup(0, &[2, 2, 5], 2).is_none(), "LRU victim must be gone");
        assert!(st.lookup(0, &[3, 3, 5], 2).is_some());
        assert_eq!(st.stats().evictions, 1);
    }

    #[test]
    fn byte_accounting_is_exact() {
        let mut st = StateStore::new(cfg(10 * cost(16, 3)));
        let mut expect = 0usize;
        for i in 0..24u32 {
            let key = [i % 7, i % 5, i];
            if st.insert_with(0, &key, 16, || state(i as f32, 16)) {
                expect += cost(16, 3);
            }
        }
        // evictions happened; recompute expectation from the gauges
        let s = st.stats();
        assert!(s.evictions > 0, "pressure must evict");
        assert_eq!(s.entries, 10);
        assert_eq!(st.bytes_resident(), 10 * cost(16, 3));
        assert_eq!(s.bytes_resident, st.bytes_resident() as u64);
        assert!(st.bytes_resident() <= 10 * cost(16, 3));
        let _ = expect;
    }

    #[test]
    fn oversized_snapshot_is_rejected() {
        let mut st = StateStore::new(cfg(cost(4, 2) - 1));
        assert!(!st.insert_with(0, &[1, 2], 4, || state(0.0, 4)));
        assert_eq!(st.len(), 0);
        assert_eq!(st.bytes_resident(), 0);
        assert_eq!(st.stats().rejected, 1);
        // the structural node was undone: the trie is empty again
        assert!(st.lookup(0, &[1, 2, 3], 2).is_none());
    }

    #[test]
    fn pinned_entries_are_never_evicted() {
        let mut st = StateStore::new(cfg(2 * cost(4, 2)));
        assert!(st.insert_with(0, &[1, 1], 4, || state(1.0, 4)));
        assert!(st.insert_with(0, &[2, 2], 4, || state(2.0, 4)));
        // pin the LRU entry by holding its handle, as a live session does
        let pin = st.lookup(0, &[1, 1, 9], 2).unwrap();
        // make [1,1] LRU again by touching [2,2]
        assert!(st.lookup(0, &[2, 2, 9], 2).is_some());
        assert!(st.insert_with(0, &[3, 3], 4, || state(3.0, 4)));
        // the unpinned [2,2] was evicted instead of the pinned LRU [1,1]
        assert!(st.lookup(0, &[1, 1, 9], 2).is_some());
        assert!(st.lookup(0, &[2, 2, 9], 2).is_none());
        assert_eq!(pin.state(), &state(1.0, 4)[..]);
        // with both residents pinned, a new insert is rejected, not
        // forced over budget
        let pin3 = st.lookup(0, &[3, 3, 9], 2).unwrap();
        assert!(!st.insert_with(0, &[4, 4], 4, || state(4.0, 4)));
        assert_eq!(st.stats().rejected, 1);
        drop((pin, pin3));
        // unpinned now: the next insert evicts normally
        assert!(st.insert_with(0, &[4, 4], 4, || state(4.0, 4)));
        assert!(st.bytes_resident() <= 2 * cost(4, 2));
    }

    #[test]
    fn doomed_insert_does_not_flush_evictable_entries() {
        // budget 2c, [1,1] pinned + [2,2] evictable; a 2c-cost insert
        // can never fit past the pin — it must be rejected WITHOUT
        // sacrificing the still-hot evictable entry on the way
        let mut st = StateStore::new(cfg(2 * cost(4, 2)));
        assert!(st.insert_with(0, &[1, 1], 4, || state(1.0, 4)));
        assert!(st.insert_with(0, &[2, 2], 4, || state(2.0, 4)));
        let pin = st.lookup(0, &[1, 1, 9], 2).unwrap();
        assert!(!st.insert_with(0, &[3, 3, 3, 3], 6, || state(3.0, 6)));
        assert_eq!(st.stats().rejected, 1);
        assert_eq!(st.stats().evictions, 0, "doomed insert must not evict");
        assert!(st.lookup(0, &[2, 2, 9], 2).is_some(), "[2,2] must survive");
        drop(pin);
    }

    #[test]
    fn adopt_shares_the_arc_and_prices_logits() {
        let mut st = StateStore::new(cfg(1 << 20));
        let snap = SnapshotRef::detached(state(1.5, 8), 3, vec![0.25; 5]);
        assert_eq!(snap.logits(), &[0.25; 5][..]);
        let resident = st.adopt(7, &[1, 2, 3], snap.clone());
        // same Arc: adoption never copies the floats
        assert!(Arc::ptr_eq(&resident.0, &snap.0));
        assert_eq!(st.len(), 1);
        // cost covers state + logits + key tokens
        assert_eq!(st.bytes_resident(), (8 + 5 + 3) * 4);
        // lookups in the adopting class see the logits
        let hit = st.lookup(7, &[1, 2, 3], 3).unwrap();
        assert_eq!(hit.tokens(), 3);
        assert_eq!(hit.logits(), &[0.25; 5][..]);
        // adopting the same key again dedups onto the resident Arc
        let other = SnapshotRef::detached(state(9.0, 8), 3, vec![0.5; 5]);
        let back = st.adopt(7, &[1, 2, 3], other);
        assert!(Arc::ptr_eq(&back.0, &resident.0), "dedup must return the resident entry");
        assert_eq!(st.stats().inserts, 1);
    }

    #[test]
    fn adopt_rejected_over_budget_returns_the_detached_handle() {
        let mut st = StateStore::new(cfg(8));
        let snap = SnapshotRef::detached(state(0.0, 64), 4, vec![0.0; 8]);
        let back = st.adopt(0, &[1, 2, 3, 4], snap.clone());
        assert!(Arc::ptr_eq(&back.0, &snap.0), "rejection hands the detached copy back");
        assert_eq!(st.len(), 0);
        assert_eq!(st.stats().rejected, 1);
    }

    #[test]
    fn exact_lookup_counts_hits_not_misses() {
        let mut st = StateStore::new(cfg(1 << 20));
        assert!(st.lookup_exact(0, &[1, 2]).is_none());
        let s = st.stats();
        assert_eq!((s.hits, s.misses), (0, 0), "a probe miss must be free");
        assert!(st.insert_with(0, &[1, 2], 4, || state(1.0, 4)));
        // a shallower prefix entry must NOT satisfy an exact probe
        assert!(st.lookup_exact(0, &[1, 2, 3]).is_none());
        let s = st.stats();
        assert_eq!((s.hits, s.misses), (0, 0));
        assert!(st.lookup_exact(0, &[1, 2]).is_some());
        let s = st.stats();
        assert_eq!((s.hits, s.misses, s.tokens_skipped), (1, 0, 2));
    }

    #[test]
    fn failed_exact_probe_does_not_refresh_recency() {
        // budget of two entries; [1,1] is the LRU: an exact probe that
        // *finds* it as a shallower prefix but then rejects it must not
        // freshen it — failed probes must leave LRU order untouched
        let mut st = StateStore::new(cfg(2 * cost(4, 2)));
        assert!(st.insert_with(0, &[1, 1], 4, || state(1.0, 4)));
        assert!(st.insert_with(0, &[2, 2], 4, || state(2.0, 4)));
        assert!(st.lookup_exact(0, &[1, 1, 5]).is_none(), "shallower entry must not hit");
        assert!(st.insert_with(0, &[3, 3], 4, || state(3.0, 4)));
        assert!(st.lookup(0, &[1, 1, 5], 2).is_none(), "[1,1] stays the LRU victim");
        assert!(st.lookup(0, &[2, 2, 5], 2).is_some());
    }

    #[test]
    fn pinned_gauge_tracks_held_handles() {
        let mut st = StateStore::new(cfg(1 << 20));
        assert!(st.insert_with(0, &[1, 1], 4, || state(1.0, 4)));
        assert!(st.insert_with(0, &[2, 2], 4, || state(2.0, 4)));
        assert_eq!(st.stats().pinned, 0);
        let pin = st.lookup(0, &[1, 1, 9], 2).unwrap();
        assert_eq!(st.stats().pinned, 1);
        let pin2 = st.lookup(0, &[2, 2, 9], 2).unwrap();
        assert_eq!(st.stats().pinned, 2);
        drop(pin);
        assert_eq!(st.stats().pinned, 1);
        drop(pin2);
        assert_eq!(st.stats().pinned, 0);
    }

    #[test]
    fn poisoned_snapshot_is_quarantined_at_insert() {
        let mut st = StateStore::new(cfg(1 << 20));
        let mut bad = state(1.0, 4);
        bad[2] = f32::NAN;
        assert!(!st.insert_with(0, &[1, 2], 4, move || bad));
        assert_eq!(st.len(), 0);
        assert_eq!(st.bytes_resident(), 0);
        let s = st.stats();
        assert_eq!(s.quarantined, 1);
        assert_eq!(s.rejected, 0, "quarantine is not a budget rejection");
        assert_eq!(s.inserts, 0);
        // the structural node was undone, exactly like a budget reject
        assert!(st.lookup(0, &[1, 2, 3], 2).is_none());
        assert_eq!(st.scan_non_finite(), 0);
    }

    #[test]
    fn adopt_refuses_non_finite_logits() {
        let mut st = StateStore::new(cfg(1 << 20));
        let snap = SnapshotRef::detached(state(1.0, 4), 2, vec![f32::INFINITY; 3]);
        let back = st.adopt(0, &[1, 2], snap.clone());
        assert!(Arc::ptr_eq(&back.0, &snap.0), "refusal hands the detached copy back");
        assert_eq!(st.len(), 0);
        assert_eq!(st.stats().quarantined, 1);
    }

    #[test]
    fn purge_removes_poisoned_residents_even_when_pinned() {
        let mut st = StateStore::new(cfg(1 << 20));
        assert!(st.insert_with(0, &[1, 1], 4, || state(1.0, 4)));
        assert!(st.insert_with(0, &[2, 2], 4, || state(2.0, 4)));
        // poison the first resident in place — tests live inside the
        // module, so they can reach through the Arc the way a buggy
        // backend scribbling into a shared buffer would
        {
            let e = st.entries[0].as_mut().expect("first insert is live");
            Arc::get_mut(&mut e.snap).expect("unpinned").state[3] = f32::NEG_INFINITY;
        }
        let pin = st.lookup(0, &[1, 1, 9], 2).unwrap(); // pin the poisoned entry
        assert_eq!(st.scan_non_finite(), 1);
        assert_eq!(st.purge_non_finite(), 1);
        assert_eq!(st.scan_non_finite(), 0);
        // the pinned holder keeps its handle; the store stops serving it
        assert!(pin.state().iter().any(|x| !x.is_finite()));
        assert!(st.lookup(0, &[1, 1, 9], 2).is_none());
        assert!(st.lookup(0, &[2, 2, 9], 2).is_some(), "healthy resident survives");
        assert_eq!(st.len(), 1);
        assert_eq!(st.bytes_resident(), cost(4, 2));
        assert_eq!(st.stats().quarantined, 1);
        assert_eq!(st.purge_non_finite(), 0, "purge is idempotent");
    }

    #[test]
    fn recover_keeps_healthy_residents_with_recency_intact() {
        // budget of three entries; one resident poisoned, and [2,2] is
        // the LRU among the healthy pair going into the crash
        let mut st = StateStore::new(cfg(3 * cost(4, 2)));
        assert!(st.insert_with(0, &[1, 1], 4, || state(1.0, 4)));
        assert!(st.insert_with(0, &[2, 2], 4, || state(2.0, 4)));
        assert!(st.insert_with(0, &[7, 7], 4, || state(7.0, 4)));
        {
            let e = st.entries[2].as_mut().expect("third insert is live");
            Arc::get_mut(&mut e.snap).expect("unpinned").state[0] = f32::NAN;
        }
        assert!(st.lookup(0, &[1, 1, 9], 2).is_some()); // [2,2] becomes LRU
        assert_eq!(st.recover(), (2, 1));
        assert!(st.lookup(0, &[7, 7, 9], 2).is_none(), "poisoned resident is gone");
        // recency preserved across recover: fill back to budget, then
        // one more insert must evict the PRE-crash LRU [2,2], not the
        // [1,1] freshened just before the crash
        assert!(st.insert_with(0, &[3, 3], 4, || state(3.0, 4)));
        assert!(st.insert_with(0, &[4, 4], 4, || state(4.0, 4)));
        assert!(st.lookup(0, &[2, 2, 9], 2).is_none(), "pre-crash LRU is the victim");
        // the survivor is servable and uncorrupted post-respawn
        assert_eq!(st.lookup(0, &[1, 1, 9], 2).unwrap().state(), &state(1.0, 4)[..]);
        assert_eq!(st.recover(), (3, 0), "recover over a healthy store is a no-op scan");
    }

    #[test]
    fn prop_trie_lookup_matches_naive_oracle() {
        // random insert/lookup streams vs a HashMap scanning oracle —
        // covers edge splits, dedup and LRU churn in one sweep
        use crate::util::prop::{check, Gen};
        use std::collections::HashMap;
        check("statecache lookup == oracle", 30, |g: &mut Gen| {
            let mut st = StateStore::new(StateCacheConfig { max_bytes: usize::MAX });
            let mut oracle: HashMap<Vec<u32>, f32> = HashMap::new();
            let ops = g.usize_in(1, 60);
            for i in 0..ops {
                let len = g.usize_in(1, 12);
                // tiny alphabet forces shared prefixes and splits
                let key: Vec<u32> = (0..len).map(|_| g.usize_in(0, 2) as u32).collect();
                if g.usize_in(0, 2) < 2 {
                    let fill = i as f32;
                    if st.insert_with(0, &key, 4, || vec![fill; 4]) {
                        oracle.insert(key, fill);
                    }
                } else {
                    let cap = g.usize_in(0, len);
                    let got = st.lookup(0, &key, cap);
                    let want = oracle
                        .iter()
                        .filter(|(k, _)| k.len() <= cap && key.starts_with(k))
                        .max_by_key(|(k, _)| k.len());
                    match (got, want) {
                        (None, None) => {}
                        (Some(h), Some((k, &fill))) => {
                            if h.tokens() != k.len() || h.state()[0] != fill {
                                return Err(format!(
                                    "key {key:?} cap {cap}: got depth {} fill {}, want {} {}",
                                    h.tokens(),
                                    h.state()[0],
                                    k.len(),
                                    fill
                                ));
                            }
                        }
                        (got, want) => {
                            return Err(format!(
                                "key {key:?} cap {cap}: got {:?}, want {:?}",
                                got.map(|h| h.tokens()),
                                want.map(|(k, _)| k.len())
                            ));
                        }
                    }
                }
            }
            Ok(())
        });
    }
}
