//! Prefix-sharing state cache: RWKV's O(1) state makes shared prompts
//! nearly free — this subsystem makes the serving layer collect.
//!
//! # Why this is cheap for RWKV and expensive for Transformers
//!
//! A Transformer resuming a T-token shared prefix must hold that
//! prefix's KV cache: O(T · n_layer · d) floats *per cached prefix
//! length*, linear in everything — caching a 1k-token system prompt for
//! a 24-layer model is tens of megabytes, and caching it at chunk
//! granularity multiplies that again.  RWKV folds the entire history
//! into a *fixed-size* recurrent state of `n_layer * 5 * d` floats —
//! tens of kilobytes, independent of how many tokens produced it (the
//! same property HFRWKV exploits to keep all request-time state
//! on-chip).  So an RWKV snapshot costs O(1) per entry no matter the
//! prefix length, and snapshotting *every 64-token chunk boundary* of a
//! 1k-token prompt costs 16 small states, not 16 growing KV prefixes.
//!
//! # What it does
//!
//! Production traffic is dominated by requests sharing long system
//! prompts.  [`StateStore`] maps token prefixes to cached state
//! snapshots through a radix trie (the private `trie` module — arena
//! nodes, compressed edges, mid-edge splits): the engine captures a
//! snapshot at every prefill chunk boundary, and admission does a
//! longest-prefix lookup so a new session resumes prefill from the
//! deepest cached state instead of token 0 — a second request behind a
//! shared 1k-token prompt prefills only its unique suffix, collapsing
//! its time-to-first-token (measured in `rust/benches/statecache.rs`,
//! `BENCH_statecache.json`).
//!
//! # Guarantees
//!
//! * **Bit-exact**: the forward core's per-column op order is
//!   shape-invariant across decode/batched-decode/chunked-prefill (the
//!   `model::forward` walk), so a state captured at any chunk boundary
//!   is *identical* to the state a full prefill would pass through —
//!   resuming changes nothing but the work done.  Asserted at 0 ULP on
//!   both the exact and hw backends in `rust/tests/statecache.rs`.
//! * **Copy-on-write**: snapshots are immutable behind [`SnapshotRef`]
//!   `Arc` handles; sessions clone the floats only when resuming, and a
//!   held handle pins its entry against eviction.
//! * **Bounded**: a configurable byte budget with exact accounting and
//!   LRU eviction over unpinned entries ([`StateCacheConfig`]).
//! * **Quarantine rule — non-finite floats never become, or stay,
//!   resident.**  A cached state is shared across *future* sessions, so
//!   one NaN/±Inf snapshot would propagate a single numeric fault into
//!   every request that later resumes from it.  The store therefore
//!   scans every candidate's state and logits at insert
//!   ([`panel_all_finite`](crate::model::panel_all_finite)) and refuses
//!   poisoned ones (counted in [`CacheStats::quarantined`], distinct
//!   from budget `rejected`); and when the engine's health guards catch
//!   a non-finite panel mid-flight it calls
//!   [`StateStore::purge_non_finite`], which sweeps out any poisoned
//!   resident — *even pinned ones* (holders keep their `Arc`; the store
//!   just stops serving it).  The chaos soak asserts
//!   [`StateStore::scan_non_finite`] `== 0` after every faulted run.
//! * **Warm crash recovery**: a worker crash does not drop the cache.
//!   [`StateStore::recover`] runs the same non-finite sweep and keeps
//!   every healthy resident — trie position, bytes and LRU recency
//!   intact — so a session the supervisor redrives after the crash
//!   resumes from its deepest healthy cached prefix and replays only
//!   the suffix since the last chunk boundary, instead of re-prefilling
//!   from token 0 against a cold cache (pins die with the crashed
//!   sessions; only provably finite snapshots survive).
//!
//! Cache keys are namespaced by model-variant class, so states produced
//! by different numerics (`Exact` vs `HwApprox` on the PJRT runtime)
//! never cross-pollinate.  The engine additionally partitions the class
//! space with a decode-namespace bit: *decode-state* snapshots
//! (post-prompt state + last-token logits, captured by best-of-n fork
//! requests) live apart from prefix snapshots, letting an identical
//! later fork request skip its prompt prefill entirely.

mod trie;

pub mod store;

pub use store::{CacheStats, Snapshot, SnapshotRef, StateStore};

/// Configuration for a [`StateStore`].
#[derive(Clone, Copy, Debug)]
pub struct StateCacheConfig {
    /// Byte budget for resident snapshots (state floats + key tokens,
    /// exactly accounted).  The store never exceeds it: LRU entries are
    /// evicted to make room, and an insert that cannot fit (oversized,
    /// or everything resident is pinned by live sessions) is rejected.
    pub max_bytes: usize,
}

impl Default for StateCacheConfig {
    fn default() -> Self {
        // 64 MiB holds thousands of tiny-model snapshots and hundreds
        // for a 24-layer/2k-d serving model — generous next to a single
        // Transformer KV prefix, which is the point
        StateCacheConfig { max_bytes: 64 << 20 }
    }
}
