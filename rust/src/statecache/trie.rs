//! Radix (compressed prefix) trie over token streams.
//!
//! Keys are `&[u32]` token prefixes; each edge carries a token
//! *fragment* rather than a single token, so a cached 1k-token system
//! prompt costs a handful of nodes instead of a thousand.  The trie is
//! purely structural: it maps a key to an opaque entry id (the
//! [`store`](super::store) owns the snapshots, budget and LRU order) and
//! supports the three operations the store needs:
//!
//! * [`Trie::insert_key`] — locate-or-create the node at an exact key,
//!   splitting edges where the key diverges mid-fragment;
//! * [`Trie::longest_entry`] — deepest node holding an entry whose path
//!   is a prefix of the query, capped at `max_len` tokens;
//! * [`Trie::remove_entry`] — detach an entry and prune/merge the now
//!   path-only nodes so the structure stays proportional to the number
//!   of live entries.
//!
//! Nodes live in an arena (`Vec<Node>` + free list) and refer to each
//! other by index, so there is no `Rc` juggling and eviction never moves
//! a node id that still carries an entry (merging always folds a dead
//! node *into* its child, keeping the child's id stable).

/// Arena index of a node. The root is always index 0.
pub type NodeId = usize;

const ROOT: NodeId = 0;

#[derive(Debug, Default)]
struct Node {
    /// Edge fragment from the parent to this node (empty for the root).
    label: Vec<u32>,
    parent: NodeId,
    /// Children ids; looked up linearly by the first token of their
    /// label (first tokens are unique among siblings by construction).
    children: Vec<NodeId>,
    /// Opaque store entry id attached at this exact prefix, if any.
    entry: Option<usize>,
}

#[derive(Debug)]
pub struct Trie {
    nodes: Vec<Node>,
    free: Vec<NodeId>,
}

impl Trie {
    pub fn new() -> Trie {
        Trie { nodes: vec![Node::default()], free: Vec::new() }
    }

    fn alloc(&mut self, node: Node) -> NodeId {
        match self.free.pop() {
            Some(id) => {
                self.nodes[id] = node;
                id
            }
            None => {
                self.nodes.push(node);
                self.nodes.len() - 1
            }
        }
    }

    fn child_by_first(&self, n: NodeId, tok: u32) -> Option<NodeId> {
        self.nodes[n]
            .children
            .iter()
            .copied()
            .find(|&c| self.nodes[c].label.first() == Some(&tok))
    }

    /// Number of live (non-freed) nodes, root included (test-only:
    /// asserts pruning/merging reclaims structure).
    #[cfg(test)]
    pub fn node_count(&self) -> usize {
        self.nodes.len() - self.free.len()
    }

    /// The entry id at `node`, if any.
    pub fn entry_at(&self, node: NodeId) -> Option<usize> {
        self.nodes[node].entry
    }

    /// Attach an entry id to a node (the node must not already hold one;
    /// the store checks via [`Trie::entry_at`] first).
    pub fn set_entry(&mut self, node: NodeId, entry: usize) {
        debug_assert!(self.nodes[node].entry.is_none());
        self.nodes[node].entry = Some(entry);
    }

    /// Token depth of a node = total label length along its path
    /// (test-only: lookups carry depth themselves).
    #[cfg(test)]
    pub fn depth(&self, mut node: NodeId) -> usize {
        let mut d = 0;
        loop {
            d += self.nodes[node].label.len();
            if node == ROOT {
                return d;
            }
            node = self.nodes[node].parent;
        }
    }

    /// Locate or create the node at exactly `key`, splitting edges as
    /// needed.  `key` must be non-empty (the root never holds an entry).
    pub fn insert_key(&mut self, key: &[u32]) -> NodeId {
        assert!(!key.is_empty(), "empty keys are not cacheable");
        let mut node = ROOT;
        let mut pos = 0;
        while pos < key.len() {
            let Some(child) = self.child_by_first(node, key[pos]) else {
                // no edge starts with key[pos]: new leaf under `node`
                let leaf = self.alloc(Node {
                    label: key[pos..].to_vec(),
                    parent: node,
                    children: Vec::new(),
                    entry: None,
                });
                self.nodes[node].children.push(leaf);
                return leaf;
            };
            let lab_len = self.nodes[child].label.len();
            let rem = &key[pos..];
            let common = self.nodes[child]
                .label
                .iter()
                .zip(rem)
                .take_while(|(a, b)| a == b)
                .count();
            if common == lab_len {
                // whole edge matched: descend
                node = child;
                pos += lab_len;
                continue;
            }
            // the key diverges (or ends) mid-edge: split the edge at
            // `common` — `child` keeps its id (and entry) below a new
            // middle node carrying the shared fragment
            let mid = self.alloc(Node {
                label: self.nodes[child].label[..common].to_vec(),
                parent: node,
                children: vec![child],
                entry: None,
            });
            let tail = self.nodes[child].label.split_off(common);
            self.nodes[child].label = tail;
            self.nodes[child].parent = mid;
            let slot = self.nodes[node]
                .children
                .iter()
                .position(|&c| c == child)
                .expect("child listed under parent");
            self.nodes[node].children[slot] = mid;
            if common == rem.len() {
                // key ends exactly at the split point
                return mid;
            }
            // key continues past the split: new leaf under `mid`
            let leaf = self.alloc(Node {
                label: key[pos + common..].to_vec(),
                parent: mid,
                children: Vec::new(),
                entry: None,
            });
            self.nodes[mid].children.push(leaf);
            return leaf;
        }
        node
    }

    /// Deepest node on the path of `key` that holds an entry, at token
    /// depth ≤ `max_len`.  Returns `(entry id, node, depth)`.
    pub fn longest_entry(&self, key: &[u32], max_len: usize) -> Option<(usize, NodeId, usize)> {
        let mut best = None;
        let mut node = ROOT;
        let mut pos = 0;
        loop {
            if pos > max_len {
                return best;
            }
            if let Some(e) = self.nodes[node].entry {
                best = Some((e, node, pos));
            }
            if pos == key.len() {
                return best;
            }
            let Some(child) = self.child_by_first(node, key[pos]) else {
                return best;
            };
            let lab = &self.nodes[child].label;
            if lab.len() > key.len() - pos
                || pos + lab.len() > max_len
                || lab != &key[pos..pos + lab.len()]
            {
                return best;
            }
            pos += lab.len();
            node = child;
        }
    }

    /// Detach the entry at `node` and prune: childless entry-less nodes
    /// are freed bottom-up, and an entry-less node left with exactly one
    /// child is folded *into* that child (the child's id — and therefore
    /// any entry id attached to it — is preserved; only its label grows
    /// at the front).  Returns the detached entry id.
    pub fn remove_entry(&mut self, node: NodeId) -> Option<usize> {
        let entry = self.nodes[node].entry.take();
        self.prune_from(node);
        entry
    }

    /// Prune upward from a possibly-dead node (also used to undo a
    /// structural `insert_key` whose entry was never attached, e.g. when
    /// the budget rejects the snapshot).
    pub fn prune_from(&mut self, mut node: NodeId) {
        loop {
            if node == ROOT || self.nodes[node].entry.is_some() {
                return;
            }
            match self.nodes[node].children.len() {
                0 => {
                    let parent = self.nodes[node].parent;
                    let slot = self.nodes[parent]
                        .children
                        .iter()
                        .position(|&c| c == node)
                        .expect("node listed under parent");
                    self.nodes[parent].children.swap_remove(slot);
                    self.nodes[node] = Node::default();
                    self.free.push(node);
                    node = parent;
                }
                1 => {
                    // fold `node` into its only child: the child absorbs
                    // the label prefix and reattaches to the grandparent
                    let child = self.nodes[node].children[0];
                    let parent = self.nodes[node].parent;
                    let mut label = std::mem::take(&mut self.nodes[node].label);
                    label.extend_from_slice(&self.nodes[child].label);
                    self.nodes[child].label = label;
                    self.nodes[child].parent = parent;
                    let slot = self.nodes[parent]
                        .children
                        .iter()
                        .position(|&c| c == node)
                        .expect("node listed under parent");
                    self.nodes[parent].children[slot] = child;
                    self.nodes[node] = Node::default();
                    self.free.push(node);
                    return;
                }
                _ => return,
            }
        }
    }
}

impl Default for Trie {
    fn default() -> Self {
        Trie::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_then_longest_prefix() {
        let mut t = Trie::new();
        let a = t.insert_key(&[1, 2, 3, 4]);
        t.set_entry(a, 10);
        let b = t.insert_key(&[1, 2, 3, 4, 5, 6]);
        t.set_entry(b, 11);
        // full-depth hit
        assert_eq!(t.longest_entry(&[1, 2, 3, 4, 5, 6, 7], usize::MAX), Some((11, b, 6)));
        // cap forces the shallower entry
        assert_eq!(t.longest_entry(&[1, 2, 3, 4, 5, 6, 7], 5), Some((10, a, 4)));
        assert_eq!(t.longest_entry(&[1, 2, 3, 4, 5, 6, 7], 3), None);
        // divergent query stops at the last matching entry
        assert_eq!(t.longest_entry(&[1, 2, 3, 4, 9], usize::MAX), Some((10, a, 4)));
        assert_eq!(t.longest_entry(&[2, 2], usize::MAX), None);
    }

    #[test]
    fn mid_edge_split_preserves_entries() {
        let mut t = Trie::new();
        let deep = t.insert_key(&[7, 8, 9, 10]);
        t.set_entry(deep, 1);
        // a shorter key that ends mid-edge splits it
        let mid = t.insert_key(&[7, 8]);
        t.set_entry(mid, 2);
        assert_eq!(t.longest_entry(&[7, 8, 9, 10], usize::MAX), Some((1, deep, 4)));
        assert_eq!(t.longest_entry(&[7, 8, 9], usize::MAX), Some((2, mid, 2)));
        // a diverging key splits and branches
        let div = t.insert_key(&[7, 8, 9, 99]);
        t.set_entry(div, 3);
        assert_eq!(t.longest_entry(&[7, 8, 9, 99], usize::MAX), Some((3, div, 4)));
        assert_eq!(t.longest_entry(&[7, 8, 9, 10], usize::MAX), Some((1, deep, 4)));
    }

    #[test]
    fn insert_same_key_returns_same_node() {
        let mut t = Trie::new();
        let a = t.insert_key(&[5, 6, 7]);
        t.set_entry(a, 0);
        assert_eq!(t.insert_key(&[5, 6, 7]), a);
        assert_eq!(t.entry_at(a), Some(0));
    }

    #[test]
    fn remove_prunes_and_merges() {
        let mut t = Trie::new();
        let a = t.insert_key(&[1, 2]);
        t.set_entry(a, 0);
        let b = t.insert_key(&[1, 2, 3, 4]);
        t.set_entry(b, 1);
        let base = t.node_count();
        // removing the middle entry merges its node into the deep child
        assert_eq!(t.remove_entry(a), Some(0));
        assert_eq!(t.node_count(), base - 1);
        assert_eq!(t.longest_entry(&[1, 2, 3, 4], usize::MAX), Some((1, b, 4)));
        assert_eq!(t.longest_entry(&[1, 2, 3], usize::MAX), None);
        // removing the last entry collapses the trie back to the root
        assert_eq!(t.remove_entry(b), Some(1));
        assert_eq!(t.node_count(), 1);
        assert_eq!(t.longest_entry(&[1, 2, 3, 4], usize::MAX), None);
    }

    #[test]
    fn depth_tracks_path_length() {
        let mut t = Trie::new();
        let a = t.insert_key(&[4, 4, 4, 4, 4]);
        assert_eq!(t.depth(a), 5);
        let b = t.insert_key(&[4, 4]);
        assert_eq!(t.depth(b), 2);
        assert_eq!(t.depth(a), 5, "split must not change depths");
    }
}
