//! Analytic CPU/GPU baselines for Figs 7–8 (DESIGN.md §2, §8).
//!
//! Batch-1 RWKV decode on commodity hardware decomposes into a per-token
//! dispatch floor (the python generation loop + per-op kernel launches +
//! the multi-kernel LayerNorm reductions of the paper's §1 motivation),
//! a small per-layer term, and weight streaming:
//!
//! `t_token = c + a·n_layer + bytes/(BW·eff)`      (weights resident)
//! `t_token = c + a·n_layer + bytes/PCIe_BW`        (weights exceed VRAM)
//!
//! Constants are calibrated ONCE against the paper's 169M ratio column
//! (the only size with fully quoted ratios) and physical bandwidth, then
//! held fixed for all other sizes — the 7B crossover (U50 < A100 < U280)
//! must *emerge* from the byte arithmetic, and does (EXPERIMENTS.md E3).
//!
//! Note on fidelity: the paper's A100 169M:7B throughput ratio (≈2.7×
//! for 44× the bytes) is only satisfiable with a launch-dominated model;
//! a pure-roofline GPU would be several times faster at 169M than the
//! paper measured.  That launch-bound behaviour is literally the paper's
//! claim (1)-(3) in §1, so we model it directly.

use crate::config::ModelShape;

/// One baseline platform (CPU or GPU).
#[derive(Clone, Copy, Debug)]
pub struct BaselineSpec {
    pub name: &'static str,
    /// fixed per-token dispatch floor, seconds (python loop, sampling,
    /// host sync) — the dominant term at small models
    pub token_overhead_s: f64,
    /// per-layer dispatch overhead, seconds
    pub layer_overhead_s: f64,
    /// device memory bandwidth, bytes/s
    pub mem_bw: f64,
    /// sustained fraction of that bandwidth on this workload
    pub bw_eff: f64,
    /// device memory capacity, bytes (0 = host memory, never spills)
    pub vram_bytes: u64,
    /// host↔device link bandwidth used when weights exceed VRAM
    pub pcie_bw: f64,
    /// bytes per weight as served by ChatRWKV on this platform
    /// (fp32 on CPU, fp16 on GPU)
    pub bytes_per_weight: f64,
    /// measured power draw during RWKV inference, watts (calibrated so
    /// the paper's energy-efficiency headlines reproduce; see Fig 8)
    pub power_w: f64,
}

impl BaselineSpec {
    /// Bytes of weights touched per generated token.
    pub fn weight_bytes(&self, shape: &ModelShape) -> f64 {
        shape.n_params() as f64 * self.bytes_per_weight
    }

    /// Whether the model's weights fit device memory.
    pub fn fits_vram(&self, shape: &ModelShape) -> bool {
        self.vram_bytes == 0 || self.weight_bytes(shape) <= self.vram_bytes as f64 * 0.92
    }

    /// Seconds per generated token.
    pub fn token_seconds(&self, shape: &ModelShape) -> f64 {
        let bytes = self.weight_bytes(shape);
        let stream = if self.fits_vram(shape) {
            bytes / (self.mem_bw * self.bw_eff)
        } else {
            // weights spill: every token re-streams them over the link
            bytes / self.pcie_bw
        };
        self.token_overhead_s + self.layer_overhead_s * shape.n_layer as f64 + stream
    }

    pub fn tokens_per_sec(&self, shape: &ModelShape) -> f64 {
        1.0 / self.token_seconds(shape)
    }

    pub fn tokens_per_joule(&self, shape: &ModelShape) -> f64 {
        self.tokens_per_sec(shape) / self.power_w
    }
}

/// Intel Core i7-12650H + DDR4 (paper §5.1), ChatRWKV fp32 CPU path.
/// Calibrated to the 26.74× @169M anchor; bandwidth-bound beyond 430M.
pub const CPU_I7_12650H: BaselineSpec = BaselineSpec {
    name: "CPU i7-12650H",
    token_overhead_s: 1.2e-3,
    layer_overhead_s: 0.45e-3,
    mem_bw: 60.0e9,
    bw_eff: 0.58,
    vram_bytes: 0,
    pcie_bw: f64::INFINITY,
    bytes_per_weight: 4.0,
    power_w: 54.5,
};

/// NVIDIA RTX 2080Ti (616 GB/s, 11 GB).  7B fp16 exceeds VRAM → PCIe3.
pub const GPU_2080TI: BaselineSpec = BaselineSpec {
    name: "RTX 2080Ti",
    token_overhead_s: 13.0e-3,
    layer_overhead_s: 0.05e-3,
    mem_bw: 616.0e9,
    bw_eff: 0.90,
    vram_bytes: 11 * 1_073_741_824,
    pcie_bw: 13.0e9,
    bytes_per_weight: 2.0,
    power_w: 126.0,
};

/// NVIDIA RTX 3090 (936 GB/s, 24 GB).
pub const GPU_3090: BaselineSpec = BaselineSpec {
    name: "RTX 3090",
    token_overhead_s: 8.1e-3,
    layer_overhead_s: 0.05e-3,
    mem_bw: 936.0e9,
    bw_eff: 0.95,
    vram_bytes: 24 * 1_073_741_824,
    pcie_bw: 13.0e9,
    bytes_per_weight: 2.0,
    power_w: 168.0,
};

/// NVIDIA A100-40G (1555 GB/s).
pub const GPU_A100: BaselineSpec = BaselineSpec {
    name: "A100",
    token_overhead_s: 5.6e-3,
    layer_overhead_s: 0.065e-3,
    mem_bw: 1555.0e9,
    bw_eff: 0.84,
    vram_bytes: 40 * 1_073_741_824,
    pcie_bw: 26.0e9,
    bytes_per_weight: 2.0,
    power_w: 152.0,
};

pub const ALL_BASELINES: [BaselineSpec; 4] =
    [CPU_I7_12650H, GPU_2080TI, GPU_3090, GPU_A100];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PAPER_SHAPES;

    #[test]
    fn gpu_order_at_169m() {
        let s = &PAPER_SHAPES[0];
        assert!(GPU_A100.tokens_per_sec(s) > GPU_3090.tokens_per_sec(s));
        assert!(GPU_3090.tokens_per_sec(s) > GPU_2080TI.tokens_per_sec(s));
        assert!(GPU_2080TI.tokens_per_sec(s) > CPU_I7_12650H.tokens_per_sec(s));
    }

    #[test]
    fn cpu_bandwidth_bound_at_7b() {
        let s = &PAPER_SHAPES[4];
        let bytes_t = s.n_params() as f64 * 4.0 / (60e9 * 0.58);
        let total = CPU_I7_12650H.token_seconds(s);
        assert!(bytes_t / total > 0.95, "{}", bytes_t / total);
    }

    #[test]
    fn gpus_launch_bound_at_169m() {
        // the paper's §1 motivation: at 169M the GPU spends most of the
        // token on dispatch, not on memory traffic
        let s = &PAPER_SHAPES[0];
        for g in [GPU_2080TI, GPU_3090, GPU_A100] {
            let overhead = g.token_overhead_s + g.layer_overhead_s * s.n_layer as f64;
            assert!(overhead / g.token_seconds(s) > 0.8, "{}", g.name);
        }
    }

    #[test]
    fn a100_bandwidth_matters_at_7b() {
        // ...but at 7B the byte term is a major fraction on the A100
        let s = &PAPER_SHAPES[4];
        let g = GPU_A100;
        let bytes_t = g.weight_bytes(s) / (g.mem_bw * g.bw_eff);
        assert!(bytes_t / g.token_seconds(s) > 0.5);
    }

    #[test]
    fn vram_spill_cliff_2080ti() {
        // 7B fp16 = ~14.8 GB > 11 GB: the 2080Ti must fall off the PCIe
        // cliff; 3B (~6 GB) still fits
        assert!(GPU_2080TI.fits_vram(&PAPER_SHAPES[3]));
        assert!(!GPU_2080TI.fits_vram(&PAPER_SHAPES[4]));
        let t3b = GPU_2080TI.tokens_per_sec(&PAPER_SHAPES[3]);
        let t7b = GPU_2080TI.tokens_per_sec(&PAPER_SHAPES[4]);
        assert!(t3b / t7b > 10.0, "{t3b} {t7b}");
    }

    #[test]
    fn throughput_decreases_with_size() {
        for b in ALL_BASELINES {
            let mut prev = f64::INFINITY;
            for s in &PAPER_SHAPES {
                let t = b.tokens_per_sec(s);
                assert!(t < prev, "{} {}", b.name, s.name);
                prev = t;
            }
        }
    }
}
