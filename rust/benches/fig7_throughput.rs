//! Bench + regenerate E3 (Fig 7): simulator evaluation cost per
//! (config, shape) pair and the full throughput grid with the paper's
//! ratio anchors.

use hfrwkv::config::{HFRWKV_CONFIGS, PAPER_SHAPES};
use hfrwkv::harness::fig7;
use hfrwkv::sim::AccelSim;
use hfrwkv::util::bench::{bench, section};

fn main() {
    section("simulator evaluation cost");
    let sim = AccelSim::new(&HFRWKV_CONFIGS[3]);
    bench("AccelSim.evaluate 7B (streaming)", || sim.evaluate(&PAPER_SHAPES[4]));
    let sim0 = AccelSim::new(&HFRWKV_CONFIGS[0]);
    bench("AccelSim.evaluate 169M (resident)", || sim0.evaluate(&PAPER_SHAPES[0]));
    bench("full fig7 grid (30 evaluations)", fig7::run);

    section("Fig 7 regeneration");
    println!("{}", fig7::report(&fig7::run(), true).unwrap());
}
