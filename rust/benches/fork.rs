//! Best-of-N decode benchmark: shared-state fork vs naive N requests.
//!
//! For N ∈ {1, 2, 4, 8}, serve the same best-of-N workload two ways:
//!
//! * **fork** — ONE request with `n_best = N`: the prompt is prefilled
//!   once, the post-prompt RWKV state (O(1) bytes) is snapshotted, and
//!   N branches decode off the shared pin with seeds `seed + b`;
//! * **naive** — N independent requests with seeds `seed + b`, each
//!   prefilling the whole prompt itself (state cache disabled so the
//!   prefix cache can't mask the comparison).
//!
//! Branch outputs are asserted bit-identical between the two modes
//! (always — it is deterministic), and under `FORK_BENCH_ASSERT=1` the
//! measured prefill work must be exactly `prompt_len` for fork vs
//! `N * prompt_len` for naive — the 1/N prefill-work claim, read
//! straight off the coordinator's `prompt_tokens_prefilled` metric.
//! Both gates are token-exact (never wall-clock), so CI sets the env
//! safely; wall-clock speedups are recorded in the JSON but never gate.
//!
//! Emits `BENCH_fork.json` so future PRs can track the trajectory.

use std::time::Instant;

use hfrwkv::coordinator::{Coordinator, CoordinatorConfig, GenRequest};
use hfrwkv::model::rwkv::testing::test_model;
use hfrwkv::util::bench::{section, BenchReport};

const PROMPT_LEN: usize = 256;
const DECODE: usize = 32;
const NS: [usize; 4] = [1, 2, 4, 8];
const SEED: u64 = 7;

fn prompt() -> Vec<u32> {
    (0..PROMPT_LEN as u32).map(|t| (t * 7 + 3) % 128).collect()
}

fn mk_coord() -> Coordinator {
    Coordinator::spawn(
        test_model(4, 128, 512, 128),
        // cache OFF: the naive baseline must genuinely re-prefill, and
        // the prefilled-token metric must count exactly the submitted
        // prompts — this isolates the fork's saving from the prefix
        // cache's (benched separately in statecache.rs)
        CoordinatorConfig { max_active: 8, state_cache_bytes: 0, ..Default::default() },
    )
}

fn req(n_best: usize, seed: u64) -> GenRequest {
    GenRequest::builder(prompt(), DECODE)
        .temperature(0.9)
        .top_k(40)
        .seed(seed)
        .n_best(n_best)
        .build()
}

fn main() {
    let mut report = BenchReport::new("fork");
    let hard_assert = matches!(std::env::var("FORK_BENCH_ASSERT").as_deref(), Ok("1"));

    section("best-of-N: shared-state fork vs naive N requests (4x128 model, 256-token prompt)");
    for &n in &NS {
        // fork mode: ONE request with n_best = n
        let coord = mk_coord();
        let t0 = Instant::now();
        let forked = coord.generate_all(req(n, SEED)).expect("fork mode");
        let fork_wall = t0.elapsed().as_secs_f64();
        let fork_prefilled = coord.metrics.lock().unwrap().prompt_tokens_prefilled;
        drop(coord);

        // naive mode: n independent requests at seeds SEED + b
        let coord = mk_coord();
        let t0 = Instant::now();
        let rxs: Vec<_> = (0..n)
            .map(|b| coord.submit(req(1, SEED + b as u64)).expect("under max_queue"))
            .collect();
        let naive: Vec<_> = rxs
            .into_iter()
            .map(|s| s.wait_one().expect("naive mode"))
            .collect();
        let naive_wall = t0.elapsed().as_secs_f64();
        let naive_prefilled = coord.metrics.lock().unwrap().prompt_tokens_prefilled;
        drop(coord);

        // branch b of the fork must be bit-identical to naive request b
        assert_eq!(forked.len(), naive.len());
        for (b, (f, s)) in forked.iter().zip(&naive).enumerate() {
            assert_eq!(
                f.tokens, s.tokens,
                "N={n} branch {b}: fork diverged from its sequential seed run"
            );
        }

        let speedup = naive_wall / fork_wall.max(1e-12);
        let work_ratio = fork_prefilled as f64 / naive_prefilled.max(1) as f64;
        println!(
            "  N={n}: fork {:>7.1} ms vs naive {:>7.1} ms ({speedup:>5.2}x wall)  \
             prefill work {fork_prefilled} vs {naive_prefilled} tokens ({work_ratio:.3} = 1/{n})",
            fork_wall * 1e3,
            naive_wall * 1e3,
        );
        report.record(&format!("fork_wall_ms_n{n}"), fork_wall * 1e3);
        report.record(&format!("naive_wall_ms_n{n}"), naive_wall * 1e3);
        report.record(&format!("wall_speedup_n{n}"), speedup);
        report.record(&format!("fork_prefilled_tokens_n{n}"), fork_prefilled as f64);
        report.record(&format!("naive_prefilled_tokens_n{n}"), naive_prefilled as f64);
        report.record(&format!("prefill_work_ratio_n{n}"), work_ratio);

        if hard_assert {
            // the acceptance bar: n_best = N performs exactly ONE prompt
            // prefill, i.e. 1/N of the naive mode's prefill work
            assert_eq!(
                fork_prefilled,
                PROMPT_LEN as u64,
                "N={n}: fork mode must prefill the prompt exactly once"
            );
            assert_eq!(
                naive_prefilled,
                (n * PROMPT_LEN) as u64,
                "N={n}: naive mode must prefill the prompt N times"
            );
        }
    }

    match report.write() {
        Ok(path) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\nfailed to write bench report: {e}"),
    }
}
