//! Chaos soak sweep: the fault-tolerant serving stack under a seeded
//! fault-injection grid (fault rate × batch capacity), plus the cost of
//! the health guards themselves at zero fault rate.
//!
//! Each cell drives a fixed request mix through a coordinator wrapping
//! [`hfrwkv::chaos::ChaosModel`] and accounts every terminal: clean
//! finishes must be **bit-exact** with the fault-free run (rollback
//! recovery is a replay, not an approximation), typed faults must carry
//! a healthy token prefix, and the gauges must drain to zero.  The
//! structural invariants always run; under `CHAOS_SOAK_ASSERT=1` any
//! violation hard-fails the bench (what CI sets).
//!
//! A second sweep kills the whole worker thread every Nth scheduling
//! cycle ([`hfrwkv::chaos::ChaosConfig::worker_kill_every`]) with the
//! requests carrying a redrive budget, soaking the self-healing path:
//! every stream is drained event-by-event and checked structurally —
//! `seq_idx` gapless across every [`GenEvent::Redriven`] seam, at most
//! `budget` redrives per stream, exactly one terminal, zero client
//! re-submissions (each request is submitted once, ever).  Requests
//! that finish clean must be bit-exact; requests that exhaust their
//! budget must fail typed ([`FinishReason::WorkerFailed`]) carrying a
//! healthy prefix; and the structured fault journal must attribute
//! every crash decision (`Redriven` records == coordinator redrives,
//! `SessionFailed` records == WorkerFailed terminals).
//!
//! Emits `BENCH_chaos.json` (recovery rates, guard overhead, redrive
//! counts, crash-survivor cache size, resume-after-kill latency) so
//! future PRs can track the whole fault surface.

use std::time::Instant;

use hfrwkv::chaos::{ChaosConfig, ChaosModel};
use hfrwkv::coordinator::{
    Coordinator, CoordinatorConfig, FaultKind, FaultPolicy, FinishReason, GenEvent, GenRequest,
    GenResponse, RecoveryAction,
};
use hfrwkv::model::rwkv::testing::test_model;
use hfrwkv::model::RwkvModel;
use hfrwkv::util::bench::{section, BenchReport};

const N_REQUESTS: u32 = 24;
const TOKENS_PER_REQUEST: usize = 8;
const RATES: [f64; 3] = [0.0, 0.05, 0.2];
const CAPS: [usize; 2] = [2, 8];
/// Crash redrives allowed per request in the worker-kill sweep.
const REDRIVE_BUDGET: u32 = 2;
/// Worker-kill sweep cells: (kill every Nth cycle, max_active).  The
/// tight cell crashes most requests at least once and exhausts some
/// budgets; the loose cells keep most requests clean.
const KILL_CELLS: [(u64, usize); 3] = [(4, 2), (6, 8), (11, 8)];

fn model() -> RwkvModel {
    test_model(2, 32, 64, 50)
}

fn requests() -> Vec<GenRequest> {
    (0..N_REQUESTS)
        .map(|i| GenRequest::greedy(vec![(i * 7 + 1) % 50, (i * 3 + 2) % 50], TOKENS_PER_REQUEST))
        .collect()
}

fn policy(health_guards: bool) -> FaultPolicy {
    // deep retry budget + zero backoff: the soak measures recovery, not
    // sleep time
    FaultPolicy { health_guards, max_retries: 12, retry_backoff_ms: 0 }
}

struct CellOutcome {
    clean: usize,
    numeric_faulted: usize,
    errored: usize,
    mismatched: usize,
    wall_s: f64,
    retries: u64,
    rollbacks: u64,
    panics_caught: u64,
    injected: u64,
    gauges_zero: bool,
    cache_poisoned: u64,
    restarts: u64,
}

/// One sweep cell: N requests through a chaos coordinator; terminals
/// accounted against the fault-free expected tokens.
fn run_cell(rate: f64, cap: usize, seed: u64, expected: &[Vec<u32>]) -> CellOutcome {
    let chaotic = ChaosModel::new(
        model(),
        ChaosConfig { seed, fault_rate: rate, ..ChaosConfig::default() },
    );
    let log = chaotic.log_handle();
    let cfg = CoordinatorConfig { max_active: cap, fault: policy(true), ..Default::default() };
    let t0 = Instant::now();
    let c = Coordinator::spawn(chaotic, cfg);
    let streams: Vec<_> = requests()
        .into_iter()
        .map(|r| c.submit(r).expect("soak stays under max_queue"))
        .collect();
    let (mut clean, mut numeric_faulted, mut errored, mut mismatched) = (0, 0, 0, 0);
    for (i, s) in streams.into_iter().enumerate() {
        // wait_one always returns — panic isolation means a faulting
        // model can never hang a stream (regression-tested in
        // rust/tests/chaos.rs)
        match s.wait_one() {
            Ok(r) => match r.finish {
                FinishReason::MaxTokens => {
                    if r.tokens == expected[i] {
                        clean += 1;
                    } else {
                        mismatched += 1;
                    }
                }
                FinishReason::NumericFault => {
                    if r.tokens.len() < expected[i].len()
                        && r.tokens == expected[i][..r.tokens.len()]
                    {
                        numeric_faulted += 1;
                    } else {
                        mismatched += 1;
                    }
                }
                _ => mismatched += 1,
            },
            Err(_) => errored += 1,
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let m = c.metrics.lock().unwrap_or_else(|e| e.into_inner()).clone();
    let injected = log.lock().unwrap_or_else(|e| e.into_inner()).corruptions();
    CellOutcome {
        clean,
        numeric_faulted,
        errored,
        mismatched,
        wall_s,
        retries: m.fault_retries,
        rollbacks: m.fault_rollbacks,
        panics_caught: m.panics_caught,
        injected,
        gauges_zero: m.active_sessions == 0 && m.queue_depth == 0,
        cache_poisoned: m.prefix_cache_quarantined,
        restarts: m.worker_restarts,
    }
}

struct KillOutcome {
    clean: usize,
    redriven_clean: usize,
    worker_failed: usize,
    mismatched: usize,
    stream_violations: Vec<String>,
    kills: u64,
    restarts: u64,
    redrives: u64,
    redrives_completed: u64,
    redrives_resumed: u64,
    resume_seconds_total: f64,
    recovered_snapshots: u64,
    journal_redriven: usize,
    journal_failed: usize,
    gauges_zero: bool,
    wall_s: f64,
}

/// One worker-kill cell: N redrive-budgeted requests through a
/// coordinator whose worker panics every Nth scheduling cycle.  Every
/// stream is drained event-by-event so the per-stream structure (seam
/// placement, seq_idx continuity, one terminal) is checked, not just
/// the terminal.
fn run_kill_cell(kill_every: u64, cap: usize, expected: &[Vec<u32>]) -> KillOutcome {
    let chaotic = ChaosModel::new(
        model(),
        ChaosConfig {
            seed: kill_every * 31 + cap as u64,
            fault_rate: 0.0,
            worker_kill_every: kill_every,
            ..ChaosConfig::default()
        },
    );
    let log = chaotic.log_handle();
    let cfg = CoordinatorConfig { max_active: cap, fault: policy(true), ..Default::default() };
    let t0 = Instant::now();
    let c = Coordinator::spawn(chaotic, cfg);
    // each request is submitted exactly once — transparent redrive means
    // the client never re-submits, whatever the worker does
    let streams: Vec<_> = requests()
        .into_iter()
        .map(|r| {
            GenRequest::builder(r.prompt, TOKENS_PER_REQUEST).redrive_budget(REDRIVE_BUDGET).build()
        })
        .map(|r| c.submit(r).expect("soak stays under max_queue"))
        .collect();

    let (mut clean, mut redriven_clean, mut worker_failed, mut mismatched) = (0, 0, 0, 0);
    let mut violations: Vec<String> = Vec::new();
    for (i, mut s) in streams.into_iter().enumerate() {
        let mut toks: Vec<u32> = Vec::new();
        let mut redriven_events = 0u32;
        let mut terminal: Option<GenResponse> = None;
        while let Some(ev) = s.recv() {
            match ev {
                GenEvent::Started { .. } => {}
                GenEvent::Token { seq_idx, token, .. } => {
                    if seq_idx != toks.len() {
                        violations.push(format!(
                            "req {i}: Token seq_idx {seq_idx} but {} delivered (gap/dup)",
                            toks.len()
                        ));
                    }
                    toks.push(token);
                }
                GenEvent::Redriven { replayed_from, .. } => {
                    redriven_events += 1;
                    if replayed_from != toks.len() {
                        violations.push(format!(
                            "req {i}: Redriven replayed_from {replayed_from} but {} delivered",
                            toks.len()
                        ));
                    }
                }
                GenEvent::Finished(r) => {
                    if terminal.is_some() {
                        violations.push(format!("req {i}: second terminal"));
                    }
                    terminal = Some(r);
                }
                GenEvent::Error { message, .. } => {
                    violations.push(format!("req {i}: error terminal under kills: {message}"));
                }
            }
        }
        if redriven_events > REDRIVE_BUDGET {
            violations.push(format!("req {i}: {redriven_events} redrives exceed the budget"));
        }
        let Some(r) = terminal else {
            violations.push(format!("req {i}: stream closed without a terminal"));
            continue;
        };
        if r.tokens != toks {
            violations.push(format!("req {i}: response tokens diverge from streamed tokens"));
        }
        match r.finish {
            FinishReason::MaxTokens => {
                if r.tokens == expected[i] {
                    clean += 1;
                    if redriven_events > 0 {
                        redriven_clean += 1;
                    }
                } else {
                    mismatched += 1;
                }
            }
            FinishReason::WorkerFailed => {
                worker_failed += 1;
                if redriven_events != REDRIVE_BUDGET {
                    violations.push(format!(
                        "req {i}: WorkerFailed after {redriven_events} redrives (budget not spent)"
                    ));
                }
                if toks.len() >= expected[i].len() || toks != expected[i][..toks.len()] {
                    violations.push(format!(
                        "req {i}: WorkerFailed tokens are not a healthy strict prefix"
                    ));
                }
            }
            other => {
                violations.push(format!("req {i}: unexpected finish under kills: {other:?}"));
            }
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let m = c.metrics.lock().unwrap_or_else(|e| e.into_inner()).clone();
    let j = c.fault_journal();
    let crash = |action: RecoveryAction| {
        j.iter().filter(|e| e.kind == FaultKind::WorkerCrash && e.action == action).count()
    };
    KillOutcome {
        clean,
        redriven_clean,
        worker_failed,
        mismatched,
        stream_violations: violations,
        kills: log.lock().unwrap_or_else(|e| e.into_inner()).worker_kills,
        restarts: m.worker_restarts,
        redrives: m.redrives,
        redrives_completed: m.redrives_completed,
        redrives_resumed: m.redrives_resumed,
        resume_seconds_total: m.redrive_resume_seconds_total,
        recovered_snapshots: m.cache_recovered_snapshots,
        journal_redriven: crash(RecoveryAction::Redriven),
        journal_failed: crash(RecoveryAction::SessionFailed),
        gauges_zero: m.active_sessions == 0 && m.queue_depth == 0,
        wall_s,
    }
}

/// Aggregate throughput of the request mix through a plain (un-wrapped)
/// model coordinator under the given fault policy — guards-on vs
/// guards-off is the cost of the per-cycle NaN scans and last-good
/// snapshots on the hot path.
fn throughput(health_guards: bool, cap: usize) -> f64 {
    let cfg = CoordinatorConfig {
        max_active: cap,
        fault: policy(health_guards),
        ..Default::default()
    };
    let t0 = Instant::now();
    let c = Coordinator::spawn(model(), cfg);
    let streams: Vec<_> = requests()
        .into_iter()
        .map(|r| c.submit(r).expect("soak stays under max_queue"))
        .collect();
    let mut total = 0usize;
    for s in streams {
        total += s.wait_one().unwrap().tokens.len();
    }
    total as f64 / t0.elapsed().as_secs_f64()
}

fn main() {
    let hard_assert = matches!(std::env::var("CHAOS_SOAK_ASSERT").as_deref(), Ok("1"));
    let mut report = BenchReport::new("chaos");
    let mut violations: Vec<String> = Vec::new();

    // the injected panics and worker kills would each print a full
    // default-hook backtrace — silence exactly those (this binary is
    // single-purpose, and real assertion failures still report through
    // the kept default hook)
    let default_hook = std::panic::take_hook();
    std::panic::set_hook(Box::new(move |info| {
        let payload = info.payload();
        let msg = payload
            .downcast_ref::<&str>()
            .copied()
            .or_else(|| payload.downcast_ref::<String>().map(|s| s.as_str()));
        if !msg.is_some_and(|s| s.contains("chaos: injected")) {
            default_hook(info);
        }
    }));

    // fault-free ground truth (tokens are independent of batching and
    // of the chaos wrapper at rate 0)
    let expected: Vec<Vec<u32>> = {
        let c = Coordinator::spawn(model(), CoordinatorConfig::default());
        requests()
            .into_iter()
            .map(|r| c.generate(r).expect("fault-free run cannot fail").tokens)
            .collect()
    };

    section("chaos soak: fault rate x max_active (24 req x 8 tok, seeded)");
    for &rate in &RATES {
        for &cap in &CAPS {
            let seed = (rate * 100.0) as u64 * 100 + cap as u64;
            let o = run_cell(rate, cap, seed, &expected);
            let key = format!("rate{:02}_b{cap}", (rate * 100.0) as u64);
            println!(
                "  rate={rate:<4} B={cap}: {:>2} clean / {} numeric / {} errored \
                 ({} injected, {} retries, {} rollbacks, {} panics caught) in {:.2}s",
                o.clean,
                o.numeric_faulted,
                o.errored,
                o.injected,
                o.retries,
                o.rollbacks,
                o.panics_caught,
                o.wall_s
            );
            report.record(&format!("{key}_clean"), o.clean as f64);
            report.record(&format!("{key}_numeric_faulted"), o.numeric_faulted as f64);
            report.record(&format!("{key}_errored"), o.errored as f64);
            report.record(&format!("{key}_injected"), o.injected as f64);
            report.record(&format!("{key}_retries"), o.retries as f64);
            report.record(&format!("{key}_rollbacks"), o.rollbacks as f64);
            report.record(&format!("{key}_wall_s"), o.wall_s);

            // invariants — structural, independent of timing
            if o.mismatched > 0 {
                violations.push(format!(
                    "{key}: {} terminals carried non-bit-exact tokens",
                    o.mismatched
                ));
            }
            if o.clean + o.numeric_faulted + o.errored != N_REQUESTS as usize {
                violations.push(format!("{key}: a request lost its terminal"));
            }
            if !o.gauges_zero {
                violations.push(format!("{key}: gauges did not drain to zero"));
            }
            if o.cache_poisoned > 0 {
                violations.push(format!(
                    "{key}: {} poisoned snapshots reached the cache door with guards on",
                    o.cache_poisoned
                ));
            }
            if o.restarts > 0 {
                violations.push(format!("{key}: in-guard faults escalated to the supervisor"));
            }
            if rate == 0.0 && (o.clean != N_REQUESTS as usize || o.injected != 0) {
                violations.push(format!("{key}: zero-rate cell must be all-clean"));
            }
        }
    }

    section(&format!(
        "worker-kill soak: kill every Nth cycle (24 req x 8 tok, redrive budget {REDRIVE_BUDGET})"
    ));
    for &(kill_every, cap) in &KILL_CELLS {
        let o = run_kill_cell(kill_every, cap, &expected);
        let key = format!("kill{kill_every}_b{cap}");
        let resume_ms = if o.redrives_resumed > 0 {
            o.resume_seconds_total / o.redrives_resumed as f64 * 1e3
        } else {
            0.0
        };
        println!(
            "  kill/{kill_every} B={cap}: {:>2} clean ({} redriven) / {} failed \
             ({} kills, {} redrives, {} snapshots survived, {:.2}ms mean resume) in {:.2}s",
            o.clean,
            o.redriven_clean,
            o.worker_failed,
            o.kills,
            o.redrives,
            o.recovered_snapshots,
            resume_ms,
            o.wall_s
        );
        report.record(&format!("{key}_clean"), o.clean as f64);
        report.record(&format!("{key}_redriven_clean"), o.redriven_clean as f64);
        report.record(&format!("{key}_worker_failed"), o.worker_failed as f64);
        report.record(&format!("{key}_kills"), o.kills as f64);
        report.record(&format!("{key}_redrives"), o.redrives as f64);
        report.record(&format!("{key}_redrives_completed"), o.redrives_completed as f64);
        report.record(&format!("{key}_recovered_snapshots"), o.recovered_snapshots as f64);
        report.record(&format!("{key}_journal_redriven"), o.journal_redriven as f64);
        report.record(&format!("{key}_journal_failed"), o.journal_failed as f64);
        report.record(&format!("{key}_mean_resume_ms"), resume_ms);
        report.record(&format!("{key}_wall_s"), o.wall_s);

        violations.extend(o.stream_violations.iter().map(|v| format!("{key}: {v}")));
        if o.mismatched > 0 {
            violations.push(format!("{key}: {} terminals carried non-bit-exact tokens", o.mismatched));
        }
        if o.clean + o.worker_failed + o.mismatched != N_REQUESTS as usize {
            violations.push(format!(
                "{key}: {} clean + {} failed + {} mismatched != {N_REQUESTS} \
                 (a request lost its terminal)",
                o.clean, o.worker_failed, o.mismatched
            ));
        }
        if !o.gauges_zero {
            violations.push(format!("{key}: gauges did not drain to zero"));
        }
        if o.kills == 0 || o.redrives == 0 {
            violations.push(format!(
                "{key}: the cell never exercised the kill path ({} kills, {} redrives)",
                o.kills, o.redrives
            ));
        }
        if o.restarts != o.kills {
            violations.push(format!(
                "{key}: {} kills but {} restarts (a kill escaped the supervisor)",
                o.kills, o.restarts
            ));
        }
        if o.journal_redriven as u64 != o.redrives {
            violations.push(format!(
                "{key}: journal attributes {} redrives, coordinator counted {}",
                o.journal_redriven, o.redrives
            ));
        }
        if o.journal_failed != o.worker_failed {
            violations.push(format!(
                "{key}: journal attributes {} crash failures, {} WorkerFailed terminals",
                o.journal_failed, o.worker_failed
            ));
        }
        if o.redrives_completed != o.redriven_clean as u64 {
            violations.push(format!(
                "{key}: {} redrives_completed vs {} redriven clean terminals",
                o.redrives_completed, o.redriven_clean
            ));
        }
    }

    section("health-guard overhead at zero fault rate (plain model)");
    for &cap in &CAPS {
        let off = throughput(false, cap);
        let on = throughput(true, cap);
        let overhead = off / on - 1.0;
        println!(
            "  B={cap}: guards off {off:>9.0} tok/s, on {on:>9.0} tok/s \
             ({:+.1}% overhead)",
            overhead * 100.0
        );
        report.record(&format!("guards_off_tok_s_b{cap}"), off);
        report.record(&format!("guards_on_tok_s_b{cap}"), on);
        report.record(&format!("guard_overhead_b{cap}"), overhead);
    }

    match report.write() {
        Ok(path) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\nfailed to write bench report: {e}"),
    }

    if violations.is_empty() {
        println!("all soak invariants held");
    } else {
        for v in &violations {
            eprintln!("VIOLATION: {v}");
        }
        if hard_assert {
            panic!("{} chaos-soak invariant violations", violations.len());
        }
        eprintln!("WARNING: set CHAOS_SOAK_ASSERT=1 to hard-fail on these");
    }
}
